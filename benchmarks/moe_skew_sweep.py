"""Expert-skew sweep: how routing imbalance degrades MoE serving.

Synthesizes one ``ExpertRoutingTrace`` per zipf exponent, replays each on
the simulator (expert-parallel instance), and reports imbalance factor vs
TPOT/throughput — the scenario class the trace-driven MoE path opened
(every trace is also replayable on the real engine via
``ServingEngine(routing=trace)``).

  PYTHONPATH=src python benchmarks/moe_skew_sweep.py
"""
from repro.configs import get_config
from repro.core import (ClusterCfg, InstanceCfg, MoECfg, ParallelismCfg,
                        SchedulerCfg, simulate)
from repro.core.config import TPU_V5E
from repro.moe import register_routing
from repro.profiler import model_spec_from_arch
from repro.workload import ShareGPTConfig, SkewConfig, generate
from repro.workload.expert_skew import routing_for_model


def run(n_requests: int = 60,
        zipf_as=(0.0, 0.6, 1.2, 1.8), ep: int = 8):
    model = model_spec_from_arch(get_config("granite-moe-3b-a800m"))
    reqs = generate(ShareGPTConfig(n_requests=n_requests, rate=15.0,
                                   vocab=32000, seed=3))
    rows = []
    for a in zipf_as:
        name = f"skew-a{a}"
        trace = routing_for_model(
            model, SkewConfig(kind="zipf", zipf_a=a, period=512, seed=0))
        register_routing(name, trace)
        icfg = InstanceCfg(
            name="i0", hw=TPU_V5E, model=model, n_devices=8,
            parallelism=ParallelismCfg(tp=8, ep=ep),
            scheduler=SchedulerCfg(max_batch_size=48),
            moe=MoECfg(routing_trace=name))
        m = simulate(ClusterCfg((icfg,)), reqs)
        rows.append((a, trace.static_imbalance(ep), m))
    return rows


def main():
    rows = run()
    print(f"{'zipf_a':>6s} {'imb(ep)':>8s} {'TTFT(ms)':>9s} "
          f"{'TPOT(ms)':>9s} {'tok/s':>8s} {'hot exp':>7s}")
    for a, imb, m in rows:
        el = m["expert_load"]
        print(f"{a:6.1f} {imb:8.2f} {m['ttft_mean_s']*1e3:9.2f} "
              f"{m['tpot_mean_s']*1e3:9.2f} "
              f"{m['throughput_tok_s']:8.0f} {el['hot_expert']:>7d}")
    # the two sides of skew, both priced from the trace: prefill is
    # compute-bound and pays the hot shard's imbalance factor (TTFT up);
    # decode is weight-bandwidth-bound and touches fewer active experts
    # per iteration (TPOT down)
    imbs = [imb for _, imb, _ in rows]
    assert imbs == sorted(imbs)
    assert rows[-1][2]["ttft_mean_s"] > rows[0][2]["ttft_mean_s"]
    assert rows[-1][2]["tpot_mean_s"] < rows[0][2]["tpot_mean_s"]


if __name__ == "__main__":
    main()
