"""Speculative-decoding sweep: acceptance rate x draft size, sim-priced.

Synthesizes one ``AcceptanceTrace`` per target acceptance rate, replays
each on the simulator at several draft lengths, and reports the TPOT
speedup over vanilla decode plus the wasted-draft-token volume — the two
sides of the speculative-decoding economics: a spec step's cost is fixed
(k + 1 draft decodes + one k+1-token verification) while its progress is
the acceptance draw + 1, so low acceptance with a deep draft *slows
decoding down* (the wasted-compute crossover), while high acceptance
approaches a (mean accepted + 1)x speedup.  Every trace is also
replayable on the real engine via
``ServingEngine(spec=SpecDecodeCfg(acceptance=trace))``.

  PYTHONPATH=src python benchmarks/spec_decode_sweep.py
"""
import dataclasses

from repro.configs import get_config
from repro.core import (ClusterCfg, InstanceCfg, RouterCfg, SchedulerCfg,
                        SpecCfg, simulate)
from repro.core.config import TPU_V6E
from repro.profiler import model_spec_from_arch
from repro.spec import register_acceptance
from repro.workload import (AcceptanceConfig, ShareGPTConfig, generate,
                            synthesize_acceptance)


def run(n_requests: int = 40, alphas=(0.3, 0.6, 0.9), ks=(2, 4, 8),
        draft_scale: float = 0.25):
    model = model_spec_from_arch(get_config("llama3.1-8b"))
    reqs = generate(ShareGPTConfig(n_requests=n_requests, rate=15.0,
                                   vocab=32000, seed=3))

    def simulate_one(spec: SpecCfg, decode_tokens: int):
        icfg = InstanceCfg(
            name="i0", hw=TPU_V6E, model=model,
            scheduler=SchedulerCfg(max_batch_size=32,
                                   decode_tokens=decode_tokens),
            spec=spec)
        return simulate(ClusterCfg((icfg,), router=RouterCfg("round_robin")),
                        reqs)

    base = simulate_one(SpecCfg(), 1)
    rows = []
    for alpha in alphas:
        for k in ks:
            name = f"sweep-a{alpha}-k{k}"
            register_acceptance(name, synthesize_acceptance(
                AcceptanceConfig(alpha=alpha, k=k, period=256, seed=0)))
            m = simulate_one(
                SpecCfg(enabled=True, k=k, draft_scale=draft_scale,
                        acceptance_trace=name), k + 1)
            rows.append((alpha, k, m))
    return base, rows


def main():
    base, rows = run()
    print(f"vanilla TPOT {base['tpot_mean_s'] * 1e3:.2f} ms")
    print(f"{'alpha':>5s} {'k':>3s} {'TPOT(ms)':>9s} {'speedup':>8s} "
          f"{'acc rate':>8s} {'mean acc':>8s} {'wasted':>7s}")
    speedup = {}
    for alpha, k, m in rows:
        sd = m["spec_decode"]
        speedup[(alpha, k)] = base["tpot_mean_s"] / m["tpot_mean_s"]
        print(f"{alpha:5.1f} {k:3d} {m['tpot_mean_s'] * 1e3:9.2f} "
              f"{speedup[(alpha, k)]:8.2f} {sd['acceptance_rate']:8.2f} "
              f"{sd['mean_accepted_len']:8.2f} "
              f"{sd['wasted_draft_tokens']:7d}")
    alphas = sorted({a for a, _, _ in rows})
    ks = sorted({k for _, k, _ in rows})
    # acceptance buys speedup at every draft size
    for k in ks:
        ordered = [speedup[(a, k)] for a in alphas]
        assert ordered == sorted(ordered), (k, ordered)
    # the wasted-compute crossover: a spec step's cost is fixed while its
    # progress follows acceptance, so at low acceptance deep drafts burn
    # more verification compute than they advance — slower than the
    # shallow draft AND slower than not speculating at all — while at
    # high acceptance every draft size beats vanilla decode
    assert speedup[(alphas[0], ks[-1])] < speedup[(alphas[0], ks[0])]
    assert speedup[(alphas[0], ks[-1])] < 1.0
    assert all(speedup[(alphas[-1], k)] > 1.0 for k in ks)
    return rows


if __name__ == "__main__":
    main()
