"""Fig. 3 reproduction: simulation wall-clock for 100 ShareGPT requests
across the paper's nine configurations (paper: everything under 12
minutes; ours is an event-level pure-Python sim, so expect seconds), plus
two decode-heavy configurations (offline burst, 2048-token outputs) that
showcase the decode fast-forward.  Full-size models with analytical
TPU-v5e traces — the 'explore new hardware' mode.

Every configuration runs twice — fast path (default) and exact stepped
mode (``fast_path=False``) — each with a FRESH trace registry so the
shared interpolation memo cannot flatter whichever run goes second.  The
two runs are decision- and metric-identical (``tests/test_fast_path.py``);
only wall-clock and event counts differ.
"""
from __future__ import annotations

import json

import numpy as np

from repro.core import (ClusterCfg, InstanceCfg, MoECfg, NetworkCfg,
                        PrefixCacheCfg, RouterCfg, SchedulerCfg,
                        TraceRegistry, simulate)
from repro.core.config import TPU_V5E
from repro.profiler import model_spec_from_arch, profile_arch
from repro.configs import get_config
from repro.workload import ShareGPTConfig, generate
from repro.workload.sharegpt import Request

DENSE = "llama3.1-8b"
MOE = "phimini-moe"

CONFIGS = ("SD", "SM", "MD", "MM", "PDD", "PDM", "SD+PC", "SM+PC",
           "MM+EO", "SD-DH", "MD-DH")
#: configurations whose workload is decode-dominated (the >= 10x
#: fast-path acceptance target applies to these)
DECODE_HEAVY = ("SD-DH", "MD-DH")


def _inst(name, arch, trace, *, role="unified", pc=False, tp=8,
          offload="none"):
    from repro.core import ParallelismCfg
    spec = model_spec_from_arch(get_config(arch))
    return InstanceCfg(
        name=name, hw=TPU_V5E, model=spec, n_devices=tp,
        parallelism=ParallelismCfg(tp=tp,
                                   ep=tp if arch == MOE else 1),
        scheduler=SchedulerCfg(max_batch_size=64, max_batch_tokens=8192),
        prefix_cache=PrefixCacheCfg(enabled=pc),
        moe=MoECfg(offload=offload,
                   offload_fraction=0.5 if offload != "none" else 0.0),
        trace_name=trace)


def _decode_heavy_reqs(n_requests: int) -> list:
    """Offline burst: every request arrives within ~1s and decodes 2048
    tokens — simulated time is almost entirely lockstep decode."""
    rng = np.random.default_rng(7)
    arrivals = np.cumsum(rng.exponential(1.0 / 100.0, n_requests))
    vocab = get_config(DENSE).vocab
    return [Request(req_id=i, arrival=float(arrivals[i]),
                    prompt_tokens=rng.integers(0, vocab, 64).tolist(),
                    output_len=2048) for i in range(n_requests)]


def run(n_requests: int = 100):
    reqs_d = generate(ShareGPTConfig(n_requests=n_requests, rate=10.0,
                                     vocab=get_config(DENSE).vocab))
    reqs_m = generate(ShareGPTConfig(n_requests=n_requests, rate=10.0,
                                     vocab=get_config(MOE).vocab))
    reqs_dh = _decode_heavy_reqs(n_requests)

    def cluster(config):
        if config == "SD":
            return ClusterCfg((_inst("i0", DENSE, DENSE),)), reqs_d
        if config == "SM":
            return ClusterCfg((_inst("i0", MOE, MOE),)), reqs_m
        if config == "MD":
            return ClusterCfg((_inst("i0", DENSE, DENSE),
                               _inst("i1", DENSE, DENSE)),
                              router=RouterCfg("least_loaded")), reqs_d
        if config == "MM":
            return ClusterCfg((_inst("i0", MOE, MOE),
                               _inst("i1", MOE, MOE)),
                              router=RouterCfg("least_loaded")), reqs_m
        if config == "PDD":
            return ClusterCfg((_inst("p0", DENSE, DENSE, role="prefill"),
                               _inst("d0", DENSE, DENSE, role="decode")),
                              pd_map={"p0": ("d0",)}), reqs_d
        if config == "PDM":
            return ClusterCfg((_inst("p0", MOE, MOE, role="prefill"),
                               _inst("d0", MOE, MOE, role="decode")),
                              pd_map={"p0": ("d0",)}), reqs_m
        if config == "SD+PC":
            return ClusterCfg((_inst("i0", DENSE, DENSE, pc=True),)), reqs_d
        if config == "SM+PC":
            return ClusterCfg((_inst("i0", MOE, MOE, pc=True),)), reqs_m
        if config == "MM+EO":   # expert offloading study
            return ClusterCfg((_inst("i0", MOE, MOE, offload="pim"),
                               _inst("i1", MOE, MOE, offload="pim")),
                              router=RouterCfg("least_loaded")), reqs_m
        if config == "SD-DH":   # decode-heavy: single dense instance
            return ClusterCfg((_inst("i0", DENSE, DENSE),)), reqs_dh
        if config == "MD-DH":   # decode-heavy: 2 instances, least-loaded
            return ClusterCfg((_inst("i0", DENSE, DENSE),
                               _inst("i1", DENSE, DENSE)),
                              router=RouterCfg("least_loaded")), reqs_dh
        raise KeyError(config)

    def fresh_registry():
        registry = TraceRegistry()
        for arch in (DENSE, MOE):
            registry.register(arch, profile_arch(arch, hardware="tpu-v5e",
                                                 mode="analytical", tp=8))
        return registry

    rows = []
    for config in CONFIGS:
        ccfg, reqs = cluster(config)
        m = simulate(ccfg, reqs, traces=fresh_registry())
        m_exact = simulate(ccfg, reqs, traces=fresh_registry(),
                           fast_path=False)
        rows.append({
            "config": config,
            "decode_heavy": config in DECODE_HEAVY,
            "sim_wall_s": m["sim_wall_s"],
            "sim_events": m["sim_events"],
            "sim_wall_exact_s": m_exact["sim_wall_s"],
            "sim_events_exact": m_exact["sim_events"],
            "speedup": m_exact["sim_wall_s"] / m["sim_wall_s"],
            "events_per_s": m["sim_events"] / m["sim_wall_s"],
            "finished": m["finished"],
            "throughput_tok_s": m.get("throughput_tok_s"),
            "tpot_mean_ms": (m.get("tpot_mean_s") or 0) * 1e3,
            "ttft_mean_s": m.get("ttft_mean_s"),
        })
        print(f"fig3,{config},sim_wall={m['sim_wall_s']*1e6:.0f}us,"
              f"events={m['sim_events']},"
              f"exact_wall={m_exact['sim_wall_s']*1e6:.0f}us,"
              f"exact_events={m_exact['sim_events']},"
              f"speedup={rows[-1]['speedup']:.1f}x", flush=True)
    return {"rows": rows}


if __name__ == "__main__":
    print(json.dumps(run(), indent=1, default=float))
