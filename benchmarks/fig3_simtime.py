"""Fig. 3 reproduction: simulation wall-clock for 100 ShareGPT requests
across nine configurations (paper: everything under 12 minutes; ours is an
event-level pure-Python sim, so expect seconds). Full-size models with
analytical TPU-v5e traces — the 'explore new hardware' mode.
"""
from __future__ import annotations

import json

from repro.core import (ClusterCfg, InstanceCfg, MoECfg, NetworkCfg,
                        PrefixCacheCfg, RouterCfg, SchedulerCfg,
                        TraceRegistry, simulate)
from repro.core.config import TPU_V5E
from repro.profiler import model_spec_from_arch, profile_arch
from repro.configs import get_config
from repro.workload import ShareGPTConfig, generate

DENSE = "llama3.1-8b"
MOE = "phimini-moe"


def _inst(name, arch, trace, *, role="unified", pc=False, tp=8,
          offload="none"):
    from repro.core import ParallelismCfg
    spec = model_spec_from_arch(get_config(arch))
    return InstanceCfg(
        name=name, hw=TPU_V5E, model=spec, n_devices=tp,
        parallelism=ParallelismCfg(tp=tp,
                                   ep=tp if arch == MOE else 1),
        scheduler=SchedulerCfg(max_batch_size=64, max_batch_tokens=8192),
        prefix_cache=PrefixCacheCfg(enabled=pc),
        moe=MoECfg(offload=offload,
                   offload_fraction=0.5 if offload != "none" else 0.0),
        trace_name=trace)


def run(n_requests: int = 100):
    registry = TraceRegistry()
    for arch in (DENSE, MOE):
        registry.register(arch, profile_arch(arch, hardware="tpu-v5e",
                                             mode="analytical", tp=8))
    reqs_d = generate(ShareGPTConfig(n_requests=n_requests, rate=10.0,
                                     vocab=get_config(DENSE).vocab))
    reqs_m = generate(ShareGPTConfig(n_requests=n_requests, rate=10.0,
                                     vocab=get_config(MOE).vocab))

    def cluster(config):
        if config == "SD":
            return ClusterCfg((_inst("i0", DENSE, DENSE),)), reqs_d
        if config == "SM":
            return ClusterCfg((_inst("i0", MOE, MOE),)), reqs_m
        if config == "MD":
            return ClusterCfg((_inst("i0", DENSE, DENSE),
                               _inst("i1", DENSE, DENSE)),
                              router=RouterCfg("least_loaded")), reqs_d
        if config == "MM":
            return ClusterCfg((_inst("i0", MOE, MOE),
                               _inst("i1", MOE, MOE)),
                              router=RouterCfg("least_loaded")), reqs_m
        if config == "PDD":
            return ClusterCfg((_inst("p0", DENSE, DENSE, role="prefill"),
                               _inst("d0", DENSE, DENSE, role="decode")),
                              pd_map={"p0": ("d0",)}), reqs_d
        if config == "PDM":
            return ClusterCfg((_inst("p0", MOE, MOE, role="prefill"),
                               _inst("d0", MOE, MOE, role="decode")),
                              pd_map={"p0": ("d0",)}), reqs_m
        if config == "SD+PC":
            return ClusterCfg((_inst("i0", DENSE, DENSE, pc=True),)), reqs_d
        if config == "SM+PC":
            return ClusterCfg((_inst("i0", MOE, MOE, pc=True),)), reqs_m
        if config == "MM+EO":   # expert offloading study
            return ClusterCfg((_inst("i0", MOE, MOE, offload="pim"),
                               _inst("i1", MOE, MOE, offload="pim")),
                              router=RouterCfg("least_loaded")), reqs_m
        raise KeyError(config)

    rows = []
    for config in ("SD", "SM", "MD", "MM", "PDD", "PDM", "SD+PC", "SM+PC",
                   "MM+EO"):
        ccfg, reqs = cluster(config)
        m = simulate(ccfg, reqs)
        rows.append({
            "config": config, "sim_wall_s": m["sim_wall_s"],
            "sim_events": m["sim_events"], "finished": m["finished"],
            "throughput_tok_s": m.get("throughput_tok_s"),
            "tpot_mean_ms": (m.get("tpot_mean_s") or 0) * 1e3,
            "ttft_mean_s": m.get("ttft_mean_s"),
        })
        print(f"fig3,{config},sim_wall={m['sim_wall_s']*1e6:.0f}us,"
              f"events={m['sim_events']}", flush=True)
    return {"rows": rows}


if __name__ == "__main__":
    print(json.dumps(run(), indent=1, default=float))
