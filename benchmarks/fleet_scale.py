"""Fleet-scale simulation benchmark: N identical instances behind a
least-loaded router, diurnal / bursty arrivals, fast-path vs exact-path
wall-clock.

  PYTHONPATH=src python -m benchmarks.fleet_scale \
      [--instances 100] [--requests 1000] [--parity] \
      [--trace trace.json [--events events.json]] [--out BENCH_simtime.json]

``--autoscale`` switches to the multi-tenant SLO scenario: a two-class
tenant mix (interactive: high priority / tight SLOs; batch: low priority /
loose SLOs) over diurnal arrivals, served twice — by a FIXED fleet sized
at the trough, and by the same fleet with the SLO-aware autoscaler allowed
to grow to ``--instances``.  Reports per-tenant goodput (throughput
counting only SLO-met requests) and the instance-count timeline, and
asserts the autoscaler improves aggregate goodput over the fixed fleet.
With ``--parity`` the autoscaled run is repeated in exact stepped mode and
compared bit-for-bit (metrics, per-instance stats, action log, timeline).

Every instance shares one analytical TPU-v5e trace object, so the indexed
grids and the exact-key interpolation memo are shared fleet-wide.  Each
mode (fast / exact) gets a FRESH TraceRegistry: the memo is warmed by
whichever run goes first, so sharing one registry across timed runs would
flatter the second mode.

Writes per-config wall-clock, event counts, events/s, speedup and parity
to ``BENCH_simtime.json``.  ``--parity`` exits non-zero unless the fast
path reproduced the exact path's decisions and metrics bit-for-bit.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import sys

import numpy as np

from repro.core import (ClusterCfg, InstanceCfg, ParallelismCfg, RouterCfg,
                        SchedulerCfg, TenantClass, TraceRegistry, simulate)
from repro.core.config import TPU_V5E
from repro.profiler import model_spec_from_arch, profile_arch
from repro.configs import get_config
from repro.runtime.autoscale import AutoscaleCfg, SLOAutoscaler
from repro.workload import diurnal
from repro.workload.sharegpt import Request
from repro.workload.tenants import (TenantSpec, TenantWorkloadCfg,
                                    generate_tenants)

ARCH = "llama3.1-8b"


def _registry() -> TraceRegistry:
    r = TraceRegistry()
    r.register(ARCH, profile_arch(ARCH, hardware="tpu-v5e",
                                  mode="analytical", tp=8))
    return r


def _cluster(n_instances: int) -> ClusterCfg:
    spec = model_spec_from_arch(get_config(ARCH))
    insts = tuple(
        InstanceCfg(name=f"i{k}", hw=TPU_V5E, model=spec, n_devices=8,
                    parallelism=ParallelismCfg(tp=8),
                    scheduler=SchedulerCfg(max_batch_size=64,
                                           max_batch_tokens=8192),
                    trace_name=ARCH)
        for k in range(n_instances))
    return ClusterCfg(insts, router=RouterCfg("least_loaded"))


def _requests(arrivals, seed: int) -> list:
    rng = np.random.default_rng(seed)
    vocab = get_config(ARCH).vocab
    reqs = []
    for i, t in enumerate(arrivals):
        plen = int(rng.integers(32, 160))
        reqs.append(Request(
            req_id=i, arrival=float(t),
            prompt_tokens=rng.integers(0, vocab, plen).tolist(),
            output_len=int(rng.integers(256, 768))))
    return reqs


def _strip(metrics: dict) -> dict:
    m = dict(metrics)
    for k in ("sim_wall_s", "sim_events", "instances"):
        m.pop(k, None)
    return m


def _run_mode(ccfg, reqs, fast: bool):
    # fresh registry per mode: the interpolation memo must start cold
    m = simulate(ccfg, reqs, traces=_registry(), fast_path=fast)
    return m


def _sans_trace(metrics: dict) -> dict:
    """Everything tracing must leave untouched: all metrics except the
    wall clock and the attribution block tracing itself adds."""
    m = dict(metrics)
    m.pop("sim_wall_s", None)
    m.pop("attribution", None)
    return m


def _run_traced(ccfg, reqs, trace_out: str, events_out: str | None,
                baseline: dict) -> dict:
    """One extra fast run with the event recorder attached.  Tracing must
    be *invisible* to the simulation: every metric (decisions, per-instance
    stats, even the event count) must match the untraced run bit-for-bit."""
    from repro.obs import EventRecorder, write_chrome_trace
    rec = EventRecorder()
    m = simulate(ccfg, reqs, traces=_registry(), trace=rec)
    assert _sans_trace(m) == _sans_trace(baseline), \
        "tracing perturbed the simulation"
    write_chrome_trace(rec, trace_out)
    if events_out:
        rec.save(events_out)
    return {"wall_s": m["sim_wall_s"], "events_recorded": len(rec.events),
            "trace": trace_out}


def run(n_instances: int = 100, n_requests: int = 1000,
        parity: bool = False, exact: bool = True,
        trace_out: str | None = None,
        events_out: str | None = None) -> dict:
    # arrival shapes: amplitude ~1 gives deep troughs (long decode-only
    # stretches, the fast-forward's best case) and sharp peaks (router and
    # admission stress); "bursty" layers cv=4 clumping on top
    # span ~2 diurnal periods regardless of the request count
    rate = max(2.0, n_requests / 120.0)
    shapes = {
        "diurnal": diurnal(rate, n_requests, period=60.0, amplitude=0.95,
                           seed=1),
        "bursty": diurnal(rate, n_requests, period=60.0, amplitude=0.95,
                          cv=4.0, seed=2),
    }
    rows = []
    all_parity = True
    for config, arrivals in shapes.items():
        reqs = _requests(arrivals, seed=3)
        ccfg = _cluster(n_instances)
        m_fast = _run_mode(ccfg, reqs, fast=True)
        row = {
            "config": config,
            "instances": n_instances,
            "requests": n_requests,
            "finished": m_fast["finished"],
            "fast": {
                "wall_s": m_fast["sim_wall_s"],
                "events": m_fast["sim_events"],
                "events_per_s": m_fast["sim_events"] / m_fast["sim_wall_s"],
            },
        }
        if exact:
            m_exact = _run_mode(ccfg, reqs, fast=False)
            ok = (_strip(m_fast) == _strip(m_exact)
                  and all(m_fast["instances"][n] == m_exact["instances"][n]
                          for n in m_fast["instances"]))
            all_parity = all_parity and ok
            row["exact"] = {
                "wall_s": m_exact["sim_wall_s"],
                "events": m_exact["sim_events"],
                "events_per_s": (m_exact["sim_events"]
                                 / m_exact["sim_wall_s"]),
            }
            row["speedup"] = m_exact["sim_wall_s"] / m_fast["sim_wall_s"]
            # exact-equivalent throughput: exact-path events retired per
            # second of fast-path wall-clock
            row["equiv_events_per_s"] = (m_exact["sim_events"]
                                         / m_fast["sim_wall_s"])
            row["parity"] = ok
        if trace_out and config == "diurnal":
            row["traced"] = _run_traced(ccfg, reqs, trace_out, events_out,
                                        baseline=m_fast)
        rows.append(row)
        msg = (f"fleet,{config},inst={n_instances},reqs={n_requests},"
               f"fast={row['fast']['wall_s']:.3f}s/"
               f"{row['fast']['events']}ev")
        if exact:
            msg += (f",exact={row['exact']['wall_s']:.3f}s/"
                    f"{row['exact']['events']}ev,"
                    f"speedup={row['speedup']:.1f}x,parity={row['parity']}")
        if "traced" in row:
            msg += (f",traced={row['traced']['wall_s']:.3f}s/"
                    f"{row['traced']['events_recorded']}rec")
        print(msg, flush=True)
    return {"rows": rows, "parity": all_parity if exact else None}


# --------------------------------------------------------------------------
# --autoscale: multi-tenant SLO scenario, fixed fleet vs SLO-aware scaler
# --------------------------------------------------------------------------

INTERACTIVE = TenantClass("interactive", priority=10, slo_ttft_ms=1000.0,
                          slo_tpot_ms=60.0, weight=3.0)
BATCH = TenantClass("batch", priority=0, slo_ttft_ms=2000.0,
                    slo_tpot_ms=2000.0, weight=1.0)


def _tenant_workload(n_requests: int, rate: float, seed: int) -> list:
    return generate_tenants(TenantWorkloadCfg(
        tenants=(
            TenantSpec(INTERACTIVE, rate_share=2.0, mean_prompt=96,
                       max_prompt=192, mean_output=128, max_output=256),
            TenantSpec(BATCH, rate_share=1.0, mean_prompt=128,
                       max_prompt=256, mean_output=384, max_output=768)),
        n_requests=n_requests, rate=rate, seed=seed,
        arrival="diurnal", period_s=15.0, amplitude=0.95,
        vocab=get_config(ARCH).vocab))


def _goodput(metrics: dict) -> float:
    return sum(t.get("goodput_tok_s", 0.0)
               for t in metrics.get("tenants", {}).values())


def run_autoscale(n_instances: int = 16, n_requests: int = 200,
                  parity: bool = False, exact: bool = True) -> dict:
    """Fixed trough-sized fleet vs the same fleet under the SLO-aware
    autoscaler (allowed to grow to ``n_instances``), one tenant-mix
    diurnal workload.  The goodput improvement is asserted — this is the
    benchmark's acceptance gate, not just a report."""
    start_n = max(n_instances // 4, 1)
    # rate sized so the trough fleet is oversubscribed at the diurnal
    # peak: pressure the autoscaler can actually relieve
    rate = max(4.0, n_requests / 10.0)
    reqs = _tenant_workload(n_requests, rate, seed=3)

    def fleet(n):
        ccfg = _cluster(n)
        # small per-instance batch budget: instance capacity, not trace
        # speed, is the bottleneck — the knob that makes fleet SIZE the
        # variable under test
        sched = SchedulerCfg(max_batch_size=4, max_batch_tokens=1024,
                             policy="priority", share_guard_tokens=4096)
        return ClusterCfg(tuple(dataclasses.replace(i, scheduler=sched)
                                for i in ccfg.instances),
                          router=ccfg.router)

    def scaler():
        return SLOAutoscaler(AutoscaleCfg(
            interval_s=1.0, target_attainment=0.95, queue_high=2.0,
            queue_low=0.25, min_instances=start_n,
            max_instances=n_instances))

    m_fixed = simulate(fleet(start_n), reqs, traces=_registry())
    m_auto = simulate(fleet(start_n), reqs, traces=_registry(),
                      autoscale=scaler())
    g_fixed, g_auto = _goodput(m_fixed), _goodput(m_auto)
    a = m_auto["autoscale"]
    row = {
        "config": "autoscale",
        "instances_min": start_n, "instances_max": n_instances,
        "requests": n_requests, "rate": rate,
        "finished_fixed": m_fixed["finished"],
        "finished_autoscaled": m_auto["finished"],
        "goodput_fixed_tok_s": g_fixed,
        "goodput_autoscaled_tok_s": g_auto,
        "goodput_improvement": g_auto / max(g_fixed, 1e-9),
        "tenants_fixed": m_fixed.get("tenants", {}),
        "tenants_autoscaled": m_auto.get("tenants", {}),
        "n_scale_out": a["n_scale_out"], "n_scale_in": a["n_scale_in"],
        "instance_timeline": a["timeline"],
        "actions": a["actions"],
        "fast": {"wall_s": m_auto["sim_wall_s"],
                 "events": m_auto["sim_events"]},
    }
    print(f"fleet,autoscale,min={start_n},max={n_instances},"
          f"reqs={n_requests},goodput_fixed={g_fixed:.0f}tok/s,"
          f"goodput_auto={g_auto:.0f}tok/s,"
          f"improvement={row['goodput_improvement']:.2f}x,"
          f"out={a['n_scale_out']},in={a['n_scale_in']}", flush=True)
    assert g_auto > g_fixed, (
        f"autoscaler failed to improve goodput: fixed={g_fixed:.1f} "
        f"autoscaled={g_auto:.1f} tok/s")
    ok = True
    if exact:
        m_exact = simulate(fleet(start_n), reqs, traces=_registry(),
                           autoscale=scaler(), fast_path=False)
        ok = (_strip(m_auto) == _strip(m_exact)
              and set(m_auto["instances"]) == set(m_exact["instances"])
              and all(m_auto["instances"][n] == m_exact["instances"][n]
                      for n in m_auto["instances"]))
        row["exact"] = {"wall_s": m_exact["sim_wall_s"],
                        "events": m_exact["sim_events"]}
        row["speedup"] = m_exact["sim_wall_s"] / m_auto["sim_wall_s"]
        row["parity"] = ok
        print(f"fleet,autoscale,parity={ok},"
              f"speedup={row['speedup']:.1f}x", flush=True)
    if parity and not ok:
        raise SystemExit("autoscale parity FAILED")
    return {"rows": [row], "parity": ok if exact else None}


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--instances", type=int, default=100)
    ap.add_argument("--requests", type=int, default=1000)
    ap.add_argument("--parity", action="store_true",
                    help="exit non-zero unless fast == exact everywhere")
    ap.add_argument("--fast-only", action="store_true",
                    help="skip the exact-path runs (no speedup/parity)")
    ap.add_argument("--autoscale", action="store_true",
                    help="multi-tenant SLO scenario: fixed fleet vs the "
                         "SLO-aware autoscaler (goodput + instance-count "
                         "timeline; asserts the autoscaler wins)")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="also run the diurnal shape once with event "
                         "tracing and write a Perfetto-loadable Chrome "
                         "trace JSON (asserts tracing changed nothing)")
    ap.add_argument("--events", default=None, metavar="PATH",
                    help="with --trace: also save the raw event log "
                         "(re-exportable via python -m repro.obs)")
    ap.add_argument("--out", default="BENCH_simtime.json")
    args = ap.parse_args()
    if args.parity and args.fast_only:
        ap.error("--parity requires the exact runs (drop --fast-only)")
    if args.autoscale:
        if args.trace:
            ap.error("--trace applies to the fleet benchmark, not "
                     "--autoscale")
        out = run_autoscale(n_instances=args.instances,
                            n_requests=args.requests,
                            parity=args.parity, exact=not args.fast_only)
    else:
        out = run(n_instances=args.instances, n_requests=args.requests,
                  parity=args.parity, exact=not args.fast_only,
                  trace_out=args.trace, events_out=args.events)
    with open(args.out, "w") as f:
        json.dump(out, f, indent=1, default=float)
    print(f"fleet,wrote={args.out}", flush=True)
    if args.parity and not out["parity"]:
        print("fleet,parity=FAILED", file=sys.stderr, flush=True)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
