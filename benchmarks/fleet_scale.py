"""Fleet-scale simulation benchmark: N identical instances behind a
least-loaded router, diurnal / bursty arrivals, fast-path vs exact-path
wall-clock.

  PYTHONPATH=src python -m benchmarks.fleet_scale \
      [--instances 100] [--requests 1000] [--parity] [--out BENCH_simtime.json]

Every instance shares one analytical TPU-v5e trace object, so the indexed
grids and the exact-key interpolation memo are shared fleet-wide.  Each
mode (fast / exact) gets a FRESH TraceRegistry: the memo is warmed by
whichever run goes first, so sharing one registry across timed runs would
flatter the second mode.

Writes per-config wall-clock, event counts, events/s, speedup and parity
to ``BENCH_simtime.json``.  ``--parity`` exits non-zero unless the fast
path reproduced the exact path's decisions and metrics bit-for-bit.
"""
from __future__ import annotations

import argparse
import json
import sys

import numpy as np

from repro.core import (ClusterCfg, InstanceCfg, ParallelismCfg, RouterCfg,
                        SchedulerCfg, TraceRegistry, simulate)
from repro.core.config import TPU_V5E
from repro.profiler import model_spec_from_arch, profile_arch
from repro.configs import get_config
from repro.workload import diurnal
from repro.workload.sharegpt import Request

ARCH = "llama3.1-8b"


def _registry() -> TraceRegistry:
    r = TraceRegistry()
    r.register(ARCH, profile_arch(ARCH, hardware="tpu-v5e",
                                  mode="analytical", tp=8))
    return r


def _cluster(n_instances: int) -> ClusterCfg:
    spec = model_spec_from_arch(get_config(ARCH))
    insts = tuple(
        InstanceCfg(name=f"i{k}", hw=TPU_V5E, model=spec, n_devices=8,
                    parallelism=ParallelismCfg(tp=8),
                    scheduler=SchedulerCfg(max_batch_size=64,
                                           max_batch_tokens=8192),
                    trace_name=ARCH)
        for k in range(n_instances))
    return ClusterCfg(insts, router=RouterCfg("least_loaded"))


def _requests(arrivals, seed: int) -> list:
    rng = np.random.default_rng(seed)
    vocab = get_config(ARCH).vocab
    reqs = []
    for i, t in enumerate(arrivals):
        plen = int(rng.integers(32, 160))
        reqs.append(Request(
            req_id=i, arrival=float(t),
            prompt_tokens=rng.integers(0, vocab, plen).tolist(),
            output_len=int(rng.integers(256, 768))))
    return reqs


def _strip(metrics: dict) -> dict:
    m = dict(metrics)
    for k in ("sim_wall_s", "sim_events", "instances"):
        m.pop(k, None)
    return m


def _run_mode(ccfg, reqs, fast: bool):
    # fresh registry per mode: the interpolation memo must start cold
    m = simulate(ccfg, reqs, traces=_registry(), fast_path=fast)
    return m


def run(n_instances: int = 100, n_requests: int = 1000,
        parity: bool = False, exact: bool = True) -> dict:
    # arrival shapes: amplitude ~1 gives deep troughs (long decode-only
    # stretches, the fast-forward's best case) and sharp peaks (router and
    # admission stress); "bursty" layers cv=4 clumping on top
    # span ~2 diurnal periods regardless of the request count
    rate = max(2.0, n_requests / 120.0)
    shapes = {
        "diurnal": diurnal(rate, n_requests, period=60.0, amplitude=0.95,
                           seed=1),
        "bursty": diurnal(rate, n_requests, period=60.0, amplitude=0.95,
                          cv=4.0, seed=2),
    }
    rows = []
    all_parity = True
    for config, arrivals in shapes.items():
        reqs = _requests(arrivals, seed=3)
        ccfg = _cluster(n_instances)
        m_fast = _run_mode(ccfg, reqs, fast=True)
        row = {
            "config": config,
            "instances": n_instances,
            "requests": n_requests,
            "finished": m_fast["finished"],
            "fast": {
                "wall_s": m_fast["sim_wall_s"],
                "events": m_fast["sim_events"],
                "events_per_s": m_fast["sim_events"] / m_fast["sim_wall_s"],
            },
        }
        if exact:
            m_exact = _run_mode(ccfg, reqs, fast=False)
            ok = (_strip(m_fast) == _strip(m_exact)
                  and all(m_fast["instances"][n] == m_exact["instances"][n]
                          for n in m_fast["instances"]))
            all_parity = all_parity and ok
            row["exact"] = {
                "wall_s": m_exact["sim_wall_s"],
                "events": m_exact["sim_events"],
                "events_per_s": (m_exact["sim_events"]
                                 / m_exact["sim_wall_s"]),
            }
            row["speedup"] = m_exact["sim_wall_s"] / m_fast["sim_wall_s"]
            # exact-equivalent throughput: exact-path events retired per
            # second of fast-path wall-clock
            row["equiv_events_per_s"] = (m_exact["sim_events"]
                                         / m_fast["sim_wall_s"])
            row["parity"] = ok
        rows.append(row)
        msg = (f"fleet,{config},inst={n_instances},reqs={n_requests},"
               f"fast={row['fast']['wall_s']:.3f}s/"
               f"{row['fast']['events']}ev")
        if exact:
            msg += (f",exact={row['exact']['wall_s']:.3f}s/"
                    f"{row['exact']['events']}ev,"
                    f"speedup={row['speedup']:.1f}x,parity={row['parity']}")
        print(msg, flush=True)
    return {"rows": rows, "parity": all_parity if exact else None}


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--instances", type=int, default=100)
    ap.add_argument("--requests", type=int, default=1000)
    ap.add_argument("--parity", action="store_true",
                    help="exit non-zero unless fast == exact everywhere")
    ap.add_argument("--fast-only", action="store_true",
                    help="skip the exact-path runs (no speedup/parity)")
    ap.add_argument("--out", default="BENCH_simtime.json")
    args = ap.parse_args()
    if args.parity and args.fast_only:
        ap.error("--parity requires the exact runs (drop --fast-only)")
    out = run(n_instances=args.instances, n_requests=args.requests,
              parity=args.parity, exact=not args.fast_only)
    with open(args.out, "w") as f:
        json.dump(out, f, indent=1, default=float)
    print(f"fleet,wrote={args.out}", flush=True)
    if args.parity and not out["parity"]:
        print("fleet,parity=FAILED", file=sys.stderr, flush=True)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
