"""KV-tier sweep: eviction-policy x two-tenant diurnal mix, plus the
residency-aware-routing TTFT comparison on a cache-hot workload.

  PYTHONPATH=src python -m benchmarks.kv_tier_sweep \
      [--requests 160] [--parity] [--out BENCH_kvtier.json]

Two scenarios, both on small tier pools (a handful of device cache
blocks, host and SSD sized in blocks) so the HBM -> host -> SSD chain is
actually exercised:

* **policy sweep** — every registered eviction policy (lru / lfu /
  priority) serves the same two-tenant diurnal mix (interactive: high
  priority, small hot prefix set; batch: low priority, long tail of cold
  prefixes) on an autoscaled fleet behind ``kv_residency`` routing.
  Reports hit rate, per-tier hit tokens, transfer traffic and per-tenant
  goodput per policy.
* **routing demo** — a cache-hot workload whose shared prefixes have
  sunk to a deliberately slow SSD tier, served once under
  ``prefix_aware`` (chases the byte-identical match and pays the
  restore) and once under ``kv_residency`` (discounts the cold match by
  its restore cost and recomputes on an idle sibling).  Asserts the
  residency-aware router wins mean TTFT — this is the benchmark's
  acceptance gate, not just a report.

Each mode (fast / exact) gets a FRESH TraceRegistry, mirroring
``fleet_scale``: the interpolation memo is warmed by whichever run goes
first, so sharing one registry across timed runs would flatter the
second mode.  ``--parity`` re-runs every configuration in exact stepped
mode — including the autoscaled sweep runs — and exits non-zero unless
fast == exact bit-for-bit (metrics and per-instance stats, tier
counters included).
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import sys

import numpy as np

from repro.configs import get_config
from repro.core import (ClusterCfg, InstanceCfg, ParallelismCfg, RouterCfg,
                        SchedulerCfg, TenantClass, TraceRegistry, simulate)
from repro.core.config import TPU_V5E, PrefixCacheCfg
from repro.core.memory import MemoryModel
from repro.profiler import model_spec_from_arch, profile_arch
from repro.runtime.autoscale import AutoscaleCfg, SLOAutoscaler
from repro.runtime.prefix_cache import eviction_policies
from repro.workload import diurnal
from repro.workload.sharegpt import Request

ARCH = "llama3.1-8b"
BASE_TOKENS = 64          # shared-prefix length (multiple of block_tokens)
BLOCK = 16

INTERACTIVE = TenantClass("interactive", priority=10, slo_ttft_ms=1000.0,
                          slo_tpot_ms=60.0, weight=3.0)
BATCH = TenantClass("batch", priority=0, slo_ttft_ms=4000.0,
                    slo_tpot_ms=2000.0, weight=1.0)


def _registry() -> TraceRegistry:
    r = TraceRegistry()
    r.register(ARCH, profile_arch(ARCH, hardware="tpu-v5e",
                                  mode="analytical", tp=8))
    return r


def _cluster(n_instances: int, policy: str, router: str,
             device_blocks: int = 16, host_blocks: int = 8,
             ssd_blocks: int = 64, ssd_bw: float = 1e9) -> ClusterCfg:
    """Fleet with tier pools sized in cache BLOCKS (not fractions of a
    128 GB HBM), so the spill chain engages within a few dozen prefixes."""
    spec = model_spec_from_arch(get_config(ARCH))
    probe = InstanceCfg(name="probe", hw=TPU_V5E, model=spec, n_devices=8,
                        parallelism=ParallelismCfg(tp=8))
    mm = MemoryModel(probe)
    bpb = mm.bytes_per_block
    hw = dataclasses.replace(TPU_V5E, host_bw=2e9, ssd_bw=ssd_bw,
                             host_capacity=host_blocks * bpb,
                             ssd_capacity=ssd_blocks * bpb)
    pc = PrefixCacheCfg(enabled=True, block_tokens=BLOCK,
                        capacity_fraction=(device_blocks + 0.5)
                        / mm.total_blocks,
                        host_spill=True, ssd_spill=True,
                        eviction_policy=policy)
    insts = tuple(
        InstanceCfg(name=f"i{k}", hw=hw, model=spec, n_devices=8,
                    parallelism=ParallelismCfg(tp=8),
                    scheduler=SchedulerCfg(max_batch_size=8,
                                           max_batch_tokens=2048),
                    prefix_cache=pc, trace_name=ARCH)
        for k in range(n_instances))
    return ClusterCfg(insts, router=RouterCfg(router))


def _base(g: int, vocab: int, seed: int = 0):
    rng = np.random.default_rng(seed * 7919 + g)
    return rng.integers(0, vocab, BASE_TOKENS).tolist()


def _tenant_mix(n_requests: int, rate: float, seed: int) -> list:
    """Two-tenant diurnal mix over shared prefixes: interactive traffic
    concentrates on 4 hot bases (the set a good policy keeps device-
    resident), batch spreads over 16 cold ones.  Unique tails stay under
    one block so only the shared bases become radix nodes."""
    vocab = get_config(ARCH).vocab
    rng = np.random.default_rng(seed)
    arrivals = diurnal(rate, n_requests, period=30.0, amplitude=0.9,
                       seed=seed)
    hot = [_base(g, vocab) for g in range(4)]
    cold = [_base(100 + g, vocab) for g in range(16)]
    reqs = []
    for i, t in enumerate(arrivals):
        if rng.random() < 0.6:
            ten, base = INTERACTIVE, hot[int(rng.integers(len(hot)))]
            out = int(rng.integers(16, 48))
        else:
            ten, base = BATCH, cold[int(rng.integers(len(cold)))]
            out = int(rng.integers(32, 96))
        tail = rng.integers(0, vocab, int(rng.integers(4, 12))).tolist()
        reqs.append(Request(
            req_id=i, arrival=float(t), prompt_tokens=base + tail,
            output_len=out, tenant=ten.name, priority=ten.priority,
            slo_ttft_ms=ten.slo_ttft_ms, slo_tpot_ms=ten.slo_tpot_ms,
            weight=ten.weight))
    return reqs


def _cache_hot(n_groups: int = 40, seed: int = 5) -> list:
    """Populate-then-revisit workload: phase A inserts one prefix per
    group (paced, so cache-borrowing load ties spread the groups evenly
    over the fleet), phase B revisits every group twice after the
    prefixes have sunk to SSD — the group count is sized well past the
    fleet's device cache, so a match-chasing router pays the SSD restore
    on nearly every revisit.  Revisit sweeps are whole passes over the
    groups (all firsts, then all seconds), so promotes from one group
    have evicted the previous one again by the time it comes back."""
    vocab = get_config(ARCH).vocab
    rng = np.random.default_rng(seed)
    reqs = []
    rid = 0
    for g in range(n_groups):
        tail = rng.integers(0, vocab, 8).tolist()
        reqs.append(Request(req_id=rid, arrival=g * 1.0,
                            prompt_tokens=_base(g, vocab) + tail,
                            output_len=8))
        rid += 1
    t0 = n_groups * 1.0 + 20.0
    for visit in range(2):
        for g in range(n_groups):
            tail = rng.integers(0, vocab, 8).tolist()
            reqs.append(Request(
                req_id=rid, arrival=t0 + (visit * n_groups + g) * 0.15,
                prompt_tokens=_base(g, vocab) + tail, output_len=8))
            rid += 1
    return reqs


def _strip(metrics: dict) -> dict:
    m = dict(metrics)
    for k in ("sim_wall_s", "sim_events", "instances"):
        m.pop(k, None)
    return m


def _bit_identical(m_fast: dict, m_exact: dict) -> bool:
    return (_strip(m_fast) == _strip(m_exact)
            and set(m_fast["instances"]) == set(m_exact["instances"])
            and all(m_fast["instances"][n] == m_exact["instances"][n]
                    for n in m_fast["instances"]))


def _cache_rollup(metrics: dict) -> dict:
    hits = sum(s["prefix_cache"]["hits"]
               for s in metrics["instances"].values() if "prefix_cache" in s)
    misses = sum(s["prefix_cache"]["misses"]
                 for s in metrics["instances"].values()
                 if "prefix_cache" in s)
    evictions = sum(s["prefix_cache"]["evictions"]
                    for s in metrics["instances"].values()
                    if "prefix_cache" in s)
    return {"hits": hits, "misses": misses,
            "hit_rate": hits / max(hits + misses, 1),
            "evictions": evictions}


# --------------------------------------------------------------------------
# scenario 1: eviction-policy sweep, two-tenant diurnal mix, autoscaled
# --------------------------------------------------------------------------

def _scaler() -> SLOAutoscaler:
    return SLOAutoscaler(AutoscaleCfg(
        interval_s=1.0, target_attainment=0.95, queue_high=2.0,
        queue_low=0.25, min_instances=2, max_instances=4))


def run_sweep(n_requests: int, exact: bool) -> tuple:
    rate = max(2.0, n_requests / 40.0)
    reqs = _tenant_mix(n_requests, rate, seed=3)
    rows = []
    all_parity = True
    for policy in eviction_policies():
        ccfg = _cluster(2, policy, router="kv_residency")
        m_fast = simulate(ccfg, reqs, traces=_registry(),
                          autoscale=_scaler())
        kv = m_fast.get("kv_tiers", {})
        row = {
            "config": "sweep", "policy": policy, "requests": n_requests,
            "finished": m_fast["finished"],
            "ttft_mean_s": m_fast["ttft_mean_s"],
            "cache": _cache_rollup(m_fast),
            "hit_tokens": kv.get("hit_tokens"),
            "transfers": kv.get("transfers"),
            "residency_blocks": kv.get("residency_blocks"),
            "tenants": {t: {"goodput_tok_s": v.get("goodput_tok_s"),
                            "slo_attainment": v.get("slo_attainment")}
                        for t, v in m_fast.get("tenants", {}).items()},
            "n_scale_out": m_fast["autoscale"]["n_scale_out"],
        }
        if exact:
            m_exact = simulate(ccfg, reqs, traces=_registry(),
                               autoscale=_scaler(), fast_path=False)
            ok = _bit_identical(m_fast, m_exact)
            all_parity = all_parity and ok
            row["parity"] = ok
        rows.append(row)
        msg = (f"kvtier,sweep,policy={policy},reqs={n_requests},"
               f"hit_rate={row['cache']['hit_rate']:.2f},"
               f"evictions={row['cache']['evictions']},"
               f"ttft={row['ttft_mean_s']:.3f}s")
        if exact:
            msg += f",parity={row['parity']}"
        print(msg, flush=True)
    return rows, all_parity


# --------------------------------------------------------------------------
# scenario 2: prefix_aware vs kv_residency on a cache-hot, SSD-cold fleet
# --------------------------------------------------------------------------

def run_routing(exact: bool) -> tuple:
    """Same workload, same fleet, two routers.  The SSD tier is priced
    slow (1 MB/s, so a 4-block restore costs ~1 s) and the fleet's whole
    device cache holds only 8 of the 40 prefix groups, so
    ``prefix_aware`` keeps chasing the byte-identical but SSD-cold match
    (a longest-match tie always resolves to the stale copy) and eats the
    restore, while ``kv_residency`` discounts those matches below the
    recompute threshold, spreads first revisits across idle siblings,
    and routes second revisits to the freshly recomputed
    device-resident copies."""
    reqs = _cache_hot()
    rows = {}
    all_parity = True
    for router in ("prefix_aware", "kv_residency"):
        ccfg = _cluster(4, "lru", router=router, device_blocks=8,
                        host_blocks=4, ssd_blocks=256, ssd_bw=1e6)
        m_fast = simulate(ccfg, reqs, traces=_registry())
        kv = m_fast.get("kv_tiers", {})
        row = {
            "config": "routing", "router": router,
            "requests": len(reqs), "finished": m_fast["finished"],
            "ttft_mean_s": m_fast["ttft_mean_s"],
            "ttft_p99_s": m_fast["ttft_p99_s"],
            "cache": _cache_rollup(m_fast),
            "hit_tokens": kv.get("hit_tokens"),
            "transfers": kv.get("transfers"),
        }
        if exact:
            m_exact = simulate(ccfg, reqs, traces=_registry(),
                               fast_path=False)
            ok = _bit_identical(m_fast, m_exact)
            all_parity = all_parity and ok
            row["parity"] = ok
        rows[router] = row
        msg = (f"kvtier,routing,router={router},"
               f"ttft={row['ttft_mean_s']:.3f}s,"
               f"hit_rate={row['cache']['hit_rate']:.2f}")
        if exact:
            msg += f",parity={row['parity']}"
        print(msg, flush=True)
    pa, kvr = rows["prefix_aware"], rows["kv_residency"]
    speedup = pa["ttft_mean_s"] / max(kvr["ttft_mean_s"], 1e-9)
    print(f"kvtier,routing,ttft_speedup={speedup:.2f}x", flush=True)
    assert kvr["ttft_mean_s"] < pa["ttft_mean_s"], (
        "kv_residency failed to beat prefix_aware TTFT on the cache-hot "
        f"workload: {kvr['ttft_mean_s']:.4f}s vs {pa['ttft_mean_s']:.4f}s")
    return [pa, kvr, {"config": "routing", "ttft_speedup": speedup}], \
        all_parity


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--requests", type=int, default=160)
    ap.add_argument("--parity", action="store_true",
                    help="exit non-zero unless fast == exact everywhere")
    ap.add_argument("--fast-only", action="store_true",
                    help="skip the exact-path runs (no parity)")
    ap.add_argument("--out", default="BENCH_kvtier.json")
    args = ap.parse_args()
    if args.parity and args.fast_only:
        ap.error("--parity requires the exact runs (drop --fast-only)")
    exact = not args.fast_only
    sweep_rows, sweep_ok = run_sweep(args.requests, exact)
    routing_rows, routing_ok = run_routing(exact)
    parity = (sweep_ok and routing_ok) if exact else None
    out = {"rows": sweep_rows + routing_rows, "parity": parity}
    with open(args.out, "w") as f:
        json.dump(out, f, indent=1, default=float)
    print(f"kvtier,wrote={args.out}", flush=True)
    if args.parity and not parity:
        print("kvtier,parity=FAILED", file=sys.stderr, flush=True)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
