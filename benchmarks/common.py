"""Shared helpers for the benchmark suite."""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.core import (ClusterCfg, InstanceCfg, ModelSpec, NetworkCfg,
                        PrefixCacheCfg, RouterCfg, SchedulerCfg, TraceRegistry)
from repro.core.config import (ENGINE_HW, RTX3090, HardwareSpec,
                               engine_scheduler_cfg)
from repro.profiler import model_spec_from_arch
from repro.configs import get_config

DENSE_TINY = "llama3.1-8b-tiny"
MOE_TINY = "phimini-moe-tiny"


def engine_matched_instance(name: str, arch: str, *, role: str = "unified",
                            max_batch: int = 4, prefix_cache: bool = False,
                            trace_name: Optional[str] = None) -> InstanceCfg:
    """Sim instance configured to mirror a ServingEngine(max_batch, 512)."""
    spec = model_spec_from_arch(get_config(arch))
    return InstanceCfg(
        name=name, hw=ENGINE_HW, model=spec, n_devices=1, role=role,
        scheduler=engine_scheduler_cfg(max_batch),
        prefix_cache=PrefixCacheCfg(enabled=prefix_cache, block_tokens=16,
                                    capacity_fraction=0.5),
        trace_name=trace_name or arch)


def pct_err(sim: float, real: float) -> float:
    if real is None or sim is None or real == 0:
        return float("nan")
    return 100.0 * abs(sim - real) / abs(real)
