"""Shared helpers for the benchmark suite."""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.core import (ClusterCfg, InstanceCfg, ModelSpec, NetworkCfg,
                        PrefixCacheCfg, RouterCfg, SchedulerCfg, TraceRegistry)
from repro.core.config import RTX3090, HardwareSpec
from repro.profiler import model_spec_from_arch
from repro.configs import get_config

ENGINE_HW = HardwareSpec(    # matches the CPU engine environment
    name="cpu-engine", peak_flops=5e10, hbm_bw=20e9, hbm_capacity=8e9,
    link_bw=8e9, host_bw=8e9)

DENSE_TINY = "llama3.1-8b-tiny"
MOE_TINY = "phimini-moe-tiny"


def engine_matched_instance(name: str, arch: str, *, role: str = "unified",
                            max_batch: int = 4, prefix_cache: bool = False,
                            trace_name: Optional[str] = None) -> InstanceCfg:
    """Sim instance configured to mirror a ServingEngine(max_batch, 512)."""
    spec = model_spec_from_arch(get_config(arch))
    return InstanceCfg(
        name=name, hw=ENGINE_HW, model=spec, n_devices=1, role=role,
        scheduler=SchedulerCfg(
            max_batch_size=max_batch, max_batch_tokens=1 << 16,
            chunked_prefill=False, prefill_exclusive=True,
            bucket_prefill=True, decode_pad_to=max_batch),
        prefix_cache=PrefixCacheCfg(enabled=prefix_cache, block_tokens=16,
                                    capacity_fraction=0.5),
        trace_name=trace_name or arch)


def pct_err(sim: float, real: float) -> float:
    if real is None or sim is None or real == 0:
        return float("nan")
    return 100.0 * abs(sim - real) / abs(real)
