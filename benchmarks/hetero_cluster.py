"""Heterogeneous-cluster sweep: mixed accelerators under one router.

Prices the same workload on clusters that mix hardware by name
(``InstanceCfg.hw_name`` -> ``repro.hw`` registry traces), sweeping:

* homogeneous baselines (all-GPU, all-TPU),
* a mixed fleet under each routing policy (round_robin vs least_loaded vs
  hardware_aware) — quantifying what throughput-weighted routing buys,
* P/D disaggregation with GPU-class prefill + TPU-class decode instances
  (and the swap), the paper's mixed-accelerator headline scenario.

  PYTHONPATH=src python benchmarks/hetero_cluster.py [--quick]
  PYTHONPATH=src python benchmarks/hetero_cluster.py --traces traces/

With ``--traces`` any profiled HardwareTrace artifacts in the directory
override the synthetic fallback for their device names.
"""
from __future__ import annotations

import argparse
import json

from repro.configs import get_config
from repro.core import (ClusterCfg, InstanceCfg, RouterCfg, SchedulerCfg,
                        simulate)
from repro.hw import HardwareRegistry, get_hw
from repro.profiler import model_spec_from_arch
from repro.workload import ShareGPTConfig, generate

ARCH = "llama3.1-8b-tiny"


def inst(name: str, hw_name: str, model, role: str = "unified",
         max_batch: int = 16) -> InstanceCfg:
    return InstanceCfg(
        name=name, hw=None, model=model, role=role, hw_name=hw_name,
        scheduler=SchedulerCfg(max_batch_size=max_batch,
                               max_batch_tokens=4096,
                               chunked_prefill=True, prefill_chunk=512))


def run_cluster(label: str, instances, router: str, reqs, hw,
                pd_map=None) -> dict:
    cfg = ClusterCfg(instances=tuple(instances),
                     router=RouterCfg(router, model_affinity=False),
                     pd_map=pd_map)
    m = simulate(cfg, reqs, hw=hw)
    per_inst = {n: {"hw": s["hw"], "tokens": s["tokens"],
                    "busy_s": round(s["busy_s"], 4)}
                for n, s in m["instances"].items()}
    # per-pair link parameters are derived from the endpoint devices'
    # interconnects (min-bw rule) — a mixed-device pair must see the
    # slower NIC, never a cluster-global constant
    hw_of = {i.name: i.hw_name for i in instances}

    def egress(dev: str) -> float:
        # the floor the link was actually derived from: a loaded artifact's
        # measured interconnect when one is registered, else the named spec
        if hw is not None and dev in hw.names():
            return hw.get(dev).interconnect.inter_instance_bw
        return get_hw(dev).inter_instance_bw

    links = {}
    for pair, v in m.get("network_links", {}).items():
        links[pair] = {"bw_gbps": v["bw"] / 1e9,
                       "gb_moved": v["bytes"] / 1e9}
        a, b = pair.split("<->")
        if a in hw_of and b in hw_of:
            floor = min(egress(hw_of[a]), egress(hw_of[b]))
            assert v["bw"] <= floor + 1e-6, \
                f"link {pair} faster than its slower endpoint"
    row = {"cluster": label, "router": router,
           "throughput_tok_s": round(m["throughput_tok_s"], 1),
           "ttft_mean_ms": round((m.get("ttft_mean_s") or 0) * 1e3, 2),
           "instances": per_inst, "links": links}
    print(f"{label:28s} router={router:14s} "
          f"tput={row['throughput_tok_s']:10.1f} tok/s", flush=True)
    return row


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--traces", default=None,
                    help="directory of HardwareTrace artifacts to load")
    ap.add_argument("--n", type=int, default=None)
    args = ap.parse_args()

    hw = HardwareRegistry()
    if args.traces:
        print("loaded traces:", hw.load_dir(args.traces))

    model = model_spec_from_arch(get_config(ARCH))
    n = args.n or (60 if args.quick else 200)
    reqs = generate(ShareGPTConfig(
        n_requests=n, rate=200.0, vocab=get_config(ARCH).vocab,
        mean_prompt=300, mean_output=60, max_prompt=2000, max_output=200))

    rows = []
    # homogeneous baselines
    rows.append(run_cluster(
        "2x rtx3090", [inst("g0", "rtx3090", model),
                       inst("g1", "rtx3090", model)],
        "round_robin", reqs, hw))
    rows.append(run_cluster(
        "2x tpu-v6e", [inst("t0", "tpu-v6e", model),
                       inst("t1", "tpu-v6e", model)],
        "round_robin", reqs, hw))
    # mixed fleet: routing policy sweep
    mixed = [inst("g0", "rtx3090", model), inst("t0", "tpu-v6e", model)]
    for router in ("round_robin", "least_loaded", "hardware_aware"):
        rows.append(run_cluster("rtx3090 + tpu-v6e", mixed, router,
                                reqs, hw))
    if not args.quick:
        # P/D disaggregation across accelerator classes
        rows.append(run_cluster(
            "PD: gpu prefill, tpu decode",
            [inst("p0", "rtx3090", model, role="prefill"),
             inst("d0", "tpu-v6e", model, role="decode")],
            "round_robin", reqs, hw, pd_map={"p0": ("d0",)}))
        rows.append(run_cluster(
            "PD: tpu prefill, gpu decode",
            [inst("p0", "tpu-v6e", model, role="prefill"),
             inst("d0", "rtx3090", model, role="decode")],
            "round_robin", reqs, hw, pd_map={"p0": ("d0",)}))
    print(json.dumps({"rows": rows}, indent=1, default=float))


if __name__ == "__main__":
    main()
