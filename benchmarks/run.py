"""Benchmark harness: one function per paper table/figure.

  python -m benchmarks.run [--quick]

Prints ``name,metric=value`` CSV lines per benchmark and writes the full
JSON to results/bench_results.json.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma-separated subset: capabilities,table3,fig2,"
                         "fig3,roofline")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None

    out = {}
    t_total = time.time()

    def want(name):
        return only is None or name in only

    if want("capabilities"):
        from benchmarks.capabilities import run as caps
        t0 = time.time()
        out["capabilities"] = caps()
        print(f"capabilities,elapsed_s={time.time()-t0:.1f}", flush=True)

    if want("fig3"):
        from benchmarks.fig3_simtime import run as fig3
        t0 = time.time()
        out["fig3_simtime"] = fig3(n_requests=100)
        print(f"fig3,elapsed_s={time.time()-t0:.1f}", flush=True)

    if want("table3"):
        from benchmarks.table3_integration import run as table3
        t0 = time.time()
        out["table3_integration"] = table3()
        print(f"table3,elapsed_s={time.time()-t0:.1f}", flush=True)

    if want("fig2"):
        from benchmarks.fig2_fidelity import run as fig2
        t0 = time.time()
        out["fig2_fidelity"] = fig2(quick=args.quick)
        print(f"fig2,elapsed_s={time.time()-t0:.1f},"
              f"mean_err={out['fig2_fidelity']['mean_err_pct']:.2f}%",
              flush=True)

    if want("roofline"):
        from benchmarks.roofline_report import run as roofline
        out["roofline"] = roofline()

    out["total_elapsed_s"] = time.time() - t_total
    os.makedirs("results", exist_ok=True)
    with open("results/bench_results.json", "w") as f:
        json.dump(out, f, indent=1, default=float)
    print(f"bench,total_s={out['total_elapsed_s']:.1f},"
          f"wrote=results/bench_results.json", flush=True)


if __name__ == "__main__":
    main()
