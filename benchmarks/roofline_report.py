"""Roofline table from the dry-run records (EXPERIMENTS.md §Roofline)."""
from __future__ import annotations

import json
import os

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results",
                       "dryrun_baseline.jsonl")


def load(path: str = RESULTS):
    recs = []
    if not os.path.exists(path):
        return recs
    with open(path) as f:
        for line in f:
            try:
                recs.append(json.loads(line))
            except Exception:
                pass
    # dedupe: keep the last record per cell
    seen = {}
    for r in recs:
        seen[(r["arch"], r["shape"], r["multi_pod"],
              r.get("attn_impl", "flash"))] = r
    return list(seen.values())


def table(recs, multi_pod: bool = False):
    rows = []
    for r in recs:
        if r["status"] != "ok" or r["multi_pod"] != multi_pod:
            continue
        roof = r["roofline"]
        terms = {"compute": roof["t_compute_s"],
                 "memory": roof["t_memory_s"],
                 "collective": roof["t_collective_s"]}
        dominant = max(terms, key=terms.get)
        bound = max(terms.values())
        rows.append({
            "arch": r["arch"], "shape": r["shape"],
            "t_compute_s": roof["t_compute_s"],
            "t_memory_s": roof["t_memory_s"],
            "t_collective_s": roof["t_collective_s"],
            "bottleneck": dominant,
            "roofline_frac": roof["t_compute_s"] / max(bound, 1e-30),
            "useful_flops_frac": r.get("useful_flops_frac"),
            "temp_gb": r.get("memory", {}).get("temp_size_in_bytes", 0) / 1e9,
            "compile_s": r.get("compile_s"),
        })
    rows.sort(key=lambda x: (x["arch"], x["shape"]))
    return rows


def run():
    recs = load()
    rows = table(recs, multi_pod=False)
    for r in rows:
        print(f"roofline,{r['arch']},{r['shape']},bottleneck={r['bottleneck']},"
              f"frac={r['roofline_frac']:.3f},useful={r['useful_flops_frac']:.2f}",
              flush=True)
    n_ok = len(rows)
    n_skip = sum(1 for r in recs if r["status"] == "skipped"
                 and not r["multi_pod"])
    worst = sorted(rows, key=lambda r: r["roofline_frac"])[:3]
    return {"rows": rows, "n_ok": n_ok, "n_skipped": n_skip,
            "worst_cells": [(w["arch"], w["shape"]) for w in worst]}


if __name__ == "__main__":
    print(json.dumps(run(), indent=1, default=float))
