"""Fig. 2 reproduction: simulator vs REAL serving engine across five system
configurations (S/M/PD x dense/MoE, +prefix cache), reporting TPOT / ITL /
throughput and the relative error. Paper claims <5% (avg 1.9%).

Both sides run through the SAME ``repro.runtime`` scheduler / router /
prefix-cache / P-D code path (``simulate`` -> SimBackend, ``ServeDriver`` ->
JaxBackend), so every dispatch decision is identical by construction (see
tests/test_runtime_parity.py) and the reported error isolates the hardware
model. Run on a quiet machine: the real engine is wall-clock timed.

``--kernels`` additionally sweeps hwtrace/3 kernel sub-buckets (per-kernel
latencies; ``repro.profiler.kernel_profiler``) and reports, for every
measured whole-iteration bucket, the gap between the measured iteration
and the kernel composition ``L*attention + L*ffn + head`` plus each
kernel's share of it — attributing fidelity error to a specific kernel
(e.g. "decode error comes from attention at long context") instead of
one opaque per-config percentage.
"""
from __future__ import annotations

import json
import time

from benchmarks.common import (DENSE_TINY, MOE_TINY, engine_matched_instance,
                               pct_err)
from repro.configs import get_config
from repro.core import ClusterCfg, NetworkCfg, RouterCfg, TraceRegistry, \
    simulate
from repro.hw.trace import kern_op
from repro.profiler import model_spec_from_arch
from repro.profiler.runtime_profiler import runtime_trace
from repro.serve import DriverCfg, ServeDriver, ServingEngine
from repro.workload import ShareGPTConfig, generate

N_REQ = 36
RATE = 8.0


def _workload(vocab: int, seed: int = 7, share: float = 0.0):
    reqs = generate(ShareGPTConfig(
        n_requests=N_REQ, rate=RATE, vocab=vocab, seed=seed,
        mean_prompt=90, mean_output=24, sigma_prompt=0.6, sigma_output=0.5,
        max_prompt=230, max_output=40, share_fraction=share,
        n_conversations=4))
    return reqs


def _run_engine(config: str, arch: str, reqs):
    cfg = get_config(arch)
    kw = dict(max_batch=4, max_len=512)
    if config.startswith("S"):
        engines = [ServingEngine(cfg, name="e0",
                                 prefix_cache=config.endswith("PC"), **kw)]
        pd = None
    elif config.startswith("M"):
        e0 = ServingEngine(cfg, name="e0", **kw)
        engines = [e0, ServingEngine(cfg, params=e0.params, name="e1", **kw)]
        pd = None
    else:  # PD
        p0 = ServingEngine(cfg, name="p0", role="prefill", **kw)
        engines = [p0, ServingEngine(cfg, params=p0.params, name="d0",
                                     role="decode", **kw)]
        pd = {"p0": ("d0",)}
    drv = ServeDriver(engines, DriverCfg(), pd_map=pd)
    return drv.run(reqs)


def _run_sim(config: str, arch: str, reqs, registry):
    pc = config.endswith("PC")
    if config.startswith("S"):
        insts = (engine_matched_instance("e0", arch, prefix_cache=pc),)
        pd = None
    elif config.startswith("M"):
        insts = (engine_matched_instance("e0", arch),
                 engine_matched_instance("e1", arch))
        pd = None
    else:
        insts = (engine_matched_instance("p0", arch, role="prefill"),
                 engine_matched_instance("d0", arch, role="decode"))
        pd = {"p0": ("d0",)}
    ccfg = ClusterCfg(instances=insts, router=RouterCfg("round_robin"),
                      network=NetworkCfg(inter_instance_bw=16e9), pd_map=pd)
    return simulate(ccfg, reqs, traces=registry)


def kernel_attribution(tr, arch: str, backend: str = "reference"):
    """Per-kernel error attribution: for every measured whole-iteration
    bucket with full kernel coverage, the measured latency, the kernel
    composition ``L*attention + L*ffn + head`` (PerfModel's kernel tier),
    the gap between them (framework/scheduling overhead the kernel tier
    cannot see — or a mispriced kernel), and each kernel's share of the
    composition.  The share column is what turns one opaque error
    percentage into "the attention kernel at context 256"."""
    spec = model_spec_from_arch(get_config(arch))
    L = spec.n_layers
    names = ("attention", "moe_gmm" if spec.is_moe else "mlp", "head")
    rows = []
    for phase in ("prefill", "decode"):
        for p in tr._grid("iter", phase):
            vals = [tr.interpolate(kern_op(backend, kn), phase,
                                   p.tokens, p.context) for kn in names]
            if any(v is None for v in vals):
                continue
            parts = {names[0]: L * vals[0], names[1]: L * vals[1],
                     names[2]: vals[2]}
            comp = sum(parts.values())
            rows.append({
                "phase": phase, "tokens": p.tokens, "context": p.context,
                "iter_ms": p.latency_s * 1e3, "kernel_sum_ms": comp * 1e3,
                "gap_pct": 100.0 * (comp - p.latency_s) / p.latency_s,
                "share": {kn: v / comp for kn, v in parts.items()},
            })
    return rows


def run(quick: bool = False, kernels: bool = False):
    registry = TraceRegistry()
    traces = {}
    attribution = {}
    for arch in (DENSE_TINY, MOE_TINY):
        tr = runtime_trace(arch, max_batch=4, max_len=512).to_trace()
        if kernels:
            from repro.profiler.kernel_profiler import kernel_points
            # reference rows — the fig2 engines run the reference backend
            tr.points.extend(kernel_points(arch, "reference",
                                           max_batch=4, max_len=512))
            attribution[arch] = kernel_attribution(tr, arch)
        registry.register(arch, tr)
        traces[arch] = tr.meta

    configs = [("S(D)", DENSE_TINY), ("S(M)", MOE_TINY),
               ("M(D)", DENSE_TINY), ("PD(D)", DENSE_TINY),
               ("S(D)+PC", DENSE_TINY)]
    if not quick:
        configs += [("M(M)", MOE_TINY)]
    rows = []
    for config, arch in configs:
        vocab = get_config(arch).vocab
        share = 0.6 if config.endswith("PC") else 0.0
        reqs = _workload(vocab, share=share)
        real = _run_engine(config, arch, reqs)
        sim = _run_sim(config, arch, reqs, registry)
        row = {
            "config": config, "arch": arch,
            "real_tpot_ms": (real.get("tpot_mean_s") or 0) * 1e3,
            "sim_tpot_ms": (sim.get("tpot_mean_s") or 0) * 1e3,
            "real_itl_ms": (real.get("itl_mean_s") or 0) * 1e3,
            "sim_itl_ms": (sim.get("itl_mean_s") or 0) * 1e3,
            "real_tput": real.get("throughput_tok_s"),
            "sim_tput": sim.get("throughput_tok_s"),
            "sim_wall_s": sim.get("sim_wall_s"),
            "tpot_err_pct": pct_err(sim.get("tpot_mean_s"),
                                    real.get("tpot_mean_s")),
            "itl_err_pct": pct_err(sim.get("itl_mean_s"),
                                   real.get("itl_mean_s")),
            "tput_err_pct": pct_err(sim.get("throughput_tok_s"),
                                    real.get("throughput_tok_s")),
        }
        rows.append(row)
        print(f"fig2,{config},tpot_err={row['tpot_err_pct']:.1f}%,"
              f"itl_err={row['itl_err_pct']:.1f}%,"
              f"tput_err={row['tput_err_pct']:.1f}%", flush=True)
    errs = [r["tput_err_pct"] for r in rows] + \
           [r["tpot_err_pct"] for r in rows]
    import numpy as np
    summary = {"rows": rows, "traces": traces,
               "mean_err_pct": float(np.nanmean(errs)),
               "max_err_pct": float(np.nanmax(errs))}
    if attribution:
        summary["kernel_attribution"] = attribution
        for arch, arows in attribution.items():
            for r in arows:
                top = max(r["share"], key=r["share"].get)
                print(f"fig2-kern,{arch},{r['phase']},tok={r['tokens']},"
                      f"ctx={r['context']},gap={r['gap_pct']:+.1f}%,"
                      f"top={top}({100 * r['share'][top]:.0f}%)", flush=True)
    return summary


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--kernels", action="store_true",
                    help="also sweep hwtrace/3 kernel sub-buckets and "
                         "report per-kernel error attribution")
    a = ap.parse_args()
    out = run(quick=a.quick, kernels=a.kernels)
    print(json.dumps(out, indent=1, default=float))
