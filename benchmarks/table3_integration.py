"""Table III reproduction: hardware-integration cost of the trace-driven
flow. Columns: LoC of the integration surface, offline profiling time,
online simulation time, and error vs real execution (from fig2).

The paper's TPU case study: 258 LoC / 21 hr profiling / 3.0 min sim / 2.25%
error (vs 4.8k LoC and 1524 min for full hardware-simulator integration).
Our analogue: the profiler + hw-spec surface is the entire integration; a
new accelerator is one ``HardwareSpec`` + one profiler run.
"""
from __future__ import annotations

import json
import os
import time

from repro.core import TraceRegistry, simulate
from repro.profiler import profile_arch
from repro.workload import ShareGPTConfig, generate


def _loc(path: str) -> int:
    n = 0
    with open(path) as f:
        in_doc = False
        for line in f:
            s = line.strip()
            if not s or s.startswith("#"):
                continue
            if s.startswith('"""') or s.startswith("'''"):
                if not (s.endswith('"""') and len(s) > 3) \
                        and not (s.endswith("'''") and len(s) > 3):
                    in_doc = not in_doc
                continue
            if in_doc:
                continue
            n += 1
    return n


def run():
    base = os.path.join(os.path.dirname(__file__), "..", "src", "repro")
    integration_files = [
        os.path.join(base, "hw", "specs.py"),
        os.path.join(base, "hw", "synthetic.py"),
        os.path.join(base, "profiler", "operator_profiler.py"),
    ]
    loc = sum(_loc(f) for f in integration_files)

    # offline profiling: analytical TPU-v6e integration (the paper's case
    # study target) — instant; measured CPU engine profile for scale.
    t0 = time.time()
    trace = profile_arch("llama3.1-8b", hardware="tpu-v6e",
                         mode="analytical", tp=2)
    t_analytical = time.time() - t0
    t0 = time.time()
    trace_measured = profile_arch("llama3.1-8b-tiny", mode="measured")
    t_measured = time.time() - t0

    # online simulation with the new hardware: 100 ShareGPT requests
    from benchmarks.fig3_simtime import _inst
    from repro.core import ClusterCfg
    from repro.configs import get_config
    registry = TraceRegistry()
    registry.register("llama3.1-8b", trace)
    reqs = generate(ShareGPTConfig(
        n_requests=100, rate=10.0, vocab=get_config("llama3.1-8b").vocab))
    # tp=2: an 8B model in bf16 does not fit a single 16GB v5e chip
    ccfg = ClusterCfg((_inst("i0", "llama3.1-8b", "llama3.1-8b", tp=2),))
    t0 = time.time()
    m = simulate(ccfg, reqs, traces=registry)
    t_sim = time.time() - t0

    out = {
        "integration_loc": loc,
        "paper_loc": 258, "paper_v1_loc": 4764,
        "profile_s_analytical": t_analytical,
        "profile_s_measured": t_measured,
        "sim_s_100req": t_sim,
        "paper_sim_min": 3.0, "paper_v1_sim_min": 1524.7,
        "throughput_tok_s_v6e": m.get("throughput_tok_s"),
    }
    print(f"table3,integration_loc={loc},profile_s={t_measured:.1f},"
          f"sim_s={t_sim:.3f}", flush=True)
    return out


if __name__ == "__main__":
    print(json.dumps(run(), indent=1, default=float))
