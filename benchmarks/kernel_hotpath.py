"""Serving hot-path kernel microbenchmark: reference vs pallas.

Times the three Pallas kernels the serving engine dispatches to under
``kernels="auto"`` — flash prefill attention, paged decode attention
(block-table indirection), and the fused MoE grouped matmul — against
their pure-JAX reference twins, and checks numerical parity on every
case (f32, awkward shapes: ragged lengths crossing page boundaries,
permuted block tables, sliding windows, zero-size expert groups).

On CPU the pallas side runs through the Pallas interpreter, so the
wall-clock columns describe the interpreter, not production kernels —
the parity columns are the point there (CI runs this to pin the
kernel-backend contract); on TPU/GPU the timings compare compiled Pallas
against XLA.  Emits one JSON row per case::

  PYTHONPATH=src python -m benchmarks.kernel_hotpath --out BENCH_kernels.json
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import flash_attention, moe_gmm, paged_attention
from repro.kernels.ops import _default_interpret
from repro.kernels.ref import (flash_attention_ref, moe_gmm_ref,
                               paged_attention_ref)


def _timeit(fn, reps: int) -> float:
    jax.block_until_ready(fn())            # compile + warm
    lat = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        lat.append(time.perf_counter() - t0)
    return float(np.median(lat))


def _case(name, pallas_fn, ref_fn, reps, valid=None):
    out_p = np.asarray(pallas_fn())
    out_r = np.asarray(ref_fn())
    if valid is not None:
        out_p, out_r = out_p[valid], out_r[valid]
    diff = float(np.max(np.abs(out_p - out_r))) if out_p.size else 0.0
    row = {
        "case": name,
        "max_abs_diff": diff,
        "parity": bool(diff < 2e-5),
        "pallas_s": _timeit(pallas_fn, reps),
        "reference_s": _timeit(ref_fn, reps),
    }
    row["speedup"] = row["reference_s"] / max(row["pallas_s"], 1e-12)
    return row


def run(reps: int = 5, seed: int = 0):
    key = jax.random.PRNGKey(seed)

    def rand(*shape):
        nonlocal key
        key, sub = jax.random.split(key)
        return jax.random.normal(sub, shape, jnp.float32)

    rows = []

    # ---- flash prefill (GQA + ragged lengths + sliding window) ----
    B, S, H, KV, dh = 2, 128, 8, 4, 64
    q, k, v = rand(B, S, H, dh), rand(B, S, KV, dh), rand(B, S, KV, dh)
    lengths = jnp.array([S, S - 37], jnp.int32)
    valid = np.zeros((B, S), bool)
    for b, n in enumerate(np.asarray(lengths)):
        valid[b, :n] = True
    rows.append(_case(
        "flash_prefill_gqa_lengths",
        lambda: flash_attention(q, k, v, lengths=lengths, bq=64, bkv=64),
        lambda: flash_attention_ref(q, k, v, lengths=lengths),
        reps, valid=valid))
    win = 48
    rows.append(_case(
        "flash_prefill_window",
        lambda: flash_attention(q, k, v, lengths=lengths, window=win,
                                bq=64, bkv=64),
        lambda: flash_attention_ref(q, k, v, lengths=lengths, window=win),
        reps, valid=valid))

    # ---- paged decode (ragged lengths crossing page boundaries, permuted
    # block table) ----
    ps, maxp, nb = 16, 8, 4
    n_pages = nb * maxp + 1
    kp, vp = rand(n_pages, ps, KV, dh), rand(n_pages, ps, KV, dh)
    table = jnp.asarray(np.random.default_rng(seed).permutation(
        nb * maxp)[:nb * maxp].reshape(nb, maxp), jnp.int32)
    dlen = jnp.array([1, ps, ps + 1, maxp * ps], jnp.int32)  # page edges
    qd = rand(nb, H, dh)
    rows.append(_case(
        "paged_decode_ragged",
        lambda: paged_attention(qd, kp, vp, table, dlen, page_size=ps),
        lambda: paged_attention_ref(qd, kp, vp, table, dlen, page_size=ps),
        reps))
    rows.append(_case(
        "paged_decode_window",
        lambda: paged_attention(qd, kp, vp, table, dlen, page_size=ps,
                                window=win),
        lambda: paged_attention_ref(qd, kp, vp, table, dlen, page_size=ps,
                                    window=win),
        reps))

    # ---- extend through the same paged kernel (chunked prefill) ----
    Se = 24
    qe = rand(nb, Se, H, dh)
    start = jnp.maximum(dlen - Se, 0)
    elen = jnp.minimum(start + Se, maxp * ps)
    rows.append(_case(
        "paged_extend",
        lambda: paged_attention(qe, kp, vp, table, elen, page_size=ps,
                                start=start),
        lambda: paged_attention_ref(qe, kp, vp, table, elen, page_size=ps,
                                    start=start),
        reps))

    # ---- fused MoE grouped matmul (uneven groups incl. zero-size) ----
    E, C, d, f = 4, 96, 64, 128
    x, w = rand(E, C, d), rand(E, d, f)
    gs = jnp.array([C, 17, 0, 5], jnp.int32)
    rows.append(_case(
        "moe_gmm_uneven_groups",
        lambda: moe_gmm(x, w, gs, bc=32),
        lambda: moe_gmm_ref(x, w, gs),
        reps))

    return {
        "jax_backend": jax.default_backend(),
        "pallas_interpret": _default_interpret(),
        "reps": reps,
        "cases": rows,
        "all_parity": all(r["parity"] for r in rows),
    }


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--reps", type=int, default=5)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=None, help="write the JSON report here")
    args = ap.parse_args()
    out = run(reps=args.reps, seed=args.seed)
    text = json.dumps(out, indent=1, default=float)
    print(text)
    if args.out:
        with open(args.out, "w") as fh:
            fh.write(text)
    if not out["all_parity"]:
        raise SystemExit("kernel parity FAILED (see max_abs_diff above)")


if __name__ == "__main__":
    main()
