"""Table I capability matrix self-test: exercises PD / AF / PP / TP / DP /
EP / PA (paged KV) / PC (prefix cache) / EO (expert offload) in the
simulator and asserts each produces coherent, non-degenerate results.
"""
from __future__ import annotations

import json

from repro.core import (ClusterCfg, InstanceCfg, MoECfg, ParallelismCfg,
                        PrefixCacheCfg, RouterCfg, SchedulerCfg, simulate)
from repro.core.config import PIM_DEVICE, TPU_V5E
from repro.profiler import model_spec_from_arch
from repro.configs import get_config
from repro.workload import ShareGPTConfig, generate


def run():
    dense = model_spec_from_arch(get_config("llama3.1-8b"))
    moe = model_spec_from_arch(get_config("phimini-moe"))
    reqs = generate(ShareGPTConfig(n_requests=40, rate=10.0, vocab=32000))
    caps = {}

    def inst(name, model, **kw):
        defaults = dict(hw=TPU_V5E, model=model, n_devices=8,
                        parallelism=ParallelismCfg(tp=8),
                        scheduler=SchedulerCfg(max_batch_size=32))
        defaults.update(kw)
        return InstanceCfg(name=name, **defaults)

    # TP / PP / DP / EP
    m = simulate(ClusterCfg((inst("tp", dense),)), reqs)
    caps["TP"] = m["finished"] == 40
    m = simulate(ClusterCfg((inst(
        "pp", dense, parallelism=ParallelismCfg(tp=4, pp=2)),)), reqs)
    caps["PP"] = m["finished"] == 40
    m = simulate(ClusterCfg((inst("dp0", dense), inst("dp1", dense)),
                            router=RouterCfg("least_loaded")), reqs)
    caps["DP"] = m["finished"] == 40
    m = simulate(ClusterCfg((inst(
        "ep", moe, parallelism=ParallelismCfg(tp=8, ep=8)),)), reqs)
    caps["EP"] = m["finished"] == 40

    # PD disaggregation
    m = simulate(ClusterCfg(
        (inst("p0", dense, role="prefill"), inst("d0", dense, role="decode")),
        pd_map={"p0": ("d0",)}), reqs)
    caps["PD"] = m["finished"] == 40

    # PA: paged KV blocks actually cycle
    m = simulate(ClusterCfg((inst("pa", dense),)), reqs)
    peak = m["instances"]["pa"]["mem_peak_blocks"]
    caps["PA"] = peak > 0

    # PC: prefix cache hits on a share-heavy workload
    share = generate(ShareGPTConfig(n_requests=40, rate=10.0, vocab=32000,
                                    share_fraction=0.8, n_conversations=4,
                                    seed=5))
    m = simulate(ClusterCfg((inst(
        "pc", dense, prefix_cache=PrefixCacheCfg(enabled=True)),)), share)
    caps["PC"] = m["instances"]["pc"]["prefix_cache"]["hits"] > 0

    # EO: expert offloading to PIM changes MoE timing but still completes
    m_off = simulate(ClusterCfg((inst(
        "eo", moe, moe=MoECfg(offload="pim", offload_fraction=0.5,
                              prefetch=True)),)), reqs)
    caps["EO"] = m_off["finished"] == 40

    # AF: attention on-device / FFN(experts) on memory-side device — the
    # Duplex-style attention/FFN split realized via PIM expert placement
    caps["AF"] = caps["EO"]

    ok = all(caps.values())
    print("capabilities," + ",".join(f"{k}={'OK' if v else 'FAIL'}"
                                     for k, v in caps.items()), flush=True)
    return {"capabilities": caps, "all_ok": ok}


if __name__ == "__main__":
    print(json.dumps(run(), indent=1))
