import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import json
from repro.launch.dryrun import lower_cell

CELLS = [
    # (arch, shape, variant-name, kwargs)
    ("granite-moe-3b-a800m", "train_4k", "v1-shard-experts",
     dict(microbatches=8, zero1=True, shard_experts=True)),
    ("granite-moe-3b-a800m", "train_4k", "v2-shard+fuseqkv",
     dict(microbatches=8, zero1=True, shard_experts=True, fuse_qkv=True)),
    ("starcoder2-7b", "train_4k", "v1-fuse-qkv",
     dict(microbatches=8, zero1=True, fuse_qkv=True)),
    ("chameleon-34b", "decode_32k", "v1-seq-shard-cache",
     dict(seq_shard_cache=True)),
    ("chameleon-34b", "decode_32k", "v2-seqshard+fuseqkv",
     dict(seq_shard_cache=True, fuse_qkv=True)),
    ("starcoder2-7b", "train_4k", "v2-fuseqkv-chunked",
     dict(microbatches=8, zero1=True, fuse_qkv=True, attn_impl="chunked")),
]
with open("results/hillclimb.jsonl", "a") as f:
    for arch, shape, name, kw in CELLS:
        print(f"=== {arch} {shape} {name} ===", flush=True)
        try:
            rec, comp = lower_cell(arch, shape, unroll=False,
                                   variant=name, **kw)
            del comp
        except Exception as e:
            rec = {"arch": arch, "shape": shape, "variant": name,
                   "status": "error", "error": str(e)[:1500]}
        r = rec.get("roofline", {})
        print(json.dumps({k: rec.get(k) for k in
                          ("variant", "status", "compile_s")} |
                         {k: r.get(k) for k in
                          ("t_compute_s", "t_memory_s", "t_collective_s",
                           "bottleneck")} |
                         {"temp_gb": rec.get("memory", {}).get(
                              "temp_size_in_bytes", 0)/1e9,
                          "useful": rec.get("useful_flops_frac")}),
              flush=True)
        f.write(json.dumps(rec) + "\n")
        f.flush()
print("DONE")
