"""Quickstart: simulate a heterogeneous multi-instance cluster serving
ShareGPT-like traffic, with a failure + elastic scale-out mid-run.

  PYTHONPATH=src python examples/quickstart.py
"""
import json

from repro.core import (Cluster, ClusterCfg, InstanceCfg, ParallelismCfg,
                        PrefixCacheCfg, RouterCfg, SchedulerCfg)
from repro.core.config import RTX3090, TPU_V5E
from repro.profiler import model_spec_from_arch
from repro.configs import get_config
from repro.workload import ShareGPTConfig, generate


def main():
    llama = model_spec_from_arch(get_config("llama3.1-8b"))
    qwen = model_spec_from_arch(get_config("qwen3-8b"))

    cluster_cfg = ClusterCfg(
        instances=(
            # a TPU pod slice with prefix caching
            InstanceCfg(name="tpu0", hw=TPU_V5E, model=llama, n_devices=8,
                        parallelism=ParallelismCfg(tp=8),
                        prefix_cache=PrefixCacheCfg(enabled=True)),
            # a GPU box serving a different model (heterogeneous!)
            InstanceCfg(name="gpu0", hw=RTX3090, model=qwen, n_devices=4,
                        parallelism=ParallelismCfg(tp=4),
                        scheduler=SchedulerCfg(max_batch_size=16)),
            InstanceCfg(name="tpu1", hw=TPU_V5E, model=llama, n_devices=8,
                        parallelism=ParallelismCfg(tp=8)),
        ),
        router=RouterCfg("least_loaded", model_affinity=False),
    )
    reqs = generate(ShareGPTConfig(n_requests=100, rate=10.0, vocab=32000,
                                   share_fraction=0.4))
    cluster = Cluster(cluster_cfg)
    cluster.submit_workload(reqs)
    # inject a node failure at t=2s (recovers at t=6s) and scale out at t=4s
    cluster.inject_failure(2.0, "tpu1", recover_after=4.0)
    cluster.add_instance(4.0, InstanceCfg(
        name="tpu2", hw=TPU_V5E, model=llama, n_devices=8,
        parallelism=ParallelismCfg(tp=8)))
    metrics = cluster.run()
    print(json.dumps({k: v for k, v in metrics.items()
                      if not isinstance(v, dict)}, indent=1, default=float))
    print("per-instance:", json.dumps(metrics["instances"], indent=1,
                                      default=float))


if __name__ == "__main__":
    main()
