"""End-to-end driver: serve a small model with batched requests on the REAL
JAX engine (continuous batching + paged slots + radix prefix cache), then
replay the identical workload in the simulator and print both.

  PYTHONPATH=src python examples/serve_real_engine.py
"""
import json

from repro.configs import get_config
from repro.core import ClusterCfg, RouterCfg, TraceRegistry, simulate
from repro.profiler.runtime_profiler import runtime_trace
from repro.serve import DriverCfg, ServeDriver, ServingEngine
from repro.workload import ShareGPTConfig, generate

ARCH = "llama3.1-8b-tiny"


def main():
    cfg = get_config(ARCH)
    reqs = generate(ShareGPTConfig(
        n_requests=24, rate=10.0, vocab=cfg.vocab, mean_prompt=90,
        mean_output=24, max_prompt=230, max_output=40,
        share_fraction=0.5, n_conversations=4))

    print("== real engine (prefix cache on) ==")
    eng = ServingEngine(cfg, max_batch=4, max_len=512, prefix_cache=True)
    real = ServeDriver([eng]).run(reqs)
    print(json.dumps(real, indent=1, default=float))

    print("== simulator replay (trace-driven) ==")
    registry = TraceRegistry()
    registry.register(ARCH,
                      runtime_trace(ARCH, max_batch=4, max_len=512)
                      .to_trace())
    from repro.serve.driver import engine_instance_cfg
    # identical policy stack (runtime scheduler/router); only the
    # ExecutionBackend differs — SimBackend prices what JaxBackend ran
    ccfg = ClusterCfg(
        (engine_instance_cfg(eng, trace_name=ARCH),),
        router=RouterCfg("round_robin"))
    sim = simulate(ccfg, reqs, traces=registry)
    print(json.dumps({k: v for k, v in sim.items()
                      if not isinstance(v, dict)}, indent=1, default=float))


if __name__ == "__main__":
    main()
