"""Design-space study: P/D disaggregation x prefix caching x KV-transfer
policy — the kind of exploration LLMServingSim2.0 exists for.

  PYTHONPATH=src python examples/pd_disagg_prefix_cache.py
"""
import json

from repro.core import (ClusterCfg, InstanceCfg, NetworkCfg, ParallelismCfg,
                        PrefixCacheCfg, RouterCfg, simulate)
from repro.core.config import TPU_V5E
from repro.profiler import model_spec_from_arch
from repro.configs import get_config
from repro.workload import ShareGPTConfig, generate


def main():
    model = model_spec_from_arch(get_config("llama3.1-8b"))
    reqs = generate(ShareGPTConfig(n_requests=100, rate=12.0, vocab=32000,
                                   share_fraction=0.5, n_conversations=10))

    def inst(name, role="unified", pc=False):
        return InstanceCfg(name=name, hw=TPU_V5E, model=model, n_devices=8,
                           parallelism=ParallelismCfg(tp=8), role=role,
                           prefix_cache=PrefixCacheCfg(enabled=pc))

    rows = []
    for pc in (False, True):
        # unified 2-instance baseline
        m = simulate(ClusterCfg((inst("u0", pc=pc), inst("u1", pc=pc)),
                                router=RouterCfg("least_loaded")), reqs)
        rows.append(("unified", pc, "-", m))
        # P/D with blocking vs layerwise-overlapped KV transfer
        for policy in ("full_blocking", "layerwise_overlap"):
            m = simulate(ClusterCfg(
                (inst("p0", role="prefill", pc=pc),
                 inst("d0", role="decode")),
                pd_map={"p0": ("d0",)},
                network=NetworkCfg(kv_transfer_policy=policy)), reqs)
            rows.append(("pd", pc, policy, m))

    print(f"{'topology':8s} {'PC':5s} {'kv-policy':18s} {'TTFT(ms)':>9s} "
          f"{'TPOT(ms)':>9s} {'ITLp99(ms)':>10s} {'tok/s':>8s}")
    for topo, pc, pol, m in rows:
        print(f"{topo:8s} {str(pc):5s} {pol:18s} "
              f"{m['ttft_mean_s']*1e3:9.1f} {m['tpot_mean_s']*1e3:9.2f} "
              f"{m['itl_p99_s']*1e3:10.2f} {m['throughput_tok_s']:8.0f}")


if __name__ == "__main__":
    main()
