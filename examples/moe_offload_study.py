"""MoE expert-offloading exploration (paper §II-C): sweep offload target
(host vs PIM) x fraction x prefetch under a *replayable* zipf expert-skew
trace, and report latency/throughput plus the expert-load imbalance the
trace induced.

The skew is an ``ExpertRoutingTrace`` artifact (``repro.moe``), not a
statistical knob: the exact same trace can be replayed on the real engine
(``ServingEngine(routing=trace)``) and the reported
``metrics()["expert_load"]`` compared one-to-one — see
``tests/test_expert_routing.py`` for the pinned sim/real parity.

  PYTHONPATH=src python examples/moe_offload_study.py
"""
from repro.core import (ClusterCfg, InstanceCfg, MoECfg, ParallelismCfg,
                        SchedulerCfg, simulate)
from repro.core.config import PIM_DEVICE, TPU_V5E
from repro.profiler import model_spec_from_arch
from repro.configs import get_config
from repro.moe import register_routing
from repro.workload import ShareGPTConfig, SkewConfig, generate
from repro.workload.expert_skew import routing_for_model

SWEEP = [("none", 0.0, False),
         ("host", 0.25, False), ("host", 0.25, True),
         ("host", 0.5, False), ("host", 0.5, True),
         ("pim", 0.5, True), ("pim", 0.75, True)]


def main(n_requests: int = 100):
    model = model_spec_from_arch(get_config("granite-moe-3b-a800m"))
    # one zipf routing trace drives every point of the sweep (and could
    # drive the real engine): offload traffic and imbalance are priced
    # from its per-layer expert counts, not redrawn per run
    trace = routing_for_model(
        model, SkewConfig(kind="zipf", zipf_a=1.1, period=512, seed=0))
    register_routing("offload-study", trace)
    reqs = generate(ShareGPTConfig(n_requests=n_requests, rate=15.0,
                                   vocab=32000))

    rows = []
    for offload, frac, prefetch in SWEEP:
        icfg = InstanceCfg(
            name="i0", hw=TPU_V5E, model=model, n_devices=8,
            parallelism=ParallelismCfg(tp=8, ep=8),
            scheduler=SchedulerCfg(max_batch_size=48),
            moe=MoECfg(offload=offload, offload_fraction=frac,
                       prefetch=prefetch, routing_trace="offload-study"),
            # memory-side accelerator the pim points execute offloaded
            # experts on (InstanceCfg.pim; PerfModel would fall back to
            # this same preset, but the study names its device explicitly)
            pim=PIM_DEVICE)
        m = simulate(ClusterCfg((icfg,)), reqs)
        rows.append((offload, frac, prefetch, m))

    print(f"routing trace: zipf a=1.1, static imbalance "
          f"{trace.static_imbalance():.2f} over {trace.n_experts} experts")
    print(f"{'target':7s} {'frac':>5s} {'prefetch':>8s} {'TPOT(ms)':>9s} "
          f"{'TTFT(ms)':>9s} {'tok/s':>8s} {'imb(ep)':>8s}")
    for off, frac, pre, m in rows:
        # the instance-level metric is sharded over the instance's ep=8
        # ranks (the cluster rollup in m["expert_load"] reports the
        # per-expert max/mean instead)
        imb = m["instances"]["i0"]["expert_load"]["imbalance"]
        print(f"{off:7s} {frac:5.2f} {str(pre):>8s} "
              f"{m['tpot_mean_s']*1e3:9.2f} {m['ttft_mean_s']*1e3:9.1f} "
              f"{m['throughput_tok_s']:8.0f} {imb:8.2f}")
    return rows


if __name__ == "__main__":
    main()
