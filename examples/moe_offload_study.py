"""MoE expert-offloading exploration (paper §II-C): sweep offload target
(host vs PIM) x fraction x prefetch and report latency/throughput.

  PYTHONPATH=src python examples/moe_offload_study.py
"""
from repro.core import (ClusterCfg, InstanceCfg, MoECfg, ParallelismCfg,
                        SchedulerCfg, simulate)
from repro.core.config import TPU_V5E
from repro.profiler import model_spec_from_arch
from repro.configs import get_config
from repro.workload import ShareGPTConfig, generate


def main():
    model = model_spec_from_arch(get_config("granite-moe-3b-a800m"))
    reqs = generate(ShareGPTConfig(n_requests=100, rate=15.0, vocab=32000))

    rows = []
    for offload, frac, prefetch in [
            ("none", 0.0, False),
            ("host", 0.25, False), ("host", 0.25, True),
            ("host", 0.5, False), ("host", 0.5, True),
            ("pim", 0.5, True), ("pim", 0.75, True)]:
        icfg = InstanceCfg(
            name="i0", hw=TPU_V5E, model=model, n_devices=8,
            parallelism=ParallelismCfg(tp=8, ep=8),
            scheduler=SchedulerCfg(max_batch_size=48),
            moe=MoECfg(offload=offload, offload_fraction=frac,
                       prefetch=prefetch, routing="zipf"))
        m = simulate(ClusterCfg((icfg,)), reqs)
        rows.append((offload, frac, prefetch, m))

    print(f"{'target':7s} {'frac':>5s} {'prefetch':>8s} {'TPOT(ms)':>9s} "
          f"{'TTFT(ms)':>9s} {'tok/s':>8s}")
    for off, frac, pre, m in rows:
        print(f"{off:7s} {frac:5.2f} {str(pre):>8s} "
              f"{m['tpot_mean_s']*1e3:9.2f} {m['ttft_mean_s']*1e3:9.1f} "
              f"{m['throughput_tok_s']:8.0f}")


if __name__ == "__main__":
    main()
