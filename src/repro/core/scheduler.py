"""Compat shim: the continuous-batching scheduler moved to the
backend-agnostic runtime layer (``repro.runtime.scheduler``)."""
from repro.runtime.scheduler import (BatchScheduler,  # noqa: F401
                                     ScheduledWork, WaitQueue)

__all__ = ["BatchScheduler", "ScheduledWork", "WaitQueue"]
