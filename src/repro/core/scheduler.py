"""Iteration-level batch scheduler (vLLM-style continuous batching).

Each call to ``next_batch`` composes one engine iteration from the running
set + waiting queue under token/size budgets, with optional chunked prefill
(Sarathi-style): prefill work is split into chunks that share iterations
with decode steps. Preemption on memory pressure recycles the lowest-
priority running request (its KV is freed; it restarts from the prefix
cache / full prefill).
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, List, Optional, Tuple

from repro.core.config import SchedulerCfg
from repro.core.memory import MemoryModel
from repro.core.perfmodel import BatchItem
from repro.core.request import (DECODING, PREFILLING, QUEUED, SimRequest)


@dataclasses.dataclass
class ScheduledWork:
    request: SimRequest
    tokens: int
    phase: str


class BatchScheduler:
    def __init__(self, cfg: SchedulerCfg, mem: MemoryModel):
        self.cfg = cfg
        self.mem = mem
        self.waiting: Deque[SimRequest] = deque()
        self.running: List[SimRequest] = []
        self.n_preemptions = 0

    def enqueue(self, req: SimRequest):
        if self.cfg.policy == "sjf":
            # shortest prompt first
            items = list(self.waiting) + [req]
            items.sort(key=lambda r: r.remaining_prefill)
            self.waiting = deque(items)
        else:
            self.waiting.append(req)

    def _try_admit(self, req: SimRequest) -> bool:
        """Reserve KV blocks for prompt + expected output."""
        need = req.remaining_prefill + req.cached_prefix + req.output_len // 4
        if self.mem.can_allocate(need):
            self.mem.allocate(need)
            return True
        return False

    def _preempt_one(self) -> Optional[SimRequest]:
        if not self.running:
            return None
        victim = max(self.running, key=lambda r: r.context_len)
        self.running.remove(victim)
        self.mem.free(victim.context_len + victim.output_len // 4)
        victim.state = QUEUED
        victim.n_preemptions += 1
        victim.prefill_done_tokens = 0
        victim.generated = 0        # conservatively restart decoding state
        self.waiting.appendleft(victim)
        self.n_preemptions += 1
        return victim

    def next_batch(self) -> List[ScheduledWork]:
        cfg = self.cfg
        if cfg.prefill_exclusive:
            return self._next_batch_exclusive()
        work: List[ScheduledWork] = []
        tokens_left = cfg.max_batch_tokens

        # 1. decode steps for all running decode-phase requests
        for req in list(self.running):
            if req.state == DECODING and tokens_left > 0:
                work.append(ScheduledWork(req, 1, "decode"))
                tokens_left -= 1

        # 2. continue chunked prefills already running
        for req in list(self.running):
            if req.state == PREFILLING and tokens_left > 0:
                chunk = min(req.remaining_prefill,
                            cfg.prefill_chunk if cfg.chunked_prefill
                            else req.remaining_prefill,
                            tokens_left)
                if chunk > 0:
                    work.append(ScheduledWork(req, chunk, "prefill"))
                    tokens_left -= chunk

        # 3. admit new requests while budget remains
        while self.waiting and tokens_left > 0 and \
                len(self.running) < cfg.max_batch_size:
            req = self.waiting[0]
            if not self._try_admit(req):
                # memory pressure: try preempting, else stop admitting
                if not self.running or self._preempt_one() is None:
                    break
                if not self._try_admit(req):
                    break
            self.waiting.popleft()
            req.state = PREFILLING
            self.running.append(req)
            chunk = min(req.remaining_prefill,
                        cfg.prefill_chunk if cfg.chunked_prefill
                        else req.remaining_prefill,
                        tokens_left)
            chunk = max(chunk, 0)
            if chunk > 0:
                work.append(ScheduledWork(req, chunk, "prefill"))
                tokens_left -= chunk
            elif req.remaining_prefill == 0:
                # fully prefix-cached prompt: go straight to decode
                req.state = DECODING
                work.append(ScheduledWork(req, 1, "decode"))
                tokens_left -= 1
        return work

    def _next_batch_exclusive(self) -> List[ScheduledWork]:
        """ServingEngine semantics: one whole-prompt prefill OR all decodes."""
        cfg = self.cfg
        if self.waiting and len(self.running) < cfg.max_batch_size:
            req = self.waiting[0]
            if self._try_admit(req):
                self.waiting.popleft()
                req.state = PREFILLING
                self.running.append(req)
                n = req.remaining_prefill
                if n > 0:
                    return [ScheduledWork(req, n, "prefill")]
                req.state = DECODING
        return [ScheduledWork(r, 1, "decode") for r in self.running
                if r.state == DECODING]

    def complete(self, req: SimRequest):
        if req in self.running:
            self.running.remove(req)
        self.mem.free(req.context_len + req.output_len // 4)

    def requeue_all(self) -> List[SimRequest]:
        """Node failure: return every in-flight request for re-dispatch."""
        out = list(self.running) + list(self.waiting)
        for r in self.running:
            self.mem.free(r.context_len + r.output_len // 4)
            r.state = QUEUED
            r.prefill_done_tokens = 0
            r.generated = 0
            r.n_restarts += 1
        self.running.clear()
        self.waiting.clear()
        return out

    def to_batch_items(self, work: List[ScheduledWork]) -> List[BatchItem]:
        return [BatchItem(tokens=w.tokens,
                          context=w.request.context_len + w.tokens
                          if w.phase == "prefill"
                          else w.request.context_len + 1,
                          phase=w.phase) for w in work]
