"""Serving metric aggregation: TTFT / TPOT / ITL / throughput (paper Fig 2)."""
from __future__ import annotations

from typing import Dict, List

import numpy as np

from repro.core.request import FINISHED, SimRequest


def aggregate(requests: List[SimRequest]) -> Dict:
    done = [r for r in requests if r.state == FINISHED]
    if not done:
        return {"finished": 0}
    ttft = np.array([r.ttft() for r in done if r.ttft() is not None])
    tpot = np.array([r.tpot() for r in done if r.tpot() is not None])
    itls = np.concatenate([np.array(r.itl()) for r in done
                           if len(r.itl())]) if any(
        len(r.itl()) for r in done) else np.array([0.0])
    t_end = max(r.t_finish for r in done)
    t_start = min(r.arrival for r in done)
    out_tokens = sum(r.generated for r in done)
    return {
        "finished": len(done),
        "ttft_mean_s": float(ttft.mean()) if ttft.size else None,
        "ttft_p99_s": float(np.percentile(ttft, 99)) if ttft.size else None,
        "tpot_mean_s": float(tpot.mean()) if tpot.size else None,
        "itl_mean_s": float(itls.mean()),
        "itl_p99_s": float(np.percentile(itls, 99)),
        "throughput_tok_s": out_tokens / max(t_end - t_start, 1e-9),
        "makespan_s": t_end - t_start,
        "preemptions": sum(r.n_preemptions for r in done),
        "restarts": sum(r.n_restarts for r in done),
        # scheduler-ledger view: peak KV block reservation per request
        # (per-instance occupancy/watermark timelines live in
        # instances[<name>]["kv_occupancy"/"kv_watermark"])
        "kv_blocks_peak_mean": float(np.mean(
            [r.kv_blocks_peak for r in done])),
        "kv_blocks_peak_max": int(max(r.kv_blocks_peak for r in done)),
    }
