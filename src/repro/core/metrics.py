"""Serving metric aggregation: TTFT / TPOT / ITL / throughput (paper Fig 2)."""
from __future__ import annotations

from typing import Dict, List

import numpy as np

from repro.core.expert import imbalance_factor
from repro.core.request import FINISHED, SimRequest


def merge_expert_load(loads: List[Dict], timeline_len: int = 4096) -> Dict:
    """Cluster-level expert-load view: elementwise-sum the per-instance
    (layer, expert) count matrices, recompute the imbalance over the
    merged counts, and interleave the bounded hot-expert timelines by
    time.  Instances serving a different MoE shape (other model, other
    trace) cannot be summed; the rollup anchors on the *most common*
    shape across instances — not dict order — and reports how many
    instances merged."""
    all_shapes = [np.asarray(l["counts"]).shape for l in loads]
    shape = max(set(all_shapes), key=all_shapes.count)
    counts = np.zeros(shape, np.int64)
    tokens = 0
    merged = 0
    timeline = []
    dropped = 0
    routed = 0
    for load in loads:
        c = np.asarray(load["counts"])
        if c.shape != shape:
            continue
        counts += c
        tokens += int(load.get("tokens", 0))
        dropped += int(load.get("dropped", 0))
        routed += int(load.get("routed", 0))
        timeline.extend(load.get("hot_timeline", ()))
        merged += 1
    timeline = sorted(timeline, key=lambda e: e[0])[-timeline_len:]
    total = counts.sum(axis=0)
    # per-expert imbalance (max/mean over experts): the cluster view has
    # no single expert-parallel sharding to report against
    shards = shape[1]
    return {
        "counts": counts.tolist(),
        "tokens": tokens,
        "instances_merged": merged,
        "imbalance": imbalance_factor(total, shards),
        "per_layer_imbalance": [imbalance_factor(c, shards)
                                for c in counts],
        "hot_expert": int(total.argmax()) if total.sum() else None,
        "hot_timeline": timeline,
        "dropped": dropped,
        "routed": routed,
        "drop_rate": dropped / max(routed, 1),
    }


def merge_spec_decode(stats: List[Dict], timeline_len: int = 4096) -> Dict:
    """Cluster-level speculative-decoding view: sum per-instance step /
    proposal / acceptance counters, recompute the rates over the merged
    totals, and interleave the bounded per-step timelines by time.
    Instances speculating a different draft length cannot be summed; the
    rollup anchors on the most common ``k`` and reports how many
    instances merged (mirroring ``merge_expert_load``)."""
    ks = [int(s["k"]) for s in stats]
    k = max(set(ks), key=ks.count)
    hist = np.zeros(k + 1, np.int64)
    steps = proposed = accepted = 0
    merged = 0
    timeline = []
    for s in stats:
        if int(s["k"]) != k:
            continue
        steps += int(s["steps"])
        proposed += int(s["proposed_tokens"])
        accepted += int(s["accepted_tokens"])
        hist += np.asarray(s["accepted_hist"], np.int64)
        timeline.extend(s.get("step_timeline", ()))
        merged += 1
    timeline = sorted(timeline, key=lambda e: e[0])[-timeline_len:]
    return {
        "k": k,
        "instances_merged": merged,
        "steps": steps,
        "proposed_tokens": proposed,
        "accepted_tokens": accepted,
        "emitted_tokens": accepted + steps,
        "acceptance_rate": accepted / max(proposed, 1),
        "mean_accepted_len": accepted / max(steps, 1),
        "wasted_draft_tokens": proposed - accepted,
        "accepted_hist": hist.tolist(),
        "step_timeline": timeline,
    }


def merge_kv_tiers(stats: List[Dict]) -> Dict:
    """Cluster-level KV-tier view: per-cache residency (deduplicated by
    cache name — a ``scope="global"`` radix tree appears in every
    instance's stats but must be counted once) plus summed hit-token and
    transfer traffic over the distinct caches."""
    by_cache: Dict[str, Dict] = {}
    for s in stats:
        by_cache.setdefault(s.get("cache", "cache"), s)
    residency = {"device": 0, "host": 0, "ssd": 0}
    hit_tokens = {"device": 0, "host": 0, "ssd": 0}
    transfers: Dict[str, Dict[str, float]] = {}
    for s in by_cache.values():
        for tier, n in s.get("residency_blocks", {}).items():
            residency[tier] = residency.get(tier, 0) + int(n)
        for tier, n in s.get("hit_tokens", {}).items():
            hit_tokens[tier] = hit_tokens.get(tier, 0) + int(n)
        for path, t in s.get("transfers", {}).items():
            agg = transfers.setdefault(path, {"blocks": 0, "bytes": 0.0})
            agg["blocks"] += int(t.get("blocks", 0))
            agg["bytes"] += float(t.get("bytes", 0.0))
    return {"caches_merged": len(by_cache),
            "residency_blocks": residency,
            "hit_tokens": hit_tokens,
            "transfers": transfers}


def slo_met(r: SimRequest) -> bool:
    """A finished request meets its tenant SLO when TTFT and TPOT are
    within the class targets (TPOT is vacuous for single-token outputs)."""
    ttft = r.ttft()
    if ttft is None or ttft > r.slo_ttft_ms / 1e3:
        return False
    tpot = r.tpot()
    return tpot is None or tpot <= r.slo_tpot_ms / 1e3


def tenant_rollup(requests: List[SimRequest]) -> Dict[str, Dict]:
    """Per-tenant serving metrics (``metrics()["tenants"]``, both
    backends): TTFT/TPOT p50/p95/p99, SLO attainment (fraction of
    finished requests meeting both targets) and **goodput** — throughput
    counting only SLO-met requests, in output tokens/s and requests/s.

    Goodput is normalized by the *global* serving window (first arrival
    to last finish over all tenants, the same span ``aggregate`` uses for
    throughput), so per-tenant goodputs are comparable to each other and
    sum toward the cluster figure.
    """
    done_all = [r for r in requests if r.state == FINISHED]
    if not done_all:
        return {}
    span = max(max(r.t_finish for r in done_all)
               - min(r.arrival for r in done_all), 1e-9)
    out: Dict[str, Dict] = {}
    for name in sorted({r.tenant for r in requests}):
        reqs = [r for r in requests if r.tenant == name]
        done = [r for r in reqs if r.state == FINISHED]
        row: Dict = {"submitted": len(reqs), "finished": len(done)}
        if done:
            ttft = np.array([r.ttft() for r in done
                             if r.ttft() is not None])
            tpot = np.array([r.tpot() for r in done
                             if r.tpot() is not None])

            def pct(a, q):
                return float(np.percentile(a, q)) if a.size else None

            met = [r for r in done if slo_met(r)]
            row.update({
                "priority": done[0].priority,
                "slo_ttft_ms": done[0].slo_ttft_ms,
                "slo_tpot_ms": done[0].slo_tpot_ms,
                "ttft_p50_s": pct(ttft, 50), "ttft_p95_s": pct(ttft, 95),
                "ttft_p99_s": pct(ttft, 99),
                "tpot_p50_s": pct(tpot, 50), "tpot_p95_s": pct(tpot, 95),
                "tpot_p99_s": pct(tpot, 99),
                "slo_attainment": len(met) / len(done),
                "slo_met": len(met),
                "goodput_tok_s": sum(r.generated for r in met) / span,
                "goodput_req_s": len(met) / span,
            })
        out[name] = row
    return out


def aggregate(requests: List[SimRequest]) -> Dict:
    done = [r for r in requests if r.state == FINISHED]
    if not done:
        return {"finished": 0}
    ttft = np.array([r.ttft() for r in done if r.ttft() is not None])
    tpot = np.array([r.tpot() for r in done if r.tpot() is not None])
    # no request produced inter-token latencies (e.g. every output was a
    # single token): report None like the other empty-stat fields rather
    # than fabricating a perfect 0.0 latency
    itls = np.concatenate([np.array(r.itl()) for r in done
                           if len(r.itl())]) if any(
        len(r.itl()) for r in done) else np.array([])
    t_end = max(r.t_finish for r in done)
    t_start = min(r.arrival for r in done)
    out_tokens = sum(r.generated for r in done)
    return {
        "finished": len(done),
        "ttft_mean_s": float(ttft.mean()) if ttft.size else None,
        "ttft_p99_s": float(np.percentile(ttft, 99)) if ttft.size else None,
        "tpot_mean_s": float(tpot.mean()) if tpot.size else None,
        "itl_mean_s": float(itls.mean()) if itls.size else None,
        "itl_p99_s": float(np.percentile(itls, 99)) if itls.size else None,
        "throughput_tok_s": out_tokens / max(t_end - t_start, 1e-9),
        "makespan_s": t_end - t_start,
        "preemptions": sum(r.n_preemptions for r in done),
        "restarts": sum(r.n_restarts for r in done),
        # scheduler-ledger view: peak KV block reservation per request
        # (per-instance occupancy/watermark timelines live in
        # instances[<name>]["kv_occupancy"/"kv_watermark"])
        "kv_blocks_peak_mean": float(np.mean(
            [r.kv_blocks_peak for r in done])),
        "kv_blocks_peak_max": int(max(r.kv_blocks_peak for r in done)),
    }
