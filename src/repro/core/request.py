"""Simulated request lifecycle + per-request metrics."""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

QUEUED = "queued"
PREFILLING = "prefilling"
TRANSFERRING = "transferring"   # P/D disaggregation KV move
DECODING = "decoding"
PREEMPTED = "preempted"
FINISHED = "finished"
FAILED = "failed"


@dataclasses.dataclass
class SimRequest:
    req_id: int
    arrival: float
    prompt_tokens: Sequence[int]
    output_len: int
    model: str = "default"

    # multi-tenant class identity (repro.core.config.TenantClass): the
    # priority keys the ``policy="priority"`` scheduler, the weight feeds
    # its starvation guard, and the SLO targets drive the per-tenant
    # attainment/goodput rollup (``metrics()["tenants"]``) plus the
    # SLO-aware autoscaler.
    tenant: str = "default"
    priority: int = 0
    weight: float = 1.0
    slo_ttft_ms: float = 2000.0
    slo_tpot_ms: float = 200.0

    state: str = QUEUED
    instance: Optional[str] = None
    decode_instance: Optional[str] = None

    prefill_done_tokens: int = 0     # chunked prefill progress
    cached_prefix: int = 0           # tokens served from prefix cache
    generated: int = 0

    t_first_token: Optional[float] = None
    t_finish: Optional[float] = None
    token_times: List[float] = dataclasses.field(default_factory=list)
    n_preemptions: int = 0
    n_restarts: int = 0              # node-failure recoveries
    kv_blocks_peak: int = 0          # max KV blocks the ledger ever held

    @property
    def prompt_len(self) -> int:
        return len(self.prompt_tokens)

    @property
    def context_len(self) -> int:
        return self.prompt_len + self.generated

    @property
    def remaining_prefill(self) -> int:
        return max(0, self.prompt_len - self.cached_prefix
                   - self.prefill_done_tokens)

    def ttft(self) -> Optional[float]:
        if self.t_first_token is None:
            return None
        return self.t_first_token - self.arrival

    def tpot(self) -> Optional[float]:
        """Time per output token after the first (paper Fig 2a)."""
        if self.t_finish is None or self.t_first_token is None \
                or self.output_len <= 1:
            return None
        return (self.t_finish - self.t_first_token) / (self.output_len - 1)

    def itl(self) -> List[float]:
        return [t2 - t1 for t1, t2 in zip(self.token_times,
                                          self.token_times[1:])]
