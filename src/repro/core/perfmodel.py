"""Trace-consuming performance model.

``iteration_latency`` prices one engine iteration (a batch of prefill
chunks + decode steps) from a hardware trace, in fidelity order:

1. **iter-level points** (``iter``/``extend``/``kv_export``) — whole
   measured iterations captured by ``repro.profiler.runtime_profiler``
   through the unified runtime's ``JaxBackend``; highest fidelity.
2. **kernel-level points** (hwtrace/3 ``kern:<backend>:<kernel>`` rows,
   swept by ``repro.profiler.kernel_profiler``) — per-kernel latencies
   (attention / mlp / moe_gmm / head) composed as ``L * attention +
   L * ffn + head``; lets fidelity studies attribute error to one kernel
   and compares kernel backends (reference vs pallas) on the same grid.
3. **operator-level points** — per-op-class latencies interpolated over
   the (tokens, context) grid (paper §II-A) and composed per layer.
4. **analytical roofline** — per-query fallback from the hardware spec for
   op/shape combos no trace covers.

Traces arrive as portable ``repro.hw.HardwareTrace`` artifacts resolved by
``InstanceCfg.hw_name`` (or raw ``Trace`` objects via ``trace_name``); for
never-measured devices the registry synthesizes one from the same
analytical model (``repro.hw.synthetic``), so this class is always a trace
*consumer* — the roofline here only patches grid gaps.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import numpy as np

from repro.core.config import InstanceCfg
from repro.core.expert import ExpertExecutionModel, ExpertRouter
from repro.core.network import allreduce_time
from repro.core.trace import Trace
from repro.hw.trace import kern_op


@dataclasses.dataclass
class BatchItem:
    tokens: int          # tokens processed for this request this iteration
    context: int         # total context length (for attention cost)
    phase: str           # prefill | decode
    start: int = 0       # KV already in cache before this work (cache hits
                         # and chunked-prefill continuations run ``extend``)
    completes: bool = True   # this work finishes the request's prefill


@dataclasses.dataclass
class IterationCost:
    total_s: float
    breakdown: dict


def _item_positions(it: BatchItem) -> np.ndarray:
    """KV positions of the tokens a batch item processes — the lookup key
    into an ``ExpertRoutingTrace``.  Follows the ``to_batch_items``
    convention: prefill work covers ``[start, start + tokens)``; a decode
    item's ``tokens`` consecutive slots end at ``context - 2`` (its
    ``context`` is ``context_len + tokens`` and the first new token's
    0-based KV index is ``context_len - 1``) — one token classically,
    the k + 1 verification window under speculative decoding."""
    if it.phase == "prefill":
        return np.arange(it.start, it.start + it.tokens)
    n = max(it.tokens, 1)
    first = max(it.context - n - 1, 0)
    return first + np.arange(n)


def batch_positions(items: List[BatchItem]) -> np.ndarray:
    """All KV positions of one batch — the single implementation shared by
    MoE trace pricing (``_moe_layer_cost``) and the backends' expert-load
    accounting, so the position convention cannot drift between them."""
    return np.concatenate([_item_positions(i) for i in items]) \
        if items else np.zeros(0, np.int64)


class PerfModel:
    def __init__(self, cfg: InstanceCfg, trace: Optional[Trace] = None,
                 expert_model: Optional[ExpertExecutionModel] = None,
                 routing=None):
        """``routing`` (an ``repro.moe.ExpertRoutingTrace``) switches MoE
        pricing from the statistical router to replayed per-layer counts;
        see ``_moe_layer_cost``."""
        self.cfg = cfg
        self.trace = trace
        self.m = cfg.model
        self.hw = cfg.hw
        self.tp = max(cfg.parallelism.tp, 1)
        self.pp = max(cfg.parallelism.pp, 1)
        self.routing = routing
        self.expert_model = expert_model
        if self.m.is_moe and expert_model is None:
            # PIM offload prices against the instance's memory-side
            # accelerator spec; the preset keeps offload="pim" from
            # silently degenerating into a free no-op when unset
            pim = cfg.pim
            if pim is None and cfg.moe.offload == "pim":
                from repro.core.config import PIM_DEVICE
                pim = PIM_DEVICE
            self.expert_model = ExpertExecutionModel(
                cfg, ExpertRouter(cfg.moe, self.m), pim=pim)

    # ---- analytical op costs (per layer-stack, per device) ----
    def _roof(self, flops: float, nbytes: float) -> float:
        return max(flops / (self.hw.peak_flops * self.hw.mmu_efficiency),
                   nbytes / self.hw.hbm_bw)

    def _linear_cost(self, tokens: int, d_in: int, d_out: int) -> float:
        flops = 2.0 * tokens * d_in * d_out / self.tp
        nbytes = (d_in * d_out / self.tp + tokens * (d_in + d_out)) \
            * self.m.dtype_bytes
        return self._roof(flops, nbytes)

    def _attn_context_cost(self, items: List[BatchItem]) -> float:
        m = self.m
        flops = 0.0
        nbytes = 0.0
        for it in items:
            if it.phase == "prefill":
                # causal: tokens x (context) / 2 average
                span = it.tokens * max(it.context, 1) / 2
            else:
                span = it.context
            flops += 4.0 * span * m.n_heads * m.d_head / self.tp
            nbytes += span * m.kv_bytes_per_token / self.tp \
                + it.tokens * m.n_heads * m.d_head * m.dtype_bytes * 3
        return self._roof(flops, nbytes)

    # ---- trace lookup with analytical fallback ----
    def _op(self, op: str, phase: str, tokens: int, context: int,
            analytical) -> float:
        """``analytical`` is a 0-arg thunk, evaluated only when the trace
        has no grid for ``(op, phase)`` — keeping the fallback lazy both
        skips wasted roofline math on trace-covered ops and leaves the
        statistical MoE router's RNG untouched when a trace prices the
        layer (so memoized pricing stays deterministic)."""
        if self.trace is not None:
            v = self.trace.interpolate(op, phase, tokens, context)
            if v is not None:
                return v
        return analytical()

    @staticmethod
    def _bucket(n: int, lo: int = 16) -> int:
        b = lo
        while b < n:
            b *= 2
        return b

    def _iter_level(self, items: List[BatchItem]) -> Optional[IterationCost]:
        """Iteration-granularity trace lookup (runtime_profiler points)."""
        if self.trace is None:
            return None
        pre = [i for i in items if i.phase == "prefill"]
        dec = [i for i in items if i.phase == "decode"]
        # prefill continuations (prefix-cache hits, chunked-prefill chunks
        # past the first) run the engine's ``extend`` path, which is priced
        # separately when the profiler measured it
        cont = [i for i in pre if i.start > 0]
        if cont and self.trace._grid("extend", "prefill"):
            pre = [i for i in pre if i.start == 0]
        else:
            cont = []
        total = 0.0
        for i in cont:
            v = self.trace.interpolate("extend", "prefill",
                                       self._bucket(i.tokens),
                                       i.start + i.tokens)
            if v is None:
                return None
            total += v
        if pre:
            T = sum(i.tokens for i in pre)
            if self.cfg.scheduler.bucket_prefill:
                T = self._bucket(T)
            v = self.trace.interpolate("iter", "prefill", T, T)
            if v is None:
                return None
            total += v
            if any(i.completes for i in pre) and \
                    (self.cfg.role == "prefill"
                     or self.cfg.prefix_cache.enabled):
                # P/D export, or radix-cache insert (same slot copy-out) —
                # charged once, when a request's prefill finishes
                ex = self.trace.interpolate("kv_export", "prefill", T, T)
                if ex is not None:
                    total += ex
        done_cont = [i for i in cont if i.completes]
        if done_cont and (self.cfg.role == "prefill"
                          or self.cfg.prefix_cache.enabled):
            # the insert (slot copy-out) lands once, on the extend iteration
            # that finishes the prompt — not on every chunk
            Tc = max(self._bucket(i.start + i.tokens) for i in done_cont)
            ex = self.trace.interpolate("kv_export", "prefill", Tc, Tc)
            if ex is not None:
                total += ex
        if dec:
            # the engine pads decode batches to its fixed slot count, so a
            # half-full batch costs the same as a full one: price at the
            # configured width, not the occupancy
            B = len(dec)
            if self.cfg.scheduler.decode_pad_to:
                B = max(B, self.cfg.scheduler.decode_pad_to)
            ctx = sum(i.context for i in dec) / len(dec)
            v = self.trace.interpolate("iter", "decode", B, int(ctx))
            if v is None:
                return None
            total += v
        return IterationCost(total, {"iter": total})

    # ---- kernel-granular tier (hwtrace/3 sub-buckets) ----
    def _kernel_backend(self) -> Optional[str]:
        """Which backend's ``kern:*`` rows price this instance.  The cfg's
        ``kernel_backend`` pins it; otherwise prefer pallas rows (they match
        what the pallas engine runs) and fall back to reference rows.  None
        when the trace carries no kernel sub-buckets for any candidate.
        Resolved once per model — traces are read-only in the sim."""
        bk = getattr(self, "_kern_bk", False)
        if bk is not False:
            return bk
        bk = None
        tr = self.trace
        if tr is not None:
            prefs = ([self.cfg.kernel_backend] if self.cfg.kernel_backend
                     else ["pallas", "reference"])
            for cand in prefs:
                if tr._grid(kern_op(cand, "attention"), "decode") \
                        or tr._grid(kern_op(cand, "attention"), "prefill"):
                    bk = cand
                    break
        self._kern_bk = bk
        return bk

    def _kernel_names(self) -> Tuple[str, str, str]:
        """The three kernel kinds one forward pass composes from."""
        return ("attention", "moe_gmm" if self.m.is_moe else "mlp", "head")

    def _kernel_coverage(self, phase: str) -> bool:
        """All three kernel grids present for ``phase``?"""
        bk = self._kernel_backend()
        return bk is not None and all(
            self.trace._grid(kern_op(bk, kn), phase)
            for kn in self._kernel_names())

    def _kernel_level(self, items: List[BatchItem]) -> Optional[IterationCost]:
        """Kernel-granularity pricing: ``L * attention + L * (mlp|moe_gmm) +
        head`` from hwtrace/3 sub-bucket rows, at the op-level tier's batch
        key (tokens = batch tokens, context = max context).  TP collectives
        and PP hops are composed analytically on top — single-device kernel
        sweeps cannot see them.  None when any kernel grid is missing for
        the batch's phase (op-level composition then takes over)."""
        bk = self._kernel_backend()
        if bk is None:
            return None
        tr = self.trace
        m = self.m
        phase = "prefill" if any(i.phase == "prefill" for i in items) \
            else "decode"
        T = sum(it.tokens for it in items)
        ctx = max(it.context for it in items)
        names = self._kernel_names()
        vals = []
        for kn in names:
            v = tr.interpolate(kern_op(bk, kn), phase, T, ctx)
            if v is None:
                return None
            vals.append(v)
        L = m.n_layers
        t_attn = L * vals[0]
        t_ffn = L * vals[1]
        t_head = vals[2]
        ar_bytes = T * m.d_model * m.dtype_bytes
        t_coll = 2 * L * allreduce_time(ar_bytes, self.tp, self.hw.link_bw)
        total = t_attn + t_ffn + t_head + t_coll
        if self.pp > 1:
            hop = T * m.d_model * m.dtype_bytes / self.hw.link_bw + 5e-6
            total = total + (self.pp - 1) * hop
        return IterationCost(total, {
            "kernel:attention": t_attn, f"kernel:{names[1]}": t_ffn,
            "kernel:head": t_head, "collective": t_coll,
            "kernel_backend": bk})

    def _moe_layer_cost(self, items: List[BatchItem], T: int,
                        routing_counts=None) -> float:
        """Mean per-MoE-layer analytical cost for this batch.

        With a routing trace attached, each of the trace's layers is
        priced from its *replayed* per-expert counts at the batch's token
        positions (imbalance, active expert set and offload traffic all
        follow the trace); the mean keeps the ``L * cost`` composition in
        ``iteration_latency`` exact even when the sim model's layer count
        differs from the trace's MoE-layer count.  Without a trace, the
        statistical router draws one representative layer.
        """
        if self.routing is not None:
            if routing_counts is None:
                pos = batch_positions(items)
                routing_counts = [self.routing.counts_for(l, pos)
                                  for l in range(self.routing.n_layers)]
            # counts are priced unclamped: capacity overflow is surfaced
            # as expert_load["drop_rate"] (a quality signal, dropped
            # tokens emit no output), while latency keeps charging the
            # full routed load — pass capacity_factor to ``layer_cost``
            # explicitly to study capacity-saturated pricing instead
            per = [self.expert_model.layer_cost(T, counts=c).total
                   for c in routing_counts]
            return float(np.mean(per))
        return self.expert_model.layer_cost(T).total

    def kv_copy_cost(self, tokens: int) -> float:
        """Slot copy cost (export/restore) for ``tokens`` of KV, from the
        measured kv_export trace; 0 when unprofiled."""
        if self.trace is None or tokens <= 0:
            return 0.0
        v = self.trace.interpolate("kv_export", "prefill",
                                   self._bucket(tokens), self._bucket(tokens))
        return v or 0.0

    def iteration_latency(self, items: List[BatchItem],
                          routing_counts=None) -> IterationCost:
        """``routing_counts`` optionally supplies the per-MoE-layer expert
        counts for this batch (derived once by the caller from the routing
        trace) so pricing and expert-load accounting share one bincount
        pass per iteration instead of each recomputing it."""
        if not items:
            return IterationCost(0.0, {})
        lvl = self._iter_level(items)
        if lvl is not None:
            return lvl
        lvl = self._kernel_level(items)
        if lvl is not None:
            return lvl
        m = self.m
        L = m.n_layers
        T = sum(it.tokens for it in items)
        phase = "prefill" if any(i.phase == "prefill" for i in items) \
            else "decode"
        ctx = max(it.context for it in items)

        qkv_d = (m.n_heads + 2 * m.n_kv_heads) * m.d_head
        t_qkv = L * self._op(
            "attn_qkv", phase, T, ctx,
            lambda: self._linear_cost(T, m.d_model, qkv_d)
            + self._linear_cost(T, m.n_heads * m.d_head, m.d_model))
        t_attn = L * self._op(
            "attn_score", phase, T, ctx,
            lambda: self._attn_context_cost(items))
        if m.is_moe:
            t_ffn = L * self._op(
                "moe_ffn", phase, T, ctx,
                lambda: self._moe_layer_cost(items, T, routing_counts))
        else:
            mults = 3 if m.mlp_gated else 2
            t_ffn = L * self._op(
                "mlp", phase, T, ctx,
                lambda: self._linear_cost(T, m.d_model, m.d_ff) * mults / 2
                + self._linear_cost(T, m.d_ff, m.d_model) / 2
                + self._linear_cost(T, m.d_model, m.d_ff) * (mults - 2))
        t_norm = L * self._op(
            "norm", phase, T, ctx,
            lambda: self._roof(10.0 * T * m.d_model,
                               4.0 * T * m.d_model * m.dtype_bytes))
        t_head = self._op(
            "head", phase, T, ctx,
            lambda: self._linear_cost(sum(1 for i in items)
                                      if phase == "decode"
                                      else T, m.d_model, m.vocab))
        t_embed = self._op(
            "embed", phase, T, ctx,
            lambda: self._roof(0.0, T * m.d_model * m.dtype_bytes * 2))
        # TP all-reduce: 2 per layer on the activations
        ar_bytes = T * m.d_model * m.dtype_bytes
        t_coll = 2 * L * allreduce_time(ar_bytes, self.tp, self.hw.link_bw)
        total = t_qkv + t_attn + t_ffn + t_norm + t_head + t_embed + t_coll
        # pipeline parallelism: per-iteration inter-stage activation hops
        # (throughput overlap across iterations is handled by the scheduler
        # running pp iterations in flight)
        if self.pp > 1:
            hop = T * m.d_model * m.dtype_bytes / self.hw.link_bw + 5e-6
            total = total + (self.pp - 1) * hop
        return IterationCost(total, {
            "qkv": t_qkv, "attn": t_attn, "ffn": t_ffn, "norm": t_norm,
            "head": t_head, "embed": t_embed, "collective": t_coll})

    # ---- fast-path helpers ----
    def pricing_deterministic(self) -> bool:
        """Whether iteration pricing is a pure function of the batch shape.
        False only when the statistical MoE router (a stateful RNG) can be
        consumed: an MoE model whose trace does not cover ``moe_ffn`` for
        both phases.  Memoizing or speculatively re-pricing such batches
        would change the draw stream and thus the simulated timeline."""
        if not self.m.is_moe or self.routing is not None:
            return True
        tr = self.trace
        if tr is None:
            return False
        if self._kernel_coverage("prefill") and \
                self._kernel_coverage("decode"):
            # complete hwtrace/3 kernel coverage: every batch is priced at
            # the kernel tier (or above), so the analytical MoE thunk —
            # and with it the router RNG — is never reached
            return True
        return bool(tr._grid("moe_ffn", "prefill")) \
            and bool(tr._grid("moe_ffn", "decode"))

    def decode_window(self, items: List[BatchItem],
                      n: int) -> Optional[np.ndarray]:
        """Per-step totals for ``n`` successive decode iterations of a
        frozen batch (every item's context grows by 1 per step): element
        ``i`` equals ``iteration_latency`` on the batch advanced ``i``
        steps, bit-identically — both paths run the same interpolation
        kernel and the same scalar accumulation chains.  None when
        vectorization can't guarantee that (no trace, an op grid missing so
        the per-item analytical fallback would engage, a routing trace
        making cost position-dependent, or a non-decode item) — callers
        then price step by step."""
        if self.trace is None or self.routing is not None or n <= 0:
            return None
        if not items or any(i.phase != "decode" for i in items):
            return None
        tr = self.trace
        steps = np.arange(n)
        if tr._grid("iter", "decode"):
            B = len(items)
            if self.cfg.scheduler.decode_pad_to:
                B = max(B, self.cfg.scheduler.decode_pad_to)
            csum = sum(i.context for i in items)
            ctx = ((csum + steps * len(items))
                   / len(items)).astype(np.int64)
            return tr.interpolate_many("iter", "decode", np.full(n, B), ctx)
        m = self.m
        bk = self._kernel_backend()
        if bk is not None and self._kernel_coverage("decode"):
            # kernel tier, vectorized: same interpolation kernel and the
            # same accumulation order as ``_kernel_level`` — bit-identical
            # to stepped pricing
            names = self._kernel_names()
            L = m.n_layers
            T = sum(it.tokens for it in items)
            ctx = max(it.context for it in items) + steps
            tok = np.full(n, T)
            t_attn = L * tr.interpolate_many(kern_op(bk, names[0]),
                                             "decode", tok, ctx)
            t_ffn = L * tr.interpolate_many(kern_op(bk, names[1]),
                                            "decode", tok, ctx)
            t_head = tr.interpolate_many(kern_op(bk, names[2]),
                                         "decode", tok, ctx)
            ar_bytes = T * m.d_model * m.dtype_bytes
            t_coll = 2 * L * allreduce_time(ar_bytes, self.tp,
                                            self.hw.link_bw)
            total = t_attn + t_ffn + t_head + t_coll
            if self.pp > 1:
                hop = T * m.d_model * m.dtype_bytes / self.hw.link_bw + 5e-6
                total = total + (self.pp - 1) * hop
            return total
        ops = ("attn_qkv", "attn_score",
               "moe_ffn" if m.is_moe else "mlp", "norm", "head", "embed")
        if not all(tr._grid(op, "decode") for op in ops):
            return None
        L = m.n_layers
        T = sum(it.tokens for it in items)
        ctx = max(it.context for it in items) + steps
        tok = np.full(n, T)

        def op(name):
            return tr.interpolate_many(name, "decode", tok, ctx)

        t_qkv = L * op("attn_qkv")
        t_attn = L * op("attn_score")
        t_ffn = L * op(ops[2])
        t_norm = L * op("norm")
        t_head = op("head")
        t_embed = op("embed")
        ar_bytes = T * m.d_model * m.dtype_bytes
        t_coll = 2 * L * allreduce_time(ar_bytes, self.tp, self.hw.link_bw)
        total = t_qkv + t_attn + t_ffn + t_norm + t_head + t_embed + t_coll
        if self.pp > 1:
            hop = T * m.d_model * m.dtype_bytes / self.hw.link_bw + 5e-6
            total = total + (self.pp - 1) * hop
        return total
