"""Discrete-event simulation engine (heapq-based)."""
from __future__ import annotations

import heapq
import itertools
from typing import Callable, Optional


class Event:
    __slots__ = ("time", "seq", "fn", "cancelled", "tag")

    def __init__(self, time: float, seq: int, fn: Callable, tag: str = ""):
        self.time = time
        self.seq = seq
        self.fn = fn
        self.cancelled = False
        self.tag = tag

    def __lt__(self, other):
        return (self.time, self.seq) < (other.time, other.seq)


class EventQueue:
    def __init__(self):
        self._heap = []
        self._counter = itertools.count()
        self.now = 0.0
        self.n_processed = 0
        self._n_live = 0          # non-cancelled events (O(1) ``empty``)

    def schedule(self, delay: float, fn: Callable, tag: str = "") -> Event:
        ev = Event(self.now + max(delay, 0.0), next(self._counter), fn, tag)
        heapq.heappush(self._heap, ev)
        self._n_live += 1
        return ev

    def schedule_at(self, t: float, fn: Callable, tag: str = "") -> Event:
        ev = Event(max(t, self.now), next(self._counter), fn, tag)
        heapq.heappush(self._heap, ev)
        self._n_live += 1
        return ev

    def cancel(self, ev: Event):
        if not ev.cancelled:
            ev.cancelled = True
            self._n_live -= 1

    def run(self, until: Optional[float] = None, max_events: int = 50_000_000):
        while self._heap and self.n_processed < max_events:
            ev = heapq.heappop(self._heap)
            if ev.cancelled:
                continue
            if until is not None and ev.time > until:
                heapq.heappush(self._heap, ev)
                self.now = until
                return
            self._n_live -= 1
            self.now = ev.time
            self.n_processed += 1
            ev.fn()

    @property
    def empty(self) -> bool:
        return self._n_live == 0
