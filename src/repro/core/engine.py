"""Discrete-event simulation engine (heapq-based).

Events carry a ``skippable`` flag: an event is skippable when its handler
provably touches only its own component (an isolated instance's iteration
completions).  Everything else — arrivals, KV transfers, failures, scale
events — is a *barrier*.  ``next_barrier_time`` exposes the earliest
pending barrier, which is the horizon the decode fast-forward path must
never cross: between now and that time, no event can change what an
isolated instance would do.
"""
from __future__ import annotations

import heapq
import itertools
from typing import Callable, Optional


class Event:
    __slots__ = ("time", "seq", "fn", "cancelled", "tag", "skippable",
                 "done")

    def __init__(self, time: float, seq: int, fn: Callable, tag: str = "",
                 skippable: bool = False):
        self.time = time
        self.seq = seq
        self.fn = fn
        self.cancelled = False
        self.tag = tag
        self.skippable = skippable
        self.done = False

    def __lt__(self, other):
        return (self.time, self.seq) < (other.time, other.seq)


class EventQueue:
    def __init__(self):
        self._heap = []
        # barrier events only (lazy mirror of _heap; executed/cancelled
        # entries are dropped when next_barrier_time walks past them)
        self._barriers = []
        self._counter = itertools.count()
        self.now = 0.0
        self.n_processed = 0
        self._n_live = 0          # non-cancelled events (O(1) ``empty``)
        self._until: Optional[float] = None   # run(until=...) horizon

    def _push(self, ev: Event) -> Event:
        heapq.heappush(self._heap, ev)
        if not ev.skippable:
            heapq.heappush(self._barriers, ev)
        self._n_live += 1
        return ev

    def schedule(self, delay: float, fn: Callable, tag: str = "",
                 skippable: bool = False) -> Event:
        return self._push(Event(self.now + max(delay, 0.0),
                                next(self._counter), fn, tag, skippable))

    def schedule_at(self, t: float, fn: Callable, tag: str = "",
                    skippable: bool = False) -> Event:
        return self._push(Event(max(t, self.now), next(self._counter), fn,
                                tag, skippable))

    def cancel(self, ev: Event):
        if not ev.cancelled:
            ev.cancelled = True
            self._n_live -= 1

    def next_barrier_time(self) -> float:
        """Earliest pending non-skippable event (inf when none) — capped by
        the active ``run(until=...)`` bound so a fast-forward bulk event
        never outruns the caller's stopping point."""
        b = self._barriers
        while b and (b[0].done or b[0].cancelled):
            heapq.heappop(b)
        t = b[0].time if b else float("inf")
        if self._until is not None:
            t = min(t, self._until)
        return t

    def run(self, until: Optional[float] = None, max_events: int = 50_000_000):
        self._until = until
        while self._heap and self.n_processed < max_events:
            ev = heapq.heappop(self._heap)
            if ev.cancelled:
                continue
            if until is not None and ev.time > until:
                heapq.heappush(self._heap, ev)
                self.now = until
                return
            self._n_live -= 1
            self.now = ev.time
            self.n_processed += 1
            ev.done = True
            ev.fn()

    @property
    def empty(self) -> bool:
        return self._n_live == 0
