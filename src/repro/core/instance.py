"""Compat constructor: a simulated serving instance is now a
``RuntimeInstance`` driven by a ``SimBackend`` (see ``repro.runtime``)."""
from __future__ import annotations

from typing import Optional

from repro.core.config import InstanceCfg
from repro.core.engine import EventQueue
from repro.core.trace import Trace
from repro.runtime.backends.sim import SimBackend
from repro.runtime.instance import RuntimeInstance
from repro.runtime.prefix_cache import RadixPrefixCache


def Instance(cfg: InstanceCfg, queue: EventQueue,
             trace: Optional[Trace] = None,
             shared_cache: Optional[RadixPrefixCache] = None) \
        -> RuntimeInstance:
    backend = SimBackend(cfg, trace=trace)
    cache = shared_cache
    if cache is None and cfg.prefix_cache.enabled:
        cache = RadixPrefixCache(cfg.prefix_cache, backend.memory,
                                 name=f"{cfg.name}.cache")
    return RuntimeInstance(cfg, queue, backend, cache=cache)


__all__ = ["Instance", "RuntimeInstance"]
