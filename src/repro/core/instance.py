"""A serving instance: scheduler + memory + prefix cache + perf model.

Runs the iteration loop as simulation events: pick a batch, price it with
the perf model, schedule the completion event, apply results (prefill
progress, decode tokens, finishes), repeat. Roles: unified | prefill |
decode (P/D disaggregation wires prefill instances to decode instances via
the cluster's KV-transfer path).
"""
from __future__ import annotations

from typing import Callable, List, Optional

from repro.core.config import InstanceCfg
from repro.core.engine import EventQueue
from repro.core.expert import ExpertExecutionModel, ExpertRouter
from repro.core.memory import MemoryModel
from repro.core.perfmodel import PerfModel
from repro.core.prefix_cache import RadixPrefixCache
from repro.core.request import (DECODING, FINISHED, PREFILLING, QUEUED,
                                TRANSFERRING, SimRequest)
from repro.core.scheduler import BatchScheduler, ScheduledWork
from repro.core.trace import Trace


class Instance:
    def __init__(self, cfg: InstanceCfg, queue: EventQueue,
                 trace: Optional[Trace] = None,
                 shared_cache: Optional[RadixPrefixCache] = None):
        self.cfg = cfg
        self.name = cfg.name
        self.queue = queue
        self.mem = MemoryModel(cfg)
        self.scheduler = BatchScheduler(cfg.scheduler, self.mem)
        self.perf = PerfModel(cfg, trace=trace)
        self.cache: Optional[RadixPrefixCache] = None
        if cfg.prefix_cache.enabled:
            self.cache = shared_cache or RadixPrefixCache(
                cfg.prefix_cache, self.mem, name=f"{cfg.name}.cache")
        self.alive = True
        self.busy = False
        self.busy_time = 0.0
        self.iterations = 0
        self.total_tokens = 0
        # callbacks wired by the cluster
        self.on_prefill_done: Optional[Callable] = None   # P/D handoff
        self.on_request_done: Optional[Callable] = None
        self._pending_cache_fetch_s = 0.0

    # ---- request entry ----
    def submit(self, req: SimRequest):
        if not self.alive:
            raise RuntimeError(f"submit to dead instance {self.name}")
        req.instance = self.name
        if self.cache is not None and req.state == QUEUED \
                and req.prefill_done_tokens == 0:
            m = self.cache.match(req.prompt_tokens, self.queue.now)
            # never cache-skip the whole prompt: the last token must be
            # recomputed to produce the first output logits
            usable = min(m.tokens, req.prompt_len - 1)
            req.cached_prefix = max(usable, 0)
            if m.lower_tier_bytes > 0:
                # promote host-tier blocks: pay the fetch on this request
                self._pending_cache_fetch_s += self.mem.transfer_time(
                    m.lower_tier_bytes, "host", "device")
                self.cache.promote(m.nodes, self.queue.now)
            if req.cached_prefix > 0:
                # restoring the hit KV into the running cache is a real slot
                # copy (measured by the engine profiler as kv_export)
                self._pending_cache_fetch_s += self.perf.kv_copy_cost(
                    req.cached_prefix)
            self.cache.pin(m.nodes)
            req._pinned_nodes = m.nodes   # type: ignore[attr-defined]
        self.scheduler.enqueue(req)
        self._kick()

    # ---- iteration loop ----
    def _kick(self):
        if self.alive and not self.busy:
            self._start_iteration()

    def _start_iteration(self):
        work = self.scheduler.next_batch()
        if not work:
            self.busy = False
            return
        self.busy = True
        items = self.scheduler.to_batch_items(work)
        cost = self.perf.iteration_latency(items)
        latency = cost.total_s + self._pending_cache_fetch_s
        self._pending_cache_fetch_s = 0.0
        self.iterations += 1
        self.total_tokens += sum(w.tokens for w in work)
        self.busy_time += latency
        self.queue.schedule(latency, lambda: self._finish_iteration(work),
                            tag=f"{self.name}.iter")

    def _finish_iteration(self, work: List[ScheduledWork]):
        if not self.alive:
            return
        now = self.queue.now
        for w in work:
            req = w.request
            if w.phase == "prefill":
                req.prefill_done_tokens += w.tokens
                if req.remaining_prefill == 0:
                    self._prefill_complete(req)
            else:
                req.generated += 1
                req.token_times.append(now)
                if req.t_first_token is None:
                    req.t_first_token = now
                if req.generated >= req.output_len:
                    self._finish_request(req)
        self.busy = False
        self._start_iteration()

    def _prefill_complete(self, req: SimRequest):
        now = self.queue.now
        # first token is produced by the prefill's last iteration
        if req.t_first_token is None:
            req.t_first_token = now
            req.token_times.append(now)
            req.generated = 1
        if self.cache is not None:
            self.cache.insert(req.prompt_tokens, now)
        if self.cfg.role == "prefill" and self.on_prefill_done is not None:
            req.state = TRANSFERRING
            self.scheduler.complete(req)
            self._unpin(req)
            self.on_prefill_done(req, self)
        else:
            req.state = DECODING
            if req.generated >= req.output_len:
                self._finish_request(req)

    def _finish_request(self, req: SimRequest):
        req.state = FINISHED
        req.t_finish = self.queue.now
        self.scheduler.complete(req)
        self._unpin(req)
        if self.on_request_done is not None:
            self.on_request_done(req, self)

    def _unpin(self, req: SimRequest):
        nodes = getattr(req, "_pinned_nodes", None)
        if nodes and self.cache is not None:
            self.cache.unpin(nodes)
            req._pinned_nodes = []   # type: ignore[attr-defined]

    # ---- decode-side admission for P/D ----
    def admit_decode(self, req: SimRequest):
        """Request arrives with KV already transferred (P/D handoff)."""
        req.instance = self.name
        req.state = DECODING
        req.prefill_done_tokens = req.prompt_len - req.cached_prefix
        self.mem.allocate(req.context_len + req.output_len // 4)
        self.scheduler.running.append(req)
        self._kick()

    # ---- failures / elasticity ----
    def fail(self) -> List[SimRequest]:
        """Node failure: drop in-flight state, return requests to re-route."""
        self.alive = False
        self.busy = False
        return self.scheduler.requeue_all()

    def revive(self):
        self.alive = True
        self._kick()

    def load(self) -> float:
        """Router load signal: queue depth + memory pressure."""
        return (len(self.scheduler.waiting) + len(self.scheduler.running)
                + 2.0 * self.mem.utilization())

    def stats(self) -> dict:
        s = {"iterations": self.iterations, "tokens": self.total_tokens,
             "busy_s": self.busy_time,
             "preemptions": self.scheduler.n_preemptions,
             "mem_peak_blocks": self.mem.peak_used}
        if self.cache is not None:
            s["prefix_cache"] = self.cache.stats()
        return s
