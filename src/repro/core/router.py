"""Compat shim: the routing-policy registry moved to the backend-agnostic
runtime layer (``repro.runtime.router``)."""
from repro.runtime.router import (GlobalRouter, LeastLoaded,  # noqa: F401
                                  PrefixAware, RoundRobin, RoutingPolicy,
                                  register_policy)

__all__ = ["GlobalRouter", "RoutingPolicy", "RoundRobin", "LeastLoaded",
           "PrefixAware", "register_policy"]
