"""Simulator configuration: hardware, instance, cluster, policies.

Mirrors the paper's Fig. 1: a cluster is a *global request router* plus a set
of heterogeneous *instances*; each instance has its own compute devices,
memory model, (optional) prefix cache, parallelism scheme and network links.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class HardwareSpec:
    """Per-device compute/memory spec (profiler hw registry feeds this)."""
    name: str
    peak_flops: float            # FLOP/s (bf16)
    hbm_bw: float                # bytes/s
    hbm_capacity: float          # bytes
    link_bw: float               # bytes/s per inter-device link
    host_bw: float = 16e9        # device<->host (PCIe-class)
    host_capacity: float = 512e9
    ssd_bw: float = 3e9
    ssd_capacity: float = 8e12
    mmu_efficiency: float = 0.85  # achievable fraction of peak on matmuls
    # egress to OTHER instances (NIC / DCN class).  ``NetworkModel`` derives
    # each inter-instance link from the two endpoint devices' values
    # (min-bw rule), so a heterogeneous P/D pair sees the slower NIC.
    inter_instance_bw: float = 25e9
    inter_instance_latency_s: float = 10e-6


@dataclasses.dataclass(frozen=True)
class ParallelismCfg:
    tp: int = 1                  # tensor parallel degree (within instance)
    pp: int = 1                  # pipeline parallel degree
    ep: int = 1                  # expert parallel degree
    dp: int = 1                  # replicas *inside* the instance


@dataclasses.dataclass(frozen=True)
class ModelSpec:
    """What the simulator needs to know about a served model."""
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    d_ff: int
    vocab: int
    moe_experts: int = 0
    moe_top_k: int = 0
    moe_d_expert: int = 0
    moe_capacity_factor: float = 1.25   # per-expert capacity buffer scale
    mlp_gated: bool = True
    param_bytes: float = 0.0     # total weight bytes (computed if 0)
    dtype_bytes: int = 2

    @property
    def is_moe(self) -> bool:
        return self.moe_experts > 0

    @property
    def kv_bytes_per_token(self) -> float:
        return (2 * self.n_layers * self.n_kv_heads * self.d_head
                * self.dtype_bytes)

    def weight_bytes(self) -> float:
        if self.param_bytes:
            return self.param_bytes
        d = self.d_model
        attn = d * self.n_heads * self.d_head * 2 \
            + d * self.n_kv_heads * self.d_head * 2
        if self.is_moe:
            ff = 3 * d * self.moe_d_expert * self.moe_experts \
                + d * self.moe_experts
        else:
            ff = (3 if self.mlp_gated else 2) * d * self.d_ff
        emb = 2 * self.vocab * d
        return (self.n_layers * (attn + ff) + emb) * self.dtype_bytes

    def expert_bytes(self) -> float:
        return 3 * self.d_model * self.moe_d_expert * self.dtype_bytes

    def flops_per_token(self, context: int = 0) -> float:
        """Dense fwd FLOPs per token (+ attention O(context) part)."""
        d = self.d_model
        attn_w = 2 * d * (self.n_heads + 2 * self.n_kv_heads) * self.d_head
        if self.is_moe:
            ff = 2 * 3 * d * self.moe_d_expert * self.moe_top_k
        else:
            ff = 2 * (3 if self.mlp_gated else 2) * d * self.d_ff
        attn_ctx = 4 * self.n_heads * self.d_head * context
        head = 2 * d * self.vocab
        return self.n_layers * (attn_w + ff + attn_ctx) + head


@dataclasses.dataclass(frozen=True)
class TenantClass:
    """A multi-tenant request class: scheduling identity + SLO targets.

    Requests tagged with a tenant class carry its ``priority`` (the
    ``policy="priority"`` scheduler key — larger runs first), its
    ``weight`` (relative service share for the starvation guard,
    ``SchedulerCfg.share_guard_tokens``) and its SLO targets through
    router -> scheduler -> backends; ``metrics()["tenants"]`` rolls up
    per-tenant TTFT/TPOT percentiles, SLO attainment and goodput
    (throughput counting only SLO-met requests) against them, and the
    SLO-aware autoscaler (``repro.runtime.autoscale``) scales the fleet
    on the worst tenant's attainment.
    """
    name: str
    priority: int = 0                # larger = scheduled first
    slo_ttft_ms: float = 2000.0      # time-to-first-token target
    slo_tpot_ms: float = 200.0       # time-per-output-token target
    weight: float = 1.0              # relative share for the fairness guard


@dataclasses.dataclass(frozen=True)
class SchedulerCfg:
    policy: str = "fcfs"             # fcfs | priority | sjf
    max_batch_size: int = 256        # max concurrent sequences
    max_batch_tokens: int = 8192     # per-iteration token budget
    chunked_prefill: bool = True
    prefill_chunk: int = 2048
    straggler_backup_ms: float = 0.0  # >0: re-dispatch if iteration exceeds
    # engine-matching semantics (mirrors repro.serve.ServingEngine):
    # prefill runs alone (one request, whole prompt), decode pads to the
    # slot count, prefill lengths round up to power-of-2 buckets
    prefill_exclusive: bool = False
    decode_pad_to: int = 0
    bucket_prefill: bool = False
    # tokens one decode step may verify/write (speculative decoding sets
    # this to draft k + 1 so the KV ledger reserves the verification
    # window and the token budget charges the real compute width; the
    # step still *emits* a variable 1..k+1 tokens per the acceptance draw)
    decode_tokens: int = 1
    # weighted-share starvation guard for policy="priority": > 0 bounds
    # how far a waiting tenant's weight-normalized service (scheduled
    # tokens / tenant weight) may lag the head-of-queue tenant's before
    # the scheduler admits the lagging tenant first.  0 disables the
    # guard (pure priority order — low-priority tenants can starve).
    share_guard_tokens: int = 0


@dataclasses.dataclass(frozen=True)
class PrefixCacheCfg:
    enabled: bool = False
    block_tokens: int = 16           # radix-tree block granularity
    capacity_fraction: float = 0.5   # fraction of free HBM usable for cache
    host_spill: bool = True          # device eviction spills HBM -> host RAM
    ssd_spill: bool = False          # host eviction spills host -> SSD
    # pluggable eviction-victim selection, resolved through the registry in
    # repro.runtime.prefix_cache (register_eviction_policy adds names):
    # "lru" | "lfu" | "priority" (priority-weighted LRU — low-priority
    # tenants' blocks evict first)
    eviction_policy: str = "lru"
    scope: str = "instance"          # instance | global


@dataclasses.dataclass(frozen=True)
class MoECfg:
    expert_parallel: bool = True
    offload: str = "none"            # none | host | pim
    offload_fraction: float = 0.0    # fraction of experts offloaded
    prefetch: bool = True            # overlap expert fetch with compute
    routing: str = "uniform"         # uniform | zipf | correlated
    zipf_a: float = 1.1
    # named ExpertRoutingTrace (resolved through repro.moe's registry at
    # instance build time, like InstanceCfg.hw_name).  When set, expert
    # load is *replayed* from the trace instead of drawn statistically:
    # the simulator prices per-layer counts from it and the real engine
    # forces the same assignments through its routing hook, so both
    # backends report identical metrics()["expert_load"].
    routing_trace: Optional[str] = None


@dataclasses.dataclass(frozen=True)
class SpecCfg:
    """Speculative decoding (draft/verify) for one instance.

    The simulator prices every spec step as draft-cost + verify-cost and
    advances requests by accepted + 1 tokens drawn deterministically from
    the named ``AcceptanceTrace`` (resolved through ``repro.spec``'s
    registry at instance build time, like ``MoECfg.routing_trace``); the
    real engine runs an actual draft model + batched target verification
    (``ServingEngine(spec=...)``) and, when replaying the same trace,
    reports identical ``metrics()["spec_decode"]``.
    """
    enabled: bool = False
    k: int = 4                       # draft proposal length per step
    # sim draft pricing model; None -> repro.spec.draft_model_spec scales
    # the target down by ``draft_scale``
    draft: Optional[ModelSpec] = None
    draft_scale: float = 0.25
    # named AcceptanceTrace — required for simulation (the sim has no
    # draft/target pair to measure acceptance from)
    acceptance_trace: Optional[str] = None


@dataclasses.dataclass(frozen=True)
class InstanceCfg:
    name: str
    hw: HardwareSpec
    model: ModelSpec
    n_devices: int = 1
    parallelism: ParallelismCfg = ParallelismCfg()
    scheduler: SchedulerCfg = SchedulerCfg()
    prefix_cache: PrefixCacheCfg = PrefixCacheCfg()
    moe: MoECfg = MoECfg()
    spec: SpecCfg = SpecCfg()
    # memory-side accelerator spec for MoE expert offloading
    # (``MoECfg.offload="pim"``): offloaded experts execute on this device
    # in ``ExpertExecutionModel``.  None falls back to the ``PIM_DEVICE``
    # preset when pim offload is configured, so the offload path always
    # prices against a real spec.
    pim: Optional[HardwareSpec] = None
    role: str = "unified"            # unified | prefill | decode
    kv_block_tokens: int = 16        # PagedAttention block size
    trace_name: Optional[str] = None  # perf-model trace to use
    # which kernel backend's hwtrace/3 sub-bucket rows price this instance
    # ("pallas" | "reference").  None auto-picks: pallas rows when the
    # trace carries them, else reference, else no kernel tier.
    kernel_backend: Optional[str] = None
    # hardware by name: resolved through the repro.hw registry at instance
    # build time (measured HardwareTrace if one is loaded, synthetic
    # analytical trace otherwise).  Lets one cluster mix accelerators —
    # e.g. GPU-class prefill + TPU-class decode instances (docs/
    # adding-hardware.md).  When set, the trace's embedded spec overrides
    # ``hw`` so memory model and fallback pricing match the device.
    hw_name: Optional[str] = None
    # KV watermark timeline window (samples kept); evictions beyond it
    # are counted in stats()["kv_watermark_dropped"] — no silent caps
    watermark_window: int = 4096


@dataclasses.dataclass(frozen=True)
class RouterCfg:
    # round_robin | least_loaded | prefix_aware | hardware_aware |
    # kv_residency (prefix matches weighted by the tier the blocks live in)
    policy: str = "round_robin"
    model_affinity: bool = True      # requests route to instances serving their model


@dataclasses.dataclass(frozen=True)
class NetworkCfg:
    """Cluster network *defaults*.  Links between instances whose hardware
    was resolved through the trace registry are derived from the endpoint
    devices' ``InterconnectSpec``s (min-bw rule; see ``NetworkModel``) —
    these values only price links with at least one endpoint that carries
    no device interconnect info (e.g. raw ``hw=`` instances and the real
    engine driver's configurable transfer bandwidth)."""
    inter_instance_bw: float = 25e9  # bytes/s between instances (DCN/PCIe)
    inter_instance_latency: float = 10e-6
    kv_transfer_policy: str = "full_blocking"  # full_blocking | layerwise_overlap


@dataclasses.dataclass(frozen=True)
class ClusterCfg:
    instances: Tuple[InstanceCfg, ...]
    router: RouterCfg = RouterCfg()
    network: NetworkCfg = NetworkCfg()
    # P/D disaggregation: map prefill-instance name -> decode-instance names
    pd_map: Optional[Dict[str, Tuple[str, ...]]] = None


# --- hardware presets -------------------------------------------------------

RTX3090 = HardwareSpec(
    name="rtx3090", peak_flops=71e12, hbm_bw=936e9, hbm_capacity=24e9,
    link_bw=16e9,   # paper's GPU baseline: PCIe 4.0 x16 interconnect
    inter_instance_bw=25e9)           # 200GbE-class NIC

TPU_V5E = HardwareSpec(
    name="tpu-v5e", peak_flops=197e12, hbm_bw=819e9, hbm_capacity=16e9,
    link_bw=50e9, inter_instance_bw=50e9)

TPU_V6E = HardwareSpec(
    name="tpu-v6e", peak_flops=918e12, hbm_bw=1.6e12, hbm_capacity=32e9,
    link_bw=100e9,  # paper's Colab TPU integration case study
    inter_instance_bw=100e9)          # ICI/DCN-class egress

PIM_DEVICE = HardwareSpec(
    name="pim", peak_flops=8e12, hbm_bw=2.0e12, hbm_capacity=16e9,
    link_bw=25e9,   # memory-side accelerator for expert offloading [7,8]
    inter_instance_bw=25e9)

CPU_HOST = HardwareSpec(
    name="cpu-host", peak_flops=2e12, hbm_bw=80e9, hbm_capacity=256e9,
    link_bw=16e9, inter_instance_bw=12.5e9)

ENGINE_HW = HardwareSpec(
    # matches the container's CPU engine environment: used for engine-matched
    # simulated instances and for the real JaxBackend's block accounting
    name="cpu-engine", peak_flops=5e10, hbm_bw=20e9, hbm_capacity=8e9,
    link_bw=8e9, host_bw=8e9, inter_instance_bw=8e9)


def engine_scheduler_cfg(max_batch: int) -> SchedulerCfg:
    """ServingEngine-matched scheduling semantics (the single definition
    shared by the real driver and the engine-matched sim benchmarks): one
    whole-prompt prefill at a time, decode pads to the slot count, bucketed
    prefill lengths."""
    return SchedulerCfg(
        max_batch_size=max_batch, max_batch_tokens=1 << 16,
        chunked_prefill=False, prefill_exclusive=True,
        bucket_prefill=True, decode_pad_to=max_batch)
