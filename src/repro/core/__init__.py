"""LLMServingSim2.0 core: the paper's primary contribution.

A discrete-event simulator for heterogeneous multi-instance LLM serving:
trace-driven perf modeling, global request routing, P/D disaggregation,
MoE expert parallelism/offloading, and radix-tree prefix caching.
"""
from repro.core.cluster import Cluster, simulate
from repro.core.config import (CPU_HOST, PIM_DEVICE, RTX3090, TPU_V5E,
                               TPU_V6E, ClusterCfg, HardwareSpec, InstanceCfg,
                               MoECfg, ModelSpec, NetworkCfg, ParallelismCfg,
                               PrefixCacheCfg, RouterCfg, SchedulerCfg,
                               SpecCfg, TenantClass)
from repro.core.metrics import aggregate
from repro.core.request import SimRequest
from repro.core.trace import Trace, TraceRegistry

__all__ = [
    "Cluster", "simulate", "ClusterCfg", "HardwareSpec", "InstanceCfg",
    "MoECfg", "ModelSpec", "NetworkCfg", "ParallelismCfg", "PrefixCacheCfg",
    "RouterCfg", "SchedulerCfg", "SpecCfg", "TenantClass", "aggregate",
    "SimRequest", "Trace",
    "TraceRegistry", "RTX3090", "TPU_V5E", "TPU_V6E", "PIM_DEVICE",
    "CPU_HOST",
]
