"""Cluster simulation driver: the unified ``ServingRuntime`` specialized to
the simulation backend.  ``simulate(requests)`` is the main entry point used
by every benchmark and example; the real-engine twin is
``repro.serve.ServeDriver`` — same scheduler, cache, router and P/D code
path, different ``ExecutionBackend``.
"""
from __future__ import annotations

from typing import TYPE_CHECKING, Dict, Optional, Sequence

from repro.core.config import ClusterCfg
from repro.core.trace import TraceRegistry
from repro.runtime.backends.sim import SimBackend
from repro.runtime.cluster import ServingRuntime
from repro.workload.sharegpt import Request

if TYPE_CHECKING:
    from repro.hw.registry import HardwareRegistry


class Cluster(ServingRuntime):
    def __init__(self, cfg: ClusterCfg,
                 traces: Optional[TraceRegistry] = None,
                 hw: Optional["HardwareRegistry"] = None):
        super().__init__(
            cfg,
            backend_factory=lambda icfg, trace: SimBackend(icfg, trace=trace),
            traces=traces, hw=hw)


def simulate(cfg: ClusterCfg, requests: Sequence[Request],
             traces: Optional[TraceRegistry] = None,
             hw: Optional["HardwareRegistry"] = None,
             until: Optional[float] = None) -> Dict:
    cluster = Cluster(cfg, traces=traces, hw=hw)
    cluster.submit_workload(requests)
    return cluster.run(until=until)
