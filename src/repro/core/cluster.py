"""Cluster simulation driver: router + instances + network + P/D wiring +
failure injection + elastic scaling. ``simulate(requests)`` is the main
entry point used by every benchmark and example.
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional, Sequence

from repro.core.config import ClusterCfg, InstanceCfg
from repro.core.engine import EventQueue
from repro.core.instance import Instance
from repro.core.metrics import aggregate
from repro.core.network import NetworkModel
from repro.core.prefix_cache import RadixPrefixCache
from repro.core.request import QUEUED, SimRequest
from repro.core.router import GlobalRouter
from repro.core.trace import TraceRegistry
from repro.workload.sharegpt import Request


class Cluster:
    def __init__(self, cfg: ClusterCfg,
                 traces: Optional[TraceRegistry] = None):
        self.cfg = cfg
        self.queue = EventQueue()
        self.network = NetworkModel(cfg.network)
        self.traces = traces or TraceRegistry()
        self.instances: Dict[str, Instance] = {}
        shared_cache = None
        for icfg in cfg.instances:
            trace = (self.traces.get(icfg.trace_name)
                     if icfg.trace_name else None)
            inst = Instance(icfg, self.queue, trace=trace)
            # global prefix cache scope: all instances share one radix tree
            if icfg.prefix_cache.enabled and \
                    icfg.prefix_cache.scope == "global":
                if shared_cache is None:
                    shared_cache = RadixPrefixCache(
                        icfg.prefix_cache, inst.mem, name="global.cache")
                inst.cache = shared_cache
            inst.on_request_done = self._on_done
            self.instances[icfg.name] = inst
        self.router = GlobalRouter(
            cfg.router, list(self.instances.values()))
        self._wire_pd()
        self.finished: List[SimRequest] = []
        self._all_requests: List[SimRequest] = []

    # ---- P/D disaggregation wiring ----
    def _wire_pd(self):
        pd = self.cfg.pd_map or {}
        for pname, dnames in pd.items():
            p_inst = self.instances[pname]
            d_insts = [self.instances[d] for d in dnames]
            rr = {"i": 0}

            def handoff(req: SimRequest, src: Instance,
                        d_insts=d_insts, rr=rr):
                # pick decode instance (round-robin over the pool)
                tgt = min(d_insts, key=lambda i: i.load()) if d_insts else None
                if tgt is None:
                    return
                req.decode_instance = tgt.name
                kv_bytes = req.prompt_len * src.cfg.model.kv_bytes_per_token
                if self.cfg.network.kv_transfer_policy == "layerwise_overlap":
                    # transfer overlapped with the last prefill layers: only
                    # the final layer's KV lands on the critical path
                    kv_bytes = kv_bytes / max(src.cfg.model.n_layers, 1)
                done_t = self.network.kv_transfer_done(
                    self.queue.now, src.name, tgt.name, kv_bytes)
                self.queue.schedule_at(
                    done_t, lambda: tgt.admit_decode(req),
                    tag=f"kv:{src.name}->{tgt.name}")

            p_inst.on_prefill_done = handoff

    # ---- lifecycle ----
    def _on_done(self, req: SimRequest, inst: Instance):
        self.finished.append(req)

    def submit_workload(self, requests: Sequence[Request]):
        for r in requests:
            sim = SimRequest(req_id=r.req_id, arrival=r.arrival,
                             prompt_tokens=list(r.prompt_tokens),
                             output_len=r.output_len, model=r.model)
            self._all_requests.append(sim)
            self.queue.schedule_at(
                r.arrival, lambda s=sim: self.router.dispatch(s,
                                                              self.queue.now),
                tag="arrival")

    # ---- failures / elastic scaling ----
    def inject_failure(self, t: float, instance: str,
                       recover_after: Optional[float] = None):
        def fail():
            inst = self.instances[instance]
            orphans = inst.fail()
            for req in orphans:
                req.state = QUEUED
                req.cached_prefix = 0
                self.router.dispatch(req, self.queue.now)
        self.queue.schedule_at(t, fail, tag=f"fail:{instance}")
        if recover_after is not None:
            self.queue.schedule_at(
                t + recover_after,
                lambda: self.instances[instance].revive(),
                tag=f"revive:{instance}")

    def add_instance(self, t: float, icfg: InstanceCfg):
        """Elastic scale-out at simulated time t."""
        def add():
            trace = (self.traces.get(icfg.trace_name)
                     if icfg.trace_name else None)
            inst = Instance(icfg, self.queue, trace=trace)
            inst.on_request_done = self._on_done
            self.instances[icfg.name] = inst
            self.router.instances.append(inst)
        self.queue.schedule_at(t, add, tag=f"scale:{icfg.name}")

    # ---- run ----
    def run(self, until: Optional[float] = None) -> Dict:
        t0 = time.time()
        self.queue.run(until=until)
        wall = time.time() - t0
        m = aggregate(self._all_requests)
        m["sim_wall_s"] = wall
        m["sim_events"] = self.queue.n_processed
        m["instances"] = {n: i.stats() for n, i in self.instances.items()}
        m["network_bytes"] = self.network.stats()
        return m


def simulate(cfg: ClusterCfg, requests: Sequence[Request],
             traces: Optional[TraceRegistry] = None,
             until: Optional[float] = None) -> Dict:
    cluster = Cluster(cfg, traces=traces)
    cluster.submit_workload(requests)
    return cluster.run(until=until)
