"""Cluster simulation driver: the unified ``ServingRuntime`` specialized to
the simulation backend.  ``simulate(requests)`` is the main entry point used
by every benchmark and example; the real-engine twin is
``repro.serve.ServeDriver`` — same scheduler, cache, router and P/D code
path, different ``ExecutionBackend``.

``fast_path`` (default on) enables the simulator's iteration-cost memo and
decode fast-forward; it is decision- and metric-identical to the stepped
exact mode (``fast_path=False``), which remains available as the reference
for the parity suite and for debugging event-by-event timelines.
"""
from __future__ import annotations

from typing import TYPE_CHECKING, Dict, Optional, Sequence

from repro.core.config import ClusterCfg
from repro.core.trace import TraceRegistry
from repro.runtime.backends.sim import SimBackend
from repro.runtime.cluster import ServingRuntime
from repro.workload.sharegpt import Request

if TYPE_CHECKING:
    from repro.hw.registry import HardwareRegistry


class Cluster(ServingRuntime):
    def __init__(self, cfg: ClusterCfg,
                 traces: Optional[TraceRegistry] = None,
                 hw: Optional["HardwareRegistry"] = None,
                 fast_path: bool = True,
                 recorder=None):
        super().__init__(
            cfg,
            backend_factory=lambda icfg, trace: SimBackend(
                icfg, trace=trace, fast_path=fast_path),
            traces=traces, hw=hw, recorder=recorder)


def simulate(cfg: ClusterCfg, requests: Sequence[Request],
             traces: Optional[TraceRegistry] = None,
             hw: Optional["HardwareRegistry"] = None,
             until: Optional[float] = None,
             fast_path: bool = True,
             autoscale=None,
             trace=None) -> Dict:
    """Run the workload to completion.  ``autoscale`` optionally attaches
    an ``repro.runtime.autoscale.SLOAutoscaler`` (metrics land under
    ``metrics()["autoscale"]``).

    ``trace`` enables runtime event tracing (``docs/observability.md``):
    pass a ``repro.obs.EventRecorder`` to keep the event log in hand, or
    a path string to write a Perfetto-loadable Chrome trace JSON there.
    Either way ``metrics()["attribution"]`` carries the per-request
    latency waterfalls.  ``None`` (default) records nothing and costs
    nothing.
    """
    recorder, trace_path = None, None
    if trace is not None:
        # lazy import: repro.core must not pull higher layers at load time
        from repro.obs.record import EventRecorder
        if isinstance(trace, EventRecorder):
            recorder = trace
        else:
            trace_path = str(trace)
            recorder = EventRecorder()
    cluster = Cluster(cfg, traces=traces, hw=hw, fast_path=fast_path,
                      recorder=recorder)
    if autoscale is not None:
        cluster.attach_autoscaler(autoscale)
    cluster.submit_workload(requests)
    m = cluster.run(until=until)
    if trace_path is not None:
        from repro.obs.export import write_chrome_trace
        write_chrome_trace(recorder, trace_path)
    return m
