"""Compat shim: the radix prefix cache moved to the backend-agnostic
runtime layer (``repro.runtime.prefix_cache``)."""
from repro.runtime.prefix_cache import (MatchResult,  # noqa: F401
                                        RadixPrefixCache, _Node)

__all__ = ["MatchResult", "RadixPrefixCache"]
