"""Paged KV-cache memory model (PagedAttention semantics) + memory tiers.

Device HBM holds model weights + a block pool for KV pages; the prefix cache
borrows idle pool blocks (paper §II-D: first-tier cache in device memory,
eviction spills to host, optionally SSD). Transfers between tiers produce
latency events through ``transfer_time``.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

from repro.core.config import HardwareSpec, InstanceCfg, ModelSpec


@dataclasses.dataclass
class TierStats:
    capacity: float
    used: float = 0.0


class MemoryModel:
    def __init__(self, cfg: InstanceCfg):
        self.cfg = cfg
        hw = cfg.hw
        model = cfg.model
        self.block_tokens = cfg.kv_block_tokens
        self.kv_bytes_per_token = model.kv_bytes_per_token / max(
            cfg.parallelism.tp, 1)  # per-device share
        weight_bytes = model.weight_bytes() / max(
            cfg.parallelism.tp * cfg.parallelism.pp, 1)
        if cfg.moe.offload != "none" and model.is_moe:
            off = cfg.moe.offload_fraction
            expert_total = (model.expert_bytes() * model.moe_experts
                            * model.n_layers) / max(cfg.parallelism.tp, 1)
            weight_bytes -= expert_total * off
        self.weight_bytes = max(weight_bytes, 0.0)
        budget = hw.hbm_capacity * 0.9 - self.weight_bytes
        if budget <= 0:
            raise ValueError(
                f"model does not fit: weights {self.weight_bytes/1e9:.1f}GB "
                f"> HBM {hw.hbm_capacity/1e9:.1f}GB (instance {cfg.name})")
        self.bytes_per_block = self.kv_bytes_per_token * self.block_tokens
        self.total_blocks = int(budget / self.bytes_per_block)
        self.free_blocks = self.total_blocks
        self.cache_blocks_used = 0       # prefix-cache borrowed blocks
        self.host = TierStats(hw.host_capacity)
        self.ssd = TierStats(hw.ssd_capacity)
        self.hw = hw
        self.peak_used = 0

    # ---- block pool ----
    def blocks_for(self, tokens: int) -> int:
        return -(-tokens // self.block_tokens)

    def can_allocate(self, tokens: int) -> bool:
        return self.blocks_for(tokens) <= self.free_blocks

    def allocate(self, tokens: int) -> bool:
        n = self.blocks_for(tokens)
        if n > self.free_blocks:
            return False
        self.free_blocks -= n
        self.peak_used = max(self.peak_used,
                             self.total_blocks - self.free_blocks)
        return True

    def free(self, tokens: int):
        self.free_blocks = min(self.total_blocks,
                               self.free_blocks + self.blocks_for(tokens))

    # block-granular API (the scheduler's reservation ledger)
    def allocate_blocks(self, n: int) -> bool:
        if n > self.free_blocks:
            return False
        self.free_blocks -= n
        self.peak_used = max(self.peak_used,
                             self.total_blocks - self.free_blocks)
        return True

    def release_blocks(self, n: int):
        self.free_blocks = min(self.total_blocks, self.free_blocks + n)

    def utilization(self) -> float:
        return 1.0 - self.free_blocks / max(self.total_blocks, 1)

    # ---- prefix cache borrowing ----
    def cache_capacity_blocks(self, fraction: float) -> int:
        return int(self.total_blocks * fraction)

    def borrow_for_cache(self, blocks: int) -> bool:
        if blocks > self.free_blocks:
            return False
        self.free_blocks -= blocks
        self.cache_blocks_used += blocks
        return True

    def return_from_cache(self, blocks: int):
        take = min(blocks, self.cache_blocks_used)
        self.cache_blocks_used -= take
        self.free_blocks += take

    # ---- lower-tier pools (prefix-cache spill targets) ----
    def tier(self, name: str) -> TierStats:
        if name == "host":
            return self.host
        if name == "ssd":
            return self.ssd
        raise KeyError(f"unknown memory tier {name!r} (host | ssd)")

    def tier_reserve(self, name: str, n_bytes: float) -> bool:
        """Claim ``n_bytes`` in a lower tier; False when it would not fit."""
        ts = self.tier(name)
        if ts.used + n_bytes > ts.capacity:
            return False
        ts.used += n_bytes
        return True

    def tier_release(self, name: str, n_bytes: float):
        ts = self.tier(name)
        ts.used = max(0.0, ts.used - n_bytes)

    def tier_stats(self) -> Dict[str, Dict[str, float]]:
        return {
            "host": {"capacity": self.host.capacity, "used": self.host.used},
            "ssd": {"capacity": self.ssd.capacity, "used": self.ssd.used},
        }

    # ---- tier transfers ----
    def transfer_time(self, n_bytes: float, src: str, dst: str) -> float:
        """device<->host<->ssd transfer latency (bandwidth-limited)."""
        path_bw = {
            ("device", "host"): self.hw.host_bw,
            ("host", "device"): self.hw.host_bw,
            ("host", "ssd"): self.hw.ssd_bw,
            ("ssd", "host"): self.hw.ssd_bw,
            ("ssd", "device"): min(self.hw.ssd_bw, self.hw.host_bw),
            ("device", "ssd"): min(self.hw.ssd_bw, self.hw.host_bw),
        }[(src, dst)]
        return n_bytes / path_bw
