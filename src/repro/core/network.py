"""Network model: intra-instance collectives + inter-instance transfers.

Intra-instance (TP all-reduce, EP all-to-all) is bandwidth-modeled from the
device link bandwidth with ring/all-to-all factors. Inter-instance transfers
(P/D KV moves, global prefix cache) go through shared ``Link`` objects that
serialize: concurrent transfers queue, which is how network contention shows
up in multi-instance simulations (paper §III-C attributes multi-instance
error to exactly this effect).

Link parameters are derived per device pair, not cluster-globally: every
instance whose hardware was resolved through the trace registry registers
its device's interconnect parameters (``register_endpoint``), and a link
between two registered endpoints gets ``min`` of their egress bandwidths
and the ``max`` of their latencies — a GPU-class NIC talking to a TPU-class
DCN port moves at the NIC's rate.  ``override_link`` pins explicit values
for one pair (e.g. a measured cross-rack route); the ``NetworkCfg`` numbers
only price links with an unregistered endpoint.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

from repro.core.config import NetworkCfg


def allreduce_time(nbytes: float, n: int, link_bw: float) -> float:
    if n <= 1:
        return 0.0
    return 2.0 * nbytes * (n - 1) / n / link_bw


def allgather_time(nbytes: float, n: int, link_bw: float) -> float:
    if n <= 1:
        return 0.0
    return nbytes * (n - 1) / n / link_bw


def alltoall_time(nbytes: float, n: int, link_bw: float) -> float:
    if n <= 1:
        return 0.0
    return nbytes * (n - 1) / n / link_bw


class Link:
    """A serialized shared link: transfers occupy it back-to-back."""

    def __init__(self, bw: float, latency: float = 10e-6):
        self.bw = bw
        self.latency = latency
        self.busy_until = 0.0
        self.bytes_moved = 0.0

    def transfer(self, now: float, nbytes: float) -> float:
        """Returns completion time, accounting for queueing."""
        start = max(now, self.busy_until)
        done = start + self.latency + nbytes / self.bw
        self.busy_until = done
        self.bytes_moved += nbytes
        return done


class NetworkModel:
    """Per-device-pair links (see module docstring).

    Endpoint interconnects are duck-typed: anything with
    ``inter_instance_bw`` / ``inter_instance_latency_s`` attributes
    (``repro.hw.InterconnectSpec`` in practice — kept duck-typed so
    ``repro.core`` stays below ``repro.hw`` in the layering).
    """

    def __init__(self, cfg: NetworkCfg):
        self.cfg = cfg
        self._links: Dict[tuple, Link] = {}
        self._endpoints: Dict[str, object] = {}
        self._overrides: Dict[tuple, Tuple[Optional[float],
                                           Optional[float]]] = {}

    # ---- topology ----
    def register_endpoint(self, name: str, interconnect) -> None:
        """Attach a device ``InterconnectSpec`` to instance ``name``.
        Existing links touching it immediately re-derive their parameters
        (in place, preserving queue state and traffic counters), so late
        registration — e.g. elastic scale-out — takes effect for all
        subsequent transfers."""
        self._endpoints[name] = interconnect
        for key in self._links:
            if name in key:
                self._reprice(key)

    def override_link(self, a: str, b: str, bw: Optional[float] = None,
                      latency: Optional[float] = None) -> None:
        """Pin explicit parameters for one instance pair (unset fields
        keep the derived value) — the escape hatch for measured routes.
        Applies immediately, also to a link that already carried traffic
        (queue state and byte counters are preserved)."""
        key = (min(a, b), max(a, b))
        self._overrides[key] = (bw, latency)
        if key in self._links:
            self._reprice(key)

    def _reprice(self, key: tuple) -> None:
        link = self._links[key]
        link.bw, link.latency = self.link_params(*key)

    def link_params(self, a: str, b: str) -> Tuple[float, float]:
        """(bandwidth, latency) the link between ``a`` and ``b`` uses:
        min-bw / max-latency over the two endpoints' device interconnects,
        ``NetworkCfg`` defaults when either endpoint is unregistered, and
        explicit overrides on top."""
        ia, ib = self._endpoints.get(a), self._endpoints.get(b)
        if ia is not None and ib is not None:
            bw = min(ia.inter_instance_bw, ib.inter_instance_bw)
            lat = max(ia.inter_instance_latency_s,
                      ib.inter_instance_latency_s)
        else:
            bw = self.cfg.inter_instance_bw
            lat = self.cfg.inter_instance_latency
        o_bw, o_lat = self._overrides.get((min(a, b), max(a, b)),
                                          (None, None))
        return (o_bw if o_bw is not None else bw,
                o_lat if o_lat is not None else lat)

    # ---- transfers ----
    def link(self, a: str, b: str) -> Link:
        key = (min(a, b), max(a, b))
        if key not in self._links:
            bw, lat = self.link_params(a, b)
            self._links[key] = Link(bw, lat)
        return self._links[key]

    def kv_transfer_done(self, now: float, src: str, dst: str,
                         nbytes: float) -> float:
        return self.link(src, dst).transfer(now, nbytes)

    def stats(self) -> dict:
        return {f"{a}<->{b}": l.bytes_moved
                for (a, b), l in self._links.items()}

    def link_stats(self) -> dict:
        """Per-link parameters + traffic (asymmetric-bandwidth audits)."""
        return {f"{a}<->{b}": {"bw": l.bw, "latency_s": l.latency,
                               "bytes": l.bytes_moved}
                for (a, b), l in self._links.items()}
