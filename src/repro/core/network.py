"""Network model: intra-instance collectives + inter-instance transfers.

Intra-instance (TP all-reduce, EP all-to-all) is bandwidth-modeled from the
device link bandwidth with ring/all-to-all factors. Inter-instance transfers
(P/D KV moves, global prefix cache) go through shared ``Link`` objects that
serialize: concurrent transfers queue, which is how network contention shows
up in multi-instance simulations (paper §III-C attributes multi-instance
error to exactly this effect).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

from repro.core.config import NetworkCfg


def allreduce_time(nbytes: float, n: int, link_bw: float) -> float:
    if n <= 1:
        return 0.0
    return 2.0 * nbytes * (n - 1) / n / link_bw


def allgather_time(nbytes: float, n: int, link_bw: float) -> float:
    if n <= 1:
        return 0.0
    return nbytes * (n - 1) / n / link_bw


def alltoall_time(nbytes: float, n: int, link_bw: float) -> float:
    if n <= 1:
        return 0.0
    return nbytes * (n - 1) / n / link_bw


class Link:
    """A serialized shared link: transfers occupy it back-to-back."""

    def __init__(self, bw: float, latency: float = 10e-6):
        self.bw = bw
        self.latency = latency
        self.busy_until = 0.0
        self.bytes_moved = 0.0

    def transfer(self, now: float, nbytes: float) -> float:
        """Returns completion time, accounting for queueing."""
        start = max(now, self.busy_until)
        done = start + self.latency + nbytes / self.bw
        self.busy_until = done
        self.bytes_moved += nbytes
        return done


class NetworkModel:
    def __init__(self, cfg: NetworkCfg):
        self.cfg = cfg
        self._links: Dict[tuple, Link] = {}

    def link(self, a: str, b: str) -> Link:
        key = (min(a, b), max(a, b))
        if key not in self._links:
            self._links[key] = Link(self.cfg.inter_instance_bw,
                                    self.cfg.inter_instance_latency)
        return self._links[key]

    def kv_transfer_done(self, now: float, src: str, dst: str,
                         nbytes: float) -> float:
        return self.link(src, dst).transfer(now, nbytes)

    def stats(self) -> dict:
        return {f"{a}<->{b}": l.bytes_moved
                for (a, b), l in self._links.items()}
