"""Operator-latency trace format (the contract between profiler and sim).

A trace is a set of measured operator latencies for one (model, hardware,
parallelism) triple, keyed by operator kind and phase, over a grid of
(tokens, context) points. The perf model interpolates this grid; anything
outside the grid falls back to the analytical model. This is LLMServingSim
2.0's central abstraction: integrating new hardware == producing one trace
file with the operator-level profiler (paper §II-A, Table III).
"""
from __future__ import annotations

import dataclasses
import json
import math
import os
from typing import Dict, List, Optional, Tuple

# operator kinds the profiler emits and the sim consumes
OP_KINDS = (
    "embed", "attn_qkv", "attn_score", "attn_out", "mlp", "moe_ffn",
    "moe_router", "norm", "head", "mamba", "xlstm", "sampler",
)


@dataclasses.dataclass
class OpPoint:
    op: str
    phase: str          # prefill | decode
    tokens: int         # batch tokens processed this iteration
    context: int        # KV/context length (decode) or seq len (prefill)
    latency_s: float


@dataclasses.dataclass
class Trace:
    model: str
    hardware: str
    tp: int
    points: List[OpPoint] = dataclasses.field(default_factory=list)
    meta: Dict = dataclasses.field(default_factory=dict)

    def add(self, op, phase, tokens, context, latency_s):
        self.points.append(OpPoint(op, phase, int(tokens), int(context),
                                   float(latency_s)))

    # ---- lookup ----
    def _grid(self, op: str, phase: str):
        pts = [p for p in self.points if p.op == op and p.phase == phase]
        return pts

    def interpolate(self, op: str, phase: str, tokens: int,
                    context: int) -> Optional[float]:
        """Log-space bilinear interpolation over the (tokens, context) grid;
        nearest-edge clamp outside; None when no points exist."""
        pts = self._grid(op, phase)
        if not pts:
            return None
        if len(pts) == 1:
            p = pts[0]
            # linear scaling in tokens as last resort
            return p.latency_s * max(tokens, 1) / max(p.tokens, 1)
        lt = math.log(max(tokens, 1))
        lc = math.log(max(context, 1))

        def key(p):
            return (math.log(max(p.tokens, 1)) - lt) ** 2 + \
                   0.25 * (math.log(max(p.context, 1)) - lc) ** 2

        pts_sorted = sorted(pts, key=key)
        nearest = pts_sorted[: 4]
        # inverse-distance weighting in log space (simple + robust for
        # monotone latency surfaces)
        num, den = 0.0, 0.0
        for p in nearest:
            d = key(p)
            if d < 1e-12:
                return p.latency_s
            w = 1.0 / d
            num += w * math.log(p.latency_s)
            den += w
        return math.exp(num / den)

    # ---- io ----
    def save(self, path: str):
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        with open(path, "w") as f:
            json.dump({
                "model": self.model, "hardware": self.hardware, "tp": self.tp,
                "meta": self.meta,
                "points": [dataclasses.asdict(p) for p in self.points],
            }, f)

    @classmethod
    def load(cls, path: str) -> "Trace":
        with open(path) as f:
            d = json.load(f)
        t = cls(model=d["model"], hardware=d["hardware"], tp=d.get("tp", 1),
                meta=d.get("meta", {}))
        for p in d["points"]:
            t.points.append(OpPoint(**p))
        return t


class TraceRegistry:
    """Named traces; instances reference them by ``trace_name``."""

    def __init__(self):
        self._traces: Dict[str, Trace] = {}

    def register(self, name: str, trace: Trace):
        self._traces[name] = trace

    def get(self, name: str) -> Optional[Trace]:
        return self._traces.get(name)

    def load_dir(self, path: str):
        for fn in os.listdir(path):
            if fn.endswith(".json"):
                self.register(fn[:-5], Trace.load(os.path.join(path, fn)))
