"""Operator-latency trace format (the contract between profiler and sim).

A trace is a set of measured operator latencies for one (model, hardware,
parallelism) triple, keyed by operator kind and phase, over a grid of
(tokens, context) points. The perf model interpolates this grid; anything
outside the grid falls back to the analytical model. This is LLMServingSim
2.0's central abstraction: integrating new hardware == producing one trace
file with the operator-level profiler (paper §II-A, Table III).

Lookup path: points are pre-indexed per ``(op, phase)`` into numpy arrays
(log-space coordinates precomputed once), and every interpolation result is
memoized on its exact ``(op, phase, tokens, context)`` key.  The scalar
``interpolate`` and the vectorized ``interpolate_many`` share one kernel, so
a fleet-scale fast path that prices whole decode windows at once returns
bit-identical values to per-step lookups.  The index is invalidated by
appending points (``add``/``load``); mutating an ``OpPoint`` in place after
a lookup is not supported.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Dict, List, Optional, Tuple

import numpy as np

# operator kinds the profiler emits and the sim consumes
OP_KINDS = (
    "embed", "attn_qkv", "attn_score", "attn_out", "mlp", "moe_ffn",
    "moe_router", "norm", "head", "mamba", "xlstm", "sampler",
)

#: memo entries kept per trace before a wholesale reset (exact keys, so a
#: reset only costs recomputation, never accuracy)
_MEMO_CAP = 1 << 18


class _OpGrid:
    """One (op, phase)'s points with log-space coordinates precomputed."""

    __slots__ = ("pts", "lt", "lc", "ll", "lat")

    def __init__(self, pts: List["OpPoint"]):
        self.pts = pts
        tok = np.array([p.tokens for p in pts], dtype=np.float64)
        ctx = np.array([p.context for p in pts], dtype=np.float64)
        self.lt = np.log(np.maximum(tok, 1.0))
        self.lc = np.log(np.maximum(ctx, 1.0))
        self.lat = np.array([p.latency_s for p in pts], dtype=np.float64)
        self.ll = np.log(self.lat)

    def lookup(self, tokens, context) -> np.ndarray:
        """Vectorized nearest-4 inverse-distance-weighted interpolation in
        log space (simple + robust for monotone latency surfaces).  One row
        per query; the scalar path is a 1-row call of this same kernel."""
        qtok = np.maximum(np.asarray(tokens, dtype=np.float64), 1.0)
        qctx = np.maximum(np.asarray(context, dtype=np.float64), 1.0)
        if len(self.pts) == 1:
            # linear scaling in tokens as last resort
            p = self.pts[0]
            return self.lat[0] * qtok / max(p.tokens, 1)
        qt = np.log(qtok)
        qc = np.log(qctx)
        k = min(4, self.lt.shape[0])
        if qt.shape[0] == 1:
            # 1-row lane: identical elementwise double ops on 1-D arrays,
            # so the value matches row 0 of the broadcast path bit-for-bit
            # (the fast==exact contract crosses this boundary)
            d = (self.lt - qt[0]) ** 2 + 0.25 * (self.lc - qc[0]) ** 2
        else:
            d = (self.lt[None, :] - qt[:, None]) ** 2 \
                + 0.25 * (self.lc[None, :] - qc[:, None]) ** 2
        # stable sort: equidistant points keep insertion order
        sel = np.argsort(d, axis=-1, kind="stable")[..., :k]
        if d.ndim == 1:
            ds = d[sel]
        else:
            ds = d[np.arange(d.shape[0])[:, None], sel]
        lls = self.ll[sel]
        # an exact grid hit would divide by ~0; clamping keeps the kernel
        # finite and warning-free, and any row that close to a point takes
        # the exact-hit branch below, so the IDW value never survives
        ws = 1.0 / np.maximum(ds, 1e-300)
        num = ws[..., 0] * lls[..., 0]
        den = ws[..., 0] + 0.0
        for j in range(1, k):
            num = num + ws[..., j] * lls[..., j]
            den = den + ws[..., j]
        out = np.exp(num / den)
        # exact grid hit: return the nearest point's measured latency
        if d.ndim == 1:
            if ds[0] < 1e-12:
                out = self.lat[sel[0]]
            return np.asarray([out])
        near = ds[:, 0] < 1e-12
        if near.any():
            out = np.where(near, self.lat[sel[:, 0]], out)
        return out


@dataclasses.dataclass
class OpPoint:
    op: str
    phase: str          # prefill | decode
    tokens: int         # batch tokens processed this iteration
    context: int        # KV/context length (decode) or seq len (prefill)
    latency_s: float


@dataclasses.dataclass
class Trace:
    model: str
    hardware: str
    tp: int
    points: List[OpPoint] = dataclasses.field(default_factory=list)
    meta: Dict = dataclasses.field(default_factory=dict)

    def add(self, op, phase, tokens, context, latency_s):
        self.points.append(OpPoint(op, phase, int(tokens), int(context),
                                   float(latency_s)))

    # ---- lookup ----
    def _index(self) -> Dict[Tuple[str, str], _OpGrid]:
        """Per-(op, phase) grid index, rebuilt when points were appended."""
        idx = getattr(self, "_idx", None)
        if idx is not None and self._idx_n == len(self.points):
            return idx
        buckets: Dict[Tuple[str, str], List[OpPoint]] = {}
        for p in self.points:
            buckets.setdefault((p.op, p.phase), []).append(p)
        idx = {key: _OpGrid(pts) for key, pts in buckets.items()}
        self._idx = idx
        self._idx_n = len(self.points)
        self._memo: Dict[Tuple, Optional[float]] = {}
        return idx

    def _grid(self, op: str, phase: str) -> List[OpPoint]:
        g = self._index().get((op, phase))
        return g.pts if g is not None else []

    def interpolate(self, op: str, phase: str, tokens: int,
                    context: int) -> Optional[float]:
        """Log-space nearest-4 IDW over the (tokens, context) grid;
        nearest-edge clamp outside; None when no points exist.  Results are
        memoized per exact key (an instance fleet sharing one trace object
        shares the memo)."""
        g = self._index().get((op, phase))
        if g is None:
            return None
        memo = self._memo
        key = (op, phase, tokens, context)
        v = memo.get(key)
        if v is None:
            if len(memo) >= _MEMO_CAP:
                memo.clear()
            v = float(g.lookup((tokens,), (context,))[0])
            memo[key] = v
        return v

    def interpolate_many(self, op: str, phase: str, tokens,
                         context) -> Optional[np.ndarray]:
        """Vectorized ``interpolate`` over parallel token/context arrays —
        same kernel, so element i is bit-identical to the scalar lookup at
        ``(tokens[i], context[i])``.  None when the grid has no points."""
        g = self._index().get((op, phase))
        if g is None:
            return None
        return g.lookup(tokens, context)

    # ---- io ----
    def save(self, path: str):
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        with open(path, "w") as f:
            json.dump({
                "model": self.model, "hardware": self.hardware, "tp": self.tp,
                "meta": self.meta,
                "points": [dataclasses.asdict(p) for p in self.points],
            }, f)

    @classmethod
    def load(cls, path: str) -> "Trace":
        with open(path) as f:
            d = json.load(f)
        t = cls(model=d["model"], hardware=d["hardware"], tp=d.get("tp", 1),
                meta=d.get("meta", {}))
        for p in d["points"]:
            t.points.append(OpPoint(**p))
        return t


class TraceRegistry:
    """Named traces; instances reference them by ``trace_name``."""

    def __init__(self):
        self._traces: Dict[str, Trace] = {}

    def register(self, name: str, trace: Trace):
        self._traces[name] = trace

    def get(self, name: str) -> Optional[Trace]:
        return self._traces.get(name)

    def load_dir(self, path: str):
        for fn in os.listdir(path):
            if fn.endswith(".json"):
                self.register(fn[:-5], Trace.load(os.path.join(path, fn)))
