"""MoE expert routing, parallelism and offloading models (paper §II-C).

The *expert router* mimics a gate function statistically: given the batch's
token count it produces per-expert loads under a configurable distribution
(uniform / zipf-skewed / temporally-correlated). Expert-parallel compute time
is set by the most-loaded expert shard (imbalance factor), with an all-to-all
on both sides. Offloading supports host and PIM targets with optional
prefetch overlap (Pre-gated MoE [7] / Duplex [8] style studies).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np

from repro.core.config import HardwareSpec, InstanceCfg, MoECfg, ModelSpec


def expert_capacity(tokens: int, top_k: int, n_experts: int,
                    capacity_factor: float) -> int:
    """Per-expert capacity-buffer size — the single definition shared by
    trace-driven pricing and the drop-rate metric, mirroring the real
    dispatch in ``repro.models.moe.moe_ffn``
    (``C = round(T * top_k * cf / E)``, floored at 1)."""
    return int(max(1, round(tokens * top_k * capacity_factor
                            / max(n_experts, 1))))


def imbalance_factor(counts, ep: int = 1) -> float:
    """max-shard / mean-shard load with experts split over ``ep`` ranks.

    The one definition of the expert-parallel imbalance metric — shared by
    the statistical router below, the trace-driven expert-load accounting
    (``repro.moe.ExpertLoadTracker``) and the cluster-level metric merge,
    so sim and real report comparable numbers.
    """
    counts = np.asarray(counts, float)
    ep = max(int(ep), 1)
    per_rank = np.array([c.sum() for c in np.array_split(counts, ep)])
    if per_rank.sum() <= 0:
        return 1.0
    return float(per_rank.max() / max(per_rank.mean(), 1e-9))


class ExpertRouter:
    """Statistical stand-in for the gate; pluggable like the real one."""

    def __init__(self, cfg: MoECfg, model: ModelSpec, seed: int = 0):
        self.cfg = cfg
        self.model = model
        self.rng = np.random.default_rng(seed)
        E = model.moe_experts
        if cfg.routing == "zipf":
            w = 1.0 / np.arange(1, E + 1) ** cfg.zipf_a
        else:
            w = np.ones(E)
        self.base_weights = w / w.sum()
        self._drift = np.ones(E) / E

    def route(self, tokens: int) -> np.ndarray:
        """Per-expert token counts for one MoE layer invocation."""
        E = self.model.moe_experts
        k = self.model.moe_top_k
        if tokens <= 0:
            return np.zeros(E)
        if self.cfg.routing == "correlated":
            # slowly drifting hot set (session affinity effects)
            self._drift = 0.95 * self._drift + 0.05 * self.rng.dirichlet(
                np.ones(E))
            p = self._drift / self._drift.sum()
        else:
            p = self.base_weights
        counts = self.rng.multinomial(tokens * k, p)
        return counts.astype(float)

    def imbalance(self, counts: np.ndarray, ep: int) -> float:
        """max-shard / mean-shard load with experts split over ep ranks."""
        return imbalance_factor(counts, ep)


@dataclasses.dataclass
class MoELayerCost:
    compute_s: float
    alltoall_s: float
    fetch_s: float        # expert weight fetch (offloading)
    overlapped_s: float   # what actually lands on the critical path

    @property
    def total(self) -> float:
        return self.overlapped_s


class ExpertExecutionModel:
    """Cost of one MoE FFN layer under EP + offloading."""

    def __init__(self, icfg: InstanceCfg, router: ExpertRouter,
                 pim: Optional[HardwareSpec] = None):
        self.icfg = icfg
        self.router = router
        self.model = icfg.model
        self.hw = icfg.hw
        self.pim = pim
        self.moe = icfg.moe

    def layer_cost(self, tokens: int,
                   counts: Optional[np.ndarray] = None,
                   capacity_factor: Optional[float] = None) -> MoELayerCost:
        """Cost of one MoE layer for ``tokens`` batch tokens.

        ``counts`` (per-expert token counts) overrides the statistical
        router — the trace-driven path: a replayed ``ExpertRoutingTrace``
        supplies the exact per-layer load, so imbalance, the active expert
        set, and offload fetch traffic are all priced from the trace.

        ``capacity_factor`` (trace-driven path only) clamps each expert's
        load at the standard top-k capacity ``C = round(tokens * top_k *
        cf / E)``: overflow tokens are *dropped* by the real engine's
        dispatch (they never reach the grouped GEMM), so a hot expert's
        compute saturates at C instead of growing unboundedly with skew —
        the drop rate itself is surfaced via
        ``ExpertLoadTracker.metrics()["drop_rate"]``.
        """
        m = self.model
        hw = self.hw
        ep = max(self.icfg.parallelism.ep, 1)
        if counts is None:
            counts = self.router.route(tokens)
        else:
            counts = np.asarray(counts, float)
            if capacity_factor and tokens > 0:
                counts = np.minimum(counts, expert_capacity(
                    tokens, m.moe_top_k, m.moe_experts, capacity_factor))
        kappa = imbalance_factor(counts, ep)
        # compute: top_k experts' FFN on the hottest shard
        flops = 2 * 3 * m.d_model * m.moe_d_expert * counts.sum() / ep * kappa
        active = (counts > 0).sum()
        w_bytes = m.expert_bytes() * active / ep
        t_compute = max(flops / (hw.peak_flops * hw.mmu_efficiency),
                        w_bytes / hw.hbm_bw)
        # all-to-all both directions (dispatch + combine)
        a2a_bytes = 2 * tokens * m.d_model * m.dtype_bytes
        t_a2a = a2a_bytes * (ep - 1) / max(ep, 1) / hw.link_bw if ep > 1 \
            else 0.0
        # offloading
        t_fetch = 0.0
        if self.moe.offload == "host" and self.moe.offload_fraction > 0:
            fetch_bytes = m.expert_bytes() * active \
                * self.moe.offload_fraction / ep
            t_fetch = fetch_bytes / hw.host_bw
        elif self.moe.offload == "pim" and self.pim is not None \
                and self.moe.offload_fraction > 0:
            # offloaded experts execute ON the memory-side device instead
            off_tokens = counts.sum() * self.moe.offload_fraction
            off_flops = 2 * 3 * m.d_model * m.moe_d_expert * off_tokens / ep
            off_bytes = m.expert_bytes() * active \
                * self.moe.offload_fraction / ep
            t_pim = max(off_flops / self.pim.peak_flops,
                        off_bytes / self.pim.hbm_bw)
            t_compute = max(t_compute * (1 - self.moe.offload_fraction),
                            t_pim)   # device + PIM run concurrently
        if self.moe.prefetch:
            crit = max(t_compute, t_fetch) + t_a2a
        else:
            crit = t_compute + t_fetch + t_a2a
        return MoELayerCost(compute_s=t_compute, alltoall_s=t_a2a,
                            fetch_s=t_fetch, overlapped_s=crit)
