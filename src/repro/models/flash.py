"""Flash attention with a block-recomputing custom VJP (pure JAX).

The naive differentiable ``chunked_attention`` lets JAX save the per-block
probability tensors for backward — O(S²) residual memory, defeating the
point of flash. This version implements the FlashAttention-2 backward:
forward saves only (q, k, v, out, lse); backward recomputes P per KV block
and accumulates dq (carry) / dk, dv (per-block outputs) in one scan.

Supports GQA, per-sequence lengths, and (possibly traced) sliding windows —
the same masking semantics as ``chunked_attention``. This is the default
train/prefill attention; the Pallas kernel in ``repro/kernels`` is the
TPU-production twin with an identical interface.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _mask_for(q_pos, kv_pos, lengths, window, B):
    mask = q_pos[:, None] >= kv_pos[None, :]
    if window is not None:
        mask = mask & (q_pos[:, None] - kv_pos[None, :] < window)
    if lengths is not None:
        mask = mask[None] & (kv_pos[None, None, :] < lengths[:, None, None])
        return mask[:, None, None]          # (B,1,1,S,bkv)
    return mask[None, None, None]           # (1,1,1,S,bkv)


def _fwd_scan(q, k, v, lengths, window, bkv, unroll):
    B, S, H, dh = q.shape
    KV = k.shape[2]
    G = H // KV
    nk = S // bkv
    scale = dh ** -0.5
    qr = (q * scale).reshape(B, S, KV, G, dh)
    kb = jnp.moveaxis(k.reshape(B, nk, bkv, KV, dh), 1, 0)
    vb = jnp.moveaxis(v.reshape(B, nk, bkv, KV, dh), 1, 0)
    q_pos = jnp.arange(S)

    def body(carry, xs):
        acc, m, l = carry
        j, kj, vj = xs
        s = jnp.einsum("bqkgd,bjkd->bkgqj", qr, kj,
                       preferred_element_type=jnp.float32)
        kv_pos = j * bkv + jnp.arange(bkv)
        s = jnp.where(_mask_for(q_pos, kv_pos, lengths, window, B), s,
                      NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        pv = jnp.einsum("bkgqj,bjkd->bkgqd", p.astype(vj.dtype), vj,
                        preferred_element_type=jnp.float32)
        acc = acc * corr[..., None] + pv
        return (acc, m_new, l_new), None

    acc0 = jnp.zeros((B, KV, G, S, dh), jnp.float32)
    m0 = jnp.full((B, KV, G, S), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, KV, G, S), jnp.float32)
    (acc, m, l), _ = jax.lax.scan(body, (acc0, m0, l0),
                                  (jnp.arange(nk), kb, vb),
                                  unroll=nk if unroll else 1)
    l = jnp.maximum(l, 1e-20)
    out = (acc / l[..., None])
    lse = m + jnp.log(l)                       # (B,KV,G,S)
    out_b = jnp.moveaxis(out, 3, 1).reshape(B, S, H, dh).astype(q.dtype)
    return out_b, lse


@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6))
def flash_attention(q, k, v, lengths=None, window=None, bkv: int = 1024,
                    unroll: bool = False):
    """q: (B,S,H,dh); k/v: (B,S,KV,dh); causal GQA flash attention."""
    bkv = min(bkv, q.shape[1])
    out, _ = _fwd_scan(q, k, v, lengths, window, bkv, unroll)
    return out


def _flash_fwd(q, k, v, lengths, window, bkv, unroll):
    bkv = min(bkv, q.shape[1])
    out, lse = _fwd_scan(q, k, v, lengths, window, bkv, unroll)
    return out, (q, k, v, out, lse, lengths, window)


def _flash_bwd(bkv, unroll, res, dout):
    q, k, v, out, lse, lengths, window = res
    B, S, H, dh = q.shape
    KV = k.shape[2]
    G = H // KV
    bkv = min(bkv, S)
    nk = S // bkv
    scale = dh ** -0.5
    qr = (q * scale).reshape(B, S, KV, G, dh)
    do = dout.reshape(B, S, KV, G, dh)
    ob = out.reshape(B, S, KV, G, dh)
    # delta_i = sum_d do_i * out_i   (B,KV,G,S)
    delta = jnp.einsum("bskgd,bskgd->bkgs", do.astype(jnp.float32),
                       ob.astype(jnp.float32))
    kb = jnp.moveaxis(k.reshape(B, nk, bkv, KV, dh), 1, 0)
    vb = jnp.moveaxis(v.reshape(B, nk, bkv, KV, dh), 1, 0)
    q_pos = jnp.arange(S)

    def body(dq_acc, xs):
        j, kj, vj = xs
        s = jnp.einsum("bqkgd,bjkd->bkgqj", qr, kj,
                       preferred_element_type=jnp.float32)
        kv_pos = j * bkv + jnp.arange(bkv)
        s = jnp.where(_mask_for(q_pos, kv_pos, lengths, window, B), s,
                      NEG_INF)
        p = jnp.exp(s - lse[..., None])                    # (B,KV,G,S,bkv)
        # dv_j = sum_q p * do
        dv = jnp.einsum("bkgqj,bqkgd->bjkd", p.astype(do.dtype), do,
                        preferred_element_type=jnp.float32)
        # dp = do . v_j
        dp = jnp.einsum("bqkgd,bjkd->bkgqj", do, vj,
                        preferred_element_type=jnp.float32)
        ds = p * (dp - delta[..., None])                   # (B,KV,G,S,bkv)
        dsb = ds.astype(q.dtype)
        # dq += ds @ k_j (scaled)
        dq_blk = jnp.einsum("bkgqj,bjkd->bqkgd", dsb, kj,
                            preferred_element_type=jnp.float32)
        dq_acc = dq_acc + dq_blk
        # dk_j = ds^T @ q (scaled q already in qr)
        dk = jnp.einsum("bkgqj,bqkgd->bjkd", dsb, qr,
                        preferred_element_type=jnp.float32)
        return dq_acc, (dk, dv)

    dq0 = jnp.zeros((B, S, KV, G, dh), jnp.float32)
    dq, (dks, dvs) = jax.lax.scan(body, dq0, (jnp.arange(nk), kb, vb),
                                  unroll=nk if unroll else 1)
    dq = (dq * scale).reshape(B, S, H, dh).astype(q.dtype)
    dk = jnp.moveaxis(dks, 0, 1).reshape(B, S, KV, dh).astype(k.dtype)
    dv = jnp.moveaxis(dvs, 0, 1).reshape(B, S, KV, dh).astype(v.dtype)
    def zero_ct(x):
        if x is None:
            return None
        if jnp.issubdtype(x.dtype, jnp.floating):
            return jnp.zeros_like(x)
        return jnp.zeros(x.shape, dtype=jax.dtypes.float0)
    return dq, dk, dv, zero_ct(lengths), zero_ct(window)


flash_attention.defvjp(_flash_fwd, _flash_bwd)
