"""Mamba2 (SSD) block — TPU-adapted chunked implementation.

The GPU reference (state-spaces/mamba) uses a fused CUDA scan; the
TPU-native formulation is the *chunked SSD* algorithm from the Mamba2 paper
[arXiv:2405.21060]: within-chunk quadratic (MXU-friendly matmuls of shape
Q×Q, Q=256) + an inter-chunk linear recurrence over chunk states via
``lax.scan``. This turns a bandwidth-bound elementwise scan into
matmul-dominated compute — exactly the hardware adaptation DESIGN.md §3
describes.

Single-token decode keeps (conv_state, ssd_state) and costs O(1) per token.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models import module as m


class MambaParams(NamedTuple):
    w_zx: jax.Array      # (d, 2*d_in)
    w_bc: jax.Array      # (d, 2*ds)   -- B and C projections (n_groups=1)
    w_dt: jax.Array      # (d, nh)
    dt_bias: jax.Array   # (nh,)
    conv_w: jax.Array    # (k, conv_dim)  depthwise causal conv
    conv_b: jax.Array    # (conv_dim,)
    A_log: jax.Array     # (nh,)
    D: jax.Array         # (nh,)
    norm_scale: jax.Array  # (d_in,)
    w_out: jax.Array     # (d_in, d)


def init_mamba(key, d: int, ssm) -> dict:
    d_in = ssm.expand * d
    nh = ssm.n_heads or d_in // ssm.head_dim
    ds = ssm.d_state
    conv_dim = d_in + 2 * ds
    ks = jax.random.split(key, 6)
    return {
        "w_zx": m.dense_init(ks[0], d, 2 * d_in),
        "w_bc": m.dense_init(ks[1], d, 2 * ds),
        "w_dt": m.dense_init(ks[2], d, nh),
        "dt_bias": jnp.log(jnp.expm1(
            jnp.exp(jax.random.uniform(ks[3], (nh,),
                                       minval=jnp.log(1e-3),
                                       maxval=jnp.log(1e-1))))),
        "conv_w": m.dense_init(ks[4], ssm.d_conv, conv_dim) * ssm.d_conv ** 0.5,
        "conv_b": m.zeros((conv_dim,)),
        "A_log": jnp.log(jnp.arange(1, nh + 1, dtype=jnp.float32)),
        "D": m.ones((nh,)),
        "norm_scale": m.zeros((d_in,)),
        "w_out": m.dense_init(ks[5], d_in, d),
    }


def _segsum(a):
    """a: (..., Q) log-decays -> (..., Q, Q) lower-tri pairwise sums."""
    Q = a.shape[-1]
    cum = jnp.cumsum(a, axis=-1)
    diff = cum[..., :, None] - cum[..., None, :]   # sum_{j+1..i}
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(xs, a, B, C, chunk: int, h0=None):
    """Chunked SSD scan.

    xs: (b, s, h, p) inputs (already dt-scaled); a: (b, s, h) log decay
    (dt * A, negative); B, C: (b, s, n). Returns (y (b,s,h,p), h_final
    (b,h,p,n)).
    """
    b, s, nh, p = xs.shape
    n = B.shape[-1]
    Q = min(chunk, s)
    s_orig = s
    if s % Q:
        # zero-pad the tail: xs=0 (no input), a=0 (decay 1 -> state
        # preserved), B=C=0. Outputs at padded positions are sliced off.
        pad = Q - s % Q
        xs = jnp.pad(xs, ((0, 0), (0, pad), (0, 0), (0, 0)))
        a = jnp.pad(a, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
        s = s + pad
    nc = s // Q
    xs = xs.reshape(b, nc, Q, nh, p)
    a = a.reshape(b, nc, Q, nh).transpose(0, 3, 1, 2)   # (b, h, c, l)
    B_ = B.reshape(b, nc, Q, n)
    C_ = C.reshape(b, nc, Q, n)

    A_cum = jnp.cumsum(a, axis=-1)                      # (b,h,c,l)
    L = jnp.exp(_segsum(a))                             # (b,h,c,l,l)
    # within-chunk (diagonal blocks)
    Y_diag = jnp.einsum("bcln,bcsn,bhcls,bcshp->bclhp", C_, B_, L, xs,
                        preferred_element_type=jnp.float32)
    # chunk states: contribution of each chunk to its final state
    decay_states = jnp.exp(A_cum[..., -1:] - A_cum)     # (b,h,c,l)
    states = jnp.einsum("bcln,bhcl,bclhp->bchpn", B_, decay_states, xs,
                        preferred_element_type=jnp.float32)
    # inter-chunk recurrence
    chunk_decay = jnp.exp(A_cum[..., -1])               # (b,h,c)
    if h0 is None:
        h0 = jnp.zeros((b, nh, p, n), jnp.float32)

    def step(h, inp):
        st_c, dec_c = inp                               # (b,h,p,n), (b,h)
        h_new = dec_c[..., None, None] * h + st_c
        return h_new, h                                 # emit state *before* chunk

    states_c = jnp.moveaxis(states, 1, 0)               # states: (b,c,h,p,n) -> (c,b,h,p,n)
    decay_c = jnp.moveaxis(chunk_decay, 2, 0)           # (c,b,h)
    h_final, h_prevs = jax.lax.scan(step, h0, (states_c, decay_c))
    h_prevs = jnp.moveaxis(h_prevs, 0, 1)               # (c,b,h,p,n) -> (b,c,h,p,n)
    # off-diagonal contribution: C_i · h_prev, decayed to position i
    state_decay = jnp.exp(A_cum)                        # (b,h,c,l)
    Y_off = jnp.einsum("bcln,bchpn,bhcl->bclhp", C_, h_prevs, state_decay,
                       preferred_element_type=jnp.float32)
    y = (Y_diag + Y_off).reshape(b, s, nh, p)[:, :s_orig]
    return y, h_final


def _causal_conv(x, w, b):
    """Depthwise causal conv1d. x: (B,S,C); w: (k,C)."""
    k = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(k):
        out = out + pad[:, i: i + x.shape[1]] * w[i]
    return out + b


def mamba_forward(params, x, cfg, state: Optional[dict] = None,
                  return_state: bool = False):
    """Full-sequence Mamba2 block. x: (B,S,d) -> (B,S,d)."""
    ssm = cfg.ssm
    B_, S, d = x.shape
    d_in = ssm.expand * d
    nh = ssm.n_heads or d_in // ssm.head_dim
    hd = d_in // nh
    ds = ssm.d_state

    zx = x @ params["w_zx"].astype(x.dtype)
    z, xc = jnp.split(zx, 2, axis=-1)
    bc = x @ params["w_bc"].astype(x.dtype)
    xbc = jnp.concatenate([xc, bc], axis=-1)            # (B,S,d_in+2ds)
    if state is not None:
        # continue from a previous chunk: conv sees its last k-1 inputs
        full = jnp.concatenate([state["conv"].astype(x.dtype), xbc], axis=1)
    else:
        full = xbc
    if full.shape[1] < ssm.d_conv - 1:     # very short first chunk
        full = jnp.pad(full, ((0, 0), (ssm.d_conv - 1 - full.shape[1], 0),
                              (0, 0)))
    conv_tail = full[:, full.shape[1] - (ssm.d_conv - 1):, :]
    conv_out = _causal_conv(full, params["conv_w"].astype(x.dtype),
                            params["conv_b"].astype(x.dtype))
    xbc = jax.nn.silu(conv_out[:, full.shape[1] - S:, :])
    xc2, Bm, Cm = jnp.split(xbc, [d_in, d_in + ds], axis=-1)
    dt = jax.nn.softplus(
        (x @ params["w_dt"].astype(x.dtype)).astype(jnp.float32)
        + params["dt_bias"])                            # (B,S,nh)
    A = -jnp.exp(params["A_log"])                       # (nh,)
    xh = xc2.reshape(B_, S, nh, hd).astype(jnp.float32)
    xs = xh * dt[..., None]
    a = dt * A                                          # (B,S,nh)
    h0 = state["ssd"] if state is not None else None
    y, h_final = ssd_chunked(xs, a, Bm.astype(jnp.float32),
                             Cm.astype(jnp.float32), ssm.chunk, h0=h0)
    y = y + params["D"][None, None, :, None] * xh
    y = y.reshape(B_, S, d_in).astype(x.dtype)
    from repro.models.layers import rmsnorm
    y = rmsnorm(y * jax.nn.silu(z), params["norm_scale"], cfg.norm_eps)
    out = y @ params["w_out"].astype(x.dtype)
    if return_state:
        new_state = {"ssd": h_final, "conv": conv_tail}
        return out, new_state
    return out


def mamba_decode(params, x, cfg, state):
    """Single-token decode. x: (B,1,d); state: {ssd (B,nh,hd,ds), conv (B,k-1,cd)}."""
    ssm = cfg.ssm
    B_, _, d = x.shape
    d_in = ssm.expand * d
    nh = ssm.n_heads or d_in // ssm.head_dim
    hd = d_in // nh
    ds = ssm.d_state
    k = ssm.d_conv

    zx = x @ params["w_zx"].astype(x.dtype)
    z, xc = jnp.split(zx, 2, axis=-1)
    bc = x @ params["w_bc"].astype(x.dtype)
    xbc = jnp.concatenate([xc, bc], axis=-1)            # (B,1,cd)
    conv_buf = jnp.concatenate([state["conv"], xbc], axis=1)  # (B,k,cd)
    conv_out = (conv_buf * params["conv_w"].astype(x.dtype)).sum(axis=1) \
        + params["conv_b"].astype(x.dtype)              # (B,cd)
    xbc1 = jax.nn.silu(conv_out)
    xc2, Bm, Cm = jnp.split(xbc1, [d_in, d_in + ds], axis=-1)
    dt = jax.nn.softplus(
        (x[:, 0] @ params["w_dt"].astype(x.dtype)).astype(jnp.float32)
        + params["dt_bias"])                            # (B,nh)
    A = -jnp.exp(params["A_log"])
    dA = jnp.exp(dt * A)                                # (B,nh)
    xh = xc2.reshape(B_, nh, hd).astype(jnp.float32)
    h = state["ssd"]                                    # (B,nh,hd,ds)
    h = dA[..., None, None] * h + jnp.einsum(
        "bhp,bn,bh->bhpn", xh, Bm.astype(jnp.float32), dt)
    y = jnp.einsum("bhpn,bn->bhp", h, Cm.astype(jnp.float32))
    y = y + params["D"][None, :, None] * xh
    y = y.reshape(B_, 1, d_in).astype(x.dtype)
    from repro.models.layers import rmsnorm
    y = rmsnorm(y * jax.nn.silu(z), params["norm_scale"], cfg.norm_eps)
    out = y @ params["w_out"].astype(x.dtype)
    new_state = {"ssd": h, "conv": conv_buf[:, 1:]}
    return out, new_state


def init_mamba_state(batch: int, d: int, ssm, dtype=jnp.float32) -> dict:
    d_in = ssm.expand * d
    nh = ssm.n_heads or d_in // ssm.head_dim
    hd = d_in // nh
    conv_dim = d_in + 2 * ssm.d_state
    return {
        "ssd": jnp.zeros((batch, nh, hd, ssm.d_state), jnp.float32),
        "conv": jnp.zeros((batch, ssm.d_conv - 1, conv_dim), dtype),
    }
