"""Generic stage-composed decoder-only model.

One `Model` class covers all 10 assigned architectures: the config's
``stages`` tuple picks block kinds (attention+MLP, attention+MoE, Mamba2,
zamba superblock, xLSTM pair); every stage is a homogeneous stack run under
``jax.lax.scan`` (stacked leading layer dim), keeping the HLO compact for
fast 512-device dry-run compiles.

Three entry points (all pure functions of (params, inputs)):
  * ``loss_fn`` / ``forward``  — training (no cache),
  * ``prefill``                — forward + materialize per-layer caches,
  * ``decode``                 — one token against the cache, per-seq lengths.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import (
    ATTN_MLP, ATTN_MOE, MAMBA2, XLSTM_PAIR, ZAMBA_SUPER, ArchConfig,
)
from repro.models import module as m
from repro.models import mamba2 as mb
from repro.models import xlstm as xl
from repro.models.layers import (
    chunked_attention, decode_attention, extend_attention,
    folded_causal_attention, local_banded_attention, rmsnorm, rmsnorm_ct16,
    rope, swiglu_mlp, gelu_mlp,
)
from repro.models.flash import flash_attention
from repro.models.moe import moe_ffn


# --------------------------------------------------------------------------
# per-block init
# --------------------------------------------------------------------------

def _init_attn(key, cfg: ArchConfig, fuse_qkv: bool = False) -> dict:
    d, H, KV, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    ks = jax.random.split(key, 4)
    if fuse_qkv:
        # single fused projection -> one dx all-reduce in backward instead
        # of a 3-tuple (see EXPERIMENTS.md Perf iteration 1)
        p = {
            "wqkv": m.dense_init(ks[0], d, (H + 2 * KV) * dh),
            "wo": m.dense_init(ks[3], H * dh, d),
        }
    else:
        p = {
            "wq": m.dense_init(ks[0], d, H * dh),
            "wk": m.dense_init(ks[1], d, KV * dh),
            "wv": m.dense_init(ks[2], d, KV * dh),
            "wo": m.dense_init(ks[3], H * dh, d),
        }
    if cfg.qkv_bias:
        p["bq"] = m.zeros((H * dh,))
        p["bk"] = m.zeros((KV * dh,))
        p["bv"] = m.zeros((KV * dh,))
    if cfg.qk_norm:
        p["q_norm"] = m.zeros((dh,))
        p["k_norm"] = m.zeros((dh,))
    return p


def _init_mlp(key, cfg: ArchConfig) -> dict:
    d, ff = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    if cfg.mlp_gated:
        return {"w_gate": m.dense_init(ks[0], d, ff),
                "w_up": m.dense_init(ks[1], d, ff),
                "w_down": m.dense_init(ks[2], ff, d)}
    return {"w_in": m.dense_init(ks[0], d, ff),
            "w_out": m.dense_init(ks[1], ff, d)}


def _init_moe(key, cfg: ArchConfig) -> dict:
    d, mo = cfg.d_model, cfg.moe
    ks = jax.random.split(key, 4)
    def one(k):
        kk = jax.random.split(k, 3)
        return {"w_gate": m.dense_init(kk[0], d, mo.d_expert),
                "w_up": m.dense_init(kk[1], d, mo.d_expert),
                "w_down": m.dense_init(kk[2], mo.d_expert, d)}
    experts = m.stack_init(ks[0], mo.n_experts, one)
    return {"router": m.dense_init(ks[1], d, mo.n_experts) * 0.1,
            "w_gate": experts["w_gate"], "w_up": experts["w_up"],
            "w_down": experts["w_down"]}


def _init_attn_mlp_layer(key, cfg: ArchConfig, fuse_qkv: bool = False) -> dict:
    ks = jax.random.split(key, 2)
    return {"norm1": m.zeros((cfg.d_model,)),
            "attn": _init_attn(ks[0], cfg, fuse_qkv),
            "norm2": m.zeros((cfg.d_model,)),
            "mlp": _init_mlp(ks[1], cfg)}


def _init_attn_moe_layer(key, cfg: ArchConfig, fuse_qkv: bool = False) -> dict:
    ks = jax.random.split(key, 2)
    return {"norm1": m.zeros((cfg.d_model,)),
            "attn": _init_attn(ks[0], cfg, fuse_qkv),
            "norm2": m.zeros((cfg.d_model,)),
            "moe": _init_moe(ks[1], cfg)}


def _init_mamba_layer(key, cfg: ArchConfig) -> dict:
    return {"norm": m.zeros((cfg.d_model,)),
            "mamba": mb.init_mamba(key, cfg.d_model, cfg.ssm)}


def _init_zamba_super(key, cfg: ArchConfig) -> dict:
    return {"inner": m.stack_init(key, 6,
                                  lambda k: _init_mamba_layer(k, cfg))}


def _init_xlstm_pair(key, cfg: ArchConfig) -> dict:
    ks = jax.random.split(key, 2)
    return {"mlstm": xl.init_mlstm(ks[0], cfg.d_model, cfg.n_heads),
            "slstm": xl.init_slstm(ks[1], cfg.d_model, cfg.n_heads)}


_STAGE_INIT = {
    ATTN_MLP: _init_attn_mlp_layer,
    ATTN_MOE: _init_attn_moe_layer,
    MAMBA2: _init_mamba_layer,
    ZAMBA_SUPER: _init_zamba_super,
    XLSTM_PAIR: _init_xlstm_pair,
}


# --------------------------------------------------------------------------
# block forward helpers
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class KernelCfg:
    """Resolved kernel-backend choice threaded through the blocks.

    ``backend`` is concrete ("reference" | "pallas"; "auto" resolves at
    engine construction via ``repro.kernels.resolve_backend``).  Pallas
    serves the no-grad phases (prefill/extend/decode); training always
    runs the differentiable pure-JAX twins.
    """
    backend: str = "reference"
    interpret: bool = True
    page_size: int = 64


def _divisor_block(S: int, b: int = 128) -> int:
    """Largest flash block size <= b that divides S (S is a static int)."""
    return next(x for x in range(min(b, S), 0, -1) if S % x == 0)


def _attention(p, x, cfg: ArchConfig, *, positions, lengths, window,
               mode: str, cache: Optional[dict], attn_impl: str,
               unroll: bool = False, kernels: Optional[KernelCfg] = None,
               block_table=None):
    """window: traced scalar (0 = full causal). Returns (out, new_cache)."""
    B, S, d = x.shape
    H, KV, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    pallas = kernels is not None and kernels.backend == "pallas"
    xn = x
    if "wqkv" in p:
        qkv = xn @ p["wqkv"].astype(x.dtype)
        q, k, v = jnp.split(qkv, [H * dh, (H + KV) * dh], axis=-1)
    else:
        q = xn @ p["wq"].astype(x.dtype)
        k = xn @ p["wk"].astype(x.dtype)
        v = xn @ p["wv"].astype(x.dtype)
    if cfg.qkv_bias:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    q = q.reshape(B, S, H, dh)
    k = k.reshape(B, S, KV, dh)
    v = v.reshape(B, S, KV, dh)
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"], cfg.norm_eps)
        k = rmsnorm(k, p["k_norm"], cfg.norm_eps)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)

    new_cache = None
    paged = cache is not None and "k_pages" in cache
    if paged and block_table is None:
        raise ValueError("paged KV cache needs the block_table threaded "
                         "through decode/extend (cache['block_table'])")
    if paged and not pallas:
        raise ValueError("paged KV cache requires the pallas kernel "
                         "backend (kernels='pallas' or 'auto')")
    if mode == "decode" and paged:
        # paged slot-KV: scatter the new token through the block table,
        # then one fused paged-attention walk over this sequence's pages
        kc, vc = cache["k_pages"], cache["v_pages"]
        ps = kernels.page_size
        maxp = block_table.shape[1]
        pos = jnp.maximum(lengths - 1, 0)
        pidx = pos // ps
        page = block_table[jnp.arange(B), jnp.minimum(pidx, maxp - 1)]
        # a full/unscheduled slot's garbage write goes to the scratch page
        # (the contiguous path's equivalent out-of-bounds scatter is
        # silently dropped; pages must not clobber a real token)
        page = jnp.where(pidx < maxp, page, kc.shape[0] - 1)
        off = pos % ps
        kc = kc.at[page, off].set(k[:, 0].astype(kc.dtype))
        vc = vc.at[page, off].set(v[:, 0].astype(vc.dtype))
        from repro.kernels import paged_attention
        out = paged_attention(q[:, 0], kc, vc, block_table, lengths,
                              page_size=ps, window=window,
                              interpret=kernels.interpret)[:, None]
        new_cache = {"k_pages": kc, "v_pages": vc}
    elif mode == "decode":
        kc, vc = cache["k"], cache["v"]
        idx = jnp.maximum(lengths - 1, 0)
        bidx = jnp.arange(B)
        kc = kc.at[bidx, idx].set(k[:, 0].astype(kc.dtype))
        vc = vc.at[bidx, idx].set(v[:, 0].astype(vc.dtype))
        out = decode_attention(q, kc, vc, lengths=lengths, window=window)
        new_cache = {"k": kc, "v": vc}
    elif mode == "extend" and paged:
        # chunked-prefill continuation / spec verify on shared page pools:
        # zero KV copies — the pages are the storage, the table the view
        kc, vc = cache["k_pages"], cache["v_pages"]
        ps = kernels.page_size
        maxp = block_table.shape[1]
        start = positions[:, 0]
        pos = start[:, None] + jnp.arange(S)[None, :]
        pidx = pos // ps
        page = block_table[jnp.arange(B)[:, None],
                           jnp.minimum(pidx, maxp - 1)]
        # pad tails past the table's reach go to the scratch page (the
        # contiguous path clamps them onto position max_len-1, which is
        # only ever read after being rewritten; scratch is never read)
        page = jnp.where(pidx < maxp, page, kc.shape[0] - 1)
        off = pos % ps
        kc = kc.at[page, off].set(k.astype(kc.dtype))
        vc = vc.at[page, off].set(v.astype(vc.dtype))
        from repro.kernels import paged_attention
        out = paged_attention(q, kc, vc, block_table, lengths,
                              page_size=ps, start=start, window=window,
                              interpret=kernels.interpret)
        new_cache = {"k_pages": kc, "v_pages": vc}
    elif mode == "extend":
        # chunked/cached prefill: S new slots written after `positions[:,0]`
        # (pad tail masked out by `lengths`); attend to the whole cache
        kc, vc = cache["k"], cache["v"]
        start = positions[:, 0]
        bidx = jnp.arange(B)[:, None]
        sidx = start[:, None] + jnp.arange(S)[None, :]
        sidx = jnp.minimum(sidx, kc.shape[1] - 1)
        kc = kc.at[bidx, sidx].set(k.astype(kc.dtype))
        vc = vc.at[bidx, sidx].set(v.astype(vc.dtype))
        out = extend_attention(q, kc, vc, start=start, lengths=lengths,
                               window=window)
        new_cache = {"k": kc, "v": vc}
    else:
        if pallas and mode == "prefill":
            from repro.kernels import flash_attention as flash_pallas
            b = _divisor_block(S)
            out = flash_pallas(q, k, v, lengths, window, bq=b, bkv=b,
                               interpret=kernels.interpret)
        elif attn_impl == "flash":
            out = flash_attention(q, k, v, lengths, window, 1024, unroll)
        elif attn_impl == "folded" and window is None:
            out = folded_causal_attention(q, k, v, lengths=lengths,
                                          unroll=unroll)
        else:
            out = chunked_attention(q, k, v, lengths=lengths, window=window,
                                    unroll=unroll)
        if mode == "prefill":
            new_cache = {"k": k.astype(cfg.compute_dtype),
                         "v": v.astype(cfg.compute_dtype)}
    out = out.reshape(B, S, H * dh)
    return out @ p["wo"].astype(x.dtype), new_cache


def _mlp(p, x, cfg: ArchConfig):
    if cfg.mlp_gated:
        return swiglu_mlp(x, p["w_gate"], p["w_up"], p["w_down"])
    return gelu_mlp(x, p["w_in"], p["w_out"])


def _attn_mlp_block(p, x, cfg, *, positions, lengths, window, mode, cache,
                    attn_impl, unroll=False, norm_fn=rmsnorm, kernels=None,
                    block_table=None):
    h, new_cache = _attention(
        p["attn"], norm_fn(x, p["norm1"], cfg.norm_eps), cfg,
        positions=positions, lengths=lengths, window=window, mode=mode,
        cache=cache, attn_impl=attn_impl, unroll=unroll, kernels=kernels,
        block_table=block_table)
    x = x + h
    x = x + _mlp(p["mlp"], norm_fn(x, p["norm2"], cfg.norm_eps), cfg)
    return x, new_cache, jnp.zeros((), jnp.float32)


def _attn_moe_block(p, x, cfg, *, positions, lengths, window, mode, cache,
                    attn_impl, unroll=False, shard_experts=False,
                    layer_idx=None, routing_hook=None, row_valid=None,
                    kernels=None, block_table=None):
    h, new_cache = _attention(
        p["attn"], rmsnorm(x, p["norm1"], cfg.norm_eps), cfg,
        positions=positions, lengths=lengths, window=window, mode=mode,
        cache=cache, attn_impl=attn_impl, unroll=unroll, kernels=kernels,
        block_table=block_table)
    x = x + h
    B, S, d = x.shape
    xn = rmsnorm(x, p["norm2"], cfg.norm_eps).reshape(B * S, d)
    pos_flat = valid = None
    if routing_hook is not None:
        # flattened (B*S,) token positions line up with xn's rows — the
        # routing hook keys its per-position expert table on them.  The
        # validity mask flags pad-tail rows (bucketed prefill/extend
        # process positions >= the sequence's real length) so recording
        # taps don't histogram padding.  In decode — a full-buffer batch
        # where empty AND occupied-but-unscheduled (mid-prefill) slots
        # are routed too — ``row_valid`` (derived from the tokens-buffer
        # sentinel in ``decode``) identifies the really-scheduled rows;
        # position 0 additionally screens empty slots for direct callers
        # that pass plain token ids.
        pos_flat = positions.reshape(B * S)
        if mode == "decode":
            valid = pos_flat > 0
            if row_valid is not None:
                valid = valid & jnp.broadcast_to(row_valid[:, None],
                                                 (B, S)).reshape(B * S)
        elif lengths is not None:
            valid = (positions < lengths[:, None]).reshape(B * S)
    y, aux = moe_ffn(xn, p["moe"], top_k=cfg.moe.top_k,
                     capacity_factor=cfg.moe.capacity_factor,
                     gated=cfg.mlp_gated, shard_experts=shard_experts,
                     router_fn=routing_hook, positions=pos_flat,
                     layer=layer_idx, valid=valid,
                     backend=kernels.backend if kernels is not None
                     else "reference",
                     interpret=kernels.interpret if kernels is not None
                     else True)
    x = x + y.reshape(B, S, d)
    return x, new_cache, aux


def _mamba_block(p, x, cfg, *, mode, cache):
    xn = rmsnorm(x, p["norm"], cfg.norm_eps)
    if mode == "decode":
        y, st = mb.mamba_decode(p["mamba"], xn, cfg, cache)
        return x + y, st, jnp.zeros((), jnp.float32)
    if mode == "prefill":
        y, st = mb.mamba_forward(p["mamba"], xn, cfg, return_state=True)
        return x + y, st, jnp.zeros((), jnp.float32)
    if mode == "extend":
        y, st = mb.mamba_forward(p["mamba"], xn, cfg, state=cache,
                                 return_state=True)
        return x + y, st, jnp.zeros((), jnp.float32)
    y = mb.mamba_forward(p["mamba"], xn, cfg)
    return x + y, None, jnp.zeros((), jnp.float32)


def _xlstm_block(p, x, cfg, *, mode, cache, unroll=False):
    nh, eps = cfg.n_heads, cfg.norm_eps
    if mode == "extend":
        raise NotImplementedError(
            "xLSTM cached-prefill (extend) is not supported; the serving "
            "engine uses fresh prefill for xLSTM models")
    if mode == "decode":
        x, st_m = xl.mlstm_decode(p["mlstm"], x, nh, eps, cache["mlstm"])
        x, st_s = xl.slstm_decode(p["slstm"], x, nh, eps, cache["slstm"])
        return x, {"mlstm": st_m, "slstm": st_s}, jnp.zeros((), jnp.float32)
    if mode == "prefill":
        x, st_m = xl.mlstm_forward(p["mlstm"], x, nh, eps, return_state=True,
                                   unroll=unroll)
        x, st_s = xl.slstm_forward(p["slstm"], x, nh, eps, return_state=True)
        return x, {"mlstm": st_m, "slstm": st_s}, jnp.zeros((), jnp.float32)
    x = xl.mlstm_forward(p["mlstm"], x, nh, eps, unroll=unroll)
    x = xl.slstm_forward(p["slstm"], x, nh, eps)
    return x, None, jnp.zeros((), jnp.float32)


# --------------------------------------------------------------------------
# the Model
# --------------------------------------------------------------------------

@dataclasses.dataclass
class Model:
    cfg: ArchConfig
    attn_impl: str = "flash"        # flash | chunked | folded
    remat: bool = True
    gemma_superblock: bool = False  # banded local layers (perf variant)
    # Fully unroll the layer stack + inner flash/SSD scans. Used by the
    # dry-run: XLA's cost_analysis does not multiply while-loop bodies by
    # trip count, so loop-free HLO is required for trustworthy roofline
    # numbers (compile is slower; execution semantics identical).
    unroll: bool = False
    fuse_qkv: bool = False          # single QKV matmul (Perf iteration 1)
    shard_experts: bool = False     # pin MoE buffers to model axis (Perf it.2)
    norm_ct16: bool = False         # bf16 cotangent boundary at norms (it.4)
    # injectable MoE routing hook (repro.moe.hooks): replaces the top-k
    # assignment step of every MoE layer — forced replay of a recorded/
    # synthetic ExpertRoutingTrace, logit biasing, or a recording tap.
    # Must be set at construction (the jitted closures capture it).
    routing_hook: Optional[Any] = None
    # resolved kernel backend ("reference" | "pallas" — resolve "auto" via
    # repro.kernels.resolve_backend before constructing the Model).  Pallas
    # only serves the no-grad phases; training uses the pure-JAX twins.
    kernel_backend: str = "reference"
    pallas_interpret: bool = True
    # paged slot-KV layout: attention caches become shared page pools
    # ("k_pages"/"v_pages", (L, n_pages, page_size, KV, dh)) indexed by a
    # per-sequence block table (cache["block_table"], (B, maxp) int32).
    # Requires kernel_backend="pallas" and an all-attention stage list.
    paged: bool = False
    page_size: int = 64

    def _kernel_cfg(self, mode: str) -> Optional[KernelCfg]:
        if self.kernel_backend != "pallas" or mode == "train":
            return None
        return KernelCfg(backend="pallas", interpret=self.pallas_interpret,
                         page_size=self.page_size)

    # ---- init ----
    def init(self, key) -> dict:
        cfg = self.cfg
        keys = jax.random.split(key, len(cfg.stages) + 4)
        params: Dict[str, Any] = {}
        if cfg.embed_inputs:
            params["embed"] = {"tok": m.embed_init(keys[0], cfg.padded_vocab,
                                                   cfg.d_model)}
        for i, st in enumerate(cfg.stages):
            init_fn = _STAGE_INIT[st.kind]
            if st.kind in (ATTN_MLP, ATTN_MOE):
                params[f"stage{i}"] = m.stack_init(
                    keys[i + 1], st.n_layers,
                    lambda k: init_fn(k, cfg, self.fuse_qkv))
            else:
                params[f"stage{i}"] = m.stack_init(
                    keys[i + 1], st.n_layers, lambda k: init_fn(k, cfg))
        if any(st.kind == ZAMBA_SUPER for st in cfg.stages):
            params["shared_attn"] = _init_attn_mlp_layer(keys[-3], cfg)
        params["final_norm"] = m.zeros((cfg.d_model,))
        nout = max(1, cfg.n_codebooks or 1)
        params["head"] = {"w": m.dense_init(keys[-2], cfg.d_model,
                                            nout * cfg.padded_vocab)}
        return params

    # ---- embedding / head ----
    def _embed(self, params, tokens):
        cfg = self.cfg
        if cfg.embed_inputs:
            x = params["embed"]["tok"].astype(cfg.compute_dtype)[tokens]
        else:
            x = tokens.astype(cfg.compute_dtype)   # precomputed embeddings
        return x

    def _head(self, params, x):
        """Logits over the *padded* vocab; consumers slice [..., :vocab]."""
        cfg = self.cfg
        logits = x @ params["head"]["w"].astype(x.dtype)
        if cfg.n_codebooks:
            B, S, _ = logits.shape
            logits = logits.reshape(B, S, cfg.n_codebooks, cfg.padded_vocab)
        return logits

    # ---- stage runners ----
    def _window_for_layer(self, li, period):
        """Traced per-layer window; None = full causal everywhere.

        Global layers get a huge window (== no restriction) so one scanned
        body covers the local:global interleave.
        """
        cfg = self.cfg
        if cfg.sliding_window == 0 or period == 0:
            return None
        is_global = (li % period) == (period - 1)
        return jnp.where(is_global, jnp.int32(2 ** 30),
                         jnp.int32(cfg.sliding_window))

    def _run_stage(self, idx, stage, params, x, *, positions, lengths, mode,
                   cache, shared_attn, row_valid=None, block_table=None):
        cfg = self.cfg
        sp = params[f"stage{idx}"]
        kind = stage.kind
        L = stage.n_layers
        # closure-captured (NOT scan xs): the kernel config is static and
        # the block table is shared by every layer of every stage
        kernels = self._kernel_cfg(mode)
        # global MoE-layer index base: routing hooks key their per-layer
        # tables on the model-wide MoE layer, not the stage-local one
        moe_off = sum(s.n_layers for s in cfg.stages[:idx]
                      if s.kind == ATTN_MOE)

        def layer(x, li, p, kcache):
            if kind == ATTN_MLP:
                window = self._window_for_layer(li, stage.local_global_period)
                return _attn_mlp_block(
                    p, x, cfg, positions=positions, lengths=lengths,
                    window=window, mode=mode, cache=kcache,
                    attn_impl=self.attn_impl, unroll=self.unroll,
                    norm_fn=rmsnorm_ct16 if self.norm_ct16 else rmsnorm,
                    kernels=kernels, block_table=block_table)
            if kind == ATTN_MOE:
                return _attn_moe_block(
                    p, x, cfg, positions=positions, lengths=lengths,
                    window=None, mode=mode, cache=kcache,
                    attn_impl=self.attn_impl, unroll=self.unroll,
                    shard_experts=self.shard_experts,
                    layer_idx=moe_off + li,
                    routing_hook=self.routing_hook, row_valid=row_valid,
                    kernels=kernels, block_table=block_table)
            if kind == MAMBA2:
                return _mamba_block(p, x, cfg, mode=mode, cache=kcache)
            if kind == ZAMBA_SUPER:
                return self._zamba_super(p, x, li, kcache, shared_attn,
                                         positions=positions, lengths=lengths,
                                         mode=mode)
            if kind == XLSTM_PAIR:
                return _xlstm_block(p, x, cfg, mode=mode, cache=kcache,
                                    unroll=self.unroll)
            raise ValueError(kind)

        if self.remat and mode == "train":
            layer = jax.checkpoint(
                layer, policy=jax.checkpoint_policies.nothing_saveable)

        if self.unroll:
            new_caches_l, auxes_l = [], []
            for li in range(L):
                p = jax.tree_util.tree_map(lambda a: a[li], sp)
                kcache = None if cache is None else jax.tree_util.tree_map(
                    lambda a: a[li], cache)
                x, nc, aux = layer(x, jnp.int32(li), p, kcache)
                new_caches_l.append(nc)
                auxes_l.append(aux)
            new_caches = None
            if new_caches_l and new_caches_l[0] is not None:
                new_caches = jax.tree_util.tree_map(
                    lambda *xs: jnp.stack(xs), *new_caches_l)
            return x, new_caches, sum(auxes_l)

        def body(carry, xs):
            x = carry
            li, p, kcache = xs
            x, new_cache, aux = layer(x, li, p, kcache)
            return x, (new_cache, aux)

        lis = jnp.arange(L)
        xs = (lis, sp, cache)
        x, (new_caches, auxes) = jax.lax.scan(body, x, xs)
        return x, new_caches, auxes.sum()

    def _zamba_super(self, p, x, li, kcache, shared_attn, *, positions,
                     lengths, mode):
        """5 mamba + 1 (mamba + shared attention) per superblock."""
        cfg = self.cfg
        aux = jnp.zeros((), jnp.float32)
        inner = p["inner"]
        new_inner = []
        for j in range(6):
            pj = jax.tree_util.tree_map(lambda a: a[j], inner)
            cj = None if kcache is None else jax.tree_util.tree_map(
                lambda a: a[j], kcache["mamba"])
            x, st, _ = _mamba_block(pj, x, cfg, mode=mode, cache=cj)
            new_inner.append(st)
        attn_cache = None if kcache is None else kcache["attn"]
        x, new_attn, _ = _attn_mlp_block(
            shared_attn, x, cfg, positions=positions, lengths=lengths,
            window=None, mode=mode, cache=attn_cache,
            attn_impl=self.attn_impl, unroll=self.unroll)
        new_cache = None
        if mode in ("prefill", "decode", "extend"):
            stacked = jax.tree_util.tree_map(
                lambda *xs: jnp.stack(xs), *new_inner)
            new_cache = {"mamba": stacked, "attn": new_attn}
        return x, new_cache, aux

    # ---- entry points ----
    def forward(self, params, tokens, *, lengths=None):
        """Training/scoring forward. tokens: (B,S) ids or (B,S,d) embeds."""
        cfg = self.cfg
        x = self._embed(params, tokens)
        B, S = x.shape[:2]
        positions = jnp.broadcast_to(jnp.arange(S), (B, S))
        aux_total = jnp.zeros((), jnp.float32)
        for i, st in enumerate(cfg.stages):
            cache_xs = None
            x, _, aux = self._run_stage(
                i, st, params, x, positions=positions, lengths=lengths,
                mode="train", cache=cache_xs,
                shared_attn=params.get("shared_attn"))
            aux_total = aux_total + aux
        x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
        return self._head(params, x), aux_total

    def loss_fn(self, params, batch):
        """batch: {tokens/inputs, labels, (weights)} -> (loss, metrics)."""
        cfg = self.cfg
        inputs = batch["inputs"]
        labels = batch["labels"]
        logits, aux = self.forward(params, inputs)
        logits = logits.astype(jnp.float32)
        logp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
        if cfg.n_codebooks:
            nll = nll.mean(axis=-1)          # average over codebook heads
        weights = batch.get("weights")
        if weights is None:
            weights = jnp.ones(nll.shape, jnp.float32)
        loss = (nll * weights).sum() / jnp.maximum(weights.sum(), 1.0)
        total = loss + 0.01 * aux
        return total, {"loss": loss, "aux_loss": aux,
                       "tokens": weights.sum()}

    def prefill(self, params, tokens, *, lengths=None):
        """Returns (logits_last, cache). tokens: (B,S)."""
        cfg = self.cfg
        x = self._embed(params, tokens)
        B, S = x.shape[:2]
        positions = jnp.broadcast_to(jnp.arange(S), (B, S))
        if lengths is None:
            lengths = jnp.full((B,), S, jnp.int32)
        caches = {}
        for i, st in enumerate(cfg.stages):
            x, new_cache, _ = self._run_stage(
                i, st, params, x, positions=positions, lengths=lengths,
                mode="prefill", cache=None,
                shared_attn=params.get("shared_attn"))
            caches[f"stage{i}"] = new_cache
        x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
        idx = jnp.maximum(lengths - 1, 0)
        x_last = x[jnp.arange(B), idx][:, None]        # (B,1,d)
        logits = self._head(params, x_last)
        caches["lengths"] = lengths
        return logits, caches

    def decode(self, params, cache, tokens):
        """One decode step. tokens: (B,1) ids (or (B,1,d) embeds).

        cache["lengths"] counts tokens *already in* the cache; the new token
        is written at index lengths (then lengths+1 is returned).
        """
        cfg = self.cfg
        # MoE routing-hook row mask for the full-buffer batch: a negative
        # token id is the engine's sentinel for a slot that is NOT
        # scheduled this iteration (free, or occupied mid-prefill) — its
        # row still computes, but must neither be recorded as workload
        # routing nor consume expert capacity under forced replay
        row_valid = None
        if jnp.issubdtype(tokens.dtype, jnp.integer):
            row_valid = tokens.reshape(tokens.shape[0], -1)[:, 0] >= 0
            tokens = jnp.maximum(tokens, 0)
        x = self._embed(params, tokens)
        B = x.shape[0]
        lengths = cache["lengths"] + 1       # include current token
        positions = (lengths - 1)[:, None]
        block_table = cache.get("block_table")
        new_cache = {"lengths": lengths}
        if block_table is not None:
            new_cache["block_table"] = block_table
        for i, st in enumerate(cfg.stages):
            x, nc, _ = self._run_stage(
                i, st, params, x, positions=positions, lengths=lengths,
                mode="decode", cache=cache[f"stage{i}"],
                shared_attn=params.get("shared_attn"),
                row_valid=row_valid, block_table=block_table)
            new_cache[f"stage{i}"] = nc
        x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
        logits = self._head(params, x)
        return logits, new_cache

    def _extend_states(self, params, cache, tokens, n_new):
        """Shared body of ``extend``/``verify``: append up to S tokens to
        the cache and return the final-norm hidden states of every
        position, ``(B, S, d)``, plus the new cache."""
        cfg = self.cfg
        x = self._embed(params, tokens)
        B, S = x.shape[:2]
        start = cache["lengths"]
        if n_new is None:
            n_new = jnp.full((B,), S, jnp.int32)
        lengths = start + n_new
        positions = start[:, None] + jnp.arange(S)[None, :]
        block_table = cache.get("block_table")
        new_cache = {"lengths": lengths}
        if block_table is not None:
            new_cache["block_table"] = block_table
        for i, st in enumerate(cfg.stages):
            x, nc, _ = self._run_stage(
                i, st, params, x, positions=positions, lengths=lengths,
                mode="extend", cache=cache[f"stage{i}"],
                shared_attn=params.get("shared_attn"),
                block_table=block_table)
            new_cache[f"stage{i}"] = nc
        x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
        return x, new_cache, n_new

    def extend(self, params, cache, tokens, n_new=None):
        """Cached/chunked prefill: append up to S tokens (``n_new`` (B,)
        real, rest padding) to a cache holding cache["lengths"] tokens per
        sequence. Returns (last-real-token logits, cache)."""
        x, new_cache, n_new = self._extend_states(params, cache, tokens,
                                                  n_new)
        idx = jnp.maximum(n_new - 1, 0)
        x_last = x[jnp.arange(x.shape[0]), idx][:, None]
        logits = self._head(params, x_last)
        return logits, new_cache

    def verify(self, params, cache, tokens, n_new=None):
        """Speculative-decoding verification: ``extend`` the cache with up
        to S tokens (the pending token + the draft's proposals) but return
        logits at EVERY position — ``(B, S, Vpad)`` — so the caller can
        compare each draft token against the target's greedy prediction
        and pick the accepted prefix + bonus token.  KV for all S slots is
        written; the caller rolls ``lengths`` back to the accepted prefix
        (unaccepted rows are dead weight overwritten by the next write at
        the same indices)."""
        x, new_cache, _ = self._extend_states(params, cache, tokens, n_new)
        logits = self._head(params, x)
        return logits, new_cache

    # ---- cache construction ----
    def page_geometry(self, batch: int, max_len: int) -> Tuple[int, int]:
        """(pages per sequence, total pool pages incl. the scratch page)."""
        maxp = -(-max_len // self.page_size)
        return maxp, batch * maxp + 1

    def init_cache(self, batch: int, max_len: int, dtype=None):
        """Zeroed cache pytree (concrete); see ``cache_specs`` for dry-run."""
        cfg = self.cfg
        dtype = dtype or cfg.compute_dtype
        cache: Dict[str, Any] = {
            "lengths": jnp.zeros((batch,), jnp.int32)}
        if self.paged:
            bad = [st.kind for st in cfg.stages
                   if st.kind not in (ATTN_MLP, ATTN_MOE)]
            if bad:
                raise ValueError(
                    f"paged KV cache only supports attention stages; "
                    f"{self.cfg.name} has {bad}")
            # every sequence starts pointing at the scratch page (last pool
            # index): garbage writes from unscheduled decode slots land
            # there and are never read back
            maxp, n_pages = self.page_geometry(batch, max_len)
            cache["block_table"] = jnp.full((batch, maxp), n_pages - 1,
                                            jnp.int32)
        for i, st in enumerate(cfg.stages):
            cache[f"stage{i}"] = self._stage_cache(st, batch, max_len, dtype)
        return cache

    def _stage_cache(self, st, batch, max_len, dtype):
        cfg = self.cfg
        L = st.n_layers
        KV, dh = cfg.n_kv_heads, cfg.d_head

        def kv(n):
            if self.paged:
                _, n_pages = self.page_geometry(batch, max_len)
                shape = (n, n_pages, self.page_size, KV, dh)
                return {"k_pages": jnp.zeros(shape, dtype),
                        "v_pages": jnp.zeros(shape, dtype)}
            return {"k": jnp.zeros((n, batch, max_len, KV, dh), dtype),
                    "v": jnp.zeros((n, batch, max_len, KV, dh), dtype)}

        if st.kind in (ATTN_MLP, ATTN_MOE):
            return kv(L)
        if st.kind == MAMBA2:
            one = mb.init_mamba_state(batch, cfg.d_model, cfg.ssm, dtype)
            return jax.tree_util.tree_map(
                lambda a: jnp.broadcast_to(a, (L,) + a.shape), one)
        if st.kind == ZAMBA_SUPER:
            one = mb.init_mamba_state(batch, cfg.d_model, cfg.ssm, dtype)
            mamba = jax.tree_util.tree_map(
                lambda a: jnp.broadcast_to(a, (L, 6) + a.shape), one)
            return {"mamba": mamba,
                    "attn": jax.tree_util.tree_map(lambda a: a, kv(L))}
        if st.kind == XLSTM_PAIR:
            ml = xl.init_mlstm_state(batch, cfg.d_model, cfg.n_heads, dtype)
            sl = xl.init_slstm_state(batch, cfg.d_model, cfg.n_heads)
            return {
                "mlstm": jax.tree_util.tree_map(
                    lambda a: jnp.broadcast_to(a, (L,) + a.shape), ml),
                "slstm": jax.tree_util.tree_map(
                    lambda a: jnp.broadcast_to(a, (L,) + a.shape), sl),
            }
        raise ValueError(st.kind)
