"""Core layers: norms, RoPE, GQA attention (chunked-flash prefill, cached
decode, banded local), gated/plain MLPs.

Attention comes in three implementations selected by the model:
  * ``chunked_attention`` — online-softmax over KV blocks via ``lax.scan``;
    O(S·bkv) live memory instead of O(S²); the pure-JAX analogue of a flash
    kernel and the default for train/prefill. Computes the full rectangle
    with causal masking (2x FLOP waste vs perfect causal skip — see
    EXPERIMENTS.md §Perf for the folded schedule that removes it).
  * ``folded_causal_attention`` — the load-balanced causal schedule: query
    blocks are paired (i, n-1-i) so every scan step touches a constant number
    of KV blocks; removes the rectangle waste.
  * ``decode_attention`` — single-query attention against a KV cache with
    per-sequence lengths and optional sliding window.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def rmsnorm(x, scale, eps: float = 1e-5):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + scale.astype(jnp.float32))).astype(dtype)


@jax.custom_vjp
def _bf16_ct_boundary(x):
    """Identity with optimization barriers on both the primal and the
    cotangent, placed at the residual-stream entry of each norm: XLA
    otherwise hoists the norm's f32 convert above the TP all-reduce on both
    the forward (residual add) and backward (dx) paths, doubling every
    activation collective (§Perf starcoder2 iterations 1/4)."""
    return jax.lax.optimization_barrier(x)


def _bf16_ct_fwd(x):
    return (jax.lax.optimization_barrier(x),
            jnp.zeros((0,), x.dtype))    # dtype token (dtypes aren't pytrees)


def _bf16_ct_bwd(token, dy):
    dy = jax.lax.optimization_barrier(dy.astype(token.dtype))
    return (dy,)


_bf16_ct_boundary.defvjp(_bf16_ct_fwd, _bf16_ct_bwd)


def rmsnorm_ct16(x, scale, eps: float = 1e-5):
    """rmsnorm with a compute-dtype cotangent boundary (see above)."""
    return rmsnorm(_bf16_ct_boundary(x), scale, eps)


def rope(x, positions, theta: float = 10_000.0):
    """Rotary embedding. x: (..., S, H, dh); positions: broadcastable to (..., S)."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = jnp.exp(-jnp.log(theta) * jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, half)
    cos = jnp.cos(angles)[..., None, :]  # (..., S, 1, half)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def _gqa_scores(qb, kb):
    """qb: (B, bq, KV, G, dh); kb: (B, bkv, KV, dh) -> (B, KV, G, bq, bkv)."""
    return jnp.einsum("bqkgd,bjkd->bkgqj", qb, kb,
                      preferred_element_type=jnp.float32)


def chunked_attention(q, k, v, *, lengths=None, window=None,
                      causal: bool = True, bkv: int = 1024,
                      unroll: bool = False):
    """Online-softmax attention over KV blocks.

    q: (B, S, H, dh), k/v: (B, S, KV, dh). Returns (B, S, H, dh).
    ``lengths``: (B,) valid token counts (None = all valid).
    ``window``: sliding window size; None = full causal. May be a traced
    scalar (per-layer local/global selection inside a layer scan).
    """
    B, S, H, dh = q.shape
    KV = k.shape[2]
    G = H // KV
    bkv = min(bkv, S)
    nk = S // bkv
    assert S % bkv == 0, (S, bkv)
    scale = dh ** -0.5
    qr = (q * scale).reshape(B, S, KV, G, dh)
    kb = jnp.moveaxis(k.reshape(B, nk, bkv, KV, dh), 1, 0)  # (nk, B, bkv, KV, dh)
    vb = jnp.moveaxis(v.reshape(B, nk, bkv, KV, dh), 1, 0)

    q_pos = jnp.arange(S)

    def body(carry, xs):
        acc, m, l = carry
        j, kj, vj = xs
        s = _gqa_scores(qr, kj)  # (B, KV, G, S, bkv)
        kv_pos = j * bkv + jnp.arange(bkv)
        mask = jnp.ones((S, bkv), bool)
        if causal:
            mask &= q_pos[:, None] >= kv_pos[None, :]
        if window is not None:
            mask &= q_pos[:, None] - kv_pos[None, :] < window
        if lengths is not None:
            mask = mask[None] & (kv_pos[None, None, :] < lengths[:, None, None])
            mask = mask[:, None, None]
        else:
            mask = mask[None, None, None]
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        pv = jnp.einsum("bkgqj,bjkd->bkgqd", p.astype(vj.dtype), vj,
                        preferred_element_type=jnp.float32)
        acc = acc * corr[..., None] + pv
        return (acc, m_new, l_new), None

    acc0 = jnp.zeros((B, KV, G, S, dh), jnp.float32)
    m0 = jnp.full((B, KV, G, S), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, KV, G, S), jnp.float32)
    (acc, m, l), _ = jax.lax.scan(
        body, (acc0, m0, l0), (jnp.arange(nk), kb, vb),
        unroll=nk if unroll else 1)
    out = acc / jnp.maximum(l[..., None], 1e-20)
    out = jnp.moveaxis(out, 3, 1).reshape(B, S, H, dh)  # (B,S,KV,G,dh)->(B,S,H,dh)
    return out.astype(q.dtype)


def folded_causal_attention(q, k, v, *, lengths=None, bkv: int = 1024,
                            depth: int = 3, unroll: bool = False):
    """Recursive-halving causal attention (removes most rectangle waste).

    The full-rectangle scan computes S² score entries for causal attention
    that only needs S²/2. Split queries in half: the lower half only ever
    attends the lower half of keys (recurse), the upper half attends all keys
    (rectangle, ~half of it useful). Cost -> S²/2 · (1 + 1/4 + 1/16 + ...)
    ≈ 0.67·S² at depth 3 vs 1.0·S² for the naive rectangle. The exact
    constant-cost folded schedule lands in the Pallas flash kernel where the
    grid is explicit; this is the best pure-XLA schedule we found (§Perf).
    """
    B, S, H, dh = q.shape
    if depth <= 0 or S // 2 < bkv or (S // 2) % bkv != 0:
        return chunked_attention(q, k, v, lengths=lengths, bkv=min(bkv, S),
                                 unroll=unroll)
    half = S // 2
    out_lo = folded_causal_attention(
        q[:, :half], k[:, :half], v[:, :half],
        lengths=lengths, bkv=bkv, depth=depth - 1, unroll=unroll)
    out_hi = _hi_half_causal(q, k, v, lengths=lengths, bkv=bkv,
                             unroll=unroll)
    return jnp.concatenate([out_lo, out_hi], axis=1)


def _hi_half_causal(q, k, v, *, lengths, bkv, unroll: bool = False):
    """Causal attention for the upper-half queries over all S keys."""
    B, S, H, dh = q.shape
    KV = k.shape[2]
    G = H // KV
    half = S // 2
    scale = dh ** -0.5
    qr = (q[:, half:] * scale).reshape(B, half, KV, G, dh)
    nk = S // bkv
    kb = jnp.moveaxis(k.reshape(B, nk, bkv, KV, dh), 1, 0)
    vb = jnp.moveaxis(v.reshape(B, nk, bkv, KV, dh), 1, 0)
    q_pos = jnp.arange(half) + half

    def body(carry, xs):
        acc, m, l = carry
        j, kj, vj = xs
        s = _gqa_scores(qr, kj)
        kv_pos = j * bkv + jnp.arange(bkv)
        mask = q_pos[:, None] >= kv_pos[None, :]
        if lengths is not None:
            mask = mask[None] & (kv_pos[None, None, :] < lengths[:, None, None])
            mask = mask[:, None, None]
        else:
            mask = mask[None, None, None]
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        pv = jnp.einsum("bkgqj,bjkd->bkgqd", p.astype(vj.dtype), vj,
                        preferred_element_type=jnp.float32)
        acc = acc * corr[..., None] + pv
        return (acc, m_new, l_new), None

    acc0 = jnp.zeros((B, KV, G, half, dh), jnp.float32)
    m0 = jnp.full((B, KV, G, half), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, KV, G, half), jnp.float32)
    (acc, m, l), _ = jax.lax.scan(body, (acc0, m0, l0),
                                  (jnp.arange(nk), kb, vb),
                                  unroll=nk if unroll else 1)
    out = acc / jnp.maximum(l[..., None], 1e-20)
    out = jnp.moveaxis(out, 3, 1).reshape(B, half, H, dh)
    return out.astype(q.dtype)


def local_banded_attention(q, k, v, *, window: int, lengths=None):
    """Sliding-window attention computing only the diagonal band.

    Query block i (size w) attends KV blocks {i-1, i} -> FLOPs 2·S·w instead
    of S². Used by gemma3-style local layers when ``gemma_superblock`` is on.
    """
    B, S, H, dh = q.shape
    KV = k.shape[2]
    G = H // KV
    w = window
    assert S % w == 0, (S, w)
    nb = S // w
    scale = dh ** -0.5
    qr = (q * scale).reshape(B, nb, w, KV, G, dh)
    kb = k.reshape(B, nb, w, KV, dh)
    vb = v.reshape(B, nb, w, KV, dh)
    # previous block (block -1 = zeros, masked out)
    kprev = jnp.concatenate([jnp.zeros_like(kb[:, :1]), kb[:, :-1]], axis=1)
    vprev = jnp.concatenate([jnp.zeros_like(vb[:, :1]), vb[:, :-1]], axis=1)
    kband = jnp.concatenate([kprev, kb], axis=2)  # (B, nb, 2w, KV, dh)
    vband = jnp.concatenate([vprev, vb], axis=2)
    s = jnp.einsum("bnqkgd,bnjkd->bnkgqj", qr, kband,
                   preferred_element_type=jnp.float32)  # (B,nb,KV,G,w,2w)
    q_pos = (jnp.arange(nb)[:, None] * w + jnp.arange(w)[None, :])  # (nb, w)
    kv_pos = (jnp.arange(nb)[:, None] - 1) * w + jnp.arange(2 * w)[None, :]
    mask = (q_pos[:, :, None] >= kv_pos[:, None, :])
    mask &= (q_pos[:, :, None] - kv_pos[:, None, :] < w)
    mask &= (kv_pos >= 0)[:, None, :]
    if lengths is not None:
        mask = mask[None] & (kv_pos[None, :, None, :] < lengths[:, None, None, None])
        mask = mask[:, :, None, None]
    else:
        mask = mask[None, :, None, None]
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bnkgqj,bnjkd->bnqkgd", p.astype(vband.dtype), vband,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, S, H, dh).astype(q.dtype)


def decode_attention(q, k_cache, v_cache, *, lengths, window=None):
    """Single-token attention against a KV cache.

    q: (B, 1, H, dh); caches: (B, S, KV, dh); lengths: (B,) tokens valid in
    cache *including* the current one (query position = lengths-1).
    ``window`` may be a traced scalar; None = full.
    """
    B, _, H, dh = q.shape
    S = k_cache.shape[1]
    KV = k_cache.shape[2]
    G = H // KV
    scale = dh ** -0.5
    qr = (q[:, 0] * scale).reshape(B, KV, G, dh)
    s = jnp.einsum("bkgd,bjkd->bkgj", qr, k_cache,
                   preferred_element_type=jnp.float32)  # (B,KV,G,S)
    kv_pos = jnp.arange(S)
    mask = kv_pos[None, :] < lengths[:, None]
    if window is not None:
        mask &= kv_pos[None, :] >= (lengths[:, None] - window)
    s = jnp.where(mask[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgj,bjkd->bkgd", p.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, 1, H, dh).astype(q.dtype)


def extend_attention(q, k_cache, v_cache, *, start, lengths, window=None):
    """Multi-token attention against a cache that already holds ``start``
    tokens per sequence (chunked/cached prefill). q: (B,S,H,dh); caches:
    (B,S_max,KV,dh) with the new chunk already written at
    [start, start+S). ``lengths`` = start + S (total tokens after chunk).
    Dense masked attention — engine-side path for modest S_max.
    """
    B, S, H, dh = q.shape
    S_max = k_cache.shape[1]
    KV = k_cache.shape[2]
    G = H // KV
    scale = dh ** -0.5
    qr = (q * scale).reshape(B, S, KV, G, dh)
    s = jnp.einsum("bqkgd,bjkd->bkgqj", qr, k_cache,
                   preferred_element_type=jnp.float32)  # (B,KV,G,S,S_max)
    kv_pos = jnp.arange(S_max)
    q_pos = start[:, None] + jnp.arange(S)[None, :]      # (B,S)
    mask = kv_pos[None, None, :] <= q_pos[..., None]     # causal incl. cache
    mask &= (kv_pos[None, None, :] < lengths[:, None, None])
    if window is not None:
        mask &= kv_pos[None, None, :] > (q_pos[..., None] - window)
    s = jnp.where(mask[:, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgqj,bjkd->bkgqd", p.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    out = jnp.moveaxis(out, 3, 1).reshape(B, S, H, dh)
    return out.astype(q.dtype)


def gelu_mlp(x, w_in, w_out):
    h = jax.nn.gelu(x @ w_in.astype(x.dtype))
    return h @ w_out.astype(x.dtype)


def swiglu_mlp(x, w_gate, w_up, w_down):
    g = jax.nn.silu(x @ w_gate.astype(x.dtype))
    h = g * (x @ w_up.astype(x.dtype))
    return h @ w_down.astype(x.dtype)
