"""Mixture-of-Experts FFN with top-k routing — sort-based dispatch.

TPU-idiomatic implementation: instead of the GShard (T, E, C) one-hot
dispatch einsum (whose dispatch tensor is quadratically large), tokens are
*sorted by expert id*, packed into per-expert capacity buffers, run through a
batched (E, C, d) einsum (the grouped GEMM that the Pallas kernel
``kernels/moe_gmm.py`` accelerates), and scattered back with combine weights.
Capacity overflow tokens are dropped (standard top-k MoE semantics); the
router is the model-side analogue of the simulator's ``core/expert.py``
ExpertRouter and can be swapped out the same way.

FLOPs: 3 · E · C · d · d_e per layer — matches the active-parameter roofline.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def router_topk(x, w_router, top_k: int):
    """Return (expert_idx (T,k) int32, combine_w (T,k) f32, aux_loss scalar)."""
    logits = (x @ w_router.astype(x.dtype)).astype(jnp.float32)  # (T, E)
    E = logits.shape[-1]
    probs = jax.nn.softmax(logits, axis=-1)
    combine_w, expert_idx = jax.lax.top_k(probs, top_k)
    combine_w = combine_w / jnp.maximum(
        combine_w.sum(-1, keepdims=True), 1e-9)
    # Switch-style load-balancing aux loss: E * sum_e f_e * p_e
    me = probs.mean(axis=0)                                   # (E,)
    ce = jnp.zeros((E,), jnp.float32).at[expert_idx.reshape(-1)].add(
        jnp.ones(expert_idx.size, jnp.float32)) / expert_idx.size
    aux = E * jnp.sum(me * ce)
    return expert_idx.astype(jnp.int32), combine_w, aux


def moe_ffn(x, params, *, top_k: int, capacity_factor: float = 1.25,
            gated: bool = True, shard_experts: bool = False,
            router_fn=None, positions=None, layer=None, valid=None,
            backend: str = "reference", interpret: bool = True):
    """x: (T, d). params: router (d,E), w_gate/w_up (E,d,de), w_down (E,de,d).

    ``backend="pallas"`` swaps the three batched einsums for the fused
    grouped-GEMM kernel (``kernels.moe_gmm``) with per-expert group sizes
    from the dispatch counts — tiles past a group's size are skipped on
    real TPUs (compute proportional to routed load, not capacity).

    ``router_fn`` is the injectable routing hook (``repro.moe.hooks``):
    called as ``router_fn(logits, positions=(T,), layer=scalar,
    top_k=int, valid=(T,) bool or None)`` and returning ``(expert_idx
    (T,k) int32, combine_w (T,k), aux scalar)``.  It replaces only the
    *assignment* step — dispatch, capacity and combine run unchanged — so
    a replayed skew exercises the real grouped-GEMM path end-to-end.
    ``valid`` flags which rows are real workload tokens (pad tails and
    empty decode slots are False); recording taps mask on it, and dispatch
    sends invalid rows straight to the overflow slot so they never consume
    a real token's expert capacity (forced replay would otherwise route
    every empty decode slot to the same table row and let it evict real
    work from the capacity buffers).
    """
    T, d = x.shape
    E = params["router"].shape[-1]
    if router_fn is None:
        expert_idx, combine_w, aux = router_topk(x, params["router"], top_k)
    else:
        logits = (x @ params["router"].astype(x.dtype)).astype(jnp.float32)
        expert_idx, combine_w, aux = router_fn(
            logits, positions=positions, layer=layer, top_k=top_k,
            valid=valid)
        expert_idx = expert_idx.astype(jnp.int32)
    # the one capacity definition shared with the simulator's pricing and
    # the drop-rate metric (T is a static Python int under jit)
    from repro.core.expert import expert_capacity
    C = expert_capacity(T, top_k, E, capacity_factor)

    # --- dispatch: sort (token, k) pairs by expert --------------------------
    flat_e = expert_idx.reshape(-1)                    # (T*k,)
    if valid is None:
        sort_e = flat_e
    else:
        # invalid rows sort into a trash bucket past every real expert
        sort_e = jnp.where(jnp.repeat(valid, top_k), flat_e, E)
    order = jnp.argsort(sort_e)                        # stable
    tok_of = order // top_k                            # token index per entry
    e_sorted = flat_e[order]
    s_sorted = sort_e[order]
    # position within expert group = rank - group_start[expert]
    counts = jnp.zeros((E + 1,), jnp.int32).at[sort_e].add(1)
    starts = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                              jnp.cumsum(counts)[:-1]])
    pos_in_e = jnp.arange(T * top_k, dtype=jnp.int32) - starts[s_sorted]
    keep = (pos_in_e < C) & (s_sorted < E)             # capacity drop
    dst_e = jnp.where(keep, e_sorted, 0)
    dst_c = jnp.where(keep, pos_in_e, C)               # C = overflow slot

    buf = jnp.zeros((E, C + 1, d), x.dtype)
    buf = buf.at[dst_e, dst_c].set(x[tok_of])          # (E, C+1, d)
    hidden_in = buf[:, :C]                             # (E, C, d)
    if shard_experts:
        # pin the expert buffers to the model axis so XLA routes tokens with
        # one all-to-all instead of resharding per einsum (Perf iteration 2;
        # GSPMD pads E when it does not divide the axis)
        from jax.sharding import PartitionSpec as P
        hidden_in = jax.lax.with_sharding_constraint(
            hidden_in, P("model", None, None))

    # --- grouped expert FFN -------------------------------------------------
    if backend == "pallas" and not shard_experts:
        from repro.kernels import moe_gmm
        # valid rows per expert buffer; rows >= size are zero either way
        # (silu(0)*0 == 0, gelu(0) == 0), the kernel just skips their tiles
        group_sizes = jnp.minimum(counts[:E], C)
        if gated:
            g = jax.nn.silu(moe_gmm(
                hidden_in, params["w_gate"].astype(x.dtype), group_sizes,
                interpret=interpret))
            u = moe_gmm(hidden_in, params["w_up"].astype(x.dtype),
                        group_sizes, interpret=interpret)
            h = g * u
        else:
            h = jax.nn.gelu(moe_gmm(
                hidden_in, params["w_up"].astype(x.dtype), group_sizes,
                interpret=interpret))
        out_e = moe_gmm(h, params["w_down"].astype(x.dtype), group_sizes,
                        interpret=interpret)
    elif gated:
        g = jax.nn.silu(jnp.einsum("ecd,edf->ecf", hidden_in,
                                   params["w_gate"].astype(x.dtype)))
        u = jnp.einsum("ecd,edf->ecf", hidden_in,
                       params["w_up"].astype(x.dtype))
        h = g * u
        out_e = jnp.einsum("ecf,efd->ecd", h,
                           params["w_down"].astype(x.dtype))
    else:
        h = jax.nn.gelu(jnp.einsum("ecd,edf->ecf", hidden_in,
                                   params["w_up"].astype(x.dtype)))
        out_e = jnp.einsum("ecf,efd->ecd", h,
                           params["w_down"].astype(x.dtype))
    if shard_experts:
        from jax.sharding import PartitionSpec as P
        out_e = jax.lax.with_sharding_constraint(
            out_e, P("model", None, None))

    # --- combine: gather back and weight ------------------------------------
    gathered = out_e[dst_e, jnp.minimum(dst_c, C - 1)]  # (T*k, d)
    w = (combine_w.reshape(-1)[order] * keep).astype(x.dtype)
    contrib = gathered * w[:, None]
    y = jnp.zeros((T, d), x.dtype).at[tok_of].add(contrib)
    return y, aux
