"""xLSTM blocks [arXiv:2405.04517]: mLSTM (matrix memory, parallelizable)
and sLSTM (scalar memory, strictly sequential exponential gating).

mLSTM prefill uses a *chunked online* form: the parallel mLSTM is attention
with an additive log-decay bias (logD[i,j] = F_i - F_j + i_j, F = cumsum of
log-sigmoid forget gates) and an abs-max normalizer instead of softmax. We
reuse the flash-style scan over KV chunks, tracking a running max of logD
(the exp part is always positive; q·k keeps its sign in the accumulator).
Decode carries (C, n, m) per head: C (hd×hd) matrix memory.

sLSTM has no parallel form (normalizer + stabilizer recurrence) -> lax.scan
over time; per-head block-diagonal recurrent weights.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import module as m
from repro.models.layers import rmsnorm

NEG_INF = -1e30


# --------------------------------------------------------------------------
# mLSTM
# --------------------------------------------------------------------------

def init_mlstm(key, d: int, nh: int) -> dict:
    d_in = 2 * d
    ks = jax.random.split(key, 8)
    return {
        "norm_in": m.zeros((d,)),
        "w_up": m.dense_init(ks[0], d, 2 * d_in),     # -> [x_in, z]
        "conv_w": m.dense_init(ks[1], 4, d_in) * 2.0,  # depthwise k=4
        "conv_b": m.zeros((d_in,)),
        "w_q": m.dense_init(ks[2], d_in, d_in),
        "w_k": m.dense_init(ks[3], d_in, d_in),
        "w_v": m.dense_init(ks[4], d_in, d_in),
        "w_i": m.dense_init(ks[5], d_in, nh),
        "w_f": m.dense_init(ks[6], d_in, nh),
        "f_bias": m.ones((nh,)) * 3.0,                # open forget gates
        "norm_h": m.zeros((d_in,)),
        "w_down": m.dense_init(ks[7], d_in, d),
    }


def _causal_conv(x, w, b):
    k = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(k):
        out = out + pad[:, i: i + x.shape[1]] * w[i]
    return out + b


def _mlstm_inner_chunked(q, k, v, i_pre, f_pre, chunk: int,
                         unroll: bool = False):
    """Chunked stabilized mLSTM. q,k,v: (B,S,nh,hd); i_pre,f_pre: (B,S,nh)."""
    B, S, nh, hd = q.shape
    F = jnp.cumsum(jax.nn.log_sigmoid(f_pre.astype(jnp.float32)), axis=1)
    I = i_pre.astype(jnp.float32)
    Q = min(chunk, S)
    S_orig = S
    if S % Q:
        # zero-pad tail: k=v=0 -> padded keys contribute nothing; padded
        # queries are sliced off; causal mask already blocks pad<-real.
        pad = Q - S % Q
        zpad4 = ((0, 0), (0, pad), (0, 0), (0, 0))
        zpad3 = ((0, 0), (0, pad), (0, 0))
        q = jnp.pad(q, zpad4)
        k = jnp.pad(k, zpad4)
        v = jnp.pad(v, zpad4)
        F = jnp.pad(F, zpad3)
        I = jnp.pad(I, zpad3)
        S = S + pad
    nc = S // Q
    scale = hd ** -0.5
    qc = (q * scale).reshape(B, nc, Q, nh, hd)
    kc = k.reshape(B, nc, Q, nh, hd)
    vc = v.reshape(B, nc, Q, nh, hd)
    Fc = F.reshape(B, nc, Q, nh)
    Ic = I.reshape(B, nc, Q, nh)

    kb = jnp.moveaxis(kc, 1, 0)
    vb = jnp.moveaxis(vc, 1, 0)
    Fb = jnp.moveaxis(Fc, 1, 0)
    Ib = jnp.moveaxis(Ic, 1, 0)

    q_idx = jnp.arange(nc)

    # align logD shapes: build Fj/Ij broadcast inside body via explicit shapes
    def body_fixed(carry, xs):
        acc, l, mx = carry
        j, kj, vj, Fj, Ij = xs                 # kj: (B,Q,nh,hd); Fj: (B,Q,nh)
        s = jnp.einsum("bcqhd,bjhd->bcqhj", qc, kj,
                       preferred_element_type=jnp.float32)
        Fi = Fc[..., None]                      # (B,nc,Q,nh,1)
        Fj_ = Fj.transpose(0, 2, 1)[:, None, None, :, :]  # (B,1,1,nh,Qj)
        Ij_ = Ij.transpose(0, 2, 1)[:, None, None, :, :]
        logD = Fi - Fj_ + Ij_
        qpos = (jnp.arange(nc)[:, None] * Q + jnp.arange(Q)[None, :])
        kpos = j * Q + jnp.arange(Q)
        causal = qpos[..., None] >= kpos[None, None, :]
        logD = jnp.where(causal[None, :, :, None, :], logD, NEG_INF)
        m_new = jnp.maximum(mx, logD.max(axis=-1))
        w = jnp.exp(logD - m_new[..., None])
        corr = jnp.exp(mx - m_new)
        sw = s * w
        acc = acc * corr[..., None] + jnp.einsum(
            "bcqhj,bjhd->bcqhd", sw, vj.astype(jnp.float32))
        l = l * corr + sw.sum(axis=-1)
        return (acc, l, m_new), None

    acc0 = jnp.zeros((B, nc, Q, nh, hd), jnp.float32)
    l0 = jnp.zeros((B, nc, Q, nh), jnp.float32)
    m0 = jnp.full((B, nc, Q, nh), NEG_INF, jnp.float32)
    (acc, l, mx), _ = jax.lax.scan(
        body_fixed, (acc0, l0, m0), (q_idx, kb, vb, Fb, Ib),
        unroll=nc if unroll else 1)
    denom = jnp.maximum(jnp.abs(l), jnp.exp(-mx))
    h = acc / denom[..., None]
    return h.reshape(B, S, nh, hd)[:, :S_orig]


def mlstm_forward(params, x, nh: int, eps: float,
                  state: Optional[dict] = None, return_state: bool = False,
                  chunk: int = 256, unroll: bool = False):
    """mLSTM block. x: (B,S,d)."""
    B, S, d = x.shape
    d_in = 2 * d
    hd = d_in // nh
    xn = rmsnorm(x, params["norm_in"], eps)
    up = xn @ params["w_up"].astype(x.dtype)
    x_in, z = jnp.split(up, 2, axis=-1)
    conv_in = x_in
    cx = jax.nn.silu(_causal_conv(x_in, params["conv_w"].astype(x.dtype),
                                  params["conv_b"].astype(x.dtype)))
    q = (cx @ params["w_q"].astype(x.dtype)).reshape(B, S, nh, hd)
    k = (cx @ params["w_k"].astype(x.dtype)).reshape(B, S, nh, hd)
    v = (x_in @ params["w_v"].astype(x.dtype)).reshape(B, S, nh, hd)
    i_pre = cx @ params["w_i"].astype(x.dtype)
    f_pre = cx @ params["w_f"].astype(x.dtype) + params["f_bias"].astype(x.dtype)
    h = _mlstm_inner_chunked(q, k, v, i_pre, f_pre, chunk, unroll=unroll)
    h = h.reshape(B, S, d_in).astype(x.dtype)
    h = rmsnorm(h, params["norm_h"], eps) * jax.nn.silu(z)
    out = x + h @ params["w_down"].astype(x.dtype)
    if return_state:
        # recompute exact final recurrent state for decode continuation
        st = _mlstm_final_state(q, k, v, i_pre, f_pre)
        st["conv"] = conv_in[:, S - 3:, :]
        return out, st
    return out


def _mlstm_final_state(q, k, v, i_pre, f_pre):
    """Exact (C, n, m) after consuming the whole sequence."""
    B, S, nh, hd = k.shape
    logf = jax.nn.log_sigmoid(f_pre.astype(jnp.float32))
    F = jnp.cumsum(logf, axis=1)                 # (B,S,nh)
    Ftot = F[:, -1]                              # (B,nh)
    # weight of step t in final state: exp(Ftot - F_t + I_t)
    logw = Ftot[:, None] - F + i_pre.astype(jnp.float32)
    mfin = logw.max(axis=1)                      # (B,nh)
    w = jnp.exp(logw - mfin[:, None])
    C = jnp.einsum("bshd,bshe,bsh->bhde", v.astype(jnp.float32),
                   k.astype(jnp.float32), w)
    n = jnp.einsum("bshd,bsh->bhd", k.astype(jnp.float32), w)
    return {"C": C, "n": n, "m": mfin}


def mlstm_decode(params, x, nh: int, eps: float, state: dict):
    """x: (B,1,d); state: {C (B,nh,hd,hd), n (B,nh,hd), m (B,nh), conv (B,3,d_in)}."""
    B, _, d = x.shape
    d_in = 2 * d
    hd = d_in // nh
    xn = rmsnorm(x, params["norm_in"], eps)
    up = xn @ params["w_up"].astype(x.dtype)
    x_in, z = jnp.split(up, 2, axis=-1)
    conv_buf = jnp.concatenate([state["conv"], x_in], axis=1)  # (B,4,d_in)
    conv_out = (conv_buf * params["conv_w"].astype(x.dtype)).sum(axis=1) \
        + params["conv_b"].astype(x.dtype)
    cx = jax.nn.silu(conv_out)                   # (B,d_in)
    q = (cx @ params["w_q"].astype(x.dtype)).reshape(B, nh, hd)
    k = (cx @ params["w_k"].astype(x.dtype)).reshape(B, nh, hd)
    v = (x_in[:, 0] @ params["w_v"].astype(x.dtype)).reshape(B, nh, hd)
    i_pre = (cx @ params["w_i"].astype(x.dtype)).astype(jnp.float32)
    f_pre = (cx @ params["w_f"].astype(x.dtype)
             + params["f_bias"].astype(x.dtype)).astype(jnp.float32)
    logf = jax.nn.log_sigmoid(f_pre)
    m_prev, C_prev, n_prev = state["m"], state["C"], state["n"]
    m_new = jnp.maximum(logf + m_prev, i_pre)
    f = jnp.exp(logf + m_prev - m_new)
    i = jnp.exp(i_pre - m_new)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    C = f[..., None, None] * C_prev + i[..., None, None] * (
        vf[..., :, None] * kf[..., None, :])
    n = f[..., None] * n_prev + i[..., None] * kf
    qf = q.astype(jnp.float32) * hd ** -0.5
    num = jnp.einsum("bhde,bhe->bhd", C, qf)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", n, qf)),
                      jnp.exp(-m_new))
    h = (num / den[..., None]).reshape(B, 1, d_in).astype(x.dtype)
    h = rmsnorm(h, params["norm_h"], eps) * jax.nn.silu(z)
    out = x + h @ params["w_down"].astype(x.dtype)
    return out, {"C": C, "n": n, "m": m_new, "conv": conv_buf[:, 1:]}


def init_mlstm_state(batch: int, d: int, nh: int, dtype=jnp.float32) -> dict:
    d_in = 2 * d
    hd = d_in // nh
    return {
        "C": jnp.zeros((batch, nh, hd, hd), jnp.float32),
        "n": jnp.zeros((batch, nh, hd), jnp.float32),
        "m": jnp.full((batch, nh), -1e30, jnp.float32),
        "conv": jnp.zeros((batch, 3, d_in), dtype),
    }


# --------------------------------------------------------------------------
# sLSTM
# --------------------------------------------------------------------------

def init_slstm(key, d: int, nh: int) -> dict:
    hd = d // nh
    ks = jax.random.split(key, 7)
    ff = int(d * 4 / 3)
    def rec(key):
        return m.dense_init(key, hd, hd * nh).reshape(hd, nh, hd).transpose(
            1, 0, 2)  # (nh, hd, hd)
    return {
        "norm_in": m.zeros((d,)),
        "w_gates": m.dense_init(ks[0], d, 4 * d),      # i,f,z,o
        "r_gates": jax.vmap(rec)(jax.random.split(ks[1], 4)),  # (4,nh,hd,hd)
        "b_gates": jnp.concatenate([m.zeros((d,)), m.ones((d,)) * 3.0,
                                    m.zeros((2 * d,))]),
        "norm_h": m.zeros((d,)),
        "w_up": m.dense_init(ks[2], d, 2 * ff),
        "w_down": m.dense_init(ks[3], ff, d),
    }


def _slstm_cell(state, gates, nh: int):
    """One sLSTM step. gates: (B, 4d) preactivations *without* recurrent part."""
    h_prev, c_prev, n_prev, m_prev = state          # each (B,nh,hd)
    B = h_prev.shape[0]
    d = h_prev.shape[1] * h_prev.shape[2]
    gi, gf, gz, go = jnp.split(gates, 4, axis=-1)
    gi = gi.reshape(B, nh, -1)
    gf = gf.reshape(B, nh, -1)
    gz = gz.reshape(B, nh, -1)
    go = go.reshape(B, nh, -1)
    logf = jax.nn.log_sigmoid(gf)
    m_new = jnp.maximum(logf + m_prev, gi)
    i = jnp.exp(gi - m_new)
    f = jnp.exp(logf + m_prev - m_new)
    c = f * c_prev + i * jnp.tanh(gz)
    n = f * n_prev + i
    h = jax.nn.sigmoid(go) * c / jnp.maximum(n, 1e-6)
    return (h, c, n, m_new)


def slstm_forward(params, x, nh: int, eps: float,
                  state: Optional[dict] = None, return_state: bool = False):
    """sLSTM block: strict sequential scan over time. x: (B,S,d)."""
    B, S, d = x.shape
    hd = d // nh
    xn = rmsnorm(x, params["norm_in"], eps)
    gates_x = xn @ params["w_gates"].astype(x.dtype) \
        + params["b_gates"].astype(x.dtype)          # (B,S,4d)
    if state is None:
        state = init_slstm_state(B, d, nh)
    st = (state["h"], state["c"], state["n"], state["m"])

    r = params["r_gates"].astype(jnp.float32)        # (4,nh,hd,hd)

    def step(carry, g_t):
        h_prev = carry[0]                            # (B,nh,hd)
        rec = jnp.einsum("bhd,ghde->bghe", h_prev, r)  # (B,4,nh,hd)
        g = g_t.astype(jnp.float32) + rec.reshape(B, 4 * d)
        new = _slstm_cell(carry, g, nh)
        return new, new[0]

    (h_f, c_f, n_f, m_f), hs = jax.lax.scan(
        step, st, jnp.moveaxis(gates_x, 1, 0))
    h = jnp.moveaxis(hs, 0, 1).reshape(B, S, d).astype(x.dtype)
    h = rmsnorm(h, params["norm_h"], eps)
    up = h @ params["w_up"].astype(x.dtype)
    a, b = jnp.split(up, 2, axis=-1)
    out = x + (jax.nn.gelu(a) * b) @ params["w_down"].astype(x.dtype)
    if return_state:
        return out, {"h": h_f, "c": c_f, "n": n_f, "m": m_f}
    return out


def slstm_decode(params, x, nh: int, eps: float, state: dict):
    B, _, d = x.shape
    xn = rmsnorm(x, params["norm_in"], eps)
    g_x = (xn[:, 0] @ params["w_gates"].astype(x.dtype)
           + params["b_gates"].astype(x.dtype))
    r = params["r_gates"].astype(jnp.float32)
    rec = jnp.einsum("bhd,ghde->bghe", state["h"], r).reshape(B, 4 * d)
    g = g_x.astype(jnp.float32) + rec
    carry = (state["h"], state["c"], state["n"], state["m"])
    h_n, c_n, n_n, m_n = _slstm_cell(carry, g, nh)
    h = h_n.reshape(B, 1, d).astype(x.dtype)
    h = rmsnorm(h, params["norm_h"], eps)
    up = h @ params["w_up"].astype(x.dtype)
    a, b = jnp.split(up, 2, axis=-1)
    out = x + (jax.nn.gelu(a) * b) @ params["w_down"].astype(x.dtype)
    return out, {"h": h_n, "c": c_n, "n": n_n, "m": m_n}


def init_slstm_state(batch: int, d: int, nh: int) -> dict:
    hd = d // nh
    z = jnp.zeros((batch, nh, hd), jnp.float32)
    return {"h": z, "c": z, "n": z,
            "m": jnp.full((batch, nh, hd), -1e30, jnp.float32)}
