"""Tiny pytree-parameter module substrate (flax is not installed).

Params are nested dicts of jnp arrays. Initializers take an explicit PRNG
key; stacked (scanned) stages are initialized with vmap over a key batch so
every layer gets independent weights while the HLO stays a single scan body.
"""
from __future__ import annotations

import math
from typing import Sequence

import jax
import jax.numpy as jnp


def dense_init(key, d_in: int, d_out: int, dtype=jnp.float32):
    """Truncated-normal fan-in init (LeCun)."""
    std = 1.0 / math.sqrt(d_in)
    return (jax.random.truncated_normal(key, -2.0, 2.0, (d_in, d_out)) * std
            ).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype=jnp.float32):
    return (jax.random.normal(key, (vocab, d)) * 0.02).astype(dtype)


def zeros(shape, dtype=jnp.float32):
    return jnp.zeros(shape, dtype)


def ones(shape, dtype=jnp.float32):
    return jnp.ones(shape, dtype)


def stack_init(key, n: int, init_fn):
    """vmap an init function over ``n`` independent keys -> stacked params."""
    keys = jax.random.split(key, n)
    return jax.vmap(init_fn)(keys)


def param_count(tree) -> int:
    return sum(x.size for x in jax.tree_util.tree_leaves(tree))


def param_bytes(tree) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree_util.tree_leaves(tree))


def cast_tree(tree, dtype):
    return jax.tree_util.tree_map(
        lambda x: x.astype(dtype) if jnp.issubdtype(x.dtype, jnp.floating) else x,
        tree)


def tree_paths(tree, prefix=()) -> Sequence:
    """Yield (path_tuple, leaf) pairs for a nested-dict pytree."""
    out = []
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.extend(tree_paths(v, prefix + (k,)))
    else:
        out.append((prefix, tree))
    return out
