"""Typed runtime events: the one schema both backends emit.

Every load-bearing runtime action is recorded as an :class:`Event`
carrying ``(t_sim, kind, instance, request, tenant, phase, dur,
payload)``.  ``t`` is always *simulated* seconds (the shared event
queue's clock); when the recorder was built with ``wall_clock=True``
(the real-engine driver) each event additionally carries ``wall`` —
wall-clock seconds since the recorder was created — so sim-vs-real
timelines are directly comparable on either axis.

Kinds (the ``payload`` column lists the load-bearing keys):

========== ============================================================
kind       meaning / payload
========== ============================================================
arrival    request entered the cluster (lane ``""``)
route      routing decision: ``policy, chosen, decision, scores``
           (per-candidate scores — residency discounts, throughput
           hints — from ``RoutingPolicy.scores``)
admit      scheduler admitted the request into the running set
iter       one engine iteration (span: ``dur`` seconds ending at ``t``);
           ``items`` is the scheduling decision tuple, plus the gauges
           ``kv_used`` / ``running`` / ``waiting``
preempt    request evicted (``reason``: memory | failure | drain)
finish     request completed (``tokens`` emitted)
kv_restore prefix-cache hit restored lower-tier KV: ``tokens, seconds,
           host_tokens, ssd_tokens``
kv_tier    cache tier move settled: ``src, dst, bytes, residency``
pd_export  prefill side handed KV off: ``target, bytes, arrive_t``
pd_admit   decode side admitted the transferred request (``parked``)
spec_step  speculative decode step: ``accepted, proposed``
scale      fleet change: ``action``: scale_out | scale_in |
           rebalance_pd | revive
fail       instance failure (``orphans``)
autoscale  autoscaler tick: ``verdict, pool, attainment, queue_depth``
========== ============================================================

This module is dependency-free on purpose: the runtime imports it at
module level without layering cycles, and consumers (export,
attribution) treat events as plain data.
"""
from __future__ import annotations

from typing import Optional

ARRIVAL = "arrival"
ROUTE = "route"
ADMIT = "admit"
ITER = "iter"
PREEMPT = "preempt"
FINISH = "finish"
KV_RESTORE = "kv_restore"
KV_TIER = "kv_tier"
PD_EXPORT = "pd_export"
PD_ADMIT = "pd_admit"
SPEC_STEP = "spec_step"
SCALE = "scale"
FAIL = "fail"
AUTOSCALE = "autoscale"

#: kinds that are request-scoped (drive the per-request waterfall)
REQUEST_KINDS = (ARRIVAL, ROUTE, ADMIT, PREEMPT, FINISH, KV_RESTORE,
                 PD_EXPORT, PD_ADMIT, SPEC_STEP)


def _jsonable(v):
    if isinstance(v, dict):
        return {k: _jsonable(x) for k, x in v.items()}
    if isinstance(v, (list, tuple)):
        return [_jsonable(x) for x in v]
    return v


class Event:
    """One recorded action.  ``key()`` is the canonical identity the
    fast==exact parity suite compares — everything except the emission
    sequence number (interleaving across instances differs between
    bulked and stepped execution) and the wall-clock stamp (which is
    real time, never reproducible)."""

    __slots__ = ("t", "kind", "inst", "req", "tenant", "phase", "dur",
                 "wall", "seq", "payload")

    def __init__(self, t: float, kind: str, inst: Optional[str] = None,
                 req: Optional[int] = None, tenant: Optional[str] = None,
                 phase: Optional[str] = None, dur: float = 0.0,
                 wall: Optional[float] = None, seq: int = 0,
                 payload: Optional[dict] = None):
        self.t = t
        self.kind = kind
        self.inst = inst
        self.req = req
        self.tenant = tenant
        self.phase = phase
        self.dur = dur
        self.wall = wall
        self.seq = seq
        self.payload = payload

    def key(self) -> tuple:
        return (self.t, self.kind, self.inst, self.req, self.tenant,
                self.phase, self.dur, self.payload)

    def to_dict(self) -> dict:
        d = {"t": self.t, "kind": self.kind}
        for f in ("inst", "req", "tenant", "phase", "wall"):
            v = getattr(self, f)
            if v is not None:
                d[f] = v
        if self.payload is not None:
            # canonical JSON form (tuples -> lists) so a save/load
            # round-trip reproduces to_dict() exactly
            d["payload"] = _jsonable(self.payload)
        if self.dur:
            d["dur"] = self.dur
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "Event":
        return cls(t=d["t"], kind=d["kind"], inst=d.get("inst"),
                   req=d.get("req"), tenant=d.get("tenant"),
                   phase=d.get("phase"), dur=d.get("dur", 0.0),
                   wall=d.get("wall"), payload=d.get("payload"))

    def __repr__(self):
        return (f"Event(t={self.t:.6f}, {self.kind!r}, inst={self.inst!r},"
                f" req={self.req!r})")
