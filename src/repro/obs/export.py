"""Chrome trace-event / Perfetto JSON export and schema validation.

``chrome_trace(recorder)`` renders the event log as a Chrome
trace-event JSON object (load it at https://ui.perfetto.dev or
``chrome://tracing``):

- pid 0 ("fleet"): one thread lane per instance.  Iterations are
  complete-slices (``ph="X"``, micro-second ``ts``/``dur``) colored by
  the first request in the batch; admits, preemptions, tier moves,
  P/D handoffs, scale/autoscale actions are instants (``ph="i"``).
- pid 0, per-instance counter tracks (``ph="C"``): ``queue_depth``,
  ``batch`` (running), ``kv_used`` blocks, and per-tier KV residency;
  plus per-tenant ``inflight`` counters.
- pid 1 ("requests"): one lane per request rendering its attribution
  waterfall (queue_wait / prefill / decode / pd_transfer /
  preempt_redo slices), capped at ``max_request_lanes``.

``validate_chrome_trace(obj)`` checks the structural contract CI
relies on: every event has a known ``ph``; slices/instants/counters
carry numeric non-negative ``ts`` (and ``dur`` for slices) plus
``pid``/``tid``; counter tracks have non-decreasing timestamps.
"""
from __future__ import annotations

import json
from typing import List, Optional

from repro.obs.events import (ADMIT, ARRIVAL, AUTOSCALE, FAIL, FINISH, ITER,
                              KV_RESTORE, KV_TIER, PD_ADMIT, PD_EXPORT,
                              PREEMPT, ROUTE, SCALE, SPEC_STEP)

#: Chrome's fixed reserved-color palette (only valid cnames render)
_CNAMES = ("thread_state_running", "thread_state_iowait",
           "thread_state_uninterruptible", "rail_response", "rail_animation",
           "rail_idle", "rail_load", "cq_build_running", "cq_build_passed",
           "cq_build_failed", "good", "bad", "terrible",
           "generic_work", "background_memory_dump", "light_memory_dump",
           "detailed_memory_dump", "vsync_highlight_color", "olive", "black")

_SEGMENT_CNAME = {"queue_wait": "rail_idle", "prefill": "rail_response",
                  "decode": "thread_state_running", "pd_transfer": "rail_load",
                  "preempt_redo": "bad", "tier_restore": "rail_animation"}

_US = 1e6


def _counter(name: str, ts: float, value, pid: int = 0, tid: int = 0) -> dict:
    return {"ph": "C", "pid": pid, "tid": tid, "name": name,
            "ts": ts, "args": {"value": value}}


def chrome_trace(recorder, max_request_lanes: int = 32) -> dict:
    """Render a recorder's event log as a Chrome trace-event dict."""
    evs = recorder.sorted_events()
    out: List[dict] = []

    # lane bookkeeping: tid 0 is the cluster lane, instances follow in
    # order of first appearance
    tids = {"": 0}

    def tid_of(inst: Optional[str]) -> int:
        lane = inst or ""
        if lane not in tids:
            tids[lane] = len(tids)
        return tids[lane]

    for ev in evs:
        ts = ev.t * _US
        tid = tid_of(ev.inst)
        p = ev.payload or {}
        args = dict(p)
        if ev.req is not None:
            args["req"] = ev.req
        if ev.tenant is not None:
            args["tenant"] = ev.tenant
        if ev.wall is not None:
            args["wall_s"] = ev.wall
        if ev.kind == ITER:
            items = p.get("items", ())
            first_req = items[0][0] if items else 0
            name = f"{ev.phase or 'iter'} b={p.get('running', len(items))}"
            args["items"] = [list(it) for it in items]
            out.append({"ph": "X", "pid": 0, "tid": tid, "name": name,
                        "cat": "iter", "ts": (ev.t - ev.dur) * _US,
                        "dur": ev.dur * _US,
                        "cname": _CNAMES[first_req % len(_CNAMES)],
                        "args": args})
            out.append(_counter(f"{ev.inst}/queue_depth", ts,
                                p.get("waiting", 0)))
            out.append(_counter(f"{ev.inst}/batch", ts, p.get("running", 0)))
            out.append(_counter(f"{ev.inst}/kv_used", ts, p.get("kv_used", 0)))
        elif ev.kind in (ADMIT, PREEMPT, KV_RESTORE, KV_TIER, PD_EXPORT,
                         PD_ADMIT, FINISH, ROUTE, SCALE, FAIL, AUTOSCALE,
                         SPEC_STEP):
            out.append({"ph": "i", "pid": 0, "tid": tid, "name": ev.kind,
                        "cat": ev.kind, "ts": ts, "s": "t", "args": args})
            if ev.kind == KV_TIER and "residency" in p:
                for tier, blocks in p["residency"].items():
                    out.append(_counter(f"{ev.inst}/kv_{tier}", ts, blocks))

    # per-tenant inflight counters (derived step function)
    inflight = {}
    for ev in evs:
        if ev.tenant is None:
            continue
        if ev.kind == ARRIVAL:
            inflight[ev.tenant] = inflight.get(ev.tenant, 0) + 1
        elif ev.kind == FINISH:
            inflight[ev.tenant] = inflight.get(ev.tenant, 0) - 1
        else:
            continue
        out.append(_counter(f"tenant/{ev.tenant}/inflight", ev.t * _US,
                            inflight[ev.tenant]))

    # request waterfall lanes (pid 1) from the attribution timelines
    from repro.obs.attribution import attribution

    class _Req:
        __slots__ = ("req_id", "arrival", "t_finish", "tenant")

    reqs = {}
    for ev in evs:
        if ev.req is None:
            continue
        r = reqs.get(ev.req)
        if r is None:
            r = reqs[ev.req] = _Req()
            r.req_id, r.arrival, r.tenant = ev.req, ev.t, ev.tenant
            r.t_finish = None
        if ev.kind == ARRIVAL:
            r.arrival = ev.t
        if r.tenant is None and ev.tenant is not None:
            r.tenant = ev.tenant
        if ev.kind == FINISH:
            r.t_finish = ev.t
    attr = attribution(list(reqs.values()), recorder)
    shown = 0
    for rid, rep in attr["requests"].items():
        if shown >= max_request_lanes:
            break
        shown += 1
        rtid = shown
        out.append({"ph": "M", "pid": 1, "tid": rtid,
                    "name": "thread_name", "args":
                    {"name": f"req {rid} ({rep['tenant']})"}})
        for t0, t1, label in rep["timeline"]:
            out.append({"ph": "X", "pid": 1, "tid": rtid, "name": label,
                        "cat": "request", "ts": t0 * _US,
                        "dur": (t1 - t0) * _US,
                        "cname": _SEGMENT_CNAME.get(label, "generic_work"),
                        "args": {"req": rid, "tenant": rep["tenant"]}})

    meta = [{"ph": "M", "pid": 0, "tid": 0, "name": "process_name",
             "args": {"name": "fleet"}},
            {"ph": "M", "pid": 1, "tid": 0, "name": "process_name",
             "args": {"name": "requests"}}]
    for lane, tid in tids.items():
        meta.append({"ph": "M", "pid": 0, "tid": tid, "name": "thread_name",
                     "args": {"name": lane or "cluster"}})
    meta.append({"ph": "M", "pid": 1, "tid": 0, "name": "thread_name",
                 "args": {"name": f"waterfalls ({shown} of "
                                  f"{len(attr['requests'])} requests)"}})
    return {"traceEvents": meta + out, "displayTimeUnit": "ms",
            "otherData": {"schema": "repro.obs/1",
                          "events": len(recorder.events),
                          "requests_total": len(attr["requests"]),
                          "requests_shown": shown}}


def write_chrome_trace(recorder, path: str,
                       max_request_lanes: int = 32) -> dict:
    trace = chrome_trace(recorder, max_request_lanes=max_request_lanes)
    with open(path, "w") as f:
        json.dump(trace, f)
    return trace


def validate_chrome_trace(obj) -> List[str]:
    """Return a list of schema violations (empty == valid)."""
    errors: List[str] = []
    if not isinstance(obj, dict) or not isinstance(
            obj.get("traceEvents"), list):
        return ["top-level object must carry a traceEvents list"]
    last_counter_ts = {}
    for i, ev in enumerate(obj["traceEvents"]):
        where = f"traceEvents[{i}]"
        if not isinstance(ev, dict) or "ph" not in ev:
            errors.append(f"{where}: missing ph")
            continue
        ph = ev["ph"]
        if ph not in ("M", "X", "i", "C", "B", "E"):
            errors.append(f"{where}: unknown ph {ph!r}")
            continue
        if ph == "M":
            continue
        for field in ("pid", "tid"):
            if not isinstance(ev.get(field), int):
                errors.append(f"{where}: {field} must be an int")
        ts = ev.get("ts")
        if not isinstance(ts, (int, float)) or ts < 0:
            errors.append(f"{where}: ts must be a non-negative number")
            continue
        if ph == "X":
            dur = ev.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                errors.append(f"{where}: X needs non-negative dur")
            if not ev.get("name"):
                errors.append(f"{where}: X needs a name")
        if ph == "C":
            name = ev.get("name")
            if not name:
                errors.append(f"{where}: C needs a name")
                continue
            if "args" not in ev or not isinstance(ev["args"], dict):
                errors.append(f"{where}: C needs an args dict")
                continue
            key = (ev.get("pid"), name)
            prev = last_counter_ts.get(key)
            if prev is not None and ts < prev:
                errors.append(f"{where}: counter {name!r} ts went backwards "
                              f"({ts} < {prev})")
            last_counter_ts[key] = ts
    return errors
