"""Per-request latency waterfalls and per-tenant bottleneck rollups.

Each finished request's end-to-end latency (``t_finish - arrival``) is
decomposed into segments that sum back to it exactly (up to float
association):

- ``queue_wait``     — arrival/requeue until the scheduler admits it
- ``prefill``        — admission until the first decode step begins
  (or until P/D export on a prefill-role instance)
- ``pd_transfer``    — P/D KV handoff in flight (export → decode admit)
- ``decode``         — decode start until finish
- ``tier_restore``   — lower-tier KV fetch charge carved out of
  ``prefill`` (bounded by it: the restore is priced into whichever
  iteration runs next on the instance, so it is an attribution of
  intent, clamped to the prefill span it logically delays)
- ``preempt_redo``   — work thrown away by preemption/failure/drain:
  the span from the (re)admission that was interrupted back to the
  preemption instant

The decomposition is a deterministic walk over the request's lifecycle
events (admit / preempt / pd_export / pd_admit) with the final
prefill/decode split anchored on iteration spans: the decode start is
the start of the first decode-phase iteration containing the request
at or after its last admission.  Requests that never produce a decode
iteration (``output_len == 1``: the single token is emitted at prefill
completion) get ``decode = 0``.
"""
from __future__ import annotations

from typing import Dict, Iterable, List, Optional, Tuple

from repro.obs.events import (ADMIT, ITER, KV_RESTORE, PD_ADMIT, PD_EXPORT,
                              PREEMPT, REQUEST_KINDS)

SEGMENTS = ("queue_wait", "prefill", "decode", "tier_restore",
            "pd_transfer", "preempt_redo")

#: slack for float round-trips when matching iteration starts
#: (``t_end - dur`` may land a hair before the admission timestamp)
_EPS = 1e-9


def _walk(req, evs: List) -> Tuple[dict, List[Tuple[float, float, str]],
                                   str, float, float]:
    """Walk one request's lifecycle events, closing segments at each
    transition.  Returns (segments, timeline, final_state,
    final_seg_start, restore_s)."""
    segs = {k: 0.0 for k in SEGMENTS}
    timeline: List[Tuple[float, float, str]] = []
    state = "queued"
    t0 = req.arrival
    restore_s = 0.0

    def close(t1: float, bucket: str) -> None:
        nonlocal t0
        if t1 > t0:
            segs[bucket] += t1 - t0
            timeline.append((t0, t1, bucket))
        t0 = t1

    for ev in evs:
        k = ev.kind
        if k == ADMIT:
            close(ev.t, "queue_wait")
            state = "active"
        elif k == PREEMPT:
            close(ev.t, "queue_wait" if state == "queued" else "preempt_redo")
            state = "queued"
        elif k == PD_EXPORT:
            close(ev.t, "prefill" if state == "active" else "preempt_redo")
            state = "transfer"
        elif k == PD_ADMIT:
            close(ev.t, "pd_transfer" if state == "transfer" else "queue_wait")
            state = "decode_active"
        elif k == KV_RESTORE:
            restore_s += (ev.payload or {}).get("seconds", 0.0)
    return segs, timeline, state, t0, restore_s


def attribution(requests: Iterable, recorder) -> dict:
    """Build ``metrics()["attribution"]`` from the event log.

    ``requests`` is the runtime's full request list; only finished
    requests (``t_finish`` set) are attributed.
    """
    by_req: Dict[int, List] = {}
    for ev in recorder.sorted_events():
        if ev.req is not None and ev.kind in REQUEST_KINDS:
            by_req.setdefault(ev.req, []).append(ev)

    finished = [r for r in requests if r.t_finish is not None]

    # first pass: walk lifecycles; remember which requests still need a
    # prefill/decode split anchored on iteration spans
    walked = {}
    need_decode_start: Dict[int, float] = {}
    for req in finished:
        segs, timeline, state, t0, restore_s = _walk(
            req, by_req.get(req.req_id, []))
        walked[req.req_id] = (req, segs, timeline, state, t0, restore_s)
        if state == "active":
            need_decode_start[req.req_id] = t0

    # second pass: one scan over iteration spans finds each pending
    # request's first decode-step start at/after its last admission
    decode_start: Dict[int, float] = {}
    if need_decode_start:
        for ev in recorder.events:
            if ev.kind != ITER:
                continue
            start = ev.t - ev.dur
            for rid, phase, _tok in (ev.payload or {}).get("items", ()):
                if phase != "decode" or rid not in need_decode_start:
                    continue
                if start >= need_decode_start[rid] - _EPS:
                    cur = decode_start.get(rid)
                    if cur is None or start < cur:
                        decode_start[rid] = start

    per_request = {}
    tenant_acc: Dict[str, dict] = {}
    for rid, (req, segs, timeline, state, t0, restore_s) in walked.items():
        tfin = req.t_finish
        if state == "decode_active":
            if tfin > t0:
                segs["decode"] += tfin - t0
                timeline.append((t0, tfin, "decode"))
        elif state == "active":
            # split the final active span; decode is the remainder so the
            # segment sum telescopes to t_finish - arrival by construction
            ds = decode_start.get(rid, tfin)
            ds = min(max(ds, t0), tfin)
            if ds > t0:
                segs["prefill"] += ds - t0
                timeline.append((t0, ds, "prefill"))
            if tfin > ds:
                segs["decode"] += tfin - ds
                timeline.append((ds, tfin, "decode"))
        else:  # queued/transfer at finish: defensive — should not happen
            if tfin > t0:
                segs["queue_wait"] += tfin - t0
                timeline.append((t0, tfin, "queue_wait"))
        carve = min(restore_s, segs["prefill"])
        if carve > 0.0:
            segs["prefill"] -= carve
            segs["tier_restore"] += carve
        total = tfin - req.arrival
        bottleneck = max(SEGMENTS, key=lambda k: segs[k])
        per_request[rid] = {"tenant": req.tenant, "total_s": total,
                            "segments": segs, "bottleneck": bottleneck,
                            "timeline": timeline}
        acc = tenant_acc.setdefault(req.tenant, {
            "requests": 0, "sum": {k: 0.0 for k in SEGMENTS},
            "bottlenecks": {}})
        acc["requests"] += 1
        for k in SEGMENTS:
            acc["sum"][k] += segs[k]
        acc["bottlenecks"][bottleneck] = \
            acc["bottlenecks"].get(bottleneck, 0) + 1

    tenants = {}
    for tenant, acc in sorted(tenant_acc.items()):
        n = acc["requests"]
        mean = {k: acc["sum"][k] / n for k in SEGMENTS}
        tenants[tenant] = {
            "requests": n,
            "mean_segments": mean,
            "dominant": max(SEGMENTS, key=lambda k: mean[k]),
            "bottleneck_counts": acc["bottlenecks"],
        }
    return {"segments": list(SEGMENTS),
            "requests": per_request,
            "tenants": tenants}
