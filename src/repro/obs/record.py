"""Event recorder: the in-memory sink the runtime emits into.

The runtime holds ``obs = None`` when tracing is disabled — every call
site is guarded with ``if obs is not None`` so the disabled path costs
one attribute load per *action*, never per token.  When enabled, the
recorder is a flat append-only list of :class:`~repro.obs.events.Event`
plus derived views:

- ``lanes()`` / ``streams()`` — per-instance event streams.  Streams
  are the canonical parity surface: decode fast-forward synthesizes
  per-step events in the same order as exact stepping *within each
  lane*, while the global interleaving across instances may differ
  (bulked vs stepped execution visits instants in a different order).
- ``series(interval)`` — simulated-time-series gauges sampled on a
  fixed sim-time cadence.  Sampling is *derived* from the event log,
  never scheduled on the event queue — scheduling sampler events would
  perturb ``sim_events`` and fast-forward barriers.  A grid point's
  value is the state after all events with ``t <= grid_t``, which makes
  the sampling order-independent and therefore fast-forward-exact.
- ``save()/load()`` — raw JSON event log (one dict per event), the
  input format for ``python -m repro.obs export``.
"""
from __future__ import annotations

import json
import time
from typing import Dict, List, Optional

from repro.obs.events import ARRIVAL, FINISH, ITER, Event


class EventRecorder:
    """Append-only event sink.

    ``wall_clock=True`` (real-engine driver) stamps each event with
    wall-clock seconds since the recorder was created, alongside the
    simulated timestamp.
    """

    def __init__(self, wall_clock: bool = False):
        self.wall_clock = bool(wall_clock)
        self.events: List[Event] = []
        self._t0 = time.perf_counter()
        self._seq = 0

    # -- emission ----------------------------------------------------------
    def emit(self, t: float, kind: str, inst: Optional[str] = None,
             req: Optional[int] = None, tenant: Optional[str] = None,
             phase: Optional[str] = None, dur: float = 0.0,
             payload: Optional[dict] = None) -> None:
        wall = (time.perf_counter() - self._t0) if self.wall_clock else None
        self._seq += 1
        self.events.append(Event(t, kind, inst=inst, req=req, tenant=tenant,
                                 phase=phase, dur=dur, wall=wall,
                                 seq=self._seq, payload=payload))

    def clear(self) -> None:
        self.events = []
        self._seq = 0
        self._t0 = time.perf_counter()

    # -- views -------------------------------------------------------------
    def sorted_events(self) -> List[Event]:
        """Events in global sim-time order; within-lane emission order is
        preserved for equal timestamps (``seq`` is monotone per lane)."""
        return sorted(self.events, key=lambda e: (e.t, e.seq))

    def lanes(self) -> Dict[str, List[Event]]:
        """Per-instance event streams in emission order.  Cluster-level
        events (arrival, route, scale, autoscale) land in lane ``""``."""
        out: Dict[str, List[Event]] = {}
        for ev in self.events:
            out.setdefault(ev.inst or "", []).append(ev)
        return out

    def streams(self) -> Dict[str, List[tuple]]:
        """Canonical per-lane identity: what fast==exact parity compares.
        Drops the sequence number and wall stamp (see ``Event.key``)."""
        return {lane: [ev.key() for ev in evs]
                for lane, evs in self.lanes().items()}

    def series(self, interval: float) -> dict:
        """Sample gauges on a fixed simulated-time cadence.

        Returns ``{"interval", "t", "instances": {name: {"kv_used",
        "running", "queue_depth"}}, "tenants": {tenant: inflight}}``
        where every gauge list is aligned with the ``t`` grid.
        """
        if interval <= 0:
            raise ValueError("interval must be > 0")
        evs = self.sorted_events()
        t_end = evs[-1].t if evs else 0.0
        n_pts = int(t_end / interval) + 1
        grid = [i * interval for i in range(n_pts)]

        inst_tracks: Dict[str, Dict[str, List[float]]] = {}
        tenant_tracks: Dict[str, List[int]] = {}
        inst_state: Dict[str, Dict[str, float]] = {}
        tenant_state: Dict[str, int] = {}

        i = 0
        for gi, gt in enumerate(grid):
            while i < len(evs) and evs[i].t <= gt:
                ev = evs[i]
                i += 1
                if ev.kind == ITER and ev.inst is not None:
                    p = ev.payload or {}
                    inst_state[ev.inst] = {
                        "kv_used": p.get("kv_used", 0),
                        "running": p.get("running", 0),
                        "queue_depth": p.get("waiting", 0),
                    }
                elif ev.kind == ARRIVAL and ev.tenant is not None:
                    tenant_state[ev.tenant] = tenant_state.get(ev.tenant, 0) + 1
                elif ev.kind == FINISH and ev.tenant is not None:
                    tenant_state[ev.tenant] = tenant_state.get(ev.tenant, 0) - 1
            for name, st in inst_state.items():
                tr = inst_tracks.get(name)
                if tr is None:
                    # zero-fill grid points before this lane's first event
                    tr = inst_tracks[name] = {"kv_used": [0] * gi,
                                              "running": [0] * gi,
                                              "queue_depth": [0] * gi}
                for k, v in st.items():
                    tr[k].append(v)
            for tenant, v in tenant_state.items():
                tr = tenant_tracks.get(tenant)
                if tr is None:
                    tr = tenant_tracks[tenant] = [0] * gi
                tr.append(v)
        return {"interval": interval, "t": grid,
                "instances": inst_tracks, "tenants": tenant_tracks}

    # -- persistence -------------------------------------------------------
    def save(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump({"schema": "repro.obs/1",
                       "wall_clock": self.wall_clock,
                       "events": [ev.to_dict() for ev in self.events]}, f)

    @classmethod
    def load(cls, path: str) -> "EventRecorder":
        with open(path) as f:
            d = json.load(f)
        rec = cls(wall_clock=d.get("wall_clock", False))
        for i, evd in enumerate(d.get("events", [])):
            ev = Event.from_dict(evd)
            ev.seq = i + 1
            rec.events.append(ev)
        rec._seq = len(rec.events)
        return rec
