"""CLI: turn raw event logs into Perfetto traces, or validate traces.

    python -m repro.obs export --events events.json --out trace.json
    python -m repro.obs validate trace.json
    python -m repro.obs series --events events.json --interval 0.5
"""
from __future__ import annotations

import argparse
import json
import sys

from repro.obs.export import chrome_trace, validate_chrome_trace
from repro.obs.record import EventRecorder


def main(argv=None) -> int:
    p = argparse.ArgumentParser(prog="python -m repro.obs",
                                description=__doc__.splitlines()[0])
    sub = p.add_subparsers(dest="cmd", required=True)

    pe = sub.add_parser("export", help="raw event log -> Chrome trace JSON")
    pe.add_argument("--events", required=True,
                    help="raw event log (EventRecorder.save / --events)")
    pe.add_argument("--out", required=True, help="output trace JSON path")
    pe.add_argument("--requests", type=int, default=32,
                    help="max per-request waterfall lanes (default 32)")

    pv = sub.add_parser("validate",
                        help="check a trace against the Chrome schema")
    pv.add_argument("trace", help="trace JSON path")

    ps = sub.add_parser("series", help="print simulated-time-series gauges")
    ps.add_argument("--events", required=True)
    ps.add_argument("--interval", type=float, default=1.0,
                    help="sim-time sampling cadence in seconds")

    args = p.parse_args(argv)
    if args.cmd == "export":
        rec = EventRecorder.load(args.events)
        trace = chrome_trace(rec, max_request_lanes=args.requests)
        errors = validate_chrome_trace(trace)
        if errors:
            for e in errors:
                print(f"error: {e}", file=sys.stderr)
            return 1
        with open(args.out, "w") as f:
            json.dump(trace, f)
        print(f"wrote {args.out}: {len(trace['traceEvents'])} trace events "
              f"from {len(rec.events)} runtime events")
        return 0
    if args.cmd == "validate":
        with open(args.trace) as f:
            obj = json.load(f)
        errors = validate_chrome_trace(obj)
        for e in errors:
            print(f"error: {e}", file=sys.stderr)
        if not errors:
            print(f"{args.trace}: ok "
                  f"({len(obj.get('traceEvents', []))} events)")
        return 1 if errors else 0
    if args.cmd == "series":
        rec = EventRecorder.load(args.events)
        json.dump(rec.series(args.interval), sys.stdout)
        print()
        return 0
    return 2


if __name__ == "__main__":
    raise SystemExit(main())
