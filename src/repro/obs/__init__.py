"""Unified runtime event tracing: Perfetto timelines, per-request
waterfalls, and simulated-time series on both backends.

Enable by passing an :class:`EventRecorder` (or an output path) to
``repro.core.simulate(..., trace=...)``, ``Cluster(...,
recorder=...)``, or ``ServeDriver(..., recorder=...)``.  Disabled is
the default and costs nothing: the runtime's ``obs`` attributes stay
``None`` and every emission site is guarded.
"""
from repro.obs.attribution import SEGMENTS, attribution
from repro.obs.events import Event
from repro.obs.export import (chrome_trace, validate_chrome_trace,
                              write_chrome_trace)
from repro.obs.record import EventRecorder

__all__ = ["Event", "EventRecorder", "attribution", "SEGMENTS",
           "chrome_trace", "write_chrome_trace", "validate_chrome_trace"]
