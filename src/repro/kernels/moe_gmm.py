"""Pallas TPU grouped expert matmul (MegaBlocks-style, dense-padded groups).

Computes out[e] = x[e] @ w[e] for E experts with per-expert valid row counts
(``group_sizes``): rows past a group's size produce zeros and — on real
TPU — their tiles are skipped via @pl.when (compute proportional to actual
load, which is what makes top-k MoE cheap). Grid (E, nC): one (expert,
row-block) tile per program; d and f stay resident in VMEM per expert.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gmm_kernel(x_ref, w_ref, gs_ref, o_ref, *, bc: int):
    # x_ref: (1, bc, d); w_ref: (1, d, f); gs_ref: (1,); o_ref: (1, bc, f)
    ci = pl.program_id(1)
    size = gs_ref[0]
    start = ci * bc

    @pl.when(start < size)
    def _():
        x = x_ref[0].astype(jnp.float32)
        w = w_ref[0].astype(jnp.float32)
        out = jax.lax.dot_general(x, w, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)
        rows = start + jax.lax.broadcasted_iota(jnp.int32, out.shape, 0)
        out = jnp.where(rows < size, out, 0.0)
        o_ref[0] = out.astype(o_ref.dtype)

    @pl.when(start >= size)
    def _():
        o_ref[0] = jnp.zeros(o_ref.shape[1:], o_ref.dtype)


def moe_gmm_pallas(x, w, group_sizes, *, bc: int = 128,
                   interpret: bool = True):
    """x: (E,C,d); w: (E,d,f); group_sizes: (E,) -> (E,C,f)."""
    E, C, d = x.shape
    f = w.shape[-1]
    bc = min(bc, C)
    if C % bc:
        # expert capacity is workload-derived and rarely a multiple of the
        # tile size; shrink to the largest divisor rather than rejecting
        bc = next(b for b in range(bc, 0, -1) if C % b == 0)
    grid = (E, C // bc)
    kernel = functools.partial(_gmm_kernel, bc=bc)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bc, d), lambda e, c: (e, c, 0)),
            pl.BlockSpec((1, d, f), lambda e, c: (e, 0, 0)),
            pl.BlockSpec((1,), lambda e, c: (e,)),
        ],
        out_specs=pl.BlockSpec((1, bc, f), lambda e, c: (e, c, 0)),
        out_shape=jax.ShapeDtypeStruct((E, C, f), x.dtype),
        interpret=interpret,
    )(x, w, group_sizes)
