"""Pallas TPU flash attention (prefill, causal, GQA, lengths + window).

Grid (B, H, nQ): each program owns one (batch, head, query-block) tile with
the query block in VMEM; K/V for the matching KV head stream through VMEM.
The causal schedule skips KV blocks beyond the diagonal via the fori upper
bound — the exact constant-work schedule the pure-XLA path can only
approximate (see models/layers.folded_causal_attention).

The kernel carries the serving engine's full masking surface: per-sequence
``lengths`` (ragged batches) and a sliding ``window`` (local-attention
layers), matching ``models/flash.flash_attention`` semantics exactly, so
the pallas backend never has to fall back to reference for windowed layers.

MXU alignment: bq/bkv multiples of 128 in production (tests sweep smaller
shapes in interpret mode, where alignment is not enforced).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30
#: "no window" sentinel: larger than any context length we ever serve
NO_WINDOW = 1 << 30


def _flash_kernel(q_ref, k_ref, v_ref, len_ref, win_ref, o_ref, *, bq: int,
                  bkv: int, causal: bool):
    # q_ref: (1, bq, 1, dh); k_ref/v_ref: (1, S, 1, dh); o_ref like q_ref;
    # len_ref: (1,) this sequence's length; win_ref: (1,) sliding window
    qi = pl.program_id(2)
    dh = q_ref.shape[-1]
    S = k_ref.shape[1]
    q = q_ref[0, :, 0, :].astype(jnp.float32) * dh ** -0.5
    length = len_ref[0]
    window = win_ref[0]
    nkv = S // bkv
    if causal:
        upper = (qi * bq + bq + bkv - 1) // bkv
    else:
        upper = nkv

    def body(j, carry):
        acc, m, l = carry
        # NB: raw python ints in pl.load index tuples crash this jax
        # version's interpret-mode discharge; use unit dslices + squeeze.
        k = pl.load(k_ref, (pl.dslice(0, 1), pl.dslice(j * bkv, bkv),
                            pl.dslice(0, 1), slice(None)))[0, :, 0, :] \
            .astype(jnp.float32)
        v = pl.load(v_ref, (pl.dslice(0, 1), pl.dslice(j * bkv, bkv),
                            pl.dslice(0, 1), slice(None)))[0, :, 0, :] \
            .astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 0)
        kv_pos = j * bkv + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 1)
        mask = (kv_pos < length) & (q_pos - kv_pos < window)
        if causal:
            mask = mask & (q_pos >= kv_pos)
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        acc = acc * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        return acc, m_new, l_new

    acc0 = jnp.zeros((bq, dh), jnp.float32)
    m0 = jnp.full((bq,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((bq,), jnp.float32)
    acc, m, l = jax.lax.fori_loop(0, upper, body, (acc0, m0, l0))
    o_ref[0, :, 0, :] = (acc / jnp.maximum(l, 1e-20)[:, None]
                         ).astype(o_ref.dtype)


def flash_attention_pallas(q, k, v, *, lengths=None, window=None,
                           bq: int = 128, bkv: int = 128,
                           causal: bool = True, interpret: bool = True):
    """q: (B,S,H,dh); k/v: (B,S,KV,dh) -> (B,S,H,dh).

    ``lengths``: (B,) int32, KV positions >= length are masked (output rows
    at q_pos >= length are garbage, as in the pure-JAX twin).  ``window``:
    scalar (python int or traced), masks q_pos - kv_pos >= window.
    """
    B, S, H, dh = q.shape
    KV = k.shape[2]
    G = H // KV
    bq = min(bq, S)
    bkv = min(bkv, S)
    assert S % bq == 0 and S % bkv == 0
    nq = S // bq
    if lengths is None:
        lengths = jnp.full((B,), S, jnp.int32)
    lengths = lengths.astype(jnp.int32)
    if window is None:
        window = NO_WINDOW
    win = jnp.reshape(jnp.asarray(window, jnp.int32), (1,))
    grid = (B, H, nq)
    kernel = functools.partial(_flash_kernel, bq=bq, bkv=bkv, causal=causal)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, 1, dh), lambda b, h, i: (b, i, h, 0)),
            pl.BlockSpec((1, S, 1, dh), lambda b, h, i: (b, 0, h // G, 0)),
            pl.BlockSpec((1, S, 1, dh), lambda b, h, i: (b, 0, h // G, 0)),
            pl.BlockSpec((1,), lambda b, h, i: (b,)),
            pl.BlockSpec((1,), lambda b, h, i: (0,)),
        ],
        out_specs=pl.BlockSpec((1, bq, 1, dh), lambda b, h, i: (b, i, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, S, H, dh), q.dtype),
        interpret=interpret,
    )(q, k, v, lengths, win)
