from repro.kernels.ops import (KERNEL_BACKENDS, flash_attention, moe_gmm,
                               paged_attention, resolve_backend)

__all__ = ["KERNEL_BACKENDS", "flash_attention", "moe_gmm",
           "paged_attention", "resolve_backend"]
