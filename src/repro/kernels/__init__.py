from repro.kernels.ops import flash_attention, moe_gmm, paged_attention

__all__ = ["flash_attention", "moe_gmm", "paged_attention"]
