"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NO_WINDOW = 1 << 30


def flash_attention_ref(q, k, v, *, causal: bool = True, lengths=None,
                        window=None):
    """q: (B,S,H,dh); k/v: (B,S,KV,dh) -> (B,S,H,dh)."""
    B, S, H, dh = q.shape
    KV = k.shape[2]
    G = H // KV
    qr = q.reshape(B, S, KV, G, dh) * dh ** -0.5
    s = jnp.einsum("bqkgd,bjkd->bkgqj", qr.astype(jnp.float32),
                   k.astype(jnp.float32))
    q_pos = jnp.arange(S)[:, None]
    kv_pos = jnp.arange(S)[None, :]
    mask = jnp.ones((S, S), bool)
    if causal:
        mask = mask & (q_pos >= kv_pos)
    if window is not None:
        mask = mask & (q_pos - kv_pos < window)
    mask = jnp.broadcast_to(mask[None], (B, S, S))
    if lengths is not None:
        mask = mask & (kv_pos[None] < lengths[:, None, None])
    s = jnp.where(mask[:, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqj,bjkd->bqkgd", p, v.astype(jnp.float32))
    return o.reshape(B, S, H, dh).astype(q.dtype)


def paged_attention_ref(q, k_pages, v_pages, block_table, lengths, *,
                        page_size: int, start=None, window=None):
    """q: (B,H,dh) decode or (B,S,H,dh) extend (with ``start``);
    k/v_pages: (P,ps,KV,dh); block_table: (B,maxp) int32; lengths: (B,)."""
    squeeze = q.ndim == 3
    if squeeze:
        q = q[:, None]
    B, S, H, dh = q.shape
    P, ps, KV, _ = k_pages.shape
    G = H // KV
    maxp = block_table.shape[1]
    if start is None:
        start = jnp.maximum(lengths - 1, 0)
    kg = k_pages[block_table.reshape(-1)].reshape(B, maxp * ps, KV, dh)
    vg = v_pages[block_table.reshape(-1)].reshape(B, maxp * ps, KV, dh)
    qr = q.reshape(B, S, KV, G, dh).astype(jnp.float32) * dh ** -0.5
    s = jnp.einsum("bskgd,bjkd->bskgj", qr, kg.astype(jnp.float32))
    q_pos = start[:, None] + jnp.arange(S)[None, :]          # (B, S)
    kv_pos = jnp.arange(maxp * ps)
    win = NO_WINDOW if window is None else window
    mask = (kv_pos[None, None] <= q_pos[..., None]) \
        & (kv_pos[None, None] < lengths[:, None, None]) \
        & (q_pos[..., None] - kv_pos[None, None] < win)      # (B, S, J)
    s = jnp.where(mask[:, :, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bskgj,bjkd->bskgd", p, vg.astype(jnp.float32))
    o = o.reshape(B, S, H, dh).astype(q.dtype)
    return o[:, 0] if squeeze else o


def moe_gmm_ref(x, w, group_sizes):
    """Grouped matmul: x: (E,C,d); w: (E,d,f); rows >= group_sizes[e] give 0."""
    E, C, d = x.shape
    out = jnp.einsum("ecd,edf->ecf", x.astype(jnp.float32),
                     w.astype(jnp.float32))
    mask = jnp.arange(C)[None, :] < group_sizes[:, None]
    return (out * mask[..., None]).astype(x.dtype)
