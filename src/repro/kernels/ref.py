"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, *, causal: bool = True):
    """q: (B,S,H,dh); k/v: (B,S,KV,dh) -> (B,S,H,dh)."""
    B, S, H, dh = q.shape
    KV = k.shape[2]
    G = H // KV
    qr = q.reshape(B, S, KV, G, dh) * dh ** -0.5
    s = jnp.einsum("bqkgd,bjkd->bkgqj", qr.astype(jnp.float32),
                   k.astype(jnp.float32))
    if causal:
        mask = jnp.tril(jnp.ones((S, S), bool))
        s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqj,bjkd->bqkgd", p, v.astype(jnp.float32))
    return o.reshape(B, S, H, dh).astype(q.dtype)


def paged_attention_ref(q, k_pages, v_pages, block_table, lengths, *,
                        page_size: int):
    """q: (B,H,dh); k/v_pages: (P,ps,KV,dh); block_table: (B,maxp) int32;
    lengths: (B,) -> (B,H,dh)."""
    B, H, dh = q.shape
    P, ps, KV, _ = k_pages.shape
    G = H // KV
    maxp = block_table.shape[1]
    kg = k_pages[block_table.reshape(-1)].reshape(B, maxp * ps, KV, dh)
    vg = v_pages[block_table.reshape(-1)].reshape(B, maxp * ps, KV, dh)
    qr = q.reshape(B, KV, G, dh).astype(jnp.float32) * dh ** -0.5
    s = jnp.einsum("bkgd,bjkd->bkgj", qr, kg.astype(jnp.float32))
    pos = jnp.arange(maxp * ps)
    mask = pos[None] < lengths[:, None]
    s = jnp.where(mask[:, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgj,bjkd->bkgd", p, vg.astype(jnp.float32))
    return o.reshape(B, H, dh).astype(q.dtype)


def moe_gmm_ref(x, w, group_sizes):
    """Grouped matmul: x: (E,C,d); w: (E,d,f); rows >= group_sizes[e] give 0."""
    E, C, d = x.shape
    out = jnp.einsum("ecd,edf->ecf", x.astype(jnp.float32),
                     w.astype(jnp.float32))
    mask = jnp.arange(C)[None, :] < group_sizes[:, None]
    return (out * mask[..., None]).astype(x.dtype)
