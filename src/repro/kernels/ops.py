"""jit'd public wrappers for the Pallas kernels + kernel-backend selection.

``interpret`` defaults to True on CPU hosts (semantics validation through
the Pallas interpreter) and False on real accelerators (TPU *and* GPU —
compiled Pallas; keying on TPU alone would silently run a GPU in the
interpreter).  ``REPRO_PALLAS_INTERPRET=0|1`` overrides either way, and
every wrapper takes an explicit ``interpret=`` for per-call control.

``resolve_backend`` maps the engine-facing choice (``"reference" |
"pallas" | "auto"``) to a concrete ``(backend, interpret)`` pair:
``auto`` is compiled Pallas on TPU/GPU, interpret-mode Pallas on CPU
(validation), and the pure-JAX reference anywhere else.

Interfaces mirror the pure-JAX twins in repro.models.
"""
from __future__ import annotations

import functools
import os
from typing import Optional, Tuple

import jax

from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.moe_gmm import moe_gmm_pallas
from repro.kernels.paged_attention import paged_attention_pallas

KERNEL_BACKENDS = ("reference", "pallas", "auto")


def _env_interpret() -> Optional[bool]:
    """REPRO_PALLAS_INTERPRET escape hatch: force interpret on/off."""
    v = os.environ.get("REPRO_PALLAS_INTERPRET")
    if v is None:
        return None
    return v.strip().lower() not in ("0", "false", "no", "off")


def _default_interpret() -> bool:
    env = _env_interpret()
    if env is not None:
        return env
    # compiled Pallas on real accelerators (TPU and GPU); the interpreter
    # everywhere else.  A bare `!= "tpu"` here would leave a CUDA backend
    # silently interpreting every kernel.
    return jax.default_backend() not in ("tpu", "gpu")


def resolve_backend(choice: str) -> Tuple[str, bool]:
    """Engine kernel choice -> (backend, interpret).

    "reference"  pure-JAX twins (layers.decode_attention & co).
    "pallas"     Pallas kernels, interpret resolved by platform/env.
    "auto"       pallas compiled on TPU/GPU, pallas interpreted on CPU
                 (so CI validates the production path), reference on
                 anything unrecognized.
    """
    if choice not in KERNEL_BACKENDS:
        raise ValueError(
            f"kernels={choice!r}: expected one of {KERNEL_BACKENDS}")
    if choice == "reference":
        return "reference", False
    if choice == "pallas" or jax.default_backend() in ("tpu", "gpu", "cpu"):
        return "pallas", _default_interpret()
    return "reference", False


@functools.partial(jax.jit,
                   static_argnames=("bq", "bkv", "causal", "interpret"))
def flash_attention(q, k, v, lengths=None, window=None, *, bq: int = 128,
                    bkv: int = 128, causal: bool = True,
                    interpret: Optional[bool] = None):
    """q: (B,S,H,dh); k/v: (B,S,KV,dh) -> (B,S,H,dh).

    ``lengths`` (B,) masks KV positions >= length per sequence; ``window``
    (scalar, python int or traced) masks q_pos - kv_pos >= window
    (sliding-window attention).  Both default to no-ops.
    """
    if window is not None and not causal:
        raise ValueError("flash_attention: window requires causal=True "
                         "(sliding windows are causal by definition)")
    if interpret is None:
        interpret = _default_interpret()
    return flash_attention_pallas(q, k, v, lengths=lengths, window=window,
                                  bq=bq, bkv=bkv, causal=causal,
                                  interpret=interpret)


@functools.partial(jax.jit, static_argnames=("page_size", "interpret"))
def paged_attention(q, k_pages, v_pages, block_table, lengths, *,
                    page_size: int, start=None, window=None,
                    interpret: Optional[bool] = None):
    """Decode: q (B,H,dh), one query per sequence at position length-1.
    Extend: q (B,S,H,dh) with ``start`` (B,), queries at start..start+S-1.
    k_pages/v_pages: (P,ps,KV,dh); block_table: (B,maxp) int32;
    ``window`` as in flash_attention."""
    if interpret is None:
        interpret = _default_interpret()
    return paged_attention_pallas(q, k_pages, v_pages, block_table, lengths,
                                  page_size=page_size, start=start,
                                  window=window, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("bc", "interpret"))
def moe_gmm(x, w, group_sizes, *, bc: int = 128,
            interpret: Optional[bool] = None):
    if interpret is None:
        interpret = _default_interpret()
    return moe_gmm_pallas(x, w, group_sizes, bc=bc, interpret=interpret)
