"""jit'd public wrappers for the Pallas kernels.

``interpret`` defaults to True on CPU (validation) and False on TPU
(production). Interfaces mirror the pure-JAX twins in repro.models.
"""
from __future__ import annotations

import functools

import jax

from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.moe_gmm import moe_gmm_pallas
from repro.kernels.paged_attention import paged_attention_pallas


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("bq", "bkv", "causal"))
def flash_attention(q, k, v, *, bq: int = 128, bkv: int = 128,
                    causal: bool = True):
    return flash_attention_pallas(q, k, v, bq=bq, bkv=bkv, causal=causal,
                                  interpret=_default_interpret())


@functools.partial(jax.jit, static_argnames=("page_size",))
def paged_attention(q, k_pages, v_pages, block_table, lengths, *,
                    page_size: int):
    return paged_attention_pallas(q, k_pages, v_pages, block_table, lengths,
                                  page_size=page_size,
                                  interpret=_default_interpret())


@functools.partial(jax.jit, static_argnames=("bc",))
def moe_gmm(x, w, group_sizes, *, bc: int = 128):
    return moe_gmm_pallas(x, w, group_sizes, bc=bc,
                          interpret=_default_interpret())
