"""Pallas TPU paged attention (block-table indirection, decode + extend).

The serving engine's KV lives in fixed-size pages (PagedAttention [9]); a
per-sequence block table maps logical positions to pages.  Grid (B, KV):
each program owns one (sequence, kv-head) pair, walking its block table
with online softmax.  Page loads are dynamic gathers (on real TPU these are
HBM->VMEM DMAs; ``interpret=True`` validates semantics on CPU).

One kernel serves both serving phases:

* **decode** — q is (B, H, dh): one query per sequence at its last
  position (``lengths - 1``), mask ``kv_pos < length`` (+ window), the
  exact semantics of ``models/layers.decode_attention``.
* **extend** — q is (B, S, H, dh) with per-sequence ``start``: queries sit
  at ``start + s``, mask ``kv_pos <= q_pos & kv_pos < length`` (+ window),
  the exact semantics of ``models/layers.extend_attention`` — chunked
  prefill continuations and speculative verify run through this path with
  zero KV copies (the pages are shared, the table is the view).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30
NO_WINDOW = 1 << 30


def paged_attention_pallas(q, k_pages, v_pages, block_table, lengths, *,
                           page_size: int, start=None, window=None,
                           interpret: bool = True):
    """q: (B,H,dh) decode or (B,S,H,dh) extend; k_pages/v_pages:
    (P,ps,KV,dh); block_table: (B,maxp) int32; lengths: (B,).
    ``start``: (B,) first query position (extend; decode infers
    ``lengths - 1``); ``window``: scalar sliding window."""
    squeeze = q.ndim == 3
    if squeeze:
        q = q[:, None]          # (B, 1, H, dh)
    B, S, H, dh = q.shape
    P, ps, KV, _ = k_pages.shape
    assert ps == page_size
    G = H // KV
    maxp = block_table.shape[1]
    lengths = lengths.astype(jnp.int32)
    if start is None:
        if not squeeze:
            raise ValueError(
                "paged_attention: multi-query (extend) calls must pass "
                "start= (the first query position per sequence)")
        start = jnp.maximum(lengths - 1, 0)
    start = start.astype(jnp.int32)
    if window is None:
        window = NO_WINDOW
    win = jnp.reshape(jnp.asarray(window, jnp.int32), (1,))
    qr = q.reshape(B, S, KV, G, dh)
    grid = (B, KV)
    kernel = functools.partial(_paged_kernel, page_size=page_size)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, S, 1, G, dh), lambda b, kv: (b, 0, kv, 0, 0)),
            pl.BlockSpec((P, ps, 1, dh), lambda b, kv: (0, 0, kv, 0)),
            pl.BlockSpec((P, ps, 1, dh), lambda b, kv: (0, 0, kv, 0)),
            pl.BlockSpec((1, maxp), lambda b, kv: (b, 0)),
            pl.BlockSpec((1,), lambda b, kv: (b,)),
            pl.BlockSpec((1,), lambda b, kv: (b,)),
            pl.BlockSpec((1,), lambda b, kv: (0,)),
        ],
        out_specs=pl.BlockSpec((1, S, 1, G, dh),
                               lambda b, kv: (b, 0, kv, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, S, KV, G, dh), q.dtype),
        interpret=interpret,
    )(qr, k_pages, v_pages, block_table, start, lengths, win)
    out = out.reshape(B, S, H, dh)
    return out[:, 0] if squeeze else out


def _paged_kernel(q_ref, kp_ref, vp_ref, table_ref, start_ref, len_ref,
                  win_ref, o_ref, *, page_size: int):
    """One (sequence, kv-head): S*G query rows x this sequence's pages."""
    S, G, dh = q_ref.shape[1], q_ref.shape[3], q_ref.shape[4]
    R = S * G
    q = q_ref[0, :, 0, :, :].astype(jnp.float32).reshape(R, dh) * dh ** -0.5
    start = start_ref[0]
    length = len_ref[0]
    window = win_ref[0]
    # cap at the table's reach: an unscheduled-but-full slot arrives with
    # length == capacity + 1 and must not walk past the last table entry
    n_used = jnp.minimum((length + page_size - 1) // page_size,
                         table_ref.shape[1])
    # row r of the flattened (S*G) query block sits at position start + r//G
    q_pos = start + jax.lax.broadcasted_iota(jnp.int32, (R, page_size),
                                             0) // G

    def body(j, carry):
        acc, m, l = carry
        page = table_ref[0, j]
        # unit dslice for the kv-head dim: raw ints in pl.load index tuples
        # crash this jax version's interpret-mode discharge
        k = pl.load(kp_ref, (page, slice(None), pl.dslice(0, 1),
                             slice(None)))[:, 0, :].astype(jnp.float32)
        v = pl.load(vp_ref, (page, slice(None), pl.dslice(0, 1),
                             slice(None)))[:, 0, :].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        kv_pos = j * page_size + jax.lax.broadcasted_iota(
            jnp.int32, (R, page_size), 1)
        mask = (kv_pos <= q_pos) & (kv_pos < length) \
            & (q_pos - kv_pos < window)
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        acc = acc * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        return acc, m_new, l_new

    acc0 = jnp.zeros((R, dh), jnp.float32)
    m0 = jnp.full((R,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((R,), jnp.float32)
    acc, m, l = jax.lax.fori_loop(0, n_used, body, (acc0, m0, l0))
    o_ref[0, :, 0, :, :] = (acc / jnp.maximum(l, 1e-20)[:, None]
                            ).reshape(S, G, dh).astype(o_ref.dtype)
