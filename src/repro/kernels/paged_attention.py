"""Pallas TPU paged attention (decode with block-table indirection).

The serving engine's KV lives in fixed-size pages (PagedAttention [9]); a
per-sequence block table maps logical positions to pages. Grid (B, KV):
each program owns one (sequence, kv-head) pair, walking its block table
with online softmax. Page loads are dynamic gathers (on real TPU these are
HBM->VMEM DMAs; ``interpret=True`` validates semantics on CPU).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def paged_attention_pallas(q, k_pages, v_pages, block_table, lengths, *,
                           page_size: int, interpret: bool = True):
    """q: (B,H,dh); k_pages/v_pages: (P,ps,KV,dh);
    block_table: (B,maxp) int32; lengths: (B,) -> (B,H,dh)."""
    B, H, dh = q.shape
    P, ps, KV, _ = k_pages.shape
    assert ps == page_size
    G = H // KV
    maxp = block_table.shape[1]
    qr = q.reshape(B, KV, G, dh)
    grid = (B, KV)
    kernel = functools.partial(_paged_two_kernel, page_size=page_size)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, G, dh), lambda b, kv: (b, kv, 0, 0)),
            pl.BlockSpec((P, ps, 1, dh), lambda b, kv: (0, 0, kv, 0)),
            pl.BlockSpec((P, ps, 1, dh), lambda b, kv: (0, 0, kv, 0)),
            pl.BlockSpec((1, maxp), lambda b, kv: (b, 0)),
            pl.BlockSpec((1,), lambda b, kv: (b,)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, dh), lambda b, kv: (b, kv, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, KV, G, dh), q.dtype),
        interpret=interpret,
    )(qr, k_pages, v_pages, block_table, lengths)
    return out.reshape(B, H, dh)


def _paged_two_kernel(q_ref, kp_ref, vp_ref, table_ref, len_ref, o_ref, *,
                      page_size: int):
    """Like _paged_kernel but with separate K/V page pools."""
    G, dh = q_ref.shape[2], q_ref.shape[3]
    q = q_ref[0, 0].astype(jnp.float32) * dh ** -0.5
    length = len_ref[0]
    n_used = (length + page_size - 1) // page_size

    def body(j, carry):
        acc, m, l = carry
        page = table_ref[0, j]
        # unit dslice for the kv-head dim: raw ints in pl.load index tuples
        # crash this jax version's interpret-mode discharge
        k = pl.load(kp_ref, (page, slice(None), pl.dslice(0, 1),
                             slice(None)))[:, 0, :].astype(jnp.float32)
        v = pl.load(vp_ref, (page, slice(None), pl.dslice(0, 1),
                             slice(None)))[:, 0, :].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        pos = j * page_size + jax.lax.broadcasted_iota(
            jnp.int32, (G, page_size), 1)
        s = jnp.where(pos < length, s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        acc = acc * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        return acc, m_new, l_new

    acc0 = jnp.zeros((G, dh), jnp.float32)
    m0 = jnp.full((G,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((G,), jnp.float32)
    acc, m, l = jax.lax.fori_loop(0, n_used, body, (acc0, m0, l0))
    o_ref[0, 0] = (acc / jnp.maximum(l, 1e-20)[:, None]).astype(o_ref.dtype)
