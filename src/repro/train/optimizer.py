"""AdamW + schedules + global-norm clipping (optax is not installed).

States mirror the param pytree (same sharding specs apply leaf-for-leaf),
so the optimizer is transparently SPMD-sharded by pjit.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    mu: Any
    nu: Any


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: Callable[[jax.Array], jax.Array] | float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0

    def init(self, params) -> AdamWState:
        z = lambda p: jnp.zeros_like(p)
        return AdamWState(step=jnp.zeros((), jnp.int32),
                          mu=jax.tree_util.tree_map(z, params),
                          nu=jax.tree_util.tree_map(z, params))

    def _lr(self, step):
        if callable(self.lr):
            return self.lr(step)
        return jnp.asarray(self.lr, jnp.float32)

    def update(self, grads, state: AdamWState, params):
        """Returns (new_params, new_state, metrics)."""
        gnorm = global_norm(grads)
        scale = jnp.minimum(1.0, self.clip_norm / jnp.maximum(gnorm, 1e-9))
        step = state.step + 1
        b1, b2 = self.b1, self.b2
        bc1 = 1.0 - b1 ** step.astype(jnp.float32)
        bc2 = 1.0 - b2 ** step.astype(jnp.float32)
        lr = self._lr(step)

        def upd(g, mu, nu, p):
            g = g.astype(jnp.float32) * scale
            mu = b1 * mu + (1 - b1) * g
            nu = b2 * nu + (1 - b2) * jnp.square(g)
            mhat = mu / bc1
            nhat = nu / bc2
            delta = mhat / (jnp.sqrt(nhat) + self.eps)
            if p.ndim >= 2:   # decoupled weight decay on matrices only
                delta = delta + self.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), mu, nu

        flat = jax.tree_util.tree_map(upd, grads, state.mu, state.nu, params)
        new_params = jax.tree_util.tree_map(lambda t: t[0], flat,
                                            is_leaf=lambda t: isinstance(t, tuple))
        new_mu = jax.tree_util.tree_map(lambda t: t[1], flat,
                                        is_leaf=lambda t: isinstance(t, tuple))
        new_nu = jax.tree_util.tree_map(lambda t: t[2], flat,
                                        is_leaf=lambda t: isinstance(t, tuple))
        return new_params, AdamWState(step, new_mu, new_nu), {
            "grad_norm": gnorm, "lr": lr}


def global_norm(tree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def cosine_schedule(peak_lr: float, warmup: int, total: int,
                    floor: float = 0.1):
    def sched(step):
        step = step.astype(jnp.float32)
        warm = peak_lr * step / max(warmup, 1)
        frac = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = peak_lr * (floor + (1 - floor) * 0.5 *
                         (1 + jnp.cos(jnp.pi * frac)))
        return jnp.where(step < warmup, warm, cos)
    return sched
