from repro.train.optimizer import AdamW, cosine_schedule, global_norm
from repro.train.train_step import (TrainState, TrainStepConfig, init_state,
                                    make_train_step)

__all__ = ["AdamW", "cosine_schedule", "global_norm", "TrainState",
           "TrainStepConfig", "init_state", "make_train_step"]
