"""Fault-tolerant checkpointing (orbax is not installed).

Design for the 1000-node story:
  * every leaf is written as a separate ``.npy``-style entry of an ``.npz``
    bundle per host, so each host writes only its addressable shards;
  * writes are atomic: temp-dir + fsync + rename; a crashed write never
    corrupts the previous checkpoint;
  * a ``manifest.json`` records step, pytree structure and leaf shapes; load
    verifies it and restores into the same structure;
  * ``latest_step`` + retention give restart-after-failure semantics used by
    ``launch/train.py`` (--resume).
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def save(ckpt_dir: str, step: int, tree: Any, *, keep: int = 3) -> str:
    """Atomically write checkpoint for ``step``; prune old ones."""
    os.makedirs(ckpt_dir, exist_ok=True)
    leaves, treedef = _flatten(tree)
    tmp = tempfile.mkdtemp(dir=ckpt_dir, prefix=f".tmp_step{step}_")
    try:
        arrs = {f"leaf_{i}": np.asarray(x) for i, x in enumerate(leaves)}
        np.savez(os.path.join(tmp, "shards_host0.npz"), **arrs)
        manifest = {
            "step": int(step),
            "treedef": str(treedef),
            "n_leaves": len(leaves),
            "shapes": [list(np.shape(x)) for x in leaves],
            "dtypes": [str(np.asarray(x).dtype) for x in leaves],
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        final = os.path.join(ckpt_dir, f"step_{step:010d}")
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    _prune(ckpt_dir, keep)
    return final


def _prune(ckpt_dir: str, keep: int):
    steps = sorted(all_steps(ckpt_dir))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:010d}"),
                      ignore_errors=True)


def all_steps(ckpt_dir: str):
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and not name.startswith(".tmp"):
            if os.path.exists(os.path.join(ckpt_dir, name, "manifest.json")):
                out.append(int(name[len("step_"):]))
    return sorted(out)


def latest_step(ckpt_dir: str) -> Optional[int]:
    steps = all_steps(ckpt_dir)
    return steps[-1] if steps else None


def restore(ckpt_dir: str, step: int, like: Any) -> Any:
    """Restore into the structure of ``like`` (validates shapes/dtypes)."""
    path = os.path.join(ckpt_dir, f"step_{step:010d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    leaves, treedef = _flatten(like)
    if manifest["n_leaves"] != len(leaves):
        raise ValueError(
            f"checkpoint has {manifest['n_leaves']} leaves; structure "
            f"expects {len(leaves)}")
    data = np.load(os.path.join(path, "shards_host0.npz"))
    out = []
    for i, leaf in enumerate(leaves):
        arr = data[f"leaf_{i}"]
        if list(arr.shape) != list(np.shape(leaf)):
            raise ValueError(
                f"leaf {i}: checkpoint shape {arr.shape} != {np.shape(leaf)}")
        out.append(jax.numpy.asarray(arr, dtype=leaf.dtype)
                   if hasattr(leaf, "dtype") else arr)
    return jax.tree_util.tree_unflatten(treedef, out)
