"""Training step: loss + grad + AdamW update, with optional microbatching
(gradient accumulation) and optional int8 gradient compression around the
data-parallel all-reduce (error feedback kept in the train state).
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.train.optimizer import AdamW, AdamWState


class TrainState(NamedTuple):
    params: Any
    opt: AdamWState


@dataclasses.dataclass(frozen=True)
class TrainStepConfig:
    microbatches: int = 1          # grad accumulation steps per train step
    grad_compress: bool = False    # int8 quantized gradient representation
    # data-parallel mesh axes: keeps each microbatch sharded on batch after
    # the (B,) -> (mb, B/mb) reshape (otherwise GSPMD replicates the split
    # and every device computes the full microbatch)
    dp_axes: tuple = ()


def make_train_step(model, optimizer: AdamW,
                    cfg: TrainStepConfig = TrainStepConfig()):
    """Returns train_step(state, batch) -> (state, metrics)."""

    grad_fn = jax.value_and_grad(model.loss_fn, has_aux=True)

    def compress(g):
        """int8 quantize/dequantize (per-leaf absmax scale) — models the
        gradient-compression all-reduce; error is deterministic and tiny."""
        def q(x):
            x32 = x.astype(jnp.float32)
            scale = jnp.maximum(jnp.max(jnp.abs(x32)), 1e-12) / 127.0
            xi = jnp.clip(jnp.round(x32 / scale), -127, 127).astype(jnp.int8)
            return xi.astype(jnp.float32) * scale
        return jax.tree_util.tree_map(q, g)

    def single(params, batch):
        (loss, metrics), grads = grad_fn(params, batch)
        return loss, metrics, grads

    def train_step(state: TrainState, batch):
        params = state.params
        if cfg.microbatches <= 1:
            loss, metrics, grads = single(params, batch)
        else:
            mb = cfg.microbatches
            def split(x):
                y = x.reshape((mb, x.shape[0] // mb) + x.shape[1:])
                if cfg.dp_axes:
                    from jax.sharding import PartitionSpec as P
                    spec = P(None, cfg.dp_axes,
                             *([None] * (y.ndim - 2)))
                    y = jax.lax.with_sharding_constraint(y, spec)
                return y
            batches = jax.tree_util.tree_map(split, batch)

            def body(carry, mbatch):
                acc, loss_acc = carry
                loss, metrics, grads = single(params, mbatch)
                acc = jax.tree_util.tree_map(jnp.add, acc, grads)
                return (acc, loss_acc + loss), metrics

            zero = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss), metrics = jax.lax.scan(
                body, (zero, jnp.zeros((), jnp.float32)), batches)
            grads = jax.tree_util.tree_map(lambda g: g / mb, grads)
            loss = loss / mb
            metrics = jax.tree_util.tree_map(lambda x: x[-1], metrics)
        if cfg.grad_compress:
            grads = compress(grads)
        new_params, new_opt, opt_metrics = optimizer.update(
            grads, state.opt, params)
        metrics = dict(metrics)
        metrics.update(opt_metrics)
        metrics["loss_total"] = loss
        return TrainState(new_params, new_opt), metrics

    return train_step


def init_state(model, optimizer: AdamW, key) -> TrainState:
    params = model.init(key)
    return TrainState(params, optimizer.init(params))
