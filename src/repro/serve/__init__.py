from repro.serve.driver import DriverCfg, ServeDriver
from repro.serve.engine import RealRadixCache, ServingEngine
from repro.serve.sampler import greedy, temperature

__all__ = ["DriverCfg", "ServeDriver", "RealRadixCache",
           "ServingEngine", "greedy", "temperature"]
