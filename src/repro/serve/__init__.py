from repro.serve.driver import DriverCfg, ServeDriver
from repro.serve.engine import RealRadixCache, ServingEngine, SpecDecodeCfg
from repro.serve.sampler import accept_length, greedy, temperature

__all__ = ["DriverCfg", "ServeDriver", "RealRadixCache",
           "ServingEngine", "SpecDecodeCfg", "accept_length", "greedy",
           "temperature"]
