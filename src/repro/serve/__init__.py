from repro.serve.driver import DriverCfg, ServeDriver
from repro.serve.engine import EngineRequest, RealRadixCache, ServingEngine
from repro.serve.sampler import greedy, temperature

__all__ = ["DriverCfg", "ServeDriver", "EngineRequest", "RealRadixCache",
           "ServingEngine", "greedy", "temperature"]
