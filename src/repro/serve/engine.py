"""Real JAX execution substrate: jitted model calls over a slot KV cache.

``ServingEngine`` is deliberately *mechanism only*: it owns the params, the
slot-based KV cache, the jitted ``prefill``/``extend``/``decode`` closures,
the per-bucket slot copy plumbing (export/restore/subcache), and an
optional *real* radix prefix store (actual KV tensors keyed by token
prefix).  It makes no serving decisions and runs no loop of its own — the
unified runtime (``repro.runtime``) schedules every iteration and drives
this engine through ``JaxBackend.execute``.

The legacy one-request-at-a-time ``step()`` loop (and its private
queue/handoff state) was retired once the profiler started probing through
the runtime: ``repro.profiler.runtime_profiler`` measures the exact
``JaxBackend`` code paths production serving runs.

Hybrid emulation (paper §III, adapted to this container): compute is REAL —
every batch runs the actual jitted model on the local device and is
wall-clock timed; time is VIRTUAL — the runtime's shared event queue
advances by the measured latencies, so multi-instance configurations behave
as if instances ran in parallel even though this container has one CPU.
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ArchConfig
from repro.models import Model


@dataclasses.dataclass
class SpecDecodeCfg:
    """Speculative decoding for a real engine: draft model + verification.

    ``draft`` is the proposer's architecture (its own params, its own slot
    KV cache — built as a nested mechanism-only ``ServingEngine``); the
    target verifies all ``k`` proposals in one batched ``verify`` call
    (an ``extend`` that returns every position's logits).  With
    ``acceptance`` unset the engine is **greedy-lossless**: the emitted
    sequence equals vanilla greedy decode token-for-token (accepted
    prefix + the target's own bonus/correction token).  With an
    ``AcceptanceTrace`` attached, the acceptance *decision* is replayed
    from the trace instead (the spec-decode analogue of forced MoE
    routing) so sim/real parity can be pinned; ``recorder`` taps
    (position, accepted) pairs for artifact capture
    (``repro.spec.record``).
    """
    draft: ArchConfig
    k: int = 4
    acceptance: Optional[Any] = None      # repro.spec.AcceptanceTrace
    draft_seed: int = 1
    draft_params: Optional[Any] = None
    recorder: Optional[Any] = None        # repro.spec.AcceptanceRecorder


def _bucket(n: int, lo: int = 16) -> int:
    b = lo
    while b < n:
        b *= 2
    return b


#: hotter tiers have lower rank; demotion only moves entries downward
_TIER_RANK = {"device": 0, "host": 1, "ssd": 2}


def _payload_to_host(payload: dict) -> dict:
    """Device -> host copy of a store entry (metadata keys pass through)."""
    return {k: v if k.startswith("_")
            else jax.tree_util.tree_map(np.asarray, v)
            for k, v in payload.items()}


def _payload_nbytes(payload: dict) -> float:
    data = {k: v for k, v in payload.items() if not k.startswith("_")}
    return float(sum(getattr(leaf, "nbytes", 0)
                     for leaf in jax.tree_util.tree_leaves(data)))


class RealRadixCache:
    """Real prefix cache: token-prefix -> stored KV slices, tier-tagged.

    Entries live on one of three tiers mirroring the runtime radix tree's
    block accounting: ``device`` (jax arrays, accelerator-resident — the
    insert default), ``host`` (numpy), ``ssd`` (pickled to a spill file;
    a matched stub is only read back through :meth:`resolve`, so the disk
    I/O lands inside the caller's wall-timed region).  Tier moves are
    driven by the runtime's eviction decisions via
    ``JaxBackend.on_tier_transfer`` — this class is mechanism only.
    Moves are entry-granular: demoting one radix block demotes every
    stored entry containing it (the payloads are whole-prefix slices,
    not per-block pages)."""

    def __init__(self, block: int = 16, max_entries: int = 64):
        self.block = block
        self.store: "OrderedDict[tuple, dict]" = OrderedDict()
        self.tier: Dict[tuple, str] = {}
        self.max_entries = max_entries
        self.hits = 0
        self.misses = 0
        self._ssd_dir: Optional[str] = None
        self._ssd_seq = 0

    def match(self, tokens,
              limit: Optional[int] = None) -> Tuple[int, Optional[dict]]:
        """Longest stored prefix of ``tokens`` (optionally capped at
        ``limit`` tokens, e.g. the runtime's radix-tree match length)."""
        best_len, best = 0, None
        n = (len(tokens) // self.block) * self.block
        if limit is not None:
            n = min(n, (limit // self.block) * self.block)
        for l in range(n, 0, -self.block):
            key = tuple(tokens[:l])
            if key in self.store:
                self.store.move_to_end(key)
                best_len, best = l, self.store[key]
                break
        if best is None:
            self.misses += 1
        else:
            self.hits += 1
        return best_len, best

    def insert(self, tokens, kv_slices: dict, tier: str = "device"):
        l = (len(tokens) // self.block) * self.block
        if l == 0:
            return
        key = tuple(tokens[:l])
        if key in self.store:
            return
        self.store[key] = kv_slices
        self.tier[key] = tier
        while len(self.store) > self.max_entries:
            old, payload = self.store.popitem(last=False)
            self.tier.pop(old, None)
            self._unlink(payload)

    # ---- tier moves (entry-granular; see class docstring) ----
    def _covering(self, prefix) -> list:
        p = tuple(prefix)
        n = len(p)
        return [k for k in list(self.store) if len(k) >= n and k[:n] == p]

    def demote(self, prefix, dst: str) -> float:
        """Move entries containing ``prefix`` down to ``dst`` ("host" |
        "ssd"); returns bytes actually moved."""
        moved = 0.0
        for k in self._covering(prefix):
            if _TIER_RANK.get(self.tier.get(k, "host"), 1) \
                    >= _TIER_RANK[dst]:
                continue
            host = _payload_to_host(self.resolve(self.store[k]))
            moved += _payload_nbytes(host)
            self._unlink(self.store[k])
            self.store[k] = host if dst == "host" else self._to_ssd(host)
            self.tier[k] = dst
        return moved

    def promote(self, prefix) -> float:
        """Bring entries containing ``prefix`` back to device arrays."""
        moved = 0.0
        for k in self._covering(prefix):
            if self.tier.get(k, "device") == "device":
                continue
            host = self.resolve(self.store[k])
            moved += _payload_nbytes(host)
            dev = {kk: v if kk.startswith("_")
                   else jax.tree_util.tree_map(jax.device_put, v)
                   for kk, v in host.items()}
            self._unlink(self.store[k])
            self.store[k] = dev
            self.tier[k] = "device"
        return moved

    def drop(self, prefix):
        for k in self._covering(prefix):
            payload = self.store.pop(k)
            self.tier.pop(k, None)
            self._unlink(payload)

    def resolve(self, payload: dict) -> dict:
        """Materialize a matched payload: SSD stubs are unpickled here, so
        call this inside the region whose wall time should absorb the
        disk read (``JaxBackend._prefill_chunk`` does)."""
        if isinstance(payload, dict) and "_ssd" in payload:
            import pickle
            with open(payload["_ssd"], "rb") as f:
                return pickle.load(f)
        return payload

    def residency(self) -> Dict[str, int]:
        out = {"device": 0, "host": 0, "ssd": 0}
        for k in self.store:
            out[self.tier.get(k, "device")] += 1
        return out

    def _to_ssd(self, host_payload: dict) -> dict:
        import os
        import pickle
        import tempfile
        if self._ssd_dir is None:
            self._ssd_dir = tempfile.mkdtemp(prefix="kv-ssd-")
        self._ssd_seq += 1
        path = os.path.join(self._ssd_dir, f"kv{self._ssd_seq}.pkl")
        with open(path, "wb") as f:
            pickle.dump(host_payload, f, protocol=pickle.HIGHEST_PROTOCOL)
        return {"_ssd": path,
                "_length": host_payload.get("_length"),
                "_length_bucket": host_payload.get("_length_bucket")}

    @staticmethod
    def _unlink(payload):
        path = payload.get("_ssd") if isinstance(payload, dict) else None
        if path:
            import os
            try:
                os.remove(path)
            except OSError:
                pass


class ServingEngine:
    """One instance's execution substrate (slots, jits, KV plumbing).

    Driven exclusively by ``repro.runtime.backends.jax_engine.JaxBackend``;
    see the module docstring for the division of labor.

    ``tp > 1`` makes the engine a tensor-parallel group: params and the
    slot KV cache are sharded over an explicit (data=1, model=tp) mesh
    (``repro.launch.mesh.make_engine_mesh``) using the production sharding
    rules (``repro.launch.sharding``), and every jit — prefill, extend,
    decode, and the slot-copy plumbing — runs SPMD over that mesh with
    GSPMD inserting the collectives.  On CPU this is validated by forcing
    host device count (``XLA_FLAGS=--xla_force_host_platform_device_count``).
    """

    def __init__(self, cfg: ArchConfig, params=None, *, max_batch: int = 8,
                 max_len: int = 512, prefix_cache: bool = False,
                 role: str = "unified", name: str = "engine0", seed: int = 0,
                 tp: int = 1, routing=None, spec: Optional[SpecDecodeCfg]
                 = None):
        self.cfg = cfg
        self.name = name
        self.role = role
        self.tp = max(int(tp), 1)
        self.mesh = None
        # MoE routing injection must happen here, before any jit traces:
        # the jitted closures capture the model's routing hook, so a hook
        # installed later would be silently ignored by cached traces.
        # ``routing`` is either an ExpertRoutingTrace (replayed verbatim —
        # forced assignment — and remembered so JaxBackend accounts
        # expert-load metrics from the same table) or a raw hook callable
        # (bias / recording; see repro.moe.hooks).
        self.routing_trace = None
        hook = None
        if routing is not None:
            if callable(routing):
                hook = routing
            else:
                from repro.moe.hooks import make_replay_hook
                from repro.moe.trace import moe_layer_count
                routing.check_model(cfg)
                if routing.n_layers != moe_layer_count(cfg):
                    raise ValueError(
                        f"routing trace {routing.model!r} has "
                        f"{routing.n_layers} MoE layers but {cfg.name!r} "
                        f"has {moe_layer_count(cfg)}")
                self.routing_trace = routing
                hook = make_replay_hook(routing)
        # kernel backend: resolve "auto" against the platform; pallas
        # serves attention-only archs at tp=1 (its decode path is the
        # paged slot-KV layout, which has no sharded variant yet) —
        # "auto" falls back to reference elsewhere, "pallas" is loud
        from repro.configs.base import ATTN_MLP, ATTN_MOE
        from repro.kernels import resolve_backend
        backend, interpret = resolve_backend(cfg.kernels)
        if backend == "pallas":
            bad = [st.kind for st in cfg.stages
                   if st.kind not in (ATTN_MLP, ATTN_MOE)]
            if bad or self.tp > 1:
                why = f"tp={self.tp}" if self.tp > 1 else \
                    f"non-attention stages {bad}"
                if cfg.kernels == "pallas":
                    raise ValueError(
                        f"kernels='pallas' does not support {why} on "
                        f"{cfg.name!r}; use kernels='auto' to fall back")
                backend, interpret = "reference", False
        self.kernel_backend = backend
        self.pallas_interpret = interpret
        self.paged = backend == "pallas"
        self.page_size = 64
        self.model = Model(cfg, remat=False, routing_hook=hook,
                           kernel_backend=backend,
                           pallas_interpret=interpret, paged=self.paged,
                           page_size=self.page_size)
        self.params = params if params is not None else self.model.init(
            jax.random.PRNGKey(seed))
        self.max_batch = max_batch
        self.max_len = max_len
        self.cache = self.model.init_cache(max_batch, max_len)
        if self.paged:
            # page allocator: free-list over the shared pool, a host
            # numpy mirror of the device block table, and per-slot
            # allocation counts.  The last pool index is the scratch
            # page — never allocated, absorbs every masked garbage write.
            self._maxp, self._n_pages = self.model.page_geometry(
                max_batch, max_len)
            self._scratch = self._n_pages - 1
            self._page_free = list(range(self._n_pages - 1))
            self._table_np = np.full((max_batch, self._maxp),
                                     self._scratch, np.int32)
            self._slot_pages = [0] * max_batch
        if self.tp > 1:
            self._shard_over_mesh()
        self.slot_free = list(range(max_batch))
        self.radix = RealRadixCache() if prefix_cache else None
        self._jit_decode = jax.jit(self.model.decode)
        self._jit_prefill = jax.jit(self.model.prefill,
                                    static_argnames=())
        self._jit_extend = jax.jit(self.model.extend)
        self._tokens_buf = np.zeros((max_batch, 1), np.int32)
        # speculative decoding: a nested mechanism-only draft engine
        # (same slot geometry, so draft slot i mirrors target slot i) and
        # the target-side batched verification jit.  The draft engine is
        # plain (tp=1, no prefix cache, no spec of its own); JaxBackend
        # orchestrates the propose/verify/rollback steps.
        self.spec = spec
        self.draft = None
        self._jit_verify = None
        if spec is not None:
            if routing is not None:
                raise ValueError(
                    "speculative decoding and trace-driven MoE routing "
                    "cannot be combined on one engine (draft tokens that "
                    "fail verification have no expert-load semantics)")
            if spec.k < 1:
                raise ValueError(f"spec.k must be >= 1, got {spec.k}")
            if spec.draft.vocab != cfg.vocab:
                raise ValueError(
                    f"draft {spec.draft.name!r} has vocab "
                    f"{spec.draft.vocab} but target {cfg.name!r} has "
                    f"{cfg.vocab}; draft/target token ids must line up")
            if spec.acceptance is not None:
                spec.acceptance.validate().check_k(spec.k)
            self.draft = ServingEngine(
                spec.draft, params=spec.draft_params, max_batch=max_batch,
                max_len=max_len, name=f"{name}.draft",
                seed=spec.draft_seed)
            self._jit_verify = jax.jit(self.model.verify)

    def _shard_over_mesh(self):
        """Lay params + slot cache out over the (data=1, model=tp) mesh.

        Uses the same PartitionSpec rules as the production launcher
        (params: column/row TP; KV: heads or head_dim on the model axis),
        post-passed by ``fit_to_mesh`` so dims that do not divide the tp
        degree are replicated explicitly.  The jits then pick the committed
        shardings up from their inputs — no per-jit in_shardings needed.
        """
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.launch import sharding as shd
        from repro.launch.mesh import make_engine_mesh
        self.mesh = make_engine_mesh(self.tp)

        def place(tree, spec_tree):
            fitted = shd.fit_to_mesh(spec_tree, tree, self.mesh)
            shardings = jax.tree_util.tree_map(
                lambda s: NamedSharding(self.mesh, s), fitted,
                is_leaf=lambda x: isinstance(x, P))
            return jax.device_put(tree, shardings)

        self.params = place(
            self.params, shd.param_pspecs(self.params, model_size=self.tp))
        self.cache = place(
            self.cache, shd.cache_pspecs(self.cache, ("data",),
                                         self.max_batch,
                                         model_size=self.tp))

    def warmup(self, buckets=(16, 32, 64, 128, 256)):
        """Compile prefill/extend/decode at every bucket so measured
        iteration latencies are steady-state (compile time excluded).
        ``JaxBackend.warmup`` extends this with chunked-prefill extend
        buckets and slot export/restore jits."""
        for P in buckets:
            if P >= self.max_len:
                continue
            pad = jnp.zeros((1, P), jnp.int32)
            lengths = jnp.asarray([P], jnp.int32)
            jax.block_until_ready(
                self._jit_prefill(self.params, pad, lengths=lengths))
            if self.radix is not None:
                sub = self._slot_subcache(0, 16)
                try:
                    jax.block_until_ready(self._jit_extend(
                        self.params, sub, pad,
                        jnp.asarray([P], jnp.int32)))
                except NotImplementedError:
                    pass
        jax.block_until_ready(self._jit_decode(
            self.params, self.cache, jnp.asarray(self._tokens_buf)))

    # ---- jitted slot/cache plumbing ----
    # eager per-op dispatch costs ~ms on CPU; these helpers are jitted per
    # bucket size with cache donation so slot copies stay O(slice).
    def _get_jit(self, kind: str, key):
        jits = getattr(self, "_slot_jits", None)
        if jits is None:
            jits = self._slot_jits = {}
        return jits.get((kind, key))

    def _put_jit(self, kind: str, key, fn):
        self._slot_jits[(kind, key)] = fn
        return fn

    # ---- paged-KV allocator (no-ops on the contiguous layout) ----
    def ensure_capacity(self, slot: int, length: int):
        """Grow ``slot``'s page allocation to cover ``length`` tokens.
        Called by JaxBackend before any write that lands past the current
        allocation (decode at the old length, spec verify's window,
        chunked-prefill extends); free-list capacity is exact — every slot
        can hold its full ``maxp`` pages simultaneously."""
        if not self.paged:
            return
        need = min(-(-length // self.page_size), self._maxp)
        have = self._slot_pages[slot]
        if need <= have:
            return
        for j in range(have, need):
            self._table_np[slot, j] = self._page_free.pop()
        self._slot_pages[slot] = need
        self._push_table()

    def _push_table(self):
        self.cache["block_table"] = jnp.asarray(self._table_np)

    def _free_pages(self, slot: int):
        if not self.paged or not self._slot_pages[slot]:
            return
        for j in range(self._slot_pages[slot]):
            self._page_free.append(int(self._table_np[slot, j]))
            self._table_np[slot, j] = self._scratch
        self._slot_pages[slot] = 0
        self._push_table()

    def _release_slot(self, slot: int):
        if slot not in self.slot_free:
            self.slot_free.append(slot)
        # zero the slot length
        self.cache["lengths"] = self.cache["lengths"].at[slot].set(0)
        self._free_pages(slot)

    def _write_slot_from_prefill(self, slot: int, cache1, n: int):
        """Copy a (B=1) prefill cache into slot ``slot`` of the big cache."""
        P = None
        for leaf in jax.tree_util.tree_leaves(cache1):
            if leaf.ndim >= 3 and leaf.shape[1] == 1:
                P = leaf.shape[2]
                break
        if self.paged:
            # prefill itself ran contiguous (flash over the chunk); the
            # engine owns the page layout, so scatter the (B=1) cache
            # through the slot's freshly-allocated table row.  Pad-tail
            # positions past the allocation route to the scratch page.
            self.ensure_capacity(slot, min(P, self.max_len))
            fn = self._get_jit("write_prefill_paged", P)
            if fn is None:
                ps, maxp, scratch = self.page_size, self._maxp, self._scratch

                def impl(cache, cache1, slot, n):
                    row = cache["block_table"][slot]
                    pos = jnp.arange(P)
                    pidx = pos // ps
                    page = row[jnp.minimum(pidx, maxp - 1)]
                    page = jnp.where(pidx < maxp, page, scratch)
                    off = pos % ps
                    out = dict(cache)
                    for key in cache:
                        if key in ("lengths", "block_table"):
                            continue
                        out[key] = {
                            "k_pages": cache[key]["k_pages"]
                            .at[:, page, off].set(cache1[key]["k"][:, 0]),
                            "v_pages": cache[key]["v_pages"]
                            .at[:, page, off].set(cache1[key]["v"][:, 0]),
                        }
                    out["lengths"] = cache["lengths"].at[slot].set(n)
                    return out
                fn = self._put_jit("write_prefill_paged", P, jax.jit(
                    impl, donate_argnums=(0,), static_argnums=(2,)))
            self.cache = fn(self.cache, cache1, slot, n)
            return
        fn = self._get_jit("write_prefill", P)
        if fn is None:
            def impl(cache, cache1, slot, n):
                def write(big, small):
                    if big.ndim >= 2 and small.shape[1] == 1:
                        if big.ndim >= 3 and small.ndim >= 3 \
                                and small.shape[2] <= big.shape[2] \
                                and big.shape[2] == self.max_len:
                            pad_len = small.shape[2]
                            return big.at[:, slot, :pad_len].set(small[:, 0])
                        return big.at[:, slot].set(small[:, 0])
                    return big
                out = dict(cache)
                for key in cache:
                    if key == "lengths":
                        continue
                    out[key] = jax.tree_util.tree_map(
                        write, cache[key], cache1[key])
                out["lengths"] = cache["lengths"].at[slot].set(n)
                return out
            fn = self._put_jit("write_prefill", P, jax.jit(
                impl, donate_argnums=(0,), static_argnums=(2,)))
        self.cache = fn(self.cache, cache1, slot, n)

    def _slot_subcache(self, slot: int, length: int):
        """A (B=1) view of one slot (full max_len buffers, real length)."""
        if self.paged:
            # zero-copy: the shared pools ARE the storage; the one-row
            # table is the view.  ``extend`` on this subcache scatters
            # straight into the slot's pages.
            fn = self._get_jit("subcache_paged", None)
            if fn is None:
                def impl(cache, slot, length):
                    sub = {}
                    for key in cache:
                        if key == "lengths":
                            sub[key] = jnp.full((1,), length, jnp.int32)
                        elif key == "block_table":
                            sub[key] = cache[key][slot: slot + 1]
                        else:
                            sub[key] = cache[key]
                    return sub
                fn = self._put_jit("subcache_paged", None,
                                   jax.jit(impl, static_argnums=(1,)))
            return fn(self.cache, slot, length)
        fn = self._get_jit("subcache", None)
        if fn is None:
            def impl(cache, slot, length):
                def take(big):
                    return big[:, slot: slot + 1] if big.ndim >= 2 else big
                sub = {}
                for key in cache:
                    if key == "lengths":
                        sub[key] = jnp.full((1,), length, jnp.int32)
                    else:
                        sub[key] = jax.tree_util.tree_map(take, cache[key])
                return sub
            fn = self._put_jit("subcache", None,
                               jax.jit(impl, static_argnums=(1,)))
        return fn(self.cache, slot, length)

    def _write_slot(self, slot: int, sub_cache, n: int):
        if self.paged:
            # the subcache's pools already hold the extend's writes
            # (shared storage): adopt them wholesale — pure pass-through,
            # jax forwards unmodified outputs without a copy — and bump
            # the slot length.  No donation: warmup writes back an
            # untouched subcache whose pools alias the live cache.
            fn = self._get_jit("write_slot_paged", None)
            if fn is None:
                def impl(cache, sub, slot, n):
                    out = dict(cache)
                    for key in cache:
                        if key in ("lengths", "block_table"):
                            continue
                        out[key] = sub[key]
                    out["lengths"] = cache["lengths"].at[slot].set(n)
                    return out
                fn = self._put_jit("write_slot_paged", None, jax.jit(
                    impl, static_argnums=(2,)))
            self.cache = fn(self.cache, sub_cache, slot, n)
            return
        fn = self._get_jit("write_slot", None)
        if fn is None:
            def impl(cache, sub, slot, n):
                def write(big, small):
                    return big.at[:, slot: slot + 1].set(small) \
                        if big.ndim >= 2 else big
                out = dict(cache)
                for key in cache:
                    if key == "lengths":
                        continue
                    out[key] = jax.tree_util.tree_map(
                        write, cache[key], sub[key])
                out["lengths"] = cache["lengths"].at[slot].set(n)
                return out
            fn = self._put_jit("write_slot", None, jax.jit(
                impl, donate_argnums=(0,), static_argnums=(2,)))
        self.cache = fn(self.cache, sub_cache, slot, n)

    def _export_slot(self, slot: int, length: int,
                     to_host: bool = True) -> dict:
        """Copy a slot's KV out (prefix cache / P/D).  Device-side gather
        is jitted per bucketed length; ``to_host=True`` adds the final
        np.asarray host copy, ``to_host=False`` keeps the gathered jax
        arrays device-resident (the prefix store's hot tier)."""
        blen = _bucket(length)
        blen = min(blen, self.max_len)
        if self.paged:
            # normalize to the contiguous ("k"/"v") payload so prefix
            # store entries and P/D handoffs interoperate across layouts
            fn = self._get_jit("export_paged", blen)
            if fn is None:
                ps, maxp = self.page_size, self._maxp
                npg = min(-(-blen // ps), maxp)

                def impl(cache, slot):
                    pages = cache["block_table"][slot, :npg]
                    out = {}
                    for key in cache:
                        if key in ("lengths", "block_table"):
                            continue
                        kp = cache[key]["k_pages"][:, pages]
                        vp = cache[key]["v_pages"][:, pages]
                        L = kp.shape[0]
                        out[key] = {
                            "k": kp.reshape((L, npg * ps) + kp.shape[3:])
                            [:, :blen],
                            "v": vp.reshape((L, npg * ps) + vp.shape[3:])
                            [:, :blen]}
                    return out
                fn = self._put_jit("export_paged", blen,
                                   jax.jit(impl, static_argnums=(1,)))
            dev = fn(self.cache, slot)
            out = jax.tree_util.tree_map(np.asarray, dev) if to_host \
                else dict(dev)
            out["_length"] = length
            out["_length_bucket"] = blen
            return out
        fn = self._get_jit("export", blen)
        if fn is None:
            def impl(cache, slot):
                def take(big):
                    if big.ndim >= 3 and big.shape[2] == self.max_len:
                        return jax.lax.dynamic_slice_in_dim(
                            big[:, slot], 0, blen, axis=1)
                    if big.ndim >= 2:
                        return big[:, slot]
                    return big
                return {key: jax.tree_util.tree_map(take, cache[key])
                        for key in cache if key != "lengths"}
            fn = self._put_jit("export", blen,
                               jax.jit(impl, static_argnums=(1,)))
        dev = fn(self.cache, slot)
        out = jax.tree_util.tree_map(np.asarray, dev) if to_host \
            else dict(dev)
        out["_length"] = length
        out["_length_bucket"] = blen
        return out

    def _restore_slot(self, slot: int, kv: dict, length: int):
        blen = kv.get("_length_bucket")
        if blen is None:   # legacy export: derive from the stored arrays
            for leaf in jax.tree_util.tree_leaves(
                    {k: v for k, v in kv.items()
                     if not k.startswith("_")}):
                if leaf.ndim >= 2 and leaf.shape[1] not in (1,) and \
                        leaf.shape[1] <= self.max_len and leaf.shape[1] >= 8:
                    blen = leaf.shape[1]
                    break
        if self.paged:
            # payload is the normalized contiguous layout (possibly from a
            # contiguous peer — P/D across layouts); scatter it through
            # the slot's freshly-allocated table row
            self.ensure_capacity(slot, blen)
            fn = self._get_jit("restore_paged", blen)
            if fn is None:
                ps, maxp, scratch = self.page_size, self._maxp, self._scratch

                def impl(cache, kv, slot, n):
                    row = cache["block_table"][slot]
                    pos = jnp.arange(blen)
                    pidx = pos // ps
                    page = row[jnp.minimum(pidx, maxp - 1)]
                    page = jnp.where(pidx < maxp, page, scratch)
                    off = pos % ps
                    out = dict(cache)
                    for key in cache:
                        if key in ("lengths", "block_table"):
                            continue
                        out[key] = {
                            "k_pages": cache[key]["k_pages"]
                            .at[:, page, off].set(kv[key]["k"]),
                            "v_pages": cache[key]["v_pages"]
                            .at[:, page, off].set(kv[key]["v"]),
                        }
                    out["lengths"] = cache["lengths"].at[slot].set(n)
                    return out
                fn = self._put_jit("restore_paged", blen, jax.jit(
                    impl, donate_argnums=(0,), static_argnums=(2,)))
            kvdev = {k: v for k, v in kv.items() if not k.startswith("_")}
            self.cache = fn(self.cache, kvdev, slot, length)
            return
        fn = self._get_jit("restore", blen)
        if fn is None:
            def impl(cache, kv, slot, n):
                def write(big, small):
                    if big.ndim >= 3 and big.shape[2] == self.max_len \
                            and small.ndim >= 2 and small.shape[1] == blen:
                        return big.at[:, slot, :blen].set(small)
                    if big.ndim >= 2:
                        return big.at[:, slot].set(small)
                    return big
                out = dict(cache)
                for key in cache:
                    if key == "lengths":
                        continue
                    out[key] = jax.tree_util.tree_map(
                        write, cache[key], kv[key])
                out["lengths"] = cache["lengths"].at[slot].set(n)
                return out
            fn = self._put_jit("restore", blen, jax.jit(
                impl, donate_argnums=(0,), static_argnums=(2,)))
        kvdev = {k: v for k, v in kv.items() if not k.startswith("_")}
        self.cache = fn(self.cache, kvdev, slot, length)
