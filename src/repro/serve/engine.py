"""Real JAX serving engine (mini-vLLM) — the fidelity ground truth.

Implements iteration-level continuous batching over a slot-based KV cache,
with an optional *real* radix prefix cache (stores actual KV tensors; hits
restore them and only the suffix is prefilled via ``Model.extend``).

Hybrid emulation: compute is REAL (every iteration runs the actual jitted
model on the local device and is wall-clock timed); time is VIRTUAL (each
instance has its own clock advanced by the measured latencies), so
multi-instance configurations behave as if instances ran in parallel even
though this container has one CPU. TTFT/TPOT/ITL read from the virtual
clocks — this is the "real GPU system + vLLM" side of the paper's §III
methodology, adapted to the container (DESIGN.md §2).
"""
from __future__ import annotations

import dataclasses
import time
from collections import OrderedDict, deque
from typing import Deque, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ArchConfig
from repro.models import Model
from repro.serve.sampler import greedy
from repro.workload.sharegpt import Request


def _bucket(n: int, lo: int = 16) -> int:
    b = lo
    while b < n:
        b *= 2
    return b


@dataclasses.dataclass
class EngineRequest:
    req: Request
    state: str = "queued"            # queued -> prefill -> decode -> done
    slot: int = -1
    generated: int = 0
    cached_prefix: int = 0
    t_first: Optional[float] = None
    t_finish: Optional[float] = None
    token_times: List[float] = dataclasses.field(default_factory=list)


class RealRadixCache:
    """Real prefix cache: token-prefix -> stored KV slices (numpy, host)."""

    def __init__(self, block: int = 16, max_entries: int = 64):
        self.block = block
        self.store: "OrderedDict[tuple, dict]" = OrderedDict()
        self.max_entries = max_entries
        self.hits = 0
        self.misses = 0

    def match(self, tokens,
              limit: Optional[int] = None) -> Tuple[int, Optional[dict]]:
        """Longest stored prefix of ``tokens`` (optionally capped at
        ``limit`` tokens, e.g. the runtime's radix-tree match length)."""
        best_len, best = 0, None
        n = (len(tokens) // self.block) * self.block
        if limit is not None:
            n = min(n, (limit // self.block) * self.block)
        for l in range(n, 0, -self.block):
            key = tuple(tokens[:l])
            if key in self.store:
                self.store.move_to_end(key)
                best_len, best = l, self.store[key]
                break
        if best is None:
            self.misses += 1
        else:
            self.hits += 1
        return best_len, best

    def insert(self, tokens, kv_slices: dict):
        l = (len(tokens) // self.block) * self.block
        if l == 0:
            return
        key = tuple(tokens[:l])
        if key in self.store:
            return
        self.store[key] = kv_slices
        while len(self.store) > self.max_entries:
            self.store.popitem(last=False)


class ServingEngine:
    """One instance. ``step()`` runs ONE real iteration, returns latency."""

    def __init__(self, cfg: ArchConfig, params=None, *, max_batch: int = 8,
                 max_len: int = 512, prefix_cache: bool = False,
                 role: str = "unified", name: str = "engine0", seed: int = 0):
        self.cfg = cfg
        self.name = name
        self.role = role
        self.model = Model(cfg, remat=False)
        self.params = params if params is not None else self.model.init(
            jax.random.PRNGKey(seed))
        self.max_batch = max_batch
        self.max_len = max_len
        self.cache = self.model.init_cache(max_batch, max_len)
        self.slot_free = list(range(max_batch))
        self.slot_req: Dict[int, EngineRequest] = {}
        self.waiting: Deque[EngineRequest] = deque()
        self.radix = RealRadixCache() if prefix_cache else None
        self.now = 0.0                   # virtual clock
        self.iterations = 0
        self._new_tokens: List[EngineRequest] = []
        self._finished: List[EngineRequest] = []
        self._handoffs: List[tuple] = []
        self._waiting_kv: Deque[tuple] = deque()   # P/D spill queue
        self.on_prefill_done = None      # P/D handoff hook
        self.on_request_done = None
        self._jit_decode = jax.jit(self.model.decode)
        self._jit_prefill = jax.jit(self.model.prefill,
                                    static_argnames=())
        self._jit_extend = jax.jit(self.model.extend)
        self._tokens_buf = np.zeros((max_batch, 1), np.int32)

    def warmup(self, buckets=(16, 32, 64, 128, 256)):
        """Compile prefill/extend/decode at every bucket so measured
        iteration latencies are steady-state (compile time excluded)."""
        import jax.numpy as jnp
        for P in buckets:
            if P >= self.max_len:
                continue
            pad = jnp.zeros((1, P), jnp.int32)
            lengths = jnp.asarray([P], jnp.int32)
            jax.block_until_ready(
                self._jit_prefill(self.params, pad, lengths=lengths))
            if self.radix is not None:
                sub = self._slot_subcache(0, 16)
                try:
                    jax.block_until_ready(self._jit_extend(
                        self.params, sub, pad,
                        jnp.asarray([P], jnp.int32)))
                except NotImplementedError:
                    pass
        jax.block_until_ready(self._jit_decode(
            self.params, self.cache, jnp.asarray(self._tokens_buf)))
        self.now = 0.0

    # ---- submission ----
    def submit(self, req: Request):
        self.waiting.append(EngineRequest(req=req))

    def has_work(self) -> bool:
        return bool(self.waiting) or bool(self.slot_req) \
            or bool(self._waiting_kv)

    # ---- one iteration (real compute) ----
    def step(self) -> float:
        self._new_tokens.clear()
        self._finished.clear()
        t0 = time.perf_counter()
        if self._waiting_kv and self.slot_free:
            ereq, kv, length, tok = self._waiting_kv.popleft()
            self.admit_with_kv(ereq, kv, length, tok)
            if self.slot_req:
                self._do_decode_iteration()
        elif self.waiting and self.slot_free:
            self._do_prefill(self.waiting.popleft())
        elif self.slot_req:
            self._do_decode_iteration()
        latency = time.perf_counter() - t0
        self.now += latency
        self.iterations += 1
        # stamp token events in virtual time
        for ereq in self._new_tokens:
            if ereq.t_first is None:
                ereq.t_first = self.now
            ereq.token_times.append(self.now)
        for ereq in self._finished:
            ereq.t_finish = self.now
            if self.on_request_done is not None:
                self.on_request_done(ereq)
        for ereq, kv, length, tok in self._handoffs:
            self.on_prefill_done(self, ereq, kv, length, tok)
        self._handoffs.clear()
        return latency

    # ---- prefill one request into a slot ----
    def _do_prefill(self, ereq: EngineRequest):
        req = ereq.req
        toks = list(req.prompt_tokens)[: self.max_len - req.output_len - 1]
        slot = self.slot_free.pop()
        ereq.slot = slot
        cached_kv = None
        cache_len = 0
        if self.radix is not None:
            cache_len, cached_kv = self.radix.match(toks)
            cache_len = min(cache_len, len(toks) - 1)
        if cached_kv is not None and cache_len > 0:
            self._restore_slot(slot, cached_kv, cache_len)
            suffix = np.asarray(toks[cache_len:], np.int32)
            P = _bucket(len(suffix))
            pad = np.zeros((1, P), np.int32)
            pad[0, :len(suffix)] = suffix
            sub_cache = self._slot_subcache(slot, cache_len)
            logits, new_sub = self._jit_extend(
                self.params, sub_cache, jnp.asarray(pad),
                jnp.asarray([len(suffix)], jnp.int32))
            self._write_slot(slot, new_sub, cache_len + len(suffix))
            ereq.cached_prefix = cache_len
        else:
            P = _bucket(len(toks))
            pad = np.zeros((1, P), np.int32)
            pad[0, :len(toks)] = np.asarray(toks, np.int32)
            lengths = jnp.asarray([len(toks)], jnp.int32)
            logits, cache1 = self._jit_prefill(self.params, jnp.asarray(pad),
                                               lengths=lengths)
            self._write_slot_from_prefill(slot, cache1, len(toks))
            if self.radix is not None:
                blk = (len(toks) // self.radix.block) * self.radix.block
                if blk > 0:
                    self.radix.insert(toks, self._export_slot(slot, blk))
        first_tok = int(np.asarray(greedy(logits, self.cfg.vocab))[0, 0])
        ereq.generated = 1
        ereq.state = "decode"
        self._new_tokens.append(ereq)
        if self.role == "prefill" and self.on_prefill_done is not None:
            # P/D: export KV; the handoff fires after this iteration's
            # latency lands on the virtual clock (see step())
            kv = self._export_slot(slot, len(toks))
            self._release_slot(slot)
            self._handoffs.append((ereq, kv, len(toks), first_tok))
        else:
            self.slot_req[slot] = ereq
            self._tokens_buf[slot, 0] = first_tok

    # ---- batched decode ----
    def _do_decode_iteration(self):
        toks = jnp.asarray(self._tokens_buf)
        logits, self.cache = self._jit_decode(self.params, self.cache, toks)
        nxt = np.asarray(greedy(logits, self.cfg.vocab))
        finished = []
        for slot, ereq in list(self.slot_req.items()):
            self._new_tokens.append(ereq)
            ereq.generated += 1
            self._tokens_buf[slot, 0] = int(nxt[slot, 0])
            if ereq.generated >= min(ereq.req.output_len,
                                     self.max_len - ereq.req.prompt_len - 1):
                finished.append(slot)
        for slot in finished:
            ereq = self.slot_req.pop(slot)
            ereq.state = "done"
            self._release_slot(slot)
            self._finished.append(ereq)

    def admit_with_kv(self, ereq: EngineRequest, kv: dict, length: int,
                      first_tok: int):
        """P/D decode-side admission: restore transferred KV into a slot."""
        if not self.slot_free:
            # keep the transferred KV; admit when a slot frees
            self._waiting_kv.append((ereq, kv, length, first_tok))
            return
        slot = self.slot_free.pop()
        self._restore_slot(slot, kv, length)
        ereq.slot = slot
        ereq.state = "decode"
        self.slot_req[slot] = ereq
        self._tokens_buf[slot, 0] = first_tok

    def decode_batch_size(self) -> int:
        return len(self.slot_req)

    # ---- jitted slot/cache plumbing ----
    # eager per-op dispatch costs ~ms on CPU; these helpers are jitted per
    # bucket size with cache donation so slot copies stay O(slice).
    def _get_jit(self, kind: str, key):
        jits = getattr(self, "_slot_jits", None)
        if jits is None:
            jits = self._slot_jits = {}
        return jits.get((kind, key))

    def _put_jit(self, kind: str, key, fn):
        self._slot_jits[(kind, key)] = fn
        return fn

    def _release_slot(self, slot: int):
        if slot not in self.slot_free:
            self.slot_free.append(slot)
        # zero the slot length
        self.cache["lengths"] = self.cache["lengths"].at[slot].set(0)

    def _write_slot_from_prefill(self, slot: int, cache1, n: int):
        """Copy a (B=1) prefill cache into slot ``slot`` of the big cache."""
        P = None
        for leaf in jax.tree_util.tree_leaves(cache1):
            if leaf.ndim >= 3 and leaf.shape[1] == 1:
                P = leaf.shape[2]
                break
        fn = self._get_jit("write_prefill", P)
        if fn is None:
            def impl(cache, cache1, slot, n):
                def write(big, small):
                    if big.ndim >= 2 and small.shape[1] == 1:
                        if big.ndim >= 3 and small.ndim >= 3 \
                                and small.shape[2] <= big.shape[2] \
                                and big.shape[2] == self.max_len:
                            pad_len = small.shape[2]
                            return big.at[:, slot, :pad_len].set(small[:, 0])
                        return big.at[:, slot].set(small[:, 0])
                    return big
                out = dict(cache)
                for key in cache:
                    if key == "lengths":
                        continue
                    out[key] = jax.tree_util.tree_map(
                        write, cache[key], cache1[key])
                out["lengths"] = cache["lengths"].at[slot].set(n)
                return out
            fn = self._put_jit("write_prefill", P, jax.jit(
                impl, donate_argnums=(0,), static_argnums=(2,)))
        self.cache = fn(self.cache, cache1, slot, n)

    def _slot_subcache(self, slot: int, length: int):
        """A (B=1) view of one slot (full max_len buffers, real length)."""
        fn = self._get_jit("subcache", None)
        if fn is None:
            def impl(cache, slot, length):
                def take(big):
                    return big[:, slot: slot + 1] if big.ndim >= 2 else big
                sub = {}
                for key in cache:
                    if key == "lengths":
                        sub[key] = jnp.full((1,), length, jnp.int32)
                    else:
                        sub[key] = jax.tree_util.tree_map(take, cache[key])
                return sub
            fn = self._put_jit("subcache", None,
                               jax.jit(impl, static_argnums=(1,)))
        return fn(self.cache, slot, length)

    def _write_slot(self, slot: int, sub_cache, n: int):
        fn = self._get_jit("write_slot", None)
        if fn is None:
            def impl(cache, sub, slot, n):
                def write(big, small):
                    return big.at[:, slot: slot + 1].set(small) \
                        if big.ndim >= 2 else big
                out = dict(cache)
                for key in cache:
                    if key == "lengths":
                        continue
                    out[key] = jax.tree_util.tree_map(
                        write, cache[key], sub[key])
                out["lengths"] = cache["lengths"].at[slot].set(n)
                return out
            fn = self._put_jit("write_slot", None, jax.jit(
                impl, donate_argnums=(0,), static_argnums=(2,)))
        self.cache = fn(self.cache, sub_cache, slot, n)

    def _export_slot(self, slot: int, length: int) -> dict:
        """Copy a slot's KV out to host numpy (prefix cache / P/D).
        Device-side gather is jitted per bucketed length; only the final
        np.asarray is a host copy."""
        blen = _bucket(length)
        blen = min(blen, self.max_len)
        fn = self._get_jit("export", blen)
        if fn is None:
            def impl(cache, slot):
                def take(big):
                    if big.ndim >= 3 and big.shape[2] == self.max_len:
                        return jax.lax.dynamic_slice_in_dim(
                            big[:, slot], 0, blen, axis=1)
                    if big.ndim >= 2:
                        return big[:, slot]
                    return big
                return {key: jax.tree_util.tree_map(take, cache[key])
                        for key in cache if key != "lengths"}
            fn = self._put_jit("export", blen,
                               jax.jit(impl, static_argnums=(1,)))
        dev = fn(self.cache, slot)
        out = jax.tree_util.tree_map(np.asarray, dev)
        out["_length"] = length
        out["_length_bucket"] = blen
        return out

    def _restore_slot(self, slot: int, kv: dict, length: int):
        blen = kv.get("_length_bucket")
        if blen is None:   # legacy export: derive from the stored arrays
            for leaf in jax.tree_util.tree_leaves(
                    {k: v for k, v in kv.items()
                     if not k.startswith("_")}):
                if leaf.ndim >= 2 and leaf.shape[1] not in (1,) and \
                        leaf.shape[1] <= self.max_len and leaf.shape[1] >= 8:
                    blen = leaf.shape[1]
                    break
        fn = self._get_jit("restore", blen)
        if fn is None:
            def impl(cache, kv, slot, n):
                def write(big, small):
                    if big.ndim >= 3 and big.shape[2] == self.max_len \
                            and small.ndim >= 2 and small.shape[1] == blen:
                        return big.at[:, slot, :blen].set(small)
                    if big.ndim >= 2:
                        return big.at[:, slot].set(small)
                    return big
                out = dict(cache)
                for key in cache:
                    if key == "lengths":
                        continue
                    out[key] = jax.tree_util.tree_map(
                        write, cache[key], kv[key])
                out["lengths"] = cache["lengths"].at[slot].set(n)
                return out
            fn = self._put_jit("restore", blen, jax.jit(
                impl, donate_argnums=(0,), static_argnums=(2,)))
        kvdev = {k: v for k, v in kv.items() if not k.startswith("_")}
        self.cache = fn(self.cache, kvdev, slot, length)
