"""Token samplers over (possibly vocab-padded) logits."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def greedy(logits, vocab: int):
    """logits: (B, 1, Vpad) (or (B,1,K,Vpad) multi-codebook -> first book)."""
    if logits.ndim == 4:
        logits = logits[:, :, 0]
    return jnp.argmax(logits[..., :vocab], axis=-1).astype(jnp.int32)


def temperature(logits, vocab: int, key, temp: float = 1.0):
    if logits.ndim == 4:
        logits = logits[:, :, 0]
    scaled = logits[..., :vocab].astype(jnp.float32) / max(temp, 1e-4)
    return jax.random.categorical(key, scaled, axis=-1).astype(jnp.int32)
