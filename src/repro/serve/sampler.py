"""Token samplers over (possibly vocab-padded) logits."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def greedy(logits, vocab: int):
    """logits: (B, S, Vpad) (or (B,S,K,Vpad) multi-codebook -> first book).
    S is 1 for classic decode and k + 1 for speculative verification —
    the argmax is per position either way, returning (B, S) int32."""
    if logits.ndim == 4:
        logits = logits[:, :, 0]
    return jnp.argmax(logits[..., :vocab], axis=-1).astype(jnp.int32)


def accept_length(draft_tokens, target_tokens) -> np.ndarray:
    """Per-row count of leading draft tokens the target's greedy
    verification confirms: ``draft`` (B, k) vs ``target`` (B, >= k) —
    target position i is the greedy prediction after consuming draft
    token i's prefix.  Returns (B,) ints in [0, k]."""
    d = np.asarray(draft_tokens)
    t = np.asarray(target_tokens)[:, :d.shape[1]]
    return np.cumprod(d == t, axis=1).sum(axis=1).astype(np.int64)


def temperature(logits, vocab: int, key, temp: float = 1.0):
    if logits.ndim == 4:
        logits = logits[:, :, 0]
    scaled = logits[..., :vocab].astype(jnp.float32) / max(temp, 1e-4)
    return jax.random.categorical(key, scaled, axis=-1).astype(jnp.int32)
