"""Multi-instance serving driver: real compute, virtual time.

Orchestrates N ``ServingEngine`` instances + a router + optional P/D wiring
as a discrete-event loop over *virtual* clocks: at each step the
earliest-available engine with work runs ONE real iteration (wall-clock
measured) and its clock advances by the measured latency. Instances thus
behave as if they ran in parallel. KV transfers between instances cost
bytes/bw in virtual time (configurable, default PCIe-class).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.serve.engine import EngineRequest, ServingEngine
from repro.workload.sharegpt import Request


@dataclasses.dataclass
class DriverCfg:
    router: str = "round_robin"         # round_robin | least_loaded
    kv_transfer_bw: float = 16e9        # bytes/s for P/D handoff
    kv_transfer_latency: float = 10e-6


class ServeDriver:
    def __init__(self, engines: List[ServingEngine],
                 cfg: DriverCfg = DriverCfg(),
                 pd_map: Optional[Dict[str, Tuple[str, ...]]] = None):
        self.engines = {e.name: e for e in engines}
        self.cfg = cfg
        self.pd_map = pd_map or {}
        self._rr = 0
        self.finished: List[EngineRequest] = []
        for e in engines:
            e.on_request_done = self._done
        for pname, dnames in self.pd_map.items():
            p = self.engines[pname]
            p.on_prefill_done = self._make_handoff(
                [self.engines[d] for d in dnames])

    def _done(self, ereq: EngineRequest):
        self.finished.append(ereq)

    def _make_handoff(self, targets: List[ServingEngine]):
        def handoff(src: ServingEngine, ereq: EngineRequest, kv: dict,
                    length: int, first_tok: int, _targets=targets):
            tgt = min(_targets, key=lambda e: len(e.slot_req))
            nbytes = sum(v.nbytes for v in _flat_np(kv))
            t_xfer = self.cfg.kv_transfer_latency + nbytes / \
                self.cfg.kv_transfer_bw
            # decode instance can't start this request before the KV lands
            tgt.now = max(tgt.now, src.now + t_xfer)
            tgt.admit_with_kv(ereq, kv, length, first_tok)
        return handoff

    def _route(self, req: Request) -> ServingEngine:
        cands = [e for e in self.engines.values()
                 if e.role in ("unified", "prefill")]
        if self.cfg.router == "least_loaded":
            return min(cands, key=lambda e: len(e.slot_req)
                       + len(e.waiting))
        e = cands[self._rr % len(cands)]
        self._rr += 1
        return e

    def run(self, requests: Sequence[Request], warmup: bool = True) -> dict:
        if warmup:
            for e in self.engines.values():
                e.warmup()
        pending = sorted(requests, key=lambda r: r.arrival)
        pi = 0
        reqmap: Dict[int, EngineRequest] = {}
        n_total = len(pending)
        guard = 0
        while len(self.finished) < n_total and guard < 10_000_000:
            guard += 1
            # 1. deliver arrivals up to the earliest engine clock
            busy_engines = [e for e in self.engines.values() if e.has_work()]
            t_min = min((e.now for e in busy_engines), default=None)
            while pi < len(pending) and (
                    t_min is None or pending[pi].arrival <= t_min
                    or not busy_engines):
                r = pending[pi]
                eng = self._route(r)
                eng.now = max(eng.now, r.arrival)
                eng.submit(r)
                pi += 1
                busy_engines = [e for e in self.engines.values()
                                if e.has_work()]
                t_min = min((e.now for e in busy_engines), default=None)
            # 2. step the earliest engine that has work
            if not busy_engines:
                if pi < len(pending):
                    continue
                break
            eng = min(busy_engines, key=lambda e: e.now)
            eng.step()
        return self.metrics()

    def metrics(self) -> dict:
        done = self.finished
        if not done:
            return {"finished": 0}
        ttft = np.array([e.t_first - e.req.arrival for e in done
                         if e.t_first is not None])
        tpot = np.array([(e.t_finish - e.t_first) / max(e.generated - 1, 1)
                         for e in done if e.t_finish and e.t_first
                         and e.generated > 1])
        itls = [np.diff(e.token_times) for e in done
                if len(e.token_times) > 1]
        itls = np.concatenate(itls) if itls else np.array([0.0])
        t_end = max(e.t_finish for e in done)
        t0 = min(e.req.arrival for e in done)
        out_tokens = sum(e.generated for e in done)
        m = {"finished": len(done),
             "ttft_mean_s": float(ttft.mean()) if ttft.size else None,
             "tpot_mean_s": float(tpot.mean()) if tpot.size else None,
             "itl_mean_s": float(itls.mean()),
             "throughput_tok_s": out_tokens / max(t_end - t0, 1e-9),
             "makespan_s": t_end - t0}
        for name, e in self.engines.items():
            if e.radix is not None:
                m[f"{name}_cache_hits"] = e.radix.hits
                m[f"{name}_cache_misses"] = e.radix.misses
        return m


def _flat_np(tree):
    out = []
    if isinstance(tree, dict):
        for k, v in tree.items():
            if k.startswith("_length"):
                continue
            out.extend(_flat_np(v))
    elif isinstance(tree, (list, tuple)):
        for v in tree:
            out.extend(_flat_np(v))
    else:
        out.append(np.asarray(tree))
    return out
