"""Multi-instance real-engine driver: real compute, virtual time.

A thin wrapper over the unified ``ServingRuntime``: N ``ServingEngine``
instances become runtime instances with ``JaxBackend`` execution.  Routing
uses the shared policy registry (``repro.runtime.router``), scheduling the
shared ``BatchScheduler``, and P/D handoff the shared cluster orchestration
— the exact code path the simulator runs, so fidelity comparisons isolate
hardware-model error only.

At each virtual instant the runtime picks the next event; an instance
iteration runs ONE real (wall-clock measured) batch and schedules its
completion at ``now + latency`` on the shared event queue, so instances
behave as if they ran in parallel.  KV transfers between instances cost
bytes/bw in virtual time (configurable, default PCIe-class).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.config import (ENGINE_HW, ClusterCfg, InstanceCfg,
                               NetworkCfg, ParallelismCfg, PrefixCacheCfg,
                               RouterCfg, SchedulerCfg, engine_scheduler_cfg)
from repro.core.request import SimRequest
from repro.runtime.backends.jax_engine import JaxBackend
from repro.runtime.cluster import ServingRuntime
from repro.serve.engine import ServingEngine
from repro.workload.sharegpt import Request


def engine_instance_cfg(engine: ServingEngine,
                        scheduler: Optional[SchedulerCfg] = None,
                        trace_name: Optional[str] = None,
                        moe=None, spec=None, hw=None,
                        prefix_cache: Optional[PrefixCacheCfg] = None
                        ) -> InstanceCfg:
    """Runtime InstanceCfg mirroring a live ``ServingEngine``.

    ``moe`` (a ``repro.core.MoECfg``) lets the simulated twin of a MoE
    engine name the same ``routing_trace`` the engine replays, and
    ``spec`` (a ``repro.core.SpecCfg``) the same ``acceptance_trace`` a
    speculating engine replays, so sim-vs-real comparisons report
    comparable ``expert_load`` / ``spec_decode`` metrics.  A speculating
    engine always mirrors its draft length into the scheduler
    (``decode_tokens = k + 1``) so the KV ledger reserves the real
    verification window.  ``hw`` overrides the default ``ENGINE_HW``
    spec and ``prefix_cache`` the derived ``PrefixCacheCfg`` — e.g. a
    sim-vs-real KV-tier comparison shrinking tier capacities so both
    backends walk the same spill chain (``tests/test_kv_tiers.py``).
    """
    from repro.core.config import MoECfg, SpecCfg
    from repro.profiler import model_spec_from_arch
    model = model_spec_from_arch(engine.cfg)
    scheduler = scheduler or engine_scheduler_cfg(engine.max_batch)
    if scheduler.max_batch_size > engine.max_batch:
        # the engine's slot count is a physical limit; an oversized batch
        # would crash slot allocation mid-run
        scheduler = dataclasses.replace(scheduler,
                                        max_batch_size=engine.max_batch)
    if spec is None and engine.spec is not None:
        spec = SpecCfg(enabled=True, k=engine.spec.k,
                       draft=model_spec_from_arch(engine.spec.draft))
    if engine.spec is not None:
        scheduler = dataclasses.replace(scheduler,
                                        decode_tokens=engine.spec.k + 1)
    if prefix_cache is None:
        prefix_cache = PrefixCacheCfg(
            enabled=engine.radix is not None,
            block_tokens=engine.radix.block if engine.radix else 16,
            capacity_fraction=0.5)
    return InstanceCfg(
        name=engine.name, hw=hw if hw is not None else ENGINE_HW,
        model=model,
        n_devices=engine.tp, role=engine.role,
        parallelism=ParallelismCfg(tp=engine.tp),
        scheduler=scheduler,
        prefix_cache=prefix_cache,
        moe=moe if moe is not None else MoECfg(),
        spec=spec if spec is not None else SpecCfg(),
        trace_name=trace_name)


@dataclasses.dataclass
class DriverCfg:
    router: str = "round_robin"         # any registered routing policy
    kv_transfer_bw: float = 16e9        # bytes/s for P/D handoff
    kv_transfer_latency: float = 10e-6
    # None -> ServingEngine-matched semantics; pass any SchedulerCfg to give
    # the real engine chunked prefill / SJF / preemption etc.
    scheduler: Optional[SchedulerCfg] = None


class ServeDriver:
    def __init__(self, engines: List[ServingEngine],
                 cfg: DriverCfg = DriverCfg(),
                 pd_map: Optional[Dict[str, Tuple[str, ...]]] = None,
                 recorder=None):
        self.cfg = cfg
        self.engines = {e.name: e for e in engines}
        ccfg = ClusterCfg(
            instances=tuple(engine_instance_cfg(e, cfg.scheduler)
                            for e in engines),
            router=RouterCfg(cfg.router),
            network=NetworkCfg(inter_instance_bw=cfg.kv_transfer_bw,
                               inter_instance_latency=cfg.kv_transfer_latency),
            pd_map=pd_map)
        # recorder: a repro.obs.EventRecorder — build it with
        # wall_clock=True so the real engine's events carry wall-clock
        # stamps alongside simulated time (same schema as the sim)
        self.runtime = ServingRuntime(
            ccfg,
            backend_factory=lambda icfg, trace: JaxBackend(
                self.engines[icfg.name], icfg),
            recorder=recorder)

    @property
    def finished(self) -> List[SimRequest]:
        return self.runtime.finished

    def run(self, requests: Sequence[Request], warmup: bool = True) -> dict:
        if warmup:
            self.runtime.warmup()
        self.runtime.submit_workload(requests)
        return self._augment(self.runtime.run())

    def metrics(self) -> dict:
        return self._augment(self.runtime.metrics())

    def _augment(self, m: dict) -> dict:
        for name, stats in m.get("instances", {}).items():
            cache = stats.get("prefix_cache")
            if cache:
                m[f"{name}_cache_hits"] = cache["hits"]
                m[f"{name}_cache_misses"] = cache["misses"]
        return m
