"""Record an ``ExpertRoutingTrace`` from a real ``JaxBackend`` run.

The recording hook (``repro.moe.hooks.make_recording_hook``) streams every
MoE layer's routing decisions to a :class:`RoutingRecorder` while the
unified runtime serves a workload through the real engine — the exact
production code paths (bucketed prefill, extend, batched decode).  The
recorder buckets observations by token position (``position % period``,
like the latency grids bucket shapes) and distills them into the
deterministic per-layer assignment tables the artifact carries: for each
(layer, position bucket), the top-k most frequently observed experts.

CLI: ``python -m repro.profiler record-routing --arch <moe-arch> --out
traces/<arch>.routing.json`` (also ``profile --experts`` to ride along
with a hardware profile).
"""
from __future__ import annotations

from typing import Dict, Optional

import numpy as np

from repro.moe.trace import ExpertRoutingTrace, moe_layer_count


class RoutingRecorder:
    """Host-side accumulator for routed (layer, position, expert) triples.

    ``enabled`` gates accumulation at *runtime* (the tap checks it on the
    host each call), so warmup/compile traffic can be excluded without
    retracing any jit.
    """

    def __init__(self, n_layers: int, n_experts: int, top_k: int,
                 period: int = 256):
        self.n_layers = n_layers
        self.n_experts = n_experts
        self.top_k = top_k
        self.period = period
        self.hist = np.zeros((n_layers, period, n_experts), np.int64)
        self.enabled = True

    def tap(self, layer, positions, expert_idx, valid=None):
        """Callback target (``jax.debug.callback``): one MoE layer's
        assignments for one executed batch.  ``valid`` masks pad-tail
        rows and empty decode slots (the jitted batch routes them too,
        but they are not workload tokens and must not bias the tables)."""
        if not self.enabled:
            return
        l = int(layer)
        if not 0 <= l < self.n_layers:
            return
        pos = np.asarray(positions).reshape(-1)
        idx = np.asarray(expert_idx).reshape(pos.size, -1)
        if valid is not None:
            keep = np.asarray(valid).reshape(-1).astype(bool)
            pos, idx = pos[keep], idx[keep]
        pos = pos % self.period
        for j in range(idx.shape[1]):
            np.add.at(self.hist[l], (pos, idx[:, j]), 1)

    def to_trace(self, model: str = "*",
                 meta: Optional[Dict] = None) -> ExpertRoutingTrace:
        """Distill the histograms into a deterministic artifact: per
        (layer, position) the top-k most observed experts (ties -> lower
        expert id); positions never observed fall back to the layer's
        global top-k."""
        layers = []
        for l in range(self.n_layers):
            h = self.hist[l]
            glob = np.argsort(-h.sum(axis=0), kind="stable")[:self.top_k]
            table = np.argsort(-h, axis=1, kind="stable")[:, :self.top_k]
            unseen = h.sum(axis=1) == 0
            table[unseen] = glob
            layers.append(table.astype(np.int32))
        info = {"source": "recorded", "period": self.period,
                "observations": int(self.hist.sum())}
        info.update(meta or {})
        return ExpertRoutingTrace(
            model=model, n_experts=self.n_experts, top_k=self.top_k,
            layers=layers, meta=info).validate()


def record_routing(arch: str, *, n_requests: int = 8, rate: float = 50.0,
                   max_batch: int = 4, max_len: int = 256,
                   period: int = 256, seed: int = 0,
                   mean_prompt: int = 40, mean_output: int = 8
                   ) -> ExpertRoutingTrace:
    """Serve a synthetic workload through the real engine with a recording
    hook installed and distill the observed routing into an artifact."""
    from repro.configs import get_config
    from repro.moe.hooks import make_recording_hook
    from repro.serve.driver import ServeDriver
    from repro.serve.engine import ServingEngine
    from repro.workload import ShareGPTConfig, generate

    cfg = get_config(arch)
    if cfg.moe is None:
        raise ValueError(f"{arch!r} is not a MoE architecture; "
                         f"record-routing needs one")
    recorder = RoutingRecorder(moe_layer_count(cfg), cfg.moe.n_experts,
                               cfg.moe.top_k, period=period)
    recorder.enabled = False          # exclude warmup/compile traffic
    eng = ServingEngine(cfg, max_batch=max_batch, max_len=max_len,
                        name="rec0", seed=seed,
                        routing=make_recording_hook(recorder))
    drv = ServeDriver([eng])
    drv.runtime.warmup()
    recorder.enabled = True
    reqs = generate(ShareGPTConfig(
        n_requests=n_requests, rate=rate, vocab=cfg.vocab, seed=seed,
        mean_prompt=mean_prompt, mean_output=mean_output,
        max_prompt=max(max_len // 2, 16), max_output=max(mean_output, 4)))
    drv.runtime.submit_workload(reqs)
    drv.runtime.run()
    return recorder.to_trace(model=cfg.name,
                             meta={"arch": arch, "n_requests": n_requests,
                                   "seed": seed})
