"""Injectable routing hooks for the real MoE model.

Each hook plugs into ``repro.models.moe.moe_ffn`` via
``Model(routing_hook=...)`` (most conveniently through
``ServingEngine(routing=<trace>)``) and replaces the top-k assignment step
of every MoE layer while the dispatch / capacity / grouped-GEMM / combine
path runs unchanged.  Contract::

    hook(logits, *, positions, layer, top_k, valid=None)
        -> (expert_idx (T, k) int32, combine_w (T, k) f32, aux scalar)

``logits`` are the router's pre-softmax scores ``(T, E)``; ``positions``
the flattened (T,) token KV positions; ``layer`` the model-wide MoE layer
index (traced inside the scan); ``valid`` (when given) flags which rows
are real workload tokens — bucketed prefill/extend pad tails and empty
decode slots are False, and recording taps must mask on it.

Hooks must be installed *before* any jit traces (the jitted closures
capture them) — ``ServingEngine`` does this at construction.

Three hooks cover the trace workflow:

* :func:`make_replay_hook` — **forced assignment**: every token routes to
  exactly ``trace.layers[layer][position % period]``.  This is what the
  sim/real expert-load parity suite replays.
* :func:`make_bias_hook` — **logit biasing**: the trace's per-layer expert
  frequencies are added as a log-frequency bias, steering (not forcing)
  the learned router toward the trace's skew.
* :func:`make_recording_hook` — free-running routing plus a host tap that
  streams ``(layer, positions, expert_idx)`` into a
  ``repro.moe.record.RoutingRecorder`` for artifact capture.
"""
from __future__ import annotations

import numpy as np


def _tables(trace):
    import jax.numpy as jnp
    return jnp.asarray(
        np.stack([np.asarray(t, np.int32) for t in trace.layers]))


def make_replay_hook(trace):
    """Force every MoE layer's assignments to the trace's table."""
    import jax.numpy as jnp
    trace.validate()
    tables = _tables(trace)           # (L, period, k)
    period = trace.period

    def hook(logits, *, positions, layer, top_k, valid=None):
        # layer is None when moe_ffn is driven directly (single layer)
        idx = tables[0 if layer is None else layer,
                     positions % period]                 # (T, k)
        w = jnp.full(idx.shape, 1.0 / top_k, jnp.float32)
        return idx, w, jnp.zeros((), jnp.float32)
    return hook


def make_bias_hook(trace, strength: float = 2.0):
    """Bias the learned router's logits toward the trace's expert
    frequencies (``strength`` scales the log-frequency bias; 0 is a
    no-op).  Softer than forced replay: combine weights stay learned."""
    import jax
    import jax.numpy as jnp
    trace.validate()
    pos = np.arange(trace.period)
    freq = np.stack([trace.counts_for(l, pos) + 1.0
                     for l in range(trace.n_layers)])    # (L, E), laplace
    freq = freq / freq.sum(axis=1, keepdims=True)
    bias = jnp.asarray(strength * (np.log(freq)
                                   - np.log(freq).mean(axis=1,
                                                       keepdims=True)),
                       jnp.float32)

    def hook(logits, *, positions, layer, top_k, valid=None):
        probs = jax.nn.softmax(
            logits + bias[0 if layer is None else layer], axis=-1)
        combine_w, expert_idx = jax.lax.top_k(probs, top_k)
        combine_w = combine_w / jnp.maximum(
            combine_w.sum(-1, keepdims=True), 1e-9)
        return (expert_idx.astype(jnp.int32), combine_w,
                jnp.zeros((), jnp.float32))
    return hook


def make_recording_hook(recorder):
    """Route exactly like the default learned router, but stream every
    layer's ``(positions, expert_idx)`` to ``recorder`` via a host
    callback (``repro.moe.record.RoutingRecorder``)."""
    import jax
    import jax.numpy as jnp

    def hook(logits, *, positions, layer, top_k, valid=None):
        probs = jax.nn.softmax(logits, axis=-1)
        combine_w, expert_idx = jax.lax.top_k(probs, top_k)
        combine_w = combine_w / jnp.maximum(
            combine_w.sum(-1, keepdims=True), 1e-9)
        expert_idx = expert_idx.astype(jnp.int32)
        if valid is None:
            valid = jnp.ones(positions.shape, bool)
        jax.debug.callback(recorder.tap, layer, positions, expert_idx,
                           valid)
        return expert_idx, combine_w, jnp.zeros((), jnp.float32)
    return hook
