"""Named expert-routing traces: how cluster configs reference an artifact.

``MoECfg.routing_trace`` names a trace; both backends resolve that name
here at instance-build time (``resolve_routing``), exactly like
``InstanceCfg.hw_name`` resolves through ``repro.hw``.  Registering once
(``register_routing``/``load_routing``) makes the artifact available to
every cluster config in the process.
"""
from __future__ import annotations

import os
from typing import Dict, List, Optional

from repro.moe.trace import READABLE_SCHEMAS, ExpertRoutingTrace


class RoutingRegistry:
    """Name -> ``ExpertRoutingTrace`` (no synthetic fallback: skew is an
    explicit experiment input, never something to guess silently)."""

    def __init__(self):
        self._traces: Dict[str, ExpertRoutingTrace] = {}

    def register(self, name: str,
                 trace: ExpertRoutingTrace) -> ExpertRoutingTrace:
        trace.validate()
        self._traces[name] = trace
        return trace

    def names(self) -> List[str]:
        return sorted(self._traces)

    def get(self, name: str) -> ExpertRoutingTrace:
        if name not in self._traces:
            raise KeyError(
                f"no expert-routing trace registered as {name!r}; loaded: "
                f"{self.names() or '(none)'} — record one with `python -m "
                f"repro.profiler record-routing --arch <moe-arch>` or "
                f"synthesize one with repro.workload.expert_skew")
        return self._traces[name]

    def load_file(self, path: str,
                  name: Optional[str] = None) -> ExpertRoutingTrace:
        trace = ExpertRoutingTrace.load(path)
        key = name or os.path.splitext(os.path.basename(path))[0]
        return self.register(key, trace)

    def load_dir(self, path: str) -> List[str]:
        """Load every routing artifact in ``path`` (registered under the
        file stem).  JSON files with a foreign or missing ``schema`` key
        (e.g. ``hwtrace`` artifacts sharing ``traces/``) are skipped."""
        import json
        import warnings
        names = []
        for fn in sorted(os.listdir(path)):
            if not fn.endswith(".json"):
                continue
            fp = os.path.join(path, fn)
            with open(fp) as f:
                try:
                    doc = json.load(f)
                except ValueError:
                    continue
            schema = doc.get("schema", "") if isinstance(doc, dict) else ""
            if not schema.startswith("moetrace/"):
                continue
            if schema not in READABLE_SCHEMAS:
                warnings.warn(
                    f"{fp}: unreadable routing schema {schema!r} — skipped")
                continue
            name = os.path.splitext(fn)[0]
            names.append(name)
            self.load_file(fp, name=name)
        return names


#: Process-wide default registry (``MoECfg.routing_trace`` resolves here
#: when no explicit registry is passed).
default_routing_registry = RoutingRegistry()


def register_routing(name: str,
                     trace: ExpertRoutingTrace) -> ExpertRoutingTrace:
    return default_routing_registry.register(name, trace)


def get_routing(name: str) -> ExpertRoutingTrace:
    return default_routing_registry.get(name)


def load_routing(path: str, name: Optional[str] = None):
    """Load a routing-trace file or directory into the default registry."""
    if os.path.isdir(path):
        return default_routing_registry.load_dir(path)
    return default_routing_registry.load_file(path, name=name)


def resolve_routing(icfg, registry: Optional[RoutingRegistry] = None
                    ) -> Optional[ExpertRoutingTrace]:
    """The trace named by ``icfg.moe.routing_trace`` (None when unset),
    checked structurally compatible with the instance's model."""
    name = getattr(icfg.moe, "routing_trace", None)
    if not name:
        return None
    reg = registry or default_routing_registry
    return reg.get(name).check_model(icfg.model)
