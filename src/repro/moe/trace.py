"""Portable expert-routing trace artifacts (the MoE sim <-> real contract).

An ``ExpertRoutingTrace`` is the versioned, JSON-serializable artifact that
makes MoE expert-load skew *replayable*: one deterministic table of top-k
expert assignments per MoE layer, indexed by token position.  It is either
**recorded** from a real ``JaxBackend`` run (``python -m repro.profiler
record-routing --arch <moe-arch>``; see ``repro.moe.record``) or
**synthesized** from a parameterized skew generator
(``repro.workload.expert_skew``), and the same artifact then drives both
execution backends:

* ``SimBackend`` prices expert compute/offload traffic from the trace's
  per-layer counts (``PerfModel(routing=...)`` -> ``ExpertExecutionModel``)
  and accounts expert-load metrics through :class:`ExpertLoadTracker`;
* ``JaxBackend`` replays the trace on the real model through an injectable
  routing hook (``repro.moe.hooks.make_replay_hook`` — forced assignment —
  or ``make_bias_hook`` — logit biasing), and accounts the same metrics.

The position convention is shared by everything that consumes a trace: a
token's *position* is its 0-based index in the sequence KV (prompt tokens
sit at their prompt offsets; the n-th generated token sits at
``prompt_len + n - 1``), and position ``p`` of MoE layer ``l`` routes to
``layers[l][p % period]``.  ``tests/test_expert_routing.py`` pins that both
backends produce identical per-layer expert token counts for a replayed
trace.

JSON schema (version ``moetrace/2``)::

    {
      "schema": "moetrace/2",       # required; moetrace/1 still loads
      "model": "granite-moe-1b-a400m-tiny",
      "n_experts": 4,
      "top_k": 2,
      "layers": [                   # one assignment table per MoE layer
        {"layer": 0,
         "assignments": [[0, 2],    #   position p -> top-k expert ids
                         [1, 0],    #   (period rows of top_k ids each;
                         ...]},     #   lookup is assignments[p % period])
        {"layer": 1, "assignments": [...]}
      ],
      "meta": {"source": "synthetic", "kind": "zipf", "seed": 0, ...}
    }

The legacy ``moetrace/1`` layout (one top-level ``assignments`` table shared
by every layer, plus ``n_layers``) loads transparently — the table is
replicated per layer — and ``save`` always re-emits ``moetrace/2``.
"""
from __future__ import annotations

import dataclasses
import json
import os
from collections import deque
from typing import Dict, List, Optional, Sequence

import numpy as np

SCHEMA_VERSION = "moetrace/2"
#: schema versions this build can read (save always emits SCHEMA_VERSION)
READABLE_SCHEMAS = ("moetrace/1", "moetrace/2")


def _imbalance(counts, shards: int) -> float:
    """``repro.core.expert.imbalance_factor`` — imported lazily: this
    module sits above ``repro.core`` in the layering (the sim backend
    imports it back), so a cold import here must not re-enter core's
    package init mid-flight."""
    from repro.core.expert import imbalance_factor
    return imbalance_factor(counts, shards)


def _metric_shards(ep: int, n_experts: int) -> int:
    """Sharding the *metric* imbalance is computed over: the instance's
    expert-parallel degree when it actually shards (ep > 1), else every
    expert is its own shard — the conventional max/mean-over-experts MoE
    imbalance (an unsharded instance would otherwise always report 1.0)."""
    return ep if ep > 1 else n_experts


def moe_layer_count(cfg) -> int:
    """Number of MoE layers a config describes.

    ``ArchConfig`` (real engine) counts its ``attn_moe`` stage layers;
    ``ModelSpec`` (simulator) has no stage structure — every layer of a
    MoE model is an MoE layer there, so its ``n_layers`` is returned.
    """
    stages = getattr(cfg, "stages", None)
    if stages:
        n = sum(st.n_layers for st in stages
                if getattr(st, "kind", "") == "attn_moe")
        if n:
            return n
    return int(getattr(cfg, "n_layers", 0))


@dataclasses.dataclass
class ExpertRoutingTrace:
    """One replayable expert-routing artifact (see module docstring).

    ``layers[l]`` is an ``(period, top_k)`` int array of expert ids; all
    layers share one ``period`` (the position bucket length — lookups wrap
    with ``position % period``, like the latency grids bucket shapes).
    """

    model: str
    n_experts: int
    top_k: int
    layers: List[np.ndarray] = dataclasses.field(default_factory=list)
    meta: Dict = dataclasses.field(default_factory=dict)

    # ---- shape access ----
    @property
    def n_layers(self) -> int:
        return len(self.layers)

    @property
    def period(self) -> int:
        return int(self.layers[0].shape[0]) if self.layers else 0

    # ---- lookup ----
    def assignments_for(self, layer: int, positions) -> np.ndarray:
        """Top-k expert ids for each token position: ``(len(positions),
        top_k)`` — the replay contract both backends share."""
        pos = np.asarray(positions, np.int64) % self.period
        return self.layers[layer][pos]

    def counts_for(self, layer: int, positions) -> np.ndarray:
        """Per-expert token counts for one layer over ``positions``
        (sums to ``len(positions) * top_k``)."""
        a = self.assignments_for(layer, positions)
        return np.bincount(a.reshape(-1), minlength=self.n_experts)

    def static_imbalance(self, ep: int = 1) -> float:
        """Imbalance factor of the table itself (all layers, one full
        period) — the workload-independent skew the generators are
        parameterized by.  ``ep=1`` reports the per-expert imbalance
        (max/mean over experts); ``ep>1`` the per-rank sharded view."""
        total = np.zeros(self.n_experts, np.int64)
        pos = np.arange(self.period)
        for l in range(self.n_layers):
            total += self.counts_for(l, pos)
        return _imbalance(total, _metric_shards(ep, self.n_experts))

    # ---- compatibility ----
    def check_model(self, spec) -> "ExpertRoutingTrace":
        """Raise unless this trace can route ``spec`` (a ``ModelSpec`` or
        an ``ArchConfig.moe``-carrying config): expert count and top-k are
        structural — a mismatched table would silently clamp ids."""
        n_experts = getattr(spec, "moe_experts", None)
        top_k = getattr(spec, "moe_top_k", None)
        if n_experts is None and getattr(spec, "moe", None) is not None:
            n_experts = spec.moe.n_experts
            top_k = spec.moe.top_k
        if not n_experts:
            raise ValueError(
                f"routing trace {self.model!r} applied to a non-MoE model "
                f"{getattr(spec, 'name', spec)!r}")
        if (self.n_experts, self.top_k) != (n_experts, top_k):
            raise ValueError(
                f"routing trace {self.model!r} has {self.n_experts} "
                f"experts top-{self.top_k}, but model "
                f"{getattr(spec, 'name', spec)!r} routes "
                f"{n_experts} experts top-{top_k}")
        return self

    # ---- validation ----
    def validate(self) -> "ExpertRoutingTrace":
        if self.n_experts < 1 or self.top_k < 1:
            raise ValueError(
                f"ExpertRoutingTrace needs n_experts >= 1 and top_k >= 1, "
                f"got {self.n_experts}/{self.top_k}")
        if self.top_k > self.n_experts:
            raise ValueError(
                f"top_k={self.top_k} exceeds n_experts={self.n_experts}")
        if not self.layers:
            raise ValueError("ExpertRoutingTrace has no layer tables")
        period = self.period
        for l, table in enumerate(self.layers):
            table = np.asarray(table)
            if table.ndim != 2 or table.shape != (period, self.top_k):
                raise ValueError(
                    f"layer {l}: assignment table shape {table.shape} != "
                    f"({period}, {self.top_k})")
            if table.size and (table.min() < 0
                               or table.max() >= self.n_experts):
                raise ValueError(
                    f"layer {l}: expert id out of range [0, "
                    f"{self.n_experts}) in assignment table")
        return self

    # ---- io ----
    def to_doc(self) -> Dict:
        return {
            "schema": SCHEMA_VERSION,
            "model": self.model,
            "n_experts": int(self.n_experts),
            "top_k": int(self.top_k),
            "layers": [{"layer": l,
                        "assignments": np.asarray(t, int).tolist()}
                       for l, t in enumerate(self.layers)],
            "meta": self.meta,
        }

    def to_json(self) -> str:
        """Canonical serialization — byte-identical for identical traces
        (the determinism contract the skew generators are tested on)."""
        return json.dumps(self.to_doc(), sort_keys=True,
                          separators=(",", ":"))

    def save(self, path: str) -> str:
        self.validate()
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        with open(path, "w") as f:
            json.dump(self.to_doc(), f, indent=1, sort_keys=True)
        return path

    @classmethod
    def load(cls, path: str) -> "ExpertRoutingTrace":
        with open(path) as f:
            doc = json.load(f)
        schema = doc.get("schema")
        if schema not in READABLE_SCHEMAS:
            raise ValueError(
                f"{path}: unsupported expert-routing schema {schema!r} "
                f"(this build reads {READABLE_SCHEMAS!r})")
        for key in ("n_experts", "top_k"):
            if key not in doc:
                raise ValueError(f"{path}: missing required key {key!r}")
        if schema == "moetrace/1":
            # legacy: one table shared by every MoE layer
            if "assignments" not in doc:
                raise ValueError(
                    f"{path}: missing required key 'assignments'")
            table = np.asarray(doc["assignments"], np.int32)
            n_layers = int(doc.get("n_layers", 1))
            layers = [table.copy() for _ in range(max(n_layers, 1))]
        else:
            raw = doc.get("layers")
            if not raw:
                raise ValueError(f"{path}: missing required key 'layers'")
            raw = sorted(raw, key=lambda g: int(g.get("layer", 0)))
            layers = [np.asarray(g["assignments"], np.int32) for g in raw]
        trace = cls(model=doc.get("model", "*"),
                    n_experts=int(doc["n_experts"]),
                    top_k=int(doc["top_k"]),
                    layers=layers, meta=doc.get("meta", {}))
        return trace.validate()


class ExpertLoadTracker:
    """Uniform expert-load accounting for both execution backends.

    Each backend calls ``observe(positions, now)`` once per executed
    iteration with the KV positions of the workload tokens it processed;
    the tracker maps them through the routing trace (the same table the
    real engine's replay hook forces in-graph) into per-layer per-expert
    token counts, an imbalance factor over the instance's expert-parallel
    sharding, and a bounded hot-expert occupancy timeline.  The parity
    suite pins that sim and real produce identical counts.
    """

    def __init__(self, trace: ExpertRoutingTrace, ep: int = 1,
                 timeline_len: int = 4096,
                 capacity_factor: Optional[float] = None):
        self.trace = trace
        self.ep = max(int(ep), 1)
        self.capacity_factor = capacity_factor
        self.counts = np.zeros((trace.n_layers, trace.n_experts), np.int64)
        self.tokens = 0
        # capacity-overflow accounting: routed (token, expert) entries
        # exceeding the per-iteration expert capacity C = round(T *
        # top_k * cf / E) at the iteration's *workload* token count —
        # the one definition in ``repro.core.expert.expert_capacity``,
        # computed identically on both backends, so the metric is
        # backend-parity by construction.  It models what capacity-
        # exact top-k dispatch drops for this workload; the real
        # engine's jitted buffers compute C over the padded batch width
        # instead, so its physical drop count can be lower when slots
        # are padded (same formula, different T).
        self.dropped = 0
        self.routed = 0
        # (t, hot expert id, hot expert's share of this iteration's load)
        self.hot_timeline = deque(maxlen=timeline_len)

    def observe(self, positions: Sequence[int], now: float):
        pos = np.asarray(positions, np.int64).reshape(-1)
        if pos.size == 0:
            return
        self.observe_counts(
            [self.trace.counts_for(l, pos)
             for l in range(self.trace.n_layers)], int(pos.size), now)

    def observe_counts(self, per_layer_counts, tokens: int, now: float):
        """Record one iteration from already-derived per-layer counts —
        lets the sim backend share the counts its perf model priced with
        instead of recomputing the same bincounts per iteration."""
        if not tokens:
            return
        cap = None
        if self.capacity_factor:
            from repro.core.expert import expert_capacity
            cap = expert_capacity(int(tokens), self.trace.top_k,
                                  self.trace.n_experts,
                                  self.capacity_factor)
        iter_counts = np.zeros(self.trace.n_experts, np.int64)
        for l, c in enumerate(per_layer_counts):
            self.counts[l] += c
            iter_counts += c
            if cap is not None:
                self.dropped += int(np.maximum(
                    np.asarray(c, np.int64) - cap, 0).sum())
                self.routed += int(np.asarray(c, np.int64).sum())
        self.tokens += int(tokens)
        hot = int(iter_counts.argmax())
        self.hot_timeline.append(
            (float(now), hot,
             float(iter_counts[hot] / max(iter_counts.sum(), 1))))

    def metrics(self) -> Dict:
        total = self.counts.sum(axis=0)
        shards = _metric_shards(self.ep, self.trace.n_experts)
        return {
            "counts": self.counts.tolist(),
            "tokens": int(self.tokens),
            "imbalance": _imbalance(total, shards),
            "per_layer_imbalance": [_imbalance(c, shards)
                                    for c in self.counts],
            "hot_expert": int(total.argmax()) if total.sum() else None,
            "hot_timeline": list(self.hot_timeline),
            # capacity-overflow drops (0.0 when no capacity_factor set;
            # "routed" is the denominator — (token, expert) entries that
            # went through capacity-checked dispatch)
            "dropped": int(self.dropped),
            "routed": int(self.routed),
            "drop_rate": self.dropped / max(self.routed, 1),
        }
