"""Trace-driven MoE expert routing: one artifact, two engines.

``repro.moe`` owns the portable representation of "which experts did each
token hit" (the MoE analogue of ``repro.hw``'s "how fast is this device"):

* :class:`ExpertRoutingTrace` — versioned JSON artifact: per-MoE-layer
  top-k assignment table over bucketed token positions.  Recorded from
  real ``JaxBackend`` runs or synthesized by the parameterized skew
  generators in ``repro.workload.expert_skew``.
* :class:`ExpertLoadTracker` — the uniform expert-load metrics accounting
  (per-expert counts, imbalance factor, hot-expert timeline) both
  execution backends report through ``metrics()["expert_load"]``.
* :class:`RoutingRegistry` / :func:`resolve_routing` — name resolution for
  ``MoECfg.routing_trace``, mirroring ``InstanceCfg.hw_name``.

This package is jax-free; the real-engine side lives in ``repro.moe.hooks``
(injectable routing hooks: forced assignment / logit bias / recording tap)
and ``repro.moe.record`` (record a trace from an engine run), both of which
import jax lazily.
"""
from repro.moe.registry import (RoutingRegistry, default_routing_registry,
                                get_routing, load_routing, register_routing,
                                resolve_routing)
from repro.moe.trace import (READABLE_SCHEMAS, SCHEMA_VERSION,
                             ExpertLoadTracker, ExpertRoutingTrace,
                             moe_layer_count)

__all__ = [
    "ExpertRoutingTrace", "ExpertLoadTracker", "moe_layer_count",
    "SCHEMA_VERSION", "READABLE_SCHEMAS",
    "RoutingRegistry", "default_routing_registry", "register_routing",
    "get_routing", "load_routing", "resolve_routing",
]
