"""Unified serving driver: router + instances + network + P/D wiring +
failure injection + elastic scaling, parameterized by execution backend.

``ServingRuntime`` owns the serving semantics once; the backend factory
decides whether instances are priced (``SimBackend``) or really executed
(``JaxBackend``).  ``repro.core.Cluster`` and ``repro.serve.ServeDriver``
are thin wrappers choosing a factory.

Every instance — whether built at construction time or added later via
``add_instance`` — goes through one ``_build_instance`` path, so elastic
scale-out instances join the shared global prefix cache and get P/D handoff
wiring exactly like their siblings (previously they silently got neither).
"""
from __future__ import annotations

import dataclasses
import time
from typing import TYPE_CHECKING, Callable, Dict, List, Optional, Sequence

from repro.core.config import ClusterCfg, InstanceCfg
from repro.core.engine import EventQueue
from repro.core.metrics import (aggregate, merge_expert_load,
                                merge_kv_tiers, merge_spec_decode,
                                tenant_rollup)
from repro.core.network import NetworkModel
from repro.core.request import QUEUED, SimRequest
from repro.core.trace import Trace, TraceRegistry
from repro.obs.events import ARRIVAL, FAIL, PD_EXPORT, PREEMPT, SCALE
from repro.runtime.backend import ExecutionBackend
from repro.runtime.instance import RuntimeInstance
from repro.runtime.prefix_cache import RadixPrefixCache
from repro.runtime.router import GlobalRouter

if TYPE_CHECKING:
    from repro.hw.registry import HardwareRegistry

BackendFactory = Callable[[InstanceCfg, Optional[Trace]], ExecutionBackend]


class ServingRuntime:
    """The one cluster driver (both backends): arrivals -> router ->
    instances -> completion, plus P/D KV handoff over the network model,
    failure injection, and elastic scale-out.

    ``backend_factory(icfg, trace)`` decides the execution substrate per
    instance; ``traces`` feeds explicit ``InstanceCfg.trace_name`` lookups
    and ``hw`` resolves ``InstanceCfg.hw_name`` through the hardware-trace
    registry (``repro.hw``), defaulting to the process-wide registry.
    """

    def __init__(self, cfg: ClusterCfg, backend_factory: BackendFactory,
                 traces: Optional[TraceRegistry] = None,
                 hw: Optional["HardwareRegistry"] = None,
                 recorder=None):
        self.cfg = cfg
        self.backend_factory = backend_factory
        # event recorder (repro.obs.EventRecorder) — None disables tracing
        # entirely: instances/router/backends keep obs=None and every
        # emission site short-circuits on one attribute load
        self.obs = recorder
        self.queue = EventQueue()
        self.network = NetworkModel(cfg.network)
        self.traces = traces or TraceRegistry()
        # hardware-by-name resolution (InstanceCfg.hw_name): measured
        # HardwareTrace artifacts when loaded, synthetic otherwise.
        # Imported lazily: repro.hw sits above repro.core in the layering,
        # so a cold `import repro.hw` must not re-enter this module.
        if hw is None:
            from repro.hw.registry import default_registry as hw
        self.hw = hw
        self.instances: Dict[str, RuntimeInstance] = {}
        # instances removed by elastic scale-in: kept for metrics (their
        # stats stay visible with a "retired" marker) but out of routing
        self.retired: Dict[str, RuntimeInstance] = {}
        self._shared_cache: Optional[RadixPrefixCache] = None
        # live P/D pool membership — starts from the config map, mutable
        # at runtime via rebalance_pd (the cfg dataclass stays frozen)
        self.pd_map: Dict[str, tuple] = {
            k: tuple(v) for k, v in (cfg.pd_map or {}).items()}
        for icfg in cfg.instances:
            self._build_instance(icfg)
        self._refresh_skippable()
        self.router = GlobalRouter(
            cfg.router, list(self.instances.values()))
        self.router.obs = recorder
        self.finished: List[SimRequest] = []
        self._all_requests: List[SimRequest] = []
        self.autoscaler = None

    def _refresh_skippable(self):
        """Mark iteration events skippable when instances are isolated:
        no P/D wiring (a prefill completion triggers cross-instance KV
        traffic) and no shared prefix cache (a sibling's iteration can
        move shared radix/memory state).  Skippable events don't gate the
        decode fast-forward horizon (``EventQueue.next_barrier_time``)."""
        iso = not self.pd_map and self._shared_cache is None
        for inst in self.instances.values():
            inst.iter_skippable = iso

    # ---- instance construction (init-time AND elastic scale-out) ----
    def _build_instance(self, icfg: InstanceCfg) -> RuntimeInstance:
        trace = (self.traces.get(icfg.trace_name)
                 if icfg.trace_name else None)
        if trace is None and icfg.hw_name:
            hwt = self.hw.resolve(icfg.hw_name, icfg.model,
                                  tp=icfg.parallelism.tp)
            if hwt.spec is not None:
                # the trace carries the device spec: memory model and
                # off-grid analytical fallback price the same hardware
                icfg = dataclasses.replace(icfg, hw=hwt.spec)
            # cached shared view: identical instances share one
            # interpolation index + memo (fleet-scale fast path)
            trace = hwt.shared_trace()
            # the trace also carries the device's interconnect parameters:
            # links between two trace-resolved instances derive bandwidth/
            # latency from the endpoint pair (min-bw rule), so mixed
            # accelerator clusters see per-pair, not cluster-global, links
            self.network.register_endpoint(icfg.name, hwt.interconnect)
        if icfg.hw is None:
            raise ValueError(
                f"instance {icfg.name!r} has no hardware spec: set "
                f"InstanceCfg.hw, or use an hw_name whose trace embeds a "
                f"spec (this one resolved to a spec-less trace)"
                if icfg.hw_name else
                f"instance {icfg.name!r} has no hardware spec: set "
                f"InstanceCfg.hw or an InstanceCfg.hw_name")
        backend = self.backend_factory(icfg, trace)
        cache: Optional[RadixPrefixCache] = None
        if icfg.prefix_cache.enabled:
            if icfg.prefix_cache.scope == "global":
                # global scope: all instances share one radix tree
                if self._shared_cache is None:
                    self._shared_cache = RadixPrefixCache(
                        icfg.prefix_cache, backend.memory,
                        name="global.cache")
                cache = self._shared_cache
            else:
                cache = RadixPrefixCache(icfg.prefix_cache, backend.memory,
                                         name=f"{icfg.name}.cache")
        inst = RuntimeInstance(icfg, self.queue, backend, cache=cache)
        if self.obs is not None:
            inst.attach_obs(self.obs)
        inst.on_request_done = self._on_done
        if self.pd_map.get(icfg.name):
            inst.on_prefill_done = self._handoff
        self.instances[icfg.name] = inst
        return inst

    # ---- P/D disaggregation ----
    def _handoff(self, req: SimRequest, src: RuntimeInstance):
        """Prefill finished on a prefill-role instance: move the KV to the
        least-loaded live decode target and admit there when it lands."""
        names = self.pd_map.get(src.name, ())
        targets = [self.instances[n] for n in names
                   if n in self.instances and self.instances[n].alive]
        if not targets:
            # no live decode target: the request is dropped, but the
            # prefill-side backend state (e.g. the engine slot) must not leak
            src.backend.release(req)
            return
        # decode-throughput-weighted: a faster decode device absorbs
        # proportionally more handoffs (phase-aware counterpart of the
        # hardware_aware arrival policy; identical to least-loaded when
        # the targets are homogeneous)
        tgt = min(targets, key=lambda i: (i.load() + 1.0)
                  / max(i.throughput_estimate("decode"), 1e-9))
        req.decode_instance = tgt.name
        handoff = src.backend.export_kv(req)
        kv_bytes = handoff.nbytes
        if self.cfg.network.kv_transfer_policy == "layerwise_overlap":
            # transfer overlapped with the last prefill layers: only the
            # final layer's KV lands on the critical path
            kv_bytes = kv_bytes / max(src.cfg.model.n_layers, 1)
        done_t = self.network.kv_transfer_done(
            self.queue.now, src.name, tgt.name, kv_bytes)
        obs = self.obs
        if obs is not None:
            obs.emit(self.queue.now, PD_EXPORT, inst=src.name,
                     req=req.req_id, tenant=req.tenant,
                     payload={"target": tgt.name, "bytes": float(kv_bytes),
                              "arrive_t": done_t})
        self.queue.schedule_at(
            done_t, lambda: tgt.admit_decode(req, handoff),
            tag=f"kv:{src.name}->{tgt.name}")

    # ---- lifecycle ----
    def _on_done(self, req: SimRequest, inst: RuntimeInstance):
        self.finished.append(req)

    def submit_workload(self, requests: Sequence):
        for r in requests:
            sim = SimRequest(req_id=r.req_id, arrival=r.arrival,
                             prompt_tokens=list(r.prompt_tokens),
                             output_len=r.output_len, model=r.model,
                             # tenant class identity rides the request end
                             # to end (router -> scheduler -> backends);
                             # getattr keeps bare request objects working
                             tenant=getattr(r, "tenant", "default"),
                             priority=getattr(r, "priority", 0),
                             weight=getattr(r, "weight", 1.0),
                             slo_ttft_ms=getattr(r, "slo_ttft_ms", 2000.0),
                             slo_tpot_ms=getattr(r, "slo_tpot_ms", 200.0))
            self._all_requests.append(sim)
            self.queue.schedule_at(
                r.arrival, lambda s=sim: self._arrive(s), tag="arrival")

    def _arrive(self, req: SimRequest):
        obs = self.obs
        if obs is not None:
            obs.emit(self.queue.now, ARRIVAL, req=req.req_id,
                     tenant=req.tenant,
                     payload={"prompt": req.prompt_len,
                              "output": req.output_len})
        self.router.dispatch(req, self.queue.now)

    # ---- failures / elastic scaling ----
    def inject_failure(self, t: float, instance: str,
                       recover_after: Optional[float] = None):
        def fail():
            inst = self.instances[instance]
            orphans = inst.fail()
            obs = self.obs
            if obs is not None:
                obs.emit(self.queue.now, FAIL, inst=instance,
                         payload={"orphans": len(orphans)})
                for req in orphans:
                    obs.emit(self.queue.now, PREEMPT, inst=instance,
                             req=req.req_id, tenant=req.tenant,
                             payload={"reason": "failure"})
            for req in orphans:
                req.state = QUEUED
                req.cached_prefix = 0
                self.router.dispatch(req, self.queue.now)
        self.queue.schedule_at(t, fail, tag=f"fail:{instance}")
        if recover_after is not None:
            def revive():
                self.instances[instance].revive()
                obs = self.obs
                if obs is not None:
                    obs.emit(self.queue.now, SCALE, inst=instance,
                             payload={"action": "revive"})
            self.queue.schedule_at(t + recover_after, revive,
                                   tag=f"revive:{instance}")

    def add_instance(self, t: float, icfg: InstanceCfg):
        """Elastic scale-out at simulated time t (same wiring as init)."""
        def add():
            inst = self._build_instance(icfg)
            self.router.instances.append(inst)
            obs = self.obs
            if obs is not None:
                obs.emit(self.queue.now, SCALE, inst=icfg.name,
                         payload={"action": "scale_out"})
            # a scale-out instance can flip isolation (e.g. first global-
            # scope cache user): re-derive for the whole fleet.  Events
            # already in the heap keep their old flag; that is safe —
            # a new shared cache is bound to this instance's memory, and
            # only events scheduled after this barrier can touch it.
            self._refresh_skippable()
        self.queue.schedule_at(t, add, tag=f"scale:{icfg.name}")

    def remove_instance(self, t: float, name: str):
        """Elastic scale-in at simulated time t: drain the instance and
        preempt-and-requeue its in-flight work to the surviving fleet.
        An explicit event, hence a decode fast-forward barrier by
        construction — the fast path can never bulk decode iterations
        across the removal.  The caller must leave at least one live
        instance able to serve the orphans (the autoscaler's
        ``min_instances`` guard)."""
        self.queue.schedule_at(t, lambda: self._remove_instance(name),
                               tag=f"scalein:{name}")

    def _remove_instance(self, name: str):
        inst = self.instances.pop(name, None)
        if inst is None:
            return
        orphans = inst.drain()
        if inst in self.router.instances:
            self.router.instances.remove(inst)
        obs = self.obs
        if obs is not None:
            obs.emit(self.queue.now, SCALE, inst=name,
                     payload={"action": "scale_in", "orphans": len(orphans)})
            for req in orphans:
                obs.emit(self.queue.now, PREEMPT, inst=name, req=req.req_id,
                         tenant=req.tenant, payload={"reason": "drain"})
        self.retired[name] = inst
        # late P/D KV transfers already in flight toward this instance
        # restart from prefill elsewhere instead of parking forever
        inst.on_dead_arrival = self._redispatch
        self._refresh_skippable()
        for req in orphans:
            req.state = QUEUED
            req.cached_prefix = 0
            self.router.dispatch(req, self.queue.now)

    def _redispatch(self, req: SimRequest):
        """Full restart of a request whose instance disappeared under it
        (scale-in racing a P/D KV transfer): progress and KV are gone."""
        req.state = QUEUED
        req.cached_prefix = 0
        req.prefill_done_tokens = 0
        req.generated = 0
        req.n_restarts += 1
        self.router.dispatch(req, self.queue.now)

    def rebalance_pd(self, t: float, pd_map: Dict[str, Sequence[str]]):
        """Replace the P/D pool membership at simulated time t (explicit
        event => fast-forward barrier).  Prefill instances named in the
        new map get handoff wiring; ones no longer named lose it.  KV
        transfers already scheduled keep their original target."""
        def apply():
            self.pd_map = {k: tuple(v) for k, v in pd_map.items()}
            for name, inst in self.instances.items():
                inst.on_prefill_done = (self._handoff
                                        if self.pd_map.get(name) else None)
            self._refresh_skippable()
            obs = self.obs
            if obs is not None:
                obs.emit(self.queue.now, SCALE,
                         payload={"action": "rebalance_pd"})
        self.queue.schedule_at(t, apply, tag="rebalance_pd")

    def attach_autoscaler(self, scaler):
        """Wire an SLO-aware autoscaling policy (``repro.runtime.
        autoscale.SLOAutoscaler``) to this runtime: the policy evaluates
        on its cadence via explicit queue events and acts through
        ``add_instance`` / ``remove_instance`` / ``rebalance_pd``, so
        every scaling action is a fast-forward barrier.  Attach before
        ``run``; returns the scaler."""
        self.autoscaler = scaler
        scaler.attach(self)
        return scaler

    # ---- run ----
    def warmup(self):
        for inst in self.instances.values():
            inst.backend.warmup()

    def run(self, until: Optional[float] = None) -> Dict:
        t0 = time.time()
        self.queue.run(until=until)
        wall = time.time() - t0
        m = self.metrics()
        m["sim_wall_s"] = wall
        return m

    def metrics(self) -> Dict:
        m = aggregate(self._all_requests)
        m["sim_events"] = self.queue.n_processed
        m["instances"] = {n: i.stats() for n, i in self.instances.items()}
        # scale-in keeps retired instances visible for accounting (marked,
        # live instances win the name on a reuse collision)
        for name, inst in self.retired.items():
            if name not in m["instances"]:
                m["instances"][name] = {**inst.stats(), "retired": True}
        # per-tenant SLO/goodput rollup — same requests both backends see,
        # so the tenant table is parity-assertable like everything else
        tenants = tenant_rollup(self._all_requests)
        if tenants:
            m["tenants"] = tenants
        if self.autoscaler is not None:
            m["autoscale"] = self.autoscaler.metrics()
        m["network_bytes"] = self.network.stats()
        m["network_links"] = self.network.link_stats()
        # trace-driven MoE: cluster-level expert-load rollup (per-instance
        # detail stays under instances[<name>]["expert_load"]) — reported
        # identically by both backends, pinned by the parity suite
        loads = [s["expert_load"] for s in m["instances"].values()
                 if "expert_load" in s]
        if loads:
            m["expert_load"] = merge_expert_load(loads)
        # trace-driven speculative decoding: same rollup shape (per-
        # instance detail stays under instances[<name>]["spec_decode"])
        specs = [s["spec_decode"] for s in m["instances"].values()
                 if "spec_decode" in s]
        if specs:
            m["spec_decode"] = merge_spec_decode(specs)
        # KV-tier rollup: residency/traffic across the fleet's distinct
        # caches (merge dedupes a shared global-scope cache by name)
        tiers = [s["kv_tiers"] for s in m["instances"].values()
                 if "kv_tiers" in s]
        if tiers:
            m["kv_tiers"] = merge_kv_tiers(tiers)
        # routing introspection is always on (cheap per-arrival counters);
        # the latency-attribution rollup needs the event log, so it only
        # appears when a recorder is attached — keeping tracing-disabled
        # metrics byte-identical to pre-tracing builds
        m["routing"] = self.router.stats()
        if self.obs is not None:
            from repro.obs.attribution import attribution
            m["attribution"] = attribution(self._all_requests, self.obs)
        return m
