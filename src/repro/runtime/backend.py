"""The ``ExecutionBackend`` protocol: what the unified runtime needs from an
execution substrate.

The runtime (scheduler, prefix-cache policy, router, P/D orchestration)
makes every *decision*; a backend turns a decided batch into *time* — and,
for real backends, into actual tokens and KV state.  Two implementations
ship:

* ``repro.runtime.backends.sim.SimBackend`` — prices batches with the
  trace-driven ``PerfModel`` (the discrete-event simulator).
* ``repro.runtime.backends.jax_engine.JaxBackend`` — executes batches with
  jitted prefill/extend/decode over a slot-based KV cache and measures
  wall-clock latency (the real engine; virtual clocks come from the shared
  event queue).
"""
from __future__ import annotations

import dataclasses
from typing import Any, List, Optional, Protocol, runtime_checkable

from repro.core.memory import MemoryModel
from repro.core.request import SimRequest
from repro.runtime.prefix_cache import MatchResult
from repro.runtime.scheduler import ScheduledWork


@dataclasses.dataclass
class KvHandoff:
    """A request's KV leaving one instance for another (P/D handoff).

    ``payload`` is backend-private (None for the simulator; real KV arrays +
    the first sampled token for the JAX engine).  ``nbytes`` is what the
    network model charges for the transfer.
    """
    nbytes: float
    payload: Optional[Any] = None


@runtime_checkable
class ExecutionBackend(Protocol):
    """Everything backend-specific about running one serving instance."""

    name: str
    memory: MemoryModel      # block pool the scheduler ledger draws from

    def warmup(self) -> None:
        """Pre-compile / pre-measure so steady-state latencies are clean."""
        ...

    def prompt_cap(self, req: SimRequest) -> Optional[int]:
        """Max prompt tokens this backend can hold for ``req`` (None =
        unbounded).  The runtime truncates the request on submission so
        scheduler bookkeeping and backend KV state always agree."""
        ...

    def execute(self, work: List[ScheduledWork], now: float) -> float:
        """Run one scheduled iteration; return its latency in seconds."""
        ...

    def on_prefix_hit(self, req: SimRequest, match: MatchResult,
                      usable: int) -> int:
        """A prefix-cache match was found for ``req``.  Return how many
        tokens the backend can actually serve from cache (<= ``usable``)
        and arrange any restore work / fetch pricing."""
        ...

    def on_prefill_complete(self, req: SimRequest) -> None:
        """Prompt fully in KV: persist the prefix payload if caching."""
        ...

    def on_preempt(self, req: SimRequest) -> int:
        """Request preempted; drop its KV.  Return the cached-prefix length
        still restorable when the request is rescheduled."""
        ...

    def release(self, req: SimRequest) -> None:
        """Request finished or left the instance: free backend state."""
        ...

    def export_kv(self, req: SimRequest) -> KvHandoff:
        """P/D: package the request's KV for transfer (frees local state)."""
        ...

    def import_kv(self, req: SimRequest, handoff: Optional[KvHandoff]) \
            -> None:
        """P/D decode side: land transferred KV before decoding starts."""
        ...

    def reset(self) -> None:
        """Instance failure: drop all backend state."""
        ...

    def stats(self) -> dict:
        ...
