"""Radix-tree prefix cache (RadixAttention-style) with multi-tier eviction.

Paper §II-D: each request does a longest-prefix match; hits insert
memory-transfer events (if the blocks live in a lower tier) instead of
prefill compute; after prefill the new prefix is inserted; capacity pressure
evicts leaves down a real HBM -> host -> SSD hierarchy (``PrefixCacheCfg.
host_spill`` / ``ssd_spill``) instead of discarding, with per-tier byte
accounting against the instance's ``MemoryModel`` pools.  Victim selection
is pluggable (``PrefixCacheCfg.eviction_policy``): ``lru``, ``lfu`` and
``priority`` ship registered; :func:`register_eviction_policy` adds more.

Every tier move is recorded as a pending transfer the runtime settles to
the execution backend (``RuntimeInstance._settle_cache``): the simulator
prices it through ``MemoryModel.transfer_time`` + the ``kv_export`` trace
rows, the real ``JaxBackend`` actually moves the stored KV payload
(device jax array -> host numpy -> disk file) so the cost is measured.
Routing probes use :meth:`RadixPrefixCache.peek` — read-only, so candidate
scans never pollute hit-rate metrics or eviction recency.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, List, Optional, Sequence, Tuple, Type

from repro.core.config import PrefixCacheCfg
from repro.core.memory import MemoryModel

#: tier order, hottest first; eviction demotes one step down this chain
#: (skipping disabled tiers) and promotion moves straight back to device
TIERS = ("device", "host", "ssd")
_RANK = {t: i for i, t in enumerate(TIERS)}


class _Node:
    __slots__ = ("key", "children", "parent", "tokens", "tier",
                 "last_access", "accesses", "priority", "ref_count",
                 "node_id")
    _ids = itertools.count()

    def __init__(self, key: Tuple[int, ...], parent: Optional["_Node"]):
        self.key = key                  # token block (length <= block_tokens)
        self.children: Dict[int, "_Node"] = {}
        self.parent = parent
        self.tokens = len(key)
        self.tier = "device"
        self.last_access = 0.0
        self.accesses = 0               # lifetime hit count (LFU signal)
        self.priority = 0               # max tenant priority that touched it
        self.ref_count = 0              # pinned by running requests
        self.node_id = next(self._ids)


@dataclasses.dataclass
class MatchResult:
    tokens: int                      # matched prefix length (tokens)
    device_tokens: int               # portion already in device HBM
    lower_tier_bytes: float          # bytes to fetch from host/ssd
    host_tokens: int = 0             # portion resident in host RAM
    ssd_tokens: int = 0              # portion resident on SSD
    nodes: List[_Node] = dataclasses.field(default_factory=list)


# ---------------------------------------------------------------------------
# eviction-policy registry
# ---------------------------------------------------------------------------

class EvictionPolicy:
    """Victim selection for one eviction: the candidate with the SMALLEST
    ``victim_key`` is evicted first.  Candidates are always unpinned leaf
    nodes of the tier under pressure; ``node_id`` tie-breaks keep the
    choice deterministic (and therefore fast==exact bit-identical)."""
    name = "base"

    def victim_key(self, node: _Node, now: float):
        raise NotImplementedError


_EVICTION_POLICIES: Dict[str, Type[EvictionPolicy]] = {}


def register_eviction_policy(cls: Type[EvictionPolicy]):
    """Make an ``EvictionPolicy`` subclass available (by its ``name``) to
    every ``PrefixCacheCfg``; returns the class (decorator-friendly)."""
    _EVICTION_POLICIES[cls.name] = cls
    return cls


def eviction_policies() -> Tuple[str, ...]:
    return tuple(sorted(_EVICTION_POLICIES))


@register_eviction_policy
class LRUEviction(EvictionPolicy):
    name = "lru"

    def victim_key(self, node, now):
        return (node.last_access, node.node_id)


@register_eviction_policy
class LFUEviction(EvictionPolicy):
    """Least-frequently-used, recency tie-broken: one-shot prefixes evict
    before reused ones even when the reused prefix is momentarily older."""
    name = "lfu"

    def victim_key(self, node, now):
        return (node.accesses, node.last_access, node.node_id)


@register_eviction_policy
class PriorityWeightedEviction(EvictionPolicy):
    """Priority-weighted LRU: blocks only ever touched by low-priority
    tenants evict before any high-priority tenant's, recency within a
    priority class."""
    name = "priority"

    def victim_key(self, node, now):
        return (node.priority, node.last_access, node.node_id)


def node_prefix(node: _Node) -> Tuple[int, ...]:
    """Full token prefix from the root through ``node`` (inclusive) — the
    payload key the real backend's KV store is addressed by."""
    parts = []
    while node is not None and node.parent is not None:
        parts.append(node.key)
        node = node.parent
    return tuple(t for key in reversed(parts) for t in key)


class RadixPrefixCache:
    """Block-granular radix tree over token-id sequences.

    The runtime owns the *policy* (what is matched, inserted, pinned,
    promoted, evicted — per-instance or shared ``scope="global"``);
    backends own the *payloads*: the simulator prices restore/fetch costs
    from the trace (``kv_export``), while ``JaxBackend`` keeps real KV
    slices keyed by prefix and restores them on a hit so only the suffix
    runs ``extend``.  Capacity borrows idle KV-pool blocks from the
    instance's ``MemoryModel``; under pressure the configured eviction
    policy demotes leaves device -> host -> SSD -> drop, with every tier's
    bytes accounted against the matching ``MemoryModel`` pool (the
    invariant ``n_host_blocks * bytes_per_block == mem.host.used`` holds
    at every quiescent point, ditto SSD).  Running requests ``pin``/
    ``unpin`` their matched nodes so shared prefixes are never evicted
    mid-flight.
    """

    def __init__(self, cfg: PrefixCacheCfg, mem: MemoryModel,
                 name: str = "cache"):
        self.cfg = cfg
        self.mem = mem
        self.name = name
        self.root = _Node((), None)
        self.block = cfg.block_tokens
        self.n_device_blocks = 0
        self.n_host_blocks = 0
        self.n_ssd_blocks = 0
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.capacity_blocks = mem.cache_capacity_blocks(
            cfg.capacity_fraction)
        policy = getattr(cfg, "eviction_policy", "lru")
        if policy not in _EVICTION_POLICIES:
            raise ValueError(
                f"unknown eviction policy {policy!r}; registered: "
                f"{sorted(_EVICTION_POLICIES)}")
        self.policy = _EVICTION_POLICIES[policy]()
        # per-tier matched tokens (accounting matches only: peek is free)
        self.tier_hit_tokens: Dict[str, int] = {t: 0 for t in TIERS}
        # cumulative tier moves: "device->host", "host->ssd", promotes
        # ("host->device", "ssd->device") and drops ("<tier>->drop")
        self.tier_transfers: Dict[str, Dict[str, float]] = {}
        # tier moves since the last settle — drained by the runtime and
        # handed to the backend (sim prices them, JaxBackend executes the
        # real payload move); entries are (src, dst, n_bytes, full_prefix)
        self._pending_transfers: List[Tuple[str, str, float,
                                            Tuple[int, ...]]] = []

    # ---- lookup ----
    def _walk(self, tokens: Sequence[int]) -> List[_Node]:
        node = self.root
        matched: List[_Node] = []
        i = 0
        n = len(tokens)
        while i + self.block <= n:
            blk = tuple(tokens[i: i + self.block])
            child = node.children.get(hash(blk))
            if child is None or child.key != blk:
                break
            matched.append(child)
            node = child
            i += self.block
        return matched

    def _result(self, matched: List[_Node]) -> MatchResult:
        dev = host = ssd = 0
        for nd in matched:
            if nd.tier == "device":
                dev += nd.tokens
            elif nd.tier == "host":
                host += nd.tokens
            else:
                ssd += nd.tokens
        return MatchResult(
            tokens=sum(nd.tokens for nd in matched), device_tokens=dev,
            lower_tier_bytes=(host + ssd) * self.mem.kv_bytes_per_token,
            host_tokens=host, ssd_tokens=ssd, nodes=matched)

    def match(self, tokens: Sequence[int], now: float,
              priority: int = 0) -> MatchResult:
        """Longest-prefix match THAT ACCOUNTS: bumps hit/miss counters,
        per-tier hit tokens, recency/frequency/priority on every matched
        node.  Exactly one call per dispatched request (the instance's
        ``submit``); routing probes must use :meth:`peek` instead."""
        matched = self._walk(tokens)
        for nd in matched:
            nd.last_access = now
            nd.accesses += 1
            if priority > nd.priority:
                nd.priority = priority
        if matched:
            self.hits += 1
        else:
            self.misses += 1
        res = self._result(matched)
        self.tier_hit_tokens["device"] += res.device_tokens
        self.tier_hit_tokens["host"] += res.host_tokens
        self.tier_hit_tokens["ssd"] += res.ssd_tokens
        return res

    def peek(self, tokens: Sequence[int]) -> MatchResult:
        """Read-only longest-prefix probe for routing policies: identical
        match semantics to :meth:`match` but touches NO state — no hit/miss
        counters, no recency/frequency bumps — so probing M candidates per
        request leaves accounting and eviction order exactly as if only
        the chosen instance had been consulted."""
        return self._result(self._walk(tokens))

    def pin(self, nodes: List[_Node]):
        for nd in nodes:
            nd.ref_count += 1

    def unpin(self, nodes: List[_Node]):
        for nd in nodes:
            nd.ref_count = max(0, nd.ref_count - 1)

    # ---- insertion ----
    def insert(self, tokens: Sequence[int], now: float,
               priority: int = 0) -> int:
        """Insert prefix blocks; returns #blocks newly placed on device.

        The chain being inserted is temporarily pinned so the evictions a
        reservation triggers can only hit *other* subtrees — the old code
        attached the child before reserving, letting the eviction scan
        select the not-yet-counted node itself (last_access 0.0 made it
        the LRU victim) and corrupt every tier counter."""
        node = self.root
        i = 0
        new_blocks = 0
        n = len(tokens)
        path: List[_Node] = []
        try:
            while i + self.block <= n:
                blk = tuple(tokens[i: i + self.block])
                child = node.children.get(hash(blk))
                if child is None or child.key != blk:
                    child = _Node(blk, node)
                    if not self._reserve_device_block(now):
                        break
                    node.children[hash(blk)] = child
                    new_blocks += 1
                    self.n_device_blocks += 1
                child.last_access = now
                if priority > child.priority:
                    child.priority = priority
                child.ref_count += 1
                path.append(child)
                node = child
                i += self.block
        finally:
            for nd in path:
                nd.ref_count -= 1
        return new_blocks

    def promote(self, nodes: List[_Node], now: float):
        """Bring lower-tier nodes back to device (caller pays transfer —
        the simulator prices the fetch in ``on_prefix_hit``, the real
        backend re-devices the stored payload at settle time)."""
        bpb = self.mem.bytes_per_block
        for nd in nodes:
            if nd.tier == "device":
                continue
            if not self._reserve_device_block(now):
                continue
            src = nd.tier
            if src == "host":
                self.n_host_blocks -= 1
            else:
                self.n_ssd_blocks -= 1
            # the lower-tier copy is released with the move: without this
            # the host pool leaks until host_spill permanently fails
            self.mem.tier_release(src, bpb)
            nd.tier = "device"
            self.n_device_blocks += 1
            self._record(src, "device", bpb, nd)

    # ---- eviction ----
    def _reserve_device_block(self, now: float) -> bool:
        if self.n_device_blocks >= self.capacity_blocks or \
                not self.mem.borrow_for_cache(1):
            if not self._evict_one(now):
                return False
            return self.mem.borrow_for_cache(1)
        return True

    def _victim(self, tier: str) -> Optional[_Node]:
        """Policy-selected unpinned node of ``tier`` with no child at its
        own tier or hotter.  Plain leaves qualify, but so does an
        interior node whose subtree has already spilled past it —
        demoting it keeps every child at-or-below its parent's
        temperature.  Restricting victims to strict leaves instead jams
        the cache: once a chain's tail spills, its interior device
        blocks become permanently unreclaimable and inserts start
        failing while lower tiers sit empty."""
        rank = _RANK[tier]
        best = None
        best_key = None
        stack = [self.root]
        while stack:
            nd = stack.pop()
            stack.extend(nd.children.values())
            if nd is self.root or nd.ref_count > 0 or nd.tier != tier:
                continue
            if any(_RANK[c.tier] <= rank for c in nd.children.values()):
                continue
            key = self.policy.victim_key(nd, 0.0)
            if best is None or key < best_key:
                best, best_key = nd, key
        return best

    def _evict_one(self, now: float) -> bool:
        """Free one DEVICE block: demote the policy's device victim to
        host (then SSD, then drop, per config), evicting lower tiers as
        needed to make room — so sustained pressure cascades device ->
        host -> SSD -> drop instead of silently leaking the host pool."""
        victim = self._victim("device")
        if victim is None:
            return False
        self.evictions += 1
        self.n_device_blocks -= 1
        self.mem.return_from_cache(1)
        self._demote(victim, "device")
        return True

    def _evict_lower(self, tier: str) -> bool:
        """Free one block of a LOWER tier (host/ssd) by demoting its
        policy victim one step further down the chain."""
        victim = self._victim(tier)
        if victim is None:
            return False
        if tier == "host":
            self.n_host_blocks -= 1
        else:
            self.n_ssd_blocks -= 1
        self.mem.tier_release(tier, self.mem.bytes_per_block)
        self._demote(victim, tier)
        return True

    def _demote(self, victim: _Node, src: str):
        """Move an already-released ``src``-tier victim one tier down:
        host for device victims (when enabled), SSD for host victims
        (when enabled), dropping when the next tier is disabled or cannot
        be freed up.  Lower-tier space is made by recursively evicting
        that tier's own victims — each recursion strictly descends the
        tier chain, so it terminates."""
        bpb = self.mem.bytes_per_block
        if src == "device" and self.cfg.host_spill:
            while not self.mem.tier_reserve("host", bpb):
                if not self._evict_lower("host"):
                    break
            else:
                victim.tier = "host"
                self.n_host_blocks += 1
                self._record("device", "host", bpb, victim)
                return
        if src in ("device", "host") and getattr(self.cfg, "ssd_spill",
                                                 False):
            while not self.mem.tier_reserve("ssd", bpb):
                if not self._evict_lower("ssd"):
                    break
            else:
                victim.tier = "ssd"
                self.n_ssd_blocks += 1
                self._record(src, "ssd", bpb, victim)
                return
        self._drop(victim, src)

    def _drop(self, victim: _Node, src: str):
        """Detach ``victim``'s subtree.  The victim's own device/tier
        accounting was already released by the caller; descendants (all
        strictly colder — victim selection guarantees it — and never
        pinned, since pins cover whole root paths) release theirs here.
        """
        parent = victim.parent
        if parent:
            parent.children.pop(hash(victim.key), None)
        bpb = self.mem.bytes_per_block
        self._record(src, "drop", bpb, victim)
        stack = list(victim.children.values())
        while stack:
            nd = stack.pop()
            stack.extend(nd.children.values())
            if nd.tier == "host":
                self.n_host_blocks -= 1
            else:
                self.n_ssd_blocks -= 1
            self.mem.tier_release(nd.tier, bpb)
            self._record(nd.tier, "drop", bpb, nd)

    def _record(self, src: str, dst: str, n_bytes: float, node: _Node):
        key = f"{src}->{dst}"
        t = self.tier_transfers.setdefault(key, {"blocks": 0, "bytes": 0.0})
        t["blocks"] += 1
        t["bytes"] += n_bytes
        self._pending_transfers.append(
            (src, dst, n_bytes, node_prefix(node)))

    def take_transfers(self) -> List[Tuple[str, str, float,
                                           Tuple[int, ...]]]:
        """Drain tier moves recorded since the last settle.  The runtime
        calls this right after every cache-mutating operation and hands
        the moves to the instance's backend, so the instance that caused
        a spill is the one that pays for (sim) or performs (real) it."""
        pending, self._pending_transfers = self._pending_transfers, []
        return pending

    def release_pressure(self, blocks_needed: int, now: float) -> int:
        """Evict until ``blocks_needed`` device blocks were freed."""
        freed = 0
        while freed < blocks_needed and self._evict_one(now):
            freed += 1
        return freed

    # ---- accounting ----
    def check_invariants(self):
        """Tier accounting invariants, asserted by the regression suite:
        per-tier node counts match the counters, and every lower tier's
        byte pool holds exactly ``blocks * bytes_per_block``."""
        counts = {t: 0 for t in TIERS}
        stack = [self.root]
        while stack:
            nd = stack.pop()
            stack.extend(nd.children.values())
            if nd is not self.root:
                counts[nd.tier] += 1
        bpb = self.mem.bytes_per_block
        assert counts["device"] == self.n_device_blocks, \
            (counts, self.n_device_blocks)
        assert counts["host"] == self.n_host_blocks, \
            (counts, self.n_host_blocks)
        assert counts["ssd"] == self.n_ssd_blocks, (counts, self.n_ssd_blocks)
        assert self.n_host_blocks * bpb == self.mem.host.used, \
            (self.n_host_blocks, bpb, self.mem.host.used)
        assert self.n_ssd_blocks * bpb == self.mem.ssd.used, \
            (self.n_ssd_blocks, bpb, self.mem.ssd.used)
        assert self.mem.host.used <= self.mem.host.capacity
        assert self.mem.ssd.used <= self.mem.ssd.capacity

    def residency(self) -> Dict[str, int]:
        return {"device": self.n_device_blocks, "host": self.n_host_blocks,
                "ssd": self.n_ssd_blocks}

    def stats(self) -> dict:
        total = self.hits + self.misses
        return {"hits": self.hits, "misses": self.misses,
                "hit_rate": self.hits / total if total else 0.0,
                "device_blocks": self.n_device_blocks,
                "host_blocks": self.n_host_blocks,
                "ssd_blocks": self.n_ssd_blocks,
                "evictions": self.evictions,
                "eviction_policy": self.policy.name}
