"""Radix-tree prefix cache (RadixAttention-style) with tiered eviction.

Paper §II-D: each request does a longest-prefix match; hits insert
memory-transfer events (if the blocks live in a lower tier) instead of
prefill compute; after prefill the new prefix is inserted; capacity pressure
evicts LRU leaves, spilling to host (and optionally SSD) rather than
discarding. Supports per-instance and global scopes and a pluggable
eviction policy.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.config import PrefixCacheCfg
from repro.core.memory import MemoryModel


class _Node:
    __slots__ = ("key", "children", "parent", "tokens", "tier",
                 "last_access", "ref_count", "node_id")
    _ids = itertools.count()

    def __init__(self, key: Tuple[int, ...], parent: Optional["_Node"]):
        self.key = key                  # token block (length <= block_tokens)
        self.children: Dict[int, "_Node"] = {}
        self.parent = parent
        self.tokens = len(key)
        self.tier = "device"
        self.last_access = 0.0
        self.ref_count = 0              # pinned by running requests
        self.node_id = next(self._ids)


@dataclasses.dataclass
class MatchResult:
    tokens: int                      # matched prefix length (tokens)
    device_tokens: int               # portion already in device HBM
    lower_tier_bytes: float          # bytes to fetch from host/ssd
    nodes: List[_Node] = dataclasses.field(default_factory=list)


class RadixPrefixCache:
    """Block-granular radix tree over token-id sequences.

    The runtime owns the *policy* (what is matched, inserted, pinned,
    promoted, evicted — per-instance or shared ``scope="global"``);
    backends own the *payloads*: the simulator prices restore/fetch costs
    from the trace (``kv_export``), while ``JaxBackend`` keeps real KV
    slices keyed by prefix and restores them on a hit so only the suffix
    runs ``extend``.  Capacity borrows idle KV-pool blocks from the
    instance's ``MemoryModel`` and evicts LRU leaves device->host(->SSD)
    under pressure.  Running requests ``pin``/``unpin`` their matched
    nodes so shared prefixes are never evicted mid-flight.
    """

    def __init__(self, cfg: PrefixCacheCfg, mem: MemoryModel,
                 name: str = "cache"):
        self.cfg = cfg
        self.mem = mem
        self.name = name
        self.root = _Node((), None)
        self.block = cfg.block_tokens
        self.n_device_blocks = 0
        self.n_host_blocks = 0
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.capacity_blocks = mem.cache_capacity_blocks(
            cfg.capacity_fraction)

    # ---- lookup ----
    def match(self, tokens: Sequence[int], now: float) -> MatchResult:
        node = self.root
        matched: List[_Node] = []
        i = 0
        n = len(tokens)
        while i + self.block <= n:
            blk = tuple(tokens[i: i + self.block])
            child = node.children.get(hash(blk))
            if child is None or child.key != blk:
                break
            child.last_access = now
            matched.append(child)
            node = child
            i += self.block
        dev = sum(nd.tokens for nd in matched if nd.tier == "device")
        lower = sum(nd.tokens for nd in matched if nd.tier != "device")
        if matched:
            self.hits += 1
        else:
            self.misses += 1
        return MatchResult(
            tokens=i, device_tokens=dev,
            lower_tier_bytes=lower * self.mem.kv_bytes_per_token,
            nodes=matched)

    def pin(self, nodes: List[_Node]):
        for nd in nodes:
            nd.ref_count += 1

    def unpin(self, nodes: List[_Node]):
        for nd in nodes:
            nd.ref_count = max(0, nd.ref_count - 1)

    # ---- insertion ----
    def insert(self, tokens: Sequence[int], now: float) -> int:
        """Insert prefix blocks; returns #blocks newly placed on device."""
        node = self.root
        i = 0
        new_blocks = 0
        n = len(tokens)
        while i + self.block <= n:
            blk = tuple(tokens[i: i + self.block])
            child = node.children.get(hash(blk))
            if child is None or child.key != blk:
                child = _Node(blk, node)
                node.children[hash(blk)] = child
                if not self._reserve_device_block(now):
                    del node.children[hash(blk)]
                    break
                new_blocks += 1
                self.n_device_blocks += 1
            child.last_access = now
            node = child
            i += self.block
        return new_blocks

    def promote(self, nodes: List[_Node], now: float):
        """Bring lower-tier nodes back to device (caller pays transfer)."""
        for nd in nodes:
            if nd.tier != "device":
                if self._reserve_device_block(now):
                    if nd.tier == "host":
                        self.n_host_blocks -= 1
                    nd.tier = "device"
                    self.n_device_blocks += 1

    # ---- eviction ----
    def _reserve_device_block(self, now: float) -> bool:
        if self.n_device_blocks >= self.capacity_blocks or \
                not self.mem.borrow_for_cache(1):
            if not self._evict_one(now):
                return False
            return self.mem.borrow_for_cache(1)
        return True

    def _evict_one(self, now: float) -> bool:
        """LRU leaf eviction; device -> host spill (or drop)."""
        victim: Optional[_Node] = None
        stack = [self.root]
        while stack:
            nd = stack.pop()
            stack.extend(nd.children.values())
            if nd is self.root or nd.children or nd.ref_count > 0:
                continue
            if nd.tier != "device":
                continue
            if victim is None or nd.last_access < victim.last_access:
                victim = nd
        if victim is None:
            return False
        self.evictions += 1
        self.n_device_blocks -= 1
        self.mem.return_from_cache(1)
        if self.cfg.host_spill and \
                self.mem.host.used + self.mem.bytes_per_block \
                <= self.mem.host.capacity:
            victim.tier = "host"
            self.n_host_blocks += 1
            self.mem.host.used += self.mem.bytes_per_block
        else:
            parent = victim.parent
            if parent:
                parent.children.pop(hash(victim.key), None)
        return True

    def release_pressure(self, blocks_needed: int, now: float) -> int:
        """Evict until ``blocks_needed`` device blocks were freed."""
        freed = 0
        while freed < blocks_needed and self._evict_one(now):
            freed += 1
        return freed

    def stats(self) -> dict:
        total = self.hits + self.misses
        return {"hits": self.hits, "misses": self.misses,
                "hit_rate": self.hits / total if total else 0.0,
                "device_blocks": self.n_device_blocks,
                "host_blocks": self.n_host_blocks,
                "evictions": self.evictions}
