"""Global request router (paper §II-B): lives outside the instances,
dispatches on arrival by pluggable policy. Custom policies subclass
``RoutingPolicy`` and are registered by name.

Backend-agnostic: candidates are ``RuntimeInstance`` objects, so one policy
registry serves both the simulator and the real JAX engine — the paper's
"flexible interface for request routing".
"""
from __future__ import annotations

from typing import TYPE_CHECKING, Dict, List, Type

from repro.core.config import RouterCfg
from repro.core.request import SimRequest

if TYPE_CHECKING:   # instances are duck-typed: .alive/.cfg/.cache/.load()
    from repro.runtime.instance import RuntimeInstance as Instance
else:
    Instance = object


class RoutingPolicy:
    name = "base"

    def choose(self, req: SimRequest, candidates: List["Instance"],
               now: float) -> "Instance":
        raise NotImplementedError


class RoundRobin(RoutingPolicy):
    name = "round_robin"

    def __init__(self):
        self._i = 0

    def choose(self, req, candidates, now):
        inst = candidates[self._i % len(candidates)]
        self._i += 1
        return inst


class LeastLoaded(RoutingPolicy):
    name = "least_loaded"

    def choose(self, req, candidates, now):
        return min(candidates, key=lambda i: i.load())


class PrefixAware(RoutingPolicy):
    """Route to the instance whose prefix cache matches longest; fall back
    to least-loaded when no instance has a meaningful match."""
    name = "prefix_aware"

    def choose(self, req, candidates, now):
        best, best_tokens = None, 0
        for inst in candidates:
            if inst.cache is None:
                continue
            m = inst.cache.match(req.prompt_tokens, now)
            if m.tokens > best_tokens:
                best, best_tokens = inst, m.tokens
        if best is not None and best_tokens >= 32 and \
                best.load() < 4 * min(c.load() for c in candidates) + 8:
            return best
        return min(candidates, key=lambda i: i.load())


_POLICIES: Dict[str, Type[RoutingPolicy]] = {
    p.name: p for p in (RoundRobin, LeastLoaded, PrefixAware)}


def register_policy(cls: Type[RoutingPolicy]):
    _POLICIES[cls.name] = cls
    return cls


class GlobalRouter:
    def __init__(self, cfg: RouterCfg, instances: List["Instance"]):
        self.cfg = cfg
        self.instances = instances
        if cfg.policy not in _POLICIES:
            raise ValueError(
                f"unknown routing policy {cfg.policy!r}; registered: "
                f"{sorted(_POLICIES)}")
        self.policy = _POLICIES[cfg.policy]()
        self.dispatched = 0

    def candidates_for(self, req: SimRequest) -> List["Instance"]:
        cands = [i for i in self.instances if i.alive
                 and i.cfg.role in ("unified", "prefill")]
        if self.cfg.model_affinity:
            matching = [i for i in cands if i.cfg.model.name == req.model
                        or req.model == "default"]
            if matching:
                cands = matching
        if not cands:
            raise RuntimeError("no live instance can serve request "
                               f"{req.req_id} (model {req.model})")
        return cands

    def dispatch(self, req: SimRequest, now: float) -> "Instance":
        inst = self.policy.choose(req, self.candidates_for(req), now)
        self.dispatched += 1
        inst.submit(req)
        return inst
