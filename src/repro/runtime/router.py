"""Global request router (paper §II-B): lives outside the instances,
dispatches on arrival by pluggable policy.

Registered policies (``RouterCfg(policy=<name>)``):

* ``round_robin``    — cycle through live candidates.
* ``least_loaded``   — minimize ``RuntimeInstance.load()`` (queue depth +
  memory pressure).
* ``prefix_aware``   — longest prefix-cache match wins (with a load guard);
  falls back to least-loaded.
* ``kv_residency``   — prefix match discounted by where the matched blocks
  actually live: device-resident tokens count full, host/SSD tokens are
  docked the prefill-equivalent cost of restoring them, so a slow-tier hit
  never beats recomputing on an idle sibling.

All cache probes go through the read-only ``RadixPrefixCache.peek`` —
routing candidates are *inspected*, never *accounted*: hit/miss counters
and eviction recency move only when the chosen instance's ``submit`` runs
the real ``match``.
* ``hardware_aware`` — throughput-weighted least-loaded for heterogeneous
  clusters: queue depth is divided by each instance's measured (or
  trace-estimated) tokens/s, so faster accelerators receive proportionally
  more work (see ``docs/serving-techniques.md``).

Custom policies subclass :class:`RoutingPolicy` and register with
:func:`register_policy`; the name is then valid in any ``RouterCfg``.

Backend-agnostic: candidates are ``RuntimeInstance`` objects, so one policy
registry serves both the simulator and the real JAX engine — the paper's
"flexible interface for request routing".
"""
from __future__ import annotations

from typing import TYPE_CHECKING, Dict, List, Type

from repro.core.config import RouterCfg
from repro.core.request import SimRequest
from repro.obs.events import ROUTE

if TYPE_CHECKING:   # instances are duck-typed: .alive/.cfg/.cache/.load()
    from repro.runtime.instance import RuntimeInstance as Instance
else:
    Instance = object


class RoutingPolicy:
    """One routing decision: pick the instance that serves ``req``.

    ``candidates`` are the live instances able to take the request (role
    and model-affinity filtered).  Policies may inspect ``inst.load()``,
    ``inst.throughput_estimate()``, ``inst.cache`` (prefix match) and
    ``inst.cfg`` — the same signals on both execution backends.
    """
    name = "base"
    #: outcome label of the last ``choose`` call — policies with a
    #: fallback path overwrite it per decision ("prefix" vs "fallback");
    #: ``None`` makes the router count the decision under the policy name
    last_decision = None

    def choose(self, req: SimRequest, candidates: List["Instance"],
               now: float) -> "Instance":
        raise NotImplementedError

    def scores(self, req: SimRequest, candidates: List["Instance"],
               now: float):
        """Per-candidate score map for observability (higher/lower need
        not be comparable across policies — the event payload documents
        intent, not a total order).  Read-only: probes must not bump any
        counters.  ``None`` means the policy has no meaningful score
        (e.g. round-robin).  Only called when event tracing is enabled."""
        return None


class RoundRobin(RoutingPolicy):
    name = "round_robin"

    def __init__(self):
        self._i = 0

    def choose(self, req, candidates, now):
        inst = candidates[self._i % len(candidates)]
        self._i += 1
        return inst


class LeastLoaded(RoutingPolicy):
    name = "least_loaded"

    def choose(self, req, candidates, now):
        return min(candidates, key=lambda i: i.load())

    def scores(self, req, candidates, now):
        return {i.name: i.load() for i in candidates}


class PrefixAware(RoutingPolicy):
    """Route to the instance whose prefix cache matches longest; fall back
    to least-loaded when no instance has a meaningful match."""
    name = "prefix_aware"

    def choose(self, req, candidates, now):
        best, best_tokens = None, 0
        for inst in candidates:
            if inst.cache is None:
                continue
            # read-only probe: a routing scan must not bump hit/miss
            # counters or LRU recency on instances that lose the vote
            m = inst.cache.peek(req.prompt_tokens)
            if m.tokens > best_tokens:
                best, best_tokens = inst, m.tokens
        if best is not None and best_tokens >= 32 and \
                best.load() < 4 * min(c.load() for c in candidates) + 8:
            self.last_decision = "prefix"
            return best
        self.last_decision = "fallback"
        return min(candidates, key=lambda i: i.load())

    def scores(self, req, candidates, now):
        return {i.name: (float(i.cache.peek(req.prompt_tokens).tokens)
                         if i.cache is not None else 0.0)
                for i in candidates}


class KvResidency(RoutingPolicy):
    """Residency-aware prefix routing: a match is worth its *device*
    tokens plus lower-tier tokens discounted by what restoring them
    costs.  The discount converts the tier-fetch time (``MemoryModel.
    transfer_time`` over the matched host/SSD bytes) into prefill-token
    equivalents via the instance's prefill throughput estimate — so a
    3 GB/s SSD hit on a busy instance loses to plain recompute on an
    idle one, while an HBM-resident match still wins outright.  Probes
    are read-only (``peek``); the same load guard as ``prefix_aware``
    keeps a hot cache from starving the rest of the fleet."""
    name = "kv_residency"

    @staticmethod
    def _effective_tokens(inst, req) -> float:
        if inst.cache is None:
            return 0.0
        m = inst.cache.peek(req.prompt_tokens)
        if m.tokens <= 0:
            return 0.0
        kb = inst.mem.kv_bytes_per_token
        restore_s = 0.0
        if m.host_tokens:
            restore_s += inst.mem.transfer_time(
                m.host_tokens * kb, "host", "device")
        if m.ssd_tokens:
            restore_s += inst.mem.transfer_time(
                m.ssd_tokens * kb, "ssd", "device")
        return m.tokens - restore_s * inst.throughput_estimate("prefill")

    def choose(self, req, candidates, now):
        best, best_eff = None, 0.0
        for inst in candidates:
            eff = self._effective_tokens(inst, req)
            if eff > best_eff:
                best, best_eff = inst, eff
        if best is not None and best_eff >= 32 and \
                best.load() < 4 * min(c.load() for c in candidates) + 8:
            self.last_decision = "residency"
            return best
        self.last_decision = "fallback"
        return min(candidates, key=lambda i: i.load())

    def scores(self, req, candidates, now):
        return {i.name: self._effective_tokens(i, req) for i in candidates}


class HardwareAware(RoutingPolicy):
    """Throughput-weighted least-loaded for mixed-accelerator clusters.

    Each candidate's queue depth is normalized by its tokens/s estimate
    (observed once the instance has run enough iterations, otherwise the
    backend's trace-priced hint), so a TPU-class instance that decodes 5x
    faster than a GPU-class sibling absorbs ~5x the queue before the router
    prefers the slower device.

    The estimate is phase-aware: a prefill-role instance (P/D
    disaggregation) is rated by its *prefill* throughput — arrival routing
    only ever hands it prefill work — instead of the blended
    prefill+decode reference batch.  Decode-side placement uses the decode
    estimate symmetrically (``ServingRuntime._handoff``).
    """
    name = "hardware_aware"

    @staticmethod
    def _score(inst) -> float:
        phase = "prefill" if inst.cfg.role == "prefill" else None
        return (inst.load() + 1.0) / max(
            inst.throughput_estimate(phase), 1e-9)

    def choose(self, req, candidates, now):
        return min(candidates, key=self._score)

    def scores(self, req, candidates, now):
        return {i.name: self._score(i) for i in candidates}


_POLICIES: Dict[str, Type[RoutingPolicy]] = {
    p.name: p for p in (RoundRobin, LeastLoaded, PrefixAware,
                        KvResidency, HardwareAware)}


def register_policy(cls: Type[RoutingPolicy]):
    """Make a ``RoutingPolicy`` subclass available (by its ``name``) to
    every ``RouterCfg`` on both backends; returns the class (decorator)."""
    _POLICIES[cls.name] = cls
    return cls


class GlobalRouter:
    """Cluster-level dispatcher: filters live candidates (role and model
    affinity), then delegates the choice to the configured policy."""

    def __init__(self, cfg: RouterCfg, instances: List["Instance"]):
        self.cfg = cfg
        self.instances = instances
        if cfg.policy not in _POLICIES:
            raise ValueError(
                f"unknown routing policy {cfg.policy!r}; registered: "
                f"{sorted(_POLICIES)}")
        self.policy = _POLICIES[cfg.policy]()
        self.dispatched = 0
        # per-outcome decision counts (always on: one dict bump per
        # arrival) — surfaced as metrics()["routing"]
        self.decision_counts: Dict[str, int] = {}
        # event recorder (None = tracing disabled)
        self.obs = None

    def candidates_for(self, req: SimRequest) -> List["Instance"]:
        cands = [i for i in self.instances if i.alive
                 and i.cfg.role in ("unified", "prefill")]
        if self.cfg.model_affinity:
            matching = [i for i in cands if i.cfg.model.name == req.model
                        or req.model == "default"]
            if matching:
                cands = matching
        if not cands:
            raise RuntimeError("no live instance can serve request "
                               f"{req.req_id} (model {req.model})")
        return cands

    def dispatch(self, req: SimRequest, now: float) -> "Instance":
        policy = self.policy
        policy.last_decision = None
        cands = self.candidates_for(req)
        inst = policy.choose(req, cands, now)
        label = policy.last_decision or policy.name
        self.decision_counts[label] = self.decision_counts.get(label, 0) + 1
        self.dispatched += 1
        obs = self.obs
        if obs is not None:
            obs.emit(now, ROUTE, req=req.req_id, tenant=req.tenant,
                     payload={"policy": policy.name, "chosen": inst.name,
                              "decision": label,
                              "scores": policy.scores(req, cands, now)})
        inst.submit(req)
        return inst

    def stats(self) -> dict:
        return {"policy": self.cfg.policy,
                "dispatched": self.dispatched,
                "decisions": dict(self.decision_counts)}
