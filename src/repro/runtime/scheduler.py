"""Iteration-level batch scheduler (vLLM-style continuous batching).

Backend-agnostic: each call to ``next_batch`` composes one engine iteration
from the running set + waiting queue under token/size budgets, with optional
chunked prefill (Sarathi-style) and preemption on memory pressure.  The same
instance drives both the discrete-event simulator and the real JAX engine —
backends only differ in how the returned ``ScheduledWork`` list is executed.

Preemption policy: memory pressure from decode growth recycles the longest-
context running request (its KV is freed; it restarts from the prefix cache
/ full prefill).  Requests whose work is already composed into the current
batch are never evicted mid-composition, and new admissions defer to
in-flight work rather than evicting it — mutual eviction livelocks.

KV block accounting is exact: every admission records its reservation in a
per-request ledger, decode extensions grow the reservation as the context
grows, and completion/preemption/requeue free exactly what was reserved —
never ``context + output//4`` recomputed after the fact (which silently
over-freed the pool as decode advanced).
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
from typing import Callable, Dict, Iterator, List, Optional

import numpy as np

from repro.core.config import SchedulerCfg
from repro.core.memory import MemoryModel
from repro.core.perfmodel import BatchItem
from repro.core.request import (DECODING, PREFILLING, QUEUED, SimRequest)


@dataclasses.dataclass
class ScheduledWork:
    request: SimRequest
    tokens: int
    phase: str


#: scheduling policies the wait queue understands; anything else is a
#: config error and is rejected loudly at scheduler construction time
#: (``policy="priority"`` silently degrading to arrival order was a bug).
POLICIES = ("fcfs", "sjf", "priority")

#: ``push_front`` key — sorts before any normal entry under every policy
#: (priority keys are ``-req.priority``, so plain ``-1`` would let a
#: priority>=1 request overtake a preempted one).
_FRONT_KEY = -(1 << 62)


class WaitQueue:
    """Policy-ordered wait queue.

    A single heap replaces the old re-sort-the-whole-deque-per-enqueue SJF
    path: O(log n) per push instead of O(n log n).  ``push_front`` (preempted
    requests go back to the head) sorts before every normal entry, LIFO among
    themselves, matching the old ``appendleft`` semantics.
    """

    def __init__(self, policy: str = "fcfs"):
        if policy not in POLICIES:
            raise ValueError(
                f"unknown scheduler policy {policy!r}; valid policies: "
                f"{', '.join(POLICIES)}")
        self.policy = policy
        self._heap: List[tuple] = []
        self._seq = itertools.count()

    def _key(self, req: SimRequest) -> int:
        if self.policy == "sjf":
            return req.remaining_prefill        # shortest prompt first
        if self.policy == "priority":
            return -req.priority                # tenant priority, then arrival
        return 0                                # fcfs: arrival order

    def push(self, req: SimRequest):
        heapq.heappush(self._heap, (self._key(req), next(self._seq), req))

    def push_front(self, req: SimRequest):
        heapq.heappush(self._heap, (_FRONT_KEY, -next(self._seq), req))

    def peek(self) -> SimRequest:
        return self._heap[0][2]

    def pop(self) -> SimRequest:
        return heapq.heappop(self._heap)[2]

    def remove(self, req: SimRequest):
        """Remove a specific queued request (the share guard admits from
        the middle of the heap).  ``remove(peek())`` == ``pop()``."""
        for i, entry in enumerate(self._heap):
            if entry[2] is req:
                last = self._heap.pop()
                if i < len(self._heap):
                    self._heap[i] = last
                    heapq.heapify(self._heap)
                return
        raise ValueError(f"request {req.req_id} not in wait queue")

    def entries(self) -> List[tuple]:
        """Raw ``(key, seq, request)`` heap entries (policy order is NOT
        the list order; compare the key tuples)."""
        return self._heap

    def clear(self):
        self._heap.clear()

    def __len__(self) -> int:
        return len(self._heap)

    def __bool__(self) -> bool:
        return bool(self._heap)

    def __iter__(self) -> Iterator[SimRequest]:
        return (entry[2] for entry in self._heap)


class BatchScheduler:
    """The unified iteration scheduler (one per instance, both backends).

    ``next_batch()`` composes one engine iteration: decode steps for the
    running set first, then continuation chunks for in-flight prefills,
    then new admissions — under ``max_batch_tokens``/``max_batch_size``
    budgets with exact KV-block reservations.  The returned
    ``ScheduledWork`` list is what an ``ExecutionBackend`` prices (sim) or
    really executes (JAX engine); ``complete``/``requeue_all`` close the
    ledger.  See the module docstring for preemption and accounting
    invariants.
    """

    def __init__(self, cfg: SchedulerCfg, mem: MemoryModel):
        self.cfg = cfg
        self.mem = mem
        self.waiting = WaitQueue(cfg.policy)
        self.running: List[SimRequest] = []
        self.n_preemptions = 0
        # exact KV accounting: req_id -> blocks currently reserved
        self._reserved: Dict[int, int] = {}
        # per-tenant service: tokens scheduled so far (prefill + decode),
        # the signal the weighted-share starvation guard compares and the
        # per-tenant service split instance stats expose.  Decode
        # fast-forward replays the stepped increments via
        # ``account_window`` so both modes read identical counters.
        self.served_tokens: Dict[str, int] = {}
        # wired by the instance: free backend-side state on preemption
        self.on_preempt: Optional[Callable[[SimRequest], None]] = None
        # wired by the instance only when event tracing is enabled:
        # fires once per waiting->running admission (P/D remote admits
        # are reported separately as pd_admit events)
        self.on_admit: Optional[Callable[[SimRequest], None]] = None

    def enqueue(self, req: SimRequest):
        self.waiting.push(req)

    # ---- per-tenant service accounting ----
    def _account(self, work: List[ScheduledWork]):
        for w in work:
            t = w.request.tenant
            self.served_tokens[t] = self.served_tokens.get(t, 0) + w.tokens

    def account_window(self, work: List[ScheduledWork], extra_steps: int):
        """Decode fast-forward replay: a window of ``n`` identical decode
        steps was composed once but stands for ``n`` stepped ``next_batch``
        calls; add the ``n - 1`` uncomposed steps' service so the counters
        match the stepped path exactly (integer adds — bit-identical)."""
        for w in work:
            t = w.request.tenant
            self.served_tokens[t] = (self.served_tokens.get(t, 0)
                                     + w.tokens * extra_steps)

    def _pick_admission(self) -> SimRequest:
        """Next admission candidate (left in the queue until the KV
        reservation succeeds).  Normally the policy head; under
        ``policy="priority"`` with ``share_guard_tokens > 0`` a starved
        tenant — one whose weight-normalized service lags the head
        tenant's by at least the guard — is admitted first (earliest of
        its queued requests), bounding priority starvation."""
        head = self.waiting.peek()
        guard = self.cfg.share_guard_tokens
        if guard <= 0 or self.cfg.policy != "priority":
            return head
        best: Dict[str, tuple] = {}     # tenant -> best (key, seq, req)
        for entry in self.waiting.entries():
            t = entry[2].tenant
            if t not in best or entry[:2] < best[t][:2]:
                best[t] = entry
        if len(best) < 2:
            return head

        def normalized(t: str) -> float:
            return self.served_tokens.get(t, 0) / max(best[t][2].weight,
                                                      1e-9)

        starved = min(best, key=lambda t: (normalized(t), t))
        if starved != head.tenant and \
                normalized(starved) + guard <= normalized(head.tenant):
            return best[starved][2]
        return head

    # ---- KV block ledger ----
    def _reserve_tokens(self, req: SimRequest, tokens: int) -> bool:
        """Grow ``req``'s reservation to cover ``tokens``; True on success."""
        need = self.mem.blocks_for(tokens)
        have = self._reserved.get(req.req_id, 0)
        if need <= have:
            return True
        if not self.mem.allocate_blocks(need - have):
            return False
        self._reserved[req.req_id] = need
        req.kv_blocks_peak = max(req.kv_blocks_peak, need)
        return True

    def _release(self, req: SimRequest):
        blocks = self._reserved.pop(req.req_id, 0)
        if blocks:
            self.mem.release_blocks(blocks)

    def reserved_blocks(self, req: SimRequest) -> int:
        return self._reserved.get(req.req_id, 0)

    def occupancy(self) -> Dict[int, int]:
        """Ledger snapshot: req_id -> KV blocks currently reserved (the
        per-request occupancy ``Metrics`` exposes for watermark plots)."""
        return dict(self._reserved)

    def _try_admit(self, req: SimRequest) -> bool:
        """Reserve KV blocks for prompt + a slice of the expected output."""
        need = req.remaining_prefill + req.cached_prefix + req.output_len // 4
        return self._reserve_tokens(req, need)

    def _tokens_held(self, req: SimRequest) -> int:
        """Tokens whose KV this request holds right now."""
        return req.cached_prefix + req.prefill_done_tokens + req.generated

    def _preempt_one(self, protected=()) -> Optional[SimRequest]:
        """Evict the longest-context running request not in ``protected``
        (requests already scheduled in the batch being composed must never
        be preempted: their work items are about to execute)."""
        pool = [r for r in self.running if r not in protected]
        if not pool:
            return None
        victim = max(pool, key=lambda r: r.context_len)
        self._preempt(victim)
        return victim

    def _preempt(self, victim: SimRequest):
        self.running.remove(victim)
        self._release(victim)
        victim.state = QUEUED
        victim.n_preemptions += 1
        victim.prefill_done_tokens = 0
        victim.generated = 0        # conservatively restart decoding state
        if self.on_preempt is not None:
            self.on_preempt(victim)
        self.waiting.push_front(victim)
        self.n_preemptions += 1

    def _ensure_decode_capacity(self, req: SimRequest, protected) -> bool:
        """Grow the reservation for the next decode step; preempt (others
        first, then ``req`` itself) under memory pressure.  A step writes
        up to ``decode_tokens`` KV entries (1 classically; the k-draft +
        bonus verification window under speculative decoding), so the
        ledger reserves the full window even though acceptance may emit
        fewer — the backend really writes that many rows before rollback."""
        need = self._tokens_held(req) + max(self.cfg.decode_tokens, 1)
        while not self._reserve_tokens(req, need):
            if self._preempt_one(protected=protected) is None:
                self._preempt(req)
                return False
        return True

    def next_batch(self) -> List[ScheduledWork]:
        cfg = self.cfg
        if cfg.prefill_exclusive:
            return self._next_batch_exclusive()
        work: List[ScheduledWork] = []
        scheduled: List[SimRequest] = []   # never preempt these: their work
        tokens_left = cfg.max_batch_tokens  # items execute this iteration
        dt = max(cfg.decode_tokens, 1)     # decode step width (spec: k + 1)

        # 1. decode steps for all running decode-phase requests
        for req in list(self.running):
            if req.state == DECODING and tokens_left > 0:
                if not self._ensure_decode_capacity(
                        req, protected=scheduled + [req]):
                    continue
                work.append(ScheduledWork(req, dt, "decode"))
                scheduled.append(req)
                tokens_left -= dt

        # 2. continue chunked prefills already running
        for req in list(self.running):
            if req.state == PREFILLING and tokens_left > 0:
                chunk = min(req.remaining_prefill,
                            cfg.prefill_chunk if cfg.chunked_prefill
                            else req.remaining_prefill,
                            tokens_left)
                if chunk > 0:
                    work.append(ScheduledWork(req, chunk, "prefill"))
                    scheduled.append(req)
                    tokens_left -= chunk

        # 3. admit new requests while budget remains
        while self.waiting and tokens_left > 0 and \
                len(self.running) < cfg.max_batch_size:
            req = self._pick_admission()
            if not self._try_admit(req):
                # memory pressure: admission defers to in-flight work (a
                # request already composed into this batch is never evicted
                # for a newcomer — mutual eviction livelocks); preemption
                # recycles memory for decode growth instead, so newcomers
                # wait for completions to free blocks
                if not self.running or \
                        self._preempt_one(protected=scheduled) is None:
                    break
                if not self._try_admit(req):
                    break
            self.waiting.remove(req)
            req.state = PREFILLING
            self.running.append(req)
            if self.on_admit is not None:
                self.on_admit(req)
            chunk = min(req.remaining_prefill,
                        cfg.prefill_chunk if cfg.chunked_prefill
                        else req.remaining_prefill,
                        tokens_left)
            chunk = max(chunk, 0)
            if chunk > 0:
                work.append(ScheduledWork(req, chunk, "prefill"))
                scheduled.append(req)
                tokens_left -= chunk
            elif req.remaining_prefill == 0:
                # fully prefix-cached prompt: go straight to decode
                req.state = DECODING
                work.append(ScheduledWork(req, dt, "decode"))
                scheduled.append(req)
                tokens_left -= dt
        self._account(work)
        return work

    def _next_batch_exclusive(self) -> List[ScheduledWork]:
        """ServingEngine semantics: one whole-prompt prefill OR all decodes."""
        cfg = self.cfg
        if self.waiting and len(self.running) < cfg.max_batch_size:
            req = self._pick_admission()
            if self._try_admit(req):
                self.waiting.remove(req)
                req.state = PREFILLING
                self.running.append(req)
                if self.on_admit is not None:
                    self.on_admit(req)
                n = req.remaining_prefill
                if n > 0:
                    work = [ScheduledWork(req, n, "prefill")]
                    self._account(work)
                    return work
                req.state = DECODING
        work = []
        dt = max(cfg.decode_tokens, 1)
        for req in list(self.running):
            if req.state == DECODING and self._ensure_decode_capacity(
                    req, protected=[w.request for w in work] + [req]):
                work.append(ScheduledWork(req, dt, "decode"))
        self._account(work)
        return work

    # ---- decode fast-forward (see RuntimeInstance._maybe_fast_forward) ----
    def decode_window_steps(self, reqs: List[SimRequest], n_max: int) -> int:
        """Largest ``n <= n_max`` successive decode steps the pool can grow
        into without any reservation failing (so no preemption the slow
        path wouldn't have done either).  Step ``i``'s reservation target
        is ``tokens_held + (i - 1) + decode_tokens`` — exactly what
        ``_ensure_decode_capacity`` would ask for at that step, since every
        step emits one token.  Block demand is monotone in ``n``, so a
        binary search finds the frontier."""
        dt = max(self.cfg.decode_tokens, 1)
        bt = self.mem.block_tokens
        base = [self._tokens_held(r) + dt for r in reqs]
        have = [self._reserved.get(r.req_id, 0) for r in reqs]
        free = self.mem.free_blocks

        def new_blocks(n: int) -> int:
            s = 0
            for b, h in zip(base, have):
                nb = -(-(b + n - 1) // bt) - h
                if nb > 0:
                    s += nb
            return s

        if new_blocks(n_max) <= free:
            return n_max
        lo, hi = 1, n_max
        while lo < hi:
            mid = (lo + hi + 1) // 2
            if new_blocks(mid) <= free:
                lo = mid
            else:
                hi = mid - 1
        return lo

    def decode_window_usage(self, reqs: List[SimRequest],
                            n: int) -> np.ndarray:
        """Pool-usage deltas the window's per-step reservations add:
        element ``i`` (0-based) is blocks-in-use growth after step
        ``i + 1``'s start-of-iteration reservations — what the slow path's
        watermark would have sampled.  Element 0 is always 0 (step 1's
        reservation was made when the batch was composed)."""
        dt = max(self.cfg.decode_tokens, 1)
        bt = self.mem.block_tokens
        base = np.array([self._tokens_held(r) + dt for r in reqs],
                        dtype=np.int64)
        have = np.array([self._reserved.get(r.req_id, 0) for r in reqs],
                        dtype=np.int64)
        steps = np.arange(n, dtype=np.int64)
        need = -(-(base[:, None] + steps[None, :]) // bt)
        return np.maximum(need - have[:, None], 0).sum(axis=0)

    def advance_decode(self, reqs: List[SimRequest], n: int):
        """Apply ``n`` decode steps' ledger growth in one lump.  Growth is
        monotone, so the lump reservation yields the same final ledger,
        pool peak and per-request ``kv_blocks_peak`` as stepping would
        have; feasibility was pre-checked by ``decode_window_steps``."""
        dt = max(self.cfg.decode_tokens, 1)
        for r in reqs:
            if not self._reserve_tokens(r, self._tokens_held(r)
                                        + n - 1 + dt):
                raise RuntimeError(
                    f"fast-forward reservation failed for req "
                    f"{r.req_id} — decode_window_steps over-estimated")

    def admit_remote(self, req: SimRequest, force: bool = False) -> bool:
        """P/D decode-side admission: KV already transferred; reserve blocks
        and join the running set (False when slots/memory are exhausted).
        ``force`` admits on an otherwise-idle scheduler with whatever blocks
        are left (slot capacity is still respected — it is physical)."""
        if len(self.running) >= self.cfg.max_batch_size:
            return False
        tokens = self._tokens_held(req) + req.output_len // 4
        if not self._reserve_tokens(req, tokens):
            if not force:
                return False
            got = min(self.mem.blocks_for(tokens), self.mem.free_blocks)
            if got > 0:
                self.mem.allocate_blocks(got)
            held = self._reserved.get(req.req_id, 0) + got
            self._reserved[req.req_id] = held
            req.kv_blocks_peak = max(req.kv_blocks_peak, held)
        self.running.append(req)
        return True

    def complete(self, req: SimRequest):
        if req in self.running:
            self.running.remove(req)
        self._release(req)

    def requeue_all(self) -> List[SimRequest]:
        """Node failure: return every in-flight request for re-dispatch."""
        out = list(self.running) + list(self.waiting)
        for r in self.running:
            self._release(r)
            r.state = QUEUED
            r.prefill_done_tokens = 0
            r.generated = 0
            r.n_restarts += 1
        self.running.clear()
        self.waiting.clear()
        self._reserved.clear()
        return out

    def to_batch_items(self, work: List[ScheduledWork]) -> List[BatchItem]:
        return to_batch_items(work)


def to_batch_items(work: List[ScheduledWork]) -> List[BatchItem]:
    """PerfModel view of scheduled work (shared by scheduler + SimBackend).
    A decode step's context covers its full verification window
    (``context_len + tokens``; tokens is 1 classically, draft k + 1 under
    speculative decoding)."""
    return [BatchItem(tokens=w.tokens,
                      context=w.request.context_len + w.tokens,
                      phase=w.phase,
                      start=(w.request.cached_prefix
                             + w.request.prefill_done_tokens)
                      if w.phase == "prefill" else 0,
                      completes=(w.phase != "prefill"
                                 or w.tokens >= w.request.remaining_prefill))
            for w in work]
