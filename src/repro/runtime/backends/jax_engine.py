"""Real-execution backend: jitted prefill/extend/decode over slot KV.

Wraps a ``repro.serve.engine.ServingEngine`` purely as a *KV mechanism*
(slot cache, jitted model calls, export/restore plumbing).  All serving
decisions — admission, chunking, decode composition, preemption, prefix
policy, P/D handoff — come from the unified runtime, so the real engine
gains chunked prefill, SJF, preemption and every registered routing policy
for free.

Hybrid emulation is preserved: compute is REAL (wall-clock timed on the
local device), time is VIRTUAL (the runtime's shared event queue advances
by the measured latencies), exactly the paper's §III methodology adapted to
this container.

Chunked prefill maps onto the model API naturally: the first chunk runs the
bucketed ``prefill`` kernel; subsequent chunks ``extend`` the slot's
subcache.  One batched ``decode`` serves all scheduled decode slots per
iteration (the full-buffer decode the engine always ran).
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional

import numpy as np

from repro.core.config import InstanceCfg
from repro.core.memory import MemoryModel
from repro.core.request import SimRequest
from repro.obs.events import SPEC_STEP
from repro.runtime.backend import KvHandoff
from repro.runtime.prefix_cache import MatchResult
from repro.runtime.scheduler import ScheduledWork


class JaxBackend:
    name = "jax"

    def __init__(self, engine, cfg: InstanceCfg):
        # late imports: the sim path must not pay for jax
        import jax  # noqa: F401
        self.eng = engine
        self.cfg = cfg
        self.memory = MemoryModel(cfg)
        self._slot: Dict[int, int] = {}      # req_id -> engine slot
        self._len: Dict[int, int] = {}       # slot   -> tokens held in KV
        self._restore: Dict[int, tuple] = {} # req_id -> (payload, length)
        self._iterations = 0
        # real work done outside execute() (prefix store, P/D export) is
        # wall-timed and charged to the next iteration
        self._carry_s = 0.0
        # event recorder, wired by RuntimeInstance.attach_obs.  The real
        # engine emits the same schema as the sim; restore cost is folded
        # into the wall-timed iteration, so kv_restore reports 0 seconds
        self.obs = None
        self.last_restore_s = 0.0
        # KV-tier accounting: restores counted at match time (mirrors
        # SimBackend), tier moves measured as they execute on the store
        self._restored_tokens = 0
        self._restore_events = 0
        self._tier_moves = 0
        self._tier_move_s = 0.0
        # expert-load accounting for a replayed ExpertRoutingTrace: the
        # engine's replay hook forces every token's assignment in-graph
        # (ServingEngine(routing=trace)); this mirror maps the *executed
        # slot positions* — tracked independently of the scheduler's
        # bookkeeping — through the same table, so the metrics state what
        # really routed and the parity suite can pin sim == real.  The
        # engine's own trace is the only valid source: a cfg-named trace
        # the engine does not replay would make these metrics fiction
        # (the model routed with its learned router), so that mismatch is
        # an error, not a fallback.
        from repro.moe import ExpertLoadTracker, resolve_routing
        self.routing = getattr(engine, "routing_trace", None)
        # output-token capture: req_id -> emitted token ids, in order.
        # Cheap, always on — it is what the greedy-losslessness suite
        # compares (speculative vs vanilla emission, token-for-token).
        self.out_tokens: Dict[int, List[int]] = {}
        # speculative decoding: the engine carries the mechanism (draft
        # engine + verify jit, ServingEngine(spec=...)); this backend
        # orchestrates propose/verify/rollback per scheduled iteration
        # and accounts metrics()["spec_decode"].  Mirrors the MoE rule:
        # a cfg that names spec decoding the engine does not run (or a
        # different acceptance trace than the engine replays) is a hard
        # error, never silently-diverging accounting.
        self.spec = getattr(engine, "spec", None)
        self.spec_tracker = None
        if getattr(cfg.spec, "enabled", False) \
                or getattr(cfg.spec, "acceptance_trace", None):
            if self.spec is None:
                raise ValueError(
                    f"instance {cfg.name!r} configures speculative "
                    f"decoding but its engine has no draft; build it "
                    f"with ServingEngine(spec=SpecDecodeCfg(...)) so the "
                    f"scheduler's multi-token accounting matches what "
                    f"actually executes")
        if self.spec is not None:
            from repro.spec import SpecDecodeTracker, resolve_acceptance
            if cfg.spec.acceptance_trace:
                named = resolve_acceptance(cfg)
                if self.spec.acceptance is None:
                    raise ValueError(
                        f"instance {cfg.name!r} names acceptance_trace="
                        f"{cfg.spec.acceptance_trace!r} but its engine "
                        f"replays no trace; build it with ServingEngine("
                        f"spec=SpecDecodeCfg(acceptance=<trace>)) so the "
                        f"reported spec_decode is what actually ran")
                if named is not self.spec.acceptance \
                        and named.to_json() != self.spec.acceptance.to_json():
                    raise ValueError(
                        f"instance {cfg.name!r} names acceptance_trace="
                        f"{cfg.spec.acceptance_trace!r} but its engine "
                        f"replays a different trace; the accounting "
                        f"table must be the one the engine draws from")
            dt = cfg.scheduler.decode_tokens
            if dt != self.spec.k + 1:
                raise ValueError(
                    f"instance {cfg.name!r} speculates k={self.spec.k} "
                    f"but its scheduler reserves decode_tokens={dt}; set "
                    f"SchedulerCfg(decode_tokens=k + 1) (engine_instance_"
                    f"cfg does this automatically) so the KV ledger "
                    f"covers the verification window")
            self.spec_tracker = SpecDecodeTracker(self.spec.k)
        # spec bookkeeping, all keyed by engine slot and tracked
        # independently of the scheduler (that independence is what the
        # sim/real parity suite tests): token history in target KV,
        # draft KV length, emitted-token count
        self._hist: Dict[int, List[int]] = {}
        self._draft_len: Dict[int, int] = {}
        self._emit: Dict[int, int] = {}
        self._steps: Dict[int, int] = {}     # slot -> spec-step ordinal
        self._emitted: Dict[int, int] = {}   # req_id -> last step's tokens
        if getattr(cfg.moe, "routing_trace", None):
            if self.routing is None:
                raise ValueError(
                    f"instance {cfg.name!r} names routing_trace="
                    f"{cfg.moe.routing_trace!r} but its engine replays no "
                    f"trace; build it with ServingEngine(routing=<trace>) "
                    f"so the reported expert_load is what actually routed")
            named = resolve_routing(cfg)
            if named is not self.routing \
                    and named.to_json() != self.routing.to_json():
                raise ValueError(
                    f"instance {cfg.name!r} names routing_trace="
                    f"{cfg.moe.routing_trace!r} but its engine replays a "
                    f"different trace ({self.routing.model!r}); the "
                    f"accounting table must be the one the model executes")
        self.expert_load = ExpertLoadTracker(
            self.routing, ep=cfg.parallelism.ep,
            capacity_factor=engine.cfg.moe.capacity_factor
            if engine.cfg.moe is not None else None) \
            if self.routing is not None else None
        self._routed_pos: List[int] = []     # positions routed this iter

    # ---- helpers ----
    def prompt_cap(self, req: SimRequest) -> int:
        """Slot capacity: prompt + generated output + 1 must fit max_len.
        The runtime truncates the request on submit, so the scheduler's
        chunk plan and the backend's KV state always agree.  Speculative
        decoding additionally writes up to k draft rows past the accepted
        context before rollback, so the window shrinks by k."""
        extra = self.eng.spec.k if self.eng.spec is not None else 0
        return max(self.eng.max_len - req.output_len - 1 - extra, 1)

    def _prompt(self, req: SimRequest) -> List[int]:
        toks = list(req.prompt_tokens)
        cap = self.prompt_cap(req)
        return toks[:cap] if len(toks) > cap else toks

    def warmup(self):
        import jax
        import jax.numpy as jnp
        from repro.serve.engine import _bucket
        eng = self.eng
        eng.warmup()
        sched = self.cfg.scheduler
        if sched.chunked_prefill or eng.radix is not None:
            # chunk 2+ of a chunked prefill (and any prefix-hit suffix)
            # runs the ``extend`` path, which compiles one jit per padded
            # chunk bucket; pre-warm every bucket a chunk can map to so
            # measured latencies are steady-state from the first request
            top = _bucket(min(max(sched.prefill_chunk, 16),
                              eng.max_len - 1)) \
                if sched.chunked_prefill else eng.max_len - 1
            P = 16
            while P <= top and P < eng.max_len:
                pad = jnp.zeros((1, P), jnp.int32)
                try:
                    sub = eng._slot_subcache(0, 16)
                    jax.block_until_ready(eng._jit_extend(
                        eng.params, sub, pad, jnp.asarray([P], jnp.int32)))
                    # the chunk write-back (slot update) compiles once
                    eng._write_slot(0, sub, 16)
                except NotImplementedError:
                    break   # no cached-prefill path (e.g. xLSTM)
                P *= 2
            eng._release_slot(0)
        if eng.radix is not None:
            # pre-compile the slot export/restore jits at every bucket so
            # prefix-cache hits don't pay compile time on the virtual clock
            for blen in (16, 32, 64, 128, 256):
                if blen >= eng.max_len:
                    break
                payload = eng._export_slot(0, blen)
                eng._restore_slot(0, payload, blen)
            eng._release_slot(0)
        if eng.spec is not None:
            # draft prefill/decode buckets + the one verify shape
            eng.draft.warmup()
            vt = jnp.zeros((eng.max_batch, eng.spec.k + 1), jnp.int32)
            n0 = jnp.zeros((eng.max_batch,), jnp.int32)
            jax.block_until_ready(
                eng._jit_verify(eng.params, eng.cache, vt, n0)[0])

    # ---- execution ----
    def execute(self, work: List[ScheduledWork], now: float) -> float:
        import jax
        t0 = time.perf_counter()
        decodes = [w for w in work if w.phase == "decode"]
        prefills = [w for w in work if w.phase == "prefill"]
        if decodes:
            if self.eng.spec is not None:
                self._spec_decode_step(decodes, now)
            else:
                self._decode_step(decodes)
        for w in prefills:
            self._prefill_chunk(w)
        jax.block_until_ready(self.eng.cache)
        self._iterations += 1
        latency = time.perf_counter() - t0 + self._carry_s
        self._carry_s = 0.0
        if self.expert_load is not None:
            self.expert_load.observe(self._routed_pos, now)
            self._routed_pos = []
        return latency

    def _decode_step(self, decodes: List[ScheduledWork]):
        import jax.numpy as jnp
        from repro.serve.sampler import greedy
        eng = self.eng
        tokens = eng._tokens_buf
        for w in decodes:
            # paged KV: the decode writes each scheduled slot's new token
            # at its old length — make sure that page exists (no-op on
            # the contiguous layout)
            slot = self._slot[w.request.req_id]
            eng.ensure_capacity(slot, self._len[slot] + 1)
        if self.routing is not None or self.eng.model.routing_hook \
                is not None:
            # routing-hook runs: mark every NON-scheduled slot (free, or
            # occupied mid-prefill) with the sentinel token -1 so the
            # model's decode mask excludes its row from MoE recording and
            # capacity — the full-buffer decode computes it regardless,
            # but it is not workload routing.  The engine buffer itself
            # is left untouched (mid-prefill slots keep their pending
            # first token).
            tokens = tokens.copy()
            scheduled_slots = {self._slot[w.request.req_id]
                               for w in decodes}
            for slot in range(eng.max_batch):
                if slot not in scheduled_slots:
                    tokens[slot, 0] = -1
        logits, eng.cache = eng._jit_decode(
            eng.params, eng.cache, jnp.asarray(tokens))
        nxt = np.asarray(greedy(logits, eng.cfg.vocab))
        scheduled = set()
        for w in decodes:
            slot = self._slot[w.request.req_id]
            eng._tokens_buf[slot, 0] = int(nxt[slot, 0])
            self.out_tokens.setdefault(w.request.req_id, []).append(
                int(nxt[slot, 0]))
            if self.expert_load is not None:
                # the decode wrote this slot's token at KV index _len
                self._routed_pos.append(self._len[slot])
            self._len[slot] += 1
            scheduled.add(slot)
        hooked = self.routing is not None \
            or eng.model.routing_hook is not None
        if scheduled != set(self._len) \
                or (hooked and len(self._len) < eng.max_batch):
            # the full-buffer decode bumped every slot's length; restore
            # the authoritative lengths of mid-prefill / unscheduled
            # slots.  With a MoE routing hook installed, ALSO zero the
            # free slots every iteration: free slots may otherwise keep
            # garbage lengths (harmless for attention — nothing reads
            # them), but the hook's validity mask identifies an empty
            # slot by its zero length (position 0), and letting the bump
            # accumulate across consecutive decode-only iterations would
            # mark phantom rows valid — contaminating recorded routing
            # traces and letting empty slots consume real tokens' expert
            # capacity under forced replay.  Unhooked engines keep the
            # old fast path.
            lengths = np.zeros((eng.max_batch,), np.int32)
            for s, n in self._len.items():
                lengths[s] = n
            eng.cache["lengths"] = jnp.asarray(lengths)

    def _spec_decode_step(self, decodes: List[ScheduledWork], now: float):
        """One speculative iteration for the scheduled decode set: the
        draft proposes k tokens per slot (k + 1 sequential full-buffer
        draft decodes — the extra call consumes the last proposal so the
        draft KV stays one-pending-token behind, exactly like the
        target), the target verifies all proposals in one batched
        ``verify`` (an extend returning every position's logits), and
        each slot keeps the accepted prefix + the target's bonus token,
        rolling both KV lengths back to the accepted context.

        Acceptance is the true greedy match (lossless) unless the engine
        replays an ``AcceptanceTrace``, in which case the decision is
        forced from the trace's deterministic draw at this slot's emitted
        position — the spec-decode analogue of forced MoE routing, and
        what the sim/real parity suite pins.
        """
        import jax.numpy as jnp
        from repro.serve.sampler import accept_length, greedy
        eng = self.eng
        dr = eng.draft
        k = eng.spec.k
        trace = eng.spec.acceptance
        recorder = eng.spec.recorder

        # 1. draft context sync: (re)build a slot's draft KV from the
        # token history whenever it diverged (first spec step, preemption
        # restart, P/D arrival) — one bucketed draft prefill per slot
        for w in decodes:
            slot = self._slot[w.request.req_id]
            hist = self._hist[slot]
            if self._draft_len.get(slot) != len(hist):
                from repro.serve.engine import _bucket
                P = _bucket(max(len(hist), 1))
                pad = np.zeros((1, P), np.int32)
                pad[0, :len(hist)] = np.asarray(hist, np.int32)
                _, c1 = dr._jit_prefill(
                    dr.params, jnp.asarray(pad),
                    lengths=jnp.asarray([len(hist)], jnp.int32))
                dr._write_slot_from_prefill(slot, c1, len(hist))
                self._draft_len[slot] = len(hist)

        # tail clamp: a request with r = output_len - generated tokens
        # left can emit at most r per step (accepted + bonus), so it only
        # uses min(k, r - 1) drafts.  Clamping the proposal window — not
        # just the emission — keeps the verified positions meaningful and
        # matches SimBackend's pricing of the same step exactly.
        k_eff = {}
        for w in decodes:
            req = w.request
            k_eff[self._slot[req.req_id]] = max(
                0, min(k, req.output_len - req.generated - 1))
        k_step = max(k_eff.values(), default=0)

        # paged KV: verify writes the pending token + k_eff drafts at
        # positions [len, len + k_eff]; the draft's k_step + 1 decodes
        # walk one position per call (no-ops on contiguous layouts)
        for w in decodes:
            slot = self._slot[w.request.req_id]
            eng.ensure_capacity(slot, self._len[slot] + k_eff[slot] + 1)
            dr.ensure_capacity(slot,
                               self._draft_len.get(slot, 0) + k_step + 1)

        # 2. propose: k_step + 1 sequential full-buffer draft decodes
        cur = np.maximum(np.asarray(eng._tokens_buf), 0)
        drafts = np.zeros((eng.max_batch, k_step), np.int32)
        for j in range(k_step + 1):
            dlogits, dr.cache = dr._jit_decode(dr.params, dr.cache,
                                               jnp.asarray(cur))
            cur = np.asarray(greedy(dlogits, eng.cfg.vocab))
            if j < k_step:
                drafts[:, j] = cur[:, 0]

        # 3. batched target verification over [pending, d1..dk_eff]
        vt = np.concatenate(
            [np.maximum(np.asarray(eng._tokens_buf), 0), drafts], axis=1)
        n_new = np.zeros((eng.max_batch,), np.int32)
        for w in decodes:
            slot = self._slot[w.request.req_id]
            n_new[slot] = k_eff[slot] + 1
        vlogits, eng.cache = eng._jit_verify(
            eng.params, eng.cache, jnp.asarray(vt), jnp.asarray(n_new))
        target = np.asarray(greedy(vlogits, eng.cfg.vocab))  # (B, k+1)
        matched = accept_length(drafts, target)

        # 4. acceptance + rollback per scheduled slot
        for w in decodes:
            req = w.request
            slot = self._slot[req.req_id]
            pos = self._emit[slot] - 1       # last emitted token's index
            step = self._steps.get(slot, 0)
            self._steps[slot] = step + 1
            if trace is not None:
                accepted = trace.accepted_for(pos, step)
            else:
                accepted = int(matched[slot])
            # matched/trace draws range over 0..k_step; a slot near its
            # output budget only verified k_eff positions (beyond that the
            # target row is unverified padding), so clamp first
            accepted = min(accepted, k_eff[slot])
            if recorder is not None:
                recorder.observe(pos, min(int(matched[slot]), k_eff[slot]))
            if self.spec_tracker is not None:
                self.spec_tracker.observe(pos, accepted, now,
                                          proposed=k_eff[slot])
            bonus = int(target[slot, accepted])
            emitted = [int(t) for t in drafts[slot, :accepted]] + [bonus]
            remaining = max(req.output_len - req.generated, 1)
            emitted = emitted[:remaining]
            t0 = int(eng._tokens_buf[slot, 0])
            self._hist[slot].extend(
                [t0] + [int(t) for t in drafts[slot, :accepted]])
            self._len[slot] += 1 + accepted
            self._draft_len[slot] += 1 + accepted
            # truncation only happens on the request's final step (its
            # slot is released before any further decode), so the bonus
            # is always the correct next pending token
            eng._tokens_buf[slot, 0] = bonus
            self.out_tokens.setdefault(req.req_id, []).extend(emitted)
            self._emit[slot] += len(emitted)
            self._emitted[req.req_id] = len(emitted)
            if self.obs is not None:
                self.obs.emit(now, SPEC_STEP, inst=self.cfg.name,
                              req=req.req_id, tenant=req.tenant,
                              payload={"accepted": int(accepted),
                                       "proposed": int(k_eff[slot])})

        # 5. restore authoritative lengths on both caches: verify bumped
        # scheduled slots to the full window; draft decodes bumped every
        # row.  Unaccepted rows become dead weight overwritten by the
        # next write at the same indices.
        lengths = np.zeros((eng.max_batch,), np.int32)
        for s, n in self._len.items():
            lengths[s] = n
        eng.cache["lengths"] = jnp.asarray(lengths)
        dlen = np.zeros((eng.max_batch,), np.int32)
        for s, n in self._draft_len.items():
            dlen[s] = n
        dr.cache["lengths"] = jnp.asarray(dlen)

    def decode_emitted(self, req: SimRequest) -> int:
        """Tokens the last decode step emitted for ``req`` (1 for vanilla
        decode; accepted + 1 under speculative decoding)."""
        return self._emitted.pop(req.req_id, 1)

    def _prefill_chunk(self, w: ScheduledWork):
        import jax.numpy as jnp
        from repro.serve.engine import _bucket
        from repro.serve.sampler import greedy
        eng = self.eng
        req = w.request
        toks = self._prompt(req)
        slot = self._slot.get(req.req_id)
        if slot is None:
            slot = eng.slot_free.pop()
            self._slot[req.req_id] = slot
            self._len[slot] = 0
            self._hist[slot] = []
            self._draft_len.pop(slot, None)
            restore = self._restore.pop(req.req_id, None)
            if restore is not None and req.cached_prefix > 0:
                payload, length = restore
                length = min(length, req.cached_prefix)
                # SSD-tier stubs load here, inside execute()'s timed
                # region, so the disk read lands on the virtual clock
                payload = eng.radix.resolve(payload)
                eng._restore_slot(slot, payload, length)
                self._len[slot] = length
                self._hist[slot] = list(toks[:length])
        start = self._len[slot]
        end = min(start + w.tokens, len(toks))
        chunk = toks[start:end]
        logits = None
        if chunk:
            P = _bucket(len(chunk))
            pad = np.zeros((1, P), np.int32)
            pad[0, :len(chunk)] = np.asarray(chunk, np.int32)
            n_new = jnp.asarray([len(chunk)], jnp.int32)
            if start == 0:
                logits, c1 = eng._jit_prefill(eng.params, jnp.asarray(pad),
                                              lengths=n_new)
                eng._write_slot_from_prefill(slot, c1, len(chunk))
            else:
                eng.ensure_capacity(slot, start + len(chunk))
                sub = eng._slot_subcache(slot, start)
                logits, new_sub = eng._jit_extend(eng.params, sub,
                                                  jnp.asarray(pad), n_new)
                eng._write_slot(slot, new_sub, start + len(chunk))
            if self.expert_load is not None:
                # the chunk's tokens occupy KV positions [start, start+n)
                self._routed_pos.extend(range(start, start + len(chunk)))
            self._len[slot] = start + len(chunk)
            self._hist[slot].extend(int(t) for t in chunk)
        if self._len[slot] >= len(toks) and logits is not None:
            # prompt complete: the last chunk's logits give the first token
            first = int(np.asarray(greedy(logits, eng.cfg.vocab))[0, 0])
            eng._tokens_buf[slot, 0] = first
            self.out_tokens.setdefault(req.req_id, []).append(first)
            self._emit[slot] = 1

    # ---- prefix cache payloads ----
    def on_prefix_hit(self, req: SimRequest, match: MatchResult,
                      usable: int) -> int:
        if self.eng.radix is None or usable <= 0:
            return 0
        toks = self._prompt(req)
        limit = min(usable, len(toks) - 1 if toks else 0)
        length, payload = self.eng.radix.match(toks, limit=limit)
        if payload is None or length <= 0:
            return 0
        self._restore[req.req_id] = (payload, length)
        if match is not None:
            # match is None on the preemption re-match path (on_preempt):
            # that restore was already counted when the request first hit
            self._restored_tokens += length
            self._restore_events += 1
        return length

    def on_prefill_complete(self, req: SimRequest):
        if self.eng.radix is None:
            return
        slot = self._slot.get(req.req_id)
        if slot is None:
            return
        t0 = time.perf_counter()
        toks = self._prompt(req)
        blk = (len(toks) // self.eng.radix.block) * self.eng.radix.block
        if blk > 0:
            # device-resident entry (hot tier): the gathered jax arrays
            # stay on device until the runtime demotes them
            self.eng.radix.insert(
                toks, self.eng._export_slot(slot, blk, to_host=False))
        self._carry_s += time.perf_counter() - t0

    def on_tier_transfer(self, src: str, dst: str, n_bytes: float,
                         prefix) -> None:
        """Execute the runtime's tier decision on the real payload store:
        demotions convert device entries to host numpy (then pickle to a
        spill file for SSD), promotions ``device_put`` them back, drops
        delete.  All of it is wall-timed into ``_carry_s`` — the same
        carry discipline as prefix-store inserts — so tier traffic is
        *measured* on this backend, matching the simulator's priced
        ``transfer_time`` charge on the other."""
        if self.eng.radix is None:
            return
        t0 = time.perf_counter()
        if dst == "device":
            self.eng.radix.promote(prefix)
        elif dst in ("host", "ssd"):
            self.eng.radix.demote(prefix, dst)
        else:
            self.eng.radix.drop(prefix)
        self._carry_s += time.perf_counter() - t0
        self._tier_move_s += time.perf_counter() - t0
        self._tier_moves += 1

    def kv_tier_stats(self) -> dict:
        s = {"restored_tokens": self._restored_tokens,
             "restore_events": self._restore_events,
             "tier_moves": self._tier_moves,
             "tier_move_s": self._tier_move_s}
        if self.eng.radix is not None:
            s["store_residency"] = self.eng.radix.residency()
        return s

    def on_preempt(self, req: SimRequest) -> int:
        self.release(req)
        # the restart regenerates the whole output from scratch — drop the
        # partial capture or out_tokens would hold it twice over
        self.out_tokens.pop(req.req_id, None)
        # re-match the store so the restart restores whatever KV survives
        return self.on_prefix_hit(req, None, req.cached_prefix) \
            if req.cached_prefix > 0 else 0

    def release(self, req: SimRequest):
        slot = self._slot.pop(req.req_id, None)
        self._restore.pop(req.req_id, None)
        self._emitted.pop(req.req_id, None)
        if slot is None:
            return
        self._len.pop(slot, None)
        self._hist.pop(slot, None)
        self._draft_len.pop(slot, None)
        self._emit.pop(slot, None)
        self._steps.pop(slot, None)
        self.eng._release_slot(slot)

    # ---- P/D handoff ----
    def export_kv(self, req: SimRequest) -> KvHandoff:
        t0 = time.perf_counter()
        slot = self._slot[req.req_id]
        length = self._len[slot]
        kv = self.eng._export_slot(slot, length)
        first = int(self.eng._tokens_buf[slot, 0])
        nbytes = float(sum(
            np.asarray(leaf).nbytes
            for k, v in kv.items() if not k.startswith("_")
            for leaf in _leaves(v)))
        self.release(req)
        self._carry_s += time.perf_counter() - t0
        return KvHandoff(nbytes=nbytes,
                         payload={"kv": kv, "first": first, "len": length})

    def import_kv(self, req: SimRequest, handoff: Optional[KvHandoff]):
        if handoff is None or handoff.payload is None:
            return
        slot = self.eng.slot_free.pop()
        self._slot[req.req_id] = slot
        p = handoff.payload
        self.eng._restore_slot(slot, p["kv"], p["len"])
        self.eng._tokens_buf[slot, 0] = p["first"]
        self._len[slot] = p["len"]
        # spec bookkeeping: the transferred KV holds exactly the (possibly
        # truncated) prompt; the pending first token is the 1 emitted
        self._hist[slot] = list(self._prompt(req))[:p["len"]]
        self._draft_len.pop(slot, None)
        self._emit[slot] = 1
        self.out_tokens.setdefault(req.req_id, []).append(p["first"])

    # ---- lifecycle ----
    def reset(self):
        import jax.numpy as jnp
        eng = self.eng
        self._slot.clear()
        self._len.clear()
        self._restore.clear()
        self._routed_pos = []
        self._hist.clear()
        self._draft_len.clear()
        self._emit.clear()
        self._steps.clear()
        self._emitted.clear()
        eng.slot_free = list(range(eng.max_batch))
        eng.cache["lengths"] = jnp.zeros((eng.max_batch,), jnp.int32)
        if getattr(eng, "paged", False):
            for slot in range(eng.max_batch):
                eng._free_pages(slot)
        if eng.spec is not None:
            eng.draft.cache["lengths"] = jnp.zeros((eng.max_batch,),
                                                   jnp.int32)
            if getattr(eng.draft, "paged", False):
                for slot in range(eng.max_batch):
                    eng.draft._free_pages(slot)

    def stats(self) -> dict:
        s = {"engine_iterations": self._iterations}
        if self.eng.radix is not None:
            s["kv_store_hits"] = self.eng.radix.hits
            s["kv_store_misses"] = self.eng.radix.misses
        if self.expert_load is not None:
            s["expert_load"] = self.expert_load.metrics()
        if self.spec_tracker is not None:
            s["spec_decode"] = self.spec_tracker.metrics()
        return s


def _leaves(tree):
    out = []
    if isinstance(tree, dict):
        for v in tree.values():
            out.extend(_leaves(v))
    elif isinstance(tree, (list, tuple)):
        for v in tree:
            out.extend(_leaves(v))
    else:
        out.append(tree)
    return out
