"""Real-execution backend: jitted prefill/extend/decode over slot KV.

Wraps a ``repro.serve.engine.ServingEngine`` purely as a *KV mechanism*
(slot cache, jitted model calls, export/restore plumbing).  All serving
decisions — admission, chunking, decode composition, preemption, prefix
policy, P/D handoff — come from the unified runtime, so the real engine
gains chunked prefill, SJF, preemption and every registered routing policy
for free.

Hybrid emulation is preserved: compute is REAL (wall-clock timed on the
local device), time is VIRTUAL (the runtime's shared event queue advances
by the measured latencies), exactly the paper's §III methodology adapted to
this container.

Chunked prefill maps onto the model API naturally: the first chunk runs the
bucketed ``prefill`` kernel; subsequent chunks ``extend`` the slot's
subcache.  One batched ``decode`` serves all scheduled decode slots per
iteration (the full-buffer decode the engine always ran).
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional

import numpy as np

from repro.core.config import InstanceCfg
from repro.core.memory import MemoryModel
from repro.core.request import SimRequest
from repro.runtime.backend import KvHandoff
from repro.runtime.prefix_cache import MatchResult
from repro.runtime.scheduler import ScheduledWork


class JaxBackend:
    name = "jax"

    def __init__(self, engine, cfg: InstanceCfg):
        # late imports: the sim path must not pay for jax
        import jax  # noqa: F401
        self.eng = engine
        self.cfg = cfg
        self.memory = MemoryModel(cfg)
        self._slot: Dict[int, int] = {}      # req_id -> engine slot
        self._len: Dict[int, int] = {}       # slot   -> tokens held in KV
        self._restore: Dict[int, tuple] = {} # req_id -> (payload, length)
        self._iterations = 0
        # real work done outside execute() (prefix store, P/D export) is
        # wall-timed and charged to the next iteration
        self._carry_s = 0.0
        # expert-load accounting for a replayed ExpertRoutingTrace: the
        # engine's replay hook forces every token's assignment in-graph
        # (ServingEngine(routing=trace)); this mirror maps the *executed
        # slot positions* — tracked independently of the scheduler's
        # bookkeeping — through the same table, so the metrics state what
        # really routed and the parity suite can pin sim == real.  The
        # engine's own trace is the only valid source: a cfg-named trace
        # the engine does not replay would make these metrics fiction
        # (the model routed with its learned router), so that mismatch is
        # an error, not a fallback.
        from repro.moe import ExpertLoadTracker, resolve_routing
        self.routing = getattr(engine, "routing_trace", None)
        if getattr(cfg.moe, "routing_trace", None):
            if self.routing is None:
                raise ValueError(
                    f"instance {cfg.name!r} names routing_trace="
                    f"{cfg.moe.routing_trace!r} but its engine replays no "
                    f"trace; build it with ServingEngine(routing=<trace>) "
                    f"so the reported expert_load is what actually routed")
            named = resolve_routing(cfg)
            if named is not self.routing \
                    and named.to_json() != self.routing.to_json():
                raise ValueError(
                    f"instance {cfg.name!r} names routing_trace="
                    f"{cfg.moe.routing_trace!r} but its engine replays a "
                    f"different trace ({self.routing.model!r}); the "
                    f"accounting table must be the one the model executes")
        self.expert_load = ExpertLoadTracker(
            self.routing, ep=cfg.parallelism.ep) \
            if self.routing is not None else None
        self._routed_pos: List[int] = []     # positions routed this iter

    # ---- helpers ----
    def prompt_cap(self, req: SimRequest) -> int:
        """Slot capacity: prompt + generated output + 1 must fit max_len.
        The runtime truncates the request on submit, so the scheduler's
        chunk plan and the backend's KV state always agree."""
        return max(self.eng.max_len - req.output_len - 1, 1)

    def _prompt(self, req: SimRequest) -> List[int]:
        toks = list(req.prompt_tokens)
        cap = self.prompt_cap(req)
        return toks[:cap] if len(toks) > cap else toks

    def warmup(self):
        import jax
        import jax.numpy as jnp
        from repro.serve.engine import _bucket
        eng = self.eng
        eng.warmup()
        sched = self.cfg.scheduler
        if sched.chunked_prefill or eng.radix is not None:
            # chunk 2+ of a chunked prefill (and any prefix-hit suffix)
            # runs the ``extend`` path, which compiles one jit per padded
            # chunk bucket; pre-warm every bucket a chunk can map to so
            # measured latencies are steady-state from the first request
            top = _bucket(min(max(sched.prefill_chunk, 16),
                              eng.max_len - 1)) \
                if sched.chunked_prefill else eng.max_len - 1
            P = 16
            while P <= top and P < eng.max_len:
                pad = jnp.zeros((1, P), jnp.int32)
                try:
                    sub = eng._slot_subcache(0, 16)
                    jax.block_until_ready(eng._jit_extend(
                        eng.params, sub, pad, jnp.asarray([P], jnp.int32)))
                    # the chunk write-back (slot update) compiles once
                    eng._write_slot(0, sub, 16)
                except NotImplementedError:
                    break   # no cached-prefill path (e.g. xLSTM)
                P *= 2
            eng._release_slot(0)
        if eng.radix is not None:
            # pre-compile the slot export/restore jits at every bucket so
            # prefix-cache hits don't pay compile time on the virtual clock
            for blen in (16, 32, 64, 128, 256):
                if blen >= eng.max_len:
                    break
                payload = eng._export_slot(0, blen)
                eng._restore_slot(0, payload, blen)
            eng._release_slot(0)

    # ---- execution ----
    def execute(self, work: List[ScheduledWork], now: float) -> float:
        import jax
        t0 = time.perf_counter()
        decodes = [w for w in work if w.phase == "decode"]
        prefills = [w for w in work if w.phase == "prefill"]
        if decodes:
            self._decode_step(decodes)
        for w in prefills:
            self._prefill_chunk(w)
        jax.block_until_ready(self.eng.cache)
        self._iterations += 1
        latency = time.perf_counter() - t0 + self._carry_s
        self._carry_s = 0.0
        if self.expert_load is not None:
            self.expert_load.observe(self._routed_pos, now)
            self._routed_pos = []
        return latency

    def _decode_step(self, decodes: List[ScheduledWork]):
        import jax.numpy as jnp
        from repro.serve.sampler import greedy
        eng = self.eng
        tokens = eng._tokens_buf
        if self.routing is not None or self.eng.model.routing_hook \
                is not None:
            # routing-hook runs: mark every NON-scheduled slot (free, or
            # occupied mid-prefill) with the sentinel token -1 so the
            # model's decode mask excludes its row from MoE recording and
            # capacity — the full-buffer decode computes it regardless,
            # but it is not workload routing.  The engine buffer itself
            # is left untouched (mid-prefill slots keep their pending
            # first token).
            tokens = tokens.copy()
            scheduled_slots = {self._slot[w.request.req_id]
                               for w in decodes}
            for slot in range(eng.max_batch):
                if slot not in scheduled_slots:
                    tokens[slot, 0] = -1
        logits, eng.cache = eng._jit_decode(
            eng.params, eng.cache, jnp.asarray(tokens))
        nxt = np.asarray(greedy(logits, eng.cfg.vocab))
        scheduled = set()
        for w in decodes:
            slot = self._slot[w.request.req_id]
            eng._tokens_buf[slot, 0] = int(nxt[slot, 0])
            if self.expert_load is not None:
                # the decode wrote this slot's token at KV index _len
                self._routed_pos.append(self._len[slot])
            self._len[slot] += 1
            scheduled.add(slot)
        hooked = self.routing is not None \
            or eng.model.routing_hook is not None
        if scheduled != set(self._len) \
                or (hooked and len(self._len) < eng.max_batch):
            # the full-buffer decode bumped every slot's length; restore
            # the authoritative lengths of mid-prefill / unscheduled
            # slots.  With a MoE routing hook installed, ALSO zero the
            # free slots every iteration: free slots may otherwise keep
            # garbage lengths (harmless for attention — nothing reads
            # them), but the hook's validity mask identifies an empty
            # slot by its zero length (position 0), and letting the bump
            # accumulate across consecutive decode-only iterations would
            # mark phantom rows valid — contaminating recorded routing
            # traces and letting empty slots consume real tokens' expert
            # capacity under forced replay.  Unhooked engines keep the
            # old fast path.
            lengths = np.zeros((eng.max_batch,), np.int32)
            for s, n in self._len.items():
                lengths[s] = n
            eng.cache["lengths"] = jnp.asarray(lengths)

    def _prefill_chunk(self, w: ScheduledWork):
        import jax.numpy as jnp
        from repro.serve.engine import _bucket
        from repro.serve.sampler import greedy
        eng = self.eng
        req = w.request
        toks = self._prompt(req)
        slot = self._slot.get(req.req_id)
        if slot is None:
            slot = eng.slot_free.pop()
            self._slot[req.req_id] = slot
            self._len[slot] = 0
            restore = self._restore.pop(req.req_id, None)
            if restore is not None and req.cached_prefix > 0:
                payload, length = restore
                length = min(length, req.cached_prefix)
                eng._restore_slot(slot, payload, length)
                self._len[slot] = length
        start = self._len[slot]
        end = min(start + w.tokens, len(toks))
        chunk = toks[start:end]
        logits = None
        if chunk:
            P = _bucket(len(chunk))
            pad = np.zeros((1, P), np.int32)
            pad[0, :len(chunk)] = np.asarray(chunk, np.int32)
            n_new = jnp.asarray([len(chunk)], jnp.int32)
            if start == 0:
                logits, c1 = eng._jit_prefill(eng.params, jnp.asarray(pad),
                                              lengths=n_new)
                eng._write_slot_from_prefill(slot, c1, len(chunk))
            else:
                sub = eng._slot_subcache(slot, start)
                logits, new_sub = eng._jit_extend(eng.params, sub,
                                                  jnp.asarray(pad), n_new)
                eng._write_slot(slot, new_sub, start + len(chunk))
            if self.expert_load is not None:
                # the chunk's tokens occupy KV positions [start, start+n)
                self._routed_pos.extend(range(start, start + len(chunk)))
            self._len[slot] = start + len(chunk)
        if self._len[slot] >= len(toks) and logits is not None:
            # prompt complete: the last chunk's logits give the first token
            first = int(np.asarray(greedy(logits, eng.cfg.vocab))[0, 0])
            eng._tokens_buf[slot, 0] = first

    # ---- prefix cache payloads ----
    def on_prefix_hit(self, req: SimRequest, match: MatchResult,
                      usable: int) -> int:
        if self.eng.radix is None or usable <= 0:
            return 0
        toks = self._prompt(req)
        limit = min(usable, len(toks) - 1 if toks else 0)
        length, payload = self.eng.radix.match(toks, limit=limit)
        if payload is None or length <= 0:
            return 0
        self._restore[req.req_id] = (payload, length)
        return length

    def on_prefill_complete(self, req: SimRequest):
        if self.eng.radix is None:
            return
        slot = self._slot.get(req.req_id)
        if slot is None:
            return
        t0 = time.perf_counter()
        toks = self._prompt(req)
        blk = (len(toks) // self.eng.radix.block) * self.eng.radix.block
        if blk > 0:
            self.eng.radix.insert(toks, self.eng._export_slot(slot, blk))
        self._carry_s += time.perf_counter() - t0

    def on_preempt(self, req: SimRequest) -> int:
        self.release(req)
        # re-match the store so the restart restores whatever KV survives
        return self.on_prefix_hit(req, None, req.cached_prefix) \
            if req.cached_prefix > 0 else 0

    def release(self, req: SimRequest):
        slot = self._slot.pop(req.req_id, None)
        self._restore.pop(req.req_id, None)
        if slot is None:
            return
        self._len.pop(slot, None)
        self.eng._release_slot(slot)

    # ---- P/D handoff ----
    def export_kv(self, req: SimRequest) -> KvHandoff:
        t0 = time.perf_counter()
        slot = self._slot[req.req_id]
        length = self._len[slot]
        kv = self.eng._export_slot(slot, length)
        first = int(self.eng._tokens_buf[slot, 0])
        nbytes = float(sum(
            np.asarray(leaf).nbytes
            for k, v in kv.items() if not k.startswith("_")
            for leaf in _leaves(v)))
        self.release(req)
        self._carry_s += time.perf_counter() - t0
        return KvHandoff(nbytes=nbytes,
                         payload={"kv": kv, "first": first, "len": length})

    def import_kv(self, req: SimRequest, handoff: Optional[KvHandoff]):
        if handoff is None or handoff.payload is None:
            return
        slot = self.eng.slot_free.pop()
        self._slot[req.req_id] = slot
        p = handoff.payload
        self.eng._restore_slot(slot, p["kv"], p["len"])
        self.eng._tokens_buf[slot, 0] = p["first"]
        self._len[slot] = p["len"]

    # ---- lifecycle ----
    def reset(self):
        import jax.numpy as jnp
        eng = self.eng
        self._slot.clear()
        self._len.clear()
        self._restore.clear()
        self._routed_pos = []
        eng.slot_free = list(range(eng.max_batch))
        eng.cache["lengths"] = jnp.zeros((eng.max_batch,), jnp.int32)

    def stats(self) -> dict:
        s = {"engine_iterations": self._iterations}
        if self.eng.radix is not None:
            s["kv_store_hits"] = self.eng.radix.hits
            s["kv_store_misses"] = self.eng.radix.misses
        if self.expert_load is not None:
            s["expert_load"] = self.expert_load.metrics()
        return s


def _leaves(tree):
    out = []
    if isinstance(tree, dict):
        for v in tree.values():
            out.extend(_leaves(v))
    elif isinstance(tree, (list, tuple)):
        for v in tree:
            out.extend(_leaves(v))
    else:
        out.append(tree)
    return out
