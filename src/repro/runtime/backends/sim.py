"""Simulation backend: batches are priced, never executed.

Wraps the trace-driven ``PerfModel`` + paged ``MemoryModel`` — exactly the
pricing the old ``core.instance.Instance`` iteration loop did inline.  All
scheduling/caching/routing decisions arrive from the unified runtime; this
class only turns a decided batch into seconds.
"""
from __future__ import annotations

from typing import List, Optional

from repro.core.config import InstanceCfg
from repro.core.memory import MemoryModel
from repro.core.perfmodel import BatchItem, PerfModel, batch_positions
from repro.core.request import SimRequest
from repro.core.trace import Trace
from repro.obs.events import SPEC_STEP
from repro.runtime.backend import KvHandoff
from repro.runtime.prefix_cache import MatchResult
from repro.runtime.scheduler import ScheduledWork, to_batch_items


#: iteration-memo entries kept before a wholesale reset (exact keys)
_ITER_MEMO_CAP = 1 << 17


class SimBackend:
    name = "sim"

    def __init__(self, cfg: InstanceCfg, trace: Optional[Trace] = None,
                 fast_path: bool = True):
        self.cfg = cfg
        self.fast_path = bool(fast_path)
        self.memory = MemoryModel(cfg)
        # replayable expert-routing trace (MoECfg.routing_trace): prices
        # per-layer expert load and feeds the uniform expert_load metrics.
        # Imported lazily: repro.moe sits above repro.core in the layering
        # (it consumes core.expert), so a cold import of this module must
        # not re-enter it mid-initialization.
        from repro.moe import ExpertLoadTracker, resolve_routing
        self.routing = resolve_routing(cfg)
        self.expert_load = ExpertLoadTracker(
            self.routing, ep=cfg.parallelism.ep,
            capacity_factor=cfg.model.moe_capacity_factor) \
            if self.routing is not None else None
        self.perf = PerfModel(cfg, trace=trace, routing=self.routing)
        # speculative decoding (SpecCfg): every decode step becomes a
        # draft-propose + target-verify pair priced below, advancing the
        # request by accepted + 1 tokens drawn deterministically from the
        # named AcceptanceTrace (repro.spec — lazily imported, same
        # layering rule as repro.moe above).
        self.spec = cfg.spec if getattr(cfg.spec, "enabled", False) else None
        self.spec_trace = None
        self.spec_tracker = None
        self.draft_perf = None
        self._emitted = {}       # req_id -> tokens emitted by the last step
        self._spec_steps = {}    # req_id -> spec-step ordinal (quantile key)
        if self.spec is not None:
            import dataclasses

            from repro.spec import (SpecDecodeTracker, draft_model_spec,
                                    resolve_acceptance)
            if self.routing is not None:
                raise ValueError(
                    f"instance {cfg.name!r} enables both a routing trace "
                    f"and speculative decoding — the combination is not "
                    f"supported (positions of draft tokens that fail "
                    f"verification have no expert-load semantics)")
            self.spec_trace = resolve_acceptance(cfg)
            if self.spec_trace is None:
                raise ValueError(
                    f"instance {cfg.name!r} enables speculative decoding "
                    f"but names no acceptance_trace; the simulator draws "
                    f"accepted lengths from the trace — record one with "
                    f"`python -m repro.profiler record-acceptance` or "
                    f"synthesize one with repro.workload.acceptance")
            if cfg.scheduler.decode_tokens != self.spec.k + 1:
                raise ValueError(
                    f"instance {cfg.name!r} speculates k={self.spec.k} "
                    f"but its scheduler reserves decode_tokens="
                    f"{cfg.scheduler.decode_tokens}; set SchedulerCfg("
                    f"decode_tokens=k + 1) so the KV ledger covers the "
                    f"verification window")
            self.spec_tracker = SpecDecodeTracker(self.spec.k)
            draft = self.spec.draft or draft_model_spec(
                cfg.model, self.spec.draft_scale)
            self.draft_perf = PerfModel(
                dataclasses.replace(cfg, model=draft,
                                    spec=dataclasses.replace(
                                        cfg.spec, enabled=False)),
                trace=None)
        # prefix-cache restore / tier-fetch latency charged to the next
        # iteration (the request that hit pays for its own fetch); spill
        # traffic (device->host->ssd demotions) is priced the same way —
        # the instance whose insert/admission forced the eviction pays
        self._pending_fetch_s = 0.0
        # last on_prefix_hit's total restore charge — the per-request
        # seconds the kv_restore event (and latency attribution) reports
        self.last_restore_s = 0.0
        # event recorder, wired by RuntimeInstance.attach_obs
        self.obs = None
        self._restored_tokens = 0
        self._restore_events = 0
        self._fetch_bytes = 0.0
        self._spill_bytes = 0.0
        self._fetch_s = 0.0
        self._spill_s = 0.0
        self._tput_hint = {}     # phase -> lazily priced reference tokens/s
        # ---- fast path (exact-mode opt-out: fast_path=False) ----
        # iteration-cost memo on the exact batch-shape signature.  Safe
        # only when pricing is a pure function of the signature: no
        # replayed routing trace (position-dependent), no spec decode
        # (step-ordinal-dependent draws), no statistical-MoE fallback
        # (stateful RNG).  Exact keys mean a hit returns the identical
        # float the slow path would have computed.
        self._memo_on = (self.fast_path and self.routing is None
                         and self.spec is None
                         and self.perf.pricing_deterministic())
        self._iter_memo = {}
        # decode fast-forward needs the same determinism guarantees
        self.supports_fast_forward = self._memo_on

    def warmup(self):
        pass

    def prompt_cap(self, req: SimRequest):
        return None

    def throughput_hint(self, phase: Optional[str] = None) -> float:
        """Trace-priced tokens/s on a reference batch — the cold-start
        signal ``hardware_aware`` routing uses before observed throughput
        exists.  ``phase`` selects the per-phase reference (a 256-token
        prefill, or a 4-wide decode at context 256); ``None`` blends both
        for unified-role instances.  P/D role-aware placement queries the
        matching phase so a prefill-fast device is rated by its prefill
        grid, not a blend it will never run."""
        if None not in self._tput_hint:
            pre = self.perf.iteration_latency(
                [BatchItem(tokens=256, context=256, phase="prefill")])
            dec = self.perf.iteration_latency(
                [BatchItem(tokens=1, context=256, phase="decode")
                 for _ in range(4)])
            self._tput_hint["prefill"] = 256 / max(pre.total_s, 1e-12)
            self._tput_hint["decode"] = 4 / max(dec.total_s, 1e-12)
            self._tput_hint[None] = (256 + 4) / max(
                pre.total_s + dec.total_s, 1e-12)
        # unknown phase strings fall back to the blended estimate rather
        # than crashing a custom routing policy
        return self._tput_hint.get(phase, self._tput_hint[None])

    def execute(self, work: List[ScheduledWork], now: float) -> float:
        spec_s = 0.0
        if self.spec is not None:
            decodes = [w for w in work if w.phase == "decode"]
            if decodes:
                spec_s = self._spec_step(decodes, now)
            work = [w for w in work if w.phase != "decode"]
        items = to_batch_items(work)
        counts = n_tokens = None
        if self.routing is not None:
            # one bincount pass per iteration, shared by pricing and the
            # expert-load accounting (the real engine accounts
            # independently, from its slot lengths — that independence is
            # what the parity suite tests)
            pos = batch_positions(items)
            n_tokens = int(pos.size)
            counts = [self.routing.counts_for(l, pos)
                      for l in range(self.routing.n_layers)]
        total = self._priced(items, counts)
        latency = total + spec_s + self._pending_fetch_s
        self._pending_fetch_s = 0.0
        if self.expert_load is not None:
            self.expert_load.observe_counts(counts, n_tokens, now)
        return latency

    def _priced(self, items: List[BatchItem], counts=None) -> float:
        """Memoized ``iteration_latency``: identical batch shapes price
        once (exact-key signature, so a hit is the identical float)."""
        if not self._memo_on:
            return self.perf.iteration_latency(
                items, routing_counts=counts).total_s
        sig = tuple((i.phase, i.tokens, i.context, i.start, i.completes)
                    for i in items)
        total = self._iter_memo.get(sig)
        if total is None:
            if len(self._iter_memo) >= _ITER_MEMO_CAP:
                self._iter_memo.clear()
            total = self.perf.iteration_latency(items).total_s
            self._iter_memo[sig] = total
        return total

    def fast_forward(self, work: List[ScheduledWork], n_max: int,
                     now: float, horizon: float) -> Optional[List[float]]:
        """Price up to ``n_max`` successive decode iterations of a frozen
        batch (every request emits 1 token/step).  Returns per-step
        latencies ``[l1..ln]`` with every chained completion time strictly
        before ``horizon`` and ``n >= 2``, or None when fewer than 2 steps
        fit (the caller then runs the normal single-step path).  Step 1's
        price includes any pending prefix-fetch charge, exactly as
        ``execute`` would have applied it; the charge is only consumed on
        success."""
        items = to_batch_items(work)
        fetch0 = self._pending_fetch_s
        # cheap pre-cap: step 1's price (memoized) bounds how many steps
        # can fit before the horizon, so a near barrier fails fast and a
        # far one doesn't price thousands of steps it will then discard.
        # Latencies grow with context, so the estimate only ever trims
        # the window — the exact strict-inequality cap below decides.
        span = horizon - now
        if span != float("inf"):
            l1 = self._priced(items) + fetch0
            if l1 > 0.0:
                est = int(span / l1) + 1
                if est < 2:
                    return None
                n_max = min(n_max, est)
        totals = self.perf.decode_window(items, n_max)
        if totals is None:
            # per-step fallback: same call sequence the slow path makes
            totals = []
            for i in range(n_max):
                if i:
                    for it in items:
                        it.context += 1
                totals.append(self._priced(items))
        lat: List[float] = []
        t = now
        fetch = self._pending_fetch_s
        for i, v in enumerate(totals):
            v = float(v)
            if i == 0:
                v = v + fetch
            t2 = t + v
            if t2 >= horizon:
                break
            lat.append(v)
            t = t2
        if len(lat) < 2:
            return None
        self._pending_fetch_s = 0.0
        return lat

    def _spec_step(self, decodes: List[ScheduledWork], now: float) -> float:
        """Price one speculative decode step for the scheduled decode set
        and draw each request's accepted length from the trace.

        Cost model mirrors what the real engine executes: ``k + 1``
        sequential draft decode iterations (propose d1..dk, then consume
        dk so the draft KV stays in sync) plus one batched target
        verification — an ``extend`` over the pending token + k drafts,
        priced through the measured extend grid when the hardware trace
        has one.  Acceptance does not change the step's cost, only its
        progress: that asymmetry is exactly the wasted-compute crossover
        ``benchmarks/spec_decode_sweep.py`` sweeps.

        Tail clamp: a request with fewer than ``k + 1`` output tokens left
        shrinks its draft/verify window to what it can still emit
        (``k_eff = output_len - generated - 1``); the batch drafts to the
        widest surviving window.  The real engine applies the identical
        clamp, so near-budget steps neither price nor execute drafts the
        request could never keep.
        """
        k = self.spec.k
        verify_items = []
        draft_items = []
        k_step = 0
        for w in decodes:
            req = w.request
            k_eff = max(0, min(k, req.output_len - req.generated - 1))
            k_step = max(k_step, k_eff)
            ctx = req.context_len
            verify_items.append(BatchItem(
                tokens=k_eff + 1, context=ctx + k_eff, phase="prefill",
                start=max(ctx - 1, 0), completes=False))
            draft_items.append(BatchItem(
                tokens=1, context=ctx + 1, phase="decode"))
        latency = self.perf.iteration_latency(verify_items).total_s \
            + (k_step + 1) * self.draft_perf.iteration_latency(
                draft_items).total_s
        obs = self.obs
        for w in decodes:
            req = w.request
            k_eff = max(0, min(k, req.output_len - req.generated - 1))
            pos = max(req.generated - 1, 0)
            step = self._spec_steps.get(req.req_id, 0)
            self._spec_steps[req.req_id] = step + 1
            accepted = min(self.spec_trace.accepted_for(pos, step), k_eff)
            self._emitted[req.req_id] = max(
                1, min(accepted + 1, req.output_len - req.generated))
            self.spec_tracker.observe(pos, accepted, now, proposed=k_eff)
            if obs is not None:
                obs.emit(now, SPEC_STEP, inst=self.cfg.name,
                         req=req.req_id, tenant=req.tenant,
                         payload={"accepted": int(accepted),
                                  "proposed": int(k_eff)})
        return latency

    def decode_emitted(self, req: SimRequest) -> int:
        """Tokens the last decode step emitted for ``req`` (1 without
        speculative decoding; accepted + 1 with it)."""
        return self._emitted.pop(req.req_id, 1)

    def on_prefix_hit(self, req: SimRequest, match: MatchResult,
                      usable: int) -> int:
        kb = self.memory.kv_bytes_per_token
        host_b = match.host_tokens * kb
        ssd_b = match.ssd_tokens * kb
        fetch0 = self._pending_fetch_s
        if host_b > 0:
            # promote host-tier blocks: pay the fetch on this request
            t = self.memory.transfer_time(host_b, "host", "device")
            self._pending_fetch_s += t
            self._fetch_s += t
            self._fetch_bytes += host_b
        if ssd_b > 0:
            # SSD-resident blocks pay the (slower) SSD->device path
            t = self.memory.transfer_time(ssd_b, "ssd", "device")
            self._pending_fetch_s += t
            self._fetch_s += t
            self._fetch_bytes += ssd_b
        if usable > 0:
            # restoring the hit KV into the running cache is a real slot
            # copy (measured by the engine profiler as kv_export)
            self._pending_fetch_s += self.perf.kv_copy_cost(usable)
            self._restored_tokens += usable
            self._restore_events += 1
        self.last_restore_s = self._pending_fetch_s - fetch0
        return usable

    def on_tier_transfer(self, src: str, dst: str, n_bytes: float,
                         prefix) -> None:
        """Settle one cache tier move.  Spills (dst is a lower tier) are
        priced through ``transfer_time`` into the next iteration, same
        carry discipline as prefix fetches.  Promotes (dst == device) were
        already priced by ``on_prefix_hit`` from the match's lower-tier
        bytes — pricing them again here would double-charge.  Drops move
        no bytes."""
        if dst in ("host", "ssd"):
            t = self.memory.transfer_time(n_bytes, src, dst)
            self._pending_fetch_s += t
            self._spill_s += t
            self._spill_bytes += n_bytes

    def kv_tier_stats(self) -> dict:
        return {"restored_tokens": self._restored_tokens,
                "restore_events": self._restore_events,
                "fetch_bytes": self._fetch_bytes,
                "spill_bytes": self._spill_bytes,
                "fetch_s": self._fetch_s,
                "spill_s": self._spill_s}

    def on_prefill_complete(self, req: SimRequest):
        pass     # insert cost is modeled inside the perf trace (kv_export)

    def on_preempt(self, req: SimRequest) -> int:
        # a preempted request restarts its decode from scratch, so its
        # spec-step ordinal restarts too (the real backend's counter is
        # slot-scoped and resets the same way on release)
        self._spec_steps.pop(req.req_id, None)
        self._emitted.pop(req.req_id, None)
        return req.cached_prefix   # simulated KV prefix stays restorable

    def release(self, req: SimRequest):
        self._spec_steps.pop(req.req_id, None)
        self._emitted.pop(req.req_id, None)

    def export_kv(self, req: SimRequest) -> KvHandoff:
        return KvHandoff(
            nbytes=req.prompt_len * self.cfg.model.kv_bytes_per_token)

    def import_kv(self, req: SimRequest, handoff: Optional[KvHandoff]):
        pass

    def reset(self):
        self._emitted.clear()
        self._spec_steps.clear()

    def stats(self) -> dict:
        s = {}
        if self.expert_load is not None:
            s["expert_load"] = self.expert_load.metrics()
        if self.spec_tracker is not None:
            s["spec_decode"] = self.spec_tracker.metrics()
        return s
