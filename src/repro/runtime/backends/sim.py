"""Simulation backend: batches are priced, never executed.

Wraps the trace-driven ``PerfModel`` + paged ``MemoryModel`` — exactly the
pricing the old ``core.instance.Instance`` iteration loop did inline.  All
scheduling/caching/routing decisions arrive from the unified runtime; this
class only turns a decided batch into seconds.
"""
from __future__ import annotations

from typing import List, Optional

from repro.core.config import InstanceCfg
from repro.core.memory import MemoryModel
from repro.core.perfmodel import PerfModel, batch_positions
from repro.core.request import SimRequest
from repro.core.trace import Trace
from repro.runtime.backend import KvHandoff
from repro.runtime.prefix_cache import MatchResult
from repro.runtime.scheduler import ScheduledWork, to_batch_items


class SimBackend:
    name = "sim"

    def __init__(self, cfg: InstanceCfg, trace: Optional[Trace] = None):
        self.cfg = cfg
        self.memory = MemoryModel(cfg)
        # replayable expert-routing trace (MoECfg.routing_trace): prices
        # per-layer expert load and feeds the uniform expert_load metrics.
        # Imported lazily: repro.moe sits above repro.core in the layering
        # (it consumes core.expert), so a cold import of this module must
        # not re-enter it mid-initialization.
        from repro.moe import ExpertLoadTracker, resolve_routing
        self.routing = resolve_routing(cfg)
        self.expert_load = ExpertLoadTracker(
            self.routing, ep=cfg.parallelism.ep) \
            if self.routing is not None else None
        self.perf = PerfModel(cfg, trace=trace, routing=self.routing)
        # prefix-cache restore / tier-fetch latency charged to the next
        # iteration (the request that hit pays for its own fetch)
        self._pending_fetch_s = 0.0
        self._tput_hint = {}     # phase -> lazily priced reference tokens/s

    def warmup(self):
        pass

    def prompt_cap(self, req: SimRequest):
        return None

    def throughput_hint(self, phase: Optional[str] = None) -> float:
        """Trace-priced tokens/s on a reference batch — the cold-start
        signal ``hardware_aware`` routing uses before observed throughput
        exists.  ``phase`` selects the per-phase reference (a 256-token
        prefill, or a 4-wide decode at context 256); ``None`` blends both
        for unified-role instances.  P/D role-aware placement queries the
        matching phase so a prefill-fast device is rated by its prefill
        grid, not a blend it will never run."""
        if None not in self._tput_hint:
            from repro.core.perfmodel import BatchItem
            pre = self.perf.iteration_latency(
                [BatchItem(tokens=256, context=256, phase="prefill")])
            dec = self.perf.iteration_latency(
                [BatchItem(tokens=1, context=256, phase="decode")
                 for _ in range(4)])
            self._tput_hint["prefill"] = 256 / max(pre.total_s, 1e-12)
            self._tput_hint["decode"] = 4 / max(dec.total_s, 1e-12)
            self._tput_hint[None] = (256 + 4) / max(
                pre.total_s + dec.total_s, 1e-12)
        # unknown phase strings fall back to the blended estimate rather
        # than crashing a custom routing policy
        return self._tput_hint.get(phase, self._tput_hint[None])

    def execute(self, work: List[ScheduledWork], now: float) -> float:
        items = to_batch_items(work)
        counts = n_tokens = None
        if self.routing is not None:
            # one bincount pass per iteration, shared by pricing and the
            # expert-load accounting (the real engine accounts
            # independently, from its slot lengths — that independence is
            # what the parity suite tests)
            pos = batch_positions(items)
            n_tokens = int(pos.size)
            counts = [self.routing.counts_for(l, pos)
                      for l in range(self.routing.n_layers)]
        cost = self.perf.iteration_latency(items, routing_counts=counts)
        latency = cost.total_s + self._pending_fetch_s
        self._pending_fetch_s = 0.0
        if self.expert_load is not None:
            self.expert_load.observe_counts(counts, n_tokens, now)
        return latency

    def on_prefix_hit(self, req: SimRequest, match: MatchResult,
                      usable: int) -> int:
        if match.lower_tier_bytes > 0:
            # promote host-tier blocks: pay the fetch on this request
            self._pending_fetch_s += self.memory.transfer_time(
                match.lower_tier_bytes, "host", "device")
        if usable > 0:
            # restoring the hit KV into the running cache is a real slot
            # copy (measured by the engine profiler as kv_export)
            self._pending_fetch_s += self.perf.kv_copy_cost(usable)
        return usable

    def on_prefill_complete(self, req: SimRequest):
        pass     # insert cost is modeled inside the perf trace (kv_export)

    def on_preempt(self, req: SimRequest) -> int:
        return req.cached_prefix   # simulated KV prefix stays restorable

    def release(self, req: SimRequest):
        pass

    def export_kv(self, req: SimRequest) -> KvHandoff:
        return KvHandoff(
            nbytes=req.prompt_len * self.cfg.model.kv_bytes_per_token)

    def import_kv(self, req: SimRequest, handoff: Optional[KvHandoff]):
        pass

    def reset(self):
        pass

    def stats(self) -> dict:
        if self.expert_load is not None:
            return {"expert_load": self.expert_load.metrics()}
        return {}
