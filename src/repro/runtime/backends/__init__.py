"""Execution backends for the unified serving runtime.

``SimBackend`` is importable unconditionally; ``JaxBackend`` pulls in jax
and the real engine, so import it from its module directly:

    from repro.runtime.backends.sim import SimBackend
    from repro.runtime.backends.jax_engine import JaxBackend
"""
from repro.runtime.backends.sim import SimBackend

__all__ = ["SimBackend"]
