"""SLO-aware autoscaling: a policy evaluated on a fixed cadence that
watches per-tenant SLO attainment and queue depth, and scales the fleet
through the runtime's elastic-scaling primitives.

The policy is deliberately event-pure: every evaluation is an explicit
event on the simulation queue (hence a decode fast-forward barrier by
construction), every observation is taken at that event's simulated time,
and every action lands as another explicit event (``add_instance`` /
``remove_instance`` / ``rebalance_pd``).  Nothing reads wall-clock time or
draws randomness, so the decision sequence — and therefore the whole
simulation — is bit-identical between the fast path and exact stepped
mode, and between ``SimBackend`` and ``JaxBackend`` up to the time axis.

Scaling rules (classic target-tracking, kept simple on purpose — the
point is the *interface*: subclass and override ``decide``):

* scale OUT when the worst tenant's SLO attainment over the last window
  drops below ``target_attainment``, or the mean per-instance queue depth
  exceeds ``queue_high`` — whichever fires first;
* scale IN when attainment is healthy and mean queue depth falls below
  ``queue_low`` — the least-loaded instance is drained (in-flight work
  preempts and requeues) and retired;
* both respect ``min_instances`` / ``max_instances`` bounds and an
  optional ``cooldown_s`` between actions.

Only instances whose role matches the template's role participate in the
count and in victim selection, so a P/D fleet can autoscale its decode
pool while the prefill pool stays fixed; when a P/D map is live, pool
membership is re-published via ``rebalance_pd`` after every action.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

from repro.core.config import InstanceCfg
from repro.core.metrics import slo_met


@dataclasses.dataclass(frozen=True)
class AutoscaleCfg:
    interval_s: float = 2.0          # evaluation cadence (simulated time)
    target_attainment: float = 0.95  # worst-tenant SLO floor before scale-out
    queue_high: float = 4.0          # mean queue depth triggering scale-out
    queue_low: float = 1.0           # mean queue depth allowing scale-in
    min_instances: int = 1
    max_instances: int = 64
    cooldown_s: float = 0.0          # min simulated time between actions
    name_prefix: str = "as"          # scale-out instances: as0, as1, ...


class SLOAutoscaler:
    """Evaluate ``AutoscaleCfg`` thresholds on cadence and act through the
    runtime's elastic-scaling events.  Attach via
    ``runtime.attach_autoscaler(SLOAutoscaler(cfg))`` (or the
    ``autoscale=`` argument of ``repro.core.simulate``) before ``run``.

    ``template`` is the ``InstanceCfg`` cloned for scale-out instances
    (only the name changes); it defaults to the first configured instance
    whose role is ``unified``, else the first instance outright.
    """

    def __init__(self, cfg: AutoscaleCfg = AutoscaleCfg(),
                 template: Optional[InstanceCfg] = None):
        self.cfg = cfg
        self.template = template
        self.rt = None
        self.ticks = 0
        self.actions: List[Dict] = []
        # (t, live instance count in the scaled pool) after every tick
        self.timeline: List[tuple] = []
        self._counter = 0
        self._seen_finished = 0
        self._last_action_t = float("-inf")

    # ---- wiring ----
    def attach(self, runtime):
        self.rt = runtime
        if self.template is None:
            insts = list(runtime.cfg.instances)
            if not insts:
                raise ValueError("autoscaler needs at least one configured "
                                 "instance to use as a scale-out template")
            unified = [i for i in insts if i.role == "unified"]
            self.template = (unified or insts)[0]
        self._schedule_tick()

    def _schedule_tick(self):
        self.rt.queue.schedule(self.cfg.interval_s, self._tick,
                               tag="autoscale.tick")

    # ---- pool view ----
    def _pool(self):
        """Live instances the policy manages (role-matched to template)."""
        role = self.template.role
        return [i for i in self.rt.instances.values()
                if i.alive and i.cfg.role == role]

    # ---- observation ----
    def observe(self) -> Dict:
        """Window observation at the current tick: worst-tenant SLO
        attainment over finishes since the last tick (None when none
        finished) and mean queue depth over the managed pool."""
        new = self.rt.finished[self._seen_finished:]
        self._seen_finished = len(self.rt.finished)
        attainment: Optional[float] = None
        if new:
            per_tenant: Dict[str, List[bool]] = {}
            for r in new:
                per_tenant.setdefault(r.tenant, []).append(slo_met(r))
            attainment = min(sum(v) / len(v) for v in per_tenant.values())
        pool = self._pool()
        depth = (sum(len(i.scheduler.waiting) + len(i._pending_decode)
                     for i in pool) / len(pool)) if pool else 0.0
        return {"attainment": attainment, "queue_depth": depth,
                "pool": pool}

    # ---- policy ----
    def decide(self, obs: Dict) -> Optional[str]:
        """Return "out", "in" or None.  Override for custom policies; the
        surrounding machinery (cadence, bounds, cooldown, event purity)
        is inherited."""
        att, depth = obs["attainment"], obs["queue_depth"]
        slo_bad = att is not None and att < self.cfg.target_attainment
        if slo_bad or depth > self.cfg.queue_high:
            return "out"
        if not slo_bad and depth < self.cfg.queue_low:
            return "in"
        return None

    # ---- the tick event ----
    def _tick(self):
        rt = self.rt
        self.ticks += 1
        now = rt.queue.now
        obs = self.observe()
        pool = obs["pool"]
        n = len(pool)
        verdict = self.decide(obs)
        if now - self._last_action_t < self.cfg.cooldown_s:
            verdict = None
        rec = rt.obs
        if rec is not None:
            from repro.obs.events import AUTOSCALE
            rec.emit(now, AUTOSCALE,
                     payload={"verdict": verdict, "pool": n,
                              "attainment": obs["attainment"],
                              "queue_depth": obs["queue_depth"]})
        if verdict == "out" and n < self.cfg.max_instances:
            name = f"{self.cfg.name_prefix}{self._counter}"
            self._counter += 1
            rt.add_instance(now, dataclasses.replace(self.template,
                                                     name=name))
            self._record("scale_out", name, obs, now)
            n += 1
            self._sync_pd(now, added=name)
        elif verdict == "in" and n > self.cfg.min_instances:
            # deterministic victim: least loaded, name as tiebreak
            victim = min(pool, key=lambda i: (i.load(), i.name))
            rt.remove_instance(now, victim.name)
            self._record("scale_in", victim.name, obs, now)
            n -= 1
            self._sync_pd(now, removed=victim.name)
        self.timeline.append((now, n))
        # keep evaluating until the workload is fully served
        if rt._all_requests and len(rt.finished) < len(rt._all_requests):
            self._schedule_tick()

    def _record(self, action: str, name: str, obs: Dict, now: float):
        self._last_action_t = now
        self.actions.append({
            "t": now, "action": action, "instance": name,
            "attainment": obs["attainment"],
            "queue_depth": obs["queue_depth"]})

    def _sync_pd(self, now: float, added: Optional[str] = None,
                 removed: Optional[str] = None):
        """When a P/D map is live and the scaled pool is the decode side,
        republish membership so prefill instances hand off to the current
        decode fleet (scale-out targets join, drained targets leave)."""
        if not self.rt.pd_map or self.template.role != "decode":
            return
        new_map: Dict[str, tuple] = {}
        for pre, decs in self.rt.pd_map.items():
            decs = tuple(d for d in decs if d != removed)
            if added is not None:
                decs = decs + (added,)
            new_map[pre] = decs
        self.rt.rebalance_pd(now, new_map)

    # ---- reporting ----
    def metrics(self) -> Dict:
        return {
            "ticks": self.ticks,
            "actions": list(self.actions),
            "timeline": list(self.timeline),
            "n_scale_out": sum(1 for a in self.actions
                               if a["action"] == "scale_out"),
            "n_scale_in": sum(1 for a in self.actions
                              if a["action"] == "scale_in"),
        }
