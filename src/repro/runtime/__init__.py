"""Backend-agnostic serving runtime (the paper's "unified" layer).

One scheduler / prefix-cache / router / P-D-orchestration stack drives both
the discrete-event simulator and the real JAX engine.  All serving *policy*
lives here exactly once; backends implement the small ``ExecutionBackend``
protocol and differ only in how a scheduled batch is turned into latency:

* ``SimBackend``   prices the batch with the trace-driven ``PerfModel``.
* ``JaxBackend``   executes it for real (jitted prefill/extend/decode over a
  slot-based KV cache) and measures wall-clock latency.

Because every dispatch decision (routing, admission, chunking, preemption,
P/D handoff) is made by the same code path, fidelity comparisons such as
``benchmarks/fig2_fidelity.py`` isolate pure hardware-model error — the
scheduling-policy divergence term is zero by construction.
"""
import repro.core  # noqa: F401  (initialize the substrate package first:
# repro.core's compat shims import runtime modules back, so entering the
# runtime package cold must let core finish before runtime submodules load)
from repro.runtime.autoscale import AutoscaleCfg, SLOAutoscaler
from repro.runtime.backend import ExecutionBackend, KvHandoff
from repro.runtime.cluster import ServingRuntime
from repro.runtime.instance import RuntimeInstance
from repro.runtime.prefix_cache import MatchResult, RadixPrefixCache
from repro.runtime.router import (GlobalRouter, HardwareAware, LeastLoaded,
                                  PrefixAware, RoundRobin, RoutingPolicy,
                                  register_policy)
from repro.runtime.scheduler import BatchScheduler, ScheduledWork, WaitQueue

__all__ = [
    "AutoscaleCfg", "SLOAutoscaler",
    "ExecutionBackend", "KvHandoff", "ServingRuntime", "RuntimeInstance",
    "MatchResult", "RadixPrefixCache", "GlobalRouter", "RoutingPolicy",
    "RoundRobin", "LeastLoaded", "PrefixAware", "HardwareAware",
    "register_policy", "BatchScheduler", "ScheduledWork", "WaitQueue",
]
