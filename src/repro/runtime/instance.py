"""A serving instance: scheduler + prefix cache + pluggable backend.

Runs the iteration loop as events on the shared queue: pick a batch with the
unified ``BatchScheduler``, hand it to the ``ExecutionBackend`` (which either
prices it — simulator — or really executes it and measures wall time — JAX
engine), schedule the completion event, apply results (prefill progress,
decode tokens, finishes), repeat.  Roles: unified | prefill | decode (P/D
disaggregation wires prefill instances to decode instances via the cluster's
KV-transfer path).

Because the loop, scheduler, cache policy and P/D flow are shared, the
sequence of scheduling decisions (``self.decisions``) is identical across
backends for the same workload — only the time axis differs.
"""
from __future__ import annotations

from collections import deque
from typing import Callable, Deque, Dict, List, Optional, Tuple

from repro.core.config import InstanceCfg
from repro.core.engine import EventQueue
from repro.core.request import (DECODING, FINISHED, QUEUED,
                                TRANSFERRING, SimRequest)
from repro.obs.events import (ADMIT, FINISH, ITER, KV_RESTORE, KV_TIER,
                              PD_ADMIT, PREEMPT)
from repro.runtime.backend import ExecutionBackend, KvHandoff
from repro.runtime.prefix_cache import RadixPrefixCache
from repro.runtime.scheduler import BatchScheduler, ScheduledWork


class RuntimeInstance:
    def __init__(self, cfg: InstanceCfg, queue: EventQueue,
                 backend: ExecutionBackend,
                 cache: Optional[RadixPrefixCache] = None):
        self.cfg = cfg
        self.name = cfg.name
        self.queue = queue
        self.backend = backend
        self.mem = backend.memory
        self.scheduler = BatchScheduler(cfg.scheduler, self.mem)
        self.scheduler.on_preempt = self._on_preempt
        self.cache = cache
        self.alive = True
        self.busy = False
        # set by the cluster: True when this instance's iteration events
        # provably touch only this instance (no P/D wiring, no shared
        # prefix cache), making them skippable for other instances'
        # decode fast-forward horizons
        self.iter_skippable = False
        # last observed decode-step latency: a cheap span pre-gate for
        # fast-forward attempts (purely advisory — skipping an attempt
        # never changes results, only which iterations get bulked)
        self._ff_latency_hint: Optional[float] = None
        self.busy_time = 0.0
        self.iterations = 0
        self.total_tokens = 0
        # per-phase observed throughput: pure-phase iterations attribute
        # their latency+tokens to that phase (mixed iterations only feed
        # the blended totals above) — the signal P/D role-aware routing
        # prefers over the blended reference batch
        self.phase_tokens: Dict[str, int] = {"prefill": 0, "decode": 0}
        self.phase_time: Dict[str, float] = {"prefill": 0.0, "decode": 0.0}
        self.phase_iters: Dict[str, int] = {"prefill": 0, "decode": 0}
        # (req_id, phase, tokens) per work item per iteration — the policy
        # trace the sim/real parity test compares across backends (bounded:
        # long production simulations keep only the most recent window)
        self.decisions: Deque[Tuple[Tuple[int, str, int], ...]] = \
            deque(maxlen=65536)
        # KV-pool watermark timeline: (t, pool blocks in use, running reqs)
        # sampled once per iteration — vLLM-style watermark plots.  The
        # window is configurable (InstanceCfg.watermark_window) and the
        # dropped-sample count is surfaced in stats() so timeline
        # consumers know when the record is truncated
        self.kv_watermark: Deque[Tuple[float, int, int]] = \
            deque(maxlen=max(int(cfg.watermark_window), 1))
        self._wm_appended = 0
        # event recorder (None = tracing disabled; every emission site is
        # guarded so the disabled path costs one attribute load)
        self.obs = None
        # callbacks wired by the cluster
        self.on_prefill_done: Optional[Callable] = None   # P/D handoff
        self.on_request_done: Optional[Callable] = None
        # set when the instance has been removed from the fleet (elastic
        # scale-in): a late P/D arrival (KV transfer scheduled before the
        # removal landed) is handed back for re-dispatch instead of being
        # parked on an instance that will never iterate again
        self.on_dead_arrival: Optional[Callable] = None
        # P/D arrivals that found no slot/memory; drained as capacity frees
        self._pending_decode: Deque[Tuple[SimRequest,
                                          Optional[KvHandoff]]] = deque()

    # ---- observability ----
    def attach_obs(self, recorder) -> None:
        """Enable event tracing: wire the recorder into the instance, its
        scheduler (admission hook) and its backend (spec-step events)."""
        self.obs = recorder
        self.scheduler.on_admit = self._emit_admit
        self.backend.obs = recorder

    def _emit_admit(self, req: SimRequest):
        self.obs.emit(self.queue.now, ADMIT, inst=self.name,
                      req=req.req_id, tenant=req.tenant)

    # ---- request entry ----
    def submit(self, req: SimRequest):
        if not self.alive:
            raise RuntimeError(f"submit to dead instance {self.name}")
        req.instance = self.name
        cap = self.backend.prompt_cap(req)
        if cap is not None and req.prompt_len > cap:
            # keep scheduler bookkeeping and backend KV state in agreement
            req.prompt_tokens = list(req.prompt_tokens)[:max(cap, 1)]
        if self.cache is not None and req.state == QUEUED \
                and req.prefill_done_tokens == 0:
            m = self.cache.match(req.prompt_tokens, self.queue.now,
                                 getattr(req, "priority", 0))
            # never cache-skip the whole prompt: the last token must be
            # recomputed to produce the first output logits
            usable = min(m.tokens, req.prompt_len - 1)
            usable = max(usable, 0)
            # backend clamps to what it can actually restore and accounts
            # any tier-fetch / KV-copy cost
            req.cached_prefix = self.backend.on_prefix_hit(req, m, usable)
            if m.lower_tier_bytes > 0:
                self.cache.promote(m.nodes, self.queue.now)
            self.cache.pin(m.nodes)
            req._pinned_nodes = m.nodes   # type: ignore[attr-defined]
            self._settle_cache()
            obs = self.obs
            if obs is not None and m.tokens > 0:
                obs.emit(self.queue.now, KV_RESTORE, inst=self.name,
                         req=req.req_id, tenant=req.tenant,
                         payload={"tokens": usable,
                                  "seconds": getattr(self.backend,
                                                     "last_restore_s", 0.0),
                                  "host_tokens": m.host_tokens,
                                  "ssd_tokens": m.ssd_tokens})
        self.scheduler.enqueue(req)
        self._kick()

    # ---- iteration loop ----
    def _kick(self):
        if self.alive and not self.busy:
            self._start_iteration()

    def _start_iteration(self):
        work = self.scheduler.next_batch()
        if not work:
            self.busy = False
            return
        self.busy = True
        if self._maybe_fast_forward(work):
            return
        self.decisions.append(
            tuple((w.request.req_id, w.phase, w.tokens) for w in work))
        latency = self.backend.execute(work, self.queue.now)
        self.iterations += 1
        tokens = sum(w.tokens for w in work)
        self.total_tokens += tokens
        self.busy_time += latency
        phases = {w.phase for w in work}
        if len(phases) == 1:
            phase = phases.pop()
            self.phase_tokens[phase] += tokens
            self.phase_time[phase] += latency
            self.phase_iters[phase] += 1
            if phase == "decode":
                # rough per-step cost, feeding the fast-forward pre-gate
                self._ff_latency_hint = latency
        self.queue.schedule(latency,
                            lambda: self._finish_iteration(work, latency),
                            tag=f"{self.name}.iter",
                            skippable=self.iter_skippable)

    def _finish_iteration(self, work: List[ScheduledWork],
                          latency: float = 0.0):
        if not self.alive:
            return
        now = self.queue.now
        self.kv_watermark.append(
            (now, self.mem.total_blocks - self.mem.free_blocks,
             len(self.scheduler.running)))
        self._wm_appended += 1
        obs = self.obs
        if obs is not None:
            phases = {w.phase for w in work}
            obs.emit(now, ITER, inst=self.name,
                     phase=(phases.pop() if len(phases) == 1 else "mixed"),
                     dur=latency,
                     payload={"items": tuple((w.request.req_id, w.phase,
                                              w.tokens) for w in work),
                              "kv_used": self.mem.total_blocks
                              - self.mem.free_blocks,
                              "running": len(self.scheduler.running),
                              "waiting": len(self.scheduler.waiting)})
        for w in work:
            req = w.request
            if w.phase == "prefill":
                req.prefill_done_tokens += w.tokens
                if req.remaining_prefill == 0:
                    self._prefill_complete(req)
            else:
                # a decode step emits 1 token classically; a speculative
                # step emits accepted + 1 (backends report the count —
                # the trace draw in sim, the verification outcome for the
                # real engine), capped at the request's output budget
                emitted = 1
                fn = getattr(self.backend, "decode_emitted", None)
                if fn is not None:
                    emitted = fn(req)
                emitted = max(1, min(emitted,
                                     req.output_len - req.generated))
                req.generated += emitted
                req.token_times.extend([now] * emitted)
                if req.t_first_token is None:
                    req.t_first_token = now
                if req.generated >= req.output_len:
                    self._finish_request(req)
        self._drain_pending_decode()
        self.busy = False
        self._start_iteration()

    # ---- decode fast-forward ----
    #: max steps per bulk event — bounds the synthesized timeline arrays
    #: (and matches the kv_watermark window) without limiting total skip
    FF_CHUNK = 4096

    def _maybe_fast_forward(self, work: List[ScheduledWork]) -> bool:
        """Advance a provably frozen decode set many iterations in one
        event.  Sound exactly when nothing can change the per-step
        decision between now and the next barrier: the backend's pricing
        is deterministic, no request is waiting/parked (admission retries
        every slow-path iteration), every running request is mid-decode
        (finishes can only land on the window's LAST step — the window
        never extends past the earliest completion, and the apply event
        runs the identical finish handling), and memory can grow the
        whole window without a preemption the slow path wouldn't have
        done.  Every synthesized
        artifact — decisions, token times, watermark samples, phase
        accounting, the KV ledger — is computed by the same arithmetic
        the stepped path runs, so fast and exact modes are bit-identical
        (``tests/test_fast_path.py``)."""
        be = self.backend
        if not getattr(be, "supports_fast_forward", False):
            return False
        if self._pending_decode:
            return False
        if self.scheduler.waiting and len(self.scheduler.running) \
                < self.scheduler.cfg.max_batch_size:
            # a free slot means the slow path would retry admission every
            # iteration (with possible preemption on memory pressure); at
            # capacity the admission loop is slot-gated before any side
            # effect, no slot can free before the window's last step, and
            # the apply event re-runs admission right there — so waiting
            # requests stay frozen exactly as the stepped path would
            # leave them
            return False
        if any(w.phase != "decode" for w in work):
            return False
        if any(r.state != DECODING for r in self.scheduler.running):
            return False
        # advisory pre-gate: when the span to the next barrier can't fit
        # ~2 steps of the last observed decode latency, skip the attempt
        # before paying any pricing.  A skipped window runs stepped —
        # results are identical either way (fast-forward is
        # identity-preserving), so a stale hint costs only speed.  This
        # keeps barrier-dense shapes (P/D interleaving, saturated
        # arrivals) from paying attempt overhead thousands of times.
        horizon = self.queue.next_barrier_time()
        span = horizon - self.queue.now
        if span <= 0.0:
            return False
        hint = self._ff_latency_hint
        if hint is not None and span < 2.0 * hint:
            return False
        n_max = min(w.request.output_len - w.request.generated
                    for w in work)
        n_max = min(n_max, self.FF_CHUNK)
        if n_max < 2:
            return False
        reqs = [w.request for w in work]
        n_max = self.scheduler.decode_window_steps(reqs, n_max)
        if n_max < 2:
            return False
        lat = be.fast_forward(work, n_max, self.queue.now, horizon)
        if lat is None:
            return False
        self._ff_latency_hint = lat[-1]
        # commit: capture pool usage BEFORE the lump reservation, then
        # grow the ledger exactly as n stepped reservations would have
        used0 = self.mem.total_blocks - self.mem.free_blocks
        used_deltas = self.scheduler.decode_window_usage(reqs, len(lat))
        self.scheduler.advance_decode(reqs, len(lat))
        decision = tuple((w.request.req_id, w.phase, w.tokens)
                         for w in work)
        times = []
        t = self.queue.now
        for l in lat:
            t = t + l
            times.append(t)
        self.queue.schedule_at(
            times[-1],
            lambda: self._apply_fast_forward(work, decision, lat, times,
                                             used_deltas, used0),
            tag=f"{self.name}.iter", skippable=self.iter_skippable)
        return True

    def _apply_fast_forward(self, work: List[ScheduledWork], decision,
                            lat, times, used_deltas, used0: int):
        """Land the bulk event: replay the per-step bookkeeping the
        stepped path would have produced, in the same accumulation
        order (float sums are order-sensitive)."""
        if not self.alive:
            return
        n = len(lat)
        tokens = sum(w.tokens for w in work)
        nrun = len(self.scheduler.running)
        # the window stands for n next_batch calls but composed only one:
        # replay the other n - 1 steps' per-tenant service increments
        self.scheduler.account_window(work, n - 1)
        for i in range(n):
            self.decisions.append(decision)
            self.kv_watermark.append(
                (times[i], used0 + int(used_deltas[i]), nrun))
            self.busy_time += lat[i]
            self.phase_time["decode"] += lat[i]
        self._wm_appended += n
        obs = self.obs
        if obs is not None:
            # synthesize the per-step iteration events the stepped path
            # would have emitted — same timestamps, durations and gauges
            # (the waiting/running sets are provably frozen mid-window)
            waiting = len(self.scheduler.waiting)
            for i in range(n):
                obs.emit(times[i], ITER, inst=self.name, phase="decode",
                         dur=lat[i],
                         payload={"items": decision,
                                  "kv_used": used0 + int(used_deltas[i]),
                                  "running": nrun, "waiting": waiting})
        self.iterations += n
        self.total_tokens += tokens * n
        self.phase_tokens["decode"] += tokens * n
        self.phase_iters["decode"] += n
        for w in work:
            req = w.request
            req.generated += n
            req.token_times.extend(times)
            if req.t_first_token is None:
                req.t_first_token = times[0]
            if req.generated >= req.output_len:
                # only possible on the window's last step (the window is
                # capped at the earliest remaining-output count), so this
                # runs at the same simulated time as the stepped path's
                # finish — releasing KV, unpinning, notifying the cluster
                self._finish_request(req)
        self._drain_pending_decode()
        self.busy = False
        self._start_iteration()

    def _prefill_complete(self, req: SimRequest):
        now = self.queue.now
        # first token is produced by the prefill's last iteration
        if req.t_first_token is None:
            req.t_first_token = now
            req.token_times.append(now)
            req.generated = 1
        if self.cache is not None:
            self.cache.insert(req.prompt_tokens, now,
                              getattr(req, "priority", 0))
            self.backend.on_prefill_complete(req)
            self._settle_cache()
        if self.cfg.role == "prefill" and self.on_prefill_done is not None:
            req.state = TRANSFERRING
            self.scheduler.complete(req)
            self._unpin(req)
            self.on_prefill_done(req, self)
        else:
            req.state = DECODING
            if req.generated >= req.output_len:
                self._finish_request(req)

    def _finish_request(self, req: SimRequest):
        req.state = FINISHED
        req.t_finish = self.queue.now
        obs = self.obs
        if obs is not None:
            obs.emit(req.t_finish, FINISH, inst=self.name, req=req.req_id,
                     tenant=req.tenant, payload={"tokens": req.generated})
        self.scheduler.complete(req)
        self.backend.release(req)
        self._unpin(req)
        if self.on_request_done is not None:
            self.on_request_done(req, self)

    def _on_preempt(self, req: SimRequest):
        req.cached_prefix = max(0, self.backend.on_preempt(req))
        obs = self.obs
        if obs is not None:
            obs.emit(self.queue.now, PREEMPT, inst=self.name,
                     req=req.req_id, tenant=req.tenant,
                     payload={"reason": "memory"})

    def _settle_cache(self):
        """Hand tier moves from the last cache mutation to the backend.

        Called immediately after every mutating cache call (match+promote
        in ``submit``, ``insert`` in ``_prefill_complete``,
        ``release_pressure`` in ``admit_decode``) so — even with a shared
        ``scope="global"`` cache — the pending list only ever holds moves
        *this* instance caused, and this instance's backend is the one
        that prices (sim) or performs (JaxBackend payload offload/restore)
        them.  Tier moves never create standalone events: their cost rides
        the instance's next iteration (``_pending_fetch_s`` /
        ``_carry_s``), which keeps the decode fast-forward sound — spills
        and promotes only happen at submit/prefill-complete/admit edges,
        all of which are barriers already.
        """
        if self.cache is None:
            return
        transfers = self.cache.take_transfers()
        fn = getattr(self.backend, "on_tier_transfer", None)
        if fn is not None:
            for src, dst, n_bytes, prefix in transfers:
                fn(src, dst, n_bytes, prefix)
        obs = self.obs
        if obs is not None and transfers:
            now = self.queue.now
            res = self.cache.residency()
            for src, dst, n_bytes, _prefix in transfers:
                obs.emit(now, KV_TIER, inst=self.name,
                         payload={"src": src, "dst": dst,
                                  "bytes": float(n_bytes),
                                  "residency": res})

    def _unpin(self, req: SimRequest):
        nodes = getattr(req, "_pinned_nodes", None)
        if nodes and self.cache is not None:
            self.cache.unpin(nodes)
            req._pinned_nodes = []   # type: ignore[attr-defined]

    # ---- decode-side admission for P/D ----
    def admit_decode(self, req: SimRequest,
                     handoff: Optional[KvHandoff] = None):
        """Request arrives with KV already transferred (P/D handoff)."""
        if not self.alive and self.on_dead_arrival is not None:
            # the instance was scaled in while this KV transfer was in
            # flight: the transferred KV is gone with the instance, so the
            # request restarts from prefill wherever the router sends it
            # (a *failed* instance keeps the classic park-until-revive
            # path below — on_dead_arrival is only set on removal)
            self.on_dead_arrival(req)
            return
        req.instance = self.name
        req.state = DECODING
        req.prefill_done_tokens = req.prompt_len - req.cached_prefix
        ok = self.scheduler.admit_remote(req)
        if not ok and self.cache is not None and self.cache.mem is self.mem:
            # memory pressure from prefix-cache borrows: evict and retry
            # (only when the cache borrows from THIS instance's pool — a
            # global-scope cache may be bound to a sibling's memory)
            self.cache.release_pressure(
                self.mem.blocks_for(req.context_len + 1), self.queue.now)
            self._settle_cache()
            ok = self.scheduler.admit_remote(req)
        if not ok and not self.scheduler.running:
            # idle instance: nothing will ever free memory, so a parked
            # request would be lost — admit with whatever blocks remain
            # (the ledger records the partial reservation exactly)
            ok = self.scheduler.admit_remote(req, force=True)
        if not ok:
            # slots/memory busy: safe to park — running work is in flight
            # and _finish_iteration drains the queue as capacity frees
            self._pending_decode.append((req, handoff))
            return
        self.backend.import_kv(req, handoff)
        obs = self.obs
        if obs is not None:
            obs.emit(self.queue.now, PD_ADMIT, inst=self.name,
                     req=req.req_id, tenant=req.tenant,
                     payload={"parked": False})
        self._kick()

    def _drain_pending_decode(self):
        while self._pending_decode:
            req, handoff = self._pending_decode[0]
            ok = self.scheduler.admit_remote(req)
            if not ok and not self.scheduler.running:
                ok = self.scheduler.admit_remote(req, force=True)
            if not ok:
                break
            self._pending_decode.popleft()
            self.backend.import_kv(req, handoff)
            obs = self.obs
            if obs is not None:
                obs.emit(self.queue.now, PD_ADMIT, inst=self.name,
                         req=req.req_id, tenant=req.tenant,
                         payload={"parked": True})

    # ---- failures / elasticity ----
    def fail(self) -> List[SimRequest]:
        """Node failure: drop in-flight state, return requests to re-route."""
        self.alive = False
        self.busy = False
        orphans = self.scheduler.requeue_all()
        for req, _ in self._pending_decode:
            # parked P/D arrivals lost their KV too: full restart elsewhere
            req.prefill_done_tokens = 0
            req.generated = 0
            req.n_restarts += 1
            orphans.append(req)
        self._pending_decode.clear()
        for req in orphans:
            # release radix pins so a (possibly shared) cache stays evictable
            self._unpin(req)
        self.backend.reset()
        return orphans

    def drain(self) -> List[SimRequest]:
        """Elastic scale-in: stop the instance and preempt-and-requeue all
        in-flight work.  Same bookkeeping as ``fail`` — running requests
        drop their KV and restart from prefill elsewhere (counted in
        ``n_restarts``), queued requests just move — but the removal is
        intentional: the cluster re-dispatches the orphans immediately and
        retires the instance instead of awaiting a revive."""
        return self.fail()

    def revive(self):
        self.alive = True
        self._kick()

    def load(self) -> float:
        """Router load signal: queue depth + memory pressure."""
        return (len(self.scheduler.waiting) + len(self.scheduler.running)
                + len(self._pending_decode) + 2.0 * self.mem.utilization())

    def throughput_estimate(self, phase: Optional[str] = None) -> float:
        """Tokens/s signal for hardware-aware routing: observed throughput
        once enough iterations ran, else the backend's static hint (the
        trace-priced reference batch for ``SimBackend``).

        ``phase`` ("prefill" | "decode") returns the phase-specific
        estimate — observed from pure-phase iterations when available,
        else the backend's per-phase hint — so P/D role-aware placement
        stops rating a prefill-only instance by a blended batch it never
        runs.  ``None`` keeps the blended estimate for unified instances.
        """
        if phase in self.phase_iters:    # unknown phase -> blended
            if self.phase_iters[phase] >= 8 and self.phase_time[phase] > 0:
                return self.phase_tokens[phase] / self.phase_time[phase]
            hint = getattr(self.backend, "throughput_hint", None)
            if hint is not None:
                return hint(phase)
        if self.iterations >= 8 and self.busy_time > 0:
            return self.total_tokens / self.busy_time
        hint = getattr(self.backend, "throughput_hint", None)
        return hint() if hint is not None else 1.0

    def stats(self) -> dict:
        s = {"iterations": self.iterations, "tokens": self.total_tokens,
             "busy_s": self.busy_time, "backend": self.backend.name,
             "hw": self.cfg.hw_name or self.cfg.hw.name,
             "preemptions": self.scheduler.n_preemptions,
             "mem_peak_blocks": self.mem.peak_used,
             # per-tenant service split (scheduled tokens) — the signal
             # the weighted-share guard balances
             "tenant_service": dict(self.scheduler.served_tokens),
             # scheduler ledger exposure: per-request blocks held right now
             # plus the sampled pool watermark timeline (vLLM-style plots)
             "kv_occupancy": self.scheduler.occupancy(),
             "kv_watermark": list(self.kv_watermark),
             # samples evicted by the bounded window — nonzero means the
             # timeline above is truncated (raise watermark_window)
             "kv_watermark_dropped": self._wm_appended
             - len(self.kv_watermark)}
        if self.cache is not None:
            s["prefix_cache"] = self.cache.stats()
            kv = {"cache": self.cache.name,
                  "residency_blocks": self.cache.residency(),
                  "hit_tokens": dict(self.cache.tier_hit_tokens),
                  "transfers": {k: dict(v) for k, v in
                                self.cache.tier_transfers.items()}}
            extra = getattr(self.backend, "kv_tier_stats", None)
            if extra is not None:
                kv.update(extra())
            s["kv_tiers"] = kv
        s.update(self.backend.stats())
        return s
