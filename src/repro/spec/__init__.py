"""Trace-driven speculative decoding: one artifact, two engines.

``repro.spec`` owns the portable representation of "how many draft tokens
does the target accept per step" (the spec-decode analogue of
``repro.moe``'s "which experts did each token hit"):

* :class:`AcceptanceTrace` — versioned JSON artifact (``spectrace/1``):
  per-position-bucket acceptance-length distributions with a
  deterministic per-position realization both backends share.  Recorded
  from real draft/target runs (``repro.spec.record``) or synthesized from
  a target acceptance rate (``repro.workload.acceptance``).
* :class:`SpecDecodeTracker` — the uniform spec-decode metrics accounting
  (acceptance rate, mean accepted length, wasted draft tokens, per-step
  timeline) both execution backends report through
  ``metrics()["spec_decode"]``.
* :class:`AcceptanceRegistry` / :func:`resolve_acceptance` — name
  resolution for ``SpecCfg.acceptance_trace``, mirroring
  ``MoECfg.routing_trace``.
* :func:`draft_model_spec` — a scaled-down ``ModelSpec`` for pricing the
  draft model when a sim config does not name one explicitly.

This package is jax-free; the real-engine side lives in
``repro.serve.engine`` (the draft engine + batched verification) and
``repro.runtime.backends.jax_engine`` (the spec-step orchestration), both
of which import jax lazily.
"""
from __future__ import annotations

import dataclasses

from repro.spec.record import AcceptanceRecorder, record_acceptance
from repro.spec.registry import (AcceptanceRegistry,
                                 default_acceptance_registry,
                                 get_acceptance, load_acceptance,
                                 register_acceptance, resolve_acceptance)
from repro.spec.trace import (READABLE_SCHEMAS, SCHEMA_VERSION,
                              AcceptanceTrace, SpecDecodeTracker)


def draft_model_spec(model, scale: float = 0.25):
    """A scaled-down ``ModelSpec`` standing in for the draft model in sim
    pricing when ``SpecCfg.draft`` is unset: layer count and widths shrink
    by ``scale`` (weight bytes roughly by ``scale**3``), vocab is shared
    (token ids must line up with the target's)."""
    if not 0 < scale <= 1:
        raise ValueError(f"draft scale must be in (0, 1], got {scale}")

    def dim(n, lo=1):
        return max(int(round(n * scale)), lo)

    return dataclasses.replace(
        model,
        name=f"{model.name}-draft{scale:g}",
        n_layers=dim(model.n_layers),
        d_model=dim(model.d_model, 8),
        d_ff=dim(model.d_ff, 8),
        n_heads=dim(model.n_heads),
        n_kv_heads=min(dim(model.n_kv_heads), dim(model.n_heads)),
        moe_experts=0, moe_top_k=0, moe_d_expert=0,
        param_bytes=0.0)


__all__ = [
    "AcceptanceTrace", "SpecDecodeTracker", "SCHEMA_VERSION",
    "READABLE_SCHEMAS", "AcceptanceRecorder", "record_acceptance",
    "AcceptanceRegistry", "default_acceptance_registry",
    "register_acceptance", "get_acceptance", "load_acceptance",
    "resolve_acceptance", "draft_model_spec",
]
