"""Record an ``AcceptanceTrace`` from a real draft/target run.

A :class:`AcceptanceRecorder` accumulates the (position, accepted-length)
pairs ``JaxBackend`` produces while serving a workload through a
speculating ``ServingEngine`` in *verify* mode (no trace replay: accepted
length = how many draft proposals the target's greedy verification really
matched).  The histogram is the artifact: per position bucket, the
observed distribution over accepted lengths 0..k.

CLI: ``python -m repro.profiler record-acceptance --arch <arch>
[--draft-arch <arch>]`` (also ``profile --spec`` to ride along with a
hardware profile).
"""
from __future__ import annotations

from typing import Dict, Optional

import numpy as np

from repro.spec.trace import AcceptanceTrace


class AcceptanceRecorder:
    """Host-side accumulator for (position, accepted) observations.

    ``enabled`` gates accumulation at runtime so warmup traffic can be
    excluded (spec steps only run from scheduled work, but the gate keeps
    the contract symmetric with ``repro.moe.record.RoutingRecorder``).
    """

    def __init__(self, k: int, period: int = 256):
        self.k = int(k)
        self.period = int(period)
        self.hist = np.zeros((self.period, self.k + 1), np.int64)
        self.enabled = True

    def observe(self, position: int, accepted: int):
        if not self.enabled:
            return
        a = int(min(max(accepted, 0), self.k))
        self.hist[int(position) % self.period, a] += 1

    def to_trace(self, model: str = "*", draft: str = "*",
                 meta: Optional[Dict] = None) -> AcceptanceTrace:
        """Distill the histogram into an artifact.  Position buckets with
        no observations fall back to the trace-global distribution (every
        recorded trace has at least one observation — an empty recorder
        is an error, not a fabricated artifact)."""
        total = self.hist.sum(axis=0)
        if total.sum() == 0:
            raise ValueError(
                "AcceptanceRecorder saw no spec steps — record through a "
                "speculating engine (ServingEngine(spec=...)) first")
        hist = self.hist.astype(float)
        unseen = hist.sum(axis=1) == 0
        hist[unseen] = total / total.sum()
        info = {"source": "recorded", "period": self.period,
                "observations": int(self.hist.sum())}
        info.update(meta or {})
        return AcceptanceTrace(model=model, draft=draft, k=self.k,
                               hist=hist, meta=info).validate()


def record_acceptance(arch: str, draft_arch: Optional[str] = None, *,
                      k: int = 4, n_requests: int = 8, rate: float = 50.0,
                      max_batch: int = 4, max_len: int = 256,
                      period: int = 256, seed: int = 0,
                      draft_seed: int = 1, mean_prompt: int = 40,
                      mean_output: int = 8) -> AcceptanceTrace:
    """Serve a synthetic workload through a speculating engine (real
    draft proposals, real batched target verification) and distill the
    observed acceptance lengths into an artifact.

    ``draft_arch`` defaults to the target architecture itself with a
    different parameter seed — the smallest self-contained draft/target
    pair this container can run; pass a genuinely smaller arch for
    realistic acceptance dynamics.
    """
    from repro.configs import get_config
    from repro.serve.driver import ServeDriver
    from repro.serve.engine import ServingEngine, SpecDecodeCfg
    from repro.workload import ShareGPTConfig, generate

    cfg = get_config(arch)
    draft_cfg = get_config(draft_arch) if draft_arch else cfg
    recorder = AcceptanceRecorder(k, period=period)
    eng = ServingEngine(
        cfg, max_batch=max_batch, max_len=max_len, name="rec0", seed=seed,
        spec=SpecDecodeCfg(draft=draft_cfg, k=k, draft_seed=draft_seed,
                           recorder=recorder))
    drv = ServeDriver([eng])
    drv.runtime.warmup()
    reqs = generate(ShareGPTConfig(
        n_requests=n_requests, rate=rate, vocab=cfg.vocab, seed=seed,
        mean_prompt=mean_prompt, mean_output=mean_output,
        max_prompt=max(max_len // 4, 16), max_output=max(mean_output, 4)))
    drv.runtime.submit_workload(reqs)
    drv.runtime.run()
    return recorder.to_trace(model=cfg.name, draft=draft_cfg.name,
                             meta={"arch": arch,
                                   "draft_arch": draft_arch or arch,
                                   "n_requests": n_requests, "seed": seed})
