"""Portable acceptance-trace artifacts (the spec-decode sim <-> real contract).

An ``AcceptanceTrace`` is the versioned, JSON-serializable artifact that
makes speculative-decoding acceptance dynamics *replayable*: per token
position (bucketed ``position % period``), a distribution over how many of
the draft model's ``k`` proposed tokens the target model accepts.  It is
either **recorded** from a real draft/target run (``python -m
repro.profiler record-acceptance --arch <arch>``; see
``repro.spec.record``) or **synthesized** from a target per-token
acceptance rate (``repro.workload.acceptance``), and the same artifact
then drives both execution backends:

* ``SimBackend`` prices every spec step as draft-cost + verify-cost and
  advances each request by the trace's accepted length + 1 (the bonus /
  correction token), so TTFT/TPOT/goodput reflect acceptance dynamics;
* ``JaxBackend`` replays the trace on the real engine: the draft still
  proposes and the target still verifies in-graph, but the acceptance
  *decision* is forced to the trace's draw (the spec-decode analogue of
  ``repro.moe``'s forced-assignment routing hook).

The determinism contract both backends share: a spec step for a request
that has already emitted ``g`` output tokens draws its accepted length at
``position = g - 1`` (the 0-based index of the last emitted token), via
:meth:`AcceptanceTrace.accepted_for` — an inverse-CDF lookup at a fixed
Weyl-sequence point, so one artifact yields one deterministic realization
with no RNG state to synchronize.  ``tests/test_spec_decode.py`` pins that
both backends produce identical per-step accepted-token counts for a
shared trace, the same way ``test_expert_routing.py`` does for expert
loads.

JSON schema (version ``spectrace/1``)::

    {
      "schema": "spectrace/1",      # required
      "model": "llama3.1-8b",       # target model
      "draft": "llama3.1-8b-draft", # draft model (informational)
      "k": 4,                       # draft proposal length per step
      "hist": [[w0, ..., wk],       # one row per position bucket:
               ...],                #   weights over accepted lengths 0..k
      "meta": {"source": "synthetic", "alpha": 0.7, ...}
    }

Rows are unnormalized nonnegative weights (recorded traces store counts,
synthesized ones probabilities); lookups normalize.
"""
from __future__ import annotations

import dataclasses
import json
import os
from collections import deque
from typing import Dict, Optional

import numpy as np

SCHEMA_VERSION = "spectrace/1"
#: schema versions this build can read (save always emits SCHEMA_VERSION)
READABLE_SCHEMAS = ("spectrace/1",)

#: Weyl-sequence increment (golden ratio conjugate): successive spec
#: steps visit quantiles low-discrepancy-uniformly, so the realized
#: acceptance rate over a run converges to the trace's distributions.
#: The quantile is keyed on the request's spec-step ordinal, NOT its
#: token position: positions advance by the draw itself (accepted + 1),
#: so a position-keyed sequence would orbit-lock onto a biased subset of
#: quantiles, while the step ordinal increments by exactly 1 per step.
_WEYL = 0.6180339887498949


def _quantile_point(step: int) -> float:
    """Deterministic quantile in [0, 1) for one per-request spec-step
    ordinal — the single definition both backends draw through."""
    return float(((int(step) + 1) * _WEYL) % 1.0)


@dataclasses.dataclass
class AcceptanceTrace:
    """One replayable acceptance-length artifact (see module docstring).

    ``hist`` is a ``(period, k + 1)`` float array: row ``b`` weights the
    accepted lengths ``0..k`` for positions with ``position % period ==
    b``.
    """

    model: str
    draft: str
    k: int
    hist: np.ndarray
    meta: Dict = dataclasses.field(default_factory=dict)

    # ---- shape access ----
    @property
    def period(self) -> int:
        return int(np.asarray(self.hist).shape[0])

    def _probs(self) -> np.ndarray:
        h = np.asarray(self.hist, float)
        return h / h.sum(axis=1, keepdims=True)

    # ---- lookup ----
    def accepted_for(self, position: int, step: int = 0) -> int:
        """Accepted draft-token count (0..k) for one spec step — the
        deterministic inverse-CDF draw both backends share.  ``position``
        (the 0-based index of the request's last emitted output token)
        selects the distribution bucket; ``step`` (the request's 0-based
        spec-step ordinal, +1 per executed step) selects the quantile,
        keeping the realized acceptance equidistributed (see module
        docstring on why position alone would bias it)."""
        position = max(int(position), 0)
        row = np.asarray(self.hist[position % self.period], float)
        cdf = np.cumsum(row)
        u = _quantile_point(step) * cdf[-1]
        return int(min(np.searchsorted(cdf, u, side="right"), self.k))

    def mean_accepted(self) -> float:
        """Expected accepted length per step (averaged over buckets)."""
        p = self._probs()
        return float((p * np.arange(self.k + 1)[None, :]).sum(axis=1).mean())

    def acceptance_rate(self) -> float:
        """Expected per-proposal acceptance: mean accepted length / k."""
        return self.mean_accepted() / max(self.k, 1)

    # ---- compatibility ----
    def check_k(self, k: int) -> "AcceptanceTrace":
        """Raise unless this trace was built for draft length ``k`` —
        a mismatched table would silently mis-draw accepted lengths."""
        if int(k) != self.k:
            raise ValueError(
                f"acceptance trace {self.model!r} was recorded for draft "
                f"length k={self.k}, but the config speculates k={k}")
        return self

    # ---- validation ----
    def validate(self) -> "AcceptanceTrace":
        if self.k < 1:
            raise ValueError(f"AcceptanceTrace needs k >= 1, got {self.k}")
        h = np.asarray(self.hist, float)
        if h.ndim != 2 or h.shape[1] != self.k + 1 or h.shape[0] < 1:
            raise ValueError(
                f"hist shape {h.shape} != (period >= 1, k + 1 = "
                f"{self.k + 1})")
        if np.any(h < 0) or np.any(~np.isfinite(h)):
            raise ValueError("hist weights must be finite and >= 0")
        if np.any(h.sum(axis=1) <= 0):
            raise ValueError(
                "every hist row needs positive total weight (an "
                "all-zero bucket has no acceptance distribution)")
        return self

    # ---- io ----
    def to_doc(self) -> Dict:
        return {
            "schema": SCHEMA_VERSION,
            "model": self.model,
            "draft": self.draft,
            "k": int(self.k),
            "hist": np.asarray(self.hist, float).tolist(),
            "meta": self.meta,
        }

    def to_json(self) -> str:
        """Canonical serialization — byte-identical for identical traces
        (the determinism contract the synthesis generator is tested on)."""
        return json.dumps(self.to_doc(), sort_keys=True,
                          separators=(",", ":"))

    def save(self, path: str) -> str:
        self.validate()
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        with open(path, "w") as f:
            json.dump(self.to_doc(), f, indent=1, sort_keys=True)
        return path

    @classmethod
    def load(cls, path: str) -> "AcceptanceTrace":
        with open(path) as f:
            doc = json.load(f)
        schema = doc.get("schema")
        if schema not in READABLE_SCHEMAS:
            raise ValueError(
                f"{path}: unsupported acceptance schema {schema!r} "
                f"(this build reads {READABLE_SCHEMAS!r})")
        for key in ("k", "hist"):
            if key not in doc:
                raise ValueError(f"{path}: missing required key {key!r}")
        trace = cls(model=doc.get("model", "*"),
                    draft=doc.get("draft", "*"),
                    k=int(doc["k"]),
                    hist=np.asarray(doc["hist"], float),
                    meta=doc.get("meta", {}))
        return trace.validate()


class SpecDecodeTracker:
    """Uniform spec-decode accounting for both execution backends.

    Each backend calls ``observe(position, accepted, now)`` once per
    executed spec step per request; since both backends draw accepted
    lengths from the same trace at the same positions (sim from the
    scheduler's request bookkeeping, real from the engine's independently
    tracked per-slot emit counts), the parity suite pins that the
    resulting metrics — acceptance rate, mean accepted length, wasted
    draft tokens, per-step timeline — are identical.
    """

    def __init__(self, k: int, timeline_len: int = 4096):
        self.k = int(k)
        self.steps = 0
        self.proposed = 0
        self.accepted = 0
        self.hist = np.zeros(self.k + 1, np.int64)
        # (t, position, accepted) per spec step, bounded
        self.timeline = deque(maxlen=timeline_len)

    def observe(self, position: int, accepted: int, now: float,
                proposed: Optional[int] = None):
        """``proposed`` is the drafts actually produced for this request
        this step — ``k`` normally, fewer when the tail clamp shrank the
        window near the output budget (both backends clamp identically, so
        acceptance-rate accounting stays comparable)."""
        a = int(min(max(accepted, 0), self.k))
        self.steps += 1
        self.proposed += self.k if proposed is None else int(proposed)
        self.accepted += a
        self.hist[a] += 1
        self.timeline.append((float(now), int(position), a))

    def metrics(self) -> Dict:
        steps = max(self.steps, 1)
        return {
            "k": self.k,
            "steps": int(self.steps),
            "proposed_tokens": int(self.proposed),
            "accepted_tokens": int(self.accepted),
            # every step also emits the bonus/correction token
            "emitted_tokens": int(self.accepted + self.steps),
            "acceptance_rate": self.accepted / max(self.proposed, 1),
            "mean_accepted_len": self.accepted / steps,
            "wasted_draft_tokens": int(self.proposed - self.accepted),
            "accepted_hist": self.hist.tolist(),
            "step_timeline": list(self.timeline),
        }
