"""Named acceptance traces: how cluster configs reference an artifact.

``SpecCfg.acceptance_trace`` names a trace; both backends resolve that
name here at instance-build time (``resolve_acceptance``), exactly like
``MoECfg.routing_trace`` resolves through ``repro.moe`` and
``InstanceCfg.hw_name`` through ``repro.hw``.  Registering once
(``register_acceptance``/``load_acceptance``) makes the artifact
available to every cluster config in the process.
"""
from __future__ import annotations

import os
from typing import Dict, List, Optional

from repro.spec.trace import READABLE_SCHEMAS, AcceptanceTrace


class AcceptanceRegistry:
    """Name -> ``AcceptanceTrace`` (no synthetic fallback: acceptance
    dynamics are an explicit experiment input, never guessed silently)."""

    def __init__(self):
        self._traces: Dict[str, AcceptanceTrace] = {}

    def register(self, name: str,
                 trace: AcceptanceTrace) -> AcceptanceTrace:
        trace.validate()
        self._traces[name] = trace
        return trace

    def names(self) -> List[str]:
        return sorted(self._traces)

    def get(self, name: str) -> AcceptanceTrace:
        if name not in self._traces:
            raise KeyError(
                f"no acceptance trace registered as {name!r}; loaded: "
                f"{self.names() or '(none)'} — record one with `python -m "
                f"repro.profiler record-acceptance --arch <arch>` or "
                f"synthesize one with repro.workload.acceptance")
        return self._traces[name]

    def load_file(self, path: str,
                  name: Optional[str] = None) -> AcceptanceTrace:
        trace = AcceptanceTrace.load(path)
        key = name or os.path.splitext(os.path.basename(path))[0]
        return self.register(key, trace)

    def load_dir(self, path: str) -> List[str]:
        """Load every acceptance artifact in ``path`` (registered under
        the file stem).  JSON files with a foreign or missing ``schema``
        key (e.g. ``hwtrace``/``moetrace`` artifacts sharing ``traces/``)
        are skipped."""
        import json
        import warnings
        names = []
        for fn in sorted(os.listdir(path)):
            if not fn.endswith(".json"):
                continue
            fp = os.path.join(path, fn)
            with open(fp) as f:
                try:
                    doc = json.load(f)
                except ValueError:
                    continue
            schema = doc.get("schema", "") if isinstance(doc, dict) else ""
            if not schema.startswith("spectrace/"):
                continue
            if schema not in READABLE_SCHEMAS:
                warnings.warn(
                    f"{fp}: unreadable acceptance schema {schema!r} — "
                    f"skipped")
                continue
            name = os.path.splitext(fn)[0]
            names.append(name)
            self.load_file(fp, name=name)
        return names


#: Process-wide default registry (``SpecCfg.acceptance_trace`` resolves
#: here when no explicit registry is passed).
default_acceptance_registry = AcceptanceRegistry()


def register_acceptance(name: str,
                        trace: AcceptanceTrace) -> AcceptanceTrace:
    return default_acceptance_registry.register(name, trace)


def get_acceptance(name: str) -> AcceptanceTrace:
    return default_acceptance_registry.get(name)


def load_acceptance(path: str, name: Optional[str] = None):
    """Load an acceptance-trace file or directory into the default
    registry."""
    if os.path.isdir(path):
        return default_acceptance_registry.load_dir(path)
    return default_acceptance_registry.load_file(path, name=name)


def resolve_acceptance(icfg, registry: Optional[AcceptanceRegistry] = None
                       ) -> Optional[AcceptanceTrace]:
    """The trace named by ``icfg.spec.acceptance_trace`` (None when
    unset), checked structurally compatible with the configured draft
    length."""
    spec = getattr(icfg, "spec", None)
    name = getattr(spec, "acceptance_trace", None)
    if not name:
        return None
    reg = registry or default_acceptance_registry
    return reg.get(name).check_k(spec.k)
