"""ShareGPT-like request workload (deterministic synthetic).

The paper samples 100 requests from ShareGPT [12] with Poisson arrivals at
10 req/s. This container is offline, so we synthesize requests whose
prompt/output length distributions match the published ShareGPT statistics
(lognormal-ish, mean prompt ~161 tokens / mean output ~338 tokens as reported
in the vLLM paper's ShareGPT analysis), plus a configurable shared-prefix
structure to exercise prefix caching (multi-turn conversations share their
conversation history — the property RadixAttention exploits).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import numpy as np

from repro.workload.arrival import poisson


@dataclasses.dataclass
class Request:
    req_id: int
    arrival: float               # seconds
    prompt_tokens: Sequence[int]  # token ids (for prefix-cache matching)
    output_len: int
    model: str = "default"
    slo_ttft_ms: float = 2000.0
    slo_tpot_ms: float = 200.0
    # multi-tenant class identity (see repro.core.config.TenantClass and
    # repro.workload.tenants): carried onto the SimRequest at submission
    # so the priority scheduler, the per-tenant metrics rollup and the
    # SLO-aware autoscaler all see the same class.
    tenant: str = "default"
    priority: int = 0
    weight: float = 1.0

    @property
    def prompt_len(self) -> int:
        return len(self.prompt_tokens)


@dataclasses.dataclass(frozen=True)
class ShareGPTConfig:
    n_requests: int = 100
    rate: float = 10.0            # Poisson rate (req/s)
    seed: int = 0
    vocab: int = 32_000
    mean_prompt: float = 161.0    # ShareGPT stats (vLLM paper)
    sigma_prompt: float = 0.9
    mean_output: float = 338.0
    sigma_output: float = 0.9
    max_prompt: int = 4096
    max_output: int = 2048
    min_len: int = 4
    # prefix sharing: fraction of requests that continue an earlier
    # conversation (reusing its prompt as a prefix)
    share_fraction: float = 0.3
    n_conversations: int = 20


def generate(cfg: ShareGPTConfig = ShareGPTConfig()) -> List[Request]:
    rng = np.random.default_rng(cfg.seed)
    arrivals = poisson(cfg.rate, cfg.n_requests, seed=cfg.seed + 1)

    def sample_len(mean, sigma, cap):
        mu = np.log(mean) - sigma ** 2 / 2
        return int(np.clip(rng.lognormal(mu, sigma), cfg.min_len, cap))

    conversations: List[List[int]] = [[] for _ in range(cfg.n_conversations)]
    requests = []
    for i in range(cfg.n_requests):
        out_len = sample_len(cfg.mean_output, cfg.sigma_output, cfg.max_output)
        conv_id = int(rng.integers(cfg.n_conversations))
        history = conversations[conv_id]
        if history and rng.random() < cfg.share_fraction:
            # multi-turn: prompt = shared history + new turn
            new_turn = rng.integers(0, cfg.vocab,
                                    sample_len(cfg.mean_prompt / 2,
                                               cfg.sigma_prompt,
                                               cfg.max_prompt // 2)).tolist()
            prompt = list(history) + new_turn
        else:
            prompt = rng.integers(0, cfg.vocab,
                                  sample_len(cfg.mean_prompt,
                                             cfg.sigma_prompt,
                                             cfg.max_prompt)).tolist()
        prompt = prompt[: cfg.max_prompt]
        conversations[conv_id] = prompt  # history grows with the turn
        requests.append(Request(
            req_id=i, arrival=float(arrivals[i]),
            prompt_tokens=prompt, output_len=out_len))
    return requests


def stats(requests: List[Request]) -> dict:
    p = np.array([r.prompt_len for r in requests], float)
    o = np.array([r.output_len for r in requests], float)
    return {"n": len(requests),
            "prompt_mean": p.mean(), "prompt_p50": np.median(p),
            "prompt_p99": np.percentile(p, 99),
            "output_mean": o.mean(), "output_p50": np.median(o),
            "output_p99": np.percentile(o, 99)}
