"""Parameterized acceptance-rate generators -> ``AcceptanceTrace``.

Synthesizes the deterministic acceptance-length distributions the
speculative-decoding scenario studies replay (the spec-decode analogue of
``repro.workload.expert_skew``).  The model is the standard truncated
geometric: with per-token target acceptance rate ``alpha``, a spec step
accepts exactly ``a < k`` drafts with probability ``alpha^a * (1 -
alpha)`` and all ``k`` with probability ``alpha^k``.  ``jitter`` perturbs
``alpha`` per position bucket (seeded; rng consumption is independent of
``alpha`` so sweeps over the rate share all other randomness), modeling
position-dependent acceptance (e.g. early tokens verifying easier than
late ones).  A fixed seed reproduces the artifact byte-for-byte.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.spec.trace import AcceptanceTrace


@dataclasses.dataclass(frozen=True)
class AcceptanceConfig:
    alpha: float = 0.7        # per-token target acceptance rate
    k: int = 4                # draft proposal length per step
    period: int = 256         # position-bucket count (wrap mod period)
    jitter: float = 0.0       # per-bucket gaussian alpha perturbation
    seed: int = 0


def synthesize_acceptance(cfg: AcceptanceConfig = AcceptanceConfig(),
                          model: str = "*",
                          draft: str = "*") -> AcceptanceTrace:
    """Build a deterministic ``AcceptanceTrace`` from an acceptance spec."""
    if not 0.0 <= cfg.alpha <= 1.0:
        raise ValueError(f"alpha must be in [0, 1], got {cfg.alpha}")
    if cfg.k < 1:
        raise ValueError(f"k must be >= 1, got {cfg.k}")
    if cfg.period < 1:
        raise ValueError(f"period must be >= 1, got {cfg.period}")
    rng = np.random.default_rng(cfg.seed)
    # noise drawn unconditionally: the rng stream is identical across
    # alpha sweeps, so per-bucket rates move monotonically with alpha
    noise = rng.normal(0.0, 1.0, cfg.period)
    alpha_b = np.clip(cfg.alpha + cfg.jitter * noise, 0.0, 1.0)
    a = np.arange(cfg.k + 1)[None, :]
    hist = alpha_b[:, None] ** a
    hist[:, :-1] *= (1.0 - alpha_b)[:, None]
    # truncated geometric rows sum to 1 exactly (modulo float), including
    # the degenerate alpha in {0, 1} cases
    meta = {"source": "synthetic", "alpha": cfg.alpha,
            "jitter": cfg.jitter, "seed": cfg.seed, "period": cfg.period}
    return AcceptanceTrace(model=model, draft=draft, k=cfg.k, hist=hist,
                           meta=meta).validate()
