"""Parameterized expert-skew generators -> ``ExpertRoutingTrace``.

Synthesizes the deterministic routing tables the MoE scenario studies
replay (uniform / zipf-skewed / temporally-correlated hot sets — the same
taxonomy ``core.expert.ExpertRouter`` modeled statistically, now emitted as
a replayable artifact both backends consume).  Sampling is Gumbel top-k
over per-position log-weights: each position draws ``top_k`` *distinct*
experts from a Plackett-Luce distribution, so token counts are conserved
(``period * top_k`` per layer) and a fixed seed reproduces the trace
byte-for-byte.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.moe.trace import ExpertRoutingTrace


@dataclasses.dataclass(frozen=True)
class SkewConfig:
    kind: str = "zipf"        # uniform | zipf | correlated
    zipf_a: float = 1.1       # zipf exponent (higher -> more imbalance)
    period: int = 512         # table length (positions wrap mod period)
    drift: float = 0.08       # correlated: per-position log-weight walk
    seed: int = 0


def _layer_logweights(skew: SkewConfig, n_experts: int,
                      rng: np.random.Generator) -> np.ndarray:
    """(period, n_experts) unnormalized log-weights for one layer.

    The zipf ranking is permuted per layer (each layer has its own hot
    set, as observed in real MoE checkpoints); ``correlated`` adds a
    random walk over positions so the hot set drifts through the sequence
    (session-affinity effects).  The rng consumption order is independent
    of ``zipf_a`` so sweeps over the exponent share all other randomness.
    """
    if skew.kind == "uniform":
        base = np.zeros(n_experts)
    elif skew.kind in ("zipf", "correlated"):
        base = -skew.zipf_a * np.log(np.arange(1, n_experts + 1))
    else:
        raise ValueError(
            f"unknown skew kind {skew.kind!r} "
            f"(uniform | zipf | correlated)")
    base = base[rng.permutation(n_experts)]
    if skew.kind == "correlated":
        walk = np.cumsum(
            rng.normal(0.0, skew.drift, size=(skew.period, n_experts)),
            axis=0)
        return base[None, :] + walk
    return np.broadcast_to(base, (skew.period, n_experts)).copy()


def synthesize_routing(n_layers: int, n_experts: int, top_k: int,
                       skew: SkewConfig = SkewConfig(),
                       model: str = "*") -> ExpertRoutingTrace:
    """Build a deterministic ``ExpertRoutingTrace`` from a skew spec."""
    if n_layers < 1:
        raise ValueError(f"n_layers must be >= 1, got {n_layers}")
    if top_k > n_experts:
        raise ValueError(
            f"top_k={top_k} exceeds n_experts={n_experts}")
    if skew.period < 1:
        raise ValueError(f"period must be >= 1, got {skew.period}")
    rng = np.random.default_rng(skew.seed)
    layers = []
    for _ in range(n_layers):
        logw = _layer_logweights(skew, n_experts, rng)
        gumbel = rng.gumbel(size=(skew.period, n_experts))
        # Gumbel top-k == sampling top_k distinct experts ~ Plackett-Luce
        order = np.argsort(-(logw + gumbel), axis=1, kind="stable")
        layers.append(order[:, :top_k].astype(np.int32))
    meta = {"source": "synthetic", "kind": skew.kind, "seed": skew.seed,
            "period": skew.period}
    if skew.kind in ("zipf", "correlated"):
        meta["zipf_a"] = skew.zipf_a
    if skew.kind == "correlated":
        meta["drift"] = skew.drift
    return ExpertRoutingTrace(model=model, n_experts=n_experts,
                              top_k=top_k, layers=layers,
                              meta=meta).validate()


def routing_for_model(model, skew: SkewConfig = SkewConfig()
                      ) -> ExpertRoutingTrace:
    """Convenience: synthesize a trace shaped for a ``ModelSpec`` or
    ``ArchConfig`` (MoE layer count, expert count and top-k read off the
    config)."""
    from repro.moe.trace import moe_layer_count
    moe = getattr(model, "moe", None)
    if moe is not None:
        n_experts, top_k = moe.n_experts, moe.top_k
    else:
        n_experts, top_k = model.moe_experts, model.moe_top_k
    if not n_experts:
        raise ValueError(
            f"{getattr(model, 'name', model)!r} is not a MoE model")
    return synthesize_routing(moe_layer_count(model), n_experts, top_k,
                              skew, model=model.name)
