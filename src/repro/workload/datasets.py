"""Deterministic synthetic token pipeline for the training example.

Generates a reproducible stream of pseudo-text token batches: a mixture of
Zipf-distributed unigram draws and short repeated n-gram motifs so the loss
actually decreases (there is learnable structure), without any external data.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    batch: int
    seq_len: int
    seed: int = 0
    zipf_a: float = 1.2
    motif_len: int = 8
    n_motifs: int = 512
    motif_prob: float = 0.5


def token_batches(cfg: DataConfig) -> Iterator[dict]:
    """Yields {'inputs': (B,S) int32, 'labels': (B,S) int32} forever."""
    rng = np.random.default_rng(cfg.seed)
    motifs = rng.integers(0, cfg.vocab,
                          size=(cfg.n_motifs, cfg.motif_len)).astype(np.int32)
    while True:
        seqs = np.empty((cfg.batch, cfg.seq_len + 1), np.int32)
        for b in range(cfg.batch):
            pos = 0
            buf = np.empty(cfg.seq_len + 1 + cfg.motif_len + 12, np.int32)
            while pos < cfg.seq_len + 1:
                if rng.random() < cfg.motif_prob:
                    m = motifs[rng.integers(cfg.n_motifs)]
                    buf[pos: pos + cfg.motif_len] = m
                    pos += cfg.motif_len
                else:
                    n = int(rng.integers(2, 12))
                    draws = rng.zipf(cfg.zipf_a, size=n) % cfg.vocab
                    buf[pos: pos + n] = draws[: len(buf) - pos]
                    pos += n
            seqs[b] = buf[: cfg.seq_len + 1]
        yield {"inputs": seqs[:, :-1], "labels": seqs[:, 1:]}
