from repro.workload.arrival import gamma, poisson, uniform
from repro.workload.sharegpt import Request, ShareGPTConfig, generate, stats
from repro.workload.datasets import DataConfig, token_batches

__all__ = ["gamma", "poisson", "uniform", "Request", "ShareGPTConfig",
           "generate", "stats", "DataConfig", "token_batches"]
