from repro.workload.arrival import diurnal, gamma, poisson, uniform
from repro.workload.sharegpt import Request, ShareGPTConfig, generate, stats
from repro.workload.datasets import DataConfig, token_batches
from repro.workload.expert_skew import (SkewConfig, routing_for_model,
                                        synthesize_routing)
from repro.workload.acceptance import AcceptanceConfig, synthesize_acceptance
from repro.workload.tenants import (TenantSpec, TenantWorkloadCfg, apportion,
                                    generate_tenants, workload_bytes)

__all__ = ["gamma", "poisson", "uniform", "Request", "ShareGPTConfig",
           "generate", "stats", "DataConfig", "token_batches",
           "SkewConfig", "synthesize_routing", "routing_for_model",
           "AcceptanceConfig", "synthesize_acceptance", "TenantSpec",
           "TenantWorkloadCfg", "apportion", "generate_tenants",
           "workload_bytes"]
