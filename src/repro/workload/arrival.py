"""Request arrival processes (paper §III-A uses Poisson @ 10 req/s)."""
from __future__ import annotations

import numpy as np


def poisson(rate: float, n: int, seed: int = 0, start: float = 0.0):
    """n arrival timestamps (seconds) of a Poisson process at ``rate`` req/s."""
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / rate, size=n)
    return start + np.cumsum(gaps)


def gamma(rate: float, cv: float, n: int, seed: int = 0, start: float = 0.0):
    """Gamma-process arrivals: cv>1 burstier than Poisson, cv<1 smoother."""
    rng = np.random.default_rng(seed)
    shape = 1.0 / (cv ** 2)
    scale = cv ** 2 / rate
    gaps = rng.gamma(shape, scale, size=n)
    return start + np.cumsum(gaps)


def uniform(rate: float, n: int, start: float = 0.0):
    return start + np.arange(1, n + 1) / rate


def diurnal(rate: float, n: int, period: float = 60.0,
            amplitude: float = 0.8, cv: float = 1.0, seed: int = 0,
            start: float = 0.0):
    """Inhomogeneous arrivals with a sinusoidal intensity — the diurnal
    load shape fleet-scale serving studies sweep (peaks stress routing
    and KV headroom; troughs exercise the decode fast-forward).

    Intensity ``lambda(t) = rate * (1 + amplitude * sin(2*pi*(t - start)
    / period))``, realized by Lewis-Shedler thinning against the peak
    rate.  ``cv`` shapes the candidate gap process (1 = exponential /
    Poisson thinning; > 1 layers burstiness on top of the diurnal
    envelope via gamma gaps).
    """
    if not 0.0 <= amplitude < 1.0 + 1e-12:
        raise ValueError(f"amplitude must be in [0, 1], got {amplitude}")
    rng = np.random.default_rng(seed)
    peak = rate * (1.0 + amplitude)
    if cv == 1.0:
        def gap():
            return rng.exponential(1.0 / peak)
    else:
        shape = 1.0 / (cv ** 2)
        scale = cv ** 2 / peak

        def gap():
            return rng.gamma(shape, scale)
    out = np.empty(n)
    t = start
    k = 0
    while k < n:
        t += gap()
        lam = rate * (1.0 + amplitude
                      * np.sin(2.0 * np.pi * (t - start) / period))
        if rng.uniform() * peak <= lam:
            out[k] = t
            k += 1
    return out
