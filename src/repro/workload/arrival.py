"""Request arrival processes (paper §III-A uses Poisson @ 10 req/s)."""
from __future__ import annotations

import numpy as np


def poisson(rate: float, n: int, seed: int = 0, start: float = 0.0):
    """n arrival timestamps (seconds) of a Poisson process at ``rate`` req/s."""
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / rate, size=n)
    return start + np.cumsum(gaps)


def gamma(rate: float, cv: float, n: int, seed: int = 0, start: float = 0.0):
    """Gamma-process arrivals: cv>1 burstier than Poisson, cv<1 smoother."""
    rng = np.random.default_rng(seed)
    shape = 1.0 / (cv ** 2)
    scale = cv ** 2 / rate
    gaps = rng.gamma(shape, scale, size=n)
    return start + np.cumsum(gaps)


def uniform(rate: float, n: int, start: float = 0.0):
    return start + np.arange(1, n + 1) / rate
