"""Multi-tenant workload generation: tenant-class mixes layered over the
arrival processes (Poisson / gamma-burst / diurnal).

Each :class:`TenantSpec` pairs a :class:`repro.core.config.TenantClass`
(identity, priority, SLO targets, weighted share) with that tenant's
traffic shape — its share of the aggregate request count and its own
prompt/output length distributions.  ``generate_tenants`` apportions the
global request budget across tenants by share (largest-remainder, so the
counts are deterministic and sum exactly), draws each tenant's arrivals
and lengths from tenant-derived seeds, and merges the streams into one
globally arrival-sorted workload with sequential request ids.

Determinism contract (pinned by the property suite): a fixed
``TenantWorkloadCfg`` yields a byte-identical workload — same ids, same
arrivals, same token ids — independent of the process or platform, so
fast/exact and sim/real comparisons can share one workload by value.
"""
from __future__ import annotations

import dataclasses
import json
from typing import List, Sequence

import numpy as np

from repro.core.config import TenantClass
from repro.workload.arrival import diurnal, gamma, poisson
from repro.workload.sharegpt import Request


@dataclasses.dataclass(frozen=True)
class TenantSpec:
    """One tenant class plus its traffic shape in the mix."""
    tenant: TenantClass
    rate_share: float = 1.0       # relative share of the aggregate load
    mean_prompt: float = 161.0    # lognormal-ish lengths (ShareGPT stats)
    sigma_prompt: float = 0.9
    mean_output: float = 338.0
    sigma_output: float = 0.9
    max_prompt: int = 4096
    max_output: int = 2048


@dataclasses.dataclass(frozen=True)
class TenantWorkloadCfg:
    tenants: Sequence[TenantSpec] = ()
    n_requests: int = 100         # aggregate across all tenants
    rate: float = 10.0            # aggregate arrival rate (req/s)
    seed: int = 0
    arrival: str = "poisson"      # poisson | gamma | diurnal
    cv: float = 2.0               # gamma / diurnal burstiness
    period_s: float = 60.0        # diurnal period
    amplitude: float = 0.8        # diurnal amplitude
    vocab: int = 32_000
    min_len: int = 4


def apportion(n: int, shares: Sequence[float]) -> List[int]:
    """Split ``n`` into integer counts proportional to ``shares`` using
    largest-remainder apportionment: deterministic, sums to exactly
    ``n``, and every positive share gets its floor first.  Ties on the
    remainder break toward the earlier tenant (stable ordering)."""
    if not shares:
        return []
    total = float(sum(shares))
    if total <= 0:
        raise ValueError(f"tenant shares must sum > 0, got {list(shares)}")
    quotas = [n * s / total for s in shares]
    counts = [int(q) for q in quotas]
    remainder = n - sum(counts)
    order = sorted(range(len(shares)),
                   key=lambda i: (-(quotas[i] - counts[i]), i))
    for i in order[:remainder]:
        counts[i] += 1
    return counts


def _arrivals(cfg: TenantWorkloadCfg, rate: float, n: int, seed: int):
    if cfg.arrival == "poisson":
        return poisson(rate, n, seed=seed)
    if cfg.arrival == "gamma":
        return gamma(rate, cfg.cv, n, seed=seed)
    if cfg.arrival == "diurnal":
        return diurnal(rate, n, period=cfg.period_s,
                       amplitude=cfg.amplitude, cv=cfg.cv, seed=seed)
    raise ValueError(f"unknown arrival process {cfg.arrival!r}; "
                     f"valid: poisson, gamma, diurnal")


def generate_tenants(cfg: TenantWorkloadCfg) -> List[Request]:
    """The tenant-class mix as one arrival-sorted request list.

    Per tenant: ``count_i`` requests (largest-remainder share of
    ``n_requests``) arriving at rate ``rate * share_i`` from the
    configured process, with lengths drawn from the tenant's own
    distributions.  Each tenant's RNG streams derive from
    ``cfg.seed`` and the tenant *index*, so adding a tenant to the end
    of the mix never perturbs the earlier tenants' draws.  The merge
    sorts by ``(arrival, tenant_index, intra_index)`` — a total order,
    so equal arrival times cannot make the output platform-dependent —
    and re-ids sequentially.
    """
    if not cfg.tenants:
        raise ValueError("TenantWorkloadCfg.tenants must name at least "
                         "one TenantSpec")
    names = [s.tenant.name for s in cfg.tenants]
    if len(set(names)) != len(names):
        raise ValueError(f"duplicate tenant names in mix: {names}")
    counts = apportion(cfg.n_requests,
                       [s.rate_share for s in cfg.tenants])
    total_share = float(sum(s.rate_share for s in cfg.tenants))
    tagged = []   # (arrival, tenant_idx, intra_idx, Request)
    for idx, (spec, count) in enumerate(zip(cfg.tenants, counts)):
        if count == 0:
            continue
        base = cfg.seed + 9973 * (idx + 1)
        rate = cfg.rate * spec.rate_share / total_share
        arrivals = _arrivals(cfg, rate, count, seed=base)
        rng = np.random.default_rng(base + 1)

        def sample_len(mean, sigma, cap):
            mu = np.log(mean) - sigma ** 2 / 2
            return int(np.clip(rng.lognormal(mu, sigma), cfg.min_len, cap))

        t = spec.tenant
        for j in range(count):
            plen = sample_len(spec.mean_prompt, spec.sigma_prompt,
                              spec.max_prompt)
            prompt = rng.integers(0, cfg.vocab, plen).tolist()
            out_len = sample_len(spec.mean_output, spec.sigma_output,
                                 spec.max_output)
            tagged.append((float(arrivals[j]), idx, j, Request(
                req_id=0, arrival=float(arrivals[j]),
                prompt_tokens=prompt, output_len=out_len,
                tenant=t.name, priority=t.priority, weight=t.weight,
                slo_ttft_ms=t.slo_ttft_ms, slo_tpot_ms=t.slo_tpot_ms)))
    tagged.sort(key=lambda e: e[:3])
    out = []
    for i, (_, _, _, req) in enumerate(tagged):
        req.req_id = i
        out.append(req)
    return out


def workload_bytes(requests: List[Request]) -> bytes:
    """Canonical byte serialization of a workload (sorted-key JSON with
    repr-roundtrip floats): equal workloads <=> equal bytes.  The
    byte-identity property test pins ``generate_tenants`` determinism
    on this."""
    rows = [{
        "req_id": r.req_id, "arrival": repr(r.arrival),
        "prompt_tokens": list(r.prompt_tokens), "output_len": r.output_len,
        "model": r.model, "tenant": r.tenant, "priority": r.priority,
        "weight": repr(r.weight), "slo_ttft_ms": repr(r.slo_ttft_ms),
        "slo_tpot_ms": repr(r.slo_tpot_ms),
    } for r in requests]
    return json.dumps(rows, sort_keys=True,
                      separators=(",", ":")).encode()
