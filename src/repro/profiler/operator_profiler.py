"""Operator-level profiler (paper §II-A) for JAX models.

Two backends:

  * **measured** — times each operator class of a real model on the local
    devices over a (tokens × context) grid; the single command of Table III:
    ``python -m repro.profiler --arch llama3.1-8b-tiny --hw cpu``.
    The PyTorch-hook mechanism of the paper maps to explicit per-operator
    jit closures here (we own the module system, DESIGN.md §3).
  * **analytical** — derives the same grid from a ``HardwareSpec`` roofline
    (instant integration of a hypothetical accelerator: TPU v5e/v6e/PIM).

Both emit a ``repro.core.trace.Trace`` consumed by the simulator's
PerfModel; the profiler also self-validates (measured-vs-analytical drift
is recorded in trace.meta, mirroring the paper's validation-in-profiler).
"""
from __future__ import annotations

import dataclasses
import time
from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.config import HardwareSpec
from repro.core.trace import Trace
from repro.hw.specs import get_hw
from repro.hw.synthetic import add_synthetic_points
from repro.models import Model
from repro.models.layers import decode_attention, rmsnorm, swiglu_mlp
from repro.models.flash import flash_attention
from repro.models.moe import moe_ffn
from repro.profiler.arch_spec import model_spec_from_arch

DEFAULT_TOKEN_GRID = (1, 2, 4, 8, 16, 32, 64, 128, 256)
DEFAULT_CTX_GRID = (64, 256, 1024)


def _time_fn(fn, *args, reps: int = 5, warmup: int = 2) -> float:
    jf = jax.jit(fn)
    for _ in range(warmup):
        jax.block_until_ready(jf(*args))
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(jf(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


@dataclasses.dataclass
class ProfilerConfig:
    arch: str
    hardware: str = "cpu-measured"
    mode: str = "measured"             # measured | analytical
    token_grid: Sequence[int] = DEFAULT_TOKEN_GRID
    ctx_grid: Sequence[int] = DEFAULT_CTX_GRID
    tp: int = 1
    seed: int = 0


class OperatorProfiler:
    def __init__(self, pcfg: ProfilerConfig):
        self.pcfg = pcfg
        self.cfg = get_config(pcfg.arch)
        self.key = jax.random.PRNGKey(pcfg.seed)

    # ---- measured backend ----
    def _measured_points(self, trace: Trace):
        cfg = self.cfg
        d, dh = cfg.d_model, cfg.d_head
        H, KV = cfg.n_heads, cfg.n_kv_heads
        dt = jnp.bfloat16
        k1, k2 = jax.random.split(self.key)
        wq = jax.random.normal(k1, (d, H * dh), dt) * 0.02
        wk = jax.random.normal(k1, (d, KV * dh), dt) * 0.02
        wo = jax.random.normal(k1, (H * dh, d), dt) * 0.02
        w_gate = jax.random.normal(k1, (d, max(cfg.d_ff, 8)), dt) * 0.02
        w_up = jax.random.normal(k2, (d, max(cfg.d_ff, 8)), dt) * 0.02
        w_down = jax.random.normal(k2, (max(cfg.d_ff, 8), d), dt) * 0.02
        head_w = jax.random.normal(k2, (d, cfg.padded_vocab), dt) * 0.02
        emb = jax.random.normal(k2, (cfg.padded_vocab, d), dt) * 0.02
        scale = jnp.zeros((d,))
        moe_params = None
        if cfg.moe:
            E, de = cfg.moe.n_experts, cfg.moe.d_expert
            moe_params = {
                "router": jax.random.normal(k1, (d, E), dt) * 0.02,
                "w_gate": jax.random.normal(k1, (E, d, de), dt) * 0.02,
                "w_up": jax.random.normal(k2, (E, d, de), dt) * 0.02,
                "w_down": jax.random.normal(k2, (E, de, d), dt) * 0.02,
            }

        for T in self.pcfg.token_grid:
            x = jax.random.normal(k1, (T, d), dt)
            # qkv + out projections
            t = _time_fn(lambda x: (x @ wq) @ wo + (x @ wk)
                         @ jnp.zeros((KV * dh, d), dt), x)
            trace.add("attn_qkv", "decode", T, 1, t)
            trace.add("attn_qkv", "prefill", T, T, t)
            # mlp or moe
            if moe_params is None:
                t = _time_fn(lambda x: swiglu_mlp(x, w_gate, w_up, w_down), x)
                trace.add("mlp", "decode", T, 1, t)
                trace.add("mlp", "prefill", T, T, t)
            else:
                t = _time_fn(lambda x: moe_ffn(
                    x, moe_params, top_k=cfg.moe.top_k)[0], x)
                trace.add("moe_ffn", "decode", T, 1, t)
                trace.add("moe_ffn", "prefill", T, T, t)
            # norm
            t = _time_fn(lambda x: rmsnorm(x, scale), x)
            trace.add("norm", "decode", T, 1, t)
            trace.add("norm", "prefill", T, T, t)
            # head + embed
            t = _time_fn(lambda x: x @ head_w, x)
            trace.add("head", "decode", T, 1, t)
            trace.add("head", "prefill", T, T, t)
            ids = jnp.zeros((T,), jnp.int32)
            t = _time_fn(lambda i: emb[i], ids)
            trace.add("embed", "decode", T, 1, t)
            trace.add("embed", "prefill", T, T, t)

        # attention score/context term over the ctx grid
        for ctx in self.pcfg.ctx_grid:
            for B in (1, 4, 16, 64):
                q = jax.random.normal(k1, (B, 1, H, dh), dt)
                kc = jax.random.normal(k1, (B, ctx, KV, dh), dt)
                vc = jax.random.normal(k2, (B, ctx, KV, dh), dt)
                lengths = jnp.full((B,), ctx, jnp.int32)
                t = _time_fn(lambda q, kc, vc: decode_attention(
                    q, kc, vc, lengths=lengths), q, kc, vc)
                trace.add("attn_score", "decode", B, ctx, t)
            # prefill attention (flash) for one sequence of length ctx
            q = jax.random.normal(k1, (1, ctx, H, dh), dt)
            kk = jax.random.normal(k1, (1, ctx, KV, dh), dt)
            vv = jax.random.normal(k2, (1, ctx, KV, dh), dt)
            t = _time_fn(lambda q, kk, vv: flash_attention(
                q, kk, vv, None, None, min(512, ctx)), q, kk, vv)
            trace.add("attn_score", "prefill", ctx, ctx, t)

    # ---- analytical backend ----
    def _analytical_points(self, trace: Trace, hw: HardwareSpec):
        # the analytical model lives once, in the synthetic-trace generator
        # (repro.hw.synthetic); the profiler's analytical mode is just that
        # generator over this profile's grids
        add_synthetic_points(trace, hw, model_spec_from_arch(self.cfg),
                             tp=self.pcfg.tp,
                             token_grid=self.pcfg.token_grid,
                             ctx_grid=self.pcfg.ctx_grid)

    # ---- entry ----
    def profile(self) -> Trace:
        pcfg = self.pcfg
        trace = Trace(model=pcfg.arch, hardware=pcfg.hardware, tp=pcfg.tp)
        t0 = time.time()
        if pcfg.mode == "measured":
            self._measured_points(trace)
        else:
            hw = get_hw(pcfg.hardware)
            self._analytical_points(trace, hw)
        trace.meta["profile_wall_s"] = time.time() - t0
        trace.meta["mode"] = pcfg.mode
        trace.meta["n_points"] = len(trace.points)
        return trace


def profile_arch(arch: str, hardware: str = "cpu-measured",
                 mode: str = "measured", tp: int = 1, **kw) -> Trace:
    return OperatorProfiler(ProfilerConfig(
        arch=arch, hardware=hardware, mode=mode, tp=tp, **kw)).profile()
