"""ArchConfig -> ModelSpec bridge (jax-free).

The simulator describes models with ``ModelSpec``; the real engine and
profiler use ``ArchConfig``.  This converter is the only coupling, kept out
of the jax-importing profiler modules so the pure-sim path (and the
synthetic-trace CLI) never pays the engine import.
"""
from __future__ import annotations

from repro.configs import ArchConfig
from repro.core.config import ModelSpec


def model_spec_from_arch(cfg: ArchConfig) -> ModelSpec:
    moe = cfg.moe
    return ModelSpec(
        name=cfg.name, n_layers=cfg.n_layers, d_model=cfg.d_model,
        n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads, d_head=cfg.d_head,
        d_ff=cfg.d_ff, vocab=cfg.vocab,
        moe_experts=moe.n_experts if moe else 0,
        moe_top_k=moe.top_k if moe else 0,
        moe_d_expert=moe.d_expert if moe else 0,
        moe_capacity_factor=moe.capacity_factor if moe else 1.25,
        mlp_gated=cfg.mlp_gated,
        param_bytes=cfg.param_count() * 2)
