"""Iteration-level engine profiler.

Times real ``ServingEngine`` iterations in controlled states and emits
``iter`` trace points (phase x tokens x context). This is the highest-
fidelity trace tier: it captures everything the operator-level composition
misses (slot writes, sampling, host sync) — the moral equivalent of the
paper's profiler hooking a real vLLM worker. The simulator's PerfModel
prefers ``iter`` points when present and falls back to operator points.
"""
from __future__ import annotations

import time
from typing import Sequence

import numpy as np

from repro.configs import get_config
from repro.core.trace import Trace
from repro.serve.engine import ServingEngine
from repro.workload.sharegpt import Request


def engine_trace(arch: str, *, max_batch: int = 4, max_len: int = 512,
                 prefill_buckets: Sequence[int] = (16, 32, 64, 128, 256),
                 decode_ctxs: Sequence[int] = (32, 64, 128, 256),
                 reps: int = 3, seed: int = 0) -> Trace:
    cfg = get_config(arch)
    trace = Trace(model=arch, hardware="cpu-engine", tp=1)
    t_start = time.time()
    eng = ServingEngine(cfg, max_batch=max_batch, max_len=max_len,
                        name="probe", seed=seed)
    eng.warmup(buckets=prefill_buckets)
    rng = np.random.default_rng(seed)

    # --- prefill latency per bucket (+ P/D KV-export cost) ---
    rid = 0
    for P in prefill_buckets:
        if P >= max_len - 8:
            continue
        lat, exp_lat = [], []
        for _ in range(reps):
            toks = rng.integers(0, cfg.vocab, P - 1).tolist()
            eng.submit(Request(req_id=rid, arrival=0.0, prompt_tokens=toks,
                               output_len=1))
            rid += 1
            lat.append(eng.step())          # the prefill iteration
            if eng.slot_req:
                slot = next(iter(eng.slot_req))
                t0 = time.perf_counter()
                eng._export_slot(slot, P - 1)
                exp_lat.append(time.perf_counter() - t0)
            while eng.slot_req:             # drain the single decode
                eng.step()
        trace.add("iter", "prefill", P, P, float(np.median(lat)))
        if exp_lat:
            trace.add("kv_export", "prefill", P, P,
                      float(np.median(exp_lat)))

    # --- cached/chunked prefill (extend) latency per (suffix, context) ---
    # the engine's extend path attends over the slot's full buffer, so it is
    # priced separately from fresh prefill (prefix-cache hits, chunk 2+)
    import jax
    import jax.numpy as jnp
    from repro.serve.engine import _bucket
    try:
        for ctx in (16, 64, 128):
            if ctx + 32 >= max_len:
                continue
            toks = rng.integers(0, cfg.vocab, ctx)
            pad = np.zeros((1, _bucket(ctx)), np.int32)
            pad[0, :ctx] = toks
            _, c1 = eng._jit_prefill(eng.params, jnp.asarray(pad),
                                     lengths=jnp.asarray([ctx], jnp.int32))
            eng._write_slot_from_prefill(0, c1, ctx)
            for S in (16, 64, 128):
                if ctx + S >= max_len:
                    continue
                suf = np.zeros((1, S), np.int32)
                suf[0] = rng.integers(0, cfg.vocab, S)
                n_new = jnp.asarray([S], jnp.int32)
                lat = []
                for rep in range(reps + 1):
                    t0 = time.perf_counter()
                    sub = eng._slot_subcache(0, ctx)
                    _, new_sub = eng._jit_extend(eng.params, sub,
                                                 jnp.asarray(suf), n_new)
                    eng._write_slot(0, new_sub, ctx)   # keep length at ctx
                    jax.block_until_ready(eng.cache["lengths"])
                    if rep:                            # rep 0 warms the jits
                        lat.append(time.perf_counter() - t0)
                trace.add("extend", "prefill", S, ctx + S,
                          float(np.median(lat)))
    except NotImplementedError:
        # some architectures (e.g. xLSTM) have no cached-prefill path; the
        # perf model then falls back to fresh-prefill pricing
        pass
    eng._release_slot(0)

    # --- decode latency per (batch, context) ---
    for ctx in decode_ctxs:
        if ctx + 16 >= max_len:
            continue
        for nb in sorted({1, max(1, max_batch // 2), max_batch}):
            eng2 = ServingEngine(cfg, params=eng.params, max_batch=max_batch,
                                 max_len=max_len, name="probe2")
            for i in range(nb):
                toks = rng.integers(0, cfg.vocab, ctx).tolist()
                eng2.submit(Request(req_id=rid, arrival=0.0,
                                    prompt_tokens=toks,
                                    output_len=reps + 4))
                rid += 1
                eng2.step()                 # prefill each
            lat = []
            for _ in range(reps + 2):
                if not eng2.slot_req:
                    break
                lat.append(eng2.step())     # decode iterations
            if lat:
                trace.add("iter", "decode", nb, ctx,
                          float(np.median(lat[1:]) if len(lat) > 1
                                else lat[0]))
    trace.meta["profile_wall_s"] = time.time() - t_start
    trace.meta["mode"] = "engine"
    trace.meta["n_points"] = len(trace.points)
    return trace
