"""CLI: the paper's single-command hardware integration.

  python -m repro.profiler --arch llama3.1-8b-tiny --mode measured
  python -m repro.profiler --arch qwen3-8b --mode analytical --hw tpu-v6e
"""
import argparse
import json

from repro.profiler import profile_arch


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--hw", default="cpu-measured")
    ap.add_argument("--mode", default="measured",
                    choices=["measured", "analytical"])
    ap.add_argument("--tp", type=int, default=1)
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    trace = profile_arch(args.arch, hardware=args.hw, mode=args.mode,
                         tp=args.tp)
    out = args.out or f"traces/{args.arch}.{args.hw}.{args.mode}.json"
    trace.save(out)
    print(json.dumps({"trace": out, **trace.meta}, indent=1))


if __name__ == "__main__":
    main()
