"""Profiler CLI: the paper's single-command hardware integration.

Emit a portable ``HardwareTrace`` artifact for one device (measured through
the unified runtime's JaxBackend on the local device, or synthesized from a
hardware spec for devices you don't have):

  # measure THIS machine through the real engine
  python -m repro.profiler profile --device cpu-engine \
      --arch llama3.1-8b-tiny --out traces/cpu-engine.json

  # sweep tensor-parallel degrees: one hwtrace/3 artifact, one grid per tp
  # (measured sweeps shard the engine; on CPU the needed host device count
  # is forced automatically)
  python -m repro.profiler profile --device cpu-engine --tp 1,2 \
      --arch llama3.1-8b-tiny --out traces/cpu-engine.json

  # synthesize a never-measured accelerator from its spec sheet
  python -m repro.profiler profile --device tpu-v6e \
      --arch llama3.1-8b-tiny --out traces/tpu-v6e.json
  python -m repro.profiler profile --device my-npu --peak-flops 200e12 \
      --hbm-bw 1.2e12 --hbm-capacity 48e9 --link-bw 50e9 \
      --arch llama3.1-8b-tiny --out traces/my-npu.json

The artifact loads via ``repro.hw`` (``load_traces("traces/")``) and is
referenced from cluster configs by ``InstanceCfg(hw_name="<device>")`` —
see docs/adding-hardware.md for the full walkthrough.

MoE architectures have a second artifact: the expert-routing trace
(``repro.moe``, schema ``moetrace/2``), replayable on both backends:

  # record what the real model routes (free-running, recording tap)
  python -m repro.profiler record-routing --arch granite-moe-1b-a400m-tiny \
      --out traces/granite-tiny.routing.json

  # or synthesize a parameterized skew without touching the engine
  python -m repro.profiler record-routing --arch granite-moe-1b-a400m-tiny \
      --mode synthetic --skew zipf --zipf-a 1.3 --out traces/zipf.json

  # ride along with a hardware profile (MoE archs, measured mode)
  python -m repro.profiler profile --device cpu-engine \
      --arch granite-moe-1b-a400m-tiny --experts

Speculative decoding has a third artifact: the acceptance trace
(``repro.spec``, schema ``spectrace/1``), replayable on both backends:

  # record real draft/target acceptance (greedy-lossless verification)
  python -m repro.profiler record-acceptance --arch llama3.1-8b-tiny \
      --k 4 --out traces/llama-tiny.acceptance.json

  # or synthesize from a target per-token acceptance rate
  python -m repro.profiler record-acceptance --arch llama3.1-8b-tiny \
      --mode synthetic --alpha 0.7 --out traces/alpha07.json

  # ride along with a hardware profile
  python -m repro.profiler profile --device cpu-engine \
      --arch llama3.1-8b-tiny --spec

The operator-level profiler (raw ``Trace``, no artifact wrapper) remains as
the ``ops`` subcommand; bare ``python -m repro.profiler --arch ...``
invocations keep their legacy meaning (= ``ops``).
"""
import argparse
import json
import os
import sys


def _parse_tp(value) -> list:
    """``--tp 1,2`` -> sorted unique degrees [1, 2]."""
    if isinstance(value, int):
        value = str(value)
    try:
        tps = sorted({int(t) for t in value.split(",") if t.strip()})
    except ValueError:
        raise SystemExit(
            f"--tp expects comma-separated integers (e.g. --tp 1,2), "
            f"got {value!r}") from None
    if not tps:
        raise SystemExit("--tp needs at least one degree (e.g. --tp 1,2)")
    if tps[0] < 1:
        raise SystemExit(f"--tp degrees must be >= 1, got {tps[0]}")
    return tps


def _ensure_devices(n: int):
    """A measured tp=n probe needs n local devices.  On a CPU host we can
    force them (the whole point of the CPU-validated sharded engine) —
    but only before jax initializes, hence this runs pre-import."""
    if n <= 1 or "jax" in sys.modules:
        return
    if os.environ.get("JAX_PLATFORMS", "").startswith(("cuda", "tpu")):
        return   # real accelerators: the visible device count is physical
    flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in flags:
        os.environ["XLA_FLAGS"] = \
            f"{flags} --xla_force_host_platform_device_count={n}".strip()


def _cmd_profile(args):
    from repro.configs import get_config
    from repro.core.config import HardwareSpec
    from repro.hw import HardwareRegistry, get_hw, register_hw
    from repro.profiler.arch_spec import model_spec_from_arch

    import dataclasses
    spec_flags = {k: getattr(args, k) for k in
                  ("peak_flops", "hbm_bw", "hbm_capacity", "link_bw")}
    if any(v is not None for v in spec_flags.values()):
        missing = [k for k, v in spec_flags.items() if v is None]
        if missing:
            raise SystemExit(
                f"defining a new device spec needs all of --peak-flops "
                f"--hbm-bw --hbm-capacity --link-bw (missing: "
                f"{', '.join('--' + m.replace('_', '-') for m in missing)})")
        register_hw(HardwareSpec(
            name=args.device,
            mmu_efficiency=args.mmu_efficiency
            if args.mmu_efficiency is not None else 0.85,
            **spec_flags))
    elif args.mmu_efficiency is not None:
        # derate/uprate a known spec without redefining the whole device
        register_hw(dataclasses.replace(
            get_hw(args.device), mmu_efficiency=args.mmu_efficiency))

    tps = _parse_tp(args.tp)
    mode = args.mode
    if mode == "auto":
        mode = "measured" if args.device in ("cpu-engine", "local") \
            else "synthetic"
    if mode == "measured":
        _ensure_devices(max(tps))
        from repro.profiler.runtime_profiler import runtime_trace
        hwt, wall = None, 0.0
        for tp in tps:
            one = runtime_trace(args.arch, device=args.device,
                                max_batch=args.max_batch,
                                max_len=args.max_len,
                                reps=args.reps, seed=args.seed, tp=tp)
            wall += one.meta.get("profile_wall_s", 0.0)
            hwt = one if hwt is None else hwt.merge(one)
        # merge() keeps the first probe's meta; restate artifact-wide facts
        hwt.meta["profile_wall_s"] = wall
        hwt.meta.pop("tp", None)
        if args.kernels is not None:
            # hwtrace/3 kernel sub-buckets: per-kernel rows per backend on
            # the base grid (single-device sweep; the perf model composes
            # tp collectives analytically on top)
            from repro.profiler.kernel_profiler import add_kernel_grid
            backends = [b for b in args.kernels.split(",") if b.strip()]
            add_kernel_grid(hwt, args.arch, backends,
                            max_batch=args.max_batch, max_len=args.max_len,
                            reps=args.reps, seed=args.seed)
    else:
        if args.kernels is not None:
            raise SystemExit(
                "--kernels sweeps real kernels and needs measured mode "
                "(--device cpu-engine/local, or --mode measured)")
        from repro.hw.synthetic import synthetic_trace
        hwt = synthetic_trace(get_hw(args.device),
                              model_spec_from_arch(get_config(args.arch)),
                              tp=tps, device=args.device)
    hwt.meta["tp_degrees"] = hwt.tp_degrees()
    hwt.meta["n_points"] = sum(
        len(hwt.grid(t)) for t in hwt.tp_degrees())
    out = args.out or f"traces/{args.device}.json"
    hwt.save(out)
    # round-trip through the registry so a broken artifact fails HERE,
    # not at simulation time
    HardwareRegistry().load_file(out)
    summary = {"trace": out, "device": hwt.device,
               "model": hwt.model, **hwt.meta}
    if args.experts is not None:
        rout = args.experts if args.experts != "auto" \
            else f"traces/{args.device}.routing.json"
        summary["routing_trace"] = _emit_routing(
            args, out=rout, synthetic=(mode != "measured"))
    if args.spec is not None:
        acc = args.spec if args.spec != "auto" \
            else f"traces/{args.device}.acceptance.json"
        summary["acceptance_trace"] = _emit_acceptance(
            args, out=acc, synthetic=(mode != "measured"))
    print(json.dumps(summary, indent=1))


def _emit_routing(args, out: str, synthetic: bool) -> str:
    """Shared by ``profile --experts`` and ``record-routing``: emit (and
    round-trip check) one ExpertRoutingTrace artifact for ``args.arch``."""
    from repro.configs import get_config
    from repro.moe import RoutingRegistry, moe_layer_count

    cfg = get_config(args.arch)
    if cfg.moe is None:
        raise SystemExit(
            f"--arch {args.arch} is not a MoE architecture; expert-routing "
            f"traces need one (e.g. granite-moe-1b-a400m-tiny)")
    if synthetic:
        from repro.workload.expert_skew import SkewConfig, synthesize_routing
        trace = synthesize_routing(
            moe_layer_count(cfg), cfg.moe.n_experts, cfg.moe.top_k,
            SkewConfig(kind=getattr(args, "skew", "zipf"),
                       zipf_a=getattr(args, "zipf_a", 1.1),
                       period=args.period, seed=args.seed),
            model=cfg.name)
    else:
        from repro.moe.record import record_routing
        trace = record_routing(
            args.arch, n_requests=getattr(args, "requests", 8),
            max_batch=args.max_batch, max_len=args.max_len,
            period=args.period, seed=args.seed)
    trace.save(out)
    RoutingRegistry().load_file(out)   # broken artifacts fail at emit time
    return out


def _emit_acceptance(args, out: str, synthetic: bool) -> str:
    """Shared by ``profile --spec`` and ``record-acceptance``: emit (and
    round-trip check) one AcceptanceTrace artifact for ``args.arch``."""
    from repro.spec import AcceptanceRegistry

    k = getattr(args, "k", 4)
    if synthetic:
        from repro.workload.acceptance import (AcceptanceConfig,
                                               synthesize_acceptance)
        trace = synthesize_acceptance(
            AcceptanceConfig(alpha=getattr(args, "alpha", 0.7), k=k,
                             period=args.period,
                             jitter=getattr(args, "jitter", 0.0),
                             seed=args.seed),
            model=args.arch)
    else:
        from repro.spec import record_acceptance
        trace = record_acceptance(
            args.arch, getattr(args, "draft_arch", None), k=k,
            n_requests=getattr(args, "requests", 8),
            max_batch=args.max_batch, max_len=args.max_len,
            period=args.period, seed=args.seed,
            draft_seed=getattr(args, "draft_seed", 1))
    trace.save(out)
    AcceptanceRegistry().load_file(out)  # broken artifacts fail at emit
    return out


def _cmd_record_acceptance(args):
    out = _emit_acceptance(
        args, out=args.out or f"traces/{args.arch}.acceptance.json",
        synthetic=(args.mode == "synthetic"))
    from repro.spec import AcceptanceTrace
    trace = AcceptanceTrace.load(out)
    print(json.dumps({"trace": out, "model": trace.model,
                      "draft": trace.draft, "k": trace.k,
                      "period": trace.period,
                      "mean_accepted": trace.mean_accepted(),
                      "acceptance_rate": trace.acceptance_rate(),
                      **trace.meta}, indent=1))


def _cmd_record_routing(args):
    out = _emit_routing(args,
                        out=args.out or f"traces/{args.arch}.routing.json",
                        synthetic=(args.mode == "synthetic"))
    from repro.moe import ExpertRoutingTrace
    trace = ExpertRoutingTrace.load(out)
    print(json.dumps({"trace": out, "model": trace.model,
                      "n_layers": trace.n_layers,
                      "n_experts": trace.n_experts, "top_k": trace.top_k,
                      "static_imbalance": trace.static_imbalance(),
                      **trace.meta}, indent=1))


def _cmd_ops(args):
    from repro.profiler.operator_profiler import profile_arch
    trace = profile_arch(args.arch, hardware=args.hw, mode=args.mode,
                         tp=args.tp)
    out = args.out or f"traces/{args.arch}.{args.hw}.{args.mode}.json"
    trace.save(out)
    print(json.dumps({"trace": out, **trace.meta}, indent=1))


def main():
    argv = sys.argv[1:]
    if argv and argv[0].startswith("-"):
        argv = ["ops", *argv]      # legacy: python -m repro.profiler --arch X

    ap = argparse.ArgumentParser(prog="python -m repro.profiler")
    sub = ap.add_subparsers(dest="cmd", required=True)

    p = sub.add_parser(
        "profile", help="emit a HardwareTrace artifact for one device")
    p.add_argument("--device", required=True,
                   help="device name (registry key of the artifact); "
                        "'cpu-engine' measures this machine")
    p.add_argument("--arch", default="llama3.1-8b-tiny")
    p.add_argument("--mode", default="auto",
                   choices=["auto", "measured", "synthetic"],
                   help="auto: measured for cpu-engine/local, synthetic "
                        "(spec-derived) otherwise")
    p.add_argument("--out", default=None,
                   help="output path (default traces/<device>.json)")
    p.add_argument("--tp", default="1",
                   help="tensor-parallel degree(s) to profile, comma-"
                        "separated (e.g. --tp 1,2); each degree becomes "
                        "one grid in the emitted hwtrace/3 artifact. "
                        "Measured sweeps shard the engine over that many "
                        "devices (forced on CPU hosts)")
    p.add_argument("--max-batch", type=int, default=4)
    p.add_argument("--max-len", type=int, default=512)
    p.add_argument("--reps", type=int, default=3)
    p.add_argument("--seed", type=int, default=0)
    # inline spec definition for a brand-new accelerator
    p.add_argument("--peak-flops", type=float, default=None)
    p.add_argument("--hbm-bw", type=float, default=None)
    p.add_argument("--hbm-capacity", type=float, default=None)
    p.add_argument("--link-bw", type=float, default=None)
    p.add_argument("--mmu-efficiency", type=float, default=None,
                   help="achievable fraction of peak on matmuls (default "
                        "0.85 for new specs; overrides a known spec's "
                        "value when given alone)")
    p.add_argument("--experts", nargs="?", const="auto", default=None,
                   metavar="PATH",
                   help="MoE archs: also emit an ExpertRoutingTrace "
                        "artifact (recorded through the engine in "
                        "measured mode, synthesized otherwise) to PATH "
                        "(default traces/<device>.routing.json)")
    p.add_argument("--period", type=int, default=256,
                   help="routing/acceptance-trace position-bucket length")
    p.add_argument("--spec", nargs="?", const="auto", default=None,
                   metavar="PATH",
                   help="also emit an AcceptanceTrace artifact (recorded "
                        "through a speculating engine in measured mode, "
                        "synthesized otherwise) to PATH (default "
                        "traces/<device>.acceptance.json)")
    p.add_argument("--k", type=int, default=4,
                   help="speculative draft length for --spec")
    p.add_argument("--kernels", nargs="?", const="reference,pallas",
                   default=None, metavar="BACKENDS",
                   help="measured mode: also sweep per-kernel latencies "
                        "(attention/mlp/moe_gmm/head) for the given "
                        "comma-separated kernel backends (default "
                        "'reference,pallas') into hwtrace/3 sub-buckets")
    p.set_defaults(fn=_cmd_profile, requests=8, alpha=0.7, jitter=0.0,
                   draft_arch=None, draft_seed=1)

    r = sub.add_parser(
        "record-routing",
        help="emit an ExpertRoutingTrace artifact (repro.moe) for a MoE "
             "arch: record the real model's routing through JaxBackend, "
             "or synthesize a parameterized skew")
    r.add_argument("--arch", required=True,
                   help="MoE architecture (e.g. granite-moe-1b-a400m-tiny)")
    r.add_argument("--mode", default="measured",
                   choices=["measured", "synthetic"],
                   help="measured: free-running recording tap on the real "
                        "engine; synthetic: parameterized skew generator")
    r.add_argument("--out", default=None,
                   help="output path (default traces/<arch>.routing.json)")
    r.add_argument("--requests", type=int, default=8,
                   help="workload size for measured recording")
    r.add_argument("--max-batch", type=int, default=4)
    r.add_argument("--max-len", type=int, default=256)
    r.add_argument("--period", type=int, default=256,
                   help="position-bucket length of the assignment tables")
    r.add_argument("--seed", type=int, default=0)
    r.add_argument("--skew", default="zipf",
                   choices=["uniform", "zipf", "correlated"],
                   help="synthetic mode: skew family")
    r.add_argument("--zipf-a", type=float, default=1.1,
                   help="synthetic mode: zipf exponent")
    r.set_defaults(fn=_cmd_record_routing)

    a = sub.add_parser(
        "record-acceptance",
        help="emit an AcceptanceTrace artifact (repro.spec): record real "
             "draft/target acceptance through a speculating engine, or "
             "synthesize from a target acceptance rate")
    a.add_argument("--arch", required=True,
                   help="target architecture (e.g. llama3.1-8b-tiny)")
    a.add_argument("--draft-arch", default=None,
                   help="draft architecture (default: the target arch "
                        "itself with a different parameter seed)")
    a.add_argument("--mode", default="measured",
                   choices=["measured", "synthetic"],
                   help="measured: real draft proposals verified by the "
                        "real target; synthetic: truncated-geometric "
                        "distributions from --alpha")
    a.add_argument("--out", default=None,
                   help="output path (default "
                        "traces/<arch>.acceptance.json)")
    a.add_argument("--k", type=int, default=4,
                   help="draft proposal length per spec step")
    a.add_argument("--requests", type=int, default=8,
                   help="workload size for measured recording")
    a.add_argument("--max-batch", type=int, default=4)
    a.add_argument("--max-len", type=int, default=256)
    a.add_argument("--period", type=int, default=256,
                   help="position-bucket count of the distributions")
    a.add_argument("--seed", type=int, default=0)
    a.add_argument("--draft-seed", type=int, default=1,
                   help="measured mode: draft parameter seed")
    a.add_argument("--alpha", type=float, default=0.7,
                   help="synthetic mode: per-token target acceptance rate")
    a.add_argument("--jitter", type=float, default=0.0,
                   help="synthetic mode: per-bucket alpha perturbation")
    a.set_defaults(fn=_cmd_record_acceptance)

    o = sub.add_parser(
        "ops", help="operator-level trace (raw Trace, legacy format)")
    o.add_argument("--arch", required=True)
    o.add_argument("--hw", default="cpu-measured")
    o.add_argument("--mode", default="measured",
                   choices=["measured", "analytical"])
    o.add_argument("--tp", type=int, default=1)
    o.add_argument("--out", default=None)
    o.set_defaults(fn=_cmd_ops)

    args = ap.parse_args(argv)
    args.fn(args)


if __name__ == "__main__":
    main()
