"""Kernel-granular profiler: per-kernel latency sub-buckets (hwtrace/3).

Where ``runtime_profiler`` measures whole engine iterations, this module
times the four kernels one forward pass composes from — ``attention``
(qkv projection + flash/paged attention + output projection), ``mlp``,
``moe_gmm`` (capacity-dispatched expert FFN), and ``head`` — in isolation,
per kernel backend, over the same (tokens, context) buckets the runtime
profiler sweeps.  The rows land in a ``HardwareTrace`` as
``kern:<backend>:<kernel>`` points (see ``repro.hw.trace``), giving the
perf model a fidelity tier between whole-iteration and op-class pricing
and letting ``benchmarks/fig2_fidelity.py`` attribute prediction error to
one specific kernel.

Row key conventions match ``PerfModel._kernel_level``:

* prefill rows at ``(tokens=T, context=T)`` — one fresh T-token prompt;
* decode rows at ``(tokens=B, context=c)`` — a B-wide step attending
  over c cached positions (paged layout, block-table indirection).

Each kernel is jitted, warmed (compile excluded) and timed over ``reps``
repetitions; the median lands in the trace.  On CPU the pallas backend
runs in interpret mode — structurally the production path, numerically
valid, but the latencies describe the interpreter; real accelerator
sweeps (TPU/GPU) are where pallas rows become pricing-grade.
"""
from __future__ import annotations

import time
from typing import List, Optional, Sequence

import numpy as np

from repro.configs import get_config
from repro.core.trace import OpPoint
from repro.hw.trace import HardwareTrace, kern_op

#: kernel backends a sweep can target
SWEEP_BACKENDS = ("reference", "pallas")


def _median_time(fn, args, reps: int) -> float:
    import jax
    jax.block_until_ready(fn(*args))          # compile + warm
    lat = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        lat.append(time.perf_counter() - t0)
    return float(np.median(lat))


def _divisor_block(n: int, b: int = 128) -> int:
    while n % b:
        b //= 2
    return max(b, 1)


def kernel_points(arch: str, backend: str, *,
                  max_batch: int = 4, max_len: int = 512,
                  prefill_buckets: Sequence[int] = (16, 32, 64, 128, 256),
                  decode_ctxs: Sequence[int] = (32, 64, 128, 256),
                  reps: int = 3, seed: int = 0, page_size: int = 64,
                  interpret: Optional[bool] = None) -> List[OpPoint]:
    """Sweep one kernel backend for ``arch``; returns ``kern:*`` OpPoints.

    ``interpret`` forwards to the pallas wrappers (None = platform
    default); ignored for the reference backend.
    """
    import jax
    import jax.numpy as jnp
    from repro.kernels import flash_attention, moe_gmm, paged_attention
    from repro.kernels.ref import flash_attention_ref, paged_attention_ref

    if backend not in SWEEP_BACKENDS:
        raise ValueError(f"kernel sweep backend must be one of "
                         f"{SWEEP_BACKENDS}, got {backend!r}")
    cfg = get_config(arch)
    dt = jnp.dtype(cfg.compute_dtype)
    d, H, KV, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    key = jax.random.PRNGKey(seed)

    def rand(*shape):
        nonlocal key
        key, sub = jax.random.split(key)
        return (jax.random.normal(sub, shape, jnp.float32)
                * shape[-1] ** -0.5).astype(dt)

    wqkv = rand(d, (H + 2 * KV) * dh)
    wo = rand(H * dh, d)
    wh = rand(d, cfg.vocab)
    pts: List[OpPoint] = []

    def add(kernel, phase, tokens, context, fn, args):
        pts.append(OpPoint(kern_op(backend, kernel), phase, int(tokens),
                           int(context), _median_time(fn, args, reps)))

    def split_qkv(x):
        """(N, d) -> q (N,H,dh), k/v (N,KV,dh) via one fused projection."""
        qkv = x @ wqkv
        n = x.shape[0]
        return (qkv[:, :H * dh].reshape(n, H, dh),
                qkv[:, H * dh:(H + KV) * dh].reshape(n, KV, dh),
                qkv[:, (H + KV) * dh:].reshape(n, KV, dh))

    # ---- attention: prefill (flash) ----
    for T in prefill_buckets:
        if T >= max_len:
            continue
        b = _divisor_block(T)

        @jax.jit
        def attn_prefill(x, lengths):
            q, k, v = split_qkv(x)
            q, k, v = q[None], k[None], v[None]
            if backend == "pallas":
                o = flash_attention(q, k, v, lengths=lengths, bq=b, bkv=b,
                                    interpret=interpret)
            else:
                o = flash_attention_ref(q, k, v, lengths=lengths)
            return o.reshape(1, T, H * dh)[0] @ wo

        add("attention", "prefill", T, T, attn_prefill,
            (rand(T, d), jnp.full((1,), T, jnp.int32)))

    # ---- attention: decode (paged) ----
    for ctx in decode_ctxs:
        if ctx + 16 >= max_len:
            continue
        npg = -(-ctx // page_size)
        for nb in sorted({1, max(1, max_batch // 2), max_batch}):
            kp = rand(nb * npg, page_size, KV, dh)
            vp = rand(nb * npg, page_size, KV, dh)
            table = jnp.arange(nb * npg, dtype=jnp.int32).reshape(nb, npg)
            lengths = jnp.full((nb,), ctx, jnp.int32)

            @jax.jit
            def attn_decode(x, kp, vp, table, lengths):
                q, _, _ = split_qkv(x)
                if backend == "pallas":
                    o = paged_attention(q, kp, vp, table, lengths,
                                        page_size=page_size,
                                        interpret=interpret)
                else:
                    o = paged_attention_ref(q, kp, vp, table, lengths,
                                            page_size=page_size)
                return o.reshape(-1, H * dh) @ wo

            add("attention", "decode", nb, ctx, attn_decode,
                (rand(nb, d), kp, vp, table, lengths))

    # ---- ffn: mlp or moe_gmm ----
    if cfg.moe is None:
        wg, wu = rand(d, cfg.d_ff), rand(d, cfg.d_ff)
        wd = rand(cfg.d_ff, d)

        @jax.jit
        def mlp(x):
            h = jax.nn.silu(x @ wg) * (x @ wu) if cfg.mlp_gated \
                else jax.nn.gelu(x @ wg)
            return h @ wd

        def ffn_at(phase, tokens, context):
            add("mlp", phase, tokens, context, mlp, (rand(tokens, d),))
    else:
        E, k_top = cfg.moe.n_experts, cfg.moe.top_k
        de = cfg.moe.d_expert
        weg, weu = rand(E, d, de), rand(E, d, de)
        wed = rand(E, de, d)

        def ffn_at(phase, tokens, context):
            # capacity-dispatched expert FFN at this batch's expert load
            C = max(1, int(np.ceil(tokens * k_top
                                   * cfg.moe.capacity_factor / E)))
            gs = jnp.full((E,), min(C, tokens), jnp.int32)

            if backend == "pallas":
                @jax.jit
                def moe(xe):
                    h = jax.nn.silu(moe_gmm(xe, weg, gs)) \
                        * moe_gmm(xe, weu, gs)
                    return moe_gmm(h, wed, gs)
            else:
                @jax.jit
                def moe(xe):
                    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, weg)) \
                        * jnp.einsum("ecd,edf->ecf", xe, weu)
                    return jnp.einsum("ecf,efd->ecd", h, wed)
            add("moe_gmm", phase, tokens, context, moe, (rand(E, C, d),))

    # ---- head ----
    @jax.jit
    def head(x):
        return x.astype(jnp.float32) @ wh.astype(jnp.float32)

    for T in prefill_buckets:
        if T >= max_len:
            continue
        ffn_at("prefill", T, T)
        add("head", "prefill", T, T, head, (rand(T, d),))
    for ctx in decode_ctxs:
        if ctx + 16 >= max_len:
            continue
        for nb in sorted({1, max(1, max_batch // 2), max_batch}):
            ffn_at("decode", nb, ctx)
            add("head", "decode", nb, ctx, head, (rand(nb, d),))
    return pts


def add_kernel_grid(hwt: HardwareTrace, arch: str,
                    backends: Sequence[str] = SWEEP_BACKENDS,
                    **kwargs) -> HardwareTrace:
    """Sweep ``backends`` and append the rows to ``hwt``'s base grid
    (kernel sweeps are single-device; tp collectives are composed
    analytically by the perf model on top of kernel rows)."""
    t0 = time.time()
    for backend in backends:
        hwt.points.extend(kernel_points(arch, backend, **kwargs))
    hwt.meta["kernel_backends"] = list(backends)
    hwt.meta["kernel_wall_s"] = round(time.time() - t0, 3)
    return hwt
