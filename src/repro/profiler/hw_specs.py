"""Compatibility shim: the hardware-spec registry moved to ``repro.hw``
(the hardware-trace pipeline owns device knowledge); import from
``repro.hw.specs`` going forward.
"""
from repro.hw.specs import (get_hw, known_hw, measured_cpu_spec,  # noqa: F401
                            register_hw)

__all__ = ["get_hw", "register_hw", "known_hw", "measured_cpu_spec"]
