"""Operator- and iteration-level latency profilers.

Submodules are imported lazily (PEP 562) so trace-artifact tooling — e.g.
``python -m repro.profiler profile --device tpu-v6e`` generating a
*synthetic* trace — never pays the jax/engine import; only the measured
paths (``runtime_trace``, ``OperatorProfiler`` in measured mode) do.
"""
_LAZY = {
    # jax-free
    "model_spec_from_arch": "repro.profiler.arch_spec",
    "get_hw": "repro.hw.specs",
    "register_hw": "repro.hw.specs",
    "measured_cpu_spec": "repro.hw.specs",
    # jax-importing (measured profilers)
    "OperatorProfiler": "repro.profiler.operator_profiler",
    "ProfilerConfig": "repro.profiler.operator_profiler",
    "profile_arch": "repro.profiler.operator_profiler",
    "runtime_trace": "repro.profiler.runtime_profiler",
}

__all__ = sorted(_LAZY)


def __getattr__(name):
    if name in _LAZY:
        import importlib
        mod = importlib.import_module(_LAZY[name])
        value = getattr(mod, name)
        globals()[name] = value
        return value
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__():
    return __all__
