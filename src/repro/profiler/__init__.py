from repro.profiler.hw_specs import get_hw, measured_cpu_spec, register_hw
from repro.profiler.operator_profiler import (OperatorProfiler,
                                              ProfilerConfig,
                                              model_spec_from_arch,
                                              profile_arch)

__all__ = ["get_hw", "measured_cpu_spec", "register_hw", "OperatorProfiler",
           "ProfilerConfig", "model_spec_from_arch", "profile_arch"]
