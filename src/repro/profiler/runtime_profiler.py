"""Iteration-level profiler that probes through the unified runtime.

Replaces the legacy ``ServingEngine.step()`` probe: every measurement runs
``JaxBackend.execute`` on hand-composed ``ScheduledWork`` batches — the
*exact* code paths production serving takes (bucketed ``prefill`` for fresh
prompts, ``extend`` for chunked-prefill continuations and prefix-cache
suffixes, one batched full-buffer ``decode`` per iteration, jitted slot
export for KV copies).  What the simulator later prices is therefore what
was measured, with no scheduling-semantics drift in between.

Emitted trace points (the highest-fidelity tier — ``PerfModel`` prefers
them over operator-level composition):

* ``("iter", "prefill", P, P)``       — one whole-prompt prefill at bucket P
* ``("extend", "prefill", S, c+S)``   — an S-token chunk extending context c
* ``("iter", "decode", B, c)``        — a B-wide decode step at context c
* ``("kv_export", "prefill", P, P)``  — slot KV copy-out (prefix-cache
  insert / P-D transfer) for P tokens

The result is a portable :class:`repro.hw.HardwareTrace` artifact — the
paper's single-command hardware integration is running this on the target
device: ``python -m repro.profiler profile --device <name> --out
traces/<name>.json``.
"""
from __future__ import annotations

import itertools
import time
from typing import Optional, Sequence

import numpy as np

from repro.configs import get_config
from repro.core.config import (ENGINE_HW, InstanceCfg, ParallelismCfg,
                               PrefixCacheCfg, SchedulerCfg)
from repro.core.request import SimRequest
from repro.core.trace import Trace
from repro.hw.trace import HardwareTrace, InterconnectSpec
from repro.profiler.arch_spec import model_spec_from_arch


def _probe_instance_cfg(arch: str, max_batch: int, max_len: int,
                        chunk: int, tp: int = 1) -> InstanceCfg:
    """Engine-matched InstanceCfg for the probe backend (chunked prefill on
    so ``warmup`` pre-compiles the extend buckets we measure)."""
    return InstanceCfg(
        name="probe", hw=ENGINE_HW, model=model_spec_from_arch(
            get_config(arch)),
        parallelism=ParallelismCfg(tp=tp),
        scheduler=SchedulerCfg(max_batch_size=max_batch,
                               max_batch_tokens=1 << 16,
                               chunked_prefill=True, prefill_chunk=chunk),
        prefix_cache=PrefixCacheCfg(enabled=False))


def runtime_trace(arch: str, *, device: str = "cpu-engine",
                  max_batch: int = 4, max_len: int = 512,
                  prefill_buckets: Sequence[int] = (16, 32, 64, 128, 256),
                  decode_ctxs: Sequence[int] = (32, 64, 128, 256),
                  extend_ctxs: Sequence[int] = (16, 64, 128),
                  extend_suffixes: Sequence[int] = (16, 64, 128),
                  reps: int = 3, seed: int = 0, tp: int = 1,
                  engine=None) -> HardwareTrace:
    """Measure ``arch`` on the local device through ``JaxBackend``.

    ``engine`` may supply a pre-built ``ServingEngine`` (params reuse);
    otherwise one is constructed.  ``tp > 1`` probes a sharded engine over
    a (1, tp) device mesh — the grid then prices tp-degree instances (the
    CLI sweeps ``--tp 1,2`` into one multi-grid artifact).  Returns a
    portable ``HardwareTrace`` labeled ``device`` with the container's
    engine spec embedded.
    """
    from repro.runtime.backends.jax_engine import JaxBackend
    from repro.runtime.scheduler import ScheduledWork
    from repro.serve.engine import ServingEngine

    cfg = get_config(arch)
    t_start = time.time()
    eng = engine or ServingEngine(cfg, max_batch=max_batch, max_len=max_len,
                                  name="probe", seed=seed, tp=tp)
    icfg = _probe_instance_cfg(arch, max_batch, max_len,
                               chunk=max(extend_suffixes), tp=eng.tp)
    backend = JaxBackend(eng, icfg)
    backend.warmup()

    trace = Trace(model=arch, hardware=device, tp=eng.tp)
    rng = np.random.default_rng(seed)
    rid = itertools.count()

    def make_req(n_prompt: int, output_len: int = 1) -> SimRequest:
        toks = rng.integers(0, cfg.vocab, n_prompt).tolist()
        return SimRequest(req_id=next(rid), arrival=0.0,
                          prompt_tokens=toks, output_len=output_len)

    def run(req: SimRequest, tokens: int, phase: str) -> float:
        return backend.execute([ScheduledWork(req, tokens, phase)], 0.0)

    # --- whole-prompt prefill per bucket (+ KV-export / slot copy cost) ---
    for P in prefill_buckets:
        if P >= max_len - 8:
            continue
        lat, exp_lat = [], []
        for _ in range(reps):
            req = make_req(P - 1)
            lat.append(run(req, P - 1, "prefill"))
            t0 = time.perf_counter()
            backend.export_kv(req)      # slot copy-out; also frees the slot
            exp_lat.append(time.perf_counter() - t0)
            backend._carry_s = 0.0      # export time was measured directly
        trace.add("iter", "prefill", P, P, float(np.median(lat)))
        trace.add("kv_export", "prefill", P, P, float(np.median(exp_lat)))

    # --- chunked/cached prefill (extend) per (suffix, context) ---
    # chunk 2+ and prefix-cache suffixes run the engine's extend path, which
    # attends over the slot's full buffer — priced separately from fresh
    # prefill.  Some architectures (e.g. xLSTM) have no cached-prefill path;
    # the perf model then falls back to fresh-prefill pricing.
    try:
        for ctx in extend_ctxs:
            for S in extend_suffixes:
                if ctx + S >= max_len:
                    continue
                lat = []
                for rep in range(reps + 1):
                    req = make_req(ctx + S)
                    run(req, ctx, "prefill")          # chunk 1: fresh
                    dt = run(req, S, "prefill")       # chunk 2: extend
                    backend.release(req)
                    if rep:                           # rep 0 warms the jits
                        lat.append(dt)
                trace.add("extend", "prefill", S, ctx + S,
                          float(np.median(lat)))
    except NotImplementedError:
        pass

    # --- batched decode per (batch, context) ---
    for ctx in decode_ctxs:
        if ctx + 16 >= max_len:
            continue
        for nb in sorted({1, max(1, max_batch // 2), max_batch}):
            reqs = []
            for _ in range(nb):
                req = make_req(ctx, output_len=reps + 4)
                run(req, ctx, "prefill")
                reqs.append(req)
            lat = []
            for _ in range(reps + 1):
                work = [ScheduledWork(r, 1, "decode") for r in reqs]
                lat.append(backend.execute(work, 0.0))
            for r in reqs:
                backend.release(r)
            trace.add("iter", "decode", nb, ctx,
                      float(np.median(lat[1:]) if len(lat) > 1 else lat[0]))

    trace.meta.update({
        "mode": "runtime", "profile_wall_s": time.time() - t_start,
        "n_points": len(trace.points), "max_batch": max_batch,
        "max_len": max_len, "tp": eng.tp,
    })
    return HardwareTrace.from_trace(
        trace, device=device, spec=ENGINE_HW,
        interconnect=InterconnectSpec.from_hw(ENGINE_HW))
