"""zamba2-1.2b [arXiv:2411.15242; hf] — hybrid: Mamba2 (SSD) backbone with a
single *shared* attention+MLP block applied every 6th layer. 38 layers =
6 superblocks of (5 mamba + 1 mamba+shared-attn) + 2 trailing mamba.
"""
from repro.configs.base import MAMBA2, ZAMBA_SUPER, ArchConfig, SSMCfg, Stage

CONFIG = ArchConfig(
    name="zamba2-1.2b", family="hybrid",
    n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32, d_head=64,
    d_ff=8192, vocab=32000,
    ssm=SSMCfg(d_state=64, expand=2, head_dim=64, chunk=256),
    stages=(Stage(ZAMBA_SUPER, 6), Stage(MAMBA2, 2)),
    subquadratic=True,
)
