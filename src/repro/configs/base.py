"""Architecture config system.

Every assigned architecture (plus the paper's own evaluation models) is
expressed as an ``ArchConfig``: a declarative description of a decoder-only
LM-family backbone built from a sequence of *stages*. Each stage is a
homogeneous stack of blocks executed under ``jax.lax.scan`` (compact HLO,
fast multi-device compiles); heterogeneous archs (zamba2 hybrid, xlstm,
gemma3 local:global) compose multiple block kinds inside one scanned
superblock or via per-layer flag arrays.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

# Block kinds understood by repro.models.transformer
ATTN_MLP = "attn_mlp"          # attention + dense MLP (pre-norm residual)
ATTN_MOE = "attn_moe"          # attention + MoE FFN
MAMBA2 = "mamba2"              # Mamba2 (SSD) block
ZAMBA_SUPER = "zamba_super"    # 5x mamba2 + 1x (mamba2 + shared attention)
XLSTM_PAIR = "xlstm_pair"      # mLSTM block followed by sLSTM block


@dataclasses.dataclass(frozen=True)
class Stage:
    kind: str
    n_layers: int               # number of scan iterations of this stage
    # gemma3-style local:global interleave: period P means layer i is
    # *global* iff (i % P == P-1); 0 disables windowing entirely.
    local_global_period: int = 0


@dataclasses.dataclass(frozen=True)
class MoECfg:
    n_experts: int
    top_k: int
    d_expert: int               # per-expert FFN hidden size
    capacity_factor: float = 1.25
    n_shared_experts: int = 0


@dataclasses.dataclass(frozen=True)
class SSMCfg:
    d_state: int = 64
    d_conv: int = 4
    expand: int = 2
    n_heads: int = 0            # 0 -> derived: d_inner // head_dim
    head_dim: int = 64
    chunk: int = 256            # SSD chunk length


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                 # dense | moe | hybrid | ssm | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: int = 128
    stages: Tuple[Stage, ...] = ()
    # attention options
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    sliding_window: int = 0     # window size for local layers (0 = none)
    mlp_gated: bool = True      # SwiGLU (3 mats) vs plain GELU MLP (2 mats)
    # MoE / SSM options
    moe: Optional[MoECfg] = None
    ssm: Optional[SSMCfg] = None
    # embedding / head options
    tie_embeddings: bool = False
    n_codebooks: int = 0        # musicgen-style multi-head output (0 = plain LM)
    embed_inputs: bool = True   # False -> input_specs provides embeddings (stub frontend)
    # norm
    norm_eps: float = 1e-5
    # sub-quadratic? (drives long_500k applicability)
    subquadratic: bool = False
    # dtypes
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    # kernel backend for the serving hot path: "reference" (pure-JAX
    # twins), "pallas" (flash prefill / paged decode / MoE GMM), or
    # "auto" (pallas on TPU/GPU, interpret-mode pallas for CPU
    # validation, reference otherwise) — see repro.kernels.resolve_backend
    kernels: str = "reference"

    @property
    def n_q_per_kv(self) -> int:
        return self.n_heads // max(1, self.n_kv_heads)

    @property
    def padded_vocab(self) -> int:
        """Vocab rounded up to a multiple of 256 so embedding/head shards
        divide evenly on the 16-way model axis (MaxText-style padding)."""
        return ((self.vocab + 255) // 256) * 256

    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks + head)."""
        d, ff, V = self.d_model, self.d_ff, self.vocab
        emb = V * d if self.embed_inputs else 0
        head = 0 if self.tie_embeddings else V * d * max(1, self.n_codebooks or 1)
        total = emb + head
        q = self.n_heads * self.d_head
        kv = self.n_kv_heads * self.d_head
        attn = d * q + 2 * d * kv + q * d  # wq, wk, wv, wo
        if self.qkv_bias:
            attn += q + 2 * kv
        mlp = (3 if self.mlp_gated else 2) * d * ff  # SwiGLU vs plain MLP
        for st in self.stages:
            n = st.n_layers
            if st.kind == ATTN_MLP:
                total += n * (attn + mlp + 2 * d)
            elif st.kind == ATTN_MOE:
                m = self.moe
                expert = 3 * d * m.d_expert
                total += n * (attn + d * m.n_experts  # router
                              + (m.n_experts + m.n_shared_experts) * expert + 2 * d)
            elif st.kind == MAMBA2:
                total += n * self._mamba_params() + n * d
            elif st.kind == ZAMBA_SUPER:
                total += n * (6 * (self._mamba_params() + d))
            elif st.kind == XLSTM_PAIR:
                total += n * self._xlstm_pair_params()
        if any(st.kind == ZAMBA_SUPER for st in self.stages):
            total += attn + mlp + 2 * d  # the shared attention block (counted once)
        total += d  # final norm
        return total

    def _mamba_params(self) -> int:
        s = self.ssm
        d = self.d_model
        d_in = s.expand * d
        nh = s.n_heads or d_in // s.head_dim
        # in_proj -> [z, x, B, C, dt], conv, A_log, D, norm, out_proj
        conv_dim = d_in + 2 * s.d_state * 1  # x, B, C share the conv (groups=dim)
        return (d * (2 * d_in + 2 * s.d_state + nh) + conv_dim * s.d_conv
                + 2 * nh + d_in + d_in * d)

    def _xlstm_pair_params(self) -> int:
        d = self.d_model
        h = self.n_heads
        # mLSTM block: up-proj 2x, q/k/v over inner, i/f/o gates, out
        d_in = 2 * d
        m = d * 2 * d_in + 3 * d_in * d_in + 3 * d_in + d_in * d + 2 * d
        # sLSTM block: 4 gates (i,f,z,o) each d->d + post up/down MLP 4/3
        ff = int(d * 4 / 3)
        s = 4 * d * d + 4 * d + 2 * d * ff + 2 * d
        return m + s

    def active_param_count(self) -> int:
        """Active params per token (MoE: only top_k experts count)."""
        if self.moe is None:
            return self.param_count()
        m = self.moe
        expert = 3 * self.d_model * m.d_expert
        inactive = (m.n_experts - m.top_k) * expert
        n_moe_layers = sum(st.n_layers for st in self.stages if st.kind == ATTN_MOE)
        return self.param_count() - n_moe_layers * inactive

    def tiny(self) -> "ArchConfig":
        """Reduced same-family config for CPU smoke tests."""
        scale = {}
        scale["n_layers"] = min(self.n_layers, 2)
        stages = []
        for st in self.stages:
            stages.append(dataclasses.replace(
                st, n_layers=1,
                local_global_period=min(st.local_global_period, 2)))
            if len(stages) == 2:
                break
        scale["stages"] = tuple(stages)
        scale["d_model"] = 64
        scale["n_heads"] = 4
        scale["n_kv_heads"] = min(self.n_kv_heads, 2) if self.n_kv_heads < self.n_heads else 4
        scale["d_head"] = 16
        scale["d_ff"] = 128
        scale["vocab"] = 256
        if self.moe is not None:
            scale["moe"] = dataclasses.replace(
                self.moe, n_experts=4, top_k=2, d_expert=32)
        if self.ssm is not None:
            scale["ssm"] = dataclasses.replace(
                self.ssm, d_state=16, head_dim=16, chunk=8)
        if self.sliding_window:
            scale["sliding_window"] = 16
        scale["name"] = self.name + "-tiny"
        return dataclasses.replace(self, **scale)


def simple_stages(kind: str, n_layers: int, period: int = 0) -> Tuple[Stage, ...]:
    return (Stage(kind=kind, n_layers=n_layers, local_global_period=period),)
