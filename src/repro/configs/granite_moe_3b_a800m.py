"""granite-moe-3b-a800m [hf:ibm-granite/granite-3.0-3b-a800m-base] — MoE,
40 experts top-8, d_expert=512, GQA kv=8.
"""
from repro.configs.base import ATTN_MOE, ArchConfig, MoECfg, simple_stages

CONFIG = ArchConfig(
    name="granite-moe-3b-a800m", family="moe",
    n_layers=32, d_model=1536, n_heads=24, n_kv_heads=8, d_head=64,
    d_ff=512, vocab=49155,
    moe=MoECfg(n_experts=40, top_k=8, d_expert=512),
    stages=simple_stages(ATTN_MOE, 32),
)
