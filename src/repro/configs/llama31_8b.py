"""llama3.1-8b — the paper's dense evaluation model (§III-A)."""
from repro.configs.base import ATTN_MLP, ArchConfig, simple_stages

CONFIG = ArchConfig(
    name="llama3.1-8b", family="dense",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, d_head=128,
    d_ff=14336, vocab=128256, rope_theta=5e5,
    stages=simple_stages(ATTN_MLP, 32),
)
