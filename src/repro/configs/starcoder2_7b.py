"""starcoder2-7b [arXiv:2402.19173; hf] — dense, GQA kv=4, RoPE."""
from repro.configs.base import ATTN_MLP, ArchConfig, simple_stages

CONFIG = ArchConfig(
    name="starcoder2-7b", family="dense",
    n_layers=32, d_model=4608, n_heads=36, n_kv_heads=4, d_head=128,
    d_ff=18432, vocab=49152, rope_theta=1e5, mlp_gated=False,
    stages=simple_stages(ATTN_MLP, 32),
)
