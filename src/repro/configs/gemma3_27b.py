"""gemma3-27b [hf:google/gemma-3-27b-pt] — dense, GQA kv=16, 5:1 local:global
sliding window (1024), qk_norm, 128k nominal context. Layer i is global iff
i % 6 == 5. Sub-quadratic for long_500k: 5/6 of layers are windowed and the
global layers at decode are linear-in-cache single-query reads.
"""
from repro.configs.base import ATTN_MLP, ArchConfig, Stage

CONFIG = ArchConfig(
    name="gemma3-27b", family="dense",
    n_layers=62, d_model=5376, n_heads=32, n_kv_heads=16, d_head=128,
    d_ff=21504, vocab=262144, qk_norm=True, rope_theta=1e6,
    sliding_window=1024,
    stages=(Stage(ATTN_MLP, 62, local_global_period=6),),
    subquadratic=True,
)
