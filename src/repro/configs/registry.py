"""Registry of the 10 assigned architectures + the paper's own eval models.

Each architecture lives in its own ``src/repro/configs/<id>.py`` module; this
registry imports and indexes them by their public arch id (``--arch <id>``).
``<id>-tiny`` resolves to the reduced same-family smoke-test config.
"""
from __future__ import annotations

from repro.configs import (
    chameleon_34b, gemma3_27b, granite_moe_1b_a400m, granite_moe_3b_a800m,
    llama31_8b, musicgen_large, phimini_moe, qwen3_8b, qwen15_32b,
    starcoder2_7b, xlstm_125m, zamba2_1p2b,
)
from repro.configs.base import ArchConfig

_MODULES = (
    starcoder2_7b, qwen15_32b, gemma3_27b, qwen3_8b, zamba2_1p2b,
    chameleon_34b, granite_moe_3b_a800m, granite_moe_1b_a400m, xlstm_125m,
    musicgen_large, llama31_8b, phimini_moe,
)

_REGISTRY = {m.CONFIG.name: m.CONFIG for m in _MODULES}

# The 10 assigned architectures (the other two are the paper's eval models).
ASSIGNED = (
    "starcoder2-7b", "qwen1.5-32b", "gemma3-27b", "qwen3-8b", "zamba2-1.2b",
    "chameleon-34b", "granite-moe-3b-a800m", "granite-moe-1b-a400m",
    "xlstm-125m", "musicgen-large",
)


def get_config(name: str) -> ArchConfig:
    if name.endswith("-tiny"):
        return get_config(name[: -len("-tiny")]).tiny()
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_archs():
    return sorted(_REGISTRY)
