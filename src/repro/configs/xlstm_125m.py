"""xlstm-125m [arXiv:2405.04517] — alternating mLSTM/sLSTM blocks; d_ff=0
(the blocks carry their own projections). 12 layers = 6 (mLSTM, sLSTM) pairs.
Fully recurrent -> O(1)-state decode, runs long_500k.
"""
from repro.configs.base import XLSTM_PAIR, ArchConfig, Stage

CONFIG = ArchConfig(
    name="xlstm-125m", family="ssm",
    n_layers=12, d_model=768, n_heads=4, n_kv_heads=4, d_head=192,
    d_ff=0, vocab=50304,
    stages=(Stage(XLSTM_PAIR, 6),),
    subquadratic=True,
)
