from repro.configs.base import ArchConfig, MoECfg, SSMCfg, Stage
from repro.configs.registry import ASSIGNED, get_config, list_archs
from repro.configs.shapes import (
    ALL_SHAPES, DECODE_32K, LONG_500K, PREFILL_32K, TRAIN_4K,
    ShapeCfg, cell_is_runnable, get_shape,
)

__all__ = [
    "ArchConfig", "MoECfg", "SSMCfg", "Stage", "ASSIGNED", "get_config",
    "list_archs", "ALL_SHAPES", "ShapeCfg", "get_shape", "cell_is_runnable",
    "TRAIN_4K", "PREFILL_32K", "DECODE_32K", "LONG_500K",
]
