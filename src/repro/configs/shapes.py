"""Assigned input-shape set. Every LM-family arch is paired with all four.

``train_4k`` lowers train_step; ``prefill_32k`` lowers prefill_step;
``decode_32k`` / ``long_500k`` lower serve_step (one new token against a KV
cache of ``seq_len``). ``long_500k`` requires a sub-quadratic arch (see
``ArchConfig.subquadratic`` and DESIGN.md §5).
"""
from __future__ import annotations

import dataclasses
from typing import Tuple


@dataclasses.dataclass(frozen=True)
class ShapeCfg:
    name: str
    seq_len: int
    global_batch: int
    step: str                  # train | prefill | decode


TRAIN_4K = ShapeCfg("train_4k", 4_096, 256, "train")
PREFILL_32K = ShapeCfg("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = ShapeCfg("decode_32k", 32_768, 128, "decode")
LONG_500K = ShapeCfg("long_500k", 524_288, 1, "decode")

ALL_SHAPES: Tuple[ShapeCfg, ...] = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)


def get_shape(name: str) -> ShapeCfg:
    for s in ALL_SHAPES:
        if s.name == name:
            return s
    raise KeyError(f"unknown shape {name!r}; have {[s.name for s in ALL_SHAPES]}")


def cell_is_runnable(arch_subquadratic: bool, shape: ShapeCfg) -> bool:
    """long_500k only runs for sub-quadratic archs (SSM/hybrid/windowed)."""
    if shape.name == "long_500k":
        return arch_subquadratic
    return True
