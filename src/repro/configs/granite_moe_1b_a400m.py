"""granite-moe-1b-a400m [hf:ibm-granite/granite-3.0-1b-a400m-base] — MoE,
32 experts top-8, d_expert=512, GQA kv=8.
"""
from repro.configs.base import ATTN_MOE, ArchConfig, MoECfg, simple_stages

CONFIG = ArchConfig(
    name="granite-moe-1b-a400m", family="moe",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=8, d_head=64,
    d_ff=512, vocab=49155,
    moe=MoECfg(n_experts=32, top_k=8, d_expert=512),
    stages=simple_stages(ATTN_MOE, 24),
)
