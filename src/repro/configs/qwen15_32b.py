"""qwen1.5-32b [hf:Qwen/Qwen1.5-32B] — dense, QKV bias, kv=40 (MHA)."""
from repro.configs.base import ATTN_MLP, ArchConfig, simple_stages

CONFIG = ArchConfig(
    name="qwen1.5-32b", family="dense",
    n_layers=64, d_model=5120, n_heads=40, n_kv_heads=40, d_head=128,
    d_ff=27392, vocab=152064, qkv_bias=True, rope_theta=1e6,
    stages=simple_stages(ATTN_MLP, 64),
)
