"""phimini-moe — the paper's MoE evaluation model (§III-A): 16 experts top-2."""
from repro.configs.base import ATTN_MOE, ArchConfig, MoECfg, simple_stages

CONFIG = ArchConfig(
    name="phimini-moe", family="moe",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, d_head=128,
    d_ff=960, vocab=32064,
    moe=MoECfg(n_experts=16, top_k=2, d_expert=960),
    stages=simple_stages(ATTN_MOE, 32),
)
