"""qwen3-8b [hf:Qwen/Qwen3-8B] — dense, GQA kv=8, qk_norm."""
from repro.configs.base import ATTN_MLP, ArchConfig, simple_stages

CONFIG = ArchConfig(
    name="qwen3-8b", family="dense",
    n_layers=36, d_model=4096, n_heads=32, n_kv_heads=8, d_head=128,
    d_ff=12288, vocab=151936, qk_norm=True, rope_theta=1e6,
    stages=simple_stages(ATTN_MLP, 36),
)
