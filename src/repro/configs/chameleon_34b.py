"""chameleon-34b [arXiv:2405.09818] — early-fusion VLM: VQ image tokens share
a unified vocab with text; the modality frontend is a stub (input ids are
precomputed VQ codes). qk_norm per the paper.
"""
from repro.configs.base import ATTN_MLP, ArchConfig, simple_stages

CONFIG = ArchConfig(
    name="chameleon-34b", family="vlm",
    n_layers=48, d_model=8192, n_heads=64, n_kv_heads=8, d_head=128,
    d_ff=22016, vocab=65536, qk_norm=True,
    stages=simple_stages(ATTN_MLP, 48),
)
