"""musicgen-large [arXiv:2306.05284; hf] — decoder-only over EnCodec tokens;
4 codebooks -> 4 parallel output heads over vocab 2048. The EnCodec frontend
is a stub: input_specs() provides precomputed (summed) frame embeddings.
Cross-attention text conditioning is out of backbone scope (DESIGN.md §5).
"""
from repro.configs.base import ATTN_MLP, ArchConfig, simple_stages

CONFIG = ArchConfig(
    name="musicgen-large", family="audio",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=32, d_head=64,
    d_ff=8192, vocab=2048, n_codebooks=4, embed_inputs=False, mlp_gated=False,
    stages=simple_stages(ATTN_MLP, 48),
)
