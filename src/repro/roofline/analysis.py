"""Roofline analysis from compiled dry-run artifacts.

Three terms per (arch × shape × mesh), in seconds:
  compute    = HLO_FLOPs / (chips × peak_FLOP/s)
  memory     = HLO_bytes / (chips × HBM_bw)
  collective = collective_bytes / (chips × link_bw)

HLO_FLOPs / HLO_bytes come from ``compiled.cost_analysis()``; collective
bytes are parsed from the post-SPMD HLO text (sum of result-shape bytes of
every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute, scaled by the op's per-device data-movement factor).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict

# TPU v5e-class hardware constants (per chip)
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # bytes/s
LINK_BW = 50e9               # bytes/s per ICI link (per direction)

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"\b(f64|f32|f16|bf16|f8e4m3|f8e5m2|s64|s32|s16|s8|"
                       r"u64|u32|u16|u8|pred|c64|c128)\[([0-9,]*)\]")

_COLL_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^)]*\)|\S+)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(", re.M)

# fraction of the result bytes each device actually moves over links
# (ring algorithms; n = group size, approximated for large n)
_MOVE_FACTOR = {
    "all-gather": 1.0,        # receives (n-1)/n of result ~ 1
    "all-reduce": 2.0,        # reduce-scatter + all-gather
    "reduce-scatter": 1.0,    # sends (n-1)/n of input ~ 1 x result*n... see note
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}


def shape_bytes(type_str: str) -> int:
    """Sum byte sizes of all array types in an HLO type string."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.groups()
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-op-kind result bytes (deduplicating -start/-done pairs)."""
    out: Dict[str, int] = {}
    seen_done = set()
    for m in _COLL_RE.finditer(hlo_text):
        type_str, kind = m.group(1), m.group(2)
        line = hlo_text[m.start(): hlo_text.find("\n", m.start())]
        # skip the -done halves of async pairs (same bytes as -start)
        if f"{kind}-done(" in line:
            continue
        out[kind] = out.get(kind, 0) + shape_bytes(type_str)
    return out


@dataclasses.dataclass
class Roofline:
    flops: float                 # per-device HLO flops
    hbm_bytes: float             # per-device bytes accessed
    coll_bytes: Dict[str, int]   # per-device collective result bytes
    n_devices: int
    coll_moved: float = 0.0      # ring-factor-scaled per-device bytes

    @property
    def t_compute(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes / HBM_BW

    @property
    def t_collective(self) -> float:
        moved = self.coll_moved or sum(
            _MOVE_FACTOR.get(k, 1.0) * v for k, v in self.coll_bytes.items())
        return moved / LINK_BW

    @property
    def bottleneck(self) -> str:
        ts = {"compute": self.t_compute, "memory": self.t_memory,
              "collective": self.t_collective}
        return max(ts, key=ts.get)

    def summary(self) -> dict:
        return {
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "flops_per_device": self.flops,
            "hbm_bytes_per_device": self.hbm_bytes,
            "collective_bytes": dict(self.coll_bytes),
        }


def from_compiled(compiled, n_devices: int) -> Roofline:
    """Loop-aware roofline via the HLO analyzer (see hlo_analyzer.py for why
    cost_analysis() alone is not usable here)."""
    from repro.roofline.hlo_analyzer import HloAnalyzer
    hlo = compiled.as_text()
    cost = HloAnalyzer(hlo).analyze()
    r = Roofline(flops=cost.flops, hbm_bytes=cost.hbm_bytes,
                 coll_bytes={k: int(v) for k, v in cost.coll_bytes.items()},
                 n_devices=n_devices)
    r.coll_moved = cost.coll_moved
    return r


def model_flops(cfg, shape) -> float:
    """MODEL_FLOPS = 6·N·D (dense) / 6·N_active·D (MoE) for train;
    2·N·D for inference steps (fwd only). D = tokens processed."""
    n = cfg.active_param_count()
    if shape.step == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.step == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    tokens = shape.global_batch * 1
    return 2.0 * n * tokens
