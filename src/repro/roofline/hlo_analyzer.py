"""Post-SPMD HLO analyzer: loop-aware FLOP / HBM-byte / collective counts.

XLA's ``compiled.cost_analysis()`` counts every while-loop body ONCE (trip
counts are ignored) and, on the CPU backend, reports unfused
bytes-accessed — both useless for a TPU roofline. This walker parses
``compiled.as_text()`` directly:

  * builds the computation call graph (while bodies, fusions, calls),
  * multiplies per-op costs by the product of enclosing loop trip counts
    (``backend_config={"known_trip_count":{"n":...}}``, emitted by
    ``lax.scan``; falls back to the max constant in the loop condition),
  * FLOPs: 2·prod(result)·prod(contracting dims) per ``dot``,
  * HBM bytes (TPU-fusion flavored): dots count lhs+rhs+result; fusions,
    scatter/gather/dynamic-(update-)slice count 2x result (one read + one
    write); pure data-movement artifacts (copy/bitcast/tuple/gte) count 0;
  * collective bytes by kind with ring-algorithm per-device move factors
    using the actual replica group size.
"""
from __future__ import annotations

import dataclasses
import json
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3": 1, "f8e5m2": 1,
    "f8e4m3fn": 1, "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8,
    "u32": 4, "u16": 2, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_ARRAY_RE = re.compile(
    r"\b(f64|f32|f16|bf16|f8e4m3fn|f8e4m3|f8e5m2|s64|s32|s16|s8|u64|u32|"
    r"u16|u8|pred|c64|c128)\[([0-9,]*)\]")

_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*\{")
_OPND_RE = re.compile(r"%([\w.\-]+)")
_TRIP_RE = re.compile(r'known_trip_count[\\"={:]+n[\\"={:]+(\d+)')
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_LHS_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")

_COLL_KINDS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


def _type_bytes_and_dims(type_str: str):
    total, dims_all = 0, []
    for m in _ARRAY_RE.finditer(type_str):
        dt, dims = m.groups()
        n = 1
        ds = []
        if dims:
            ds = [int(d) for d in dims.split(",")]
            for d in ds:
                n *= d
        total += n * _DTYPE_BYTES[dt]
        dims_all.append(ds)
    return total, dims_all


@dataclasses.dataclass
class OpCost:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    coll_bytes: Dict[str, float] = dataclasses.field(default_factory=dict)
    coll_moved: float = 0.0      # ring-factor-scaled per-device bytes

    def add(self, other: "OpCost", mult: float = 1.0):
        self.flops += other.flops * mult
        self.hbm_bytes += other.hbm_bytes * mult
        self.coll_moved += other.coll_moved * mult
        for k, v in other.coll_bytes.items():
            self.coll_bytes[k] = self.coll_bytes.get(k, 0.0) + v * mult


class HloAnalyzer:
    def __init__(self, hlo_text: str):
        self.text = hlo_text
        self.shape_of: Dict[str, str] = {}
        self.comps: Dict[str, List[str]] = {}
        self._parse_structure()

    def _parse_structure(self):
        cur = None
        for line in self.text.splitlines():
            mc = _COMP_RE.match(line)
            if mc and line.rstrip().endswith("{"):
                cur = mc.group(1)
                self.comps[cur] = []
                continue
            if line.strip() == "}":
                cur = None
                continue
            if cur is None:
                continue
            self.comps[cur].append(line)
            md = _DEF_RE.match(line)
            if md:
                rest = line[md.end():]
                # the type is everything up to the op name token
                self.shape_of[md.group(1)] = rest.split(" ")[0] \
                    if not rest.startswith("(") else rest[:rest.index(")") + 1]

    # -- helpers --
    def _operands(self, line: str) -> List[str]:
        """Operand names inside the first (...) of the op call."""
        op_idx = line.find("(")
        if op_idx < 0:
            return []
        depth, end = 0, len(line)
        for i in range(op_idx, len(line)):
            if line[i] == "(":
                depth += 1
            elif line[i] == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        return _OPND_RE.findall(line[op_idx:end])

    def _result_type(self, line: str) -> str:
        md = _DEF_RE.match(line)
        rest = line[md.end():] if md else line
        if rest.startswith("("):
            return rest[: rest.index(")") + 1]
        return rest.split(" ")[0]

    def _group_size(self, line: str, kind: str) -> int:
        m = _GROUPS_RE.search(line)
        if m:
            return int(m.group(2))
        m = _GROUPS_LIST_RE.search(line)
        if m:
            return len(m.group(1).split(","))
        return 2

    def _op_name(self, line: str) -> Optional[str]:
        md = _DEF_RE.match(line)
        if not md:
            return None
        rest = line[md.end():]
        # skip the type token(s)
        if rest.startswith("("):
            rest = rest[rest.index(")") + 1:].lstrip()
        else:
            sp = rest.find(" ")
            rest = rest[sp + 1:] if sp >= 0 else ""
        return rest.split("(")[0].strip()

    def _line_cost(self, line: str) -> Tuple[OpCost, List[Tuple[str, float]]]:
        """Returns (cost, [(called_computation, multiplier), ...])."""
        cost = OpCost()
        calls: List[Tuple[str, float]] = []
        op = self._op_name(line)
        if not op:
            return cost, calls
        rtype = self._result_type(line)
        rbytes, _ = _type_bytes_and_dims(rtype)

        if op == "while":
            trips = 1.0
            mt = _TRIP_RE.search(line)
            if mt:
                trips = float(mt.group(1))
            body = re.search(r"body=%?([\w.\-]+)", line)
            if body:
                calls.append((body.group(1), trips))
            return cost, calls
        if op in ("fusion", "call", "async-start"):
            m = re.search(r"(?:calls|to_apply)=%?([\w.\-]+)", line)
            if m:
                calls.append((m.group(1), 1.0))
            if op == "fusion":
                name = _DEF_RE.match(line).group(1)
                if "dynamic-update-slice" in name or "dynamic_update_slice" \
                        in name:
                    # in-place accumulator update: traffic ~ the small
                    # operands (slice + indices), buffer is aliased
                    small = sum(
                        _type_bytes_and_dims(self.shape_of.get(o, ""))[0]
                        for o in self._operands(line)
                        if _type_bytes_and_dims(
                            self.shape_of.get(o, ""))[0] < rbytes)
                    cost.hbm_bytes += 2.0 * min(small or rbytes, rbytes)
                else:
                    cost.hbm_bytes += 2.0 * rbytes
            return cost, calls
        if op == "conditional":
            for m in re.finditer(r"(?:true_computation|false_computation|"
                                 r"branch_computations)=\{?%?([\w.\-]+)", line):
                calls.append((m.group(1), 1.0))
            return cost, calls

        base = op.replace("-start", "").replace("-done", "")
        if base in _COLL_KINDS:
            if op.endswith("-done"):
                return cost, calls
            n = self._group_size(line, base)
            factor = {"all-gather": (n - 1) / n,
                      "all-reduce": 2 * (n - 1) / n,
                      "reduce-scatter": (n - 1),
                      "all-to-all": (n - 1) / n,
                      "collective-permute": 1.0}[base]
            cost.coll_bytes[base] = rbytes
            cost.coll_moved = rbytes * factor
            cost.hbm_bytes += 2.0 * rbytes
            return cost, calls

        if op == "dot":
            ops = self._operands(line)
            lhs_shape = self.shape_of.get(ops[0], "") if ops else ""
            rhs_shape = self.shape_of.get(ops[1], "") if len(ops) > 1 else ""
            rb, rdims = _type_bytes_and_dims(rtype)
            lb, ldims = _type_bytes_and_dims(lhs_shape)
            rhb, _ = _type_bytes_and_dims(rhs_shape)
            contract = 1
            mc = _LHS_CONTRACT_RE.search(line)
            if mc and ldims and ldims[0]:
                for ci in mc.group(1).split(","):
                    if ci:
                        contract *= ldims[0][int(ci)]
            rsize = 1
            for ds in rdims:
                for d in ds:
                    rsize *= d
            cost.flops += 2.0 * rsize * contract
            cost.hbm_bytes += rb + lb + rhb
            return cost, calls

        if op == "convolution":
            cost.hbm_bytes += 3.0 * rbytes
            return cost, calls
        if op in ("scatter", "dynamic-update-slice"):
            # in-place update: traffic ~ 2x the *update* operand, not the
            # full result buffer
            ops = self._operands(line)
            ub = rbytes
            if len(ops) > 1:
                ub, _ = _type_bytes_and_dims(self.shape_of.get(ops[1], ""))
                ub = ub or rbytes
            cost.hbm_bytes += 2.0 * min(ub, rbytes)
            return cost, calls
        if op in ("gather", "dynamic-slice", "reduce", "reduce-window"):
            cost.hbm_bytes += 2.0 * rbytes
            return cost, calls
        if op == "sort":
            cost.hbm_bytes += 4.0 * rbytes   # multi-pass
            return cost, calls
        # copies from resharding are real data movement on TPU
        if op == "copy":
            cost.hbm_bytes += 2.0 * rbytes
            return cost, calls
        return cost, calls

    def analyze_computation(self, name: str, _memo=None) -> OpCost:
        if _memo is None:
            _memo = {}
        if name in _memo:
            return _memo[name]
        total = OpCost()
        for line in self.comps.get(name, ()):
            cost, calls = self._line_cost(line)
            total.add(cost)
            for callee, mult in calls:
                sub = self.analyze_computation(callee, _memo)
                total.add(sub, mult)
        _memo[name] = total
        return total

    def entry(self) -> str:
        m = re.search(r"^ENTRY\s+%?([\w.\-]+)", self.text, re.M)
        if not m:
            raise ValueError("no ENTRY computation found")
        return m.group(1)

    def analyze(self) -> OpCost:
        return self.analyze_computation(self.entry())
