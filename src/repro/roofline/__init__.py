from repro.roofline.analysis import (HBM_BW, LINK_BW, PEAK_FLOPS, Roofline,
                                     collective_bytes, from_compiled,
                                     model_flops, shape_bytes)

__all__ = ["HBM_BW", "LINK_BW", "PEAK_FLOPS", "Roofline", "collective_bytes",
           "from_compiled", "model_flops", "shape_bytes"]
