"""Production mesh definitions.

A function (not a module-level constant) so importing this module never
touches jax device state; the dry-run sets XLA_FLAGS before first jax init.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 (data, model) single pod; 2x16x16 (pod, data, model) two pods."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    import numpy as np
    n = int(np.prod(shape))
    return jax.make_mesh(shape, axes, devices=jax.devices()[:n])


def make_host_mesh(model_parallel: int = 1):
    """Degenerate mesh over the actually-available devices (smoke tests)."""
    n = len(jax.devices())
    return jax.make_mesh((n // model_parallel, model_parallel),
                         ("data", "model"))


def make_engine_mesh(tp: int = 1):
    """Serving-engine mesh: exactly ``tp`` devices as a (1, tp)
    (data, model) grid.  One ``ServingEngine`` is one tensor-parallel
    group — replica scale-out happens at the instance level (the runtime
    routes across engines), never inside the engine, so the data axis is
    always 1.  CPU validation forces multiple host devices via
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` (set before the
    first jax import)."""
    devs = jax.devices()
    if len(devs) < tp:
        raise ValueError(
            f"tensor-parallel degree {tp} needs {tp} devices but only "
            f"{len(devs)} are visible; on CPU set "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={tp} "
            f"before importing jax")
    return jax.make_mesh((1, tp), ("data", "model"), devices=devs[:tp])


def dp_axes(mesh) -> tuple:
    """The data-parallel axis names of a mesh (pod axis folds into DP)."""
    names = mesh.axis_names
    return tuple(a for a in names if a in ("pod", "data"))


def dp_size(mesh) -> int:
    s = 1
    for a in dp_axes(mesh):
        s *= mesh.shape[a]
    return s
