"""ShapeDtypeStruct stand-ins for every model input: weak-type-correct,
shardable, no device allocation. The dry-run lowers against these."""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs import ArchConfig, ShapeCfg
from repro.models import Model
from repro.train.optimizer import AdamW
from repro.train.train_step import TrainState

SDS = jax.ShapeDtypeStruct


def train_batch_specs(cfg: ArchConfig, shape: ShapeCfg) -> dict:
    B, S = shape.global_batch, shape.seq_len
    if cfg.embed_inputs:
        inputs = SDS((B, S), jnp.int32)
    else:
        inputs = SDS((B, S, cfg.d_model), jnp.bfloat16)
    if cfg.n_codebooks:
        labels = SDS((B, S, cfg.n_codebooks), jnp.int32)
    else:
        labels = SDS((B, S), jnp.int32)
    return {"inputs": inputs, "labels": labels}


def prefill_specs(cfg: ArchConfig, shape: ShapeCfg):
    B, S = shape.global_batch, shape.seq_len
    if cfg.embed_inputs:
        return SDS((B, S), jnp.int32)
    return SDS((B, S, cfg.d_model), jnp.bfloat16)


def decode_token_specs(cfg: ArchConfig, shape: ShapeCfg):
    B = shape.global_batch
    if cfg.embed_inputs:
        return SDS((B, 1), jnp.int32)
    return SDS((B, 1, cfg.d_model), jnp.bfloat16)


def cache_specs(model: Model, shape: ShapeCfg):
    """Shape-only cache pytree via eval_shape (no allocation)."""
    B, S = shape.global_batch, shape.seq_len
    return jax.eval_shape(lambda: model.init_cache(B, S))


def params_specs(model: Model):
    return jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))


def state_specs(model: Model, optimizer: AdamW) -> TrainState:
    params = params_specs(model)
    opt = jax.eval_shape(optimizer.init, params)
    return TrainState(params, opt)


def input_specs(cfg: ArchConfig, shape: ShapeCfg, model: Model,
                optimizer: AdamW | None = None):
    """All inputs for the step kind of ``shape``: the dry-run entry point."""
    if shape.step == "train":
        opt = optimizer or AdamW()
        return {"state": state_specs(model, opt),
                "batch": train_batch_specs(cfg, shape)}
    if shape.step == "prefill":
        return {"params": params_specs(model),
                "tokens": prefill_specs(cfg, shape)}
    if shape.step == "decode":
        return {"params": params_specs(model),
                "cache": cache_specs(model, shape),
                "tokens": decode_token_specs(cfg, shape)}
    raise ValueError(shape.step)
