"""Sharding rules: params, caches, and batch inputs -> PartitionSpec trees.

TP on the ``model`` axis (attention heads / FFN hidden / experts / vocab),
DP on ``data`` (+``pod``); long-context (batch < dp) decode shards the KV
cache sequence dim instead (sequence parallelism). GSPMD handles the
not-evenly-divisible cases (e.g. 36 heads on 16 shards) by padding — the
roofline table records where that costs us (§Perf).
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

MODEL = "model"

# leaf name -> which *trailing* dim gets the model axis (negative index),
# None = replicate.  Context key "moe" overrides for expert-stacked weights.
_COL = {"wq", "wk", "wv", "wqkv", "bq", "bk", "bv", "w_gate", "w_up", "w_in",
        "w_zx", "w_dt", "w_q", "w_k", "w_v", "w_gates"}
_ROW = {"wo", "w_down", "w_out"}
_REPL = {"norm1", "norm2", "norm", "final_norm", "q_norm", "k_norm",
         "norm_scale", "norm_in", "norm_h", "conv_w", "conv_b", "A_log",
         "D", "dt_bias", "w_bc", "router", "r_gates", "b_gates", "f_bias",
         "w_i", "w_f", "lengths"}


def fit_to_mesh(spec_tree, shape_tree, mesh):
    """Replace any sharded dim that does not divide evenly by None.

    pjit requires *boundary* (input/output) shardings to divide exactly;
    GSPMD only pads intermediates. This post-pass keeps the rules simple and
    makes every uneven case (e.g. 40 experts on 16 shards) explicit:
    the leaf is replicated and the roofline table shows the cost.
    """
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))

    def ax_size(entry) -> int:
        if entry is None:
            return 1
        if isinstance(entry, (tuple, list)):
            n = 1
            for e in entry:
                n *= sizes[e]
            return n
        return sizes[entry]

    def fix(spec, leaf):
        dims = tuple(spec) + (None,) * (leaf.ndim - len(tuple(spec)))
        out = []
        for d, entry in zip(leaf.shape, dims):
            out.append(entry if d % ax_size(entry) == 0 else None)
        return P(*out)

    return jax.tree_util.tree_map(
        fix, spec_tree, shape_tree,
        is_leaf=lambda x: isinstance(x, P))


def _param_spec(path: Tuple[str, ...], leaf, model_size: int = 16) -> P:
    name = path[-1]
    rank = np.ndim(leaf) if not hasattr(leaf, "ndim") else leaf.ndim
    in_moe = "moe" in path
    if path[-2:] == ("embed", "tok") or (len(path) >= 2 and path[-2] == "embed"):
        return P(MODEL, None)
    if "head" in path:
        return _trailing(rank, -1)
    if in_moe and name in ("w_gate", "w_up", "w_down"):
        # experts stacked at dim -3: expert parallelism when E divides the
        # TP axis; otherwise fall back to TP inside each expert.
        E = leaf.shape[-3]
        if E % model_size == 0:
            return _trailing(rank, -3)
        return _trailing(rank, -1 if name in ("w_gate", "w_up") else -2)
    if name in _REPL:
        return P(*([None] * rank))
    if name in _COL:
        return _trailing(rank, -1)
    if name in _ROW:
        return _trailing(rank, -2)
    return P(*([None] * rank))


def _trailing(rank: int, dim: int) -> P:
    spec = [None] * rank
    spec[dim] = MODEL
    return P(*spec)


def param_pspecs(params_shape: Any, model_size: int = 16):
    """Map a params (or opt-state) shape tree to PartitionSpecs.

    ``model_size`` is the model-axis extent divisibility heuristics use
    (16 for the production mesh; the serving engine passes its tp degree).
    """
    def walk(tree, path=()):
        if isinstance(tree, dict):
            return {k: walk(v, path + (k,)) for k, v in tree.items()}
        if isinstance(tree, (list, tuple)):
            vals = [walk(v, path + (str(i),)) for i, v in enumerate(tree)]
            return type(tree)(vals)
        return _param_spec(path, tree, model_size)
    return walk(params_shape)


def state_pspecs(state_shape, zero1: bool = False):
    """TrainState(params, AdamWState(step, mu, nu)) -> same-leaf specs.

    ``zero1=True`` additionally shards the Adam moments over the 'data'
    axis (ZeRO-1): the first not-yet-sharded dim of each moment leaf gets
    'data'. XLA inserts the gather/scatter around the update.
    """
    from repro.train.train_step import TrainState
    from repro.train.optimizer import AdamWState
    pspec = param_pspecs(state_shape.params)
    mu = param_pspecs(state_shape.opt.mu)
    nu = param_pspecs(state_shape.opt.nu)
    if zero1:
        def add_data(spec, leaf):
            dims = list(tuple(spec)) + [None] * (leaf.ndim - len(tuple(spec)))
            for i, (d, entry) in enumerate(zip(leaf.shape, dims)):
                if entry is None and d % 16 == 0 and d > 1:
                    dims[i] = "data"
                    break
            return P(*dims)
        mu = jax.tree_util.tree_map(add_data, mu, state_shape.opt.mu,
                                    is_leaf=lambda x: isinstance(x, P))
        nu = jax.tree_util.tree_map(add_data, nu, state_shape.opt.nu,
                                    is_leaf=lambda x: isinstance(x, P))
    return TrainState(pspec, AdamWState(P(), mu, nu))


def batch_pspecs(batch_shape, dp: Tuple[str, ...]):
    """Shard the leading batch dim of every batch leaf on the dp axes."""
    def spec(leaf):
        rank = leaf.ndim
        if leaf.shape[0] == 1:
            return P(*([None] * rank))   # batch-1: unshardable
        return P(dp, *([None] * (rank - 1)))
    return jax.tree_util.tree_map(spec, batch_shape)


def cache_pspecs(cache_shape, dp: Tuple[str, ...], batch: int,
                 seq_shard: bool = False, model_size: int = 16):
    """KV caches (L,B,S,KV,dh) / SSM states -> specs.

    batch >= dp size: shard B on dp, KV heads on model.
    batch == 1 (long-context): shard cache sequence on 'data' (SP) and KV
    heads on model; SSM states shard heads on model only.
    ``model_size`` is the model-axis extent (16 for the production mesh;
    the serving engine passes its tp) used to choose between sharding the
    KV-head dim and the head_dim.
    """
    sp = batch > 1

    def walk(tree, path=()):
        if isinstance(tree, dict):
            return {k: walk(v, path + (k,)) for k, v in tree.items()}
        name = path[-1]
        rank = tree.ndim
        if name == "lengths":
            return P(dp) if sp else P(None)
        if name == "block_table":        # (B, maxp) int32: replicate —
            return P(*([None] * rank))   # every shard walks the same pages
        b_ax = rank - tree.shape[::-1].index(batch) - 1 if batch in tree.shape \
            else None
        if name in ("k_pages", "v_pages"):
            # paged pools (..., n_pages, ps, KV, dh): no batch dim — pages
            # are shared storage — so only the head dims can carry TP
            spec = [None] * rank
            if tree.shape[-2] % model_size == 0:
                spec[-2] = MODEL
            else:
                spec[-1] = MODEL
            return P(*spec)
        if name in ("k", "v"):
            # (..., B, S, KV, dh)
            spec = [None] * rank
            if sp:
                spec[-4] = dp
            else:
                spec[-3] = "data"       # SP over cache sequence
            if seq_shard and sp:
                # Perf iteration 3: shard the cache sequence on the model
                # axis (flash-decoding style split-K) instead of padding
                # few KV heads / splitting head_dim
                spec[-3] = MODEL
            elif tree.shape[-2] % model_size == 0:  # KV heads fill TP axis
                spec[-2] = MODEL
            else:                           # shard head_dim (128/16=8)
                spec[-1] = MODEL
            return P(*spec)
        if name == "ssd":                # (..., B, nh, hd, ds)
            spec = [None] * rank
            if sp:
                spec[-4] = dp
            spec[-3] = MODEL
            return P(*spec)
        if name == "conv":               # (..., B, k-1, cd)
            spec = [None] * rank
            if sp:
                spec[-3] = dp
            return P(*spec)
        if name == "C":                  # mlstm (..., B, nh, hd, hd)
            spec = [None] * rank
            if sp:
                spec[-4] = dp
            return P(*spec)
        if name in ("n", "m", "h", "c"):
            spec = [None] * rank
            if sp and b_ax is not None:
                spec[b_ax] = dp
            return P(*spec)
        spec = [None] * rank
        if sp and b_ax is not None:
            spec[b_ax] = dp
        return P(*spec)

    return walk(cache_shape)


def logits_pspec(rank: int, dp, batch: int):
    spec = [None] * rank
    if batch > 1:
        spec[0] = dp
    spec[-1] = MODEL
    return P(*spec)
