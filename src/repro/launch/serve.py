"""Serving driver: real JAX engine(s) with batched requests.

  PYTHONPATH=src python -m repro.launch.serve --arch llama3.1-8b-tiny \
      --n 32 --rate 10 [--pd] [--prefix-cache] [--instances 2]
"""
from __future__ import annotations

import argparse
import json

from repro.configs import get_config
from repro.serve import DriverCfg, ServeDriver, ServingEngine
from repro.workload import ShareGPTConfig, generate


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.1-8b-tiny")
    ap.add_argument("--n", type=int, default=24)
    ap.add_argument("--rate", type=float, default=10.0)
    ap.add_argument("--instances", type=int, default=1)
    ap.add_argument("--pd", action="store_true")
    ap.add_argument("--prefix-cache", action="store_true")
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=512)
    ap.add_argument("--tp", type=int, default=1,
                    help="tensor-parallel degree per engine (needs >= tp "
                         "visible devices; on CPU set XLA_FLAGS="
                         "--xla_force_host_platform_device_count)")
    ap.add_argument("--router", default="round_robin",
                    help="any registered routing policy "
                         "(round_robin | least_loaded | prefix_aware)")
    ap.add_argument("--chunked-prefill", action="store_true",
                    help="continuous batching with chunked prefill on the "
                         "real engine (unified runtime scheduler)")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    reqs = generate(ShareGPTConfig(
        n_requests=args.n, rate=args.rate, vocab=cfg.vocab,
        mean_prompt=90, mean_output=24, max_prompt=args.max_len // 2,
        max_output=48, share_fraction=0.5 if args.prefix_cache else 0.0))
    kw = dict(max_batch=args.max_batch, max_len=args.max_len,
              prefix_cache=args.prefix_cache, tp=args.tp)
    if args.pd:
        p0 = ServingEngine(cfg, name="p0", role="prefill", **kw)
        engines = [p0, ServingEngine(cfg, params=p0.params, name="d0",
                                     role="decode", **kw)]
        pd = {"p0": ("d0",)}
    else:
        e0 = ServingEngine(cfg, name="e0", **kw)
        engines = [e0] + [
            ServingEngine(cfg, params=e0.params, name=f"e{i}", **kw)
            for i in range(1, args.instances)]
        pd = None
    sched = None
    if args.chunked_prefill:
        from repro.core.config import SchedulerCfg
        sched = SchedulerCfg(max_batch_size=args.max_batch,
                             max_batch_tokens=256,
                             chunked_prefill=True, prefill_chunk=64)
    drv = ServeDriver(engines, DriverCfg(router=args.router,
                                         scheduler=sched), pd_map=pd)
    m = drv.run(reqs)
    print(json.dumps(m, indent=1, default=float))


if __name__ == "__main__":
    main()
