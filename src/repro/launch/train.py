"""Fault-tolerant training driver.

  PYTHONPATH=src python -m repro.launch.train --arch demo-110m --steps 300
  PYTHONPATH=src python -m repro.launch.train --arch demo-110m --resume

Runs data-parallel (+TP if the host mesh has a model axis) training with
atomic checkpointing and restart-after-failure semantics: kill the process
at any step and --resume continues from the last durable checkpoint.
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.configs.base import ATTN_MLP, ArchConfig, simple_stages
from repro.models import Model
from repro.train import (AdamW, TrainStepConfig, cosine_schedule, init_state,
                         make_train_step)
from repro.train import checkpoint as ckpt
from repro.workload.datasets import DataConfig, token_batches

# ~110M-parameter demo config (the "train a ~100M model" driver)
DEMO_110M = ArchConfig(
    name="demo-110m", family="dense", n_layers=12, d_model=768, n_heads=12,
    n_kv_heads=4, d_head=64, d_ff=2048, vocab=16384,
    stages=simple_stages(ATTN_MLP, 12))


def get_train_config(name: str) -> ArchConfig:
    if name == "demo-110m":
        return DEMO_110M
    if name == "demo-10m":
        return dataclasses.replace(
            DEMO_110M, name="demo-10m", n_layers=4, d_model=256, n_heads=4,
            d_ff=768, vocab=4096, stages=simple_stages(ATTN_MLP, 4))
    return get_config(name)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="demo-10m")
    ap.add_argument("--steps", type=int, default=40)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default="checkpoints")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--grad-compress", action="store_true")
    args = ap.parse_args()

    cfg = get_train_config(args.arch)
    model = Model(cfg, remat=False)
    optimizer = AdamW(lr=cosine_schedule(args.lr, 20, args.steps))
    step_fn = jax.jit(make_train_step(
        model, optimizer,
        TrainStepConfig(microbatches=args.microbatches,
                        grad_compress=args.grad_compress)))

    state = init_state(model, optimizer, jax.random.PRNGKey(0))
    start = 0
    if args.resume:
        latest = ckpt.latest_step(args.ckpt_dir)
        if latest is not None:
            state = ckpt.restore(args.ckpt_dir, latest, state)
            start = latest
            print(f"resumed from step {latest}")

    data = token_batches(DataConfig(vocab=cfg.vocab, batch=args.batch,
                                    seq_len=args.seq, seed=0))
    # deterministic resume: skip consumed batches
    for _ in range(start):
        next(data)

    losses = []
    t0 = time.time()
    for step in range(start, args.steps):
        batch = next(data)
        state, metrics = step_fn(state, batch)
        loss = float(metrics["loss"])
        losses.append(loss)
        if (step + 1) % args.ckpt_every == 0 or step + 1 == args.steps:
            path = ckpt.save(args.ckpt_dir, step + 1, state)
            print(f"step {step+1}: loss={loss:.4f} "
                  f"grad_norm={float(metrics['grad_norm']):.3f} "
                  f"ckpt={path}", flush=True)
        elif (step + 1) % 10 == 0:
            print(f"step {step+1}: loss={loss:.4f}", flush=True)
    dt = time.time() - t0
    print(f"done: {args.steps - start} steps in {dt:.1f}s "
          f"({dt / max(args.steps - start, 1):.2f}s/step); "
          f"loss {losses[0]:.3f} -> {losses[-1]:.3f}")
    assert losses[-1] < losses[0], "loss did not decrease"


if __name__ == "__main__":
    main()
