import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import: jax locks device count on first init.

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this:
  1. builds the production mesh (16x16 single pod / 2x16x16 multi-pod),
  2. constructs ShapeDtypeStruct inputs (no allocation),
  3. ``jax.jit(step, in_shardings=..., out_shardings=...).lower().compile()``,
  4. records memory_analysis / cost_analysis / collective schedule,
  5. appends a JSON record consumed by EXPERIMENTS.md §Dry-run / §Roofline.

Usage:
  python -m repro.launch.dryrun --arch starcoder2-7b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--out results.json]
"""
import argparse
import json
import time
import traceback

import jax

from repro.configs import (ASSIGNED, cell_is_runnable, get_config, get_shape,
                           ALL_SHAPES)
from repro.launch.mesh import dp_axes, make_production_mesh
from repro.launch import sharding as shd
from repro.launch.specs import input_specs
from repro.models import Model
from repro.roofline import analysis as ra
from repro.train.optimizer import AdamW
from repro.train.train_step import make_train_step

from jax.sharding import NamedSharding, PartitionSpec as P


def _ns(mesh, spec_tree):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))


def lower_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
               attn_impl: str = "flash", donate: bool = True,
               unroll: bool = True, microbatches: int = 1,
               zero1: bool = False, fuse_qkv: bool = False,
               shard_experts: bool = False, seq_shard_cache: bool = False,
               norm_ct16: bool = False, variant: str = "baseline"):
    """Lower+compile one cell; returns (record, compiled).

    ``unroll=True`` removes every while loop from the HLO so that
    cost_analysis / collective parsing count per-layer work correctly
    (XLA does not multiply loop bodies by trip count).
    """
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    if not cell_is_runnable(cfg.subquadratic, shape):
        return {"arch": arch, "shape": shape_name,
                "multi_pod": multi_pod, "status": "skipped",
                "reason": "long_500k requires sub-quadratic attention "
                          "(DESIGN.md §5)"}, None
    mesh = make_production_mesh(multi_pod=multi_pod)
    dp = dp_axes(mesh)
    n_dev = mesh.size
    model = Model(cfg, attn_impl=attn_impl, unroll=unroll,
                  fuse_qkv=fuse_qkv, shard_experts=shard_experts,
                  norm_ct16=norm_ct16)
    t0 = time.time()
    specs = input_specs(cfg, shape, model)

    with mesh:
        if shape.step == "train":
            optimizer = AdamW()
            from repro.train.train_step import TrainStepConfig
            step_fn = make_train_step(
                model, optimizer,
                TrainStepConfig(microbatches=microbatches, dp_axes=dp))
            state_sp = shd.fit_to_mesh(
                shd.state_pspecs(specs["state"], zero1=zero1),
                specs["state"], mesh)
            batch_sp = shd.fit_to_mesh(
                shd.batch_pspecs(specs["batch"], dp), specs["batch"], mesh)
            metrics_sp = jax.tree_util.tree_map(
                lambda _: P(),
                jax.eval_shape(step_fn, specs["state"], specs["batch"])[1])
            jf = jax.jit(step_fn,
                         in_shardings=(_ns(mesh, state_sp), _ns(mesh, batch_sp)),
                         out_shardings=(_ns(mesh, state_sp),
                                        _ns(mesh, metrics_sp)),
                         donate_argnums=(0,) if donate else ())
            lowered = jf.lower(specs["state"], specs["batch"])
        elif shape.step == "prefill":
            param_sp = shd.fit_to_mesh(shd.param_pspecs(specs["params"]),
                                       specs["params"], mesh)
            tok_sp = shd.fit_to_mesh(
                shd.batch_pspecs({"t": specs["tokens"]}, dp)["t"],
                specs["tokens"], mesh)
            out_shape = jax.eval_shape(model.prefill, specs["params"],
                                       specs["tokens"])
            logits_sp = shd.fit_to_mesh(
                shd.logits_pspec(out_shape[0].ndim, dp, shape.global_batch),
                out_shape[0], mesh)
            cache_sp = shd.fit_to_mesh(
                shd.cache_pspecs(out_shape[1], dp, shape.global_batch),
                out_shape[1], mesh)
            jf = jax.jit(model.prefill,
                         in_shardings=(_ns(mesh, param_sp), _ns(mesh, tok_sp)),
                         out_shardings=(_ns(mesh, logits_sp),
                                        _ns(mesh, cache_sp)))
            lowered = jf.lower(specs["params"], specs["tokens"])
        else:  # decode
            param_sp = shd.fit_to_mesh(shd.param_pspecs(specs["params"]),
                                       specs["params"], mesh)
            cache_sp = shd.fit_to_mesh(
                shd.cache_pspecs(specs["cache"], dp, shape.global_batch,
                                 seq_shard=seq_shard_cache),
                specs["cache"], mesh)
            tok_sp = shd.fit_to_mesh(
                shd.batch_pspecs({"t": specs["tokens"]}, dp)["t"],
                specs["tokens"], mesh)
            out_shape = jax.eval_shape(model.decode, specs["params"],
                                       specs["cache"], specs["tokens"])
            logits_sp = shd.fit_to_mesh(
                shd.logits_pspec(out_shape[0].ndim, dp, shape.global_batch),
                out_shape[0], mesh)
            out_cache_sp = shd.fit_to_mesh(
                shd.cache_pspecs(out_shape[1], dp, shape.global_batch,
                                 seq_shard=seq_shard_cache),
                out_shape[1], mesh)
            jf = jax.jit(model.decode,
                         in_shardings=(_ns(mesh, param_sp),
                                       _ns(mesh, cache_sp),
                                       _ns(mesh, tok_sp)),
                         out_shardings=(_ns(mesh, logits_sp),
                                        _ns(mesh, out_cache_sp)),
                         donate_argnums=(1,) if donate else ())
            lowered = jf.lower(specs["params"], specs["cache"],
                               specs["tokens"])
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    mem = {}
    try:
        ma = compiled.memory_analysis()
        for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                     "temp_size_in_bytes", "alias_size_in_bytes",
                     "generated_code_size_in_bytes"):
            if hasattr(ma, attr):
                mem[attr] = int(getattr(ma, attr))
    except Exception as e:  # pragma: no cover
        mem["error"] = str(e)

    roof = ra.from_compiled(compiled, n_dev)
    mf = ra.model_flops(cfg, shape)
    rec = {
        "arch": arch, "shape": shape_name, "multi_pod": multi_pod,
        "mesh": list(mesh.devices.shape), "n_devices": n_dev,
        "status": "ok", "attn_impl": attn_impl, "unroll": unroll,
        "microbatches": microbatches, "zero1": zero1, "variant": variant,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory": mem,
        "roofline": roof.summary(),
        "model_flops_global": mf,
        "model_flops_per_device": mf / n_dev,
        "useful_flops_frac": (mf / n_dev) / max(roof.flops, 1.0),
    }
    return rec, compiled


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--attn-impl", default="flash")
    ap.add_argument("--out", default="dryrun_results.jsonl")
    ap.add_argument("--skip-done", action="store_true")
    ap.add_argument("--no-unroll", action="store_true")
    ap.add_argument("--microbatches", type=int, default=0,
                    help="0 = auto (8 for train, 1 otherwise)")
    ap.add_argument("--zero1", action="store_true")
    args = ap.parse_args()

    done = set()
    if args.skip_done and os.path.exists(args.out):
        with open(args.out) as f:
            for line in f:
                try:
                    r = json.loads(line)
                    done.add((r["arch"], r["shape"], r["multi_pod"],
                              r.get("attn_impl", "flash")))
                except Exception:
                    pass

    cells = []
    archs = ASSIGNED if args.all or not args.arch else [args.arch]
    shapes = [s.name for s in ALL_SHAPES] if args.all or not args.shape \
        else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    for a in archs:
        for s in shapes:
            for mp in meshes:
                cells.append((a, s, mp))

    with open(args.out, "a") as f:
        for arch, shape, mp in cells:
            key = (arch, shape, mp, args.attn_impl)
            if key in done:
                print(f"skip (done): {key}")
                continue
            print(f"=== {arch} x {shape} multi_pod={mp} ===", flush=True)
            # big-model training needs grad accumulation to fit HBM
            mb = args.microbatches
            if shape == "train_4k" and mb == 0:
                mb = 8      # auto default for the baseline table
            elif mb == 0:
                mb = 1
            try:
                rec, compiled = lower_cell(
                    arch, shape, multi_pod=mp, attn_impl=args.attn_impl,
                    unroll=not args.no_unroll,
                    microbatches=mb, zero1=args.zero1)
                del compiled
            except Exception as e:
                rec = {"arch": arch, "shape": shape, "multi_pod": mp,
                       "status": "error", "error": str(e)[:2000],
                       "attn_impl": args.attn_impl,
                       "traceback": traceback.format_exc()[-4000:]}
            print(json.dumps({k: v for k, v in rec.items()
                              if k != "traceback"}, indent=None),
                  flush=True)
            f.write(json.dumps(rec) + "\n")
            f.flush()


if __name__ == "__main__":
    main()
