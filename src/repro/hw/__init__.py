"""Hardware-trace pipeline: profiler artifacts -> registry -> perf models.

``repro.hw`` owns the portable representation of "how fast is this device"
(see ``docs/adding-hardware.md``):

* :class:`HardwareTrace` — versioned JSON artifact: op -> latency table
  over (tokens, context) buckets, interconnect params, optional device spec.
* :class:`HardwareRegistry` / :data:`default_registry` — device name ->
  trace resolution used by ``ServingRuntime`` for ``InstanceCfg.hw_name``,
  with synthetic (analytical-roofline) fallback for never-measured devices.
* :func:`synthetic_trace` — the analytical model as a trace generator.
* ``specs`` — named ``HardwareSpec`` registry (rtx3090, tpu-v5e/v6e, pim,
  cpu-host, cpu-engine, plus ``register_hw`` for new devices).

This package is jax-free: the pure simulator prices heterogeneous clusters
without importing the real-engine stack.
"""
from repro.hw.registry import (HardwareRegistry, default_registry,
                               load_traces, register_trace)
from repro.hw.specs import get_hw, known_hw, measured_cpu_spec, register_hw
from repro.hw.synthetic import add_synthetic_points, synthetic_trace
from repro.hw.trace import (READABLE_SCHEMAS, SCHEMA_VERSION, HardwareTrace,
                            InterconnectSpec)

__all__ = [
    "HardwareTrace", "InterconnectSpec", "SCHEMA_VERSION",
    "READABLE_SCHEMAS",
    "HardwareRegistry", "default_registry", "register_trace", "load_traces",
    "synthetic_trace", "add_synthetic_points",
    "get_hw", "register_hw", "known_hw", "measured_cpu_spec",
]
