"""Synthetic hardware traces from an analytical roofline (jax-free).

This is the "integrate a hypothetical accelerator instantly" path (paper
Table III): given a ``HardwareSpec`` (peak FLOP/s, HBM bandwidth, link
bandwidth) and a ``ModelSpec``, derive the same operator-latency grid the
measured profiler would emit.  The analytical model lives here ONCE — the
operator profiler's analytical mode and the hardware registry's fallback
both call :func:`add_synthetic_points`, and ``core.perfmodel`` keeps only a
per-query roofline for op/shape combos outside any trace grid.
"""
from __future__ import annotations

from typing import Optional, Sequence

from repro.core.config import HardwareSpec, ModelSpec
from repro.hw.trace import HardwareTrace, InterconnectSpec

DEFAULT_TOKEN_GRID = (1, 2, 4, 8, 16, 32, 64, 128, 256, 512)
DEFAULT_CTX_GRID = (64, 256, 1024, 4096)
DEFAULT_BATCH_GRID = (1, 4, 16, 64)


def add_synthetic_points(trace, spec: HardwareSpec, model: ModelSpec,
                         tp: int = 1,
                         token_grid: Sequence[int] = DEFAULT_TOKEN_GRID,
                         ctx_grid: Sequence[int] = DEFAULT_CTX_GRID,
                         batch_grid: Sequence[int] = DEFAULT_BATCH_GRID):
    """Fill ``trace`` (anything with an ``add(op, phase, tokens, context,
    latency_s)`` method) with analytical operator points for one device."""
    tp = max(tp, 1)

    def roof(flops: float, nbytes: float) -> float:
        return max(flops / (spec.peak_flops * spec.mmu_efficiency),
                   nbytes / spec.hbm_bw) + 2e-6

    d, dh = model.d_model, model.d_head
    qkv_d = (model.n_heads + 2 * model.n_kv_heads) * dh
    for T in token_grid:
        for phase, ctx in (("decode", 1), ("prefill", T)):
            wb = (d * qkv_d + model.n_heads * dh * d) / tp * 2
            trace.add("attn_qkv", phase, T, ctx, roof(
                2 * T * (d * qkv_d + model.n_heads * dh * d) / tp,
                wb + T * d * 4))
            if model.is_moe:
                de, E, k = model.moe_d_expert, model.moe_experts, \
                    model.moe_top_k
                trace.add("moe_ffn", phase, T, ctx, roof(
                    2 * 3 * T * k * d * de / tp,
                    3 * d * de * min(E, T * k) / tp * 2 + T * d * 4))
            else:
                mults = 3 if model.mlp_gated else 2
                trace.add("mlp", phase, T, ctx, roof(
                    2 * mults * T * d * model.d_ff / tp,
                    mults * d * model.d_ff / tp * 2 + T * d * 4))
            trace.add("norm", phase, T, ctx, roof(10 * T * d, 4 * T * d))
            trace.add("head", phase, T, ctx, roof(
                2 * T * d * model.vocab / tp,
                d * model.vocab / tp * 2 + T * d * 2))
            trace.add("embed", phase, T, ctx, roof(0, T * d * 4))
    for ctx in ctx_grid:
        for B in batch_grid:
            kv_b = ctx * B * model.kv_bytes_per_token / tp
            trace.add("attn_score", "decode", B, ctx, roof(
                4 * B * ctx * model.n_heads * dh / tp, kv_b))
        trace.add("attn_score", "prefill", ctx, ctx, roof(
            4 * ctx * (ctx / 2) * model.n_heads * dh / tp,
            ctx * model.kv_bytes_per_token / tp * 2))
    return trace


class _GridAdder:
    """Adapter routing ``add`` calls into one tp grid of an artifact."""

    def __init__(self, hwt: HardwareTrace, tp: int):
        self.hwt, self.tp = hwt, tp

    def add(self, op, phase, tokens, context, latency_s):
        self.hwt.add(op, phase, tokens, context, latency_s, tp=self.tp)


def synthetic_trace(spec: HardwareSpec, model: ModelSpec, *, tp=1,
                    device: Optional[str] = None,
                    token_grid: Sequence[int] = DEFAULT_TOKEN_GRID,
                    ctx_grid: Sequence[int] = DEFAULT_CTX_GRID) \
        -> HardwareTrace:
    """A full ``HardwareTrace`` artifact for a device that was never
    measured — the analytical model as a "synthetic trace" generator.

    ``tp`` may be a single tensor-parallel degree or a sequence of degrees
    (``tp=(1, 2)``); each degree gets its own grid in the one artifact,
    mirroring what a measured ``--tp 1,2`` profiler sweep emits.
    """
    tps = sorted({max(int(t), 1)
                  for t in (tp if isinstance(tp, (list, tuple)) else (tp,))})
    hwt = HardwareTrace(device=device or spec.name, model=model.name,
                        tp=tps[0], spec=spec,
                        interconnect=InterconnectSpec.from_hw(spec))
    for t in tps:
        add_synthetic_points(_GridAdder(hwt, t), spec, model, tp=t,
                             token_grid=token_grid, ctx_grid=ctx_grid)
    hwt.meta.update({"mode": "synthetic", "tp_degrees": tps,
                     "n_points": sum(len(hwt.grid(t)) for t in tps)})
    return hwt
