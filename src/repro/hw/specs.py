"""Named hardware-spec registry (jax-free).

The spec registry answers "what are this device's peak numbers" for the
synthetic-trace generator and the paged KV memory model.  The paper's
single-command integration flow is: pick/define a spec here, then either
run the profiler in measured mode on the real device or let
``repro.hw.synthetic`` derive a trace analytically (``python -m
repro.profiler profile --device <name> ...``).
"""
from __future__ import annotations

import time

from repro.core.config import (CPU_HOST, ENGINE_HW, PIM_DEVICE, RTX3090,
                               TPU_V5E, TPU_V6E, HardwareSpec)

_REGISTRY = {
    "rtx3090": RTX3090,
    "tpu-v5e": TPU_V5E,
    "tpu-v6e": TPU_V6E,
    "pim": PIM_DEVICE,
    "cpu-host": CPU_HOST,
    "cpu-engine": ENGINE_HW,
}


def get_hw(name: str) -> HardwareSpec:
    if name not in _REGISTRY:
        raise KeyError(f"unknown hardware {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def register_hw(spec: HardwareSpec) -> HardwareSpec:
    _REGISTRY[spec.name] = spec
    return spec


def known_hw() -> list:
    return sorted(_REGISTRY)


def measured_cpu_spec(flops: float = None) -> HardwareSpec:
    """Calibrate a spec for THIS host CPU with a quick matmul probe."""
    import numpy as np
    if flops is None:
        n = 768
        a = np.random.rand(n, n).astype(np.float32)
        b = np.random.rand(n, n).astype(np.float32)
        a @ b  # warm
        t0 = time.perf_counter()
        reps = 6
        for _ in range(reps):
            a @ b
        dt = (time.perf_counter() - t0) / reps
        flops = 2 * n ** 3 / dt
    return register_hw(HardwareSpec(
        name="cpu-measured", peak_flops=flops, hbm_bw=20e9,
        hbm_capacity=16e9, link_bw=8e9))
