"""Portable hardware-trace artifacts (the profiler <-> simulator contract).

A ``HardwareTrace`` is the versioned, JSON-serializable artifact the
profiler emits and the simulator's hardware registry consumes: one file per
device describing everything the perf model needs to price a cluster
instance on that hardware — the measured (or synthesized) operator-latency
table, the interconnect parameters, and optionally the full device spec for
off-grid analytical fallback.  Integrating a new accelerator is producing
one of these files (``python -m repro.profiler profile --device <name>
--out traces/<name>.json``) and referencing it from an ``InstanceCfg`` by
``hw_name`` (see ``docs/adding-hardware.md``).

JSON schema (version ``hwtrace/1``)::

    {
      "schema": "hwtrace/1",          # required; rejected on mismatch
      "device": "tpu-v6e",            # hardware name (registry key)
      "model": "llama3.1-8b-tiny",    # arch the op table was captured for
      "tp": 1,                        # tensor-parallel degree of the capture
      "interconnect": {               # network parameters of the device
        "link_bw": 1.0e11,            #   bytes/s per intra-instance link
        "host_bw": 1.6e10,            #   device<->host bytes/s
        "inter_instance_bw": 2.5e10,  #   bytes/s between instances
        "inter_instance_latency_s": 1.0e-5
      },
      "spec": {                       # optional full HardwareSpec: enables
        "name": "tpu-v6e",            #   analytical fallback for op/shape
        "peak_flops": 9.18e14,        #   combos outside the trace grid and
        "hbm_bw": 1.6e12, ...         #   the paged KV memory model
      },
      "points": [                     # the op -> latency table over a
        {"op": "iter",                #   (tokens x context) bucket grid;
         "phase": "prefill",          #   op kinds: iter | extend |
         "tokens": 64,                #   kv_export | attn_qkv | attn_score
         "context": 64,               #   | mlp | moe_ffn | norm | head |
         "latency_s": 0.0123}, ...    #   embed  (see repro.core.trace)
      ],
      "meta": {"mode": "runtime", "profile_wall_s": 12.3, ...}
    }

``points`` with op ``iter`` are whole-iteration measurements (highest
fidelity tier, preferred by ``PerfModel``); operator-class points compose an
iteration when no ``iter`` grid exists; anything else falls back to the
device spec's analytical roofline.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Dict, List, Optional

from repro.core.config import HardwareSpec
from repro.core.trace import OpPoint, Trace

SCHEMA_VERSION = "hwtrace/1"


@dataclasses.dataclass(frozen=True)
class InterconnectSpec:
    """Network parameters carried with a trace so heterogeneous cluster
    configs inherit realistic transfer pricing per device."""
    link_bw: float = 16e9                 # bytes/s per intra-instance link
    host_bw: float = 16e9                 # device <-> host bytes/s
    inter_instance_bw: float = 25e9       # bytes/s between instances
    inter_instance_latency_s: float = 10e-6

    @classmethod
    def from_hw(cls, spec: HardwareSpec) -> "InterconnectSpec":
        return cls(link_bw=spec.link_bw, host_bw=spec.host_bw)


@dataclasses.dataclass
class HardwareTrace:
    """One device's portable performance artifact (see module docstring)."""

    device: str
    model: str
    tp: int = 1
    points: List[OpPoint] = dataclasses.field(default_factory=list)
    interconnect: InterconnectSpec = \
        dataclasses.field(default_factory=InterconnectSpec)
    spec: Optional[HardwareSpec] = None
    meta: Dict = dataclasses.field(default_factory=dict)

    # ---- construction ----
    def add(self, op: str, phase: str, tokens: int, context: int,
            latency_s: float):
        self.points.append(OpPoint(op, phase, int(tokens), int(context),
                                   float(latency_s)))

    @classmethod
    def from_trace(cls, trace: Trace, *, device: Optional[str] = None,
                   spec: Optional[HardwareSpec] = None,
                   interconnect: Optional[InterconnectSpec] = None) \
            -> "HardwareTrace":
        """Wrap a raw perf-model ``Trace`` into a portable artifact."""
        if interconnect is None:
            interconnect = (InterconnectSpec.from_hw(spec) if spec
                            else InterconnectSpec())
        return cls(device=device or trace.hardware, model=trace.model,
                   tp=trace.tp, points=list(trace.points),
                   interconnect=interconnect, spec=spec,
                   meta=dict(trace.meta))

    def to_trace(self) -> Trace:
        """The ``repro.core.trace.Trace`` view the ``PerfModel`` consumes."""
        return Trace(model=self.model, hardware=self.device, tp=self.tp,
                     points=list(self.points), meta=dict(self.meta))

    # ---- validation ----
    def validate(self):
        if not self.device:
            raise ValueError("HardwareTrace.device must be non-empty")
        if self.tp < 1:
            raise ValueError(f"HardwareTrace.tp must be >= 1, got {self.tp}")
        for i, p in enumerate(self.points):
            if p.tokens < 1 or p.context < 0:
                raise ValueError(
                    f"point {i} ({p.op}/{p.phase}) has invalid shape "
                    f"tokens={p.tokens} context={p.context}")
            if not p.latency_s > 0:
                raise ValueError(
                    f"point {i} ({p.op}/{p.phase}) has non-positive "
                    f"latency {p.latency_s}")
        return self

    # ---- io ----
    def save(self, path: str) -> str:
        self.validate()
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        doc = {
            "schema": SCHEMA_VERSION,
            "device": self.device,
            "model": self.model,
            "tp": self.tp,
            "interconnect": dataclasses.asdict(self.interconnect),
            "spec": dataclasses.asdict(self.spec) if self.spec else None,
            "points": [dataclasses.asdict(p) for p in self.points],
            "meta": self.meta,
        }
        with open(path, "w") as f:
            json.dump(doc, f, indent=1)
        return path

    @classmethod
    def load(cls, path: str) -> "HardwareTrace":
        with open(path) as f:
            doc = json.load(f)
        schema = doc.get("schema")
        if schema != SCHEMA_VERSION:
            raise ValueError(
                f"{path}: unsupported hardware-trace schema {schema!r} "
                f"(this build reads {SCHEMA_VERSION!r})")
        for key in ("device", "points"):
            if key not in doc:
                raise ValueError(f"{path}: missing required key {key!r}")
        spec = HardwareSpec(**doc["spec"]) if doc.get("spec") else None
        try:
            points = [OpPoint(**p) for p in doc["points"]]
        except TypeError as e:
            raise ValueError(f"{path}: malformed trace point: {e}") from e
        hwt = cls(device=doc["device"], model=doc.get("model", "*"),
                  tp=doc.get("tp", 1), points=points,
                  interconnect=InterconnectSpec(**doc.get("interconnect",
                                                          {})),
                  spec=spec, meta=doc.get("meta", {}))
        return hwt.validate()
