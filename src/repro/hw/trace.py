"""Portable hardware-trace artifacts (the profiler <-> simulator contract).

A ``HardwareTrace`` is the versioned, JSON-serializable artifact the
profiler emits and the simulator's hardware registry consumes: one file per
device describing everything the perf model needs to price a cluster
instance on that hardware — the measured (or synthesized) operator-latency
tables, the interconnect parameters, and optionally the full device spec
for off-grid analytical fallback.  Integrating a new accelerator is
producing one of these files (``python -m repro.profiler profile --device
<name> --tp 1,2 --out traces/<name>.json``) and referencing it from an
``InstanceCfg`` by ``hw_name`` (see ``docs/adding-hardware.md``).

JSON schema (version ``hwtrace/3``)::

    {
      "schema": "hwtrace/3",          # required; hwtrace/1 and /2 still load
      "device": "tpu-v6e",            # hardware name (registry key)
      "model": "llama3.1-8b-tiny",    # arch the op tables were captured for
      "interconnect": {               # network parameters of the device
        "link_bw": 1.0e11,            #   bytes/s per intra-instance link
        "host_bw": 1.6e10,            #   device<->host bytes/s
        "inter_instance_bw": 2.5e10,  #   bytes/s between instances
        "inter_instance_latency_s": 1.0e-5
      },
      "spec": {                       # optional full HardwareSpec: enables
        "name": "tpu-v6e",            #   analytical fallback for op/shape
        "peak_flops": 9.18e14,        #   combos outside the trace grid and
        "hbm_bw": 1.6e12, ...         #   the paged KV memory model
      },
      "grids": [                      # one latency grid per tensor-parallel
        {"tp": 1,                     #   degree the device was profiled at;
         "points": [                  #   each grid is an op -> latency table
           {"op": "iter",             #   over (tokens x context) buckets;
            "phase": "prefill",       #   op kinds: iter | extend |
            "tokens": 64,             #   kv_export | attn_qkv | attn_score
            "context": 64,            #   | mlp | moe_ffn | norm | head |
            "latency_s": 0.0123},     #   embed  (see repro.core.trace)
           ...],
         "kernels": [                 #   optional kernel sub-buckets (new
           {"kernel": "attention",    #   in hwtrace/3): per-kernel latency
            "backend": "pallas",      #   rows keyed by the kernel backend
            "phase": "decode",        #   that produced them; kernel kinds:
            "tokens": 4,              #   attention | mlp | moe_gmm | head
            "context": 128,           #   (see repro.profiler.kernel_profiler)
            "latency_s": 3.1e-4},
           ...]},
        {"tp": 2, "points": [...]}
      ],
      "meta": {"mode": "runtime", "profile_wall_s": 12.3, ...}
    }

The legacy ``hwtrace/1`` layout (top-level ``"tp"`` + ``"points"`` instead
of ``"grids"``) loads transparently as a single-grid artifact, and
``hwtrace/2`` (no ``"kernels"`` lists) loads as an artifact with op-level
grids only; ``save`` always emits ``hwtrace/3``, so loading an older file
and re-saving it migrates in place.

In memory, kernel rows are ordinary ``OpPoint``s whose op string is
``kern:<backend>:<kernel>`` (e.g. ``kern:pallas:attention``) — the
``Trace`` interpolation machinery is op-string-agnostic, so kernel grids
get indexing/memoization for free and ``PerfModel`` prices them as a
fidelity tier between whole-iteration and op-class points.

``points`` with op ``iter`` are whole-iteration measurements (highest
fidelity tier, preferred by ``PerfModel``); operator-class points compose an
iteration when no ``iter`` grid exists; anything else falls back to the
device spec's analytical roofline.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Dict, List, Optional

from repro.core.config import HardwareSpec
from repro.core.trace import OpPoint, Trace

SCHEMA_VERSION = "hwtrace/3"
#: schema versions this build can read (save always emits SCHEMA_VERSION)
READABLE_SCHEMAS = ("hwtrace/1", "hwtrace/2", "hwtrace/3")

#: prefix marking an in-memory kernel-granular point (hwtrace/3 sub-buckets)
KERN_PREFIX = "kern:"
#: kernel kinds the kernel profiler sweeps (one engine forward pass is
#: L x attention + L x (mlp | moe_gmm) + head under either backend)
KERNEL_KINDS = ("attention", "mlp", "moe_gmm", "head")


def kern_op(backend: str, kernel: str) -> str:
    """Op string for a kernel sub-bucket row (``kern:<backend>:<kernel>``)."""
    return f"{KERN_PREFIX}{backend}:{kernel}"


def split_kern_op(op: str) -> Optional[tuple]:
    """``(backend, kernel)`` when ``op`` is a kernel row, else None."""
    if not op.startswith(KERN_PREFIX):
        return None
    backend, _, kernel = op[len(KERN_PREFIX):].partition(":")
    return (backend, kernel)


@dataclasses.dataclass(frozen=True)
class InterconnectSpec:
    """Network parameters carried with a trace.  These are what
    ``NetworkModel`` derives inter-instance ``Link``s from (min-bw rule
    across the two endpoints), so heterogeneous cluster configs inherit
    realistic, per-device-pair transfer pricing."""
    link_bw: float = 16e9                 # bytes/s per intra-instance link
    host_bw: float = 16e9                 # device <-> host bytes/s
    inter_instance_bw: float = 25e9       # bytes/s between instances
    inter_instance_latency_s: float = 10e-6

    @classmethod
    def from_hw(cls, spec: HardwareSpec) -> "InterconnectSpec":
        return cls(link_bw=spec.link_bw, host_bw=spec.host_bw,
                   inter_instance_bw=spec.inter_instance_bw,
                   inter_instance_latency_s=spec.inter_instance_latency_s)


@dataclasses.dataclass
class HardwareTrace:
    """One device's portable performance artifact (see module docstring).

    ``tp``/``points`` are the *base* grid (lowest profiled tensor-parallel
    degree — tp=1 for every artifact the profiler emits today);
    ``tp_grids`` holds additional grids captured at other tp degrees.
    Single-tp consumers (``to_trace``, ``add``, round-trip pricing) keep
    working unchanged on the base grid.
    """

    device: str
    model: str
    tp: int = 1
    points: List[OpPoint] = dataclasses.field(default_factory=list)
    interconnect: InterconnectSpec = \
        dataclasses.field(default_factory=InterconnectSpec)
    spec: Optional[HardwareSpec] = None
    meta: Dict = dataclasses.field(default_factory=dict)
    # extra tensor-parallel grids: tp degree -> points (never contains
    # ``self.tp``; use ``grid``/``tp_degrees`` for uniform access)
    tp_grids: Dict[int, List[OpPoint]] = dataclasses.field(
        default_factory=dict)

    # ---- construction ----
    def add(self, op: str, phase: str, tokens: int, context: int,
            latency_s: float, tp: Optional[int] = None):
        """Append one point to the base grid (or the ``tp`` grid)."""
        pt = OpPoint(op, phase, int(tokens), int(context), float(latency_s))
        if tp is None or tp == self.tp:
            self.points.append(pt)
        else:
            self.tp_grids.setdefault(int(tp), []).append(pt)

    def add_grid(self, tp: int, points: List[OpPoint]):
        """Attach a whole latency grid captured at tensor-parallel ``tp``."""
        tp = int(tp)
        if tp == self.tp:
            raise ValueError(
                f"{self.device}: grid for tp={tp} already exists (base)")
        if tp in self.tp_grids:
            raise ValueError(
                f"{self.device}: grid for tp={tp} already exists")
        self.tp_grids[tp] = list(points)

    def merge(self, other: "HardwareTrace") -> "HardwareTrace":
        """Absorb ``other``'s grids (same device+model) into this artifact —
        how the profiler CLI folds a ``--tp 1,2`` sweep into one file."""
        if (other.device, other.model) != (self.device, self.model):
            raise ValueError(
                f"cannot merge trace for ({other.device}, {other.model}) "
                f"into ({self.device}, {self.model})")
        for tp in other.tp_degrees():
            self.add_grid(tp, other.grid(tp))
        return self

    @classmethod
    def from_trace(cls, trace: Trace, *, device: Optional[str] = None,
                   spec: Optional[HardwareSpec] = None,
                   interconnect: Optional[InterconnectSpec] = None) \
            -> "HardwareTrace":
        """Wrap a raw perf-model ``Trace`` into a portable artifact."""
        if interconnect is None:
            interconnect = (InterconnectSpec.from_hw(spec) if spec
                            else InterconnectSpec())
        return cls(device=device or trace.hardware, model=trace.model,
                   tp=trace.tp, points=list(trace.points),
                   interconnect=interconnect, spec=spec,
                   meta=dict(trace.meta))

    # ---- grid access ----
    def tp_degrees(self) -> List[int]:
        """Every tensor-parallel degree this artifact has a grid for."""
        return sorted({self.tp, *self.tp_grids})

    def grid(self, tp: int) -> Optional[List[OpPoint]]:
        """The latency grid at tensor-parallel ``tp`` (None if absent)."""
        if tp == self.tp:
            return self.points
        return self.tp_grids.get(tp)

    def at_tp(self, tp: int) -> Optional["HardwareTrace"]:
        """A single-grid view of this artifact at tensor-parallel ``tp``
        (``self`` when ``tp`` is the base degree; None when no grid
        matches).  This is how ``HardwareRegistry.resolve`` hands the perf
        model the grid matching the instance's parallelism instead of
        rescaling analytically."""
        if tp == self.tp:
            return self
        pts = self.tp_grids.get(tp)
        if pts is None:
            return None
        # defensive copies (like every other construction path): mutating
        # a resolved view must never reach back into the cached artifact
        return HardwareTrace(device=self.device, model=self.model, tp=tp,
                             points=list(pts),
                             interconnect=self.interconnect,
                             spec=self.spec, meta=dict(self.meta))

    def to_trace(self, tp: Optional[int] = None) -> Trace:
        """The ``repro.core.trace.Trace`` view the ``PerfModel`` consumes
        (base grid by default; pass ``tp`` for another profiled degree)."""
        tp = self.tp if tp is None else tp
        pts = self.grid(tp)
        if pts is None:
            raise KeyError(
                f"{self.device}: no grid at tp={tp} "
                f"(have {self.tp_degrees()})")
        return Trace(model=self.model, hardware=self.device, tp=tp,
                     points=list(pts), meta=dict(self.meta))

    def shared_trace(self, tp: Optional[int] = None) -> Trace:
        """Cached ``to_trace`` view: every caller at the same ``tp`` gets
        the SAME ``Trace`` object, so a fleet of identical instances
        shares one interpolation index and one exact-key memo instead of
        re-deriving them per instance.  Treat the result as read-only
        (``Trace.add`` on it would leak into every sharer)."""
        cache = self.__dict__.setdefault("_shared_traces", {})
        key = self.tp if tp is None else tp
        t = cache.get(key)
        if t is None:
            t = cache[key] = self.to_trace(tp)
        return t

    # ---- validation ----
    def validate(self):
        if not self.device:
            raise ValueError("HardwareTrace.device must be non-empty")
        if self.tp < 1:
            raise ValueError(f"HardwareTrace.tp must be >= 1, got {self.tp}")
        if self.tp in self.tp_grids:
            raise ValueError(
                f"tp_grids must not duplicate the base tp={self.tp}")
        for tp in self.tp_degrees():
            if tp < 1:
                raise ValueError(f"grid tp must be >= 1, got {tp}")
            for i, p in enumerate(self.grid(tp)):
                if p.tokens < 1 or p.context < 0:
                    raise ValueError(
                        f"tp={tp} point {i} ({p.op}/{p.phase}) has invalid "
                        f"shape tokens={p.tokens} context={p.context}")
                if not p.latency_s > 0:
                    raise ValueError(
                        f"tp={tp} point {i} ({p.op}/{p.phase}) has "
                        f"non-positive latency {p.latency_s}")
        return self

    # ---- kernel sub-buckets ----
    def kernel_backends(self, tp: Optional[int] = None) -> List[str]:
        """Kernel backends the grid at ``tp`` carries sub-bucket rows for."""
        pts = self.grid(self.tp if tp is None else tp) or []
        seen = []
        for p in pts:
            bk = split_kern_op(p.op)
            if bk is not None and bk[0] not in seen:
                seen.append(bk[0])
        return seen

    # ---- io ----
    @staticmethod
    def _grid_doc(points: List[OpPoint]) -> Dict:
        """Serialize one grid: op-class rows under ``points``, kernel rows
        (op ``kern:<backend>:<kernel>``) under ``kernels``."""
        doc: Dict = {"points": []}
        kerns = []
        for p in points:
            bk = split_kern_op(p.op)
            if bk is None:
                doc["points"].append(dataclasses.asdict(p))
            else:
                kerns.append({"kernel": bk[1], "backend": bk[0],
                              "phase": p.phase, "tokens": p.tokens,
                              "context": p.context, "latency_s": p.latency_s})
        if kerns:
            doc["kernels"] = kerns
        return doc

    def save(self, path: str) -> str:
        self.validate()
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        doc = {
            "schema": SCHEMA_VERSION,
            "device": self.device,
            "model": self.model,
            "interconnect": dataclasses.asdict(self.interconnect),
            "spec": dataclasses.asdict(self.spec) if self.spec else None,
            "grids": [{"tp": tp, **self._grid_doc(self.grid(tp))}
                      for tp in self.tp_degrees()],
            "meta": self.meta,
        }
        with open(path, "w") as f:
            json.dump(doc, f, indent=1)
        return path

    @classmethod
    def load(cls, path: str) -> "HardwareTrace":
        with open(path) as f:
            doc = json.load(f)
        schema = doc.get("schema")
        if schema not in READABLE_SCHEMAS:
            raise ValueError(
                f"{path}: unsupported hardware-trace schema {schema!r} "
                f"(this build reads {READABLE_SCHEMAS!r})")
        if "device" not in doc:
            raise ValueError(f"{path}: missing required key 'device'")

        def parse_points(raw):
            try:
                return [OpPoint(**p) for p in raw]
            except TypeError as e:
                raise ValueError(
                    f"{path}: malformed trace point: {e}") from e

        def parse_kernels(raw):
            # hwtrace/3 kernel sub-buckets -> kern:<backend>:<kernel> points
            # (hwtrace/2 grids simply have no "kernels" key: op-level only)
            try:
                return [OpPoint(kern_op(k["backend"], k["kernel"]),
                                k["phase"], k["tokens"], k["context"],
                                k["latency_s"]) for k in raw]
            except (KeyError, TypeError) as e:
                raise ValueError(
                    f"{path}: malformed kernel point: {e}") from e

        if schema == "hwtrace/1":
            # legacy single-grid layout: top-level tp + points
            if "points" not in doc:
                raise ValueError(f"{path}: missing required key 'points'")
            grids = {int(doc.get("tp", 1)): parse_points(doc["points"])}
        else:
            raw_grids = doc.get("grids")
            if not raw_grids:
                raise ValueError(f"{path}: missing required key 'grids'")
            grids = {}
            for g in raw_grids:
                tp = int(g.get("tp", 1))
                if tp in grids:
                    raise ValueError(f"{path}: duplicate grid for tp={tp}")
                grids[tp] = parse_points(g.get("points", [])) \
                    + parse_kernels(g.get("kernels", []))
        base = min(grids)
        spec = HardwareSpec(**doc["spec"]) if doc.get("spec") else None
        hwt = cls(device=doc["device"], model=doc.get("model", "*"),
                  tp=base, points=grids.pop(base), tp_grids=grids,
                  interconnect=InterconnectSpec(**doc.get("interconnect",
                                                          {})),
                  spec=spec, meta=doc.get("meta", {}))
        return hwt.validate()
