"""Hardware registry: device name -> ``HardwareTrace`` -> ``PerfModel``.

The registry is how a simulated cluster mixes accelerators: every
``InstanceCfg`` may name its hardware (``hw_name="tpu-v6e"``) and the
``ServingRuntime`` resolves that name here at instance-build time.
Resolution order:

1. a registered/loaded measured trace for the device whose ``model``
   matches the instance's model AND that carries a grid at the instance's
   tensor-parallel degree (trace latencies are (model, hardware, tp)
   specific — a table measured for another model or parallelism does not
   transfer);
2. otherwise a synthetic trace generated from the device's
   ``HardwareSpec`` (the spec embedded in a model-mismatched trace, or the
   named spec registry) — the paper's instant analytical integration.

Loaded traces double as spec carriers: when a trace embeds a
``HardwareSpec``, the runtime swaps it into the instance config so the
memory model and off-grid analytical fallback price with the same device
the trace was captured on.
"""
from __future__ import annotations

import os
from typing import Dict, List, Optional, Tuple

from repro.core.config import ModelSpec
from repro.hw.specs import get_hw, known_hw
from repro.hw.synthetic import synthetic_trace
from repro.hw.trace import HardwareTrace


class HardwareRegistry:
    """Named ``HardwareTrace`` artifacts plus synthetic fallback."""

    def __init__(self):
        self._traces: Dict[str, HardwareTrace] = {}
        # synthetic traces are derived per (device, model, tp) and cached
        self._synth: Dict[Tuple[str, str, int], HardwareTrace] = {}

    # ---- population ----
    def register(self, hwt: HardwareTrace) -> HardwareTrace:
        hwt.validate()
        self._traces[hwt.device] = hwt
        return hwt

    def load_file(self, path: str) -> HardwareTrace:
        return self.register(HardwareTrace.load(path))

    def load_dir(self, path: str) -> List[str]:
        """Load every hardware-trace artifact in ``path``; returns the
        device names registered.  JSON files that are not artifacts at all
        (no ``schema`` key — e.g. raw operator ``Trace`` dumps from the
        ``ops`` subcommand, which share the default ``traces/`` directory)
        are skipped with a warning; a *versioned* artifact this build
        cannot read still raises."""
        import json
        import warnings
        names = []
        for fn in sorted(os.listdir(path)):
            if not fn.endswith(".json"):
                continue
            fp = os.path.join(path, fn)
            with open(fp) as f:
                try:
                    doc = json.load(f)
                except ValueError:
                    warnings.warn(f"{fp}: not JSON — skipped")
                    continue
            if not isinstance(doc, dict) or "schema" not in doc:
                warnings.warn(
                    f"{fp}: not a HardwareTrace artifact (no 'schema' "
                    f"key) — skipped")
                continue
            schema = str(doc["schema"])
            if schema.startswith(("moetrace/", "spectrace/")):
                # expert-routing / acceptance artifacts share traces/ by
                # design (profile --experts/--spec emits them next to the
                # hw trace): silently not ours, exactly as their own
                # registries silently skip hwtrace files
                continue
            if not schema.startswith("hwtrace/"):
                warnings.warn(
                    f"{fp}: not a HardwareTrace artifact (schema "
                    f"{schema!r}) — skipped")
                continue
            names.append(self.load_file(fp).device)
        return names

    # ---- lookup ----
    def names(self) -> List[str]:
        return sorted(self._traces)

    def get(self, device: str) -> HardwareTrace:
        if device not in self._traces:
            raise KeyError(
                f"no hardware trace registered for {device!r}; loaded: "
                f"{self.names() or '(none)'} — profile one with "
                f"`python -m repro.profiler profile --device {device} "
                f"--out traces/{device}.json` or use a known spec name "
                f"({known_hw()})")
        return self._traces[device]

    def resolve(self, device: str, model: ModelSpec,
                tp: int = 1) -> HardwareTrace:
        """The trace that prices ``model`` on ``device`` at tensor-parallel
        degree ``tp`` (see module doc).  A registered trace must match the
        model AND carry a grid profiled at ``tp`` (multi-grid artifacts
        hold one grid per swept degree) — trace latencies embed the
        parallelism they were captured at; anything else gets a synthetic
        grid at the right tp."""
        tp = max(tp, 1)
        hwt = self._traces.get(device)
        if hwt is not None and hwt.model in ("*", model.name):
            view = hwt.at_tp(tp)
            if view is not None:
                return view
        key = (device, model.name, tp)
        if key not in self._synth:
            spec = hwt.spec if (hwt is not None and hwt.spec) else None
            if spec is None:
                try:
                    spec = get_hw(device)
                except KeyError:
                    raise KeyError(
                        f"cannot resolve hardware {device!r} for model "
                        f"{model.name!r}: no matching trace loaded "
                        f"(have {self.names() or '(none)'}) and no spec "
                        f"named {device!r} ({known_hw()})") from None
            self._synth[key] = synthetic_trace(spec, model, tp=tp,
                                               device=device)
        return self._synth[key]


#: Process-wide default registry; ``ServingRuntime`` uses it when no
#: explicit registry is passed, so ``load_traces("traces/")`` once makes
#: every profiled device available to every cluster config by ``hw_name``.
default_registry = HardwareRegistry()


def register_trace(hwt: HardwareTrace) -> HardwareTrace:
    return default_registry.register(hwt)


def load_traces(path: str) -> List[str]:
    """Load a trace file or directory into the default registry."""
    if os.path.isdir(path):
        return default_registry.load_dir(path)
    return [default_registry.load_file(path).device]
