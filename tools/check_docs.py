#!/usr/bin/env python
"""Docs health checker: links resolve, walkthroughs execute.

Two checks keep the documentation from silently rotting:

1. **Links** — every relative markdown link in README.md and docs/ must
   point at a file that exists (anchors are stripped; external URLs are
   ignored).
2. **Commands** — every fenced code block tagged ``bash docs-test`` in
   docs/ is executed verbatim from the repository root (with
   ``PYTHONPATH=src``); a non-zero exit fails the check.  This is how the
   adding-hardware walkthrough stays executable as written.

Usage:
  python tools/check_docs.py             # links + commands (CI docs job)
  python tools/check_docs.py --links-only
"""
from __future__ import annotations

import argparse
import os
import re
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# [text](target) — excluding images is unnecessary; image targets must
# exist too.  Inline code spans are stripped first so `foo[i](x)` in code
# doesn't parse as a link.
_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
_CODE_SPAN = re.compile(r"`[^`]*`")
_FENCE = re.compile(r"^```(.*)$")


def md_files():
    out = [os.path.join(REPO, "README.md")]
    docs = os.path.join(REPO, "docs")
    for root, _, files in os.walk(docs):
        out.extend(os.path.join(root, f) for f in files
                   if f.endswith(".md"))
    return [p for p in out if os.path.exists(p)]


def _strip_fences(text: str) -> str:
    """Remove fenced code blocks (their contents aren't prose links)."""
    out, in_fence = [], False
    for line in text.splitlines():
        if _FENCE.match(line.strip()):
            in_fence = not in_fence
            continue
        if not in_fence:
            out.append(line)
    return "\n".join(out)


def check_links() -> list:
    errors = []
    for path in md_files():
        with open(path) as f:
            text = _strip_fences(f.read())
        text = _CODE_SPAN.sub("", text)
        for m in _LINK.finditer(text):
            target = m.group(1)
            if target.startswith(("http://", "https://", "mailto:")):
                continue
            target = target.split("#", 1)[0]
            if not target:
                continue            # pure in-page anchor
            resolved = os.path.normpath(
                os.path.join(os.path.dirname(path), target))
            if not os.path.exists(resolved):
                rel = os.path.relpath(path, REPO)
                errors.append(f"{rel}: broken link -> {m.group(1)}")
    return errors


def docs_test_blocks():
    """(file, index, script) for every ``bash docs-test`` fenced block."""
    blocks = []
    for path in md_files():
        with open(path) as f:
            lines = f.read().splitlines()
        script, in_block, idx = [], False, 0
        for line in lines:
            m = _FENCE.match(line.strip())
            if m and not in_block:
                info = m.group(1).strip()
                if "docs-test" in info.split():
                    in_block = True
                    script = []
                continue
            if m and in_block:
                idx += 1
                blocks.append((os.path.relpath(path, REPO), idx,
                               "\n".join(script)))
                in_block = False
                continue
            if in_block:
                script.append(line)
    return blocks


def run_blocks() -> list:
    errors = []
    env = dict(os.environ, PYTHONPATH="src" + (
        os.pathsep + os.environ["PYTHONPATH"]
        if os.environ.get("PYTHONPATH") else ""))
    for path, idx, script in docs_test_blocks():
        label = f"{path} block {idx}"
        print(f"== running {label} ==", flush=True)
        proc = subprocess.run(["bash", "-euo", "pipefail", "-c", script],
                              cwd=REPO, env=env)
        if proc.returncode != 0:
            errors.append(f"{label}: exit {proc.returncode}")
    return errors


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--links-only", action="store_true")
    args = ap.parse_args()

    errors = check_links()
    for e in errors:
        print(f"LINK: {e}", file=sys.stderr)
    n_blocks = 0
    if not args.links_only:
        n_blocks = len(docs_test_blocks())
        errors += run_blocks()
    if errors:
        print(f"\n{len(errors)} docs problem(s)", file=sys.stderr)
        sys.exit(1)
    print(f"docs ok: {len(md_files())} files linked cleanly"
          + ("" if args.links_only else
             f", {n_blocks} docs-test block(s) executed"))


if __name__ == "__main__":
    main()
