"""Unit tests for the unified scheduler's KV ledger + preemption safety."""
from repro.core.config import (HardwareSpec, InstanceCfg, ModelSpec,
                               SchedulerCfg)
from repro.core.memory import MemoryModel
from repro.core.request import DECODING, QUEUED, SimRequest
from repro.runtime.scheduler import BatchScheduler

MODEL = ModelSpec(name="m", n_layers=2, d_model=64, n_heads=2, n_kv_heads=1,
                  d_head=16, d_ff=128, vocab=100, param_bytes=1e6)
# pool of ~30 KV blocks so decode growth hits memory pressure
HW = HardwareSpec(name="tiny", peak_flops=1e12, hbm_bw=1e11,
                  hbm_capacity=(1e6 + 30 * 16 * MODEL.kv_bytes_per_token)
                  / 0.9 + 1, link_bw=1e9)


def _sched(**kw):
    cfg = InstanceCfg(name="i", hw=HW, model=MODEL,
                      scheduler=SchedulerCfg(max_batch_size=8,
                                             max_batch_tokens=4096, **kw))
    mem = MemoryModel(cfg)
    return BatchScheduler(cfg.scheduler, mem), mem


def _drive(sched, reqs, iters=2000):
    """Run the scheduler loop, applying results the way the instance does."""
    for r in reqs:
        sched.enqueue(r)
    for _ in range(iters):
        work = sched.next_batch()
        if not work:
            if any(r.state == QUEUED for r in sched.waiting):
                continue
            break
        for w in work:
            # a preempted (QUEUED) request's work must never execute —
            # its backend state was already released
            assert w.request.state != QUEUED, \
                f"preempted request {w.request.req_id} scheduled"
            if w.phase == "prefill":
                w.request.prefill_done_tokens += w.tokens
                if w.request.remaining_prefill == 0:
                    w.request.state = DECODING
                    w.request.generated = max(w.request.generated, 1)
            else:
                w.request.generated += 1
                if w.request.generated >= w.request.output_len:
                    sched.complete(w.request)


def test_preempted_request_never_in_scheduled_batch():
    sched, mem = _sched()
    # each request alone fits the pool (100+250 tokens = 22 blocks of 30)
    # but both at peak do not (44 > 30): pressure hits mid-decode while
    # both are scheduled, forcing preemption against in-flight work
    reqs = [SimRequest(req_id=i, arrival=0.0,
                       prompt_tokens=list(range(100)), output_len=250)
            for i in range(2)]
    _drive(sched, reqs)
    assert sched.n_preemptions > 0          # the scenario exercised pressure
    assert all(r.generated >= r.output_len for r in reqs)


def test_block_ledger_frees_exactly_what_was_reserved():
    sched, mem = _sched()
    reqs = [SimRequest(req_id=i, arrival=0.0,
                       prompt_tokens=list(range(120 + 16 * i)),
                       output_len=200) for i in range(4)]
    _drive(sched, reqs)
    for r in list(sched.running):
        sched.complete(r)
    sched.requeue_all()
    # exact accounting: the pool returns to its full size, never above
    assert mem.free_blocks == mem.total_blocks
    assert not sched._reserved


def test_over_free_impossible_on_completion_after_long_decode():
    """The old code freed context+output//4 (context grows with decode),
    silently over-freeing; the ledger frees the recorded reservation."""
    sched, mem = _sched()
    req = SimRequest(req_id=0, arrival=0.0, prompt_tokens=list(range(64)),
                     output_len=400)
    _drive(sched, [req])
    assert req.generated >= req.output_len
    assert mem.free_blocks == mem.total_blocks
    assert 0 <= mem.free_blocks <= mem.total_blocks


def test_ledger_exposes_per_request_occupancy_and_peak():
    """The ledger is observable: ``occupancy()`` snapshots per-request
    blocks mid-flight and ``kv_blocks_peak`` records each request's high
    watermark (survives completion — the Metrics view)."""
    sched, mem = _sched()
    req = SimRequest(req_id=7, arrival=0.0, prompt_tokens=list(range(100)),
                     output_len=40)
    sched.enqueue(req)
    work = sched.next_batch()
    assert work and work[0].request is req
    occ = sched.occupancy()
    assert set(occ) == {7}
    assert occ[7] == sched.reserved_blocks(req) > 0
    assert occ[7] == mem.total_blocks - mem.free_blocks
    assert req.kv_blocks_peak == occ[7]
    occ[7] = 10_000                    # a snapshot copy, not the ledger
    assert sched.reserved_blocks(req) != 10_000
    _drive(sched, [req])
    # decode growth past the admission reservation raised the peak, and
    # the final ledger is empty while the peak survives for metrics
    assert req.kv_blocks_peak >= mem.blocks_for(100 + 40)
    assert sched.occupancy() == {}
    assert mem.free_blocks == mem.total_blocks
