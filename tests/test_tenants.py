"""Multi-tenant SLO classes: priority scheduling, weighted-share
starvation guard, per-tenant metric rollup, elastic scale-in drain, and
sim/real parity of tenant-tagged workloads.

The contended-queue tests use a hand-built iter-level trace with fixed
step latencies so service order is the only degree of freedom — what the
priority policy and the share guard decide is then directly observable in
the prefill-decision sequence.
"""
import copy

import numpy as np
import pytest

from repro.configs import get_config
from repro.core import (ClusterCfg, InstanceCfg, RouterCfg, SchedulerCfg,
                        TenantClass, TraceRegistry)
from repro.core.cluster import Cluster
from repro.core.config import TPU_V5E
from repro.core.metrics import slo_met, tenant_rollup
from repro.core.request import FINISHED, SimRequest
from repro.profiler import model_spec_from_arch
from repro.core.trace import Trace
from repro.runtime.scheduler import WaitQueue
from repro.workload.sharegpt import Request

ARCH = "llama3.1-8b-tiny"

GOLD = TenantClass("gold", priority=10, slo_ttft_ms=500.0,
                   slo_tpot_ms=50.0, weight=3.0)
FREE = TenantClass("free", priority=0, slo_ttft_ms=5000.0,
                   slo_tpot_ms=500.0, weight=1.0)


def _slow_trace(decode_s=0.005, prefill_s=0.01):
    """Iter-level trace with constant step latencies: slow enough that a
    queue actually forms, flat so timing never reorders decisions."""
    t = Trace(model="m", hardware="h", tp=1)
    for b in (1, 2, 4, 8, 16):
        for ctx in (16, 256, 4096):
            t.add("iter", "decode", b, ctx, decode_s)
    for tok in (16, 64, 256, 1024):
        t.add("iter", "prefill", tok, tok, prefill_s)
    return t


def _registry():
    r = TraceRegistry()
    r.register(ARCH, _slow_trace())
    return r


def _inst(name="i0", **kw):
    spec = model_spec_from_arch(get_config(ARCH))
    base = dict(hw=TPU_V5E, model=spec, n_devices=1, trace_name=ARCH)
    base.update(kw)
    return InstanceCfg(name=name, **base)


def _req(i, tc: TenantClass, arrival=0.0, plen=32, out=8):
    rng = np.random.default_rng(100 + i)
    return Request(req_id=i, arrival=arrival,
                   prompt_tokens=rng.integers(0, 1000, plen).tolist(),
                   output_len=out, tenant=tc.name, priority=tc.priority,
                   weight=tc.weight, slo_ttft_ms=tc.slo_ttft_ms,
                   slo_tpot_ms=tc.slo_tpot_ms)


def _prefill_order(cluster, name="i0"):
    """req_id per first-prefill decision, in service order."""
    seen = []
    for it in cluster.instances[name].decisions:
        for rid, phase, _ in it:
            if phase == "prefill" and rid not in seen:
                seen.append(rid)
    return seen


def _serve(reqs, scheduler, n_inst=1, router="round_robin"):
    ccfg = ClusterCfg(tuple(_inst(f"i{k}", scheduler=scheduler)
                            for k in range(n_inst)),
                      router=RouterCfg(router))
    cl = Cluster(ccfg, traces=_registry())
    cl.submit_workload([copy.deepcopy(r) for r in reqs])
    m = cl.run()
    return m, cl


# --------------------------------------------------------------------------
# policy plumbing
# --------------------------------------------------------------------------

def test_unknown_policy_rejected_loudly():
    with pytest.raises(ValueError, match="bogus"):
        WaitQueue(policy="bogus")
    # the full construction path rejects it too (it used to silently
    # fall back to arrival order)
    with pytest.raises(ValueError, match="wrong"):
        Cluster(ClusterCfg((_inst(
            scheduler=SchedulerCfg(policy="wrong")),)),
            traces=_registry())
    # the valid set is spelled out for the user
    with pytest.raises(ValueError, match="priority"):
        WaitQueue(policy="priorty")


def test_priority_orders_contended_queue():
    """policy="priority" must actually key on request priority (it used
    to silently degrade to arrival order).  Request 0 is admitted the
    instant it arrives; the rest are queued by then and must drain
    highest-priority-first, arrival order breaking ties."""
    prios = [0, 3, 1, 5, 1, 4]
    classes = {p: TenantClass(f"t{p}", priority=p) for p in set(prios)}
    reqs = [_req(i, classes[p]) for i, p in enumerate(prios)]
    sched = SchedulerCfg(max_batch_size=1, max_batch_tokens=1 << 16,
                         policy="priority", chunked_prefill=False,
                         prefill_exclusive=True)
    m, cl = _serve(reqs, sched)
    assert m["finished"] == len(reqs)
    order = _prefill_order(cl)
    assert order[0] == 0
    tail = [prios[rid] for rid in order[1:]]
    assert tail == sorted(tail, reverse=True)
    # arrival order breaks the priority tie (req 2 before req 4)
    assert order.index(2) < order.index(4)


def test_fcfs_unaffected_by_priority_tags():
    """Tenant tags must not leak into non-priority policies."""
    reqs = [_req(0, FREE), _req(1, GOLD), _req(2, GOLD), _req(3, FREE)]
    sched = SchedulerCfg(max_batch_size=1, max_batch_tokens=1 << 16,
                         policy="fcfs", chunked_prefill=False,
                         prefill_exclusive=True)
    _, cl = _serve(reqs, sched)
    assert _prefill_order(cl) == [0, 1, 2, 3]


# --------------------------------------------------------------------------
# weighted-share starvation guard
# --------------------------------------------------------------------------

def _guard_scenario(guard_tokens):
    """8 gold requests + 2 free riders, one slot, equal weights (so the
    guard's anti-starvation bound is isolated from weighted entitlement):
    where do the free tenant's requests land in the service order?"""
    gold = TenantClass("gold", priority=10, weight=1.0)
    free = TenantClass("free", priority=0, weight=1.0)
    reqs = [_req(i, gold) for i in range(8)] \
        + [_req(8, free), _req(9, free)]
    sched = SchedulerCfg(max_batch_size=1, max_batch_tokens=1 << 16,
                         policy="priority", chunked_prefill=False,
                         prefill_exclusive=True,
                         share_guard_tokens=guard_tokens)
    m, cl = _serve(reqs, sched)
    assert m["finished"] == 10
    order = _prefill_order(cl)
    return [order.index(rid) for rid in (8, 9)], cl


def test_priority_starves_without_guard():
    """Baseline semantics: pure priority serves every gold request before
    any free one (the behavior the guard exists to bound)."""
    free_pos, _ = _guard_scenario(0)
    assert free_pos == [8, 9]


def test_share_guard_bounds_starvation():
    """With a guard, the free tenant is admitted once its weight-
    normalized service lags gold's by the guard — interleaved with gold,
    not parked behind all of it."""
    free_pos, cl = _guard_scenario(64)
    assert max(free_pos) < 8, f"free tenant still starved: {free_pos}"
    assert free_pos[0] >= 1   # gold's head start is respected
    # the service split the guard balanced is reported per tenant
    svc = cl.instances["i0"].stats()["tenant_service"]
    assert svc["gold"] > 0 and svc["free"] > 0


def test_share_guard_respects_weights():
    """A heavier tenant is entitled to proportionally more service before
    the guard calls it starved: raising the free tenant's weight pulls
    its admission earlier."""
    light = TenantClass("free", priority=0, weight=0.25)
    heavy = TenantClass("free", priority=0, weight=8.0)

    def pos(free_cls):
        reqs = [_req(i, GOLD) for i in range(8)] + [_req(8, free_cls)]
        sched = SchedulerCfg(max_batch_size=1, max_batch_tokens=1 << 16,
                             policy="priority", chunked_prefill=False,
                             prefill_exclusive=True,
                             share_guard_tokens=64)
        _, cl = _serve(reqs, sched)
        return _prefill_order(cl).index(8)

    assert pos(heavy) <= pos(light)


# --------------------------------------------------------------------------
# per-tenant rollup math (hand-computed pin)
# --------------------------------------------------------------------------

def _finished(req_id, tenant, arrival, first, finish, out_len, tc):
    r = SimRequest(req_id=req_id, arrival=arrival,
                   prompt_tokens=[1, 2, 3], output_len=out_len,
                   tenant=tenant, priority=tc.priority, weight=tc.weight,
                   slo_ttft_ms=tc.slo_ttft_ms, slo_tpot_ms=tc.slo_tpot_ms)
    r.state = FINISHED
    r.t_first_token = first
    r.t_finish = finish
    r.generated = out_len
    return r


def test_tenant_rollup_hand_computed():
    gold = TenantClass("gold", priority=10, slo_ttft_ms=150.0,
                       slo_tpot_ms=100.0)
    free = TenantClass("free", priority=0, slo_ttft_ms=1000.0,
                       slo_tpot_ms=1000.0)
    reqs = [
        # ttft 0.10s <= 0.15s, tpot (0.3-0.1)/2 = 0.10s <= 0.10s -> MET
        _finished(0, "gold", 0.00, 0.10, 0.30, 3, gold),
        # ttft 0.45s > 0.15s -> MISSED
        _finished(1, "gold", 0.05, 0.50, 0.60, 2, gold),
        # ttft 0.20s <= 1.0s, tpot (0.9-0.2)/4 = 0.175s <= 1.0s -> MET
        _finished(2, "free", 0.10, 0.30, 1.00, 5, free),
        # unfinished: counted submitted, excluded from percentiles
        SimRequest(req_id=3, arrival=0.2, prompt_tokens=[1],
                   output_len=4, tenant="free"),
    ]
    assert [slo_met(r) for r in reqs[:3]] == [True, False, True]
    roll = tenant_rollup(reqs)
    assert sorted(roll) == ["free", "gold"]
    g, f = roll["gold"], roll["free"]
    assert (g["submitted"], g["finished"]) == (2, 2)
    assert (f["submitted"], f["finished"]) == (2, 1)
    # span = last finish (1.0) - first arrival (0.0) over ALL finished
    span = 1.0
    assert g["slo_attainment"] == 0.5 and g["slo_met"] == 1
    assert g["goodput_tok_s"] == pytest.approx(3 / span)
    assert g["goodput_req_s"] == pytest.approx(1 / span)
    assert f["slo_attainment"] == 1.0
    assert f["goodput_tok_s"] == pytest.approx(5 / span)
    # ttft percentiles over [0.10, 0.45]: linear interpolation
    assert g["ttft_p50_s"] == pytest.approx(0.275)
    assert g["ttft_p95_s"] == pytest.approx(0.10 + 0.95 * 0.35)
    assert g["ttft_p99_s"] == pytest.approx(0.10 + 0.99 * 0.35)
    # free tenant: single sample, all percentiles collapse onto it
    assert f["ttft_p50_s"] == f["ttft_p99_s"] == pytest.approx(0.20)
    assert f["tpot_p50_s"] == pytest.approx(0.175)
    assert g["priority"] == 10 and g["slo_ttft_ms"] == 150.0


def test_tenant_rollup_empty_and_single():
    assert tenant_rollup([]) == {}
    lone = SimRequest(req_id=0, arrival=0.0, prompt_tokens=[1],
                      output_len=2, tenant="only")
    assert tenant_rollup([lone]) == {}          # nothing finished yet
    lone.state = FINISHED
    lone.t_first_token, lone.t_finish, lone.generated = 0.1, 0.2, 2
    roll = tenant_rollup([lone])
    assert roll["only"]["slo_attainment"] == 1.0


# --------------------------------------------------------------------------
# elastic scale-in: drain semantics
# --------------------------------------------------------------------------

def test_drain_requeues_in_flight_exactly_once():
    """Scale-in mid-decode: the drained instance's in-flight requests
    restart on the survivor exactly once, queued ones just move, and the
    retired instance stays visible in metrics."""
    reqs = [_req(i, GOLD, arrival=0.0, plen=32, out=40) for i in range(4)]
    sched = SchedulerCfg(max_batch_size=2, max_batch_tokens=1 << 16,
                         policy="priority")
    ccfg = ClusterCfg((_inst("i0", scheduler=sched),
                       _inst("i1", scheduler=sched)),
                      router=RouterCfg("round_robin"))
    cl = Cluster(ccfg, traces=_registry())
    cl.submit_workload([copy.deepcopy(r) for r in reqs])
    # mid-decode for everything on i0 (prefill 0.01s + 40 x 0.005s decode)
    cl.remove_instance(0.05, "i0")
    m = cl.run()
    assert m["finished"] == 4
    assert sorted(cl.instances) == ["i1"]
    assert sorted(cl.retired) == ["i0"]
    by_id = {r.req_id: r for r in cl._all_requests}
    # round-robin: even ids landed on i0 and restarted exactly once
    assert [by_id[i].n_restarts for i in range(4)] == [1, 0, 1, 0]
    assert all(r.instance == "i1" for r in cl._all_requests)
    stats = m["instances"]
    assert stats["i0"]["retired"] is True
    assert "retired" not in stats["i1"]
    assert stats["i0"]["iterations"] > 0    # it did serve before draining
    # the drained instance never iterates again
    assert not cl.retired["i0"].alive


def test_remove_last_instance_then_scale_out_recovers():
    """Orphans of a full-fleet drain are re-dispatched to an instance
    added later (router dispatch at requeue targets live instances)."""
    reqs = [_req(0, FREE, out=40)]
    sched = SchedulerCfg(max_batch_size=2, policy="priority")
    ccfg = ClusterCfg((_inst("i0", scheduler=sched),))
    cl = Cluster(ccfg, traces=_registry())
    cl.submit_workload(copy.deepcopy(reqs))
    cl.add_instance(0.04, _inst("i1", scheduler=sched))
    cl.remove_instance(0.05, "i0")
    m = cl.run()
    assert m["finished"] == 1
    assert cl._all_requests[0].instance == "i1"


# --------------------------------------------------------------------------
# sim/real parity with tenant-tagged requests
# --------------------------------------------------------------------------

def test_tenant_parity_sim_vs_real_engine():
    """Tenant tags ride through both backends: identical decision
    sequences under policy="priority" (arrivals at t=0 so order cannot
    depend on the time axis), and both report the same per-tenant
    submitted/finished rollup."""
    from repro.serve import DriverCfg, ServeDriver, ServingEngine
    from repro.serve.driver import engine_instance_cfg

    cfg = get_config(ARCH)
    rng = np.random.default_rng(7)
    reqs = []
    for i in range(6):
        tc = GOLD if i % 2 else FREE
        reqs.append(Request(
            req_id=i, arrival=0.0,
            prompt_tokens=rng.integers(0, cfg.vocab, 24 + 8 * i).tolist(),
            output_len=4 + i, tenant=tc.name, priority=tc.priority,
            weight=tc.weight, slo_ttft_ms=tc.slo_ttft_ms,
            slo_tpot_ms=tc.slo_tpot_ms))
    sched = SchedulerCfg(max_batch_size=2, max_batch_tokens=1 << 16,
                         policy="priority", chunked_prefill=False,
                         prefill_exclusive=True)

    eng = ServingEngine(cfg, max_batch=2, max_len=256, name="e0")
    drv = ServeDriver([eng], DriverCfg(scheduler=sched))
    real = drv.run(reqs, warmup=False)
    real_dec = {n: list(i.decisions)
                for n, i in drv.runtime.instances.items()}

    icfg = engine_instance_cfg(eng, sched)
    sim_cl = Cluster(ClusterCfg(instances=(icfg,),
                                router=RouterCfg("round_robin")))
    sim_cl.submit_workload(reqs)
    sim = sim_cl.run()
    sim_dec = {n: list(i.decisions) for n, i in sim_cl.instances.items()}

    assert real_dec == sim_dec
    assert real["finished"] == sim["finished"] == 6
    for m in (real, sim):
        assert sorted(m["tenants"]) == ["free", "gold"]
    for t in ("free", "gold"):
        assert real["tenants"][t]["submitted"] \
            == sim["tenants"][t]["submitted"] == 3
        assert real["tenants"][t]["finished"] \
            == sim["tenants"][t]["finished"] == 3
    # priority actually ordered the real engine's queue: after req 0
    # (admitted on arrival) every gold request prefills before any
    # remaining free one
    order = []
    for it in real_dec["e0"]:
        for rid, phase, _ in it:
            if phase == "prefill" and rid not in order:
                order.append(rid)
    tail_prio = [reqs[rid].priority for rid in order[1:]]
    assert tail_prio == sorted(tail_prio, reverse=True)
