"""Docs health (fast tier): intra-repo links resolve and the acceptance
profile command emits a loadable artifact.  The full docs-test command
blocks run in the CI docs job (``python tools/check_docs.py``)."""
import os
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(REPO, "tools"))


def test_markdown_links_resolve():
    from check_docs import check_links, md_files
    assert len(md_files()) >= 6        # README + docs tree
    assert check_links() == []


def test_docs_have_executable_blocks():
    from check_docs import docs_test_blocks
    blocks = docs_test_blocks()
    # the adding-hardware walkthrough must stay executable as written
    assert any("adding-hardware" in path for path, _, _ in blocks)
    assert len(blocks) >= 3


def test_profile_cli_emits_loadable_artifact(tmp_path):
    """The acceptance command (synthetic mode for speed): profile a device
    by name, load the artifact through the hw registry."""
    out = str(tmp_path / "tpu-v6e.json")
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    proc = subprocess.run(
        [sys.executable, "-m", "repro.profiler", "profile",
         "--device", "tpu-v6e", "--arch", "llama3.1-8b-tiny",
         "--out", out],
        cwd=REPO, env=env, capture_output=True, text=True, timeout=240)
    assert proc.returncode == 0, proc.stderr
    from repro.hw import HardwareRegistry
    reg = HardwareRegistry()
    hwt = reg.load_file(out)
    assert hwt.device == "tpu-v6e"
    assert reg.get("tpu-v6e") is hwt
    assert len(hwt.points) > 50
