"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, asserting output shapes and no NaNs. The FULL configs are exercised only
via the dry-run (ShapeDtypeStruct, no allocation)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED, get_config
from repro.models import Model

B, S = 2, 32


def _inputs(cfg, key):
    if cfg.embed_inputs:
        toks = jax.random.randint(key, (B, S), 0, cfg.vocab)
    else:
        toks = jax.random.normal(key, (B, S, cfg.d_model),
                                 dtype=jnp.bfloat16)
    if cfg.n_codebooks:
        labels = jax.random.randint(key, (B, S, cfg.n_codebooks), 0, cfg.vocab)
    else:
        labels = jax.random.randint(key, (B, S), 0, cfg.vocab)
    return {"inputs": toks, "labels": labels}


@pytest.mark.parametrize("arch", ASSIGNED)
def test_forward_and_loss(arch):
    cfg = get_config(arch + "-tiny")
    model = Model(cfg, remat=False)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    batch = _inputs(cfg, jax.random.PRNGKey(1))
    logits, aux = jax.jit(model.forward)(params, batch["inputs"])
    if cfg.n_codebooks:
        assert logits.shape == (B, S, cfg.n_codebooks, cfg.vocab)
    else:
        assert logits.shape == (B, S, cfg.vocab)
    assert not np.any(np.isnan(np.asarray(logits, np.float32)))
    loss, metrics = jax.jit(model.loss_fn)(params, batch)
    assert np.isfinite(float(loss)), (arch, float(loss))


@pytest.mark.parametrize("arch", ASSIGNED)
def test_train_step_decreases_nothing_nan(arch):
    cfg = get_config(arch + "-tiny")
    model = Model(cfg, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    batch = _inputs(cfg, jax.random.PRNGKey(1))

    @jax.jit
    def step(p):
        (l, m), g = jax.value_and_grad(model.loss_fn, has_aux=True)(p, batch)
        p2 = jax.tree_util.tree_map(lambda a, b: a - 1e-3 * b, p, g)
        return l, p2

    l0, params = step(params)
    l1, _ = step(params)
    assert np.isfinite(float(l0)) and np.isfinite(float(l1))


@pytest.mark.parametrize("arch", ASSIGNED)
def test_prefill_decode_consistency(arch):
    """decode(prefill(x[:-1]), x[-1]) must match forward(x) logits."""
    cfg = get_config(arch + "-tiny")
    model = Model(cfg, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    key = jax.random.PRNGKey(2)
    if cfg.embed_inputs:
        toks = jax.random.randint(key, (B, S), 0, cfg.vocab)
        head, last = toks[:, :-1], toks[:, -1:]
    else:
        toks = jax.random.normal(key, (B, S, cfg.d_model), dtype=jnp.bfloat16)
        head, last = toks[:, :-1], toks[:, -1:]

    full_logits, _ = jax.jit(model.forward)(params, toks)
    logits_pre, cache = jax.jit(model.prefill)(params, head)
    # prefill last-token logits == forward logits at position S-2
    np.testing.assert_allclose(
        np.asarray(logits_pre[:, 0], np.float32),
        np.asarray(full_logits[:, S - 2], np.float32), rtol=0.15, atol=0.15)

    # grow KV caches to S slots for the decode step (no-op for SSM states)
    def grow(a):
        if a.ndim == 5 and a.shape[2] == S - 1:  # (L,B,S-1,KV,dh)
            pad = [(0, 0)] * a.ndim
            pad[2] = (0, 1)
            return jnp.pad(a, pad)
        return a
    cache = jax.tree_util.tree_map(grow, cache)
    logits_dec, _ = jax.jit(model.decode)(params, cache, last)
    np.testing.assert_allclose(
        np.asarray(logits_dec[:, 0], np.float32),
        np.asarray(full_logits[:, -1], np.float32), rtol=0.15, atol=0.15)
