"""Hypothesis property tests on system invariants."""
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="hypothesis not installed; property tests skipped")
import hypothesis.strategies as st          # noqa: E402
from hypothesis import given, settings      # noqa: E402

from repro.core.config import (InstanceCfg, ModelSpec, PrefixCacheCfg,
                               SchedulerCfg, TPU_V5E)
from repro.core.engine import EventQueue
from repro.core.memory import MemoryModel
from repro.core.prefix_cache import RadixPrefixCache
from repro.core.trace import Trace
from repro.roofline.hlo_analyzer import _type_bytes_and_dims
from repro.train.optimizer import AdamW, global_norm
from repro.workload.acceptance import AcceptanceConfig, synthesize_acceptance
from repro.workload.expert_skew import SkewConfig, synthesize_routing

MODEL = ModelSpec(name="m", n_layers=4, d_model=256, n_heads=4,
                  n_kv_heads=2, d_head=64, d_ff=512, vocab=1000)


def _mem():
    return MemoryModel(InstanceCfg(name="i", hw=TPU_V5E, model=MODEL))


# --- event queue: executes in nondecreasing time order ---------------------
@given(st.lists(st.floats(min_value=0, max_value=1e6,
                          allow_nan=False), min_size=1, max_size=200))
@settings(max_examples=50, deadline=None)
def test_event_queue_order(delays):
    q = EventQueue()
    fired = []
    for d in delays:
        q.schedule(d, lambda d=d: fired.append(q.now))
    q.run()
    assert fired == sorted(fired)
    assert len(fired) == len(delays)


# --- memory model: allocate/free conservation -------------------------------
@given(st.lists(st.integers(min_value=1, max_value=5000), min_size=1,
                max_size=80))
@settings(max_examples=50, deadline=None)
def test_memory_blocks_conserved(token_counts):
    mem = _mem()
    total = mem.total_blocks
    allocated = []
    for n in token_counts:
        if mem.allocate(n):
            allocated.append(n)
        assert 0 <= mem.free_blocks <= total
    for n in allocated:
        mem.free(n)
    assert mem.free_blocks == total


# --- radix prefix cache: match is always a true prefix, block-aligned -------
@given(st.lists(st.lists(st.integers(0, 50), min_size=0, max_size=120),
                min_size=1, max_size=30))
@settings(max_examples=30, deadline=None)
def test_radix_match_is_prefix(prompts):
    mem = _mem()
    cache = RadixPrefixCache(PrefixCacheCfg(enabled=True, block_tokens=8),
                             mem)
    seen = []
    for t, p in enumerate(prompts):
        m = cache.match(p, float(t))
        assert m.tokens % 8 == 0
        assert m.tokens <= len(p)
        if m.tokens:
            # the matched region was previously inserted as a prefix
            assert any(list(q[:m.tokens]) == list(p[:m.tokens])
                       for q in seen)
        cache.insert(p, float(t))
        seen.append(list(p))
        # borrowed device blocks never exceed pool capacity
        assert cache.n_device_blocks <= cache.capacity_blocks + 1
        assert mem.free_blocks >= 0


# --- trace interpolation: within grid bounds, positive, monotone-ish --------
@given(st.integers(1, 512), st.integers(1, 4096))
@settings(max_examples=50, deadline=None)
def test_trace_interpolation_bounds(tokens, ctx):
    tr = Trace(model="m", hardware="h", tp=1)
    for t in (1, 16, 64, 256):
        for c in (16, 256, 2048):
            tr.add("iter", "decode", t, c, 1e-4 * t + 1e-7 * c)
    v = tr.interpolate("iter", "decode", tokens, ctx)
    assert v is not None and v > 0
    lo = min(p.latency_s for p in tr.points)
    hi = max(p.latency_s for p in tr.points)
    assert lo * 0.5 <= v <= hi * 2.0   # IDW stays within the hull


# --- optimizer: step decreases a convex quadratic ---------------------------
def test_adamw_minimizes_quadratic():
    import jax
    import jax.numpy as jnp
    opt = AdamW(lr=0.1, weight_decay=0.0)
    params = {"w": jnp.asarray([5.0, -3.0])}
    state = opt.init(params)
    def loss(p):
        return jnp.sum(p["w"] ** 2)
    for _ in range(120):
        g = jax.grad(loss)(params)
        params, state, _ = opt.update(g, state, params)
    assert float(loss(params)) < 1e-2


# --- expert-skew generators: conservation, monotone zipf, determinism -------
@given(st.sampled_from(["uniform", "zipf", "correlated"]),
       st.integers(2, 16), st.integers(16, 128), st.integers(0, 2 ** 16))
@settings(max_examples=40, deadline=None)
def test_skew_tokens_conserved_across_experts(kind, n_experts, period, seed):
    top_k = min(2, n_experts)
    t = synthesize_routing(2, n_experts, top_k,
                           SkewConfig(kind=kind, period=period, seed=seed))
    for l in range(t.n_layers):
        counts = t.counts_for(l, np.arange(period))
        # every position routes to exactly top_k *distinct* experts
        assert counts.sum() == period * top_k
        assert np.all(np.diff(np.sort(t.layers[l], axis=1), axis=1) > 0)
    # positions wrap mod period: a double pass doubles every count
    double = t.counts_for(0, np.arange(2 * period))
    assert np.array_equal(double, 2 * t.counts_for(0, np.arange(period)))


@given(st.floats(0.0, 1.2), st.floats(0.5, 1.5), st.integers(0, 2 ** 16))
@settings(max_examples=30, deadline=None, derandomize=True)
def test_zipf_exponent_monotonically_increases_imbalance(a, delta, seed):
    def imb(zipf_a):
        return synthesize_routing(
            1, 8, 2, SkewConfig(kind="zipf", zipf_a=zipf_a, period=512,
                                seed=seed)).static_imbalance()
    # same seed -> same permutation + same gumbel noise; each position's
    # membership shifts toward hotter ranks as the exponent grows, but
    # the max-over-experts is NOT strictly monotone for tiny exponent
    # steps (a rank-2 count can shrink faster than rank-1 grows), hence
    # the delta >= 0.5 floor in the strategy — an empirical guarantee,
    # stress-tested over ~10^4 (a, delta, seed) combos, not a theorem
    assert imb(a + delta) >= imb(a) - 1e-9


@given(st.sampled_from(["uniform", "zipf", "correlated"]),
       st.integers(0, 2 ** 16))
@settings(max_examples=30, deadline=None)
def test_skew_fixed_seed_identical_trace_bytes(kind, seed):
    cfg = SkewConfig(kind=kind, zipf_a=1.3, period=64, seed=seed)
    a = synthesize_routing(2, 8, 2, cfg, model="m")
    b = synthesize_routing(2, 8, 2, cfg, model="m")
    assert a.to_json() == b.to_json()


# --- acceptance generators: bounds, determinism, monotone alpha -------------
@given(st.floats(0.0, 1.0), st.integers(1, 8), st.integers(1, 64),
       st.floats(0.0, 0.3), st.integers(0, 2 ** 16))
@settings(max_examples=40, deadline=None)
def test_acceptance_draws_bounded(alpha, k, period, jitter, seed):
    t = synthesize_acceptance(AcceptanceConfig(alpha=alpha, k=k,
                                               period=period,
                                               jitter=jitter, seed=seed))
    draws = [t.accepted_for(p, s) for p in (0, 1, period, 3 * period + 1)
             for s in range(12)]
    assert all(0 <= a <= k for a in draws)
    assert 0.0 <= t.mean_accepted() <= k
    # rows are genuine distributions over 0..k
    h = np.asarray(t.hist)
    assert h.shape == (period, k + 1)
    np.testing.assert_allclose(h.sum(axis=1), 1.0, atol=1e-9)


@given(st.sampled_from([0.0, 0.05, 0.15]), st.integers(0, 2 ** 16))
@settings(max_examples=30, deadline=None)
def test_acceptance_fixed_seed_identical_trace_bytes(jitter, seed):
    cfg = AcceptanceConfig(alpha=0.6, k=4, period=32, jitter=jitter,
                           seed=seed)
    a = synthesize_acceptance(cfg, model="m")
    b = synthesize_acceptance(cfg, model="m")
    assert a.to_json() == b.to_json()


@given(st.floats(0.0, 0.9), st.floats(0.05, 1.0), st.integers(1, 8),
       st.floats(0.0, 0.2), st.integers(0, 2 ** 16))
@settings(max_examples=40, deadline=None)
def test_acceptance_alpha_monotone_mean_accepted(a, delta, k, jitter, seed):
    def mean(alpha):
        return synthesize_acceptance(AcceptanceConfig(
            alpha=alpha, k=k, period=32, jitter=jitter,
            seed=seed)).mean_accepted()
    # same seed -> same per-bucket noise; each bucket's truncated-
    # geometric mean is nondecreasing in its (clipped) alpha, so the
    # bucket average is too
    assert mean(min(a + delta, 1.0)) >= mean(a) - 1e-9


# --- HLO shape parsing ------------------------------------------------------
@given(st.sampled_from(["f32", "bf16", "s32", "pred"]),
       st.lists(st.integers(1, 64), min_size=0, max_size=4))
@settings(max_examples=50, deadline=None)
def test_hlo_shape_bytes(dtype, dims):
    sizes = {"f32": 4, "bf16": 2, "s32": 4, "pred": 1}
    s = f"{dtype}[{','.join(map(str, dims))}]"
    total, parsed = _type_bytes_and_dims(s)
    want = sizes[dtype]
    for d in dims:
        want *= d
    assert total == want


# --- multi-tenant workload generation ---------------------------------------
def _tenant_cfg(shares, n, seed, arrival="poisson"):
    from repro.core.config import TenantClass
    from repro.workload.tenants import TenantSpec, TenantWorkloadCfg
    specs = tuple(
        TenantSpec(TenantClass(f"t{i}", priority=i, weight=float(i + 1)),
                   rate_share=s, mean_prompt=20, max_prompt=40,
                   mean_output=10, max_output=20)
        for i, s in enumerate(shares))
    return TenantWorkloadCfg(tenants=specs, n_requests=n, rate=50.0,
                             seed=seed, arrival=arrival, vocab=500)


@given(st.integers(0, 500),
       st.lists(st.floats(0.01, 10.0), min_size=1, max_size=6))
@settings(max_examples=40, deadline=None)
def test_apportion_exact_and_proportional(n, shares):
    from repro.workload.tenants import apportion
    counts = apportion(n, shares)
    assert sum(counts) == n
    assert all(c >= 0 for c in counts)
    total = sum(shares)
    # largest-remainder never strays more than 1 from the exact quota
    for c, s in zip(counts, shares):
        assert abs(c - n * s / total) < 1.0 + 1e-9


@given(st.integers(0, 2 ** 16),
       st.lists(st.floats(0.1, 5.0), min_size=1, max_size=4),
       st.integers(10, 120))
@settings(max_examples=20, deadline=None)
def test_tenant_mix_matches_weights(seed, shares, n):
    """Per-tenant request counts ARE the largest-remainder apportionment
    of the shares (the mix converges to the weights by construction)."""
    from repro.workload.tenants import apportion, generate_tenants
    reqs = generate_tenants(_tenant_cfg(shares, n, seed))
    got = {}
    for r in reqs:
        got[r.tenant] = got.get(r.tenant, 0) + 1
    want = apportion(n, shares)
    for i, w in enumerate(want):
        assert got.get(f"t{i}", 0) == w


@given(st.integers(0, 2 ** 16),
       st.sampled_from(["poisson", "gamma", "diurnal"]))
@settings(max_examples=20, deadline=None)
def test_tenant_merge_sorted_sequential_and_tagged(seed, arrival):
    """The merged stream is globally arrival-sorted with sequential ids,
    and every request carries its tenant class verbatim."""
    from repro.workload.tenants import generate_tenants
    reqs = generate_tenants(_tenant_cfg([2.0, 1.0], 60, seed, arrival))
    assert [r.req_id for r in reqs] == list(range(60))
    arr = [r.arrival for r in reqs]
    assert arr == sorted(arr)
    for r in reqs:
        i = int(r.tenant[1:])
        assert (r.priority, r.weight) == (i, float(i + 1))
        assert 1 <= len(r.prompt_tokens) <= 40
        assert 1 <= r.output_len <= 20


@given(st.integers(0, 2 ** 16),
       st.sampled_from(["poisson", "gamma", "diurnal"]))
@settings(max_examples=15, deadline=None)
def test_tenant_workload_fixed_seed_byte_identical(seed, arrival):
    from repro.workload.tenants import generate_tenants, workload_bytes
    a = generate_tenants(_tenant_cfg([1.0, 3.0, 0.5], 40, seed, arrival))
    b = generate_tenants(_tenant_cfg([1.0, 3.0, 0.5], 40, seed, arrival))
    assert workload_bytes(a) == workload_bytes(b)
    # and a different seed genuinely moves the draws
    c = generate_tenants(_tenant_cfg([1.0, 3.0, 0.5], 40, seed + 1,
                                     arrival))
    assert workload_bytes(a) != workload_bytes(c)
