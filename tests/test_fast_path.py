"""Fast-path parity suite: the simulator's indexed trace grids, iteration
memo and decode fast-forward must be decision- and metric-IDENTICAL to the
stepped exact mode (``fast_path=False``).

Every parity test runs the same workload both ways and compares the full
observable surface bit-for-bit: aggregate metrics, per-instance stats
(including the kv_watermark timeline), the scheduling-decision sequences,
phase accounting, and every request's token timestamps.  Equality is
exact (``==`` on floats) — the fast path is engineered to run the same
IEEE operation chains as the stepped path, not to approximate them.
"""
import copy
import dataclasses

import numpy as np
import pytest

from repro.configs import get_config
from repro.core import (ClusterCfg, InstanceCfg, PrefixCacheCfg, RouterCfg,
                        SchedulerCfg, SpecCfg, TraceRegistry)
from repro.core.cluster import Cluster
from repro.core.config import TPU_V5E, HardwareSpec, ModelSpec
from repro.core.perfmodel import BatchItem, PerfModel
from repro.core.trace import Trace
from repro.obs import EventRecorder
from repro.profiler import model_spec_from_arch, profile_arch
from repro.workload import ShareGPTConfig, generate
from repro.workload.sharegpt import Request

ARCH = "llama3.1-8b-tiny"
MOE_ARCH = "phimini-moe-tiny"


@pytest.fixture(scope="module")
def tiny_trace():
    """Analytical op-level trace for the tiny dense arch (covers every
    decode op, so ``decode_window`` takes the vectorized branch)."""
    return profile_arch(ARCH, hardware="tpu-v5e", mode="analytical", tp=1)


def _registry(trace):
    r = TraceRegistry()
    r.register(ARCH, trace)
    return r


def _inst(name="i0", **kw):
    spec = model_spec_from_arch(get_config(ARCH))
    base = dict(hw=TPU_V5E, model=spec, n_devices=1,
                scheduler=SchedulerCfg(max_batch_size=8,
                                       max_batch_tokens=2048),
                trace_name=ARCH)
    base.update(kw)
    return InstanceCfg(name=name, **base)


def _pair(ccfg, reqs, registry=None, setup=None):
    """Run fast and exact modes on one workload and assert the complete
    observable surface is identical; returns both metric dicts + clusters
    so tests can add scenario-specific assertions.  ``setup(cluster)``
    runs before workload submission — the hook scale/drain/autoscale
    scenarios use to schedule their elastic events on both runs.

    Both runs carry an event recorder, so parity covers the traced
    surface too: fast-forward must synthesize the same per-lane event
    streams as exact stepping (and the attribution rollup derived from
    them lands in the compared metrics)."""
    def one(fast):
        rec = EventRecorder()
        cl = Cluster(ccfg, traces=registry, fast_path=fast, recorder=rec)
        if setup is not None:
            setup(cl)
        cl.submit_workload([copy.deepcopy(r) for r in reqs])
        return cl.run(), cl, rec

    m_f, cl_f, rec_f = one(True)
    m_e, cl_e, rec_e = one(False)
    st_f, st_e = rec_f.streams(), rec_e.streams()
    assert set(st_f) == set(st_e)
    for lane in st_f:
        assert st_f[lane] == st_e[lane], f"event stream diverges: {lane}"
    sf, se = dict(m_f), dict(m_e)
    for k in ("sim_wall_s", "sim_events"):
        sf.pop(k), se.pop(k)
    i_f, i_e = sf.pop("instances"), se.pop("instances")
    assert sf == se
    assert set(i_f) == set(i_e)
    for n in i_f:
        assert i_f[n] == i_e[n], f"instance stats diverge: {n}"
    assert set(cl_f.retired) == set(cl_e.retired)
    live_and_retired = {**cl_f.retired, **cl_f.instances}
    ref_pool = {**cl_e.retired, **cl_e.instances}
    for n, inst in live_and_retired.items():
        ref = ref_pool[n]
        assert list(inst.decisions) == list(ref.decisions), n
        assert inst.phase_time == ref.phase_time, n
        assert inst.phase_tokens == ref.phase_tokens, n
        assert inst.phase_iters == ref.phase_iters, n
    rf = {r.req_id: r for r in cl_f._all_requests}
    re_ = {r.req_id: r for r in cl_e._all_requests}
    assert set(rf) == set(re_)
    for rid in rf:
        assert rf[rid].token_times == re_[rid].token_times, rid
        assert rf[rid].t_first_token == re_[rid].t_first_token, rid
        assert rf[rid].t_finish == re_[rid].t_finish, rid
    return m_f, cl_f, m_e, cl_e


# --------------------------------------------------------------------------
# end-to-end parity
# --------------------------------------------------------------------------

def test_parity_decode_heavy_single_instance(tiny_trace):
    """Offline burst of long decodes — the fast-forward's best case: the
    bulk events must collapse the event count while reproducing the
    stepped timeline exactly."""
    rng = np.random.default_rng(0)
    reqs = [Request(req_id=i, arrival=0.001 * i,
                    prompt_tokens=rng.integers(0, 1000, 24).tolist(),
                    output_len=120) for i in range(12)]
    m_f, _, m_e, _ = _pair(ClusterCfg((_inst(),)), reqs,
                           _registry(tiny_trace))
    assert m_f["finished"] == 12
    # the whole point: far fewer events for the identical result
    assert m_f["sim_events"] * 4 < m_e["sim_events"]


def test_parity_fleet_staggered_arrivals(tiny_trace):
    """Multi-instance least-loaded routing with arrivals interleaving
    decode — windows are horizon-capped by every arrival barrier."""
    reqs = generate(ShareGPTConfig(n_requests=40, rate=200.0, vocab=1000,
                                   mean_prompt=40, max_prompt=80,
                                   mean_output=60, max_output=120, seed=4))
    ccfg = ClusterCfg(tuple(_inst(f"i{k}") for k in range(3)),
                      router=RouterCfg("least_loaded"))
    m_f, cl_f, _, _ = _pair(ccfg, reqs, _registry(tiny_trace))
    assert m_f["finished"] == 40
    # the router spread work: parity must hold across instances
    assert sum(1 for i in cl_f.instances.values() if i.iterations) >= 2


def test_parity_under_memory_pressure_analytical():
    """KV pressure forces mid-decode preemption; the fast path must stop
    windows exactly where the ledger would have preempted (and this config
    has no trace, covering the per-step analytical fallback)."""
    model = ModelSpec(name="m", n_layers=2, d_model=64, n_heads=2,
                      n_kv_heads=1, d_head=16, d_ff=128, vocab=100,
                      param_bytes=1e6)
    hw = HardwareSpec(name="tiny", peak_flops=1e12, hbm_bw=1e11,
                      hbm_capacity=(1e6 + 30 * 16 * model.kv_bytes_per_token)
                      / 0.9 + 1, link_bw=1e9)
    icfg = InstanceCfg(name="i0", hw=hw, model=model,
                       scheduler=SchedulerCfg(max_batch_size=8,
                                              max_batch_tokens=4096))
    reqs = [Request(req_id=i, arrival=0.0,
                    prompt_tokens=list(range(100)), output_len=250)
            for i in range(2)]
    m_f, _, _, cl_e = _pair(ClusterCfg((icfg,)), reqs)
    assert m_f["finished"] == 2
    assert cl_e.instances["i0"].scheduler.n_preemptions > 0


def test_parity_with_prefix_cache(tiny_trace):
    """Instance-scope radix cache: fetch charges land on step 1 of a
    window and cache hits/pins replay identically."""
    reqs = generate(ShareGPTConfig(n_requests=30, rate=100.0, vocab=1000,
                                   share_fraction=0.8, n_conversations=3,
                                   mean_prompt=50, max_prompt=100,
                                   mean_output=40, max_output=80, seed=11))
    ccfg = ClusterCfg((_inst(prefix_cache=PrefixCacheCfg(enabled=True)),))
    m_f, _, _, _ = _pair(ccfg, reqs, _registry(tiny_trace))
    assert m_f["instances"]["i0"]["prefix_cache"]["hits"] > 0


def test_parity_moe_statistical_router():
    """An MoE instance whose trace does not cover ``moe_ffn`` prices
    through the statistical router's RNG: the backend must refuse to
    memoize or fast-forward, and fast_path=True then IS the exact path."""
    spec = model_spec_from_arch(get_config(MOE_ARCH))
    icfg = InstanceCfg(name="i0", hw=TPU_V5E, model=spec,
                       scheduler=SchedulerCfg(max_batch_size=8,
                                              max_batch_tokens=2048))
    reqs = generate(ShareGPTConfig(n_requests=8, rate=100.0, vocab=1000,
                                   mean_prompt=30, max_prompt=60,
                                   mean_output=20, max_output=40, seed=5))
    m_f, cl_f, _, _ = _pair(ClusterCfg((icfg,)), reqs)
    assert m_f["finished"] == 8
    assert not cl_f.instances["i0"].backend.supports_fast_forward


# --------------------------------------------------------------------------
# elastic scaling parity: scale-out, drain, and the autoscaler loop are
# explicit events (fast-forward barriers by construction) — the fast path
# must reproduce the stepped timeline through every fleet change
# --------------------------------------------------------------------------

def _slow_iter_trace(decode_s=0.005, prefill_s=0.01):
    """Constant-latency iter-level trace: slow enough for queues to build
    (so the autoscaler has something to react to) while decode windows
    stay perfectly vectorizable."""
    t = Trace(model="m", hardware="h", tp=1)
    for b in (1, 2, 4, 8, 16):
        for ctx in (16, 256, 4096):
            t.add("iter", "decode", b, ctx, decode_s)
    for tok in (16, 64, 256, 1024):
        t.add("iter", "prefill", tok, tok, prefill_s)
    return t


def test_parity_scale_out_mid_run():
    """add_instance lands mid-decode: windows must stop at the barrier,
    the router must see the newcomer identically in both modes."""
    rng = np.random.default_rng(2)
    # arrivals straddle the scale event: routing decisions after t=0.05
    # see (and load-balance onto) the new instance
    reqs = [Request(req_id=i, arrival=0.02 * i,
                    prompt_tokens=rng.integers(0, 1000, 24).tolist(),
                    output_len=100) for i in range(10)]
    ccfg = ClusterCfg((_inst("i0"),), router=RouterCfg("least_loaded"))
    m_f, cl_f, _, _ = _pair(
        ccfg, reqs, _registry(_slow_iter_trace()),
        setup=lambda cl: cl.add_instance(0.05, _inst("grown")))
    assert m_f["finished"] == 10
    assert cl_f.instances["grown"].iterations > 0


def test_parity_scale_in_drain_mid_run():
    """remove_instance drains mid-decode: orphans restart on survivors at
    the identical simulated time in both modes, and the retired
    instance's frozen stats stay parity-comparable."""
    reqs = [Request(req_id=i, arrival=0.0,
                    prompt_tokens=list(range(32)), output_len=60)
            for i in range(4)]
    ccfg = ClusterCfg((_inst("i0"), _inst("i1")),
                      router=RouterCfg("round_robin"))
    m_f, cl_f, _, _ = _pair(
        ccfg, reqs, _registry(_slow_iter_trace()),
        setup=lambda cl: cl.remove_instance(0.08, "i0"))
    assert m_f["finished"] == 4
    assert sorted(cl_f.retired) == ["i0"]
    assert m_f["restarts"] > 0
    assert m_f["instances"]["i0"]["retired"] is True


def test_parity_autoscaler_full_loop():
    """The SLO autoscaler observing, scaling out under pressure and
    scaling in as load drains — every tick and action an explicit event —
    must be bit-identical across fast and exact modes (decisions,
    metrics, action log, instance-count timeline)."""
    from repro.core.config import TenantClass
    from repro.runtime.autoscale import AutoscaleCfg, SLOAutoscaler
    from repro.workload.tenants import (TenantSpec, TenantWorkloadCfg,
                                        generate_tenants)
    wl = generate_tenants(TenantWorkloadCfg(
        tenants=(
            TenantSpec(TenantClass("interactive", priority=10,
                                   slo_ttft_ms=500, slo_tpot_ms=10,
                                   weight=3.0),
                       rate_share=2.0, mean_prompt=30, max_prompt=60,
                       mean_output=40, max_output=80),
            TenantSpec(TenantClass("batch", priority=0,
                                   slo_ttft_ms=10_000, slo_tpot_ms=1000),
                       rate_share=1.0, mean_prompt=60, max_prompt=120,
                       mean_output=120, max_output=240)),
        n_requests=60, rate=100.0, arrival="diurnal", seed=3, vocab=1000))
    sched = SchedulerCfg(max_batch_size=4, max_batch_tokens=512,
                         policy="priority", share_guard_tokens=512)
    ccfg = ClusterCfg((_inst("i0", scheduler=sched),),
                      router=RouterCfg("least_loaded"))

    def attach(cl):
        cl.attach_autoscaler(SLOAutoscaler(AutoscaleCfg(
            interval_s=0.5, queue_high=2.0, queue_low=0.5,
            min_instances=1, max_instances=6)))

    m_f, cl_f, m_e, _ = _pair(ccfg, wl, _registry(_slow_iter_trace()),
                              setup=attach)
    assert m_f["finished"] == 60
    a = m_f["autoscale"]
    assert a["n_scale_out"] > 0 and a["n_scale_in"] > 0
    assert a == m_e["autoscale"]          # action log + timeline, exactly
    # the fleet actually breathed: timeline reaches >1 and returns toward 1
    sizes = [n for _, n in a["timeline"]]
    assert max(sizes) > 1 and sizes[-1] < max(sizes)
    # per-tenant rollup is part of the parity surface too
    assert m_f["tenants"] == m_e["tenants"]


# --------------------------------------------------------------------------
# determinism gating
# --------------------------------------------------------------------------

def test_fast_forward_gating():
    from repro.runtime.backends.sim import SimBackend
    dense = _inst()
    assert SimBackend(dense).supports_fast_forward
    assert not SimBackend(dense, fast_path=False).supports_fast_forward

    moe_spec = model_spec_from_arch(get_config(MOE_ARCH))
    moe = InstanceCfg(name="m0", hw=TPU_V5E, model=moe_spec,
                      scheduler=SchedulerCfg(max_batch_size=8))
    # statistical router (no covering trace): stateful RNG -> exact mode
    assert not SimBackend(moe).supports_fast_forward
    # a trace covering moe_ffn in both phases restores determinism
    t = Trace(model="m", hardware="h", tp=1)
    for phase in ("prefill", "decode"):
        for tok in (1, 16, 256):
            t.add("moe_ffn", phase, tok, 256, 1e-4 * tok)
    assert SimBackend(moe, trace=t).supports_fast_forward

    # spec decode draws are step-ordinal-dependent -> exact mode
    from repro.spec import register_acceptance
    from repro.workload.acceptance import (AcceptanceConfig,
                                           synthesize_acceptance)
    register_acceptance("ffgate-acc", synthesize_acceptance(
        AcceptanceConfig(alpha=0.5, k=3, period=16)))
    spec_cfg = _inst(scheduler=SchedulerCfg(max_batch_size=8,
                                            decode_tokens=4),
                     spec=SpecCfg(enabled=True, k=3,
                                  acceptance_trace="ffgate-acc"))
    assert not SimBackend(spec_cfg).supports_fast_forward


# --------------------------------------------------------------------------
# decode_window == stepped iteration_latency (the pricing contract)
# --------------------------------------------------------------------------

def _stepped(pm, items, n):
    items = [dataclasses.replace(i) for i in items]
    out = []
    for s in range(n):
        if s:
            for it in items:
                it.context += 1
        out.append(pm.iteration_latency(items).total_s)
    return out


def test_decode_window_matches_stepped_pricing_op_level(tiny_trace):
    pm = PerfModel(_inst(), trace=tiny_trace)
    items = [BatchItem(tokens=1, context=50 + 3 * i, phase="decode")
             for i in range(4)]
    win = pm.decode_window(items, 40)
    assert win is not None and len(win) == 40
    assert win.tolist() == _stepped(pm, items, 40)   # bit-identical


def _iter_trace():
    t = Trace(model="m", hardware="h", tp=1)
    for B in (1, 2, 4, 8, 16):
        for ctx in (16, 64, 256, 1024):
            t.add("iter", "decode", B, ctx, 1e-4 * B + 1e-6 * ctx)
    for T in (16, 64, 256):
        t.add("iter", "prefill", T, T, 1e-3)
    return t


def test_decode_window_matches_stepped_pricing_iter_level():
    pm = PerfModel(_inst(), trace=_iter_trace())
    items = [BatchItem(tokens=1, context=60 + i, phase="decode")
             for i in range(3)]
    win = pm.decode_window(items, 25)
    assert win is not None
    assert win.tolist() == _stepped(pm, items, 25)


def test_decode_window_refuses_unvectorizable_batches(tiny_trace):
    pm = PerfModel(_inst(), trace=tiny_trace)
    # a prefill item cannot be window-advanced
    assert pm.decode_window([BatchItem(tokens=8, context=8,
                                       phase="prefill")], 4) is None
    # no trace at all -> per-item analytical fallback would engage
    assert PerfModel(_inst()).decode_window(
        [BatchItem(tokens=1, context=32, phase="decode")], 4) is None


def test_decode_pad_to_prices_padded_width():
    """Regression: a half-full decode batch must be priced at the padded
    slot width (the engine pads to ``decode_pad_to``), not the occupancy —
    and the window path must agree with the stepped path about it."""
    spec = model_spec_from_arch(get_config(ARCH))
    t = _iter_trace()
    padded = InstanceCfg(name="i0", hw=TPU_V5E, model=spec,
                         scheduler=SchedulerCfg(max_batch_size=16,
                                                decode_pad_to=8))
    pm = PerfModel(padded, trace=t)
    items = [BatchItem(tokens=1, context=64, phase="decode")
             for _ in range(2)]
    got = pm.iteration_latency(items).total_s
    assert got == t.interpolate("iter", "decode", 8, 64)      # B=8, not 2
    assert got != t.interpolate("iter", "decode", 2, 64)
    assert pm.decode_window(items, 10).tolist() == _stepped(pm, items, 10)
    # without padding the occupancy is priced
    plain = InstanceCfg(name="i1", hw=TPU_V5E, model=spec,
                        scheduler=SchedulerCfg(max_batch_size=16))
    pm0 = PerfModel(plain, trace=t)
    assert pm0.iteration_latency(items).total_s \
        == t.interpolate("iter", "decode", 2, 64)


# --------------------------------------------------------------------------
# trace index + memo + interpolation kernel
# --------------------------------------------------------------------------

def test_scalar_vector_lookup_bit_identity():
    """``interpolate_many`` element i must equal the scalar
    ``interpolate`` at the same key EXACTLY — the fast==exact contract
    crosses this boundary.  The power-of-two grid creates exact distance
    ties, exercising the stable tie-break."""
    rng = np.random.default_rng(1)
    t = Trace(model="m", hardware="h", tp=1)
    for tok in (1, 2, 4, 8, 16):
        for ctx in (16, 32, 64, 128):
            t.add("op", "decode", tok, ctx, float(rng.uniform(1e-5, 1e-2)))
    toks = rng.integers(1, 32, 200)
    ctxs = rng.integers(1, 300, 200)
    vec = t.interpolate_many("op", "decode", toks.astype(np.float64),
                             ctxs.astype(np.float64))
    for i in range(len(toks)):
        assert vec[i] == t.interpolate("op", "decode", int(toks[i]),
                                       int(ctxs[i]))


def test_interpolation_matches_nearest4_idw_reference():
    rng = np.random.default_rng(7)
    t = Trace(model="m", hardware="h", tp=1)
    pts = [(int(tok), int(ctx), float(rng.uniform(1e-5, 1e-2)))
           for tok in (1, 3, 9, 27) for ctx in (10, 100, 1000)]
    for tok, ctx, lat in pts:
        t.add("op", "decode", tok, ctx, lat)

    def ref(tok, ctx):
        lt = np.log(np.float64(max(tok, 1)))
        lc = np.log(np.float64(max(ctx, 1)))
        d = [(np.log(np.float64(p[0])) - lt) ** 2
             + 0.25 * (np.log(np.float64(p[1])) - lc) ** 2 for p in pts]
        order = sorted(range(len(pts)), key=lambda i: (d[i], i))[:4]
        if d[order[0]] < 1e-12:
            return pts[order[0]][2]
        w = [1.0 / d[i] for i in order]
        return float(np.exp(sum(wi * np.log(np.float64(pts[i][2]))
                                for wi, i in zip(w, order)) / sum(w)))

    for tok, ctx in ((2, 50), (5, 500), (30, 5), (1, 10), (9, 100)):
        assert t.interpolate("op", "decode", tok, ctx) \
            == pytest.approx(ref(tok, ctx), rel=1e-9)
    # exact grid hits return the measured latency verbatim
    assert t.interpolate("op", "decode", 3, 100) == pts[4][2]


def test_add_invalidates_index_and_memo():
    t = Trace(model="m", hardware="h", tp=1)
    t.add("op", "decode", 1, 16, 1e-4)
    t.add("op", "decode", 8, 128, 8e-4)
    v1 = t.interpolate("op", "decode", 4, 64)     # IDW blend, memoized
    assert v1 == t.interpolate("op", "decode", 4, 64)
    t.add("op", "decode", 4, 64, 3.14e-4)         # exact point at the key
    v2 = t.interpolate("op", "decode", 4, 64)
    assert v2 == 3.14e-4 and v2 != v1
    # vector path sees the new index too
    assert t.interpolate_many("op", "decode",
                              np.asarray([4.0]), np.asarray([64.0]))[0] \
        == 3.14e-4


def test_single_point_grid_scales_linearly_in_tokens():
    t = Trace(model="m", hardware="h", tp=1)
    t.add("op", "prefill", 16, 16, 2e-3)
    assert t.interpolate("op", "prefill", 32, 64) == pytest.approx(4e-3)
    assert t.interpolate_many("op", "prefill", np.asarray([32.0]),
                              np.asarray([64.0]))[0] == pytest.approx(4e-3)
