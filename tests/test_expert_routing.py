"""Trace-driven MoE expert routing: sim/real expert-load parity, artifact
round-trip + legacy migration, routing-hook contract, and trace-driven
pricing (in the style of ``tests/test_hw_trace.py``).

The parity tests replay one synthetic zipf ``ExpertRoutingTrace`` through
both execution backends on the same workload and pin *identical* per-layer
expert token counts — the backends derive token positions independently
(sim from the scheduler's request bookkeeping, real from the engine's slot
lengths), so agreement means the unified runtime's chunking/position
accounting matches what the real engine executed.
"""
import dataclasses
import json

import numpy as np
import pytest

from repro.configs import get_config
from repro.core import ClusterCfg, InstanceCfg, MoECfg, RouterCfg
from repro.core.cluster import Cluster
from repro.core.config import TPU_V5E, ModelSpec, ParallelismCfg, SchedulerCfg
from repro.core.perfmodel import BatchItem, PerfModel
from repro.moe import (SCHEMA_VERSION, ExpertRoutingTrace, RoutingRegistry,
                       moe_layer_count, register_routing)
from repro.workload import ShareGPTConfig, generate
from repro.workload.expert_skew import SkewConfig, synthesize_routing

ARCH = "granite-moe-1b-a400m-tiny"


def _tiny_trace(seed=7, kind="zipf", zipf_a=1.4, period=128):
    cfg = get_config(ARCH)
    return synthesize_routing(
        moe_layer_count(cfg), cfg.moe.n_experts, cfg.moe.top_k,
        SkewConfig(kind=kind, zipf_a=zipf_a, period=period, seed=seed),
        model=cfg.name)


def _workload(vocab, n=6, seed=3):
    reqs = generate(ShareGPTConfig(
        n_requests=n, rate=50.0, vocab=vocab, seed=seed,
        mean_prompt=40, mean_output=6, sigma_prompt=0.4, sigma_output=0.3,
        max_prompt=90, max_output=8, share_fraction=0.0))
    for r in reqs:
        r.arrival = 0.0     # decision parity must not depend on latencies
    return reqs


# --------------------------------------------------------------------------
# sim/real parity
# --------------------------------------------------------------------------

def _run_parity_pair(scheduler: SchedulerCfg):
    from repro.serve import DriverCfg, ServeDriver, ServingEngine
    from repro.serve.driver import engine_instance_cfg

    cfg = get_config(ARCH)
    trace = _tiny_trace()
    register_routing("parity-zipf", trace)
    reqs = _workload(vocab=cfg.vocab)

    eng = ServingEngine(cfg, max_batch=2, max_len=256, name="e0",
                        routing=trace)
    drv = ServeDriver([eng], DriverCfg(scheduler=scheduler))
    real = drv.run(reqs, warmup=False)

    icfg = engine_instance_cfg(eng, scheduler,
                               moe=MoECfg(routing_trace="parity-zipf"))
    sim_cluster = Cluster(ClusterCfg(instances=(icfg,),
                                     router=RouterCfg("round_robin")))
    sim_cluster.submit_workload(reqs)
    sim = sim_cluster.run()
    return trace, real, sim


def test_sim_real_expert_load_parity_chunked():
    """One zipf trace, two engines, identical per-layer expert counts —
    with chunked prefill, so extend-path positions are exercised too."""
    sched = SchedulerCfg(max_batch_size=2, max_batch_tokens=64,
                         chunked_prefill=True, prefill_chunk=16)
    trace, real, sim = _run_parity_pair(sched)
    assert real["finished"] == sim["finished"] == 6
    r = real["instances"]["e0"]["expert_load"]
    s = sim["instances"]["e0"]["expert_load"]
    assert r["tokens"] == s["tokens"] > 0
    assert r["counts"] == s["counts"]
    assert np.asarray(r["counts"]).shape == (trace.n_layers,
                                             trace.n_experts)
    # counts conserve tokens: every routed token hits exactly top_k experts
    assert np.asarray(r["counts"]).sum() == \
        r["tokens"] * trace.top_k * trace.n_layers
    assert r["imbalance"] == pytest.approx(s["imbalance"])
    assert r["per_layer_imbalance"] == pytest.approx(
        s["per_layer_imbalance"])
    assert r["hot_expert"] == s["hot_expert"]
    # capacity-drop accounting is derived from the same counts + the one
    # shared expert_capacity definition on both backends
    assert r["dropped"] == s["dropped"]
    assert r["routed"] == s["routed"] > 0
    assert r["drop_rate"] == s["drop_rate"]
    # the replayed zipf skew is actually visible in the counts
    total = np.asarray(s["counts"]).sum(axis=0)
    assert total.max() > 1.5 * total.min()


def test_sim_real_expert_load_parity_engine_matched():
    """Whole-prompt prefill semantics (the engine's historical loop)."""
    from repro.core.config import engine_scheduler_cfg
    trace, real, sim = _run_parity_pair(engine_scheduler_cfg(2))
    r = real["instances"]["e0"]["expert_load"]
    s = sim["instances"]["e0"]["expert_load"]
    assert r["counts"] == s["counts"]
    assert r["tokens"] == s["tokens"] > 0


def test_cluster_level_expert_load_on_both_paths():
    """metrics()["expert_load"] is the acceptance surface: reported by the
    sim cluster and the real driver alike, rolled up over instances."""
    sched = SchedulerCfg(max_batch_size=2, max_batch_tokens=64,
                         chunked_prefill=True, prefill_chunk=16)
    trace, real, sim = _run_parity_pair(sched)
    for m in (real, sim):
        el = m["expert_load"]
        assert el["counts"] == real["expert_load"]["counts"]
        assert el["instances_merged"] == 1
        assert el["imbalance"] > 1.0
        assert el["hot_expert"] is not None
        times = [t for t, _, _ in el["hot_timeline"]]
        assert times == sorted(times) and len(times) > 0


# --------------------------------------------------------------------------
# routing hook contract (real model side)
# --------------------------------------------------------------------------

def test_replay_hook_returns_trace_assignments():
    import jax.numpy as jnp
    from repro.moe.hooks import make_replay_hook
    trace = _tiny_trace(period=16)
    hook = make_replay_hook(trace)
    positions = jnp.asarray([0, 5, 15, 16, 33])   # wraps mod period
    idx, w, aux = hook(jnp.zeros((5, trace.n_experts)),
                       positions=positions, layer=0, top_k=trace.top_k)
    expect = trace.assignments_for(0, np.asarray([0, 5, 15, 16, 33]))
    np.testing.assert_array_equal(np.asarray(idx), expect)
    np.testing.assert_allclose(np.asarray(w), 1.0 / trace.top_k)
    assert float(aux) == 0.0


def test_replay_hook_changes_real_model_routing():
    """Forcing two different (balanced, capacity-safe) routings through
    the same params must change the computed output — the hook really
    routes in-graph, it is not just metric bookkeeping."""
    import jax
    from repro.models import Model
    from repro.moe.hooks import make_replay_hook

    cfg = get_config(ARCH)
    E, k, L = cfg.moe.n_experts, cfg.moe.top_k, moe_layer_count(cfg)

    def forced(shift):
        # position p -> experts [(p+shift) % E, (p+shift+1) % E]: balanced
        # across experts, so no token is dropped by the capacity buffers
        # (an everyone-to-one-expert table would overflow capacity and
        # zero the late tokens' contributions under EVERY forcing)
        p = np.arange(32)[:, None]
        table = ((p + shift + np.arange(k)[None, :]) % E).astype(np.int32)
        return ExpertRoutingTrace(model=cfg.name, n_experts=E, top_k=k,
                                  layers=[table.copy() for _ in range(L)])

    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 16), 0, cfg.vocab)
    base = Model(cfg, remat=False)
    params = base.init(jax.random.PRNGKey(0))
    out = {}
    for shift in (0, 2):
        model = Model(cfg, remat=False,
                      routing_hook=make_replay_hook(forced(shift)))
        logits, _ = model.forward(params, toks)
        out[shift] = np.asarray(logits, np.float32)
    assert not np.allclose(out[0], out[2])
    # determinism: the same forced trace reproduces identical logits
    model = Model(cfg, remat=False,
                  routing_hook=make_replay_hook(forced(0)))
    again, _ = model.forward(params, toks)
    np.testing.assert_array_equal(out[0], np.asarray(again, np.float32))


def test_invalid_rows_never_consume_expert_capacity():
    """Pad tails / empty decode slots are routed by the jitted batch too;
    under forced replay they would all hit the same table row and could
    evict real tokens from the capacity buffers — dispatch must send them
    straight to overflow so a real token's output is identical with or
    without invalid neighbors."""
    import jax
    import jax.numpy as jnp
    from repro.models.moe import moe_ffn
    from repro.moe.hooks import make_replay_hook

    d, de, E, k = 16, 8, 4, 2
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    params = {"router": jax.random.normal(ks[0], (d, E)),
              "w_gate": jax.random.normal(ks[1], (E, d, de)) * 0.1,
              "w_up": jax.random.normal(ks[2], (E, d, de)) * 0.1,
              "w_down": jax.random.normal(ks[3], (E, de, d)) * 0.1}
    def replay(table):
        return make_replay_hook(ExpertRoutingTrace(
            model="m", n_experts=E, top_k=k,
            layers=[np.asarray(table, np.int32)]))

    x = jax.random.normal(ks[4], (4, d))
    pos = jnp.arange(4)
    # capacity C = round(4*2*1.25/4) = 3.  Mixed batch: two INVALID rows
    # forced onto the same experts {0,1} as the two real rows — stable
    # sorting would hand them capacity slots 0,1 and push a real entry
    # past C if they were not excluded from dispatch.
    hot = replay([[0, 1]] * 4)
    y_mixed, _ = moe_ffn(x, params, top_k=k, router_fn=hot,
                         positions=pos,
                         valid=jnp.asarray([False, False, True, True]))
    # reference at the SAME T (same capacity): extra rows are valid but
    # routed to disjoint experts, so the real rows face no competition
    apart = replay([[2, 3], [2, 3], [0, 1], [0, 1]])
    y_ref, _ = moe_ffn(x, params, top_k=k, router_fn=apart,
                       positions=pos,
                       valid=jnp.asarray([True, True, True, True]))
    np.testing.assert_allclose(np.asarray(y_mixed[2:], np.float32),
                               np.asarray(y_ref[2:], np.float32),
                               rtol=1e-5, atol=1e-6)
    # and invalid rows contribute nothing
    np.testing.assert_array_equal(np.asarray(y_mixed[:2], np.float32), 0.0)


def test_bias_hook_steers_toward_trace_skew():
    import jax
    import jax.numpy as jnp
    from repro.moe.hooks import make_bias_hook
    trace = _tiny_trace(zipf_a=2.5, period=64)
    hook = make_bias_hook(trace, strength=25.0)
    logits = jax.random.normal(jax.random.PRNGKey(0),
                               (256, trace.n_experts))
    idx, w, _ = hook(logits, positions=jnp.arange(256), layer=0,
                     top_k=trace.top_k)
    counts = np.bincount(np.asarray(idx).reshape(-1),
                         minlength=trace.n_experts)
    ref = np.zeros(trace.n_experts, np.int64)
    for l in range(trace.n_layers):
        ref += trace.counts_for(l, np.arange(trace.period))
    # a strong bias concentrates load on the trace's hot expert
    assert counts.argmax() == ref.argmax()


def test_engine_rejects_mismatched_trace():
    from repro.serve import ServingEngine
    cfg = get_config(ARCH)
    bad = synthesize_routing(moe_layer_count(cfg), 8, 2,
                             SkewConfig(period=32), model="other")
    with pytest.raises(ValueError, match="experts"):
        ServingEngine(cfg, max_batch=2, max_len=64, routing=bad)


# --------------------------------------------------------------------------
# artifact round-trip / schema / registry
# --------------------------------------------------------------------------

def test_trace_roundtrip_and_deterministic_bytes(tmp_path):
    t = synthesize_routing(2, 8, 2, SkewConfig(zipf_a=1.2, period=64,
                                               seed=3), model="m")
    p1 = t.save(str(tmp_path / "a.json"))
    loaded = ExpertRoutingTrace.load(p1)
    assert loaded.n_layers == 2 and loaded.period == 64
    assert (loaded.model, loaded.n_experts, loaded.top_k) == ("m", 8, 2)
    for a, b in zip(t.layers, loaded.layers):
        np.testing.assert_array_equal(a, b)
    assert json.load(open(p1))["schema"] == SCHEMA_VERSION
    # replay equivalence: same counts for arbitrary positions
    pos = np.asarray([0, 1, 63, 64, 200])
    np.testing.assert_array_equal(t.counts_for(1, pos),
                                  loaded.counts_for(1, pos))
    # fixed seed => byte-identical artifact
    t2 = synthesize_routing(2, 8, 2, SkewConfig(zipf_a=1.2, period=64,
                                                seed=3), model="m")
    p2 = t2.save(str(tmp_path / "b.json"))
    assert open(p1, "rb").read() == open(p2, "rb").read()


def test_legacy_moetrace1_loads_and_migrates(tmp_path):
    """moetrace/1 (one shared table + n_layers) loads by replication and
    re-saves as moetrace/2 with identical routing."""
    shared = synthesize_routing(1, 4, 2, SkewConfig(period=32, seed=1))
    legacy = str(tmp_path / "legacy.json")
    json.dump({
        "schema": "moetrace/1", "model": "m", "n_experts": 4, "top_k": 2,
        "n_layers": 3, "assignments": shared.layers[0].tolist(),
        "meta": {"source": "synthetic"},
    }, open(legacy, "w"))
    loaded = ExpertRoutingTrace.load(legacy)
    assert loaded.n_layers == 3
    pos = np.arange(48)
    for l in range(3):
        np.testing.assert_array_equal(loaded.counts_for(l, pos),
                                      shared.counts_for(0, pos))
    migrated = str(tmp_path / "migrated.json")
    loaded.save(migrated)
    doc = json.load(open(migrated))
    assert doc["schema"] == "moetrace/2"
    assert [g["layer"] for g in doc["layers"]] == [0, 1, 2]
    re = ExpertRoutingTrace.load(migrated)
    np.testing.assert_array_equal(re.counts_for(2, pos),
                                  shared.counts_for(0, pos))


def test_schema_gate_and_validation(tmp_path):
    t = synthesize_routing(1, 4, 2, SkewConfig(period=16))
    path = t.save(str(tmp_path / "t.json"))
    doc = json.load(open(path))
    doc["schema"] = "moetrace/999"
    json.dump(doc, open(path, "w"))
    with pytest.raises(ValueError, match="schema"):
        ExpertRoutingTrace.load(path)
    # out-of-range expert ids never reach disk
    bad = synthesize_routing(1, 4, 2, SkewConfig(period=16))
    bad.layers[0][0, 0] = 9
    with pytest.raises(ValueError, match="out of range"):
        bad.save(str(tmp_path / "bad.json"))
    with pytest.raises(ValueError, match="top_k"):
        ExpertRoutingTrace(model="m", n_experts=2, top_k=4,
                           layers=[np.zeros((4, 4), np.int32)]).validate()


def test_registry_resolution_and_model_check(tmp_path):
    from repro.moe import resolve_routing
    reg = RoutingRegistry()
    t = synthesize_routing(2, 8, 2, SkewConfig(period=32), model="m")
    reg.load_file(t.save(str(tmp_path / "routing.json")))
    assert reg.names() == ["routing"]
    model = ModelSpec(name="m", n_layers=2, d_model=64, n_heads=4,
                      n_kv_heads=2, d_head=16, d_ff=128, vocab=256,
                      moe_experts=8, moe_top_k=2, moe_d_expert=32)
    icfg = InstanceCfg(name="i0", hw=TPU_V5E, model=model,
                       moe=MoECfg(routing_trace="routing"))
    assert resolve_routing(icfg, reg) is reg.get("routing")
    # structural mismatch is an error, not a silent clamp
    wrong = dataclasses.replace(model, moe_experts=16, moe_top_k=4)
    bad = dataclasses.replace(icfg, model=wrong)
    with pytest.raises(ValueError, match="experts"):
        resolve_routing(bad, reg)
    # unknown names fail with guidance
    missing = dataclasses.replace(icfg,
                                  moe=MoECfg(routing_trace="nope"))
    with pytest.raises(KeyError, match="record-routing"):
        resolve_routing(missing, reg)
    # hw registry must skip routing artifacts in traces/ silently (the
    # profile --experts workflow puts them there by design)
    import warnings
    from repro.hw import HardwareRegistry
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        assert HardwareRegistry().load_dir(str(tmp_path)) == []


def test_capacity_drop_rate_binds_under_skew():
    """When capacity_factor binds, overflow entries register as drops —
    a hot trace drops, a uniform one (at the same capacity) does not,
    and the tracker's capacity matches the real dispatch's
    (``repro.core.expert.expert_capacity``)."""
    from repro.core.expert import expert_capacity
    from repro.moe import ExpertLoadTracker

    hot = synthesize_routing(2, 4, 2, SkewConfig(kind="zipf", zipf_a=3.0,
                                                 period=64, seed=1))
    uni = synthesize_routing(2, 4, 2, SkewConfig(kind="uniform",
                                                 period=64, seed=1))
    pos = np.arange(64)
    for trace, expect_drops in ((hot, True), (uni, False)):
        tr = ExpertLoadTracker(trace, capacity_factor=1.25)
        tr.observe(pos, now=0.0)
        m = tr.metrics()
        cap = expert_capacity(64, 2, 4, 1.25)
        want = sum(int(np.maximum(trace.counts_for(l, pos) - cap, 0).sum())
                   for l in range(2))
        assert m["dropped"] == want
        assert (m["drop_rate"] > 0) == expect_drops
        assert m["routed"] == 64 * 2 * 2
    # without a capacity factor the metric reports zero, not garbage
    tr = ExpertLoadTracker(hot)
    tr.observe(pos, now=0.0)
    assert tr.metrics()["drop_rate"] == 0.0


def test_pim_offload_prices_nontrivially():
    """InstanceCfg.pim (or the PIM_DEVICE fallback) makes offload="pim"
    change pricing — the historical spec-less default silently priced it
    identically to no offload."""
    from repro.core.config import PIM_DEVICE
    model = ModelSpec(name="m", n_layers=4, d_model=1536, n_heads=24,
                      n_kv_heads=8, d_head=64, d_ff=512, vocab=32000,
                      moe_experts=40, moe_top_k=8, moe_d_expert=512)
    items = [BatchItem(tokens=2048, context=2048, phase="prefill")]

    def price(moe, pim=None):
        icfg = InstanceCfg(name="i0", hw=TPU_V5E, model=model,
                           parallelism=ParallelismCfg(tp=8, ep=8),
                           moe=moe, pim=pim)
        return PerfModel(icfg).iteration_latency(items).total_s

    base = price(MoECfg())
    pim_default = price(MoECfg(offload="pim", offload_fraction=0.75,
                               prefetch=True))
    pim_named = price(MoECfg(offload="pim", offload_fraction=0.75,
                             prefetch=True), pim=PIM_DEVICE)
    assert pim_default != base
    assert pim_default == pim_named        # fallback == explicit preset
    # a slower memory-side device prices offload slower
    import dataclasses as dc
    slow = dc.replace(PIM_DEVICE, peak_flops=PIM_DEVICE.peak_flops / 16,
                      hbm_bw=PIM_DEVICE.hbm_bw / 16, name="slow-pim")
    assert price(MoECfg(offload="pim", offload_fraction=0.75,
                        prefetch=True), pim=slow) > pim_named


# --------------------------------------------------------------------------
# trace-driven pricing (SimBackend / PerfModel)
# --------------------------------------------------------------------------

def test_skewed_trace_prices_prefill_slower_than_uniform():
    """Expert-parallel prefill pays the trace's imbalance factor: the same
    batch under a hot zipf trace is slower than under a uniform one."""
    model = ModelSpec(name="m", n_layers=4, d_model=1536, n_heads=24,
                      n_kv_heads=8, d_head=64, d_ff=512, vocab=32000,
                      moe_experts=40, moe_top_k=8, moe_d_expert=512)
    icfg = InstanceCfg(name="i0", hw=TPU_V5E, model=model,
                       parallelism=ParallelismCfg(tp=8, ep=8))
    uni = synthesize_routing(4, 40, 8, SkewConfig(kind="uniform",
                                                  period=512, seed=0))
    hot = synthesize_routing(4, 40, 8, SkewConfig(kind="zipf", zipf_a=2.0,
                                                  period=512, seed=0))
    items = [BatchItem(tokens=4096, context=4096, phase="prefill")]
    lat_u = PerfModel(icfg, routing=uni).iteration_latency(items).total_s
    lat_h = PerfModel(icfg, routing=hot).iteration_latency(items).total_s
    assert lat_h > lat_u > 0
    # and the statistical-router fallback still works with no trace
    assert PerfModel(icfg).iteration_latency(items).total_s > 0


def test_recorder_distills_bucketed_tables():
    from repro.moe.record import RoutingRecorder
    rec = RoutingRecorder(n_layers=1, n_experts=4, top_k=2, period=8)
    # position 0 overwhelmingly routes to {3, 1}; position 1 to {0, 2}
    for _ in range(5):
        rec.tap(0, np.asarray([0, 1]), np.asarray([[3, 1], [0, 2]]))
    rec.tap(0, np.asarray([0]), np.asarray([[2, 0]]))
    t = rec.to_trace(model="m")
    assert sorted(t.layers[0][0].tolist()) == [1, 3]
    assert sorted(t.layers[0][1].tolist()) == [0, 2]
    # unseen positions fall back to the layer-global top-k
    glob = sorted(t.layers[0][5].tolist())
    assert glob == sorted(np.argsort(-rec.hist[0].sum(0),
                                     kind="stable")[:2].tolist())
    assert t.meta["source"] == "recorded"
    # pad-tail / empty-slot rows are masked out, not histogrammed
    before = rec.hist.copy()
    rec.tap(0, np.asarray([0, 6]), np.asarray([[0, 1], [0, 1]]),
            valid=np.asarray([False, True]))
    delta = rec.hist - before
    assert delta[0, 0].sum() == 0 and delta[0, 6].sum() == 2
    # disabled recorder ignores taps (warmup exclusion)
    rec.enabled = False
    before = rec.hist.copy()
    rec.tap(0, np.asarray([0]), np.asarray([[0, 1]]))
    np.testing.assert_array_equal(before, rec.hist)


def test_recording_counts_exactly_the_workload_tokens():
    """Pad tails, free decode slots, AND occupied-but-unscheduled slots
    (mid-chunked-prefill during a decode iteration) must contribute zero
    observations: the full-buffer decode computes their rows anyway, so
    both historical leaks — free slots' stale length bumps across
    consecutive decode-only iterations, and mid-prefill slots riding in
    the decode batch — once inflated recorded traces with phantom rows."""
    from repro.moe.hooks import make_recording_hook
    from repro.moe.record import RoutingRecorder
    from repro.serve import DriverCfg, ServeDriver, ServingEngine

    cfg = get_config(ARCH)
    rec = RoutingRecorder(moe_layer_count(cfg), cfg.moe.n_experts,
                          cfg.moe.top_k, period=64)
    rec.enabled = False
    # max_batch 4, 3 requests, chunked prefill with a tiny token budget:
    # decode iterations overlap other requests' prefill chunks AND a slot
    # stays free throughout — both phantom-row geometries at once
    eng = ServingEngine(cfg, max_batch=4, max_len=128, name="r0",
                        routing=make_recording_hook(rec))
    sched = SchedulerCfg(max_batch_size=4, max_batch_tokens=32,
                         chunked_prefill=True, prefill_chunk=16)
    drv = ServeDriver([eng], DriverCfg(scheduler=sched))
    drv.runtime.warmup()
    rec.enabled = True
    reqs = generate(ShareGPTConfig(
        n_requests=3, rate=50.0, vocab=cfg.vocab, seed=2, mean_prompt=30,
        mean_output=10, max_prompt=60, max_output=12, share_fraction=0.0))
    drv.runtime.submit_workload(reqs)
    drv.runtime.run()
    # prompt tokens + (output - 1) decode steps, top_k entries each, per
    # MoE layer — nothing more, nothing less
    rows = sum(r.prompt_len + r.output_len - 1 for r in reqs)
    assert int(rec.hist.sum()) == \
        rows * cfg.moe.top_k * moe_layer_count(cfg)


def test_jax_backend_rejects_unreplayed_cfg_trace():
    """A cfg-named routing trace the engine does not replay must fail
    loudly: accounting it anyway would report routing that never ran."""
    from repro.runtime.backends.jax_engine import JaxBackend
    from repro.serve import ServingEngine
    from repro.serve.driver import engine_instance_cfg
    cfg = get_config(ARCH)
    register_routing("unreplayed", _tiny_trace())
    eng = ServingEngine(cfg, max_batch=2, max_len=64)   # no routing=
    icfg = engine_instance_cfg(eng,
                               moe=MoECfg(routing_trace="unreplayed"))
    with pytest.raises(ValueError, match="replays no trace"):
        JaxBackend(eng, icfg)
    # an engine replaying a DIFFERENT trace than cfg names is just as
    # wrong: accounting and execution would use different tables
    other = _tiny_trace(seed=99)
    eng2 = ServingEngine(cfg, max_batch=2, max_len=64, routing=other)
    with pytest.raises(ValueError, match="different trace"):
        JaxBackend(eng2, icfg)
