"""Unit tests for the discrete-event queue (``repro.core.engine``):
cancellation bookkeeping, ``run(until=...)`` re-push semantics, past-time
clamping, the event cutoff, and the barrier-horizon view the decode
fast-forward path relies on."""
from repro.core.engine import EventQueue


def test_cancel_updates_live_count_and_empty():
    q = EventQueue()
    seen = []
    q.schedule(1.0, lambda: seen.append(1))
    e2 = q.schedule(2.0, lambda: seen.append(2))
    assert not q.empty
    q.cancel(e2)
    q.cancel(e2)                      # idempotent
    assert q._n_live == 1
    q.run()
    assert seen == [1]
    assert q.empty
    assert q.now == 1.0
    assert q.n_processed == 1         # cancelled events never count


def test_run_until_repushes_future_event():
    q = EventQueue()
    seen = []
    q.schedule(5.0, lambda: seen.append(q.now))
    q.run(until=3.0)
    assert q.now == 3.0 and seen == []
    assert not q.empty                # the event survived the early stop
    q.run(until=10.0)
    assert seen == [5.0] and q.now == 5.0


def test_schedule_at_past_time_clamps_to_now():
    q = EventQueue()
    seen = []
    q.schedule(2.0, lambda: q.schedule_at(
        1.0, lambda: seen.append(q.now)))
    q.run()
    assert seen == [2.0]              # never travels back in time


def test_max_events_cutoff():
    q = EventQueue()

    def reschedule():
        q.schedule(1.0, reschedule)

    q.schedule(1.0, reschedule)
    q.run(max_events=10)
    assert q.n_processed == 10
    assert not q.empty


def test_next_barrier_skips_skippable_and_cancelled():
    q = EventQueue()
    q.schedule(1.0, lambda: None, skippable=True)
    b1 = q.schedule(2.0, lambda: None)
    b2 = q.schedule(3.0, lambda: None)
    assert q.next_barrier_time() == 2.0
    q.cancel(b1)
    assert q.next_barrier_time() == 3.0
    q.cancel(b2)
    assert q.next_barrier_time() == float("inf")


def test_next_barrier_excludes_the_executing_event():
    """From inside a handler, the event being executed is no longer
    pending — the horizon must look past it (this is what lets an
    instance fast-forward from its own completion event)."""
    q = EventQueue()
    seen = []
    q.schedule(1.0, lambda: seen.append(q.next_barrier_time()))
    q.schedule(5.0, lambda: None)
    q.run()
    assert seen == [5.0]


def test_next_barrier_capped_by_run_until():
    """A ``run(until=...)`` bound is itself a horizon: a fast-forward
    window computed mid-run must not outrun the caller's stopping point,
    even when the next real barrier is farther out."""
    q = EventQueue()
    seen = []
    q.schedule(1.0, lambda: seen.append(q.next_barrier_time()))
    q.schedule(9.0, lambda: None)
    q.run(until=4.0)
    assert seen == [4.0]
