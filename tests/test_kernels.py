"""Pallas kernel validation: shape/dtype sweeps vs the pure-jnp oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

TOLS = {jnp.float32: dict(rtol=2e-5, atol=2e-5),
        jnp.bfloat16: dict(rtol=2e-2, atol=2e-2)}


@pytest.mark.parametrize("S,H,KV,dh", [
    (64, 4, 4, 16), (128, 4, 2, 32), (256, 8, 2, 16), (64, 2, 1, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(S, H, KV, dh, dtype):
    B = 2
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, S, H, dh), dtype)
    k = jax.random.normal(ks[1], (B, S, KV, dh), dtype)
    v = jax.random.normal(ks[2], (B, S, KV, dh), dtype)
    out = ops.flash_attention(q, k, v, bq=32, bkv=32)
    want = ref.flash_attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               **TOLS[dtype])


@pytest.mark.parametrize("B,H,KV,dh,ps,maxp", [
    (2, 4, 2, 16, 16, 4), (3, 8, 4, 32, 8, 6), (1, 2, 1, 64, 32, 3)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_attention_sweep(B, H, KV, dh, ps, maxp, dtype):
    P = B * maxp + 2
    ks = jax.random.split(jax.random.PRNGKey(1), 4)
    q = jax.random.normal(ks[0], (B, H, dh), dtype)
    kp = jax.random.normal(ks[1], (P, ps, KV, dh), dtype)
    vp = jax.random.normal(ks[2], (P, ps, KV, dh), dtype)
    table = jax.random.permutation(ks[3], P)[: B * maxp].reshape(B, maxp)
    table = table.astype(jnp.int32)
    lengths = jnp.array([(i % maxp) * ps + ps // 2 + 1 for i in range(B)],
                        jnp.int32)
    out = ops.paged_attention(q, kp, vp, table, lengths, page_size=ps)
    want = ref.paged_attention_ref(q, kp, vp, table, lengths, page_size=ps)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               **TOLS[dtype])


@pytest.mark.parametrize("E,C,d,f", [(4, 64, 32, 16), (8, 128, 16, 64),
                                     (2, 32, 128, 8)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_moe_gmm_sweep(E, C, d, f, dtype):
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    x = jax.random.normal(ks[0], (E, C, d), dtype)
    w = jax.random.normal(ks[1], (E, d, f), dtype)
    gs = jax.random.randint(ks[2], (E,), 0, C + 1).astype(jnp.int32)
    out = ops.moe_gmm(x, w, gs, bc=32)
    want = ref.moe_gmm_ref(x, w, gs)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               **TOLS[dtype])
