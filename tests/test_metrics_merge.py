"""Direct unit tests for the cluster-level metric mergers
(``repro.core.metrics``): the dedup/anchoring rules are load-bearing for
multi-instance rollups but were previously only exercised indirectly
through end-to-end runs.
"""
import pytest

from repro.core.metrics import (aggregate, merge_kv_tiers,
                                merge_spec_decode)
from repro.core.request import FINISHED, SimRequest


# --------------------------------------------------------------------------
# merge_kv_tiers: dedup by cache name
# --------------------------------------------------------------------------

def _tier_stats(cache, device=10, host=4, ssd=0, hit_dev=100,
                transfers=None):
    return {"cache": cache,
            "residency_blocks": {"device": device, "host": host, "ssd": ssd},
            "hit_tokens": {"device": hit_dev, "host": 0, "ssd": 0},
            "transfers": transfers or {}}


def test_merge_kv_tiers_dedups_shared_cache_by_name():
    """A ``scope="global"`` radix tree shows up in every instance's stats
    under one shared cache name — its residency must be counted ONCE."""
    shared = [_tier_stats("global", device=10, host=4, hit_dev=100)
              for _ in range(3)]
    m = merge_kv_tiers(shared)
    assert m["caches_merged"] == 1
    assert m["residency_blocks"] == {"device": 10, "host": 4, "ssd": 0}
    assert m["hit_tokens"]["device"] == 100


def test_merge_kv_tiers_sums_distinct_caches():
    stats = [
        _tier_stats("i0", device=10, host=2, hit_dev=50,
                    transfers={"device->host": {"blocks": 3, "bytes": 300.0}}),
        _tier_stats("i1", device=7, host=0, ssd=5, hit_dev=20,
                    transfers={"device->host": {"blocks": 1, "bytes": 100.0},
                               "host->ssd": {"blocks": 5, "bytes": 500.0}}),
    ]
    m = merge_kv_tiers(stats)
    assert m["caches_merged"] == 2
    assert m["residency_blocks"] == {"device": 17, "host": 2, "ssd": 5}
    assert m["hit_tokens"]["device"] == 70
    assert m["transfers"]["device->host"] == {"blocks": 4, "bytes": 400.0}
    assert m["transfers"]["host->ssd"] == {"blocks": 5, "bytes": 500.0}


def test_merge_kv_tiers_mixed_shared_and_private():
    """One global cache seen twice plus one private cache: the global
    counts once, the private adds on top."""
    stats = [_tier_stats("global", device=10),
             _tier_stats("global", device=10),
             _tier_stats("i1-private", device=3, host=1)]
    m = merge_kv_tiers(stats)
    assert m["caches_merged"] == 2
    assert m["residency_blocks"]["device"] == 13
    assert m["residency_blocks"]["host"] == 5


# --------------------------------------------------------------------------
# merge_spec_decode: most-common-k anchoring
# --------------------------------------------------------------------------

def _spec_stats(k, steps, accepted, proposed=None):
    return {"k": k, "steps": steps,
            "proposed_tokens": proposed if proposed is not None
            else steps * k,
            "accepted_tokens": accepted,
            "accepted_hist": [0] * (k + 1),
            "step_timeline": []}


def test_merge_spec_decode_anchors_on_most_common_k():
    """Mixed draft lengths cannot be summed: the rollup anchors on the
    most common ``k`` (not dict/list order) and skips the rest."""
    stats = [_spec_stats(4, steps=10, accepted=20),
             _spec_stats(2, steps=99, accepted=99),   # first, but minority
             _spec_stats(4, steps=30, accepted=60)]
    stats = [stats[1], stats[0], stats[2]]            # minority k first
    m = merge_spec_decode(stats)
    assert m["k"] == 4
    assert m["instances_merged"] == 2                 # undercount reported
    assert m["steps"] == 40
    assert m["accepted_tokens"] == 80
    assert m["proposed_tokens"] == 160
    assert m["acceptance_rate"] == pytest.approx(0.5)
    assert m["mean_accepted_len"] == pytest.approx(2.0)
    assert m["emitted_tokens"] == 80 + 40
    assert m["wasted_draft_tokens"] == 80
    assert len(m["accepted_hist"]) == 5               # k+1 bins for k=4


def test_merge_spec_decode_uniform_k_merges_all():
    stats = [_spec_stats(3, steps=5, accepted=10) for _ in range(4)]
    m = merge_spec_decode(stats)
    assert m["instances_merged"] == 4
    assert m["steps"] == 20 and m["accepted_tokens"] == 40


# --------------------------------------------------------------------------
# aggregate: no-ITL regression (single-token outputs)
# --------------------------------------------------------------------------

def _finished_req(req_id, output_len, token_times):
    r = SimRequest(req_id=req_id, arrival=0.0,
                   prompt_tokens=list(range(8)), output_len=output_len)
    r.state = FINISHED
    r.generated = output_len
    r.token_times = list(token_times)
    r.t_first_token = token_times[0]
    r.t_finish = token_times[-1]
    r.kv_blocks_peak = 1
    return r


def test_aggregate_reports_none_itl_for_single_token_outputs():
    """Every output is one token -> no inter-token latencies exist; the
    aggregate must say None, not fabricate a perfect 0.0."""
    m = aggregate([_finished_req(0, 1, [0.5]), _finished_req(1, 1, [0.7])])
    assert m["finished"] == 2
    assert m["itl_mean_s"] is None
    assert m["itl_p99_s"] is None
    assert m["ttft_mean_s"] > 0                # other stats still computed


def test_aggregate_itl_present_with_multi_token_outputs():
    m = aggregate([_finished_req(0, 3, [0.5, 0.6, 0.8])])
    assert m["itl_mean_s"] == pytest.approx(0.15)
    assert m["itl_p99_s"] == pytest.approx(0.2, rel=0.05)
