"""Multi-tier KV offload: accounting invariants, eviction policies,
read-only routing probes, and sim/real tier parity.

The regression anchors here are the two accounting bugs this layer
shipped with: ``promote`` leaking ``mem.host.used`` (the host pool filled
with ghosts until ``host_spill`` permanently failed) and lower-tier nodes
being unreclaimable (``_evict_one`` skipped every non-device node, so
``host.used`` grew monotonically and spill silently degraded to drop).
``RadixPrefixCache.check_invariants`` pins the repaired bookkeeping:
per-tier node counts match the counters and every lower tier's pool holds
exactly ``blocks * bytes_per_block``.
"""
import dataclasses

import pytest

from repro.configs import get_config
from repro.core import ClusterCfg, RouterCfg, simulate
from repro.core.cluster import Cluster
from repro.core.config import (TPU_V5E, InstanceCfg, ModelSpec,
                               ParallelismCfg, PrefixCacheCfg, SchedulerCfg)
from repro.core.memory import MemoryModel
from repro.runtime.prefix_cache import (RadixPrefixCache,
                                        eviction_policies)
from repro.serve import DriverCfg, ServeDriver, ServingEngine
from repro.serve.driver import engine_instance_cfg, engine_scheduler_cfg
from repro.workload import ShareGPTConfig, generate
from repro.workload.sharegpt import Request

TINY = ModelSpec(name="tiny", n_layers=2, d_model=64, n_heads=4,
                 n_kv_heads=2, d_head=16, d_ff=128, vocab=256)
BLOCK = 8
BPB = TINY.kv_bytes_per_token * BLOCK      # bytes per radix/KV block


def _cache(device_blocks=2, host_blocks=2, ssd_blocks=0, policy="lru",
           host_spill=True, ssd_spill=False):
    hw = dataclasses.replace(TPU_V5E, hbm_capacity=1e9,
                             host_capacity=host_blocks * BPB,
                             ssd_capacity=ssd_blocks * BPB)
    pc = PrefixCacheCfg(enabled=True, block_tokens=BLOCK,
                        host_spill=host_spill, ssd_spill=ssd_spill,
                        eviction_policy=policy)
    icfg = InstanceCfg(name="t", hw=hw, model=TINY, kv_block_tokens=BLOCK,
                       prefix_cache=pc)
    mem = MemoryModel(icfg)
    assert mem.bytes_per_block == BPB
    cache = RadixPrefixCache(pc, mem)
    cache.capacity_blocks = device_blocks    # exact, tiny, test-controlled
    return cache, mem


def _prefix(seed: int, blocks: int):
    return [seed * 1000 + j for j in range(blocks * BLOCK)]


# ---------------------------------------------------------------------------
# satellite 1: promote must release the lower-tier bytes it vacates
# ---------------------------------------------------------------------------

def test_promote_spill_round_trip_releases_host_bytes():
    cache, mem = _cache(device_blocks=2, host_blocks=4)
    a, b, c = _prefix(1, 1), _prefix(2, 1), _prefix(3, 1)
    cache.insert(a, 1.0)
    cache.insert(b, 2.0)                 # device now at capacity
    cache.insert(c, 3.0)                 # LRU victim (a) spills to host
    cache.check_invariants()
    assert cache.n_host_blocks == 1
    assert mem.host.used == BPB
    assert cache.tier_transfers["device->host"]["blocks"] == 1

    m = cache.match(a, 4.0)
    assert m.host_tokens == BLOCK and m.device_tokens == 0
    assert m.lower_tier_bytes == BPB
    cache.capacity_blocks = 3            # room to promote without evicting
    cache.promote(m.nodes, 4.0)
    cache.check_invariants()
    # the regression: promote decremented n_host_blocks but left
    # mem.host.used claimed, leaking the host pool one block per promote
    assert cache.n_host_blocks == 0
    assert mem.host.used == 0.0
    assert cache.tier_transfers["host->device"]["blocks"] == 1
    m2 = cache.match(a, 5.0)
    assert m2.device_tokens == BLOCK and m2.lower_tier_bytes == 0.0


def test_repeated_round_trips_never_leak():
    cache, mem = _cache(device_blocks=2, host_blocks=2)
    a, b = _prefix(1, 1), _prefix(2, 1)
    cache.insert(a, 0.0)
    cache.insert(b, 1.0)
    for t in range(2, 22):
        # alternate pressure so a and b keep swapping tiers
        victim_prefix = a if t % 2 == 0 else b
        m = cache.match(victim_prefix, float(t))
        if m.lower_tier_bytes > 0:
            cache.promote(m.nodes, float(t))
        cache.release_pressure(1, float(t) + 0.5)
        cache.check_invariants()
        assert mem.host.used <= mem.host.capacity


# ---------------------------------------------------------------------------
# satellite 2: lower tiers are reclaimable (host -> ssd -> drop)
# ---------------------------------------------------------------------------

def test_host_tier_evicts_to_ssd_then_drops_under_pressure():
    cache, mem = _cache(device_blocks=2, host_blocks=2, ssd_blocks=2,
                        ssd_spill=True)
    for s in range(8):
        cache.insert(_prefix(s, 1), float(s))
        cache.check_invariants()
    # cascaded demotion kept every tier at capacity instead of failing
    assert cache.n_device_blocks == 2
    assert cache.n_host_blocks == 2
    assert cache.n_ssd_blocks == 2
    assert mem.host.used == 2 * BPB <= mem.host.capacity
    assert mem.ssd.used == 2 * BPB <= mem.ssd.capacity
    assert cache.tier_transfers["host->ssd"]["blocks"] >= 1
    assert cache.tier_transfers["ssd->drop"]["blocks"] >= 1


def test_host_tier_drops_when_ssd_disabled():
    cache, mem = _cache(device_blocks=2, host_blocks=2, ssd_spill=False)
    for s in range(8):
        cache.insert(_prefix(s, 1), float(s))
        cache.check_invariants()
    # the regression: host-tier nodes were never evicted, so host.used
    # grew monotonically and device eviction degraded to silent drops
    assert cache.n_host_blocks == 2
    assert mem.host.used == 2 * BPB
    assert cache.tier_transfers["host->drop"]["blocks"] >= 1
    assert cache.n_ssd_blocks == 0 and mem.ssd.used == 0.0


# ---------------------------------------------------------------------------
# satellite 3: routing probes are read-only
# ---------------------------------------------------------------------------

def test_peek_touches_no_state():
    cache, _ = _cache(device_blocks=4, host_blocks=4)
    a = _prefix(1, 2)
    cache.insert(a, 1.0)
    nodes = cache._walk(a)
    before = [(nd.last_access, nd.accesses) for nd in nodes]
    h, ms = cache.hits, cache.misses
    for _ in range(5):
        m = cache.peek(a)
        assert m.tokens == 2 * BLOCK
        assert cache.peek(_prefix(9, 1)).tokens == 0
    assert (cache.hits, cache.misses) == (h, ms)
    assert [(nd.last_access, nd.accesses) for nd in nodes] == before
    # the accounting match still works and is the only thing that counts
    cache.match(a, 2.0)
    assert (cache.hits, cache.misses) == (h + 1, ms)


DENSE = ModelSpec(name="dense-8b", n_layers=32, d_model=4096, n_heads=32,
                  n_kv_heads=8, d_head=128, d_ff=14336, vocab=128256)


def _inst(name, **kw):
    base = dict(hw=TPU_V5E, model=DENSE, n_devices=8,
                parallelism=ParallelismCfg(tp=8),
                scheduler=SchedulerCfg(max_batch_size=32),
                prefix_cache=PrefixCacheCfg(enabled=True))
    base.update(kw)
    return InstanceCfg(name=name, **base)


@pytest.mark.parametrize("policy", ["prefix_aware", "kv_residency"])
def test_dispatching_n_requests_produces_exactly_n_accounting_events(policy):
    """Routing probes across M candidates must not inflate hit/miss
    accounting: N dispatched requests -> exactly N match events."""
    n = 40
    reqs = generate(ShareGPTConfig(n_requests=n, rate=20.0, vocab=32000,
                                   share_fraction=0.8, n_conversations=4,
                                   seed=7))
    m = simulate(ClusterCfg((_inst("a"), _inst("b"), _inst("c")),
                            router=RouterCfg(policy)), reqs)
    assert m["finished"] == n
    events = sum(i["prefix_cache"]["hits"] + i["prefix_cache"]["misses"]
                 for i in m["instances"].values())
    assert events == n
    # per-instance residency stats are part of the public metrics surface
    for stats in m["instances"].values():
        kv = stats["kv_tiers"]
        assert set(kv["residency_blocks"]) == {"device", "host", "ssd"}
    assert m["kv_tiers"]["caches_merged"] == 3


# ---------------------------------------------------------------------------
# satellite 4: pinned prefixes survive pressure under every policy
# ---------------------------------------------------------------------------

def test_all_expected_policies_registered():
    assert {"lru", "lfu", "priority"} <= set(eviction_policies())


@pytest.mark.parametrize("policy", sorted(eviction_policies()))
def test_pinned_prefix_survives_release_pressure(policy):
    cache, _ = _cache(device_blocks=4, host_blocks=8, policy=policy)
    shared = _prefix(1, 2)
    cache.insert(shared, 1.0)
    m = cache.match(shared, 2.0)
    cache.pin(m.nodes)
    sibling = _prefix(2, 2)
    cache.insert(sibling, 3.0)
    freed = cache.release_pressure(4, 4.0)
    cache.check_invariants()
    assert freed >= 1
    # pinned shared prefix stays device-resident in full
    assert all(nd.tier == "device" for nd in m.nodes)
    # the unpinned sibling paid: its evictable leaf left the device tier
    sib_nodes = cache._walk(sibling)
    assert any(nd.tier != "device" for nd in sib_nodes) \
        or len(sib_nodes) < 2
    cache.unpin(m.nodes)
    freed2 = cache.release_pressure(4, 5.0)
    cache.check_invariants()
    assert freed2 >= 1            # unpinning makes the prefix reclaimable


def test_lfu_keeps_hot_prefix_lru_would_evict():
    cache, _ = _cache(device_blocks=2, host_blocks=4, policy="lfu")
    hot, cold = _prefix(1, 1), _prefix(2, 1)
    cache.insert(hot, 1.0)
    for t in (2.0, 3.0, 4.0):
        cache.match(hot, t)
    cache.insert(cold, 5.0)       # newer but never re-used
    cache.insert(_prefix(3, 1), 6.0)
    cache.check_invariants()
    # LRU would have evicted hot (older last_access); LFU spills cold
    assert cache._walk(hot)[0].tier == "device"
    assert cache._walk(cold)[0].tier == "host"


def test_priority_weighted_eviction_protects_high_priority_tenant():
    cache, _ = _cache(device_blocks=2, host_blocks=4, policy="priority")
    low, high = _prefix(1, 1), _prefix(2, 1)
    cache.insert(high, 0.5, priority=5)   # older, high-priority tenant
    cache.insert(low, 1.0, priority=0)
    cache.insert(_prefix(3, 1), 2.0, priority=0)
    cache.check_invariants()
    assert cache._walk(high)[0].tier == "device"
    assert cache._walk(low)[0].tier == "host"


def test_unknown_eviction_policy_is_loud():
    hw = dataclasses.replace(TPU_V5E, hbm_capacity=1e9)
    icfg = InstanceCfg(name="t", hw=hw, model=TINY, kv_block_tokens=BLOCK,
                       prefix_cache=PrefixCacheCfg(enabled=True,
                                                   block_tokens=BLOCK))
    mem = MemoryModel(icfg)
    with pytest.raises(ValueError, match="nope"):
        RadixPrefixCache(PrefixCacheCfg(enabled=True, block_tokens=BLOCK,
                                        eviction_policy="nope"), mem)


# ---------------------------------------------------------------------------
# sim/real tier-accounting parity
# ---------------------------------------------------------------------------

ARCH = "llama3.1-8b-tiny"


def _grouped_workload(vocab, n_groups=2, tail=8):
    """Two-phase shared-prefix workload: phase A (t=0) populates the
    cache, phase B (t=1e6, long after A finished on either time axis)
    hits it.  Shared prefixes are exact block multiples (32 tokens) so
    the runtime radix tree and the real KV store agree on restored
    lengths token-for-token."""
    reqs = []
    rid = 0
    for g in range(n_groups):
        base = [(g * 977 + j * 13) % vocab for j in range(32)]
        reqs.append(Request(req_id=rid, arrival=0.0,
                            prompt_tokens=base + [(g * 31 + 1 + j) % vocab
                                                  for j in range(tail)],
                            output_len=4))
        rid += 1
    for g in range(n_groups):
        base = [(g * 977 + j * 13) % vocab for j in range(32)]
        for k in range(2):
            reqs.append(Request(req_id=rid, arrival=1e6,
                                prompt_tokens=base
                                + [(g * 53 + k * 7 + 2 + j) % vocab
                                   for j in range(tail)],
                                output_len=4))
            rid += 1
    return reqs


def test_sim_real_tier_hit_and_restore_accounting_parity():
    """One shared workload, both backends: identical scheduling decisions
    AND identical tier-hit / transfer / restore accounting.  Cache
    capacity is pinned to 3 blocks so phase A's two 2-block prefixes
    force a device->host spill, and phase B's hits restore through the
    lower tier on both backends."""
    cfg = get_config(ARCH)
    reqs = _grouped_workload(cfg.vocab)
    sched = engine_scheduler_cfg(2)

    eng = ServingEngine(cfg, max_batch=2, max_len=256, prefix_cache=True,
                        name="e0")
    drv = ServeDriver([eng], DriverCfg(scheduler=sched))
    for inst in drv.runtime.instances.values():
        inst.cache.capacity_blocks = 3
    real = drv.run(reqs, warmup=False)
    real_dec = {n: i.decisions for n, i in drv.runtime.instances.items()}

    icfg = engine_instance_cfg(eng, sched)
    sim_cluster = Cluster(ClusterCfg(instances=(icfg,),
                                     router=RouterCfg("round_robin")))
    for inst in sim_cluster.instances.values():
        inst.cache.capacity_blocks = 3
    sim_cluster.submit_workload(reqs)
    sim = sim_cluster.run()
    sim_dec = {n: i.decisions for n, i in sim_cluster.instances.items()}

    assert real["finished"] == sim["finished"] == len(reqs)
    assert real_dec == sim_dec

    rkv = real["instances"]["e0"]["kv_tiers"]
    skv = sim["instances"]["e0"]["kv_tiers"]
    for key in ("residency_blocks", "hit_tokens", "transfers"):
        assert rkv[key] == skv[key], key
    assert rkv["restored_tokens"] == skv["restored_tokens"] > 0
    assert rkv["restore_events"] == skv["restore_events"] > 0
    # the workload actually exercised the tier chain
    assert rkv["transfers"].get("device->host", {}).get("blocks", 0) >= 1
    assert rkv["hit_tokens"]["host"] + rkv["hit_tokens"]["ssd"] > 0
    assert real["instances"]["e0"]["prefix_cache"] == \
        sim["instances"]["e0"]["prefix_cache"]
    for inst in sim_cluster.instances.values():
        inst.cache.check_invariants()
    for inst in drv.runtime.instances.values():
        inst.cache.check_invariants()
