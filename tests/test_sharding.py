"""Sharding-rule unit coverage: ``fit_to_mesh`` uneven-shard replication
and ``dp_axes`` pod folding.

``fit_to_mesh`` and ``dp_axes`` only consume ``mesh.axis_names`` /
``mesh.devices.shape`` / ``mesh.shape``, so a lightweight stand-in mesh
lets these rules be tested at production extents (16-way model axis, 2-pod
folding) without 512 real devices.
"""
import numpy as np
import pytest

jax = pytest.importorskip("jax")
from jax.sharding import PartitionSpec as P  # noqa: E402

from repro.launch.mesh import dp_axes, dp_size  # noqa: E402
from repro.launch.sharding import (cache_pspecs, fit_to_mesh,  # noqa: E402
                                   param_pspecs)


class FakeMesh:
    """Duck-typed mesh: axis names + extents, no devices."""

    def __init__(self, shape, axes):
        self.axis_names = tuple(axes)
        self.devices = np.empty(shape, dtype=object)
        self.shape = dict(zip(axes, shape))


class Leaf:
    def __init__(self, *shape):
        self.shape = shape
        self.ndim = len(shape)


MESH16 = FakeMesh((16, 16), ("data", "model"))


def test_fit_to_mesh_replicates_uneven_heads():
    """36 heads x 16 shards does not divide: the sharded dim must fall
    back to replication (pjit boundary shardings divide exactly)."""
    spec = {"wq": P(None, "model")}
    shapes = {"wq": Leaf(512, 36 * 64)}      # 2304 % 16 == 0: kept
    assert fit_to_mesh(spec, shapes, MESH16)["wq"] == P(None, "model")
    shapes = {"wq": Leaf(512, 36)}           # heads dim itself: replicated
    assert fit_to_mesh(spec, shapes, MESH16)["wq"] == P(None, None)


def test_fit_to_mesh_replicates_uneven_experts():
    """40 experts on a 16-way model axis (stacked dim -3) replicate; 64
    experts shard."""
    spec = {"w_gate": P("model", None, None)}
    uneven = {"w_gate": Leaf(40, 64, 32)}
    even = {"w_gate": Leaf(64, 64, 32)}
    assert fit_to_mesh(spec, uneven, MESH16)["w_gate"] == P(None, None, None)
    assert fit_to_mesh(spec, even, MESH16)["w_gate"] == P("model", None, None)


def test_fit_to_mesh_pads_missing_trailing_dims():
    """A spec shorter than the leaf rank is right-padded with None."""
    spec = {"x": P("model")}
    shapes = {"x": Leaf(32, 7, 5)}
    assert fit_to_mesh(spec, shapes, MESH16)["x"] == P("model", None, None)


def test_fit_to_mesh_folded_axes_tuple_entries():
    """A dim sharded over folded ('pod','data') axes needs divisibility by
    the product of the extents."""
    mesh = FakeMesh((2, 16, 16), ("pod", "data", "model"))
    spec = {"b": P(("pod", "data"), None)}
    ok = {"b": Leaf(64, 8)}      # 64 % (2*16) == 0
    bad = {"b": Leaf(24, 8)}     # 24 % 32 != 0
    assert fit_to_mesh(spec, ok, mesh)["b"] == P(("pod", "data"), None)
    assert fit_to_mesh(spec, bad, mesh)["b"] == P(None, None)


def test_dp_axes_pod_folding():
    """The pod axis folds into data-parallelism; the model axis never."""
    single = FakeMesh((16, 16), ("data", "model"))
    multi = FakeMesh((2, 16, 16), ("pod", "data", "model"))
    assert dp_axes(single) == ("data",)
    assert dp_size(single) == 16
    assert dp_axes(multi) == ("pod", "data")
    assert dp_size(multi) == 32
    engine = FakeMesh((1, 2), ("data", "model"))
    assert dp_axes(engine) == ("data",)
    assert dp_size(engine) == 1


def test_param_pspecs_model_size_picks_expert_layout():
    """The MoE expert-stacking heuristic follows the model-axis extent:
    4 experts shard on a tp=2 engine mesh but not on the 16-way pod."""
    params = {"stage0": {"moe": {"w_gate": Leaf(4, 64, 32)}}}
    prod = param_pspecs(params)["stage0"]["moe"]["w_gate"]
    engine = param_pspecs(params, model_size=2)["stage0"]["moe"]["w_gate"]
    assert prod == P(None, None, "model")       # per-expert TP fallback
    assert engine == P("model", None, None)     # expert parallelism


def test_cache_pspecs_model_size_picks_kv_layout():
    """KV-head sharding follows the model-axis extent too: 2 KV heads
    shard the head dim on the 16-way mesh but the KV-head dim at tp=2."""
    cache = {"lengths": Leaf(4),
             "stage0": {"k": Leaf(2, 4, 128, 2, 64),
                        "v": Leaf(2, 4, 128, 2, 64)}}
    prod = cache_pspecs(cache, ("data",), batch=4)
    eng = cache_pspecs(cache, ("data",), batch=4, model_size=2)
    assert prod["stage0"]["k"] == P(None, ("data",), None, None, "model")
    assert eng["stage0"]["k"] == P(None, ("data",), None, "model", None)
